//! Quickstart: the core primitive in 40 lines.
//!
//! Sparsify one stochastic gradient with the paper's optimal probabilities
//! (Algorithm 3), encode it for the wire, decode it back, and check the
//! unbiased-rescaling invariants. Run with:
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use gsparse::coding;
use gsparse::rngkit::{RandArray, Xoshiro256pp};
use gsparse::sparsify::{greedy_probs, sample_sparse};

fn main() {
    // A skewed "gradient": a few large coordinates, many small ones.
    let mut rng = Xoshiro256pp::seed_from_u64(7);
    let d = 4096;
    let g: Vec<f32> = (0..d)
        .map(|i| {
            let base = (rng.next_gaussian() * 0.02) as f32;
            if i % 100 == 0 {
                base + rng.next_gaussian() as f32
            } else {
                base
            }
        })
        .collect();

    // 1. Optimal keep-probabilities targeting 5% density (Algorithm 3).
    let rho = 0.05;
    let mut p = Vec::new();
    let pv = greedy_probs(&g, rho, 2, &mut p);
    println!(
        "expected nnz {:.1} / {d} ({:.2}% density), variance inflation {:.2}x",
        pv.expected_nnz,
        100.0 * pv.expected_nnz / d as f64,
        pv.variance / g.iter().map(|&x| (x as f64).powi(2)).sum::<f64>()
    );

    // 2. Bernoulli sampling + unbiased 1/p rescale.
    let mut rand = RandArray::from_seed(11, 1 << 16);
    let sparse = sample_sparse(&g, &p, pv.inv_lambda, &mut rand);
    println!(
        "sampled {} survivors ({} exact + {} shared-magnitude ±{:.4})",
        sparse.nnz(),
        sparse.exact.len(),
        sparse.shared.len(),
        sparse.shared_mag
    );

    // 3. The §3.3 hybrid wire format.
    let mut wire = Vec::new();
    let encoding = coding::encode(&sparse, &mut wire);
    println!(
        "encoded {} bytes ({encoding:?}) vs {} bytes dense — {:.1}x smaller",
        wire.len(),
        d * 4,
        (d * 4) as f64 / wire.len() as f64
    );

    // 4. Round-trip and verify.
    let back = coding::decode(&wire).expect("round trip");
    assert_eq!(back, sparse);
    let decoded = back.to_dense();
    for i in 0..d {
        if decoded[i] != 0.0 {
            assert_eq!(decoded[i].signum(), g[i].signum());
        }
    }
    println!("round-trip exact; signs preserved; E[Q(g)] = g by construction ✓");
}
