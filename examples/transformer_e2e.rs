//! END-TO-END driver: train a transformer language model for a few hundred
//! steps with data-parallel, per-layer gradient sparsification — proving the
//! whole three-layer stack composes:
//!
//!   L1 Pallas kernels + L2 JAX transformer  --(make artifacts)-->  HLO text
//!   L3 Rust: PJRT load/compile/execute + Algorithm-1 coordinator
//!   (sparsify → encode → all-reduce → decode → Adam), Python not running.
//!
//! ```sh
//! make artifacts
//! cargo run --release --example transformer_e2e -- --steps 200 --rho 0.05
//! ```
//!
//! The loss curve is recorded in EXPERIMENTS.md §E2E. The default artifact
//! is a ~1.6M-parameter model (d_model 128, 2 layers); regenerate artifacts
//! with `python -m compile.aot --e2e-dmodel 256 --e2e-layers 4` for a ~4M
//! variant (see DESIGN.md §Substitutions for the scale rationale).

use gsparse::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.get_parse("steps", 200usize);
    let workers = args.get_parse("workers", 4usize);
    let rho = args.get_parse("rho", 0.05f32);
    gsparse::figures::run_transformer_e2e(steps, workers, rho, args.flag("batch-layers"))
}
