//! Distributed ℓ2-logistic regression (the paper's §5.1 workload) with all
//! three methods side by side — a miniature Figure 1 cell:
//!
//! ```sh
//! cargo run --release --example distributed_logreg
//! ```
//!
//! With `--transport tcp` the same workload additionally runs on the real
//! distributed runtime — one server + workers over loopback TCP sockets —
//! and is checked bitwise against the `InProc` channel backend:
//!
//! ```sh
//! cargo run --release --example distributed_logreg -- --transport tcp
//! ```

use gsparse::config::{ConvexConfig, Method};
use gsparse::coordinator::dist::{self, DistConfig};
use gsparse::coordinator::sync::{estimate_f_star, train_convex, OptKind, TrainOptions};
use gsparse::data::gen_logistic;
use gsparse::metrics::{ascii_plot, XAxis};
use gsparse::model::LogisticModel;
use gsparse::transport::{InProcTransport, TcpTransport};

fn main() {
    let base = ConvexConfig {
        n: 1024,
        d: 2048,
        c1: 0.9,
        c2: 0.0625, // 4^-2: strong gradient sparsity
        reg: 1.0 / (10.0 * 1024.0),
        rho: 0.1,
        workers: 4,
        batch: 8,
        epochs: 20,
        lr: 1.0,
        method: Method::Dense,
        seed: 2018,
        qsgd_bits: 4,
    };
    println!(
        "N={} d={} M={} batch={} C1={} C2={} — generating data + estimating f*...",
        base.n, base.d, base.workers, base.batch, base.c1, base.c2
    );
    let ds = gen_logistic(base.n, base.d, base.c1, base.c2, base.seed);
    let model = LogisticModel::new(base.reg);
    let f_star = estimate_f_star(&ds, &model, 400, 1.0);
    let opts = TrainOptions {
        opt: OptKind::Sgd,
        f_star,
        ..Default::default()
    };

    let mut curves = Vec::new();
    for method in [Method::Dense, Method::GSpar, Method::UniSp] {
        let mut cfg = base.clone();
        cfg.method = method;
        let curve = train_convex(&cfg, &opts, &ds, &model);
        println!(
            "{:<24} final suboptimality {:.4e}   ideal bits {:>12.3e}   sim net {:>8.1} ms",
            curve.label(),
            curve.final_loss(),
            curve.ledger.ideal_bits as f64,
            curve.points.last().map(|p| p.wall_ms).unwrap_or(0.0),
        );
        curves.push(curve);
    }
    println!("\nSuboptimality vs data passes (log scale):");
    print!("{}", ascii_plot(&curves, 72, 14, XAxis::DataPasses));
    println!("\nSame curves vs communication bits:");
    print!("{}", ascii_plot(&curves, 72, 14, XAxis::CommBits));

    // ---- optional: the real distributed runtime over the transport ----
    let args = gsparse::cli::Args::from_env();
    let Some(backend) = args.get("transport") else {
        return;
    };
    let codec = args
        .get("codec")
        .map(|s| gsparse::coding::WireCodec::parse(s).expect("codec raw|entropy"))
        .unwrap_or_default();
    let cfg = DistConfig {
        workers: args.get_parse("dist-workers", 2),
        rounds: args.get_parse("rounds", 300),
        method: Method::GSpar,
        rho: base.rho,
        qsgd_bits: base.qsgd_bits,
        batch: base.batch,
        lr: base.lr,
        seed: base.seed,
        n: base.n,
        d: base.d,
        c1: base.c1,
        c2: base.c2,
        reg: base.reg,
        codec,
    };
    println!(
        "\nDistributed runtime: {} workers x {} rounds over '{backend}' vs 'inproc'...",
        cfg.workers, cfg.rounds
    );
    let inproc = dist::run_threads(InProcTransport::new(), "logreg", &cfg)
        .expect("inproc cluster");
    let other = match backend {
        "inproc" => None,
        "tcp" => Some(
            dist::run_threads(TcpTransport::new(), "127.0.0.1:0", &cfg)
                .expect("tcp loopback cluster"),
        ),
        b => panic!("unknown transport {b} (inproc|tcp)"),
    };
    for (name, rep) in std::iter::once(("inproc", &inproc))
        .chain(other.iter().map(|r| ("tcp", r)))
    {
        let ledger = &rep.curve.ledger;
        println!(
            "{name:>7}: final loss {:.6}  wire {} B  measured {} B ({:.2}x framing)  \
             sim net {:.1} ms",
            rep.final_loss,
            ledger.wire_bytes,
            ledger.measured_bytes,
            ledger.measured_bytes as f64 / ledger.wire_bytes.max(1) as f64,
            rep.sim_time_s * 1e3,
        );
    }
    if let Some(tcp) = &other {
        assert_eq!(
            tcp.grad_digest, inproc.grad_digest,
            "TCP and InProc must ship bitwise-identical compressed gradients"
        );
        assert_eq!(tcp.final_w, inproc.final_w);
        println!(
            "parity: gradient digest {:#018x} identical across backends ✓",
            tcp.grad_digest
        );
    }
}
