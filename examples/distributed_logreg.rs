//! Distributed ℓ2-logistic regression (the paper's §5.1 workload) with all
//! three methods side by side — a miniature Figure 1 cell:
//!
//! ```sh
//! cargo run --release --example distributed_logreg
//! ```
//!
//! With `--transport tcp` the same workload additionally runs on the real
//! distributed runtime — one server + workers over loopback TCP sockets —
//! and is checked bitwise against the `InProc` channel backend:
//!
//! ```sh
//! cargo run --release --example distributed_logreg -- --transport tcp
//! ```

use gsparse::api::{DistTask, MethodSpec, Session, SyncTask};
use gsparse::config::Method;
use gsparse::coordinator::sync::{estimate_f_star, OptKind};
use gsparse::data::gen_logistic;
use gsparse::metrics::{ascii_plot, XAxis};
use gsparse::model::LogisticModel;
use gsparse::transport::{InProcTransport, TcpTransport};

fn main() {
    // The paper's §5.1 workload: N=1024, d=2048, C1=0.9, C2=4^-2 (strong
    // gradient sparsity), M=4 workers, minibatch 8.
    let (n, d) = (1024usize, 2048usize);
    let (c1, c2) = (0.9f32, 0.0625f32);
    let reg = 1.0 / (10.0 * 1024.0);
    let (rho, workers, seed) = (0.1f32, 4usize, 2018u64);
    println!(
        "N={n} d={d} M={workers} batch=8 C1={c1} C2={c2} — generating data + estimating f*..."
    );
    let ds = gen_logistic(n, d, c1, c2, seed);
    let model = LogisticModel::new(reg);
    let f_star = estimate_f_star(&ds, &model, 400, 1.0);
    let task = SyncTask {
        batch: 8,
        epochs: 20,
        lr: 1.0,
        opt: OptKind::Sgd,
        f_star,
        ..SyncTask::default()
    };

    let mut curves = Vec::new();
    for method in [Method::Dense, Method::GSpar, Method::UniSp] {
        let session = Session::builder()
            .method(MethodSpec::from_parts(method, rho, c2 * c1, 4))
            .workers(workers)
            .seed(seed)
            .build();
        let curve = session.train_convex(&task, &ds, &model);
        println!(
            "{:<24} final suboptimality {:.4e}   ideal bits {:>12.3e}   sim net {:>8.1} ms",
            curve.label(),
            curve.final_loss(),
            curve.ledger.ideal_bits as f64,
            curve.points.last().map(|p| p.wall_ms).unwrap_or(0.0),
        );
        curves.push(curve);
    }
    println!("\nSuboptimality vs data passes (log scale):");
    print!("{}", ascii_plot(&curves, 72, 14, XAxis::DataPasses));
    println!("\nSame curves vs communication bits:");
    print!("{}", ascii_plot(&curves, 72, 14, XAxis::CommBits));

    // ---- optional: the real distributed runtime over the transport ----
    let args = gsparse::cli::Args::from_env();
    let Some(backend) = args.get("transport") else {
        return;
    };
    let codec = args
        .get("codec")
        .map(|s| gsparse::coding::WireCodec::parse(s).expect("codec raw|entropy"))
        .unwrap_or_default();
    let dist_session = Session::builder()
        .method(MethodSpec::GSpar { rho, iters: 2 })
        .codec(codec)
        .workers(args.get_parse("dist-workers", 2))
        .seed(seed)
        .build();
    let dist_task = DistTask {
        rounds: args.get_parse("rounds", 300),
        batch: 8,
        lr: 1.0,
        n,
        d,
        c1,
        c2,
        reg,
    };
    println!(
        "\nDistributed runtime: {} workers x {} rounds over '{backend}' vs 'inproc'...",
        dist_session.workers(),
        dist_task.rounds
    );
    let inproc = dist_session
        .dist_threads(InProcTransport::new(), "logreg", &dist_task)
        .expect("inproc cluster");
    let other = match backend {
        "inproc" => None,
        "tcp" => Some(
            dist_session
                .dist_threads(TcpTransport::new(), "127.0.0.1:0", &dist_task)
                .expect("tcp loopback cluster"),
        ),
        b => panic!("unknown transport {b} (inproc|tcp)"),
    };
    for (name, rep) in std::iter::once(("inproc", &inproc))
        .chain(other.iter().map(|r| ("tcp", r)))
    {
        let ledger = &rep.curve.ledger;
        println!(
            "{name:>7}: final loss {:.6}  wire {} B  measured {} B ({:.2}x framing)  \
             sim net {:.1} ms",
            rep.final_loss,
            ledger.wire_bytes,
            ledger.measured_bytes,
            ledger.measured_bytes as f64 / ledger.wire_bytes.max(1) as f64,
            rep.sim_time_s * 1e3,
        );
    }
    if let Some(tcp) = &other {
        assert_eq!(
            tcp.grad_digest, inproc.grad_digest,
            "TCP and InProc must ship bitwise-identical compressed gradients"
        );
        assert_eq!(tcp.final_w, inproc.final_w);
        println!(
            "parity: gradient digest {:#018x} identical across backends ✓",
            tcp.grad_digest
        );
    }
}
