//! Distributed ℓ2-logistic regression (the paper's §5.1 workload) with all
//! three methods side by side — a miniature Figure 1 cell:
//!
//! ```sh
//! cargo run --release --example distributed_logreg
//! ```

use gsparse::config::{ConvexConfig, Method};
use gsparse::coordinator::sync::{estimate_f_star, train_convex, OptKind, TrainOptions};
use gsparse::data::gen_logistic;
use gsparse::metrics::{ascii_plot, XAxis};
use gsparse::model::LogisticModel;

fn main() {
    let base = ConvexConfig {
        n: 1024,
        d: 2048,
        c1: 0.9,
        c2: 0.0625, // 4^-2: strong gradient sparsity
        reg: 1.0 / (10.0 * 1024.0),
        rho: 0.1,
        workers: 4,
        batch: 8,
        epochs: 20,
        lr: 1.0,
        method: Method::Dense,
        seed: 2018,
        qsgd_bits: 4,
    };
    println!(
        "N={} d={} M={} batch={} C1={} C2={} — generating data + estimating f*...",
        base.n, base.d, base.workers, base.batch, base.c1, base.c2
    );
    let ds = gen_logistic(base.n, base.d, base.c1, base.c2, base.seed);
    let model = LogisticModel::new(base.reg);
    let f_star = estimate_f_star(&ds, &model, 400, 1.0);
    let opts = TrainOptions {
        opt: OptKind::Sgd,
        f_star,
        ..Default::default()
    };

    let mut curves = Vec::new();
    for method in [Method::Dense, Method::GSpar, Method::UniSp] {
        let mut cfg = base.clone();
        cfg.method = method;
        let curve = train_convex(&cfg, &opts, &ds, &model);
        println!(
            "{:<24} final suboptimality {:.4e}   ideal bits {:>12.3e}   sim net {:>8.1} ms",
            curve.label(),
            curve.final_loss(),
            curve.ledger.ideal_bits as f64,
            curve.points.last().map(|p| p.wall_ms).unwrap_or(0.0),
        );
        curves.push(curve);
    }
    println!("\nSuboptimality vs data passes (log scale):");
    print!("{}", ascii_plot(&curves, 72, 14, XAxis::DataPasses));
    println!("\nSame curves vs communication bits:");
    print!("{}", ascii_plot(&curves, 72, 14, XAxis::CommBits));
}
