//! CNN on CIFAR-like images (§5.2): per-layer sparsified data-parallel Adam
//! over the AOT-compiled JAX model, dense vs ρ = 0.05 vs ρ = 0.004.
//!
//! Requires artifacts: `make artifacts`, then
//!
//! ```sh
//! cargo run --release --example cnn_cifar_like -- --steps 15
//! ```

use gsparse::api::{MethodSpec, Session};
use gsparse::cli::Args;
use gsparse::data::CifarLike;
use gsparse::model::hlo::HloTrainStep;
use gsparse::opt::Adam;
use gsparse::rngkit::Xoshiro256pp;
use gsparse::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.get_parse("steps", 12usize);
    let channels = args.get_parse("channels", 24usize);
    let workers = 2usize;

    let mut rt = Runtime::cpu()?.with_artifact_dir("artifacts")?;
    let step = HloTrainStep::from_manifest(&mut rt, &format!("cnn{channels}_step"))?;
    println!(
        "cnn{channels}: {} params in {} tensors (per-layer sparsification)",
        step.total_params(),
        step.params.len()
    );
    let ds = CifarLike::generate(512, 3);
    let bsz = step.x_dims[0];
    let layer_dims = step.layer_dims();
    let batch_layers = args.flag("batch-layers");

    for rho in [1.0f32, 0.05, 0.004] {
        let mut params = step.init_params(&mut rt, 0)?;
        let method = if rho >= 1.0 {
            MethodSpec::Dense
        } else {
            MethodSpec::GSpar { rho: rho.min(1.0), iters: 2 }
        };
        let session = Session::builder()
            .method(method)
            .workers(workers)
            .seed(4)
            .batch_layers(batch_layers)
            .build();
        let mut cluster = session.cluster(&layer_dims);
        let mut adams: Vec<Adam> = layer_dims.iter().map(|&d| Adam::new(d, 0.02)).collect();
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let mut x = vec![0.0f32; bsz * CifarLike::PIXELS];
        let mut y = vec![0i32; bsz];
        let mut first = None;
        let mut last = 0.0f32;
        for _ in 0..steps {
            let mut grads = Vec::new();
            let mut loss_sum = 0.0;
            for _ in 0..workers {
                let idx: Vec<usize> = (0..bsz)
                    .map(|_| rng.next_below(ds.n as u64) as usize)
                    .collect();
                ds.batch_into(&idx, &mut x, &mut y);
                let (loss, g) = step.grads(&mut rt, &params, &x, &y)?;
                loss_sum += loss;
                grads.push(g);
            }
            let updates = cluster.round(&grads);
            for ((p, upd), adam) in params.iter_mut().zip(&updates).zip(adams.iter_mut()) {
                adam.step(p, &upd.grad);
            }
            last = loss_sum / workers as f32;
            first.get_or_insert(last);
        }
        println!(
            "rho {:<6} loss {:.3} -> {:.3}   var {:.2}  spa {:.4}  comm {:.2} Mbit (dense would be {:.1})",
            if rho >= 1.0 { "dense".to_string() } else { rho.to_string() },
            first.unwrap(),
            last,
            cluster.var_meter.value(),
            cluster.spa_meter.value(),
            cluster.ledger.ideal_bits as f64 / 1e6,
            (steps * workers * step.total_params() * 32) as f64 / 1e6,
        );
    }
    Ok(())
}
