//! Asynchronous shared-memory SVM (§5.3, Algorithm 4): GSpar vs dense under
//! all three update schemes, reporting wall time, coordinate updates, and
//! CAS conflicts.
//!
//! ```sh
//! cargo run --release --example async_svm
//! ```

use gsparse::config::{AsyncSvmConfig, Method, UpdateScheme};
use gsparse::coordinator::AsyncSvmEngine;
use gsparse::data::gen_svm;

fn main() {
    let n = 8192;
    let d = 256;
    let ds = gen_svm(n, d, 0.01, 0.9, 2018);
    println!("SVM: N={n} d={d} C1=0.01 C2=0.9 (the paper's §5.3 recipe)\n");
    println!(
        "{:<28} {:>9} {:>12} {:>12} {:>12}",
        "config", "wall_ms", "final_loss", "updates", "conflicts"
    );
    for scheme in [UpdateScheme::Lock, UpdateScheme::Atomic, UpdateScheme::Wild] {
        for method in [Method::Dense, Method::GSpar] {
            let cfg = AsyncSvmConfig {
                n,
                d,
                c1: 0.01,
                c2: 0.9,
                reg: 0.1,
                rho: 0.05,
                threads: 8,
                lr: 0.05,
                method,
                seed: 2018,
                total_steps: 40_000,
                scheme,
            };
            let report = AsyncSvmEngine::new(cfg).run(&ds);
            println!(
                "{:<28} {:>9.1} {:>12.5} {:>12} {:>12}",
                format!(
                    "{}+{scheme}",
                    if method == Method::Dense { "dense" } else { "GSpar" }
                ),
                report.wall_ms,
                report.final_loss,
                report.updates,
                report.conflicts
            );
        }
    }
    println!(
        "\nGSpar touches ~ρ·d coordinates per step instead of d, which is what\n\
         reduces lock/CAS conflicts between threads (the §5.3 mechanism)."
    );
}
