//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Supports the forms the `gsparse` binary and examples need:
//! `prog SUBCOMMAND [--flag] [--key value] [--key=value] [positional...]`.

use std::collections::BTreeMap;

/// Parsed arguments: a subcommand, `--key value` options, `--flag` booleans,
/// and positionals, in a deterministic order.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable); `argv[0]` must already be
    /// stripped by the caller.
    pub fn parse_from<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        // First non-flag token is the subcommand.
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                out.consume_option(stripped, &mut it);
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Self {
        Self::parse_from(std::env::args().skip(1))
    }

    fn consume_option<I: Iterator<Item = String>>(
        &mut self,
        stripped: &str,
        it: &mut std::iter::Peekable<I>,
    ) {
        if let Some((k, v)) = stripped.split_once('=') {
            self.opts.insert(k.to_string(), v.to_string());
        } else if it
            .peek()
            .map(|n| !n.starts_with("--"))
            .unwrap_or(false)
        {
            let v = it.next().unwrap();
            self.opts.insert(stripped.to_string(), v);
        } else {
            self.flags.push(stripped.to_string());
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Typed getter with a default; exits with a clear message on a malformed
    /// value (this is a CLI front door, not a library error path).
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            None => default,
            Some(s) => s.parse().unwrap_or_else(|_| {
                eprintln!("error: --{name} expects a {}", std::any::type_name::<T>());
                std::process::exit(2);
            }),
        }
    }

    /// Comma-separated list getter, e.g. `--rho 0.1,0.05,0.01`.
    pub fn get_list<T: std::str::FromStr>(&self, name: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
    {
        match self.get(name) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .map(|p| {
                    p.trim().parse().unwrap_or_else(|_| {
                        eprintln!("error: --{name} expects comma-separated values");
                        std::process::exit(2);
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        // NB: a bare `--flag` followed by a positional is ambiguous in this
        // grammar (the positional becomes the flag's value); callers use
        // `--key=value` style or put positionals first.
        let a = parse("train --rho 0.1 --workers=4 out.csv --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("rho"), Some("0.1"));
        assert_eq!(a.get("workers"), Some("4"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["out.csv"]);
    }

    #[test]
    fn typed_getters() {
        let a = parse("fig --n 100 --eps 0.5");
        assert_eq!(a.get_parse("n", 0usize), 100);
        assert!((a.get_parse("eps", 0.0f64) - 0.5).abs() < 1e-12);
        assert_eq!(a.get_parse("missing", 7u32), 7);
        assert_eq!(a.get_or("missing", "x"), "x");
    }

    #[test]
    fn list_getter() {
        let a = parse("x --rho 0.1,0.05");
        assert_eq!(a.get_list("rho", &[1.0f64]), vec![0.1, 0.05]);
        assert_eq!(a.get_list("other", &[1.0f64]), vec![1.0]);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse("run --fast");
        assert!(a.flag("fast"));
        assert_eq!(a.subcommand.as_deref(), Some("run"));
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--help");
        assert!(a.subcommand.is_none());
        assert!(a.flag("help"));
    }
}
