//! Tiny benchmarking harness (criterion is unavailable in the offline
//! registry — see DESIGN.md §Substitutions).
//!
//! Provides warmed-up, repeated timing with mean / p50 / p95 and throughput
//! reporting, plus a `black_box` to defeat dead-code elimination. All bench
//! targets (`rust/benches/*.rs`, `harness = false`) use this.

use std::time::{Duration, Instant};

/// Re-export of the standard hint; used by benches to keep results alive.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Result of a timed run.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
    /// Optional items-per-iteration for throughput reporting.
    pub items: Option<u64>,
}

impl Stats {
    /// Throughput in items/second, if `items` was set.
    pub fn throughput(&self) -> Option<f64> {
        self.items
            .map(|n| n as f64 / self.mean.as_secs_f64())
    }

    pub fn report(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e9 => format!("  {:>8.2} Gitem/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("  {:>8.2} Mitem/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:>8.2} Kitem/s", t / 1e3),
            Some(t) => format!("  {:>8.2} item/s", t),
            None => String::new(),
        };
        format!(
            "{:<44} mean {:>10?}  p50 {:>10?}  p95 {:>10?}  min {:>10?}{}",
            self.name, self.mean, self.p50, self.p95, self.min, tp
        )
    }
}

/// Benchmark runner: fixed warmup then `samples` timed invocations.
pub struct Bencher {
    samples: usize,
    warmup: usize,
    min_sample_time: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            samples: 30,
            warmup: 3,
            min_sample_time: Duration::from_micros(50),
        }
    }
}

impl Bencher {
    pub fn new(samples: usize, warmup: usize) -> Self {
        Self {
            samples,
            warmup,
            min_sample_time: Duration::from_micros(50),
        }
    }

    /// Quick preset for heavier end-to-end benches.
    pub fn heavy() -> Self {
        Self::new(5, 1)
    }

    /// Time `f`, auto-batching fast functions so each sample is at least
    /// `min_sample_time` long. `items` is the per-invocation work amount
    /// used for throughput (e.g. the gradient dimension).
    pub fn bench<F: FnMut()>(&self, name: &str, items: Option<u64>, mut f: F) -> Stats {
        for _ in 0..self.warmup {
            f();
        }
        // Calibrate batch size.
        let mut batch = 1usize;
        loop {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            if t0.elapsed() >= self.min_sample_time || batch >= 1 << 20 {
                break;
            }
            batch *= 4;
        }
        let mut times: Vec<Duration> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            times.push(t0.elapsed() / batch as u32);
        }
        times.sort();
        let mean = times.iter().sum::<Duration>() / times.len() as u32;
        let stats = Stats {
            name: name.to_string(),
            iters: self.samples * batch,
            mean,
            p50: times[times.len() / 2],
            p95: times[(times.len() * 95 / 100).min(times.len() - 1)],
            min: times[0],
            items,
        };
        println!("{}", stats.report());
        stats
    }
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let b = Bencher::new(5, 1);
        let mut acc = 0u64;
        let s = b.bench("noop-ish", Some(100), || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(s.iters >= 5);
        assert!(s.mean >= Duration::ZERO);
        assert!(s.throughput().unwrap() > 0.0);
        assert!(s.report().contains("noop-ish"));
    }

    #[test]
    fn percentiles_ordered() {
        let b = Bencher::new(10, 1);
        let s = b.bench("sleepless", None, || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(s.min <= s.p50);
        assert!(s.p50 <= s.p95);
        assert!(s.throughput().is_none());
    }
}
