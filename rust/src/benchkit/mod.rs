//! Tiny benchmarking harness (criterion is unavailable in the offline
//! registry — see DESIGN.md §Substitutions).
//!
//! Provides warmed-up, repeated timing with mean / p50 / p95 and throughput
//! reporting, plus a `black_box` to defeat dead-code elimination. All bench
//! targets (`rust/benches/*.rs`, `harness = false`) use this.

use std::time::{Duration, Instant};

/// Re-export of the standard hint; used by benches to keep results alive.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Result of a timed run.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
    /// Optional items-per-iteration for throughput reporting.
    pub items: Option<u64>,
}

impl Stats {
    /// Throughput in items/second, if `items` was set.
    pub fn throughput(&self) -> Option<f64> {
        self.items
            .map(|n| n as f64 / self.mean.as_secs_f64())
    }

    pub fn report(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e9 => format!("  {:>8.2} Gitem/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("  {:>8.2} Mitem/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:>8.2} Kitem/s", t / 1e3),
            Some(t) => format!("  {:>8.2} item/s", t),
            None => String::new(),
        };
        format!(
            "{:<44} mean {:>10?}  p50 {:>10?}  p95 {:>10?}  min {:>10?}{}",
            self.name, self.mean, self.p50, self.p95, self.min, tp
        )
    }
}

/// Benchmark runner: fixed warmup then `samples` timed invocations.
pub struct Bencher {
    samples: usize,
    warmup: usize,
    min_sample_time: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            samples: 30,
            warmup: 3,
            min_sample_time: Duration::from_micros(50),
        }
    }
}

impl Bencher {
    pub fn new(samples: usize, warmup: usize) -> Self {
        Self {
            samples,
            warmup,
            min_sample_time: Duration::from_micros(50),
        }
    }

    /// Quick preset for heavier end-to-end benches.
    pub fn heavy() -> Self {
        Self::new(5, 1)
    }

    /// Time `f`, auto-batching fast functions so each sample is at least
    /// `min_sample_time` long. `items` is the per-invocation work amount
    /// used for throughput (e.g. the gradient dimension).
    pub fn bench<F: FnMut()>(&self, name: &str, items: Option<u64>, mut f: F) -> Stats {
        for _ in 0..self.warmup {
            f();
        }
        // Calibrate batch size.
        let mut batch = 1usize;
        loop {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            if t0.elapsed() >= self.min_sample_time || batch >= 1 << 20 {
                break;
            }
            batch *= 4;
        }
        let mut times: Vec<Duration> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            times.push(t0.elapsed() / batch as u32);
        }
        times.sort();
        let mean = times.iter().sum::<Duration>() / times.len() as u32;
        let stats = Stats {
            name: name.to_string(),
            iters: self.samples * batch,
            mean,
            p50: times[times.len() / 2],
            p95: times[(times.len() * 95 / 100).min(times.len() - 1)],
            min: times[0],
            items,
        };
        println!("{}", stats.report());
        stats
    }
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Machine-readable bench sink: collects [`Stats`] plus derived scalar
/// metrics and writes one JSON document (hand-rolled — serde is unavailable
/// offline). The perf trajectory of the hot path is tracked through these
/// files (`BENCH_*.json`), which CI uploads as artifacts.
#[derive(Debug, Default)]
pub struct JsonReport {
    entries: Vec<String>,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// JSON has no `inf`/`NaN` tokens; non-finite values serialize as `null`.
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

impl JsonReport {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a timed result.
    pub fn push(&mut self, s: &Stats) {
        let tp = s
            .throughput()
            .map(|t| format!(",\"items_per_s\":{}", json_num(t)))
            .unwrap_or_default();
        let items = s
            .items
            .map(|n| format!(",\"items\":{n}"))
            .unwrap_or_default();
        self.entries.push(format!(
            "{{\"name\":\"{}\",\"kind\":\"timing\",\"iters\":{},\"mean_ns\":{},\
             \"p50_ns\":{},\"p95_ns\":{},\"min_ns\":{}{items}{tp}}}",
            json_escape(&s.name),
            s.iters,
            s.mean.as_nanos(),
            s.p50.as_nanos(),
            s.p95.as_nanos(),
            s.min.as_nanos(),
        ));
    }

    /// Record a derived scalar metric (speedups, allocation counts, ...).
    pub fn push_metric(&mut self, name: &str, value: f64) {
        self.entries.push(format!(
            "{{\"name\":\"{}\",\"kind\":\"metric\",\"value\":{}}}",
            json_escape(name),
            json_num(value)
        ));
    }

    /// Serialize the report document.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\"schema\":\"gsparse-bench-v1\",\"results\":[\n");
        out.push_str(&self.entries.join(",\n"));
        out.push_str("\n]}\n");
        out
    }

    /// Write to `path` (e.g. `BENCH_sparsify.json`).
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

/// Counting wrapper around the system allocator, shared by the steady-state
/// allocation test (`tests/alloc_free.rs`) and the `sparsify_micro` bench so
/// both measure the same thing. A `#[global_allocator]` must live in the
/// final binary, so declare it there:
///
/// ```text
/// use gsparse::benchkit::{allocation_count, CountingAllocator};
/// #[global_allocator]
/// static GLOBAL: CountingAllocator = CountingAllocator;
/// let before = allocation_count();
/// // ... hot path ...
/// let allocs = allocation_count() - before;
/// ```
pub struct CountingAllocator;

static ALLOCATION_COUNT: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Total allocations (alloc + alloc_zeroed + realloc) observed so far by
/// [`CountingAllocator`], if it is installed as the global allocator.
pub fn allocation_count() -> u64 {
    ALLOCATION_COUNT.load(std::sync::atomic::Ordering::Relaxed)
}

// SAFETY: a pure pass-through to `System` plus one relaxed counter bump —
// layouts are forwarded untouched, so every GlobalAlloc contract obligation
// (layout validity, pointer provenance, no unwinding) is exactly `System`'s.
unsafe impl std::alloc::GlobalAlloc for CountingAllocator {
    // SAFETY: caller upholds GlobalAlloc's contract; forwarded verbatim.
    unsafe fn alloc(&self, layout: std::alloc::Layout) -> *mut u8 {
        ALLOCATION_COUNT.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // SAFETY: same layout the caller passed under the same contract.
        unsafe { std::alloc::System.alloc(layout) }
    }
    // SAFETY: caller upholds GlobalAlloc's contract; forwarded verbatim.
    unsafe fn alloc_zeroed(&self, layout: std::alloc::Layout) -> *mut u8 {
        ALLOCATION_COUNT.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // SAFETY: same layout the caller passed under the same contract.
        unsafe { std::alloc::System.alloc_zeroed(layout) }
    }
    // SAFETY: caller upholds GlobalAlloc's contract; forwarded verbatim.
    unsafe fn realloc(&self, ptr: *mut u8, layout: std::alloc::Layout, new_size: usize) -> *mut u8 {
        ALLOCATION_COUNT.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // SAFETY: ptr came from this allocator (i.e. from `System`), and
        // layout/new_size are the caller's, under the same contract.
        unsafe { std::alloc::System.realloc(ptr, layout, new_size) }
    }
    // SAFETY: caller upholds GlobalAlloc's contract; forwarded verbatim.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: std::alloc::Layout) {
        // SAFETY: ptr was produced by `System` via this wrapper with the
        // same layout, per the caller's contract.
        unsafe { std::alloc::System.dealloc(ptr, layout) }
    }
}

/// The skewed synthetic gradient the hot-path benches and tests share: ~10%
/// large-magnitude coordinates (σ = 4), a `zero_frac` fraction of exact
/// zeros, and small noise (σ = 0.05) elsewhere — the shape the paper's
/// (ρ,s)-approximate-sparsity analysis targets.
pub fn skewed_gradient(d: usize, seed: u64, zero_frac: f32) -> Vec<f32> {
    let mut rng = crate::rngkit::Xoshiro256pp::seed_from_u64(seed);
    (0..d)
        .map(|_| {
            let u = rng.next_f32();
            if u < 0.1 {
                (rng.next_gaussian() * 4.0) as f32
            } else if u < 0.1 + zero_frac {
                0.0
            } else {
                (rng.next_gaussian() * 0.05) as f32
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let b = Bencher::new(5, 1);
        let mut acc = 0u64;
        let s = b.bench("noop-ish", Some(100), || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(s.iters >= 5);
        assert!(s.mean >= Duration::ZERO);
        assert!(s.throughput().unwrap() > 0.0);
        assert!(s.report().contains("noop-ish"));
    }

    #[test]
    fn json_report_shape() {
        let b = Bencher::new(3, 1);
        let s = b.bench("fast \"op\"", Some(10), || {
            black_box(1 + 1);
        });
        let mut rep = JsonReport::new();
        rep.push(&s);
        rep.push_metric("speedup", 2.5);
        let doc = rep.to_json();
        assert!(doc.starts_with('{') && doc.trim_end().ends_with('}'), "{doc}");
        assert!(doc.contains("\\\"op\\\""), "name must be escaped: {doc}");
        assert!(doc.contains("\"kind\":\"timing\""));
        assert!(doc.contains("\"kind\":\"metric\""));
        assert!(doc.contains("\"value\":2.5"));
        assert!(doc.contains("\"mean_ns\":"));
    }

    #[test]
    fn percentiles_ordered() {
        let b = Bencher::new(10, 1);
        let s = b.bench("sleepless", None, || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(s.min <= s.p50);
        assert!(s.p50 <= s.p95);
        assert!(s.throughput().is_none());
    }
}
