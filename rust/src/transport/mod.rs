//! Pluggable point-to-point transport for the distributed runtime.
//!
//! The paper's claims are about *real* communication cost, so the byte
//! ledger needs a column that was actually measured on a link rather than
//! derived from the α-β model. This module provides that link: a
//! [`Transport`] builds length-delimited framed connections
//! ([`Connection`]) that carry the existing [`crate::coding`] wire bytes,
//! with per-link byte counters ([`LinkCounters`]) accumulating every framed
//! byte — payload plus the 4-byte length prefix plus the handshake.
//!
//! Two backends implement the trait:
//!
//! * [`InProcTransport`] — `mpsc` channels inside one process. This wraps
//!   what the coordinators always did, but through the same framing (the
//!   handshake and every message are encoded to bytes), so its counters are
//!   **byte-for-byte identical** to the TCP backend's — the property the
//!   transport-parity tests pin down.
//! * [`TcpTransport`] — `std::net` sockets over loopback or a real NIC,
//!   with a tiny handshake carrying the protocol version and worker id.
//!
//! The deployment layer on top (connect/accept ordering, config exchange,
//! round scheduling) lives in [`crate::coordinator::dist`].

pub mod frame;
mod inproc;
mod tcp;

pub use frame::{
    Hello, MsgView, TraceCtx, FRAME_OVERHEAD, HELLO_LEN, MAX_FRAME_LEN, MIN_TRANSPORT_VERSION,
    TRANSPORT_VERSION,
};
pub use inproc::InProcTransport;
pub use tcp::TcpTransport;

use std::sync::atomic::{AtomicU64, Ordering};

use crate::sync::{mpsc, thread, Arc};

/// Transport-layer errors. (`Display`/`Error` are hand-written: the offline
/// image has no `thiserror`.)
#[derive(Debug)]
pub enum TransportError {
    /// The peer hung up (socket EOF / channel disconnected).
    Closed,
    /// Underlying socket error.
    Io(std::io::Error),
    /// A frame declared a length above [`MAX_FRAME_LEN`].
    FrameTooLarge(u64),
    /// The first frame was not a well-formed hello.
    BadHandshake(&'static str),
    /// The peer speaks a different protocol version.
    VersionMismatch { ours: u8, theirs: u8 },
    /// The peer announced a different wire codec than this side was
    /// configured with — gradients would be undecodable, so the link is
    /// refused during the handshake.
    CodecMismatch { ours: u8, theirs: u8 },
    /// No listener is bound at the requested in-process address.
    NoSuchAddress(String),
    /// A frame arrived that the protocol state machine did not expect.
    UnexpectedMessage(&'static str),
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Closed => write!(f, "connection closed by peer"),
            TransportError::Io(e) => write!(f, "socket error: {e}"),
            TransportError::FrameTooLarge(n) => {
                write!(f, "frame length {n} exceeds cap {MAX_FRAME_LEN}")
            }
            TransportError::BadHandshake(why) => write!(f, "bad handshake: {why}"),
            TransportError::VersionMismatch { ours, theirs } => {
                write!(f, "protocol version mismatch: ours {ours}, theirs {theirs}")
            }
            TransportError::CodecMismatch { ours, theirs } => {
                write!(f, "wire codec mismatch: ours {ours}, theirs {theirs}")
            }
            TransportError::NoSuchAddress(a) => write!(f, "no listener bound at {a:?}"),
            TransportError::UnexpectedMessage(what) => write!(f, "unexpected message: {what}"),
        }
    }
}

impl std::error::Error for TransportError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TransportError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for TransportError {
    fn from(e: std::io::Error) -> Self {
        match e.kind() {
            std::io::ErrorKind::UnexpectedEof
            | std::io::ErrorKind::ConnectionReset
            | std::io::ErrorKind::BrokenPipe => TransportError::Closed,
            _ => TransportError::Io(e),
        }
    }
}

/// Shared per-link byte/frame counters. Cloning yields another handle to the
/// same counters, so a caller can keep reading after the connection moved
/// into a worker thread or a [`Mux`].
#[derive(Debug, Clone, Default)]
pub struct LinkCounters {
    inner: Arc<CounterCells>,
}

#[derive(Debug, Default)]
struct CounterCells {
    bytes_tx: AtomicU64,
    bytes_rx: AtomicU64,
    frames_tx: AtomicU64,
    frames_rx: AtomicU64,
    frames_vectored: AtomicU64,
}

impl LinkCounters {
    pub fn new() -> Self {
        Self::default()
    }

    pub(crate) fn add_tx(&self, frame_payload_len: usize) {
        self.add_tx_ctx(frame_payload_len, None);
    }

    pub(crate) fn add_rx(&self, frame_payload_len: usize) {
        self.add_rx_ctx(frame_payload_len, None);
    }

    /// [`Self::add_tx`] for a frame whose first payload bytes carried a
    /// [`TraceCtx`]: the `frame_tx` trace event records the context's flow
    /// id and round, linking it to the peer's matching `frame_rx` in a
    /// merged timeline. Counter columns are identical either way.
    pub(crate) fn add_tx_ctx(&self, frame_payload_len: usize, ctx: Option<frame::TraceCtx>) {
        let framed = frame_payload_len as u64 + FRAME_OVERHEAD as u64;
        self.inner.bytes_tx.fetch_add(framed, Ordering::Relaxed);
        self.inner.frames_tx.fetch_add(1, Ordering::Relaxed);
        match ctx {
            Some(c) => crate::trace::counter_flow(
                crate::trace::Stage::FrameTx,
                framed,
                c.flow_id(),
                c.round,
            ),
            None => crate::trace::counter(crate::trace::Stage::FrameTx, framed),
        }
    }

    /// [`Self::add_rx`] for a received frame that carried a [`TraceCtx`].
    pub(crate) fn add_rx_ctx(&self, frame_payload_len: usize, ctx: Option<frame::TraceCtx>) {
        let framed = frame_payload_len as u64 + FRAME_OVERHEAD as u64;
        self.inner.bytes_rx.fetch_add(framed, Ordering::Relaxed);
        self.inner.frames_rx.fetch_add(1, Ordering::Relaxed);
        match ctx {
            Some(c) => crate::trace::counter_flow(
                crate::trace::Stage::FrameRx,
                framed,
                c.flow_id(),
                c.round,
            ),
            None => crate::trace::counter(crate::trace::Stage::FrameRx, framed),
        }
    }

    /// Framed bytes sent on this link (payload + length prefixes).
    pub fn bytes_tx(&self) -> u64 {
        self.inner.bytes_tx.load(Ordering::Relaxed)
    }

    /// Framed bytes received on this link.
    pub fn bytes_rx(&self) -> u64 {
        self.inner.bytes_rx.load(Ordering::Relaxed)
    }

    pub fn frames_tx(&self) -> u64 {
        self.inner.frames_tx.load(Ordering::Relaxed)
    }

    pub fn frames_rx(&self) -> u64 {
        self.inner.frames_rx.load(Ordering::Relaxed)
    }

    /// Record that the last counted tx frame was written by a
    /// scatter/gather path from multiple payload segments — i.e. the
    /// whole-payload assembly copy the contiguous path pays was skipped.
    pub(crate) fn note_vectored(&self) {
        self.inner.frames_vectored.fetch_add(1, Ordering::Relaxed);
        crate::trace::counter(crate::trace::Stage::VectoredTx, 1);
    }

    /// Frames sent zero-copy via multi-segment scatter/gather writes
    /// (no contiguous payload assembly) — the transport bench reports this
    /// as the "saved copy" count of the pipelined path.
    pub fn frames_vectored(&self) -> u64 {
        self.inner.frames_vectored.load(Ordering::Relaxed)
    }

    /// Total framed bytes that crossed the link in either direction.
    pub fn bytes_total(&self) -> u64 {
        self.bytes_tx() + self.bytes_rx()
    }
}

/// One framed, bidirectional link. `send`/`recv` move whole frames; the
/// payload bytes are opaque to the transport (the coordinators put
/// [`frame`]-encoded protocol messages in them).
pub trait Connection: Send {
    /// Send one frame (the payload; the transport adds the length prefix).
    fn send(&mut self, payload: &[u8]) -> Result<(), TransportError>;

    /// Send one frame whose payload is the concatenation of `segments` —
    /// the bytes on the wire are identical to assembling them into one
    /// buffer and calling [`Connection::send`]. The default implementation
    /// does exactly that assembly; backends with scatter/gather writes
    /// (TCP's `write_vectored`) override it to skip the payload copy, and
    /// count the skipped copy in [`LinkCounters::frames_vectored`].
    fn send_vectored(&mut self, segments: &[&[u8]]) -> Result<(), TransportError> {
        let total: usize = segments.iter().map(|s| s.len()).sum();
        let mut buf = Vec::with_capacity(total);
        for s in segments {
            buf.extend_from_slice(s);
        }
        self.send(&buf)
    }

    /// Receive one frame into `buf` (cleared/overwritten; capacity reused).
    fn recv(&mut self, buf: &mut Vec<u8>) -> Result<(), TransportError>;

    /// A handle to this link's byte counters.
    fn counters(&self) -> LinkCounters;

    /// Human-readable peer description (for errors and logs).
    fn peer(&self) -> String;
}

/// Accepts inbound connections. The transport consumes the hello frame
/// during `accept` (validating magic + version); protocol-level agreement
/// (worker count, dimensions, config) is the caller's job.
pub trait Listener: Send {
    fn accept(&mut self) -> Result<(Box<dyn Connection>, Hello), TransportError>;

    /// The address workers should `connect` to (e.g. `127.0.0.1:40319`).
    fn local_addr(&self) -> String;
}

/// A connection factory: one per backend.
pub trait Transport: Send {
    fn listen(&self, addr: &str) -> Result<Box<dyn Listener>, TransportError>;

    /// Connect and send the hello frame; returns the established link.
    fn connect(&self, addr: &str, hello: &Hello) -> Result<Box<dyn Connection>, TransportError>;
}

/// Accept exactly `n` connections and return them ordered by handshake
/// worker id, rejecting out-of-range and duplicate ids and any peer whose
/// announced wire codec differs from `codec` — the shared accept phase of
/// every coordinator (arrival order is scheduler-dependent; the id ordering
/// is what makes runs deterministic, and the codec agreement is what makes
/// every later gradient frame decodable).
pub fn accept_n(
    listener: &mut dyn Listener,
    n: usize,
    codec: crate::coding::WireCodec,
) -> Result<Vec<Box<dyn Connection>>, TransportError> {
    Ok(accept_n_hello(listener, n, codec)?
        .into_iter()
        .map(|(conn, _)| conn)
        .collect())
}

/// [`accept_n`], but keeping each peer's validated [`Hello`] next to its
/// connection — callers that negotiate per-link capabilities (e.g. whether
/// a v2 peer may receive `GRAD_BATCH` frames) read the announced version
/// from it.
pub fn accept_n_hello(
    listener: &mut dyn Listener,
    n: usize,
    codec: crate::coding::WireCodec,
) -> Result<Vec<(Box<dyn Connection>, Hello)>, TransportError> {
    let _span = crate::trace::span(crate::trace::Stage::Handshake);
    let mut slots: Vec<Option<(Box<dyn Connection>, Hello)>> = (0..n).map(|_| None).collect();
    for _ in 0..n {
        let (conn, hello) = listener.accept()?;
        if hello.codec != codec.index() as u8 {
            return Err(TransportError::CodecMismatch {
                ours: codec.index() as u8,
                theirs: hello.codec,
            });
        }
        let wid = hello.worker_id as usize;
        if wid >= n {
            return Err(TransportError::BadHandshake("worker id out of range"));
        }
        if slots[wid].is_some() {
            return Err(TransportError::BadHandshake("duplicate worker id"));
        }
        slots[wid] = Some((conn, hello));
    }
    Ok(slots
        .into_iter()
        .map(|s| s.expect("all slots filled"))
        .collect())
}

/// Arrival-order multiplexer over many connections: one reader thread per
/// link feeds `(id, frame)` pairs into a single queue — how the SSP
/// parameter server consumes pushes from any worker, whichever finishes
/// first (the transport equivalent of the `mpsc` the server used to own).
///
/// The mux owns its connections; callers keep [`LinkCounters`] handles for
/// byte accounting. Iteration ends when every peer has closed its link.
pub struct Mux {
    rx: Option<mpsc::Receiver<(u32, Result<Vec<u8>, TransportError>)>>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl Mux {
    pub fn new(conns: Vec<(u32, Box<dyn Connection>)>) -> Self {
        let (tx, rx) = mpsc::channel();
        let handles = conns
            .into_iter()
            .map(|(id, mut conn)| {
                let tx = tx.clone();
                thread::spawn(move || loop {
                    let mut buf = Vec::new();
                    match conn.recv(&mut buf) {
                        Ok(()) => {
                            if tx.send((id, Ok(buf))).is_err() {
                                break; // mux consumer gone
                            }
                        }
                        Err(TransportError::Closed) => break,
                        Err(e) => {
                            let _ = tx.send((id, Err(e)));
                            break;
                        }
                    }
                })
            })
            .collect();
        Self {
            rx: Some(rx),
            handles,
        }
    }

    /// Next frame from any link, in arrival order; `None` once every link
    /// has closed.
    pub fn recv(&mut self) -> Option<(u32, Result<Vec<u8>, TransportError>)> {
        self.rx.as_ref().and_then(|rx| rx.recv().ok())
    }
}

impl Drop for Mux {
    fn drop(&mut self) {
        // Disconnect the queue first so a reader's next send observes the
        // closed consumer, then reap only the readers that have already
        // exited. A reader still parked in a blocking `recv()` on a live
        // link is detached rather than joined — it exits on its own when
        // the peer closes — so dropping a Mux mid-run (e.g. during a panic
        // unwind) can never hang the process.
        drop(self.rx.take());
        for h in self.handles.drain(..) {
            if h.is_finished() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_framed_bytes() {
        let c = LinkCounters::new();
        c.add_tx(100);
        c.add_tx(0);
        c.add_rx(24);
        assert_eq!(c.bytes_tx(), 100 + 2 * FRAME_OVERHEAD as u64);
        assert_eq!(c.bytes_rx(), 24 + FRAME_OVERHEAD as u64);
        assert_eq!(c.frames_tx(), 2);
        assert_eq!(c.frames_rx(), 1);
        let clone = c.clone();
        c.add_rx(1);
        assert_eq!(clone.frames_rx(), 2, "clones share the same cells");
        assert_eq!(clone.bytes_total(), clone.bytes_tx() + clone.bytes_rx());
        assert_eq!(c.frames_vectored(), 0);
        c.note_vectored();
        assert_eq!(clone.frames_vectored(), 1);
    }

    #[test]
    fn default_send_vectored_concatenates_segments() {
        // The trait default must produce exactly the frame `send` would.
        struct Capture {
            frames: Vec<Vec<u8>>,
            counters: LinkCounters,
        }
        impl Connection for Capture {
            fn send(&mut self, payload: &[u8]) -> Result<(), TransportError> {
                self.counters.add_tx(payload.len());
                self.frames.push(payload.to_vec());
                Ok(())
            }
            fn recv(&mut self, _buf: &mut Vec<u8>) -> Result<(), TransportError> {
                Err(TransportError::Closed)
            }
            fn counters(&self) -> LinkCounters {
                self.counters.clone()
            }
            fn peer(&self) -> String {
                "capture".into()
            }
        }
        let mut c = Capture {
            frames: Vec::new(),
            counters: LinkCounters::new(),
        };
        c.send_vectored(&[b"head", b"", b"tail"]).unwrap();
        c.send_vectored(&[]).unwrap();
        assert_eq!(c.frames, vec![b"headtail".to_vec(), Vec::new()]);
        assert_eq!(c.counters.frames_tx(), 2);
        assert_eq!(c.counters.frames_vectored(), 0, "default path still copies");
    }

    #[test]
    fn accept_n_orders_by_worker_id_and_rejects_bad_ids() {
        use crate::coding::WireCodec;
        let t = InProcTransport::new();
        let mut listener = t.listen("acc").unwrap();
        // Connect out of order; accept_n must hand back id order.
        for wid in [2u32, 0, 1] {
            let _ = t.connect("acc", &Hello::new(wid)).unwrap();
        }
        let conns = accept_n(listener.as_mut(), 3, WireCodec::Raw).unwrap();
        for (wid, conn) in conns.iter().enumerate() {
            assert!(conn.peer().contains(&format!("w{wid}")), "{}", conn.peer());
        }
        // Out-of-range and duplicate ids are clean handshake errors.
        let mut listener = t.listen("acc2").unwrap();
        let _ = t.connect("acc2", &Hello::new(9)).unwrap();
        assert!(matches!(
            accept_n(listener.as_mut(), 2, WireCodec::Raw),
            Err(TransportError::BadHandshake(_))
        ));
        let mut listener = t.listen("acc3").unwrap();
        let _ = t.connect("acc3", &Hello::new(0)).unwrap();
        let _ = t.connect("acc3", &Hello::new(0)).unwrap();
        assert!(matches!(
            accept_n(listener.as_mut(), 2, WireCodec::Raw),
            Err(TransportError::BadHandshake(_))
        ));
    }

    #[test]
    fn accept_n_rejects_codec_mismatch() {
        use crate::coding::WireCodec;
        let t = InProcTransport::new();
        // A raw-codec worker knocking on an entropy-codec server (and the
        // reverse) is refused during the handshake, not mid-run.
        let mut listener = t.listen("codec").unwrap();
        let _ = t.connect("codec", &Hello::new(0)).unwrap();
        assert!(matches!(
            accept_n(listener.as_mut(), 1, WireCodec::Entropy),
            Err(TransportError::CodecMismatch { ours: 1, theirs: 0 })
        ));
        let mut listener = t.listen("codec2").unwrap();
        let _ = t
            .connect("codec2", &Hello::with_codec(0, WireCodec::Entropy))
            .unwrap();
        assert!(matches!(
            accept_n(listener.as_mut(), 1, WireCodec::Raw),
            Err(TransportError::CodecMismatch { ours: 0, theirs: 1 })
        ));
        // Matching codecs proceed.
        let mut listener = t.listen("codec3").unwrap();
        let _ = t
            .connect("codec3", &Hello::with_codec(0, WireCodec::Entropy))
            .unwrap();
        assert_eq!(
            accept_n(listener.as_mut(), 1, WireCodec::Entropy)
                .unwrap()
                .len(),
            1
        );
    }

    #[test]
    fn io_error_eof_maps_to_closed() {
        let eof = std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "eof");
        assert!(matches!(TransportError::from(eof), TransportError::Closed));
        let other = std::io::Error::new(std::io::ErrorKind::PermissionDenied, "no");
        assert!(matches!(TransportError::from(other), TransportError::Io(_)));
    }

    #[test]
    fn errors_display() {
        let msgs = [
            TransportError::Closed.to_string(),
            TransportError::FrameTooLarge(1 << 40).to_string(),
            TransportError::BadHandshake("x").to_string(),
            TransportError::VersionMismatch { ours: 1, theirs: 2 }.to_string(),
            TransportError::CodecMismatch { ours: 0, theirs: 1 }.to_string(),
            TransportError::NoSuchAddress("ps".into()).to_string(),
            TransportError::UnexpectedMessage("weights").to_string(),
        ];
        for m in msgs {
            assert!(!m.is_empty());
        }
    }
}
