//! Frame and protocol-message codec shared by every transport backend.
//!
//! Wire layout of one frame: `u32 LE payload length` + payload. The payload
//! of the first frame on a connection is the handshake ([`Hello`]); every
//! later payload is a tagged protocol message:
//!
//! ```text
//! offset  size  field
//! 0       1     tag
//! 1       ...   body (per-tag layout below)
//! ```
//!
//! * `PULL` — empty body; a worker requesting the current weights.
//! * `WEIGHTS` — `u64 version` + `d × f32 LE` weights.
//! * `WEIGHTS_BATCH` — `u64 version` + `u32 tensor count L` + `L × u32`
//!   per-tensor f32 counts + the concatenated `f32 LE` payloads: a whole
//!   multi-tensor model's weights in **one** frame per pull round-trip,
//!   mirroring what `GRAD_BATCH` does for the upload direction (v3 links
//!   only; v2 peers receive plain `WEIGHTS`).
//! * `GRAD` — `u64 based_on` + `f64 g_norm_sq` + `f64 q_norm_sq` +
//!   `f64 expected_nnz` + `u64 ideal_bits` + `u8 kind` + payload, where
//!   `kind = 0` means the payload is [`crate::coding`] wire bytes and
//!   `kind = 1` means raw dense `f32 LE` (the fallback for quantized
//!   methods whose codec is not implemented as bytes).
//! * `SHUTDOWN` — empty body; the server ending a worker's run.
//! * `CONFIG` — opaque config bytes (the deployment layer defines the
//!   layout; the transport just ships them).
//!
//! Everything here is plain byte shuffling over caller-held buffers — no
//! allocation beyond growing the reused `Vec<u8>`s to their plateau.

use super::TransportError;

/// Bytes of framing prepended to every payload (the `u32` length prefix).
pub const FRAME_OVERHEAD: usize = 4;

/// Hard cap on a single frame's payload, enforced on receive *before*
/// allocating — an adversarial length prefix must not OOM the server.
pub const MAX_FRAME_LEN: usize = 1 << 28; // 256 MiB

/// Transport protocol version carried in every handshake. Version 2 added
/// the negotiated wire-codec byte to the hello; version 3 added the
/// batched `GRAD_BATCH` frame; version 4 added the optional per-frame
/// trace context ([`TraceCtx`], flagged in the tag byte) and the clock
/// `PROBE` frame. The 10-byte hello layout is unchanged across the whole
/// window, so v2–v4 peers interoperate — a v4 side simply never stamps
/// trace contexts on (or sends probes to) a peer whose hello announced an
/// older version, leaving the bytes it ships bitwise identical to a v3
/// run.
pub const TRANSPORT_VERSION: u8 = 4;

/// Oldest hello this side still accepts. Version-2 peers speak the same
/// frame grammar minus `GRAD_BATCH`, so they remain first-class citizens;
/// anything older predates the codec negotiation and is refused.
pub const MIN_TRANSPORT_VERSION: u8 = 2;

/// Handshake magic (first frame on every connection).
pub const HELLO_MAGIC: &[u8; 4] = b"GSTP";

/// Encoded hello length: magic + version + worker id + codec.
pub const HELLO_LEN: usize = 10;

const TAG_PULL: u8 = 0x10;
const TAG_WEIGHTS: u8 = 0x11;
const TAG_GRAD: u8 = 0x12;
const TAG_SHUTDOWN: u8 = 0x13;
const TAG_CONFIG: u8 = 0x14;
const TAG_GRAD_BATCH: u8 = 0x15;
const TAG_WEIGHTS_BATCH: u8 = 0x16;
const TAG_SPARSE_REDUCE: u8 = 0x17;
const TAG_RING_ADDR: u8 = 0x18;
const TAG_PROBE: u8 = 0x19;

/// Tag-byte flag marking a frame whose body is preceded by a 12-byte
/// [`TraceCtx`] (v4 links only). Real tags live in `0x10..=0x19`, so a
/// flagged tag (`0x90..=0x99`) can never collide with an unflagged one.
pub const TRACE_CTX_FLAG: u8 = 0x80;

/// Encoded length of a [`TraceCtx`]: `u32 round + u32 sender + u32 seq`.
pub const TRACE_CTX_LEN: usize = 12;

/// Clock-probe body length: `u8 kind + 3 × u64` timestamps.
pub const PROBE_BODY_LEN: usize = 25;

/// Probe kind: a ping carrying the sender's send timestamp in `t0`.
pub const PROBE_PING: u8 = 0;

/// Probe kind: a pong echoing the ping's `t0` plus the responder's local
/// receive (`t1`) and reply-send (`t2`) timestamps.
pub const PROBE_PONG: u8 = 1;

/// Per-frame causal trace context (v4 links): which round the frame
/// belongs to, which rank sent it, and a per-link sequence number. The
/// `(sender, seq)` pair is the flow id linking the sender's `frame_tx`
/// span to the receiver's `frame_rx` span in a merged cross-process
/// timeline — see [`crate::telemetry::merge`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceCtx {
    /// Round index the frame belongs to (the sender's `trace::set_round`).
    pub round: u32,
    /// Sender rank (`u32::MAX` = the server, like `trace::SERVER_WORKER`).
    pub sender: u32,
    /// Per-link monotonically increasing frame sequence number.
    pub seq: u32,
}

impl TraceCtx {
    /// The flow id joining the tx and rx halves of this frame's journey.
    pub fn flow_id(&self) -> u64 {
        (u64::from(self.sender) << 32) | u64::from(self.seq)
    }

    fn write(&self, out: &mut [u8]) {
        out[0..4].copy_from_slice(&self.round.to_le_bytes());
        out[4..8].copy_from_slice(&self.sender.to_le_bytes());
        out[8..12].copy_from_slice(&self.seq.to_le_bytes());
    }

    fn read(buf: &[u8]) -> Self {
        Self {
            round: u32::from_le_bytes(buf[0..4].try_into().unwrap()),
            sender: u32::from_le_bytes(buf[4..8].try_into().unwrap()),
            seq: u32::from_le_bytes(buf[8..12].try_into().unwrap()),
        }
    }
}

/// Stamp an encoded frame (or the tag-bearing first segment of a vectored
/// send) with a trace context: sets [`TRACE_CTX_FLAG`] on the tag byte and
/// inserts the 12 encoded context bytes between tag and body. Only valid
/// on an unstamped frame; use [`restamp_ctx`] to overwrite in place.
pub fn stamp_ctx(buf: &mut Vec<u8>, ctx: TraceCtx) {
    debug_assert!(!buf.is_empty() && buf[0] & TRACE_CTX_FLAG == 0, "already stamped");
    buf[0] |= TRACE_CTX_FLAG;
    let mut enc = [0u8; TRACE_CTX_LEN];
    ctx.write(&mut enc);
    buf.splice(1..1, enc);
}

/// Overwrite the trace context of an already-stamped frame in place (no
/// byte shifting) — how a sender reuses one encoded broadcast frame across
/// several links that each need their own sequence number.
pub fn restamp_ctx(buf: &mut [u8], ctx: TraceCtx) {
    debug_assert!(buf.len() > TRACE_CTX_LEN && buf[0] & TRACE_CTX_FLAG != 0, "not stamped");
    ctx.write(&mut buf[1..1 + TRACE_CTX_LEN]);
}

/// Read the trace context of a frame (or of a vectored send's first
/// segment) without consuming it. `None` when the frame is unstamped or
/// too short to carry a context.
pub fn peek_ctx(buf: &[u8]) -> Option<TraceCtx> {
    if buf.len() > TRACE_CTX_LEN && buf[0] & TRACE_CTX_FLAG != 0 {
        Some(TraceCtx::read(&buf[1..1 + TRACE_CTX_LEN]))
    } else {
        None
    }
}

/// The handshake sent by the connecting side as its first frame. Besides
/// identifying the worker it pins the protocol version *and* the wire codec
/// the peer will encode gradients with — both sides must agree before any
/// gradient crosses the link, so codec mismatches fail at accept time with
/// a clean error instead of as undecodable payloads mid-run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Hello {
    pub version: u8,
    pub worker_id: u32,
    /// The [`crate::coding::WireCodec`] the sender will use, as `u8`.
    pub codec: u8,
}

impl Hello {
    /// A hello under the default [`WireCodec::Raw`](crate::coding::WireCodec).
    pub fn new(worker_id: u32) -> Self {
        Self::with_codec(worker_id, crate::coding::WireCodec::Raw)
    }

    pub fn with_codec(worker_id: u32, codec: crate::coding::WireCodec) -> Self {
        Self {
            version: TRANSPORT_VERSION,
            worker_id,
            codec: codec.index() as u8,
        }
    }

    /// A hello announcing an explicit (older) protocol version — how a
    /// session configured for v2 compatibility connects, and how the
    /// fallback tests impersonate a v2 peer. Clamped to the supported
    /// window so an out-of-range request cannot produce an undecodable
    /// hello.
    pub fn with_version(worker_id: u32, codec: crate::coding::WireCodec, version: u8) -> Self {
        Self {
            version: version.clamp(MIN_TRANSPORT_VERSION, TRANSPORT_VERSION),
            worker_id,
            codec: codec.index() as u8,
        }
    }

    /// Whether this peer may be sent `GRAD_BATCH` frames (hello ≥ v3).
    pub fn supports_batch(&self) -> bool {
        self.version >= 3
    }

    /// Whether this peer understands [`TraceCtx`]-stamped frames and clock
    /// `PROBE` frames (hello ≥ v4). Frames to an older peer must stay
    /// unstamped — that is the bitwise-compatibility contract of the v4
    /// bump.
    pub fn supports_ctx(&self) -> bool {
        self.version >= 4
    }

    /// The decoded codec (`decode` validated the byte, so this never fails
    /// on a received hello).
    pub fn wire_codec(&self) -> crate::coding::WireCodec {
        crate::coding::WireCodec::from_u8(self.codec)
            .expect("codec byte validated during decode")
    }

    /// Encode into `out` (cleared first).
    pub fn encode(&self, out: &mut Vec<u8>) {
        out.clear();
        out.extend_from_slice(HELLO_MAGIC);
        out.push(self.version);
        out.extend_from_slice(&self.worker_id.to_le_bytes());
        out.push(self.codec);
    }

    pub fn decode(buf: &[u8]) -> Result<Self, TransportError> {
        // Magic + version are validated before the exact-length check so a
        // peer speaking an older protocol (whose hello is a different
        // length, e.g. the 9-byte version-1 form) still gets the
        // informative VersionMismatch instead of a generic length error.
        if buf.len() < 5 {
            return Err(TransportError::BadHandshake("wrong hello length"));
        }
        if &buf[0..4] != HELLO_MAGIC {
            return Err(TransportError::BadHandshake("bad magic"));
        }
        let version = buf[4];
        if !(MIN_TRANSPORT_VERSION..=TRANSPORT_VERSION).contains(&version) {
            return Err(TransportError::VersionMismatch {
                ours: TRANSPORT_VERSION,
                theirs: version,
            });
        }
        if buf.len() != HELLO_LEN {
            return Err(TransportError::BadHandshake("wrong hello length"));
        }
        let codec = buf[9];
        if crate::coding::WireCodec::from_u8(codec).is_none() {
            return Err(TransportError::BadHandshake("unknown wire codec"));
        }
        Ok(Self {
            version,
            worker_id: u32::from_le_bytes(buf[5..9].try_into().unwrap()),
            codec,
        })
    }
}

/// Gradient-message metadata (everything in a `GRAD` frame but the payload).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GradHeader {
    /// Weight version the gradient was computed against.
    pub based_on: u64,
    /// `‖g‖²` before compression (the server can't recompute it).
    pub g_norm_sq: f64,
    /// `‖Q(g)‖²` after compression.
    pub q_norm_sq: f64,
    /// Expected survivors `Σ_i p_i` (feeds the `spa` meter).
    pub expected_nnz: f64,
    /// Idealized coding length under the paper's bit model.
    pub ideal_bits: u64,
    /// 0 = sparse [`crate::coding`] wire bytes, 1 = raw dense `f32 LE`.
    pub kind: u8,
}

const GRAD_HEADER_LEN: usize = 1 + 8 + 8 + 8 + 8 + 8 + 1;

/// A decoded view of one protocol message, borrowing from the recv buffer.
#[derive(Debug, PartialEq)]
pub enum MsgView<'a> {
    Pull,
    Weights { version: u64, w_bytes: &'a [u8] },
    /// A whole multi-tensor weight set in one frame (v3 links only):
    /// `batch` is the validated `u32 count + count × u32 lens + payload`
    /// region — read it through [`weights_batch_count`] /
    /// [`weights_batch_into`] / [`weights_batch_segments_into`].
    WeightsBatch { version: u64, batch: &'a [u8] },
    Grad { header: GradHeader, payload: &'a [u8] },
    /// A whole model update in one frame: the header carries the
    /// layer-summed statistics, the payload is a
    /// [`crate::coding::batch`] `WireBatch` (v3 links only).
    GradBatch { header: GradHeader, payload: &'a [u8] },
    Shutdown,
    Config { bytes: &'a [u8] },
    /// One hop of a ring collective ([`crate::collective`]): `chunk` is the
    /// ring-chunk index the payload covers, `phase` distinguishes the
    /// pipeline stage (reduce-scatter, all-gather, sketch, …; the collective
    /// layer defines the values and refuses unexpected ones). The payload
    /// reuses the [`crate::coding`] WireBatch layout for sparse stages and
    /// raw `f32 LE` for the index-free aligned stages.
    SparseReduce { chunk: u32, phase: u8, payload: &'a [u8] },
    /// Ring-link bootstrap for the dist runtime: worker `worker_id`'s own
    /// listener address, relayed through the server so each worker learns
    /// its right neighbour without any out-of-band channel.
    RingAddr { worker_id: u32, addr: &'a [u8] },
    /// NTP-style clock probe (v4 links): a [`PROBE_PING`] carries the
    /// sender's send timestamp in `t0`; the [`PROBE_PONG`] echoes it and
    /// adds the responder's local receive (`t1`) and reply-send (`t2`)
    /// timestamps, from which the pinger estimates the peer's clock offset
    /// ([`crate::telemetry::clock`]).
    Probe { kind: u8, t0: u64, t1: u64, t2: u64 },
}

/// Encode a `PULL` message into `out` (cleared first).
pub fn encode_pull(out: &mut Vec<u8>) {
    out.clear();
    out.push(TAG_PULL);
}

/// Encode a `WEIGHTS` message into `out` (cleared first).
pub fn encode_weights(out: &mut Vec<u8>, version: u64, w: &[f32]) {
    out.clear();
    out.reserve(1 + 8 + 4 * w.len());
    out.push(TAG_WEIGHTS);
    out.extend_from_slice(&version.to_le_bytes());
    for &x in w {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Encode a `WEIGHTS_BATCH` message into `out` (cleared first): every
/// tensor of a multi-tensor model in one frame — one round-trip per pull
/// regardless of the layer count, the download-direction sibling of
/// `GRAD_BATCH`.
pub fn encode_weights_batch(out: &mut Vec<u8>, version: u64, tensors: &[&[f32]]) {
    let total: usize = tensors.iter().map(|t| t.len()).sum();
    out.clear();
    out.reserve(1 + 8 + 4 + 4 * tensors.len() + 4 * total);
    out.push(TAG_WEIGHTS_BATCH);
    out.extend_from_slice(&version.to_le_bytes());
    out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for t in tensors {
        out.extend_from_slice(&(t.len() as u32).to_le_bytes());
    }
    for t in tensors {
        for &x in t.iter() {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
}

/// Tensor count of a validated `WEIGHTS_BATCH` region.
pub fn weights_batch_count(batch: &[u8]) -> usize {
    u32::from_le_bytes(batch[0..4].try_into().unwrap()) as usize
}

/// Concatenate every tensor of a validated `WEIGHTS_BATCH` region into one
/// caller-held `f32` arena (cleared first; capacity reused) — the layout
/// single-arena consumers (e.g. the dist runtime's flat weight vector)
/// want.
pub fn weights_batch_into(batch: &[u8], out: &mut Vec<f32>) {
    let count = weights_batch_count(batch);
    weights_into(&batch[4 + 4 * count..], out);
}

/// Split a validated `WEIGHTS_BATCH` region into per-tensor vectors
/// (resized to the tensor count; inner capacity reused).
pub fn weights_batch_segments_into(batch: &[u8], out: &mut Vec<Vec<f32>>) {
    let count = weights_batch_count(batch);
    if out.len() != count {
        out.resize_with(count, Vec::new);
    }
    let mut off = 4 + 4 * count;
    for (t, slot) in out.iter_mut().enumerate() {
        let len = u32::from_le_bytes(batch[4 + 4 * t..8 + 4 * t].try_into().unwrap()) as usize;
        weights_into(&batch[off..off + 4 * len], slot);
        off += 4 * len;
    }
}

/// Encode a `GRAD` message into `out` (cleared first).
pub fn encode_grad(out: &mut Vec<u8>, header: &GradHeader, payload: &[u8]) {
    encode_grad_tagged(out, TAG_GRAD, header, payload);
}

/// Encode only the tag + header prefix of a `GRAD` message into `out`
/// (cleared first) — the first segment of a vectored send whose remaining
/// segment is the codec payload, sparing the sender the payload copy.
/// Byte-for-byte, `prefix ++ payload` equals what [`encode_grad`] produces
/// for the same header and payload.
pub fn encode_grad_prefix(out: &mut Vec<u8>, header: &GradHeader) {
    encode_grad_tagged(out, TAG_GRAD, header, &[]);
}

/// Encode a `GRAD_BATCH` message into `out` (cleared first): the same
/// header layout as `GRAD` with layer-summed statistics, followed by a
/// `WireBatch` payload. Batches are always sparse wire bytes, so
/// `header.kind` must be 0.
pub fn encode_grad_batch(out: &mut Vec<u8>, header: &GradHeader, payload: &[u8]) {
    debug_assert_eq!(header.kind, 0, "batch frames carry sparse wire bytes");
    encode_grad_tagged(out, TAG_GRAD_BATCH, header, payload);
}

/// Encode only the tag + header prefix of a `GRAD_BATCH` message into
/// `out` (cleared first) — the first segment of a vectored send whose
/// remaining segments are the `WireBatch` header and per-layer payloads.
/// Byte-for-byte, `prefix ++ payload` equals what [`encode_grad_batch`]
/// produces for the same header and payload.
pub fn encode_grad_batch_prefix(out: &mut Vec<u8>, header: &GradHeader) {
    debug_assert_eq!(header.kind, 0, "batch frames carry sparse wire bytes");
    encode_grad_tagged(out, TAG_GRAD_BATCH, header, &[]);
}

fn encode_grad_tagged(out: &mut Vec<u8>, tag: u8, header: &GradHeader, payload: &[u8]) {
    out.clear();
    out.reserve(GRAD_HEADER_LEN + payload.len());
    out.push(tag);
    out.extend_from_slice(&header.based_on.to_le_bytes());
    out.extend_from_slice(&header.g_norm_sq.to_le_bytes());
    out.extend_from_slice(&header.q_norm_sq.to_le_bytes());
    out.extend_from_slice(&header.expected_nnz.to_le_bytes());
    out.extend_from_slice(&header.ideal_bits.to_le_bytes());
    out.push(header.kind);
    out.extend_from_slice(payload);
}

/// Encode a `SPARSE_REDUCE` hop message into `out` (cleared first).
pub fn encode_sparse_reduce(out: &mut Vec<u8>, chunk: u32, phase: u8, payload: &[u8]) {
    out.clear();
    out.reserve(1 + 4 + 1 + payload.len());
    out.push(TAG_SPARSE_REDUCE);
    out.extend_from_slice(&chunk.to_le_bytes());
    out.push(phase);
    out.extend_from_slice(payload);
}

/// Encode only the tag + chunk + phase prefix of a `SPARSE_REDUCE` message
/// into `out` (cleared first) — the first segment of a vectored send whose
/// remaining segment is the hop payload. Byte-for-byte, `prefix ++ payload`
/// equals what [`encode_sparse_reduce`] produces.
pub fn encode_sparse_reduce_prefix(out: &mut Vec<u8>, chunk: u32, phase: u8) {
    encode_sparse_reduce(out, chunk, phase, &[]);
}

/// Encode a `RING_ADDR` bootstrap message into `out` (cleared first).
pub fn encode_ring_addr(out: &mut Vec<u8>, worker_id: u32, addr: &str) {
    out.clear();
    out.reserve(1 + 4 + addr.len());
    out.push(TAG_RING_ADDR);
    out.extend_from_slice(&worker_id.to_le_bytes());
    out.extend_from_slice(addr.as_bytes());
}

/// Encode a `PROBE` message into `out` (cleared first). Pings set `t0` to
/// the sender's clock and zero the rest; pongs echo the ping's `t0` and
/// fill `t1`/`t2` from the responder's clock.
pub fn encode_probe(out: &mut Vec<u8>, kind: u8, t0: u64, t1: u64, t2: u64) {
    debug_assert!(kind == PROBE_PING || kind == PROBE_PONG);
    out.clear();
    out.reserve(1 + PROBE_BODY_LEN);
    out.push(TAG_PROBE);
    out.push(kind);
    out.extend_from_slice(&t0.to_le_bytes());
    out.extend_from_slice(&t1.to_le_bytes());
    out.extend_from_slice(&t2.to_le_bytes());
}

/// Encode a `SHUTDOWN` message into `out` (cleared first).
pub fn encode_shutdown(out: &mut Vec<u8>) {
    out.clear();
    out.push(TAG_SHUTDOWN);
}

/// Encode a `CONFIG` message into `out` (cleared first).
pub fn encode_config(out: &mut Vec<u8>, bytes: &[u8]) {
    out.clear();
    out.reserve(1 + bytes.len());
    out.push(TAG_CONFIG);
    out.extend_from_slice(bytes);
}

/// Decode one protocol message from a received frame payload. A
/// [`TRACE_CTX_FLAG`]-stamped frame decodes to the same view as its
/// unstamped twin — the context is observability metadata, read separately
/// via [`peek_ctx`], never protocol state.
pub fn decode(buf: &[u8]) -> Result<MsgView<'_>, TransportError> {
    let (&raw_tag, mut body) = buf
        .split_first()
        .ok_or(TransportError::UnexpectedMessage("empty frame"))?;
    let tag = raw_tag & !TRACE_CTX_FLAG;
    if raw_tag & TRACE_CTX_FLAG != 0 {
        if body.len() < TRACE_CTX_LEN {
            return Err(TransportError::UnexpectedMessage("trace ctx truncated"));
        }
        body = &body[TRACE_CTX_LEN..];
    }
    match tag {
        TAG_PULL => {
            if !body.is_empty() {
                return Err(TransportError::UnexpectedMessage("pull with body"));
            }
            Ok(MsgView::Pull)
        }
        TAG_WEIGHTS => {
            if body.len() < 8 || (body.len() - 8) % 4 != 0 {
                return Err(TransportError::UnexpectedMessage("weights body length"));
            }
            Ok(MsgView::Weights {
                version: u64::from_le_bytes(body[0..8].try_into().unwrap()),
                w_bytes: &body[8..],
            })
        }
        TAG_WEIGHTS_BATCH => {
            // Fully validated here so the `weights_batch_*` readers can
            // index without re-checking: count table present, every length
            // fits, and the payload is exactly the declared total.
            if body.len() < 12 {
                return Err(TransportError::UnexpectedMessage("weights batch truncated"));
            }
            let batch = &body[8..];
            let count = u32::from_le_bytes(batch[0..4].try_into().unwrap()) as usize;
            // The length table alone bounds `count` before any multiply
            // can overflow or any allocation can happen.
            if batch.len() < 4 || (batch.len() - 4) / 4 < count {
                return Err(TransportError::UnexpectedMessage("weights batch count"));
            }
            let mut total: u64 = 0;
            for t in 0..count {
                let len =
                    u32::from_le_bytes(batch[4 + 4 * t..8 + 4 * t].try_into().unwrap());
                total += len as u64;
            }
            let payload_len = (batch.len() - 4 - 4 * count) as u64;
            if total.checked_mul(4) != Some(payload_len) {
                return Err(TransportError::UnexpectedMessage("weights batch payload"));
            }
            Ok(MsgView::WeightsBatch {
                version: u64::from_le_bytes(body[0..8].try_into().unwrap()),
                batch,
            })
        }
        TAG_GRAD | TAG_GRAD_BATCH => {
            // Header length minus the tag byte (offsets below are relative
            // to `body`, which already skipped tag + any trace context).
            let hdr = GRAD_HEADER_LEN - 1;
            if body.len() < hdr {
                return Err(TransportError::UnexpectedMessage("grad header truncated"));
            }
            let kind = body[hdr - 1];
            if kind > 1 || (tag == TAG_GRAD_BATCH && kind != 0) {
                return Err(TransportError::UnexpectedMessage("grad kind"));
            }
            let header = GradHeader {
                based_on: u64::from_le_bytes(body[0..8].try_into().unwrap()),
                g_norm_sq: f64::from_le_bytes(body[8..16].try_into().unwrap()),
                q_norm_sq: f64::from_le_bytes(body[16..24].try_into().unwrap()),
                expected_nnz: f64::from_le_bytes(body[24..32].try_into().unwrap()),
                ideal_bits: u64::from_le_bytes(body[32..40].try_into().unwrap()),
                kind,
            };
            let payload = &body[hdr..];
            if tag == TAG_GRAD {
                Ok(MsgView::Grad { header, payload })
            } else {
                Ok(MsgView::GradBatch { header, payload })
            }
        }
        TAG_SHUTDOWN => {
            if !body.is_empty() {
                return Err(TransportError::UnexpectedMessage("shutdown with body"));
            }
            Ok(MsgView::Shutdown)
        }
        TAG_CONFIG => Ok(MsgView::Config { bytes: body }),
        TAG_SPARSE_REDUCE => {
            if body.len() < 5 {
                return Err(TransportError::UnexpectedMessage("sparse reduce truncated"));
            }
            Ok(MsgView::SparseReduce {
                chunk: u32::from_le_bytes(body[0..4].try_into().unwrap()),
                phase: body[4],
                payload: &body[5..],
            })
        }
        TAG_RING_ADDR => {
            if body.len() < 4 {
                return Err(TransportError::UnexpectedMessage("ring addr truncated"));
            }
            Ok(MsgView::RingAddr {
                worker_id: u32::from_le_bytes(body[0..4].try_into().unwrap()),
                addr: &body[4..],
            })
        }
        TAG_PROBE => {
            if body.len() != PROBE_BODY_LEN {
                return Err(TransportError::UnexpectedMessage("probe body length"));
            }
            let kind = body[0];
            if kind != PROBE_PING && kind != PROBE_PONG {
                return Err(TransportError::UnexpectedMessage("probe kind"));
            }
            Ok(MsgView::Probe {
                kind,
                t0: u64::from_le_bytes(body[1..9].try_into().unwrap()),
                t1: u64::from_le_bytes(body[9..17].try_into().unwrap()),
                t2: u64::from_le_bytes(body[17..25].try_into().unwrap()),
            })
        }
        _ => Err(TransportError::UnexpectedMessage("unknown tag")),
    }
}

/// Copy a `WEIGHTS` body into a caller-held `f32` buffer (resized to fit).
pub fn weights_into(w_bytes: &[u8], out: &mut Vec<f32>) {
    out.clear();
    out.reserve(w_bytes.len() / 4);
    for chunk in w_bytes.chunks_exact(4) {
        out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
    }
}

/// `out[i] += alpha · f32_le(payload[4i..])` — the apply side of a
/// `kind = 1` dense gradient payload (the encode side is
/// `Compressed::dense_le_bytes_into`). Stops at the shorter of the two
/// lengths; callers that require an exact match check it first.
pub fn add_dense_le(payload: &[u8], alpha: f32, out: &mut [f32]) {
    for (o, chunk) in out.iter_mut().zip(payload.chunks_exact(4)) {
        *o += alpha * f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hello_roundtrip_and_rejections() {
        let h = Hello::new(3);
        let mut buf = Vec::new();
        h.encode(&mut buf);
        assert_eq!(Hello::decode(&buf).unwrap(), h);

        let mut bad = buf.clone();
        bad[0] = b'X';
        assert!(matches!(
            Hello::decode(&bad),
            Err(TransportError::BadHandshake(_))
        ));
        let mut bad = buf.clone();
        bad[4] = 9;
        assert!(matches!(
            Hello::decode(&bad),
            Err(TransportError::VersionMismatch { theirs: 9, .. })
        ));
        assert!(matches!(
            Hello::decode(&buf[..5]),
            Err(TransportError::BadHandshake(_))
        ));
        // The codec byte is validated like the version.
        let mut bad = buf.clone();
        bad[9] = 7;
        assert!(matches!(
            Hello::decode(&bad),
            Err(TransportError::BadHandshake(_))
        ));
        let entropy = Hello::with_codec(4, crate::coding::WireCodec::Entropy);
        entropy.encode(&mut buf);
        assert_eq!(buf.len(), HELLO_LEN);
        let back = Hello::decode(&buf).unwrap();
        assert_eq!(back, entropy);
        assert_eq!(back.wire_codec(), crate::coding::WireCodec::Entropy);
        // A version-1 peer's 9-byte hello must surface the version skew,
        // not a generic length error, even though its length differs.
        let mut v1 = Vec::new();
        v1.extend_from_slice(HELLO_MAGIC);
        v1.push(1);
        v1.extend_from_slice(&7u32.to_le_bytes());
        assert_eq!(v1.len(), 9);
        assert!(matches!(
            Hello::decode(&v1),
            Err(TransportError::VersionMismatch { ours: 4, theirs: 1 })
        ));
    }

    #[test]
    fn v2_hellos_still_decode_and_disable_batching() {
        // The v2↔v3 compatibility window: a version-2 hello (same 10-byte
        // layout) is accepted, reports itself batch-incapable, and a
        // version beyond ours is still refused.
        let v2 = Hello::with_version(5, crate::coding::WireCodec::Entropy, 2);
        assert_eq!(v2.version, 2);
        let mut buf = Vec::new();
        v2.encode(&mut buf);
        let back = Hello::decode(&buf).unwrap();
        assert_eq!(back, v2);
        assert!(!back.supports_batch());
        assert!(!back.supports_ctx());
        assert!(Hello::new(0).supports_batch());
        assert!(Hello::new(0).supports_ctx());
        // A v3 peer batches but must never be stamped with trace contexts.
        let v3 = Hello::with_version(1, crate::coding::WireCodec::Raw, 3);
        assert!(v3.supports_batch());
        assert!(!v3.supports_ctx());
        // with_version clamps into the supported window.
        assert_eq!(Hello::with_version(0, crate::coding::WireCodec::Raw, 0).version, 2);
        assert_eq!(Hello::with_version(0, crate::coding::WireCodec::Raw, 9).version, 4);
        let mut future = buf.clone();
        future[4] = 5;
        assert!(matches!(
            Hello::decode(&future),
            Err(TransportError::VersionMismatch { ours: 4, theirs: 5 })
        ));
    }

    #[test]
    fn message_roundtrips() {
        let mut buf = Vec::new();
        encode_pull(&mut buf);
        assert_eq!(decode(&buf).unwrap(), MsgView::Pull);

        let w = [1.0f32, -2.5, 0.0];
        encode_weights(&mut buf, 7, &w);
        match decode(&buf).unwrap() {
            MsgView::Weights { version, w_bytes } => {
                assert_eq!(version, 7);
                let mut back = Vec::new();
                weights_into(w_bytes, &mut back);
                assert_eq!(back, w);
            }
            other => panic!("{other:?}"),
        }

        let header = GradHeader {
            based_on: 11,
            g_norm_sq: 2.5,
            q_norm_sq: 3.25,
            expected_nnz: 14.5,
            ideal_bits: 999,
            kind: 0,
        };
        encode_grad(&mut buf, &header, b"payload-bytes");
        match decode(&buf).unwrap() {
            MsgView::Grad { header: h, payload } => {
                assert_eq!(h, header);
                assert_eq!(payload, b"payload-bytes");
            }
            other => panic!("{other:?}"),
        }
        // The vectored-send prefix concatenated with the payload is exactly
        // the one-shot frame.
        let mut prefix = Vec::new();
        encode_grad_prefix(&mut prefix, &header);
        assert_eq!(prefix.len(), GRAD_HEADER_LEN);
        let mut glued = prefix.clone();
        glued.extend_from_slice(b"payload-bytes");
        assert_eq!(glued, buf);

        encode_shutdown(&mut buf);
        assert_eq!(decode(&buf).unwrap(), MsgView::Shutdown);

        encode_config(&mut buf, b"cfg");
        assert_eq!(decode(&buf).unwrap(), MsgView::Config { bytes: b"cfg" });
    }

    #[test]
    fn grad_batch_roundtrips_and_rejects_dense_kind() {
        let header = GradHeader {
            based_on: 3,
            g_norm_sq: 1.5,
            q_norm_sq: 2.0,
            expected_nnz: 9.0,
            ideal_bits: 4242,
            kind: 0,
        };
        let mut buf = Vec::new();
        encode_grad_batch(&mut buf, &header, b"wire-batch-bytes");
        match decode(&buf).unwrap() {
            MsgView::GradBatch { header: h, payload } => {
                assert_eq!(h, header);
                assert_eq!(payload, b"wire-batch-bytes");
            }
            other => panic!("{other:?}"),
        }
        // A batch frame claiming a dense payload is malformed.
        let kind_off = GRAD_HEADER_LEN - 1;
        let mut bad = buf.clone();
        bad[kind_off] = 1;
        assert!(decode(&bad).is_err());
        assert!(decode(&buf[..GRAD_HEADER_LEN - 1]).is_err());
        // The vectored-send prefix concatenated with the payload is exactly
        // the one-shot frame.
        let mut prefix = Vec::new();
        encode_grad_batch_prefix(&mut prefix, &header);
        assert_eq!(prefix.len(), GRAD_HEADER_LEN);
        let mut glued = prefix.clone();
        glued.extend_from_slice(b"wire-batch-bytes");
        assert_eq!(glued, buf);
    }

    #[test]
    fn weights_batch_roundtrips_multi_tensor() {
        let a = [1.0f32, -2.5, 0.0];
        let b: [f32; 0] = [];
        let c = [7.25f32];
        let mut buf = Vec::new();
        encode_weights_batch(&mut buf, 42, &[&a, &b, &c]);
        match decode(&buf).unwrap() {
            MsgView::WeightsBatch { version, batch } => {
                assert_eq!(version, 42);
                assert_eq!(weights_batch_count(batch), 3);
                let mut flat = Vec::new();
                weights_batch_into(batch, &mut flat);
                assert_eq!(flat, vec![1.0, -2.5, 0.0, 7.25]);
                let mut segs = Vec::new();
                weights_batch_segments_into(batch, &mut segs);
                assert_eq!(segs, vec![a.to_vec(), b.to_vec(), c.to_vec()]);
            }
            other => panic!("{other:?}"),
        }
        // An empty tensor list is a valid (12-byte) batch.
        encode_weights_batch(&mut buf, 0, &[]);
        match decode(&buf).unwrap() {
            MsgView::WeightsBatch { batch, .. } => assert_eq!(weights_batch_count(batch), 0),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn weights_batch_rejects_malformed() {
        let w = [0.5f32, 1.5];
        let mut buf = Vec::new();
        encode_weights_batch(&mut buf, 9, &[&w]);
        // Truncated header / truncated payload / inflated count all refuse.
        assert!(decode(&buf[..10]).is_err());
        assert!(decode(&buf[..buf.len() - 1]).is_err());
        let mut bad = buf.clone();
        bad[9] = 200; // count LSB (body offset 8 → frame offset 9)
        assert!(decode(&bad).is_err());
        // A length-table entry that disagrees with the payload size.
        let mut bad = buf.clone();
        bad[13] = 3; // first tensor length LSB: 2 → 3
        assert!(decode(&bad).is_err());
    }

    #[test]
    fn sparse_reduce_and_ring_addr_roundtrip() {
        let mut buf = Vec::new();
        encode_sparse_reduce(&mut buf, 6, 1, b"hop-payload");
        assert_eq!(
            decode(&buf).unwrap(),
            MsgView::SparseReduce {
                chunk: 6,
                phase: 1,
                payload: b"hop-payload",
            }
        );
        // Prefix + payload equals the one-shot frame (vectored send path).
        let mut prefix = Vec::new();
        encode_sparse_reduce_prefix(&mut prefix, 6, 1);
        let mut glued = prefix.clone();
        glued.extend_from_slice(b"hop-payload");
        assert_eq!(glued, buf);
        // An empty payload is legal (a worker can own an empty chunk).
        encode_sparse_reduce(&mut buf, 0, 0, b"");
        assert!(matches!(
            decode(&buf).unwrap(),
            MsgView::SparseReduce { chunk: 0, phase: 0, payload: b"" }
        ));
        // Truncated header refuses.
        assert!(decode(&[TAG_SPARSE_REDUCE, 1, 2, 3]).is_err());

        encode_ring_addr(&mut buf, 3, "127.0.0.1:4242");
        assert_eq!(
            decode(&buf).unwrap(),
            MsgView::RingAddr {
                worker_id: 3,
                addr: b"127.0.0.1:4242",
            }
        );
        assert!(decode(&[TAG_RING_ADDR, 1]).is_err());
    }

    #[test]
    fn decode_rejects_malformed() {
        assert!(decode(&[]).is_err());
        assert!(decode(&[0xFF]).is_err());
        assert!(decode(&[TAG_PULL, 1]).is_err());
        assert!(decode(&[TAG_SHUTDOWN, 0]).is_err());
        assert!(decode(&[TAG_WEIGHTS, 1, 2]).is_err());
        // Weights body not a multiple of 4 after the version.
        let mut buf = Vec::new();
        encode_weights(&mut buf, 1, &[1.0]);
        buf.push(0);
        assert!(decode(&buf).is_err());
        // Grad header truncated / bad kind.
        let mut buf = Vec::new();
        encode_grad(
            &mut buf,
            &GradHeader {
                based_on: 0,
                g_norm_sq: 0.0,
                q_norm_sq: 0.0,
                expected_nnz: 0.0,
                ideal_bits: 0,
                kind: 0,
            },
            b"",
        );
        assert!(decode(&buf[..buf.len() - 1]).is_err());
        let mut bad = buf.clone();
        bad[GRAD_HEADER_LEN - 1] = 9;
        assert!(decode(&bad).is_err());
    }

    #[test]
    fn trace_ctx_stamp_peek_and_transparent_decode() {
        let ctx = TraceCtx { round: 7, sender: 2, seq: 41 };
        assert_eq!(ctx.flow_id(), (2u64 << 32) | 41);

        // Stamping any of the four stampable frame kinds leaves the decoded
        // view identical to the unstamped twin.
        let header = GradHeader {
            based_on: 11,
            g_norm_sq: 2.5,
            q_norm_sq: 3.25,
            expected_nnz: 14.5,
            ideal_bits: 999,
            kind: 0,
        };
        let mut plain = Vec::new();
        encode_grad(&mut plain, &header, b"payload-bytes");
        assert_eq!(peek_ctx(&plain), None);
        let mut stamped = plain.clone();
        stamp_ctx(&mut stamped, ctx);
        assert_eq!(stamped.len(), plain.len() + TRACE_CTX_LEN);
        assert_eq!(peek_ctx(&stamped), Some(ctx));
        match (decode(&plain).unwrap(), decode(&stamped).unwrap()) {
            (MsgView::Grad { header: a, payload: pa }, MsgView::Grad { header: b, payload: pb }) => {
                assert_eq!(a, b);
                assert_eq!(pa, pb);
            }
            other => panic!("{other:?}"),
        }
        // Restamping overwrites in place without shifting.
        let ctx2 = TraceCtx { round: 8, sender: 2, seq: 42 };
        restamp_ctx(&mut stamped, ctx2);
        assert_eq!(stamped.len(), plain.len() + TRACE_CTX_LEN);
        assert_eq!(peek_ctx(&stamped), Some(ctx2));

        // A stamped vectored-send prefix glues to the same bytes as the
        // stamped one-shot frame.
        let mut prefix = Vec::new();
        encode_grad_prefix(&mut prefix, &header);
        stamp_ctx(&mut prefix, ctx2);
        let mut glued = prefix.clone();
        glued.extend_from_slice(b"payload-bytes");
        assert_eq!(glued, stamped);

        // Stamped WEIGHTS and SPARSE_REDUCE decode transparently too.
        let mut buf = Vec::new();
        encode_weights(&mut buf, 7, &[1.0, -2.5]);
        stamp_ctx(&mut buf, ctx);
        assert!(matches!(decode(&buf).unwrap(), MsgView::Weights { version: 7, .. }));
        encode_sparse_reduce(&mut buf, 6, 1, b"hop");
        stamp_ctx(&mut buf, ctx);
        assert!(matches!(
            decode(&buf).unwrap(),
            MsgView::SparseReduce { chunk: 6, phase: 1, payload: b"hop" }
        ));

        // A flagged tag with a truncated context refuses; a flagged unknown
        // tag is still unknown.
        assert!(decode(&[TAG_GRAD | TRACE_CTX_FLAG, 1, 2]).is_err());
        let mut junk = vec![0x7F | TRACE_CTX_FLAG];
        junk.extend_from_slice(&[0u8; TRACE_CTX_LEN + 4]);
        assert!(decode(&junk).is_err());
    }

    #[test]
    fn probe_roundtrips_and_rejects_malformed() {
        let mut buf = Vec::new();
        encode_probe(&mut buf, PROBE_PING, 123, 0, 0);
        assert_eq!(buf.len(), 1 + PROBE_BODY_LEN);
        assert_eq!(
            decode(&buf).unwrap(),
            MsgView::Probe { kind: PROBE_PING, t0: 123, t1: 0, t2: 0 }
        );
        encode_probe(&mut buf, PROBE_PONG, 123, 456, 789);
        assert_eq!(
            decode(&buf).unwrap(),
            MsgView::Probe { kind: PROBE_PONG, t0: 123, t1: 456, t2: 789 }
        );
        // Truncated body / bad kind refuse.
        assert!(decode(&buf[..buf.len() - 1]).is_err());
        let mut bad = buf.clone();
        bad[1] = 7;
        assert!(decode(&bad).is_err());
    }

    #[test]
    fn add_dense_le_applies_scaled_payload() {
        let vals = [1.0f32, -2.0, 0.5];
        let mut payload = Vec::new();
        for v in vals {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        let mut out = vec![10.0f32; 3];
        add_dense_le(&payload, 2.0, &mut out);
        assert_eq!(out, vec![12.0, 6.0, 11.0]);
    }

    #[test]
    fn property_grad_roundtrip() {
        crate::proptest_lite::run("grad frame roundtrip", 64, |gen| {
            let header = GradHeader {
                based_on: gen.u64(),
                g_norm_sq: gen.f64_in(0.0, 1e9),
                q_norm_sq: gen.f64_in(0.0, 1e9),
                expected_nnz: gen.f64_in(0.0, 1e6),
                ideal_bits: gen.u64() >> 16,
                kind: u8::from(gen.bool()),
            };
            let len = gen.usize_in(0, 4096);
            let payload: Vec<u8> = (0..len).map(|_| gen.u64() as u8).collect();
            let mut buf = Vec::new();
            encode_grad(&mut buf, &header, &payload);
            match decode(&buf) {
                Ok(MsgView::Grad { header: h, payload: p }) if h == header && p == payload => {
                    Ok(())
                }
                other => Err(format!("bad roundtrip: {other:?}")),
            }
        });
    }
}
