//! In-process transport backend: `mpsc` channels behind the [`Transport`]
//! trait.
//!
//! This preserves what the coordinators always did (threads exchanging
//! messages inside one process, deterministic and dependency-free) but
//! pushes every message through the same framing as the TCP backend: the
//! handshake and each payload are real encoded bytes, and the counters add
//! the same 4-byte length prefix per frame. A run over `InProc` therefore
//! produces a measured-byte ledger **identical** to the same run over
//! loopback TCP — the invariant `tests/transport_tcp.rs` asserts.

use super::{Connection, Hello, Listener, LinkCounters, Transport, TransportError};
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

type Registry = Arc<Mutex<HashMap<String, mpsc::Sender<InProcConn>>>>;

/// The in-process backend. Cloning shares the address registry, so workers
/// on other threads can `connect` to a name this instance `listen`ed on.
#[derive(Clone, Default)]
pub struct InProcTransport {
    registry: Registry,
}

impl InProcTransport {
    pub fn new() -> Self {
        Self::default()
    }
}

struct InProcConn {
    tx: mpsc::Sender<Vec<u8>>,
    rx: mpsc::Receiver<Vec<u8>>,
    counters: LinkCounters,
    peer: String,
}

impl Connection for InProcConn {
    fn send(&mut self, payload: &[u8]) -> Result<(), TransportError> {
        // Mirror the TCP backend's cap exactly — backend parity includes
        // the failure modes, not just the bytes.
        if payload.len() > super::MAX_FRAME_LEN {
            return Err(TransportError::FrameTooLarge(payload.len() as u64));
        }
        let ctx = super::frame::peek_ctx(payload);
        self.tx
            .send(payload.to_vec())
            .map_err(|_| TransportError::Closed)?;
        self.counters.add_tx_ctx(payload.len(), ctx);
        Ok(())
    }

    fn send_vectored(&mut self, segments: &[&[u8]]) -> Result<(), TransportError> {
        // The channel needs one owned Vec either way, so the segments are
        // assembled straight into it — a single copy, same as `send`. The
        // zero-copy counter stays untouched: this backend never saves one.
        let total: usize = segments.iter().map(|s| s.len()).sum();
        if total > super::MAX_FRAME_LEN {
            return Err(TransportError::FrameTooLarge(total as u64));
        }
        let mut frame = Vec::with_capacity(total);
        for s in segments {
            frame.extend_from_slice(s);
        }
        let ctx = super::frame::peek_ctx(&frame);
        self.tx.send(frame).map_err(|_| TransportError::Closed)?;
        self.counters.add_tx_ctx(total, ctx);
        Ok(())
    }

    fn recv(&mut self, buf: &mut Vec<u8>) -> Result<(), TransportError> {
        let frame = self.rx.recv().map_err(|_| TransportError::Closed)?;
        self.counters
            .add_rx_ctx(frame.len(), super::frame::peek_ctx(&frame));
        *buf = frame;
        Ok(())
    }

    fn counters(&self) -> LinkCounters {
        self.counters.clone()
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }
}

struct InProcListener {
    rx: mpsc::Receiver<InProcConn>,
    addr: String,
}

impl Listener for InProcListener {
    fn accept(&mut self) -> Result<(Box<dyn Connection>, Hello), TransportError> {
        let mut conn = self.rx.recv().map_err(|_| TransportError::Closed)?;
        let mut buf = Vec::new();
        conn.recv(&mut buf)?;
        let hello = Hello::decode(&buf)?;
        Ok((Box::new(conn), hello))
    }

    fn local_addr(&self) -> String {
        self.addr.clone()
    }
}

impl Transport for InProcTransport {
    fn listen(&self, addr: &str) -> Result<Box<dyn Listener>, TransportError> {
        let (tx, rx) = mpsc::channel();
        self.registry
            .lock()
            .expect("registry lock")
            .insert(addr.to_string(), tx);
        Ok(Box::new(InProcListener {
            rx,
            addr: addr.to_string(),
        }))
    }

    fn connect(&self, addr: &str, hello: &Hello) -> Result<Box<dyn Connection>, TransportError> {
        let pending = {
            let reg = self.registry.lock().expect("registry lock");
            reg.get(addr)
                .cloned()
                .ok_or_else(|| TransportError::NoSuchAddress(addr.to_string()))?
        };
        // Two crossed channels form the bidirectional link.
        let (tx_c2s, rx_c2s) = mpsc::channel();
        let (tx_s2c, rx_s2c) = mpsc::channel();
        let mut client = InProcConn {
            tx: tx_c2s,
            rx: rx_s2c,
            counters: LinkCounters::new(),
            peer: format!("inproc:{addr}"),
        };
        let server = InProcConn {
            tx: tx_s2c,
            rx: rx_c2s,
            counters: LinkCounters::new(),
            peer: format!("inproc:{addr}#w{}", hello.worker_id),
        };
        // The handshake travels (and is counted) like any other frame.
        let mut hello_frame = Vec::new();
        hello.encode(&mut hello_frame);
        client.send(&hello_frame)?;
        pending
            .send(server)
            .map_err(|_| TransportError::NoSuchAddress(addr.to_string()))?;
        Ok(Box::new(client))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::FRAME_OVERHEAD;

    #[test]
    fn connect_accept_send_recv() {
        let t = InProcTransport::new();
        let mut listener = t.listen("ps").unwrap();
        let t2 = t.clone();
        let client = std::thread::spawn(move || {
            let mut conn = t2.connect("ps", &Hello::new(5)).unwrap();
            conn.send(b"from-client").unwrap();
            let mut buf = Vec::new();
            conn.recv(&mut buf).unwrap();
            assert_eq!(buf, b"from-server");
            conn.counters()
        });
        let (mut conn, hello) = listener.accept().unwrap();
        assert_eq!(hello.worker_id, 5);
        let mut buf = Vec::new();
        conn.recv(&mut buf).unwrap();
        assert_eq!(buf, b"from-client");
        conn.send(b"from-server").unwrap();
        let client_counters = client.join().unwrap();
        // Client: hello + "from-client" (11) sent, "from-server" (11) recvd.
        assert_eq!(
            client_counters.bytes_tx(),
            (crate::transport::HELLO_LEN + 11 + 2 * FRAME_OVERHEAD) as u64
        );
        assert_eq!(client_counters.bytes_rx(), (11 + FRAME_OVERHEAD) as u64);
        // Server side counts the mirror image (hello counted on accept).
        assert_eq!(
            conn.counters().bytes_rx(),
            (crate::transport::HELLO_LEN + 11 + 2 * FRAME_OVERHEAD) as u64
        );
        assert!(conn.peer().contains("w5"));
    }

    #[test]
    fn vectored_send_matches_contiguous_and_counts_no_saved_copy() {
        let t = InProcTransport::new();
        let mut listener = t.listen("vec").unwrap();
        let mut conn = t.connect("vec", &Hello::new(1)).unwrap();
        let (mut server, _) = listener.accept().unwrap();
        conn.send(b"a-b-c").unwrap();
        conn.send_vectored(&[b"a-", b"", b"b-c"]).unwrap();
        let mut first = Vec::new();
        server.recv(&mut first).unwrap();
        let mut second = Vec::new();
        server.recv(&mut second).unwrap();
        assert_eq!(first, second);
        // The channel backend always pays the assembly copy, so the
        // saved-copy counter must not move.
        assert_eq!(conn.counters().frames_vectored(), 0);
        // Oversized gather lists are refused before anything is queued.
        let big = vec![0u8; crate::transport::MAX_FRAME_LEN / 2 + 1];
        assert!(matches!(
            conn.send_vectored(&[&big, &big]),
            Err(TransportError::FrameTooLarge(_))
        ));
    }

    #[test]
    fn connect_unknown_address_fails() {
        let t = InProcTransport::new();
        assert!(matches!(
            t.connect("nowhere", &Hello::new(0)),
            Err(TransportError::NoSuchAddress(_))
        ));
    }

    #[test]
    fn recv_after_peer_drop_is_closed() {
        let t = InProcTransport::new();
        let mut listener = t.listen("x").unwrap();
        let conn = t.connect("x", &Hello::new(0)).unwrap();
        let (mut server, _) = listener.accept().unwrap();
        drop(conn);
        let mut buf = Vec::new();
        assert!(matches!(
            server.recv(&mut buf),
            Err(TransportError::Closed)
        ));
    }
}
