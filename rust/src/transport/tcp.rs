//! TCP transport backend: `std::net` sockets (loopback or a real NIC)
//! behind the [`Transport`] trait.
//!
//! Each frame is written as one contiguous buffer (length prefix + payload)
//! so a message is a single `write_all` syscall in steady state;
//! `TCP_NODELAY` is set because the parameter-server protocol is
//! request/response shaped and Nagle batching would serialize rounds on the
//! RTT. The receive path validates the declared length against
//! [`super::MAX_FRAME_LEN`] *before* allocating, so an adversarial or
//! corrupted peer cannot OOM the process.

use super::{Connection, Hello, Listener, LinkCounters, Transport, TransportError};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};

/// The TCP backend (stateless; addresses are `host:port` strings, with
/// `host:0` asking the OS for a free port — read it back via
/// [`Listener::local_addr`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct TcpTransport;

impl TcpTransport {
    pub fn new() -> Self {
        Self
    }
}

struct TcpConn {
    stream: TcpStream,
    counters: LinkCounters,
    /// Reused send assembly buffer (prefix + payload in one write).
    scratch: Vec<u8>,
    peer: String,
}

impl TcpConn {
    fn new(stream: TcpStream) -> Result<Self, TransportError> {
        stream.set_nodelay(true)?;
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "<unknown>".into());
        Ok(Self {
            stream,
            counters: LinkCounters::new(),
            scratch: Vec::new(),
            peer,
        })
    }
}

impl Connection for TcpConn {
    fn send(&mut self, payload: &[u8]) -> Result<(), TransportError> {
        // MAX_FRAME_LEN ≪ u32::MAX, so the cap check makes the cast safe.
        if payload.len() > super::MAX_FRAME_LEN {
            return Err(TransportError::FrameTooLarge(payload.len() as u64));
        }
        let len = payload.len() as u32;
        self.scratch.clear();
        self.scratch.reserve(4 + payload.len());
        self.scratch.extend_from_slice(&len.to_le_bytes());
        self.scratch.extend_from_slice(payload);
        self.stream.write_all(&self.scratch)?;
        self.counters.add_tx(payload.len());
        Ok(())
    }

    fn recv(&mut self, buf: &mut Vec<u8>) -> Result<(), TransportError> {
        let mut prefix = [0u8; 4];
        self.stream.read_exact(&mut prefix)?;
        let len = u32::from_le_bytes(prefix) as usize;
        if len > super::MAX_FRAME_LEN {
            return Err(TransportError::FrameTooLarge(len as u64));
        }
        // Append via `take` + `read_to_end`: no pre-zeroing memset of the
        // buffer, which matters at weights-frame sizes (4·d bytes/frame).
        buf.clear();
        buf.reserve(len);
        let got = (&mut self.stream).take(len as u64).read_to_end(buf)?;
        if got < len {
            return Err(TransportError::Closed);
        }
        self.counters.add_rx(len);
        Ok(())
    }

    fn counters(&self) -> LinkCounters {
        self.counters.clone()
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }
}

struct TcpListenerWrap {
    listener: TcpListener,
}

impl Listener for TcpListenerWrap {
    fn accept(&mut self) -> Result<(Box<dyn Connection>, Hello), TransportError> {
        let (stream, _) = self.listener.accept()?;
        let mut conn = TcpConn::new(stream)?;
        let mut buf = Vec::new();
        conn.recv(&mut buf)?;
        let hello = Hello::decode(&buf)?;
        Ok((Box::new(conn), hello))
    }

    fn local_addr(&self) -> String {
        self.listener
            .local_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "<unbound>".into())
    }
}

impl Transport for TcpTransport {
    fn listen(&self, addr: &str) -> Result<Box<dyn Listener>, TransportError> {
        let listener = TcpListener::bind(addr)?;
        Ok(Box::new(TcpListenerWrap { listener }))
    }

    fn connect(&self, addr: &str, hello: &Hello) -> Result<Box<dyn Connection>, TransportError> {
        let stream = TcpStream::connect(addr)?;
        let mut conn = TcpConn::new(stream)?;
        let mut frame = Vec::new();
        hello.encode(&mut frame);
        conn.send(&frame)?;
        Ok(Box::new(conn))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_roundtrip_with_matching_counters() {
        let t = TcpTransport::new();
        let mut listener = t.listen("127.0.0.1:0").unwrap();
        let addr = listener.local_addr();
        let client = std::thread::spawn(move || {
            let mut conn = t.connect(&addr, &Hello::new(2)).unwrap();
            conn.send(b"ping").unwrap();
            let mut buf = Vec::new();
            conn.recv(&mut buf).unwrap();
            assert_eq!(buf, b"pong-back");
            conn.counters()
        });
        let (mut conn, hello) = listener.accept().unwrap();
        assert_eq!(hello.worker_id, 2);
        let mut buf = Vec::new();
        conn.recv(&mut buf).unwrap();
        assert_eq!(buf, b"ping");
        conn.send(b"pong-back").unwrap();
        let cc = client.join().unwrap();
        // What the client sent, the server received — framed bytes agree.
        assert_eq!(cc.bytes_tx(), conn.counters().bytes_rx());
        assert_eq!(cc.bytes_rx(), conn.counters().bytes_tx());
    }

    #[test]
    fn oversized_length_prefix_is_rejected_before_allocating() {
        let t = TcpTransport::new();
        let mut listener = t.listen("127.0.0.1:0").unwrap();
        let addr = listener.local_addr();
        let raw = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            // Claim a 4 GiB − 1 frame; never send it.
            s.write_all(&u32::MAX.to_le_bytes()).unwrap();
            s.flush().unwrap();
            // Hold the socket open until the server has reacted.
            let mut byte = [0u8; 1];
            let _ = s.read(&mut byte);
        });
        let err = listener.accept().unwrap_err();
        assert!(
            matches!(err, TransportError::FrameTooLarge(n) if n == u32::MAX as u64),
            "{err:?}"
        );
        raw.join().unwrap();
    }

    #[test]
    fn garbage_handshake_is_rejected() {
        let t = TcpTransport::new();
        let mut listener = t.listen("127.0.0.1:0").unwrap();
        let addr = listener.local_addr();
        let raw = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            // A well-formed frame whose payload is not a hello.
            s.write_all(&9u32.to_le_bytes()).unwrap();
            s.write_all(b"NOTGSPR!!").unwrap();
            s.flush().unwrap();
            let mut byte = [0u8; 1];
            let _ = s.read(&mut byte);
        });
        let err = listener.accept().unwrap_err();
        assert!(matches!(err, TransportError::BadHandshake(_)), "{err:?}");
        raw.join().unwrap();
    }
}
