//! TCP transport backend: `std::net` sockets (loopback or a real NIC)
//! behind the [`Transport`] trait.
//!
//! Frames are written with `write_vectored`: the 4-byte length prefix and
//! the payload segments go to the kernel as one gather list, so steady
//! state is a single syscall with **no contiguous assembly copy** of the
//! payload (the scratch-buffer memcpy the first TCP backend paid per
//! frame). A short-write loop re-submits the unwritten tail, degrading to
//! per-segment `write_all` only if the socket stops accepting vectored
//! writes entirely. `TCP_NODELAY` is set because the parameter-server
//! protocol is request/response shaped and Nagle batching would serialize
//! rounds on the RTT. The receive path validates the declared length
//! against [`super::MAX_FRAME_LEN`] *before* allocating, so an adversarial
//! or corrupted peer cannot OOM the process.

use super::{Connection, Hello, Listener, LinkCounters, Transport, TransportError};
use std::io::{IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream};

/// The TCP backend (stateless; addresses are `host:port` strings, with
/// `host:0` asking the OS for a free port — read it back via
/// [`Listener::local_addr`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct TcpTransport;

impl TcpTransport {
    pub fn new() -> Self {
        Self
    }
}

struct TcpConn {
    stream: TcpStream,
    counters: LinkCounters,
    peer: String,
}

impl TcpConn {
    fn new(stream: TcpStream) -> Result<Self, TransportError> {
        stream.set_nodelay(true)?;
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "<unknown>".into());
        Ok(Self {
            stream,
            counters: LinkCounters::new(),
            peer,
        })
    }

    /// Write `segments` (prefix already included by the caller) as one
    /// gather list, looping on short writes. `write_vectored` may accept
    /// any prefix of the requested bytes; the loop re-submits from the
    /// first unwritten byte. If the socket ever reports zero progress on a
    /// non-empty request, fall back to plain `write_all` per segment — the
    /// bytes on the wire are identical either way.
    fn write_segments(&mut self, segments: &[&[u8]]) -> Result<(), TransportError> {
        let mut idx = 0; // first segment not fully written
        let mut off = 0; // bytes of segments[idx] already written
        while idx < segments.len() {
            if off == segments[idx].len() {
                idx += 1;
                off = 0;
                continue;
            }
            let mut iov: Vec<IoSlice<'_>> = Vec::with_capacity(segments.len() - idx);
            iov.push(IoSlice::new(&segments[idx][off..]));
            iov.extend(segments[idx + 1..].iter().map(|s| IoSlice::new(s)));
            let mut n = self.stream.write_vectored(&iov)?;
            if n == 0 {
                // write_all fallback: drain the remaining segments one by
                // one (handles sockets/wrappers that refuse gather writes).
                self.stream.write_all(&segments[idx][off..])?;
                for s in &segments[idx + 1..] {
                    self.stream.write_all(s)?;
                }
                return Ok(());
            }
            // Advance (idx, off) past the n bytes the kernel accepted.
            while n > 0 {
                let rem = segments[idx].len() - off;
                if n >= rem {
                    n -= rem;
                    idx += 1;
                    off = 0;
                } else {
                    off += n;
                    n = 0;
                }
            }
        }
        Ok(())
    }
}

impl Connection for TcpConn {
    fn send(&mut self, payload: &[u8]) -> Result<(), TransportError> {
        self.send_vectored(&[payload])
    }

    fn send_vectored(&mut self, segments: &[&[u8]]) -> Result<(), TransportError> {
        let total: usize = segments.iter().map(|s| s.len()).sum();
        // MAX_FRAME_LEN ≪ u32::MAX, so the cap check makes the cast safe.
        if total > super::MAX_FRAME_LEN {
            return Err(TransportError::FrameTooLarge(total as u64));
        }
        let prefix = (total as u32).to_le_bytes();
        let mut gather: Vec<&[u8]> = Vec::with_capacity(1 + segments.len());
        gather.push(&prefix);
        gather.extend_from_slice(segments);
        self.write_segments(&gather)?;
        // The trace context (if stamped) lives in the tag-bearing first
        // segment; peeking it links this send's frame_tx event to the
        // peer's frame_rx in a merged cross-process timeline.
        let ctx = segments.first().and_then(|s| super::frame::peek_ctx(s));
        self.counters.add_tx_ctx(total, ctx);
        if segments.len() > 1 {
            // A multi-segment frame went out without the contiguous
            // assembly copy the single-buffer path would have paid.
            self.counters.note_vectored();
        }
        Ok(())
    }

    fn recv(&mut self, buf: &mut Vec<u8>) -> Result<(), TransportError> {
        let mut prefix = [0u8; 4];
        self.stream.read_exact(&mut prefix)?;
        let len = u32::from_le_bytes(prefix) as usize;
        if len > super::MAX_FRAME_LEN {
            return Err(TransportError::FrameTooLarge(len as u64));
        }
        // Append via `take` + `read_to_end`: no pre-zeroing memset of the
        // buffer, which matters at weights-frame sizes (4·d bytes/frame).
        buf.clear();
        buf.reserve(len);
        let got = (&mut self.stream).take(len as u64).read_to_end(buf)?;
        if got < len {
            return Err(TransportError::Closed);
        }
        self.counters.add_rx_ctx(len, super::frame::peek_ctx(buf));
        Ok(())
    }

    fn counters(&self) -> LinkCounters {
        self.counters.clone()
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }
}

struct TcpListenerWrap {
    listener: TcpListener,
}

impl Listener for TcpListenerWrap {
    fn accept(&mut self) -> Result<(Box<dyn Connection>, Hello), TransportError> {
        let (stream, _) = self.listener.accept()?;
        let mut conn = TcpConn::new(stream)?;
        let mut buf = Vec::new();
        conn.recv(&mut buf)?;
        let hello = Hello::decode(&buf)?;
        Ok((Box::new(conn), hello))
    }

    fn local_addr(&self) -> String {
        self.listener
            .local_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "<unbound>".into())
    }
}

impl Transport for TcpTransport {
    fn listen(&self, addr: &str) -> Result<Box<dyn Listener>, TransportError> {
        let listener = TcpListener::bind(addr)?;
        Ok(Box::new(TcpListenerWrap { listener }))
    }

    fn connect(&self, addr: &str, hello: &Hello) -> Result<Box<dyn Connection>, TransportError> {
        let stream = TcpStream::connect(addr)?;
        let mut conn = TcpConn::new(stream)?;
        let mut frame = Vec::new();
        hello.encode(&mut frame);
        conn.send(&frame)?;
        Ok(Box::new(conn))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_roundtrip_with_matching_counters() {
        let t = TcpTransport::new();
        let mut listener = t.listen("127.0.0.1:0").unwrap();
        let addr = listener.local_addr();
        let client = std::thread::spawn(move || {
            let mut conn = t.connect(&addr, &Hello::new(2)).unwrap();
            conn.send(b"ping").unwrap();
            let mut buf = Vec::new();
            conn.recv(&mut buf).unwrap();
            assert_eq!(buf, b"pong-back");
            conn.counters()
        });
        let (mut conn, hello) = listener.accept().unwrap();
        assert_eq!(hello.worker_id, 2);
        let mut buf = Vec::new();
        conn.recv(&mut buf).unwrap();
        assert_eq!(buf, b"ping");
        conn.send(b"pong-back").unwrap();
        let cc = client.join().unwrap();
        // What the client sent, the server received — framed bytes agree.
        assert_eq!(cc.bytes_tx(), conn.counters().bytes_rx());
        assert_eq!(cc.bytes_rx(), conn.counters().bytes_tx());
    }

    #[test]
    fn vectored_send_is_bytewise_identical_to_contiguous_send() {
        let t = TcpTransport::new();
        let mut listener = t.listen("127.0.0.1:0").unwrap();
        let addr = listener.local_addr();
        let client = std::thread::spawn(move || {
            let mut conn = t.connect(&addr, &Hello::new(0)).unwrap();
            // The same logical frame, three ways: contiguous, two-segment,
            // and many-segment with empty slices mixed in.
            let payload = b"prefix-middle-suffix";
            conn.send(payload).unwrap();
            conn.send_vectored(&[b"prefix-", b"middle-suffix"]).unwrap();
            conn.send_vectored(&[b"", b"prefix-", b"middle", b"-suffix", b""])
                .unwrap();
            conn.counters()
        });
        let (mut conn, _) = listener.accept().unwrap();
        let mut buf = Vec::new();
        for _ in 0..3 {
            conn.recv(&mut buf).unwrap();
            assert_eq!(buf, b"prefix-middle-suffix");
        }
        let cc = client.join().unwrap();
        // Counters agree with the receiver, and only the two multi-segment
        // frames count as vectored (the hello and the contiguous send used
        // a single payload segment).
        assert_eq!(cc.bytes_tx(), conn.counters().bytes_rx());
        assert_eq!(cc.frames_tx(), 4); // hello + 3 frames
        assert_eq!(cc.frames_vectored(), 2);
    }

    #[test]
    fn oversized_vectored_frame_is_rejected_before_writing() {
        let t = TcpTransport::new();
        let mut listener = t.listen("127.0.0.1:0").unwrap();
        let addr = listener.local_addr();
        let client = std::thread::spawn(move || {
            let mut conn = t.connect(&addr, &Hello::new(0)).unwrap();
            let big = vec![0u8; super::super::MAX_FRAME_LEN / 2 + 1];
            let err = conn.send_vectored(&[&big, &big]).unwrap_err();
            assert!(matches!(err, TransportError::FrameTooLarge(_)), "{err:?}");
            // The link is still usable: nothing of the oversized frame hit
            // the wire.
            conn.send(b"ok").unwrap();
        });
        let (mut conn, _) = listener.accept().unwrap();
        let mut buf = Vec::new();
        conn.recv(&mut buf).unwrap();
        assert_eq!(buf, b"ok");
        client.join().unwrap();
    }

    #[test]
    fn oversized_length_prefix_is_rejected_before_allocating() {
        let t = TcpTransport::new();
        let mut listener = t.listen("127.0.0.1:0").unwrap();
        let addr = listener.local_addr();
        let raw = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            // Claim a 4 GiB − 1 frame; never send it.
            s.write_all(&u32::MAX.to_le_bytes()).unwrap();
            s.flush().unwrap();
            // Hold the socket open until the server has reacted.
            let mut byte = [0u8; 1];
            let _ = s.read(&mut byte);
        });
        let err = listener.accept().unwrap_err();
        assert!(
            matches!(err, TransportError::FrameTooLarge(n) if n == u32::MAX as u64),
            "{err:?}"
        );
        raw.join().unwrap();
    }

    #[test]
    fn garbage_handshake_is_rejected() {
        let t = TcpTransport::new();
        let mut listener = t.listen("127.0.0.1:0").unwrap();
        let addr = listener.local_addr();
        let raw = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            // A well-formed frame whose payload is not a hello.
            s.write_all(&9u32.to_le_bytes()).unwrap();
            s.write_all(b"NOTGSPR!!").unwrap();
            s.flush().unwrap();
            let mut byte = [0u8; 1];
            let _ = s.read(&mut byte);
        });
        let err = listener.accept().unwrap_err();
        assert!(matches!(err, TransportError::BadHandshake(_)), "{err:?}");
        raw.join().unwrap();
    }
}
