//! Experiment configuration: typed structs for every workload plus a simple
//! `key = value` config-file format (serde/toml unavailable offline).
//!
//! Files look like:
//! ```text
//! # synthetic logistic regression, Fig 1 cell (1,1)
//! n = 1024
//! d = 2048
//! c1 = 0.6
//! c2 = 0.25
//! reg = 9.765625e-5
//! rho = 0.1
//! method = gspar
//! ```
//! Sections (`[name]`) namespace keys as `name.key`.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

/// Which gradient compressor a run uses. This is the user-facing switch that
/// selects among the paper's method and every baseline we implement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Dense (no compression) — the paper's "baseline".
    Dense,
    /// The paper's gradient sparsification, greedy solver (Alg. 3) — "GSpar".
    GSpar,
    /// The paper's closed-form solver (Alg. 2).
    GSparExact,
    /// Uniform-probability sampling baseline — "UniSp".
    UniSp,
    /// QSGD stochastic quantization [Alistarh et al.].
    Qsgd,
    /// TernGrad {-1,0,+1} ternarization [Wen et al.].
    TernGrad,
    /// Deterministic top-k (biased) ablation.
    TopK,
    /// 1-bit SGD with error feedback [Seide et al.] ablation.
    OneBit,
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        Some(match s.to_ascii_lowercase().as_str() {
            "dense" | "baseline" => Method::Dense,
            "gspar" | "greedy" => Method::GSpar,
            "gspar-exact" | "exact" | "closed-form" => Method::GSparExact,
            "unisp" | "uniform" => Method::UniSp,
            "qsgd" => Method::Qsgd,
            "terngrad" => Method::TernGrad,
            "topk" | "top-k" => Method::TopK,
            "onebit" | "1bit" => Method::OneBit,
            _ => return None,
        })
    }

    pub fn all() -> &'static [Method] {
        &[
            Method::Dense,
            Method::GSpar,
            Method::GSparExact,
            Method::UniSp,
            Method::Qsgd,
            Method::TernGrad,
            Method::TopK,
            Method::OneBit,
        ]
    }
}

impl fmt::Display for Method {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Method::Dense => "dense",
            Method::GSpar => "gspar",
            Method::GSparExact => "gspar-exact",
            Method::UniSp => "unisp",
            Method::Qsgd => "qsgd",
            Method::TernGrad => "terngrad",
            Method::TopK => "topk",
            Method::OneBit => "onebit",
        };
        f.write_str(s)
    }
}

/// Synchronous convex experiment configuration (Figures 1–6).
#[derive(Clone, Debug)]
pub struct ConvexConfig {
    /// Dataset size N (paper: 1024).
    pub n: usize,
    /// Dimension d (paper: 2048).
    pub d: usize,
    /// Magnitude shrink factor C1 (paper: 0.6 / 0.9; smaller = sparser).
    pub c1: f32,
    /// Shrink threshold C2 (paper: 4^-1, 4^-2, 4^-3).
    pub c2: f32,
    /// ℓ2 regularization λ2 (paper: 1/(10N), 1/N).
    pub reg: f32,
    /// Target density ρ for Algorithm 3.
    pub rho: f32,
    /// Number of workers M (paper: 4).
    pub workers: usize,
    /// Minibatch size per worker (paper: 8).
    pub batch: usize,
    /// Data passes (epochs) to run.
    pub epochs: usize,
    /// Base learning rate.
    pub lr: f32,
    /// Compressor.
    pub method: Method,
    /// RNG seed.
    pub seed: u64,
    /// QSGD bit width (only for Method::Qsgd).
    pub qsgd_bits: u32,
}

impl Default for ConvexConfig {
    fn default() -> Self {
        Self {
            n: 1024,
            d: 2048,
            c1: 0.6,
            c2: 0.25,
            reg: 1.0 / (10.0 * 1024.0),
            rho: 0.1,
            workers: 4,
            batch: 8,
            epochs: 30,
            lr: 0.5,
            method: Method::GSpar,
            seed: 42,
            qsgd_bits: 4,
        }
    }
}

/// Asynchronous shared-memory SVM configuration (Figure 9, §5.3).
#[derive(Clone, Debug)]
pub struct AsyncSvmConfig {
    pub n: usize,
    pub d: usize,
    pub c1: f32,
    pub c2: f32,
    pub reg: f32,
    pub rho: f32,
    pub threads: usize,
    pub lr: f32,
    pub method: Method,
    pub seed: u64,
    /// Total coordinate updates budget across all threads.
    pub total_steps: usize,
    /// Update scheme: lock / atomic / wild.
    pub scheme: UpdateScheme,
}

/// §5.3's three shared-memory update schemes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateScheme {
    Lock,
    Atomic,
    Wild,
}

impl UpdateScheme {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "lock" => UpdateScheme::Lock,
            "atomic" => UpdateScheme::Atomic,
            "wild" => UpdateScheme::Wild,
            _ => return None,
        })
    }
}

impl fmt::Display for UpdateScheme {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            UpdateScheme::Lock => "lock",
            UpdateScheme::Atomic => "atomic",
            UpdateScheme::Wild => "wild",
        })
    }
}

impl Default for AsyncSvmConfig {
    fn default() -> Self {
        Self {
            n: 51200,
            d: 256,
            c1: 0.01,
            c2: 0.9,
            reg: 0.1,
            rho: 0.05,
            threads: 16,
            lr: 0.25,
            method: Method::GSpar,
            seed: 42,
            total_steps: 200_000,
            scheme: UpdateScheme::Atomic,
        }
    }
}

/// Raw parsed `key = value` file.
#[derive(Debug, Default, Clone)]
pub struct ConfigFile {
    map: BTreeMap<String, String>,
}

impl ConfigFile {
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected `key = value`", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            map.insert(key, v.trim().to_string());
        }
        Ok(Self { map })
    }

    pub fn load(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        match self.map.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| format!("config key `{key}`: cannot parse `{s}`")),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }

    /// Build a [`ConvexConfig`] starting from defaults.
    pub fn convex(&self) -> Result<ConvexConfig, String> {
        let mut c = ConvexConfig::default();
        c.n = self.get_parse("n", c.n)?;
        c.d = self.get_parse("d", c.d)?;
        c.c1 = self.get_parse("c1", c.c1)?;
        c.c2 = self.get_parse("c2", c.c2)?;
        c.reg = self.get_parse("reg", c.reg)?;
        c.rho = self.get_parse("rho", c.rho)?;
        c.workers = self.get_parse("workers", c.workers)?;
        c.batch = self.get_parse("batch", c.batch)?;
        c.epochs = self.get_parse("epochs", c.epochs)?;
        c.lr = self.get_parse("lr", c.lr)?;
        c.seed = self.get_parse("seed", c.seed)?;
        c.qsgd_bits = self.get_parse("qsgd_bits", c.qsgd_bits)?;
        if let Some(m) = self.get("method") {
            c.method = Method::parse(m).ok_or_else(|| format!("unknown method `{m}`"))?;
        }
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic_file() {
        let cf = ConfigFile::parse(
            "# comment\n n = 512 \n method = unisp\n[net]\nbandwidth = 1e9\n",
        )
        .unwrap();
        assert_eq!(cf.get("n"), Some("512"));
        assert_eq!(cf.get("net.bandwidth"), Some("1e9"));
        let c = cf.convex().unwrap();
        assert_eq!(c.n, 512);
        assert_eq!(c.method, Method::UniSp);
        assert_eq!(c.d, 2048); // default preserved
    }

    #[test]
    fn parse_error_reports_line() {
        let err = ConfigFile::parse("valid = 1\nbogus line\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn bad_value_reports_key() {
        let cf = ConfigFile::parse("n = notanumber\n").unwrap();
        let err = cf.convex().unwrap_err();
        assert!(err.contains("`n`"), "{err}");
    }

    #[test]
    fn method_roundtrip() {
        for m in Method::all() {
            assert_eq!(Method::parse(&m.to_string()), Some(*m));
        }
        assert_eq!(Method::parse("nope"), None);
    }

    #[test]
    fn scheme_roundtrip() {
        for s in [UpdateScheme::Lock, UpdateScheme::Atomic, UpdateScheme::Wild] {
            assert_eq!(UpdateScheme::parse(&s.to_string()), Some(s));
        }
    }

    #[test]
    fn defaults_match_paper() {
        let c = ConvexConfig::default();
        assert_eq!(c.n, 1024);
        assert_eq!(c.d, 2048);
        assert_eq!(c.workers, 4);
        assert_eq!(c.batch, 8);
        let a = AsyncSvmConfig::default();
        assert_eq!(a.n, 51200);
        assert_eq!(a.d, 256);
        assert!((a.c1 - 0.01).abs() < 1e-9);
        assert!((a.c2 - 0.9).abs() < 1e-9);
    }
}
