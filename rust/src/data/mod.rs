//! Dataset generators.
//!
//! * [`synthetic`] — the paper's two synthetic recipes: the §5.1 logistic
//!   regression data (Gaussian features magnitude-sparsified by `(C₁, C₂)`,
//!   labels from a random linear teacher) and the §5.3 SVM data (same
//!   sparsification, noisy teacher);
//! * [`cifar_like`] — the CIFAR-10 stand-in for the §5.2 CNN experiments
//!   (class-conditional structured images, 32×32×3, 10 classes; see
//!   DESIGN.md §Substitutions);
//! * [`corpus`] — a tiny deterministic byte corpus for the transformer
//!   end-to-end example.

mod cifar_like;
mod corpus;
mod synthetic;

pub use cifar_like::{CifarLike, IMG_CLASSES, IMG_DIM};
pub use corpus::ByteCorpus;
pub use synthetic::{gen_logistic, gen_svm, Dataset};

/// Deterministic shard of example indices for worker `m` of `M` (round-robin,
/// matching "each of them owns its local copy ... local data" in §1/Alg. 1).
pub fn shard_indices(n: usize, worker: usize, num_workers: usize) -> Vec<usize> {
    (0..n).filter(|i| i % num_workers == worker).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_partition_the_dataset() {
        let n = 103;
        let m = 4;
        let mut seen = vec![false; n];
        for w in 0..m {
            for i in shard_indices(n, w, m) {
                assert!(!seen[i], "index {i} in two shards");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }
}
