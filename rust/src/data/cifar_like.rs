//! CIFAR-10 stand-in for the §5.2 CNN experiments (no network access — see
//! DESIGN.md §Substitutions).
//!
//! Class-conditional structured images: each of the 10 classes owns a set of
//! oriented frequency/blob prototypes; a sample is a noisy mixture of its
//! class prototypes. This gives a task a small conv net genuinely learns
//! (loss decreases, classes separable) with naturally skewed conv-layer
//! gradients — the property the paper's per-layer sparsification exploits.

use crate::rngkit::Xoshiro256pp;

/// Image side (CIFAR: 32).
pub const IMG_DIM: usize = 32;
/// Number of classes (CIFAR: 10).
pub const IMG_CLASSES: usize = 10;

/// An in-memory synthetic image-classification dataset, CHW f32 layout.
#[derive(Clone)]
pub struct CifarLike {
    /// `n × (3·32·32)` images, flattened CHW, values in [-1, 1].
    pub images: Vec<f32>,
    pub labels: Vec<u8>,
    pub n: usize,
}

impl CifarLike {
    /// Pixel count per image.
    pub const PIXELS: usize = 3 * IMG_DIM * IMG_DIM;

    pub fn generate(n: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        // Per-class prototype parameters: orientation, frequency, color bias.
        let protos: Vec<(f32, f32, [f32; 3])> = (0..IMG_CLASSES)
            .map(|c| {
                let theta = std::f32::consts::PI * c as f32 / IMG_CLASSES as f32;
                let freq = 0.2 + 0.08 * (c % 5) as f32;
                let color = [
                    0.6 * ((c % 3) as f32 - 1.0),
                    0.6 * (((c / 3) % 3) as f32 - 1.0),
                    0.6 * (((c / 2) % 3) as f32 - 1.0),
                ];
                (theta, freq, color)
            })
            .collect();
        let mut images = vec![0.0f32; n * Self::PIXELS];
        let mut labels = vec![0u8; n];
        for s in 0..n {
            let c = rng.next_below(IMG_CLASSES as u64) as usize;
            labels[s] = c as u8;
            let (theta, freq, color) = protos[c];
            let phase = rng.next_f32() * std::f32::consts::TAU;
            let img = &mut images[s * Self::PIXELS..(s + 1) * Self::PIXELS];
            for ch in 0..3 {
                for yy in 0..IMG_DIM {
                    for xx in 0..IMG_DIM {
                        let u = xx as f32 * theta.cos() + yy as f32 * theta.sin();
                        let wave = (freq * u * std::f32::consts::TAU / IMG_DIM as f32
                            * IMG_DIM as f32
                            + phase)
                            .sin();
                        let noise = (rng.next_f32() - 0.5) * 0.6;
                        img[ch * IMG_DIM * IMG_DIM + yy * IMG_DIM + xx] =
                            (0.5 * wave + 0.4 * color[ch] + noise).clamp(-1.0, 1.0);
                    }
                }
            }
        }
        Self { images, labels, n }
    }

    /// Borrow image `i` as a CHW slice.
    pub fn image(&self, i: usize) -> &[f32] {
        &self.images[i * Self::PIXELS..(i + 1) * Self::PIXELS]
    }

    /// Copy a minibatch (images into `x`: `bs × PIXELS`; labels into `y`).
    pub fn batch_into(&self, idx: &[usize], x: &mut [f32], y: &mut [i32]) {
        assert_eq!(x.len(), idx.len() * Self::PIXELS);
        assert_eq!(y.len(), idx.len());
        for (b, &i) in idx.iter().enumerate() {
            x[b * Self::PIXELS..(b + 1) * Self::PIXELS].copy_from_slice(self.image(i));
            y[b] = self.labels[i] as i32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let ds = CifarLike::generate(20, 5);
        assert_eq!(ds.n, 20);
        assert_eq!(ds.images.len(), 20 * CifarLike::PIXELS);
        assert!(ds.images.iter().all(|&v| (-1.0..=1.0).contains(&v)));
        assert!(ds.labels.iter().all(|&l| (l as usize) < IMG_CLASSES));
    }

    #[test]
    fn classes_are_distinguishable() {
        // Mean images of different classes should differ far more than two
        // halves of the same class — i.e. there is real signal to learn.
        let ds = CifarLike::generate(600, 6);
        let mut means = vec![vec![0.0f64; CifarLike::PIXELS]; IMG_CLASSES];
        let mut counts = vec![0usize; IMG_CLASSES];
        for i in 0..ds.n {
            let c = ds.labels[i] as usize;
            counts[c] += 1;
            for (m, &v) in means[c].iter_mut().zip(ds.image(i)) {
                *m += v as f64;
            }
        }
        for c in 0..IMG_CLASSES {
            for m in means[c].iter_mut() {
                *m /= counts[c].max(1) as f64;
            }
        }
        let dist = |a: &[f64], b: &[f64]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>()
        };
        let d01 = dist(&means[0], &means[5]);
        assert!(d01 > 1.0, "class means too close: {d01}");
    }

    #[test]
    fn batch_into_copies() {
        let ds = CifarLike::generate(10, 7);
        let idx = [3usize, 7];
        let mut x = vec![0.0f32; 2 * CifarLike::PIXELS];
        let mut y = vec![0i32; 2];
        ds.batch_into(&idx, &mut x, &mut y);
        assert_eq!(&x[..CifarLike::PIXELS], ds.image(3));
        assert_eq!(y[0], ds.labels[3] as i32);
    }

    #[test]
    fn deterministic_by_seed() {
        let a = CifarLike::generate(5, 11);
        let b = CifarLike::generate(5, 11);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }
}
