//! Tiny deterministic byte corpus for the transformer end-to-end example:
//! a synthetic "language" with Zipf-ish token frequencies and local
//! structure (repeating phrase templates), so a small LM's loss visibly
//! drops below the uniform-entropy baseline within a few hundred steps.

use crate::rngkit::Xoshiro256pp;

/// A byte-level corpus with sampling of fixed-length training windows.
pub struct ByteCorpus {
    pub bytes: Vec<u8>,
    /// Vocabulary size (max byte value + 1 used by the generator).
    pub vocab: usize,
}

impl ByteCorpus {
    /// Generate `len` bytes of synthetic text over a `vocab ≤ 256` alphabet.
    pub fn generate(len: usize, vocab: usize, seed: u64) -> Self {
        assert!((2..=256).contains(&vocab));
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        // A bank of phrase templates (n-grams) reused with high probability:
        // gives the LM learnable bigram/trigram structure.
        let n_phrases = 64;
        let phrases: Vec<Vec<u8>> = (0..n_phrases)
            .map(|_| {
                let plen = 3 + rng.next_below(6) as usize;
                (0..plen)
                    .map(|_| {
                        // Zipf-ish marginal: favor small byte values.
                        let r = rng.next_f64();
                        ((r * r * vocab as f64) as usize).min(vocab - 1) as u8
                    })
                    .collect()
            })
            .collect();
        let mut bytes = Vec::with_capacity(len + 8);
        while bytes.len() < len {
            if rng.next_f32() < 0.85 {
                let p = &phrases[rng.next_below(n_phrases as u64) as usize];
                bytes.extend_from_slice(p);
            } else {
                bytes.push(rng.next_below(vocab as u64) as u8);
            }
        }
        bytes.truncate(len);
        Self { bytes, vocab }
    }

    /// Sample a `(tokens, targets)` window of length `seq` (targets are the
    /// next-token shift).
    pub fn sample_window(&self, seq: usize, rng: &mut Xoshiro256pp) -> (Vec<i32>, Vec<i32>) {
        assert!(self.bytes.len() > seq + 1);
        let start = rng.next_below((self.bytes.len() - seq - 1) as u64) as usize;
        let tokens = self.bytes[start..start + seq].iter().map(|&b| b as i32).collect();
        let targets = self.bytes[start + 1..start + seq + 1]
            .iter()
            .map(|&b| b as i32)
            .collect();
        (tokens, targets)
    }

    /// Empirical unigram entropy in nats (upper bound any LM should beat).
    pub fn unigram_entropy_nats(&self) -> f64 {
        let mut counts = vec![0u64; 256];
        for &b in &self.bytes {
            counts[b as usize] += 1;
        }
        let n = self.bytes.len() as f64;
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.ln()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_length_and_vocab() {
        let c = ByteCorpus::generate(10_000, 64, 3);
        assert_eq!(c.bytes.len(), 10_000);
        assert!(c.bytes.iter().all(|&b| (b as usize) < 64));
    }

    #[test]
    fn windows_are_shifted_pairs() {
        let c = ByteCorpus::generate(1000, 32, 4);
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let (t, y) = c.sample_window(16, &mut rng);
        assert_eq!(t.len(), 16);
        assert_eq!(y.len(), 16);
        assert_eq!(&t[1..], &y[..15]);
    }

    #[test]
    fn has_structure_below_uniform_entropy() {
        let c = ByteCorpus::generate(50_000, 64, 6);
        let h = c.unigram_entropy_nats();
        let uniform = (64f64).ln();
        assert!(h < uniform - 0.3, "unigram entropy {h} vs uniform {uniform}");
        assert!(h > 1.0, "degenerate corpus: {h}");
    }
}
