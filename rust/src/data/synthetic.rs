//! The paper's synthetic data recipes, transcribed exactly.
//!
//! §5.1 (logistic regression):
//! ```text
//! dense data generation:      x̄_ni ~ N(0,1)
//! magnitude sparsification:   B̄ ~ Uniform[0,1]^d;  B̄_i ← C₁·B̄_i  if B̄_i ≤ C₂
//! data sparsification:        x_n ← x̄_n ⊙ B̄
//! label generation:           w̄ ~ N(0, I);  y_n ← sign(x̄_nᵀ w̄)
//! ```
//! The smaller `C₁`/`C₂`, the sparser the effective gradients; the paper
//! notes the gradient is then roughly `((1−C₂)d, C₂·C₁/(C₁+2))`-approximately
//! sparse.
//!
//! §5.3 (SVM): same feature recipe with `w̄ ~ Uniform[−0.5, 0.5]^d` and noisy
//! labels `y_n = sign(x_nᵀ w̄ + σ), σ ~ N(0,1)`.

use crate::rngkit::Xoshiro256pp;
use crate::tensor::Matrix;

/// A binary-classification dataset: row-major features + ±1 labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Matrix,
    pub y: Vec<f32>,
    /// The magnitude mask B̄ actually applied (kept for diagnostics: its
    /// sparsity drives the gradient's (ρ, s)-approximate sparsity).
    pub magnitude: Vec<f32>,
    /// Teacher weights (for reference / debugging).
    pub teacher: Vec<f32>,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.x.rows()
    }

    pub fn d(&self) -> usize {
        self.x.cols()
    }
}

/// Shared feature recipe: N(0,1) features, magnitude vector sparsified by
/// `(c1, c2)` (`B̄_i ← C₁ B̄_i` when `B̄_i ≤ C₂`), applied column-wise.
fn gen_features(n: usize, d: usize, c1: f32, c2: f32, rng: &mut Xoshiro256pp) -> (Matrix, Vec<f32>) {
    let mut magnitude = vec![0.0f32; d];
    for b in magnitude.iter_mut() {
        let u = rng.next_f32();
        *b = if u <= c2 { c1 * u } else { u };
    }
    let mut x = Matrix::zeros(n, d);
    for r in 0..n {
        let row = x.row_mut(r);
        for (i, v) in row.iter_mut().enumerate() {
            *v = rng.next_gaussian() as f32 * magnitude[i];
        }
    }
    (x, magnitude)
}

/// §5.1 logistic-regression data. Labels use the *dense* features times the
/// Gaussian teacher (the paper applies the sign to `x̄ᵀw̄`; we use the masked
/// features — equivalent up to teacher rescaling — and note it here).
pub fn gen_logistic(n: usize, d: usize, c1: f32, c2: f32, seed: u64) -> Dataset {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let (x, magnitude) = gen_features(n, d, c1, c2, &mut rng);
    let teacher: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
    let y: Vec<f32> = (0..n)
        .map(|r| {
            let s = crate::tensor::dot(x.row(r), &teacher);
            if s >= 0.0 {
                1.0
            } else {
                -1.0
            }
        })
        .collect();
    Dataset {
        x,
        y,
        magnitude,
        teacher,
    }
}

/// §5.3 SVM data: uniform teacher, Gaussian label noise.
pub fn gen_svm(n: usize, d: usize, c1: f32, c2: f32, seed: u64) -> Dataset {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let teacher: Vec<f32> = (0..d).map(|_| rng.next_f32() - 0.5).collect();
    let (x, magnitude) = gen_features(n, d, c1, c2, &mut rng);
    let y: Vec<f32> = (0..n)
        .map(|r| {
            let s = crate::tensor::dot(x.row(r), &teacher) + rng.next_gaussian() as f32;
            if s >= 0.0 {
                1.0
            } else {
                -1.0
            }
        })
        .collect();
    Dataset {
        x,
        y,
        magnitude,
        teacher,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        let ds = gen_logistic(100, 64, 0.6, 0.25, 7);
        assert_eq!(ds.n(), 100);
        assert_eq!(ds.d(), 64);
        assert!(ds.y.iter().all(|&y| y == 1.0 || y == -1.0));
        assert_eq!(ds.magnitude.len(), 64);
    }

    #[test]
    fn smaller_c_constants_give_smaller_masked_columns() {
        // With C2 = 0.9 and C1 = 0.01 (the §5.3 setting), ~90% of columns
        // carry magnitude ≤ 0.01 — features are much sparser in magnitude.
        let strong = gen_svm(10, 2000, 0.01, 0.9, 8);
        let weak = gen_svm(10, 2000, 0.9, 0.25, 8);
        let small_strong = strong.magnitude.iter().filter(|&&b| b <= 0.011).count();
        let small_weak = weak.magnitude.iter().filter(|&&b| b <= 0.011).count();
        assert!(
            small_strong as f64 > 0.85 * 2000.0,
            "strong sparsification: {small_strong}"
        );
        assert!(
            (small_weak as f64) < 0.2 * 2000.0,
            "weak sparsification: {small_weak}"
        );
    }

    #[test]
    fn deterministic_by_seed() {
        let a = gen_logistic(20, 16, 0.6, 0.25, 99);
        let b = gen_logistic(20, 16, 0.6, 0.25, 99);
        let c = gen_logistic(20, 16, 0.6, 0.25, 100);
        assert_eq!(a.x.as_slice(), b.x.as_slice());
        assert_eq!(a.y, b.y);
        assert_ne!(a.x.as_slice(), c.x.as_slice());
    }

    #[test]
    fn labels_roughly_balanced() {
        let ds = gen_logistic(2000, 128, 0.6, 0.25, 13);
        let pos = ds.y.iter().filter(|&&y| y > 0.0).count();
        let frac = pos as f64 / 2000.0;
        assert!((0.35..0.65).contains(&frac), "label balance {frac}");
        let svm = gen_svm(2000, 128, 0.6, 0.25, 13);
        let pos = svm.y.iter().filter(|&&y| y > 0.0).count();
        let frac = pos as f64 / 2000.0;
        assert!((0.35..0.65).contains(&frac), "svm label balance {frac}");
    }

    #[test]
    fn gradient_of_linear_model_is_skewed_when_data_sparse() {
        // The property the whole paper rests on: sparse feature magnitudes
        // make gradients of linear models approximately sparse. Measure the
        // fraction of the gradient's ℓ1 mass in the top 10% coordinates.
        let mass_top10 = |c1: f32, c2: f32| {
            let ds = gen_logistic(256, 512, c1, c2, 21);
            let _w = vec![0.0f32; 512];
            // logistic gradient at w=0: -Σ y_n x_n σ(-0) = -½ Σ y_n x_n
            let mut g = vec![0.0f32; 512];
            for r in 0..ds.n() {
                crate::tensor::axpy(-0.5 * ds.y[r] / ds.n() as f32, ds.x.row(r), &mut g);
            }
            let mut mags: Vec<f32> = g.iter().map(|x| x.abs()).collect();
            mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let top: f32 = mags[..51].iter().sum();
            let total: f32 = mags.iter().sum();
            top / total
        };
        let sparse = mass_top10(0.01, 0.9); // §5.3-style strong sparsity
        let dense = mass_top10(1.0, 0.0); // no sparsification
        assert!(
            sparse > 0.75,
            "strongly-sparsified data should concentrate gradient mass: {sparse}"
        );
        assert!(sparse > dense + 0.2, "sparse {sparse} vs dense {dense}");
    }
}
