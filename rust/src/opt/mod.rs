//! Optimizers with the paper's step-size conventions.
//!
//! §5.1: gradient-sparsified **SGD** uses a diminishing step size
//! `η_t ∝ 1/(t · var)` where `var = ‖Q(g)‖²/‖g‖²` is the realized variance
//! inflation; sparsified **SVRG** uses a constant step divided by the same
//! factor (`η ∝ 1/var`); the Fig 5–6 QSGD comparison uses plain `η_t ∝ 1/t`
//! for both methods. §5.2 uses **Adam** (initial step 0.02). §5.3 uses
//! `lr/ρ` for the asynchronous runs.

mod adam;
mod schedule;

pub use adam::Adam;
pub use schedule::LrSchedule;

/// Plain SGD step `w ← w − η v` over a dense update vector.
#[derive(Debug, Clone)]
pub struct Sgd {
    pub schedule: LrSchedule,
    t: u64,
}

impl Sgd {
    pub fn new(schedule: LrSchedule) -> Self {
        Self { schedule, t: 0 }
    }

    /// Current step index (1-based after the first step).
    pub fn steps(&self) -> u64 {
        self.t
    }

    /// Apply one update with the realized variance factor `var` (pass 1.0
    /// for dense baselines). Returns the step size used.
    pub fn step(&mut self, w: &mut [f32], v: &[f32], var: f64) -> f32 {
        self.t += 1;
        let eta = self.schedule.eta(self.t, var);
        crate::tensor::axpy(-eta, v, w);
        eta
    }
}

/// SVRG inner-loop update (the update rule itself; the distributed variant
/// with a master-kept full gradient lives in `coordinator::svrg`).
#[derive(Debug, Clone)]
pub struct Svrg {
    pub schedule: LrSchedule,
    t: u64,
}

impl Svrg {
    pub fn new(schedule: LrSchedule) -> Self {
        Self { schedule, t: 0 }
    }

    /// Inner-loop step `w ← w − η v` where `v` is the (sparsified)
    /// variance-reduced gradient `Q(g(w) − g(w̃) + ∇f(w̃))`. SVRG keeps a
    /// constant base step divided by the variance factor (§5.1).
    pub fn step(&mut self, w: &mut [f32], v: &[f32], var: f64) -> f32 {
        self.t += 1;
        let eta = self.schedule.eta_constant(var);
        crate::tensor::axpy(-eta, v, w);
        eta
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_minimizes_quadratic() {
        // f(w) = ½‖w‖²; gradient = w; SGD with 1/t decay converges.
        let mut w = vec![4.0f32, -3.0];
        let mut sgd = Sgd::new(LrSchedule::inv_t(1.0));
        for _ in 0..200 {
            let g = w.clone();
            sgd.step(&mut w, &g, 1.0);
        }
        assert!(crate::tensor::norm2_sq(&w) < 1e-3, "{w:?}");
        assert_eq!(sgd.steps(), 200);
    }

    #[test]
    fn variance_scaled_steps_are_smaller() {
        let mut sgd_a = Sgd::new(LrSchedule::inv_t_var(1.0));
        let mut sgd_b = Sgd::new(LrSchedule::inv_t_var(1.0));
        let mut w1 = vec![1.0f32];
        let mut w2 = vec![1.0f32];
        let g = vec![1.0f32];
        let eta_low_var = sgd_a.step(&mut w1, &g, 1.0);
        let eta_high_var = sgd_b.step(&mut w2, &g, 4.0);
        assert!(eta_high_var < eta_low_var);
        assert!((eta_low_var / eta_high_var - 4.0).abs() < 1e-5);
    }

    #[test]
    fn svrg_constant_step_converges_on_quadratic() {
        let mut w = vec![2.0f32, 2.0];
        let mut svrg = Svrg::new(LrSchedule::constant(0.5));
        for _ in 0..100 {
            let g = w.clone(); // exact gradient: variance-reduced limit
            svrg.step(&mut w, &g, 1.0);
        }
        assert!(crate::tensor::norm2_sq(&w) < 1e-8);
    }
}
