//! Adam optimizer (Kingma & Ba) — §5.2 uses it for the CNN experiments with
//! initial step size 0.02 and per-layer gradient sparsification.

/// Adam state over one flat parameter vector (one instance per layer when
/// the coordinator sparsifies per-layer, matching §5.2).
#[derive(Debug, Clone)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    pub fn new(dim: usize, lr: f32) -> Self {
        Self {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; dim],
            v: vec![0.0; dim],
            t: 0,
        }
    }

    pub fn steps(&self) -> u64 {
        self.t
    }

    /// One Adam update with gradient `g` (possibly a decoded sparsified
    /// gradient — zeros simply decay the moments toward zero, which is the
    /// behaviour the paper's CNN experiments rely on).
    pub fn step(&mut self, w: &mut [f32], g: &[f32]) {
        assert_eq!(w.len(), g.len());
        assert_eq!(w.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        let lr_t = self.lr * b2t.sqrt() / b1t;
        for i in 0..w.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g[i] * g[i];
            w[i] -= lr_t * self.m[i] / (self.v[i].sqrt() + self.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_minimizes_quadratic() {
        let mut w = vec![3.0f32, -2.0, 1.0];
        let mut adam = Adam::new(3, 0.05);
        for _ in 0..2000 {
            let g = w.clone();
            adam.step(&mut w, &g);
        }
        assert!(crate::tensor::norm2_sq(&w) < 1e-4, "{w:?}");
    }

    #[test]
    fn adam_handles_sparse_gradients() {
        // Zeros in g must not produce NaNs or updates blowing up.
        let mut w = vec![1.0f32; 8];
        let mut adam = Adam::new(8, 0.02);
        for t in 0..500 {
            let g: Vec<f32> = (0..8)
                .map(|i| if (t + i) % 4 == 0 { w[i] * 4.0 } else { 0.0 })
                .collect();
            adam.step(&mut w, &g);
        }
        assert!(w.iter().all(|x| x.is_finite()));
        assert!(crate::tensor::norm2_sq(&w) < 0.5, "{w:?}");
    }

    #[test]
    fn bias_correction_first_step() {
        // After one step with gradient g, the update is ≈ lr·sign(g).
        let mut w = vec![0.0f32];
        let mut adam = Adam::new(1, 0.1);
        adam.step(&mut w, &[0.5]);
        assert!((w[0] + 0.1).abs() < 1e-3, "{w:?}");
    }
}
