//! Learning-rate schedules used by the paper's experiments.

/// A step-size rule. `eta(t, var)` for per-step decaying rules; the
/// variance factor divides the base rate as §5.1 prescribes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LrSchedule {
    /// Constant base rate (optionally divided by `var` via
    /// [`LrSchedule::eta_constant`]) — SVRG's convention.
    Constant { base: f32 },
    /// `η_t = base / t` — the Fig 5–6 convention (variance-agnostic).
    InvT { base: f32 },
    /// `η_t = base / (t · var)` — sparsified SGD's convention (§5.1).
    InvTVar { base: f32 },
}

impl LrSchedule {
    pub fn constant(base: f32) -> Self {
        LrSchedule::Constant { base }
    }

    pub fn inv_t(base: f32) -> Self {
        LrSchedule::InvT { base }
    }

    pub fn inv_t_var(base: f32) -> Self {
        LrSchedule::InvTVar { base }
    }

    /// Step size at (1-based) step `t` with realized variance factor `var`.
    pub fn eta(&self, t: u64, var: f64) -> f32 {
        let t = t.max(1) as f64;
        match *self {
            LrSchedule::Constant { base } => base,
            LrSchedule::InvT { base } => (base as f64 / t) as f32,
            LrSchedule::InvTVar { base } => (base as f64 / (t * var.max(1e-12))) as f32,
        }
    }

    /// Constant-style step with variance division (`η ∝ 1/var`) regardless
    /// of `t` — SVRG's rule. For `InvT`/`InvTVar` this falls back to `eta`
    /// at `t = 1`.
    pub fn eta_constant(&self, var: f64) -> f32 {
        match *self {
            LrSchedule::Constant { base } => (base as f64 / var.max(1e-12)) as f32,
            other => other.eta(1, var),
        }
    }

    pub fn base(&self) -> f32 {
        match *self {
            LrSchedule::Constant { base }
            | LrSchedule::InvT { base }
            | LrSchedule::InvTVar { base } => base,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inv_t_decays() {
        let s = LrSchedule::inv_t(1.0);
        assert!((s.eta(1, 1.0) - 1.0).abs() < 1e-7);
        assert!((s.eta(10, 1.0) - 0.1).abs() < 1e-7);
        // var is ignored by plain InvT.
        assert_eq!(s.eta(10, 5.0), s.eta(10, 1.0));
    }

    #[test]
    fn inv_t_var_divides_by_variance() {
        let s = LrSchedule::inv_t_var(1.0);
        assert!((s.eta(2, 2.0) - 0.25).abs() < 1e-7);
    }

    #[test]
    fn constant_with_var() {
        let s = LrSchedule::constant(0.8);
        assert_eq!(s.eta(100, 1.0), 0.8);
        assert!((s.eta_constant(2.0) - 0.4).abs() < 1e-7);
    }

    #[test]
    fn t_zero_clamped() {
        let s = LrSchedule::inv_t(1.0);
        assert!(s.eta(0, 1.0).is_finite());
    }
}
