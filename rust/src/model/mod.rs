//! Training models.
//!
//! * [`logistic`] / [`svm`] — the paper's two convex workloads in pure Rust
//!   (analytic losses and minibatch gradients). These drive the Figure 1–6
//!   and Figure 9 experiments and cross-check the HLO path.
//! * [`hlo`] — models backed by AOT-compiled JAX/Pallas artifacts (the CNN
//!   of §5.2 and the transformer e2e example), executed via
//!   [`crate::runtime`].

pub mod hlo;
mod logistic;
mod svm;

pub use logistic::LogisticModel;
pub use svm::SvmModel;

use crate::data::Dataset;

/// A convex empirical-risk model over a [`Dataset`]: everything the
/// synchronous and asynchronous trainers need.
pub trait ConvexModel: Send + Sync {
    /// Full-dataset objective f(w) (including regularizer).
    fn loss(&self, ds: &Dataset, w: &[f32]) -> f64;

    /// Minibatch stochastic gradient over example indices `idx`,
    /// accumulated into `g` (zeroed by the callee).
    fn grad_minibatch(&self, ds: &Dataset, w: &[f32], idx: &[usize], g: &mut [f32]);

    /// Full gradient ∇f(w) (for SVRG reference points and f* search).
    fn grad_full(&self, ds: &Dataset, w: &[f32], g: &mut [f32]) {
        let idx: Vec<usize> = (0..ds.n()).collect();
        self.grad_minibatch(ds, w, &idx, g);
    }
}

#[cfg(test)]
pub(crate) fn numerical_grad_check(
    model: &dyn ConvexModel,
    ds: &Dataset,
    w: &[f32],
    tol: f64,
) {
    let d = w.len();
    let mut g = vec![0.0f32; d];
    let idx: Vec<usize> = (0..ds.n()).collect();
    model.grad_minibatch(ds, w, &idx, &mut g);
    let h = 1e-3f32;
    // Spot-check a handful of coordinates against central differences.
    for i in (0..d).step_by((d / 7).max(1)) {
        let mut wp = w.to_vec();
        wp[i] += h;
        let mut wm = w.to_vec();
        wm[i] -= h;
        let num = (model.loss(ds, &wp) - model.loss(ds, &wm)) / (2.0 * h as f64);
        assert!(
            (num - g[i] as f64).abs() <= tol * (1.0 + num.abs()),
            "coord {i}: numerical {num} vs analytic {}",
            g[i]
        );
    }
}
