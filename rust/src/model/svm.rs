//! ℓ2-regularized SVM with hinge loss (§5.3, eq. 16):
//! `f(w) = (1/N) Σ_n max(1 − y_n·x_nᵀw, 0) + λ₂‖w‖²`.

use super::ConvexModel;
use crate::data::Dataset;
use crate::tensor::{axpy, dot, norm2_sq};

/// Hinge-loss SVM with ℓ2 regularization `reg`.
#[derive(Debug, Clone, Copy)]
pub struct SvmModel {
    pub reg: f32,
}

impl SvmModel {
    pub fn new(reg: f32) -> Self {
        Self { reg }
    }
}

impl ConvexModel for SvmModel {
    fn loss(&self, ds: &Dataset, w: &[f32]) -> f64 {
        let n = ds.n();
        let mut total = 0.0f64;
        for r in 0..n {
            let margin = ds.y[r] * dot(ds.x.row(r), w);
            total += (1.0 - margin).max(0.0) as f64;
        }
        total / n as f64 + (self.reg as f64) * norm2_sq(w) as f64
    }

    fn grad_minibatch(&self, ds: &Dataset, w: &[f32], idx: &[usize], g: &mut [f32]) {
        g.fill(0.0);
        let scale = 1.0 / idx.len() as f32;
        for &r in idx {
            let margin = ds.y[r] * dot(ds.x.row(r), w);
            if margin < 1.0 {
                // Subgradient of hinge: −y_n x_n on the active side.
                axpy(-ds.y[r] * scale, ds.x.row(r), g);
            }
        }
        axpy(2.0 * self.reg, w, g);
    }
}

impl SvmModel {
    /// Single-example subgradient written *sparsely*: calls `emit(i, value)`
    /// for each non-zero coordinate — the allocation-free path the §5.3
    /// asynchronous engine uses (gradient support = the example's support).
    pub fn grad_example_sparse<F: FnMut(usize, f32)>(
        &self,
        ds: &Dataset,
        w: &[f32],
        r: usize,
        mut emit: F,
    ) {
        let row = ds.x.row(r);
        let margin = ds.y[r] * dot(row, w);
        let active = margin < 1.0;
        for (i, &xi) in row.iter().enumerate() {
            let mut v = 2.0 * self.reg * w[i];
            if active && xi != 0.0 {
                v -= ds.y[r] * xi;
            }
            if v != 0.0 {
                emit(i, v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_svm;

    #[test]
    fn gradient_matches_numerical_away_from_kink() {
        let ds = gen_svm(48, 20, 0.6, 0.25, 41);
        let model = SvmModel::new(0.05);
        // Small random w keeps most margins away from the hinge kink.
        let mut rng = crate::rngkit::Xoshiro256pp::seed_from_u64(42);
        let w: Vec<f32> = (0..20).map(|_| (rng.next_gaussian() * 0.01) as f32).collect();
        crate::model::numerical_grad_check(&model, &ds, &w, 2e-2);
    }

    #[test]
    fn loss_decreases_under_gd() {
        let ds = gen_svm(256, 64, 0.01, 0.9, 43);
        let model = SvmModel::new(0.1);
        let mut w = vec![0.0f32; 64];
        let mut g = vec![0.0f32; 64];
        let l0 = model.loss(&ds, &w);
        for _ in 0..100 {
            model.grad_full(&ds, &w, &mut g);
            axpy(-0.2, &g, &mut w);
        }
        let l1 = model.loss(&ds, &w);
        assert!(l1 < l0, "{l0} -> {l1}");
    }

    #[test]
    fn sparse_example_grad_matches_dense() {
        let ds = gen_svm(32, 16, 0.6, 0.25, 44);
        let model = SvmModel::new(0.05);
        let mut rng = crate::rngkit::Xoshiro256pp::seed_from_u64(45);
        let w: Vec<f32> = (0..16).map(|_| (rng.next_gaussian() * 0.2) as f32).collect();
        for r in 0..8 {
            let mut dense = vec![0.0f32; 16];
            model.grad_minibatch(&ds, &w, &[r], &mut dense);
            let mut sparse = vec![0.0f32; 16];
            model.grad_example_sparse(&ds, &w, r, |i, v| sparse[i] += v);
            for i in 0..16 {
                assert!((dense[i] - sparse[i]).abs() < 1e-6, "r={r} coord {i}");
            }
        }
    }

    #[test]
    fn hinge_inactive_examples_contribute_only_regularizer() {
        let ds = gen_svm(4, 4, 1.0, 0.0, 46);
        let model = SvmModel::new(0.25);
        // Huge w in the teacher direction makes all margins > 1 ... use the
        // fact that with w = large · teacher-ish vector most are inactive;
        // instead test directly: zero-label-agreement case.
        let mut w = vec![0.0f32; 4];
        // Run GD to (approximate) stationarity.
        let mut g = vec![0.0f32; 4];
        let mut lr = 0.3f32;
        for _ in 0..2000 {
            model.grad_full(&ds, &w, &mut g);
            axpy(-lr, &g, &mut w);
            lr *= 0.999; // hinge subgradients need decay to settle
        }
        model.grad_full(&ds, &w, &mut g);
        assert!(crate::tensor::norm2_sq(&g) < 1e-2, "{g:?}");
    }
}
