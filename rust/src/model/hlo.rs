//! Models backed by AOT-compiled JAX/Pallas artifacts: the §5.2 CNN and the
//! end-to-end transformer.
//!
//! Convention (enforced by `python/compile/aot.py` and the manifest): a
//! *training-step artifact* named `<model>_step` takes
//! `(param_0 … param_{P−1}, x, y)` and returns
//! `(loss, grad_0 … grad_{P−1})`. Parameters stay on the Rust side as flat
//! `f32` vectors (one per layer — matching the paper's per-layer
//! sparsification in §5.2); an `<model>_init` artifact returns the initial
//! parameters from an i32 seed.

use crate::runtime::{lit, Runtime};
use anyhow::{anyhow, Context, Result};

/// Parameter layout: one named flat tensor per layer.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub dims: Vec<usize>,
}

impl ParamSpec {
    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// An HLO-backed training step for a model with P parameter tensors.
pub struct HloTrainStep {
    /// Artifact name (e.g. `cnn32_step`).
    pub step_name: String,
    pub params: Vec<ParamSpec>,
    /// Batch input dims (x), e.g. `[B, 3, 32, 32]`.
    pub x_dims: Vec<usize>,
    /// Dtype of the batch input (`f32` for images, `i32` for token ids).
    pub x_dtype: String,
    /// Label dims (y), e.g. `[B]`.
    pub y_dims: Vec<usize>,
}

impl HloTrainStep {
    /// Build the spec from the manifest signature of `<name>_step`:
    /// inputs `[p_0 … p_{P−1}, x, y]`, outputs `[loss, g_0 … g_{P−1}]`.
    pub fn from_manifest(rt: &mut Runtime, step_name: &str) -> Result<Self> {
        let exe = rt.get(step_name)?;
        let sig = exe
            .sig
            .clone()
            .ok_or_else(|| anyhow!("artifact `{step_name}` missing from manifest"))?;
        anyhow::ensure!(
            sig.inputs.len() >= 3,
            "step artifact must take at least (param, x, y)"
        );
        anyhow::ensure!(
            sig.outputs.len() == sig.inputs.len() - 1,
            "step artifact must return (loss, grads...) matching params; \
             got {} inputs / {} outputs",
            sig.inputs.len(),
            sig.outputs.len()
        );
        let p = sig.inputs.len() - 2;
        let params = sig.inputs[..p]
            .iter()
            .enumerate()
            .map(|(i, t)| ParamSpec {
                name: format!("p{i}"),
                dims: t.dims.clone(),
            })
            .collect();
        Ok(Self {
            step_name: step_name.to_string(),
            params,
            x_dims: sig.inputs[p].dims.clone(),
            x_dtype: sig.inputs[p].dtype.clone(),
            y_dims: sig.inputs[p + 1].dims.clone(),
        })
    }

    /// Total parameter count across layers.
    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.elements()).sum()
    }

    /// Flat per-layer sizes, in parameter order — the layer list the
    /// per-layer (and batched) gradient pipelines compress against
    /// (`Session::cluster(&step.layer_dims())`).
    pub fn layer_dims(&self) -> Vec<usize> {
        self.params.iter().map(|p| p.elements()).collect()
    }

    /// Initialize parameters by running `<model>_init` (artifact name is the
    /// step name with `_step` replaced by `_init`), seeded by `seed`.
    pub fn init_params(&self, rt: &mut Runtime, seed: i32) -> Result<Vec<Vec<f32>>> {
        let init_name = self
            .step_name
            .strip_suffix("_step")
            .map(|s| format!("{s}_init"))
            .ok_or_else(|| anyhow!("step artifact `{}` not named *_step", self.step_name))?;
        let exe = rt.get(&init_name)?;
        let outs = exe
            .run_f32(&[lit::i32_tensor(&[seed], &[])?])
            .with_context(|| format!("running {init_name}"))?;
        anyhow::ensure!(
            outs.len() == self.params.len(),
            "{init_name} returned {} tensors, expected {}",
            outs.len(),
            self.params.len()
        );
        for (o, spec) in outs.iter().zip(&self.params) {
            anyhow::ensure!(
                o.len() == spec.elements(),
                "init output size mismatch for {}",
                spec.name
            );
        }
        Ok(outs)
    }

    /// Run one training step with f32 batch input (images): returns
    /// `(loss, per-layer gradients)`.
    pub fn grads(
        &self,
        rt: &mut Runtime,
        params: &[Vec<f32>],
        x: &[f32],
        y: &[i32],
    ) -> Result<(f32, Vec<Vec<f32>>)> {
        let x_lit = lit::f32_tensor(
            x,
            &self.x_dims.iter().map(|&d| d as i64).collect::<Vec<_>>(),
        )?;
        self.grads_with(rt, params, x_lit, y)
    }

    /// Run one training step with i32 batch input (token ids).
    pub fn grads_tokens(
        &self,
        rt: &mut Runtime,
        params: &[Vec<f32>],
        tokens: &[i32],
        y: &[i32],
    ) -> Result<(f32, Vec<Vec<f32>>)> {
        let x_lit = lit::i32_tensor(
            tokens,
            &self.x_dims.iter().map(|&d| d as i64).collect::<Vec<_>>(),
        )?;
        self.grads_with(rt, params, x_lit, y)
    }

    fn grads_with(
        &self,
        rt: &mut Runtime,
        params: &[Vec<f32>],
        x_lit: xla::Literal,
        y: &[i32],
    ) -> Result<(f32, Vec<Vec<f32>>)> {
        anyhow::ensure!(params.len() == self.params.len(), "param count mismatch");
        let mut inputs = Vec::with_capacity(params.len() + 2);
        for (p, spec) in params.iter().zip(&self.params) {
            inputs.push(lit::f32_tensor(
                p,
                &spec.dims.iter().map(|&d| d as i64).collect::<Vec<_>>(),
            )?);
        }
        inputs.push(x_lit);
        inputs.push(lit::i32_tensor(
            y,
            &self.y_dims.iter().map(|&d| d as i64).collect::<Vec<_>>(),
        )?);
        let exe = rt.get(&self.step_name)?;
        let mut outs = exe.run_f32(&inputs)?;
        anyhow::ensure!(
            outs.len() == self.params.len() + 1,
            "step returned {} tensors",
            outs.len()
        );
        let loss = outs.remove(0)[0];
        Ok((loss, outs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_spec_elements() {
        let p = ParamSpec {
            name: "w".into(),
            dims: vec![3, 4, 5],
        };
        assert_eq!(p.elements(), 60);
    }

    // Full HLO-step tests require artifacts; they live in rust/tests/.
}
