//! ℓ2-regularized logistic regression (§5.1, eq. 14):
//! `f(w) = (1/N) Σ_n log(1 + exp(−y_n·x_nᵀw)) + λ₂‖w‖²`.

use super::ConvexModel;
use crate::data::Dataset;
use crate::tensor::{axpy, dot, log1p_exp_neg, norm2_sq, sigmoid};

/// Logistic regression with ℓ2 regularization `reg` (the paper's λ₂).
#[derive(Debug, Clone, Copy)]
pub struct LogisticModel {
    pub reg: f32,
}

impl LogisticModel {
    pub fn new(reg: f32) -> Self {
        Self { reg }
    }
}

impl ConvexModel for LogisticModel {
    fn loss(&self, ds: &Dataset, w: &[f32]) -> f64 {
        let n = ds.n();
        let mut total = 0.0f64;
        for r in 0..n {
            let margin = ds.y[r] * dot(ds.x.row(r), w);
            total += log1p_exp_neg(margin) as f64;
        }
        total / n as f64 + (self.reg as f64) * norm2_sq(w) as f64
    }

    fn grad_minibatch(&self, ds: &Dataset, w: &[f32], idx: &[usize], g: &mut [f32]) {
        g.fill(0.0);
        let scale = 1.0 / idx.len() as f32;
        for &r in idx {
            let margin = ds.y[r] * dot(ds.x.row(r), w);
            // dℓ/dmargin = −σ(−margin); chain through y_n x_n.
            let coef = -sigmoid(-margin) * ds.y[r] * scale;
            axpy(coef, ds.x.row(r), g);
        }
        // Regularizer gradient 2λ₂w.
        axpy(2.0 * self.reg, w, g);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_logistic;

    #[test]
    fn gradient_matches_numerical() {
        let ds = gen_logistic(40, 24, 0.6, 0.25, 31);
        let model = LogisticModel::new(0.01);
        let mut rng = crate::rngkit::Xoshiro256pp::seed_from_u64(32);
        let w: Vec<f32> = (0..24).map(|_| (rng.next_gaussian() * 0.3) as f32).collect();
        crate::model::numerical_grad_check(&model, &ds, &w, 5e-3);
    }

    #[test]
    fn loss_decreases_under_gd() {
        let ds = gen_logistic(128, 32, 0.6, 0.25, 33);
        let model = LogisticModel::new(1.0 / (10.0 * 128.0));
        let mut w = vec![0.0f32; 32];
        let mut g = vec![0.0f32; 32];
        let l0 = model.loss(&ds, &w);
        for _ in 0..50 {
            model.grad_full(&ds, &w, &mut g);
            axpy(-0.5, &g, &mut w);
        }
        let l1 = model.loss(&ds, &w);
        assert!(l1 < l0 * 0.8, "GD failed to reduce loss: {l0} -> {l1}");
    }

    #[test]
    fn minibatch_gradient_is_unbiased_estimator() {
        let ds = gen_logistic(64, 16, 0.9, 0.25, 34);
        let model = LogisticModel::new(0.0);
        let w = vec![0.05f32; 16];
        let mut full = vec![0.0f32; 16];
        model.grad_full(&ds, &w, &mut full);
        // Average single-example gradients = full gradient.
        let mut acc = vec![0.0f64; 16];
        let mut g = vec![0.0f32; 16];
        for r in 0..64 {
            model.grad_minibatch(&ds, &w, &[r], &mut g);
            for (a, &x) in acc.iter_mut().zip(&g) {
                *a += x as f64 / 64.0;
            }
        }
        for i in 0..16 {
            assert!((acc[i] - full[i] as f64).abs() < 1e-5, "coord {i}");
        }
    }

    #[test]
    fn regularizer_contributes() {
        let ds = gen_logistic(16, 8, 0.6, 0.25, 35);
        let w = vec![1.0f32; 8];
        let l0 = LogisticModel::new(0.0).loss(&ds, &w);
        let l1 = LogisticModel::new(0.5).loss(&ds, &w);
        assert!((l1 - l0 - 0.5 * 8.0).abs() < 1e-6);
    }
}
