//! A vendored mini exhaustive-interleaving checker (loom-style).
//!
//! ## How it works
//!
//! Threads under test run as real OS threads, but a token-passing scheduler
//! serializes them: exactly one thread (the `current` one) executes at a
//! time, and every instrumented operation — `lock`, `try_lock`, channel
//! `send`/`recv`, condvar wait/notify, spawn/join — is a *scheduling
//! point* where the scheduler may hand the token to any runnable thread.
//! Each run therefore corresponds to one interleaving, identified by the
//! sequence of decisions taken at points with more than one runnable
//! thread. [`check`] drives a depth-first search over those decisions:
//! replay a recorded prefix, take the next unexplored branch, run to
//! completion, repeat — until the tree is exhausted ([`Report::complete`])
//! or the iteration budget runs out.
//!
//! Blocking is modeled, never real: a thread that would block (`lock` on a
//! held mutex, `recv` on an empty channel, condvar wait, join on a live
//! thread) parks itself as `Blocked(reason)` and the token moves on. The
//! matching event (unlock, send/sender-drop, notify, thread exit) marks it
//! runnable again. If no thread is runnable and some are blocked, that
//! interleaving deadlocks — the checker panics with the blocked set, which
//! is precisely the bug class the `ShardPool` drop/panic protocol and the
//! SSP clock condvar must never exhibit.
//!
//! ## Rules for code under test
//!
//! * The closure must be deterministic given the schedule (no clocks, no
//!   ambient randomness) — divergence during replay panics.
//! * Every thread spawned inside the closure must be joined before it
//!   returns (dropping a [`crate::sparsify::ShardPool`] does this).
//! * Threads not created through [`thread::spawn`] (or used outside any
//!   active [`check`]) fall through to plain `std` behavior, so the same
//!   primitives stay usable in ordinary `--features model` builds.
//!
//! Limitations, accepted on purpose: no atomic-ordering exploration (the
//! scheduler is sequentially consistent), condvar notify wakes the
//! lowest-tid waiter, and there is no partial-order reduction — keep
//! modeled scenarios small (2–3 threads, a handful of operations).

use std::cell::RefCell;
use std::ops::{Deref, DerefMut};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc as std_mpsc;
use std::sync::{Arc, Condvar as StdCondvar, Mutex as StdMutex};
use std::sync::{LockResult, PoisonError, TryLockError, TryLockResult};

// ---------------------------------------------------------------------------
// Scheduler
// ---------------------------------------------------------------------------

/// What a parked thread is waiting for.
#[derive(Clone, Debug, PartialEq, Eq)]
enum Waiting {
    Lock(u64),
    Chan(u64),
    Cond(u64),
    Join(usize),
}

#[derive(Clone, Debug)]
enum Ts {
    Runnable,
    Blocked(Waiting),
    Finished,
}

/// One recorded decision: which of the runnable threads got the token.
#[derive(Clone, Debug)]
struct Choice {
    chosen: usize,
    options: Vec<usize>,
}

#[derive(Default)]
struct State {
    threads: Vec<Ts>,
    current: usize,
    choices: Vec<Choice>,
    replay: Vec<usize>,
    deadlock: Option<String>,
}

struct Sched {
    state: StdMutex<State>,
    cv: StdCondvar,
}

thread_local! {
    static CTX: RefCell<Option<(Arc<Sched>, usize)>> = const { RefCell::new(None) };
}

fn ctx() -> Option<(Arc<Sched>, usize)> {
    CTX.with(|c| c.borrow().clone())
}

static NEXT_OBJ_ID: AtomicU64 = AtomicU64::new(1);

fn fresh_id() -> u64 {
    NEXT_OBJ_ID.fetch_add(1, Ordering::Relaxed)
}

impl Sched {
    fn new(replay: Vec<usize>) -> Self {
        Self {
            state: StdMutex::new(State {
                threads: vec![Ts::Runnable],
                current: 0,
                choices: Vec::new(),
                replay,
                deadlock: None,
            }),
            cv: StdCondvar::new(),
        }
    }

    /// Pick the next token holder among runnable threads. Records a
    /// [`Choice`] whenever more than one thread could run (that is where
    /// the DFS branches). Must be called with the state lock held.
    fn pick_next(&self, st: &mut State) {
        let options: Vec<usize> = st
            .threads
            .iter()
            .enumerate()
            .filter(|(_, t)| matches!(t, Ts::Runnable))
            .map(|(i, _)| i)
            .collect();
        if options.is_empty() {
            if st
                .threads
                .iter()
                .any(|t| matches!(t, Ts::Blocked(_)))
            {
                st.deadlock = Some(format!("{:?}", st.threads));
                self.cv.notify_all();
            }
            return;
        }
        let idx = if options.len() == 1 {
            0
        } else {
            let d = st.choices.len();
            let i = if d < st.replay.len() { st.replay[d] } else { 0 };
            assert!(
                i < options.len(),
                "model: schedule diverged (replay wants option {i} of {} at depth {d}) \
                 — the closure is nondeterministic",
                options.len()
            );
            st.choices.push(Choice {
                chosen: i,
                options: options.clone(),
            });
            i
        };
        st.current = options[idx];
        self.cv.notify_all();
    }

    /// Park until this thread holds the token and is runnable.
    fn wait_turn(&self, me: usize) {
        let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        loop {
            if let Some(d) = &st.deadlock {
                let msg = d.clone();
                drop(st);
                panic!("model: deadlock — all live threads blocked: {msg}");
            }
            if st.current == me && matches!(st.threads[me], Ts::Runnable) {
                return;
            }
            st = self.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// A plain scheduling point for the current thread.
    fn yield_point(&self, me: usize) {
        {
            let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
            st.threads[me] = Ts::Runnable;
            self.pick_next(&mut st);
        }
        self.wait_turn(me);
    }

    /// Park the current thread as blocked and give the token away.
    fn block_current(&self, me: usize, w: Waiting) {
        {
            let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
            st.threads[me] = Ts::Blocked(w);
            self.pick_next(&mut st);
        }
        self.wait_turn(me);
    }

    /// Mark every thread blocked on `w` runnable (the waking thread keeps
    /// the token; the woken ones compete at the next scheduling point).
    fn wake(&self, pred: impl Fn(&Waiting) -> bool, limit: usize) {
        let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        let mut n = 0usize;
        for t in st.threads.iter_mut() {
            if n >= limit {
                break;
            }
            if let Ts::Blocked(w) = t {
                if pred(w) {
                    *t = Ts::Runnable;
                    n += 1;
                }
            }
        }
        if n > 0 {
            self.cv.notify_all();
        }
    }

    fn register_thread(&self) -> usize {
        let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        st.threads.push(Ts::Runnable);
        st.threads.len() - 1
    }

    fn thread_finished(&self, me: usize) {
        let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        st.threads[me] = Ts::Finished;
        for t in st.threads.iter_mut() {
            if matches!(t, Ts::Blocked(Waiting::Join(j)) if *j == me) {
                *t = Ts::Runnable;
            }
        }
        self.pick_next(&mut st);
    }

    fn is_thread_finished(&self, tid: usize) -> bool {
        let st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        matches!(st.threads[tid], Ts::Finished)
    }
}

/// Scheduling point for the calling thread, if a check is active.
fn maybe_yield() {
    if let Some((sched, me)) = ctx() {
        sched.yield_point(me);
    }
}

// ---------------------------------------------------------------------------
// The DFS driver
// ---------------------------------------------------------------------------

/// Exploration budget.
pub struct Opts {
    /// Stop after this many distinct interleavings (`complete` stays false
    /// if the budget is the reason exploration stopped).
    pub max_iterations: usize,
}

impl Default for Opts {
    fn default() -> Self {
        Self {
            max_iterations: 20_000,
        }
    }
}

/// Outcome of [`check`].
#[derive(Debug)]
pub struct Report {
    /// Number of distinct interleavings executed.
    pub iterations: usize,
    /// True when the schedule tree was exhausted (every interleaving ran).
    pub complete: bool,
}

/// Explore every interleaving of `f` (within `Opts::default()` budget).
/// Panics — with the failing schedule printed — as soon as any
/// interleaving panics, deadlocks, or diverges from its replay.
pub fn check(f: impl Fn()) -> Report {
    check_with(Opts::default(), f)
}

pub fn check_with(opts: Opts, f: impl Fn()) -> Report {
    assert!(
        ctx().is_none(),
        "model: check() does not nest inside another active check"
    );
    let mut replay: Vec<usize> = Vec::new();
    let mut iterations = 0usize;
    loop {
        let sched = Arc::new(Sched::new(replay.clone()));
        CTX.with(|c| *c.borrow_mut() = Some((Arc::clone(&sched), 0)));
        let result = catch_unwind(AssertUnwindSafe(&f));
        CTX.with(|c| *c.borrow_mut() = None);
        iterations += 1;
        if let Err(payload) = result {
            eprintln!(
                "model: interleaving #{iterations} failed; schedule prefix: {replay:?}"
            );
            resume_unwind(payload);
        }
        let choices = sched
            .state
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .choices
            .clone();
        let mut prefix = choices;
        let mut next: Option<Vec<usize>> = None;
        while let Some(c) = prefix.pop() {
            if c.chosen + 1 < c.options.len() {
                let mut r: Vec<usize> = prefix.iter().map(|p| p.chosen).collect();
                r.push(c.chosen + 1);
                next = Some(r);
                break;
            }
        }
        match next {
            None => {
                return Report {
                    iterations,
                    complete: true,
                }
            }
            Some(_) if iterations >= opts.max_iterations => {
                return Report {
                    iterations,
                    complete: false,
                }
            }
            Some(r) => replay = r,
        }
    }
}

// ---------------------------------------------------------------------------
// Mutex / Condvar
// ---------------------------------------------------------------------------

/// Instrumented drop-in for [`std::sync::Mutex`]. Under an active
/// [`check`], `lock` never blocks the OS thread: it try-locks, and parks in
/// the scheduler on contention.
pub struct Mutex<T> {
    id: u64,
    inner: StdMutex<T>,
}

impl<T> Mutex<T> {
    pub fn new(t: T) -> Self {
        Self {
            id: fresh_id(),
            inner: StdMutex::new(t),
        }
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        let Some((sched, me)) = ctx() else {
            return wrap_lock_result(self, self.inner.lock());
        };
        loop {
            sched.yield_point(me);
            match self.inner.try_lock() {
                Ok(g) => {
                    let guard = MutexGuard {
                        mutex: self,
                        inner: Some(g),
                    };
                    // Hold-visible point: without a scheduling point here,
                    // the token never leaves a lock holder inside its
                    // critical section, and `try_lock` contention (the
                    // trace ring's drop-on-contention path) would be
                    // unreachable in any explored schedule.
                    sched.yield_point(me);
                    return Ok(guard);
                }
                Err(TryLockError::WouldBlock) => {
                    sched.block_current(me, Waiting::Lock(self.id));
                }
                Err(TryLockError::Poisoned(p)) => {
                    return Err(PoisonError::new(MutexGuard {
                        mutex: self,
                        inner: Some(p.into_inner()),
                    }));
                }
            }
        }
    }

    pub fn try_lock(&self) -> TryLockResult<MutexGuard<'_, T>> {
        maybe_yield();
        match self.inner.try_lock() {
            Ok(g) => Ok(MutexGuard {
                mutex: self,
                inner: Some(g),
            }),
            Err(TryLockError::WouldBlock) => Err(TryLockError::WouldBlock),
            Err(TryLockError::Poisoned(p)) => {
                Err(TryLockError::Poisoned(PoisonError::new(MutexGuard {
                    mutex: self,
                    inner: Some(p.into_inner()),
                })))
            }
        }
    }

    pub fn into_inner(self) -> LockResult<T> {
        self.inner
            .into_inner()
            .map_err(|p| PoisonError::new(p.into_inner()))
    }

    pub fn get_mut(&mut self) -> LockResult<&mut T> {
        self.inner
            .get_mut()
            .map_err(|p| PoisonError::new(p.into_inner()))
    }
}

impl<T> std::fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mutex").field("id", &self.id).finish()
    }
}

fn wrap_lock_result<'a, T>(
    mutex: &'a Mutex<T>,
    r: LockResult<std::sync::MutexGuard<'a, T>>,
) -> LockResult<MutexGuard<'a, T>> {
    match r {
        Ok(g) => Ok(MutexGuard {
            mutex,
            inner: Some(g),
        }),
        Err(p) => Err(PoisonError::new(MutexGuard {
            mutex,
            inner: Some(p.into_inner()),
        })),
    }
}

/// Guard for [`Mutex`]; releasing it wakes scheduler-parked waiters.
pub struct MutexGuard<'a, T> {
    mutex: &'a Mutex<T>,
    inner: Option<std::sync::MutexGuard<'a, T>>,
}

impl<T> Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard holds the lock")
    }
}

impl<T> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard holds the lock")
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        if let Some(g) = self.inner.take() {
            drop(g); // release the real lock first
            if let Some((sched, _)) = ctx() {
                let id = self.mutex.id;
                sched.wake(|w| *w == Waiting::Lock(id), usize::MAX);
            }
        }
    }
}

impl<T> std::fmt::Debug for MutexGuard<'_, T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MutexGuard")
            .field("mutex", &self.mutex.id)
            .finish()
    }
}

/// Instrumented drop-in for [`std::sync::Condvar`]. `notify_one` wakes the
/// lowest-tid waiter (a documented reduction of the schedule space).
/// Outside an active [`check`] it forwards to a real `std` condvar; mixing
/// model-scheduled waiters with non-model notifiers is not supported.
pub struct Condvar {
    id: u64,
    inner: StdCondvar,
}

impl Condvar {
    pub fn new() -> Self {
        Self {
            id: fresh_id(),
            inner: StdCondvar::new(),
        }
    }

    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        let mutex = guard.mutex;
        let Some((sched, me)) = ctx() else {
            // Passthrough: wait on the real condvar with the real guard.
            // (We must skip the model guard's Drop, which would try to wake
            // scheduler waiters that do not exist here.)
            let mut guard = guard;
            let inner = guard.inner.take().expect("guard holds the lock");
            std::mem::forget(guard);
            return wrap_lock_result(mutex, self.inner.wait(inner));
        };
        let mutex_id = mutex.id;
        {
            let mut guard = guard;
            let inner = guard.inner.take().expect("guard holds the lock");
            std::mem::forget(guard);
            // Atomically (under the scheduler lock): park as a condvar
            // waiter, release the mutex, wake lock waiters, move the token.
            let mut st = sched.state.lock().unwrap_or_else(PoisonError::into_inner);
            st.threads[me] = Ts::Blocked(Waiting::Cond(self.id));
            drop(inner);
            for t in st.threads.iter_mut() {
                if matches!(t, Ts::Blocked(Waiting::Lock(l)) if *l == mutex_id) {
                    *t = Ts::Runnable;
                }
            }
            sched.pick_next(&mut st);
        }
        sched.wait_turn(me);
        mutex.lock()
    }

    pub fn notify_one(&self) {
        if let Some((sched, me)) = ctx() {
            sched.yield_point(me);
            let id = self.id;
            sched.wake(|w| *w == Waiting::Cond(id), 1);
        } else {
            self.inner.notify_one();
        }
    }

    pub fn notify_all(&self) {
        if let Some((sched, me)) = ctx() {
            sched.yield_point(me);
            let id = self.id;
            sched.wake(|w| *w == Waiting::Cond(id), usize::MAX);
        } else {
            self.inner.notify_all();
        }
    }
}

impl Default for Condvar {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for Condvar {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Condvar").field("id", &self.id).finish()
    }
}

// ---------------------------------------------------------------------------
// Channels
// ---------------------------------------------------------------------------

pub mod mpsc {
    use super::*;

    /// Instrumented unbounded channel: `std::sync::mpsc` underneath, with
    /// `recv` turned into a schedulable try/park loop and sender drops
    /// ordered so disconnection is visible *before* waiters wake.
    pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
        let (tx, rx) = std_mpsc::channel();
        let id = fresh_id();
        (
            Sender {
                inner: Some(tx),
                id,
            },
            Receiver { inner: rx, id },
        )
    }

    pub struct Sender<T> {
        // `Option` so Drop can release the std sender *before* waking
        // parked receivers — otherwise a woken receiver try-recvs Empty,
        // parks again, and the disconnect event is lost (missed wakeup).
        inner: Option<std_mpsc::Sender<T>>,
        id: u64,
    }

    impl<T> Sender<T> {
        pub fn send(&self, t: T) -> Result<(), std_mpsc::SendError<T>> {
            maybe_yield();
            let r = self.inner.as_ref().expect("sender is live").send(t);
            if r.is_ok() {
                if let Some((sched, _)) = ctx() {
                    let id = self.id;
                    sched.wake(|w| *w == Waiting::Chan(id), usize::MAX);
                }
            }
            r
        }
    }

    impl<T> Clone for Sender<T> {
        fn clone(&self) -> Self {
            Self {
                inner: self.inner.clone(),
                id: self.id,
            }
        }
    }

    impl<T> Drop for Sender<T> {
        fn drop(&mut self) {
            drop(self.inner.take());
            if let Some((sched, _)) = ctx() {
                let id = self.id;
                sched.wake(|w| *w == Waiting::Chan(id), usize::MAX);
            }
        }
    }

    impl<T> std::fmt::Debug for Sender<T> {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("Sender").field("id", &self.id).finish()
        }
    }

    pub struct Receiver<T> {
        inner: std_mpsc::Receiver<T>,
        id: u64,
    }

    impl<T> Receiver<T> {
        pub fn recv(&self) -> Result<T, std_mpsc::RecvError> {
            let Some((sched, me)) = ctx() else {
                return self.inner.recv();
            };
            loop {
                sched.yield_point(me);
                match self.inner.try_recv() {
                    Ok(v) => return Ok(v),
                    Err(std_mpsc::TryRecvError::Empty) => {
                        sched.block_current(me, Waiting::Chan(self.id));
                    }
                    Err(std_mpsc::TryRecvError::Disconnected) => {
                        return Err(std_mpsc::RecvError)
                    }
                }
            }
        }

        pub fn try_recv(&self) -> Result<T, std_mpsc::TryRecvError> {
            maybe_yield();
            self.inner.try_recv()
        }
    }

    impl<T> std::fmt::Debug for Receiver<T> {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("Receiver").field("id", &self.id).finish()
        }
    }
}

// ---------------------------------------------------------------------------
// Threads
// ---------------------------------------------------------------------------

pub mod thread {
    use super::*;

    /// Instrumented spawn: inside an active [`check`], the child registers
    /// with the scheduler and takes its first step only when handed the
    /// token; outside, this is exactly `std::thread::spawn`.
    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        let Some((sched, _me)) = ctx() else {
            return JoinHandle {
                inner: std::thread::spawn(f),
                sched: None,
                tid: 0,
            };
        };
        let tid = sched.register_thread();
        let sched_child = Arc::clone(&sched);
        let sched_exit = Arc::clone(&sched);
        let inner = std::thread::spawn(move || {
            CTX.with(|c| *c.borrow_mut() = Some((Arc::clone(&sched_child), tid)));
            let result = catch_unwind(AssertUnwindSafe(move || {
                sched_child.wait_turn(tid);
                f()
            }));
            sched_exit.thread_finished(tid);
            CTX.with(|c| *c.borrow_mut() = None);
            match result {
                Ok(v) => v,
                Err(p) => resume_unwind(p),
            }
        });
        JoinHandle {
            inner,
            sched: Some(sched),
            tid,
        }
    }

    pub struct JoinHandle<T> {
        inner: std::thread::JoinHandle<T>,
        sched: Option<Arc<Sched>>,
        tid: usize,
    }

    impl<T> JoinHandle<T> {
        pub fn join(self) -> std::thread::Result<T> {
            if let (Some(sched), Some((_, me))) = (&self.sched, ctx()) {
                sched.yield_point(me);
                if !sched.is_thread_finished(self.tid) {
                    sched.block_current(me, Waiting::Join(self.tid));
                }
                // Logically finished; the real join below returns promptly.
            }
            self.inner.join()
        }

        pub fn is_finished(&self) -> bool {
            match &self.sched {
                Some(sched) if ctx().is_some() => sched.is_thread_finished(self.tid),
                _ => self.inner.is_finished(),
            }
        }
    }

    impl<T> std::fmt::Debug for JoinHandle<T> {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("JoinHandle").field("tid", &self.tid).finish()
        }
    }
}

// ---------------------------------------------------------------------------
// Self-tests for the checker itself
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Two threads contending for one mutex: the checker must find both
    /// acquisition orders and terminate with a complete tree.
    #[test]
    fn explores_both_lock_orders_exhaustively() {
        static FIRST_WAS_CHILD: AtomicUsize = AtomicUsize::new(0);
        static FIRST_WAS_MAIN: AtomicUsize = AtomicUsize::new(0);
        let report = check(|| {
            let m = Arc::new(Mutex::new(Vec::<u8>::new()));
            let m2 = Arc::clone(&m);
            let h = thread::spawn(move || {
                m2.lock().expect("model mutex").push(b'c');
            });
            m.lock().expect("model mutex").push(b'm');
            h.join().expect("child clean");
            let order = m.lock().expect("model mutex").clone();
            match order.as_slice() {
                [b'c', b'm'] => FIRST_WAS_CHILD.fetch_add(1, Ordering::Relaxed),
                [b'm', b'c'] => FIRST_WAS_MAIN.fetch_add(1, Ordering::Relaxed),
                other => panic!("impossible order {other:?}"),
            };
        });
        assert!(report.complete, "tree not exhausted: {report:?}");
        assert!(report.iterations >= 2, "{report:?}");
        assert!(FIRST_WAS_CHILD.load(Ordering::Relaxed) > 0);
        assert!(FIRST_WAS_MAIN.load(Ordering::Relaxed) > 0);
    }

    /// A channel round trip with the sender dropped first: disconnection
    /// must surface as `Err`, never as a lost wakeup.
    #[test]
    fn channel_disconnect_is_never_a_missed_wakeup() {
        let report = check(|| {
            let (tx, rx) = mpsc::channel::<u32>();
            let h = thread::spawn(move || {
                tx.send(7).expect("receiver alive");
                // tx drops here
            });
            assert_eq!(rx.recv(), Ok(7));
            assert!(rx.recv().is_err(), "disconnect must be observed");
            h.join().expect("sender clean");
        });
        assert!(report.complete, "{report:?}");
        assert!(report.iterations >= 2, "{report:?}");
    }

    /// A genuine deadlock (AB-BA lock order) must be detected, not hung on.
    #[test]
    fn detects_ab_ba_deadlock() {
        let caught = catch_unwind(AssertUnwindSafe(|| {
            check(|| {
                let a = Arc::new(Mutex::new(()));
                let b = Arc::new(Mutex::new(()));
                let (a2, b2) = (Arc::clone(&a), Arc::clone(&b));
                let h = thread::spawn(move || {
                    let _ga = a2.lock().expect("model mutex");
                    let _gb = b2.lock().expect("model mutex");
                });
                let _gb = b.lock().expect("model mutex");
                let _ga = a.lock().expect("model mutex");
                drop(_ga);
                drop(_gb);
                h.join().expect("child clean");
            });
        }));
        let payload = caught.expect_err("some interleaving must deadlock");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("deadlock"), "unexpected panic: {msg}");
    }
}
