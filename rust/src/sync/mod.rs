//! Synchronization shim: the one import point for every lock, channel, and
//! thread handle on the concurrency-bearing paths (`sparsify::pool`,
//! `trace`, the transport [`Mux`](crate::transport::Mux), and the SSP
//! clock pair in `coordinator::param_server`).
//!
//! * Default build: thin re-exports of `std::sync` / `std::thread` /
//!   `std::sync::mpsc` — zero cost, identical semantics.
//! * `--features model`: the same names resolve to the instrumented
//!   primitives in [`model`], a vendored mini exhaustive-interleaving
//!   checker (loom-style, no external deps — the offline-image rule) that
//!   serializes threads onto a token-passing scheduler and DFS-explores
//!   every scheduling decision. `rust/tests/model.rs` uses it to
//!   model-check the `ShardPool` dispatch/drop/panic protocol and the
//!   trace-ring owner-only `try_lock` claim.
//!
//! Atomics and `Arc` stay `std` in both builds: the checker serializes
//! execution, so every atomic access is already sequentially consistent
//! under it, and the repo's atomics are relaxed counters whose values never
//! drive control flow across threads.

#[cfg(feature = "model")]
pub mod model;

#[cfg(feature = "model")]
pub use model::{Condvar, Mutex, MutexGuard};

#[cfg(feature = "model")]
pub mod mpsc {
    pub use super::model::mpsc::{channel, Receiver, Sender};
    pub use std::sync::mpsc::{RecvError, SendError, TryRecvError};
}

#[cfg(feature = "model")]
pub mod thread {
    pub use super::model::thread::{spawn, JoinHandle};
    pub use std::thread::Result;
}

#[cfg(not(feature = "model"))]
pub use std::sync::{Condvar, Mutex, MutexGuard};

#[cfg(not(feature = "model"))]
pub mod mpsc {
    pub use std::sync::mpsc::*;
}

#[cfg(not(feature = "model"))]
pub mod thread {
    pub use std::thread::*;
}

pub use std::sync::{Arc, LockResult, PoisonError, TryLockError, TryLockResult};

pub mod atomic {
    pub use std::sync::atomic::*;
}
