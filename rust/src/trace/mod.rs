//! `gsparse::trace` — low-overhead, allocation-free-in-steady-state
//! instrumentation for the whole runtime.
//!
//! The paper's argument is a *time*/accuracy trade, but until this module
//! the runtime could only report bytes ([`crate::metrics::CommLedger`]):
//! where a round's wall-clock goes — solve vs. sample vs. encode vs. send
//! vs. decode vs. apply — was invisible, and the PR-6 pipeline overlap was
//! only observable through a bench-side ratio. This module makes it
//! directly measurable:
//!
//! * a [`Recorder`] collects fixed-size [`Event`] records into **per-thread
//!   ring buffers** (one `Mutex<Ring>` per registered thread, only ever
//!   locked by its owner in steady state and by the exporter at the end, so
//!   recording never blocks the hot loop — a contended `try_lock` drops the
//!   event instead of waiting);
//! * [`span`] / [`counter`] are the universal instrumentation points: when
//!   no recorder is installed on the calling thread they cost one relaxed
//!   atomic load ([`TraceConfig::Off`] compiles to near-no-ops — pinned by
//!   `tests/trace.rs` and the `trace_micro` bench);
//! * exporters turn a drained event list into Chrome `trace_event` JSON
//!   (load in `chrome://tracing` / Perfetto) or JSONL span dumps, plus a
//!   [`MetricsSnapshot`] of counters/gauges/log₂-bucketed histograms that
//!   the reports embed and the benches write into `BENCH_trace.json`.
//!
//! ## Event record layout
//!
//! One event is a fixed 48-byte record (logical layout; `repr(Rust)` may
//! reorder fields in memory, the exporters use the field names):
//!
//! ```text
//! byte   0        8        16       24       32      36      40     41    42
//!        ├────────┼────────┼────────┼────────┼───────┼───────┼──────┼─────┼─────┤
//!        │t_start │ t_end  │ bytes  │  flow  │ round │ layer │stage │ wrk │ tid │
//!        │ ns u64 │ ns u64 │  u64   │  u64   │  u32  │  u32  │  u8  │ u16 │ u16 │
//!        └────────┴────────┴────────┴────────┴───────┴───────┴──────┴─────┴─────┘
//! ```
//!
//! * `t_start`/`t_end` — nanoseconds on the recorder's monotonic clock
//!   (every timestamp in one recorder shares the same `Instant` origin, so
//!   spans from different threads of one process align exactly);
//! * `bytes` — stage-dependent payload size (frame bytes for
//!   `FrameTx`/`FrameRx`, wire bytes for `Encode`, chunk count for
//!   `ShardDispatch`, zero where meaningless);
//! * `flow` — the causal flow id of a v4 trace-context-stamped frame
//!   (`sender_rank << 32 | seq`, zero = no flow): the `frame_tx` event on
//!   the sending process and the `frame_rx` event on the receiving one
//!   carry the same id, which is what lets the cross-process merger
//!   ([`crate::telemetry::merge`]) connect them with Chrome flow arrows;
//! * `round`/`layer` — ambient context set by the coordinators via
//!   [`set_round`] and per-span via [`Span::layer`] (a stamped frame's
//!   `frame_rx` uses the *sender's* round from the trace context, so both
//!   halves of a flow agree even when the receiver's ambient round lags);
//! * `stage` — the [`Stage`] id; `wrk`/`tid` — the worker id the thread
//!   was installed with and the recorder-local thread index (these become
//!   `pid`/`tid` lanes in the Chrome export, which is what makes traces
//!   from separate worker processes mergeable by concatenation).
//!
//! ## Determinism
//!
//! Recording only ever *reads* the data path (lengths, counts) and writes
//! into trace-private buffers; it never consumes RNG draws, reorders float
//! accumulation, or adds wire frames. Tracing on vs. off is therefore
//! bitwise-identical on every coordinator path — pinned by
//! `tests/trace.rs` across all four coordinators.
//!
//! ## Turning it on
//!
//! Programmatic: `Session::builder().trace(TraceConfig::on())`, then read
//! back events from the session's recorder. Environment (the CI hook):
//! `GSPARSE_TRACE=json|jsonl` enables recording in every session built
//! without an explicit config; setting `GSPARSE_TRACE_OUT=<stem>`
//! *additionally* makes every coordinator dump its trace at run end
//! (recording and dumping are separate switches so a whole test suite can
//! run traced without processes racing on dump files). The `gsparse`
//! binary's `--trace-out STEM` flag sets both. The distributed runtime
//! ships the config to worker processes in the CONFIG frame, so a
//! multi-process run produces one trace file per role keyed by worker id —
//! mergeable by concatenating their `traceEvents` arrays, or (better) by
//! the clock-aligning `gsparse trace-merge` subcommand.
//!
//! ## Dump file naming
//!
//! Run-end dumps are written to
//! `<stem>.<run-tag>.<role>.trace.json[l]`, where `<stem>` is
//! `GSPARSE_TRACE_OUT`, `<run-tag>` is `r<rounds>.<topology>` (built by
//! [`run_tag`] — e.g. `r40.star`, `r40.ring`; coordinators without a wire
//! topology use their schedule name, e.g. `r30.sim` for the synchronous
//! simulator), and `<role>` is `server`, `worker<N>`, `cluster`, `ps`,
//! `sync`, or `async`. Two runs with different shapes in one directory
//! therefore never silently overwrite each other's dumps; re-running the
//! *same* shape intentionally replaces them. The server of a dist run
//! additionally writes `<stem>.<run-tag>.clock.json` (per-worker clock
//! offsets, consumed by `trace-merge`).

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Instant;

use crate::sync::{Arc, Mutex};

use crate::transport::LinkCounters;

/// Default ring capacity per registered thread (events, not bytes).
pub const DEFAULT_CAPACITY: usize = 1 << 16;

/// Number of log₂ duration buckets a [`Histogram`] carries. Bucket `i`
/// counts spans with `duration_ns in [2^i, 2^(i+1))` (bucket 0 also takes
/// zero-length counter events); 40 buckets cover up to ~18 minutes.
pub const HISTOGRAM_BUCKETS: usize = 40;

/// Export format of the run-end trace dump.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceFormat {
    /// Chrome `trace_event` JSON (open in `chrome://tracing` / Perfetto).
    Chrome,
    /// One JSON object per span, one per line.
    Jsonl,
}

/// Whether (and how) a session records trace events.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TraceConfig {
    /// No recorder is created; every instrumentation point reduces to one
    /// relaxed atomic load (and not even that when no recorder exists
    /// process-wide).
    #[default]
    Off,
    /// Record into per-thread rings of `capacity` events; run-end dumps
    /// (when requested via the environment / CLI) use `format`.
    On {
        /// Ring capacity per registered thread; the oldest events are
        /// overwritten (and counted as dropped) once a ring is full.
        capacity: usize,
        /// Export format for run-end dumps.
        format: TraceFormat,
    },
}

impl TraceConfig {
    /// Tracing on, with the default capacity and Chrome-JSON dumps.
    pub fn on() -> Self {
        TraceConfig::On {
            capacity: DEFAULT_CAPACITY,
            format: TraceFormat::Chrome,
        }
    }

    pub fn enabled(&self) -> bool {
        matches!(self, TraceConfig::On { .. })
    }

    /// Read the trace switch from `GSPARSE_TRACE` — the hook the CI matrix
    /// uses. Unset or empty (or `off`/`0`) means [`TraceConfig::Off`];
    /// `json`/`chrome` and `jsonl` enable the matching dump format;
    /// anything else panics so a typo'd CI matrix cannot silently run the
    /// wrong configuration (the same contract as
    /// [`crate::api::pipeline_from_env`]).
    pub fn from_env() -> Self {
        match std::env::var("GSPARSE_TRACE") {
            Err(_) => TraceConfig::Off,
            Ok(v) => match v.as_str() {
                "" | "off" | "0" => TraceConfig::Off,
                "json" | "chrome" | "1" | "on" => TraceConfig::on(),
                "jsonl" => TraceConfig::On {
                    capacity: DEFAULT_CAPACITY,
                    format: TraceFormat::Jsonl,
                },
                _ => panic!("GSPARSE_TRACE must be json|jsonl|off, got {v:?}"),
            },
        }
    }

    /// Whether run-end dumps were requested: `GSPARSE_TRACE_OUT` is set
    /// and non-empty. Recording (`GSPARSE_TRACE`) and dumping are separate
    /// opt-ins — the CI matrix traces every test without any of them
    /// writing files; only dedicated runs (the `--trace-out` CLI flag sets
    /// both variables) dump.
    pub fn dump_requested() -> bool {
        matches!(std::env::var("GSPARSE_TRACE_OUT"), Ok(v) if !v.is_empty())
    }

    /// The CONFIG-frame encoding: mode byte + u32 ring capacity.
    pub(crate) fn wire_bytes(&self) -> [u8; 5] {
        let (mode, cap) = match *self {
            TraceConfig::Off => (0u8, 0u32),
            TraceConfig::On {
                capacity,
                format: TraceFormat::Chrome,
            } => (1, capacity as u32),
            TraceConfig::On {
                capacity,
                format: TraceFormat::Jsonl,
            } => (2, capacity as u32),
        };
        let mut out = [0u8; 5];
        out[0] = mode;
        out[1..5].copy_from_slice(&cap.to_le_bytes());
        out
    }

    /// Decode the CONFIG-frame bytes; `None` on an unknown mode byte.
    pub(crate) fn from_wire(mode: u8, capacity: u32) -> Option<Self> {
        match mode {
            0 => Some(TraceConfig::Off),
            1 => Some(TraceConfig::On {
                capacity: (capacity as usize).max(1),
                format: TraceFormat::Chrome,
            }),
            2 => Some(TraceConfig::On {
                capacity: (capacity as usize).max(1),
                format: TraceFormat::Jsonl,
            }),
            _ => None,
        }
    }

    /// The dump format, defaulting to Chrome when off.
    pub fn format(&self) -> TraceFormat {
        match *self {
            TraceConfig::On { format, .. } => format,
            TraceConfig::Off => TraceFormat::Chrome,
        }
    }
}

/// Stage id of an event — the vocabulary shared by every layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Stage {
    /// One coordinator synchronization round (block), end to end.
    Round = 0,
    /// Probability solve (Algorithm 2/3) inside the compress engines.
    Solve = 1,
    /// Bernoulli sampling sweep (including the fused solve+sample path).
    Sample = 2,
    /// Wire encoding (codec or `WireBatch` sub-message).
    Encode = 3,
    /// Wire decoding on the receiving side.
    Decode = 4,
    /// Applying a received update to the weights.
    Apply = 5,
    /// One local gradient step (no wire traffic).
    LocalStep = 6,
    /// Weight pull: request + waiting for + decoding fresh weights.
    Pull = 7,
    /// Gradient push: framing + handing the payload to the connection.
    Push = 8,
    /// Leader/server time spent waiting on stragglers (recv order).
    BarrierWait = 9,
    /// A `ShardPool` dispatch: jobs handed out → all chunk tails joined
    /// (`bytes` carries the chunk count).
    ShardDispatch = 10,
    /// Transport handshake (hello exchange + validation).
    Handshake = 11,
    /// One framed transport send (`bytes` = payload + prefix). Counter.
    FrameTx = 12,
    /// One framed transport receive. Counter.
    FrameRx = 13,
    /// A vectored (scatter/gather, copy-skipping) frame send. Counter.
    VectoredTx = 14,
    /// One ring-collective hop: send own chunk + receive + merge the
    /// neighbour's (`bytes` = received hop payload).
    Hop = 15,
    /// Aligned-sparsity sketch work: local sketch build, ring exchange, and
    /// the shared top-k index agreement.
    Sketch = 16,
}

/// Every stage, in id order (export tables iterate this).
pub const STAGES: [Stage; 17] = [
    Stage::Round,
    Stage::Solve,
    Stage::Sample,
    Stage::Encode,
    Stage::Decode,
    Stage::Apply,
    Stage::LocalStep,
    Stage::Pull,
    Stage::Push,
    Stage::BarrierWait,
    Stage::ShardDispatch,
    Stage::Handshake,
    Stage::FrameTx,
    Stage::FrameRx,
    Stage::VectoredTx,
    Stage::Hop,
    Stage::Sketch,
];

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::Round => "round",
            Stage::Solve => "solve",
            Stage::Sample => "sample",
            Stage::Encode => "encode",
            Stage::Decode => "decode",
            Stage::Apply => "apply",
            Stage::LocalStep => "local_step",
            Stage::Pull => "pull",
            Stage::Push => "push",
            Stage::BarrierWait => "barrier_wait",
            Stage::ShardDispatch => "shard_dispatch",
            Stage::Handshake => "handshake",
            Stage::FrameTx => "frame_tx",
            Stage::FrameRx => "frame_rx",
            Stage::VectoredTx => "vectored_tx",
            Stage::Hop => "hop",
            Stage::Sketch => "sketch",
        }
    }
}

/// One fixed-size trace record. See the module docs for the layout.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    pub t_start_ns: u64,
    pub t_end_ns: u64,
    pub bytes: u64,
    /// Causal flow id (`sender << 32 | seq`) of a trace-context-stamped
    /// frame; zero = not part of a cross-process flow.
    pub flow: u64,
    pub round: u32,
    pub layer: u32,
    pub stage: Stage,
    pub worker: u16,
    pub tid: u16,
}

impl Event {
    pub fn duration_ns(&self) -> u64 {
        self.t_end_ns.saturating_sub(self.t_start_ns)
    }
}

/// Worker id the coordinators install leader/server threads under (worker
/// threads use their real id).
pub const SERVER_WORKER: u16 = u16::MAX;

// ---------------------------------------------------------------------------
// Recorder internals
// ---------------------------------------------------------------------------

/// Count of live recorders process-wide: the global fast-path gate. When
/// zero, [`span`]/[`counter`] return after a single relaxed load.
static ACTIVE_RECORDERS: AtomicUsize = AtomicUsize::new(0);

#[inline(always)]
fn tracing_possible() -> bool {
    ACTIVE_RECORDERS.load(Ordering::Relaxed) != 0
}

/// Fixed-capacity overwrite-oldest ring of events.
#[derive(Debug)]
struct Ring {
    buf: Vec<Event>,
    /// Next write slot.
    next: usize,
    /// Live events (≤ capacity).
    len: usize,
    /// Events overwritten because the ring was full.
    dropped: u64,
}

impl Ring {
    fn with_capacity(capacity: usize) -> Self {
        Self {
            buf: Vec::with_capacity(capacity),
            next: 0,
            len: 0,
            dropped: 0,
        }
    }

    // verifier: hot-path — overwrite-oldest into preallocated storage only.
    #[inline]
    fn push(&mut self, ev: Event) {
        let cap = self.buf.capacity();
        if self.buf.len() < cap {
            self.buf.push(ev);
            self.len += 1;
        } else {
            self.buf[self.next] = ev;
            self.dropped += 1;
        }
        self.next = (self.next + 1) % cap.max(1);
    }

    /// Events in record order (oldest first).
    fn drain_ordered(&mut self) -> Vec<Event> {
        let mut out = Vec::with_capacity(self.len);
        if self.buf.len() < self.buf.capacity() {
            out.extend_from_slice(&self.buf);
        } else {
            out.extend_from_slice(&self.buf[self.next..]);
            out.extend_from_slice(&self.buf[..self.next]);
        }
        self.buf.clear();
        self.next = 0;
        self.len = 0;
        out
    }
}

#[derive(Debug)]
struct ThreadBuf {
    worker: u16,
    tid: u16,
    ring: Mutex<Ring>,
}

#[derive(Debug)]
struct Shared {
    capacity: usize,
    origin: Instant,
    threads: Mutex<Vec<Arc<ThreadBuf>>>,
    next_tid: AtomicU64,
}

impl Drop for Shared {
    fn drop(&mut self) {
        ACTIVE_RECORDERS.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Owns the per-thread rings of one traced run. Cloning yields another
/// handle to the same buffers (it is an `Arc` inside), which is how one
/// recorder serves every thread of a coordinator.
#[derive(Clone, Debug)]
pub struct Recorder {
    shared: Arc<Shared>,
}

impl Recorder {
    /// Create a recorder for `cfg`; `None` when tracing is off (the
    /// coordinators thread that `Option` through untouched).
    pub fn new(cfg: &TraceConfig) -> Option<Self> {
        match *cfg {
            TraceConfig::Off => None,
            TraceConfig::On { capacity, .. } => {
                ACTIVE_RECORDERS.fetch_add(1, Ordering::Relaxed);
                Some(Self {
                    shared: Arc::new(Shared {
                        capacity: capacity.max(1),
                        origin: Instant::now(),
                        threads: Mutex::new(Vec::new()),
                        next_tid: AtomicU64::new(0),
                    }),
                })
            }
        }
    }

    /// Drain every thread's ring into one list sorted by start time.
    /// Threads may keep recording afterwards (their rings restart empty).
    pub fn drain(&self) -> Vec<Event> {
        let threads = self.shared.threads.lock().expect("trace thread registry");
        let mut out = Vec::new();
        for t in threads.iter() {
            if let Ok(mut ring) = t.ring.lock() {
                out.extend(ring.drain_ordered());
            }
        }
        out.sort_by_key(|e| (e.t_start_ns, e.tid));
        out
    }

    /// Allocate a reusable per-thread registration under `worker`.
    ///
    /// Coordinators that spawn fresh OS threads every round (the cluster's
    /// scoped comm threads) create one handle per logical worker up front
    /// and re-install it on whichever thread runs that worker each round —
    /// the ring is allocated once per worker, not once per round, keeping
    /// the steady state allocation-free. A handle must not be installed on
    /// two threads at once (events would contend on the ring's `try_lock`
    /// and be dropped, never corrupted).
    pub fn thread_handle(&self, worker: u16) -> ThreadHandle {
        let tid = self.shared.next_tid.fetch_add(1, Ordering::Relaxed) as u16;
        let buf = Arc::new(ThreadBuf {
            worker,
            tid,
            ring: Mutex::new(Ring::with_capacity(self.shared.capacity)),
        });
        self.shared
            .threads
            .lock()
            .expect("trace thread registry")
            .push(Arc::clone(&buf));
        ThreadHandle {
            buf,
            origin: self.shared.origin,
        }
    }

    /// Total events overwritten across all rings (ring too small).
    pub fn dropped(&self) -> u64 {
        let threads = self.shared.threads.lock().expect("trace thread registry");
        threads
            .iter()
            .map(|t| t.ring.lock().map(|r| r.dropped).unwrap_or(0))
            .sum()
    }
}

// ---------------------------------------------------------------------------
// Thread-local installation + recording
// ---------------------------------------------------------------------------

struct ThreadCtx {
    buf: Arc<ThreadBuf>,
    origin: Instant,
    round: u32,
}

thread_local! {
    static CURRENT: RefCell<Option<ThreadCtx>> = const { RefCell::new(None) };
}

/// Uninstalls the thread's recorder context on drop (scoped-thread safe).
#[must_use = "dropping the guard uninstalls the recorder from this thread"]
pub struct InstallGuard {
    installed: bool,
}

impl Drop for InstallGuard {
    fn drop(&mut self) {
        if self.installed {
            CURRENT.with(|c| *c.borrow_mut() = None);
        }
    }
}

/// A reusable per-thread registration (see [`Recorder::thread_handle`]).
/// Cloning shares the same ring.
#[derive(Clone, Debug)]
pub struct ThreadHandle {
    buf: Arc<ThreadBuf>,
    origin: Instant,
}

/// Register the calling thread with `recorder` under `worker`: allocates
/// this thread's ring (the one non-steady-state allocation) and makes
/// [`span`]/[`counter`] record into it until the guard drops.
pub fn install(recorder: &Recorder, worker: u16) -> InstallGuard {
    install_handle(&recorder.thread_handle(worker))
}

/// Install a pre-allocated [`ThreadHandle`] on the calling thread — no
/// allocation, so round-scoped threads can re-register for free.
pub fn install_handle(handle: &ThreadHandle) -> InstallGuard {
    CURRENT.with(|c| {
        *c.borrow_mut() = Some(ThreadCtx {
            buf: Arc::clone(&handle.buf),
            origin: handle.origin,
            round: 0,
        })
    });
    InstallGuard { installed: true }
}

/// [`install_handle`] through an `Option` (mirrors [`install_opt`]).
pub fn install_handle_opt(handle: Option<&ThreadHandle>) -> InstallGuard {
    match handle {
        Some(h) => install_handle(h),
        None => InstallGuard { installed: false },
    }
}

/// [`install`] through an `Option` — the no-recorder case returns an inert
/// guard, which is what lets coordinators write one unconditional line.
pub fn install_opt(recorder: Option<&Recorder>, worker: u16) -> InstallGuard {
    match recorder {
        Some(r) => install(r, worker),
        None => InstallGuard { installed: false },
    }
}

/// Set the ambient round index recorded into subsequent events from this
/// thread. No-op when no recorder is installed.
pub fn set_round(round: u32) {
    if !tracing_possible() {
        return;
    }
    CURRENT.with(|c| {
        if let Some(ctx) = c.borrow_mut().as_mut() {
            ctx.round = round;
        }
    });
}

// verifier: hot-path — allocation-free, clock-free, try_lock only.
#[inline]
fn record(stage: Stage, t0: Instant, t1: Option<Instant>, bytes: u64, layer: u32) {
    record_flow(stage, t0, t1, bytes, layer, 0, None);
}

/// [`record`] with an explicit flow id and (for stamped `frame_rx`) the
/// sender's round overriding the receiver's ambient one.
// verifier: hot-path — allocation-free, clock-free, try_lock only.
#[inline]
fn record_flow(
    stage: Stage,
    t0: Instant,
    t1: Option<Instant>,
    bytes: u64,
    layer: u32,
    flow: u64,
    round: Option<u32>,
) {
    CURRENT.with(|c| {
        let borrow = c.borrow();
        let Some(ctx) = borrow.as_ref() else { return };
        let start = t0.duration_since(ctx.origin).as_nanos() as u64;
        let end = t1
            .map(|t| t.duration_since(ctx.origin).as_nanos() as u64)
            .unwrap_or(start);
        let ev = Event {
            t_start_ns: start,
            t_end_ns: end,
            bytes,
            flow,
            round: round.unwrap_or(ctx.round),
            layer,
            stage,
            worker: ctx.buf.worker,
            tid: ctx.buf.tid,
        };
        // Only the owning thread and the run-end exporter ever take this
        // lock, so steady state is uncontended; under contention the event
        // is dropped rather than ever blocking the hot loop.
        if let Ok(mut ring) = ctx.buf.ring.try_lock() {
            ring.push(ev);
        }
    });
}

/// An in-flight span; records on drop. Inert (one branch on drop) when the
/// thread has no installed recorder.
pub struct Span {
    t0: Option<Instant>,
    stage: Stage,
    bytes: u64,
    layer: u32,
}

impl Span {
    /// Attach a byte count (meaning is stage-specific; see [`Event`]).
    #[inline]
    pub fn bytes(&mut self, bytes: u64) {
        self.bytes = bytes;
    }

    /// Attach a layer index (multi-layer coordinators).
    #[inline]
    pub fn layer(&mut self, layer: u32) {
        self.layer = layer;
    }
}

impl Drop for Span {
    #[inline]
    fn drop(&mut self) {
        if let Some(t0) = self.t0 {
            record(self.stage, t0, Some(Instant::now()), self.bytes, self.layer);
        }
    }
}

/// Open a span for `stage`. When tracing is off this is one relaxed atomic
/// load plus an inert guard; when on, the clock is read at open and close.
// verifier: hot-path (clock-ok) — reads the clock, allocates nothing.
#[inline]
pub fn span(stage: Stage) -> Span {
    let t0 = if tracing_possible() && CURRENT.with(|c| c.borrow().is_some()) {
        Some(Instant::now())
    } else {
        None
    };
    Span {
        t0,
        stage,
        bytes: 0,
        layer: 0,
    }
}

/// Record a zero-duration counter event (e.g. one transport frame).
// verifier: hot-path (clock-ok) — reads the clock, allocates nothing.
#[inline]
pub fn counter(stage: Stage, bytes: u64) {
    if !tracing_possible() {
        return;
    }
    let now = Instant::now();
    record(stage, now, None, bytes, 0);
}

/// Record a zero-duration counter event that belongs to a cross-process
/// flow (a trace-context-stamped frame): `flow` is the
/// [`TraceCtx::flow_id`](crate::transport::TraceCtx::flow_id), `round` the
/// sender's round carried in the context (which overrides the receiving
/// thread's ambient round, keeping both halves of the flow on one round).
// verifier: hot-path (clock-ok) — reads the clock, allocates nothing.
#[inline]
pub fn counter_flow(stage: Stage, bytes: u64, flow: u64, round: u32) {
    if !tracing_possible() {
        return;
    }
    let now = Instant::now();
    record_flow(stage, now, None, bytes, 0, flow, Some(round));
}

/// The ambient round of the calling thread's installed recorder context
/// (zero when none is installed) — what frame senders stamp into a
/// [`TraceCtx`](crate::transport::TraceCtx).
pub fn current_round() -> u32 {
    if !tracing_possible() {
        return 0;
    }
    CURRENT.with(|c| c.borrow().as_ref().map_or(0, |ctx| ctx.round))
}

/// Next flow sequence number for this process's stamped frames. One
/// process-wide counter (not per-link) so a flow id `sender << 32 | seq`
/// is unique no matter how many links or topologies a process drives at
/// once — the merger matches ids globally.
// verifier: hot-path — one relaxed RMW, nothing else.
#[inline]
pub fn next_flow_seq() -> u32 {
    static SEQ: AtomicU64 = AtomicU64::new(1);
    SEQ.fetch_add(1, Ordering::Relaxed) as u32
}

/// Nanoseconds now on this process's trace clock: the installed recorder's
/// origin when one is active on the calling thread, else a process-global
/// epoch fixed at first use. Clock-probe timestamps
/// ([`crate::telemetry::clock`]) use this so the offsets they estimate
/// apply directly to this process's trace event timestamps.
pub fn now_ns() -> u64 {
    let from_recorder = CURRENT.with(|c| {
        c.borrow()
            .as_ref()
            .map(|ctx| ctx.origin.elapsed().as_nanos() as u64)
    });
    from_recorder.unwrap_or_else(|| {
        static EPOCH: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();
        EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
    })
}

// ---------------------------------------------------------------------------
// Exporters
// ---------------------------------------------------------------------------

fn json_escape(s: &str, out: &mut String) {
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
}

/// Render events as Chrome `trace_event` JSON ("X" complete events;
/// `ts`/`dur` in microseconds). `pid` is the worker id and `tid` the
/// recorder-local thread index, so per-worker traces from separate
/// processes merge by concatenating their `traceEvents` arrays.
pub fn chrome_trace_json(events: &[Event]) -> String {
    use std::fmt::Write as _;
    let mut out = String::with_capacity(events.len() * 96 + 64);
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    for (i, e) in events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "{{\"name\":\"{}\",\"cat\":\"gsparse\",\"ph\":\"X\",\"ts\":{:.3},\"dur\":{:.3},\
             \"pid\":{},\"tid\":{},\"args\":{{\"round\":{},\"layer\":{},\"bytes\":{}",
            e.stage.name(),
            e.t_start_ns as f64 / 1e3,
            e.duration_ns() as f64 / 1e3,
            e.worker,
            e.tid,
            e.round,
            e.layer,
            e.bytes
        );
        if e.flow != 0 {
            let _ = write!(out, ",\"flow\":{}", e.flow);
        }
        out.push_str("}}");
    }
    out.push_str("]}");
    out
}

/// Render events as JSONL: one span object per line.
pub fn jsonl(events: &[Event]) -> String {
    use std::fmt::Write as _;
    let mut out = String::with_capacity(events.len() * 128);
    for e in events {
        let _ = writeln!(
            out,
            "{{\"stage\":\"{}\",\"worker\":{},\"tid\":{},\"round\":{},\"layer\":{},\
             \"t_start_ns\":{},\"t_end_ns\":{},\"bytes\":{},\"flow\":{}}}",
            e.stage.name(),
            e.worker,
            e.tid,
            e.round,
            e.layer,
            e.t_start_ns,
            e.t_end_ns,
            e.bytes,
            e.flow
        );
    }
    out
}

/// The dump-file stem: `GSPARSE_TRACE_OUT`, defaulting to `gsparse_trace`.
pub fn out_stem() -> String {
    match std::env::var("GSPARSE_TRACE_OUT") {
        Ok(v) if !v.is_empty() => v,
        _ => "gsparse_trace".to_string(),
    }
}

/// The run-shape tag embedded in every dump filename (see the module docs):
/// `r<rounds>.<topology>`, e.g. `r40.star`. Keeping the shape in the name
/// is what stops successive runs with different shapes in one directory
/// from silently overwriting each other's dumps.
pub fn run_tag(rounds: usize, topology: &str) -> String {
    format!("r{rounds}.{topology}")
}

/// Drain `recorder` and write `<stem>.<tag>.<role>.trace.json[l]` (`tag`
/// from [`run_tag`]); returns the path written. The coordinators call this
/// at run end when the environment asked for dumps
/// ([`TraceConfig::dump_requested`]).
pub fn dump(
    recorder: &Recorder,
    tag: &str,
    role: &str,
    format: TraceFormat,
) -> std::io::Result<std::path::PathBuf> {
    dump_events(&recorder.drain(), tag, role, format)
}

/// [`dump`] for an already-drained event list — what coordinators that
/// also roll the events into a [`MetricsSnapshot`] use, so one drain
/// serves both.
pub fn dump_events(
    events: &[Event],
    tag: &str,
    role: &str,
    format: TraceFormat,
) -> std::io::Result<std::path::PathBuf> {
    let (suffix, body) = match format {
        TraceFormat::Chrome => (".trace.json", chrome_trace_json(events)),
        TraceFormat::Jsonl => (".trace.jsonl", jsonl(events)),
    };
    let path = std::path::PathBuf::from(format!("{}.{tag}.{role}{suffix}", out_stem()));
    std::fs::write(&path, body)?;
    Ok(path)
}

// ---------------------------------------------------------------------------
// Metrics snapshot
// ---------------------------------------------------------------------------

/// A log₂-bucketed duration histogram: bucket `i` counts spans whose
/// duration in nanoseconds satisfies `floor(log2(max(ns, 1))) == i`
/// (fixed boundaries `[2^i, 2^(i+1))`, so snapshots from different runs
/// merge bucket-by-bucket).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Histogram {
    pub name: String,
    pub count: u64,
    pub sum_ns: u64,
    pub buckets: [u64; HISTOGRAM_BUCKETS],
}

impl Histogram {
    fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            count: 0,
            sum_ns: 0,
            buckets: [0; HISTOGRAM_BUCKETS],
        }
    }

    fn observe(&mut self, ns: u64) {
        self.count += 1;
        self.sum_ns += ns;
        let b = (63 - ns.max(1).leading_zeros() as usize).min(HISTOGRAM_BUCKETS - 1);
        self.buckets[b] += 1;
    }

    /// Lower bound of bucket `i` in nanoseconds.
    pub fn bucket_lower_bound_ns(i: usize) -> u64 {
        1u64 << i
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }
}

/// A periodic roll-up of a trace: per-stage counters (event and byte
/// totals), free-form gauges, and per-stage duration [`Histogram`]s. The
/// reports embed one and the benches write one into `BENCH_trace.json`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    /// `(name, value)` monotone counters.
    pub counters: Vec<(String, u64)>,
    /// `(name, value)` point-in-time gauges.
    pub gauges: Vec<(String, f64)>,
    /// Per-stage span-duration histograms (only stages that occurred).
    pub histograms: Vec<Histogram>,
}

impl MetricsSnapshot {
    /// Roll `events` up into per-stage counters + histograms.
    pub fn from_events(events: &[Event]) -> Self {
        let mut snap = MetricsSnapshot::default();
        let mut by_stage: Vec<Option<(u64, u64, Histogram)>> =
            (0..STAGES.len()).map(|_| None).collect();
        let mut max_round = 0u32;
        for e in events {
            let idx = e.stage as usize;
            let slot = by_stage[idx].get_or_insert_with(|| {
                (0, 0, Histogram::new(&format!("{}_duration_ns", e.stage.name())))
            });
            slot.0 += 1;
            slot.1 += e.bytes;
            slot.2.observe(e.duration_ns());
            max_round = max_round.max(e.round);
        }
        snap.counters.push(("events_total".into(), events.len() as u64));
        let rounds_seen = max_round as u64 + u64::from(!events.is_empty());
        snap.counters.push(("rounds_seen".into(), rounds_seen));
        for (stage, slot) in STAGES.iter().zip(by_stage) {
            if let Some((n, bytes, hist)) = slot {
                snap.counters.push((format!("{}_events", stage.name()), n));
                snap.counters.push((format!("{}_bytes", stage.name()), bytes));
                snap.histograms.push(hist);
            }
        }
        snap
    }

    /// Fold one link's transport counters into the registry under `label`
    /// (e.g. `link_w0`): framed bytes and frames in both directions plus
    /// the vectored-send count — the `LinkCounters` columns, so the
    /// snapshot is the one place with both timing and byte truth.
    pub fn fold_link_counters(&mut self, label: &str, c: &LinkCounters) {
        self.counters.push((format!("{label}_bytes_tx"), c.bytes_tx()));
        self.counters.push((format!("{label}_bytes_rx"), c.bytes_rx()));
        self.counters.push((format!("{label}_frames_tx"), c.frames_tx()));
        self.counters.push((format!("{label}_frames_rx"), c.frames_rx()));
        self.counters
            .push((format!("{label}_frames_vectored"), c.frames_vectored()));
    }

    pub fn push_gauge(&mut self, name: &str, value: f64) {
        self.gauges.push((name.to_string(), value));
    }

    /// Surface the recorder's ring-overwrite count
    /// ([`Recorder::dropped`]) as the `trace_dropped_total` counter —
    /// nonzero means the rings were too small for this run and the
    /// timing roll-ups undercount (the drop itself never blocked the hot
    /// path; that is the ring's contract).
    pub fn set_dropped(&mut self, dropped: u64) {
        if let Some(slot) = self
            .counters
            .iter_mut()
            .find(|(n, _)| n == "trace_dropped_total")
        {
            slot.1 = dropped;
        } else {
            self.counters.push(("trace_dropped_total".into(), dropped));
        }
    }

    /// Counter value by name (test/driver convenience).
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| n == name).map(|&(_, v)| v)
    }

    /// Histogram by name (test/driver convenience).
    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.iter().find(|h| h.name == name)
    }

    /// Hand-rolled JSON (the offline image has no serde): a schema-stable
    /// object `{"schema":"gsparse-metrics-v1","counters":{...},
    /// "gauges":{...},"histograms":[{"name":…,"count":…,"sum_ns":…,
    /// "buckets":[…]}]}` with log₂ bucket boundaries implied by index.
    pub fn to_json(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        out.push_str("{\"schema\":\"gsparse-metrics-v1\",\"counters\":{");
        for (i, (name, v)) in self.counters.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push('"');
            json_escape(name, &mut out);
            let _ = write!(out, "\":{v}");
        }
        out.push_str("},\"gauges\":{");
        for (i, (name, v)) in self.gauges.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push('"');
            json_escape(name, &mut out);
            if v.is_finite() {
                let _ = write!(out, "\":{v}");
            } else {
                out.push_str("\":null");
            }
        }
        out.push_str("},\"histograms\":[");
        for (i, h) in self.histograms.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str("{\"name\":\"");
            json_escape(&h.name, &mut out);
            let _ = write!(out, "\",\"count\":{},\"sum_ns\":{},\"buckets\":[", h.count, h.sum_ns);
            for (j, b) in h.buckets.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                let _ = write!(out, "{b}");
            }
            out.push_str("]}");
        }
        out.push_str("]}");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_config_creates_no_recorder_and_spans_are_inert() {
        assert!(Recorder::new(&TraceConfig::Off).is_none());
        // No recorder installed on this thread: spans and counters are
        // no-ops whatever other tests' recorders are doing.
        let mut s = span(Stage::Solve);
        s.bytes(10);
        drop(s);
        counter(Stage::FrameTx, 4);
    }

    #[test]
    fn spans_record_with_ambient_context() {
        let rec = Recorder::new(&TraceConfig::on()).unwrap();
        {
            let _g = install(&rec, 3);
            set_round(7);
            {
                let mut s = span(Stage::Encode);
                s.bytes(128);
                s.layer(2);
            }
            counter(Stage::FrameTx, 36);
        }
        let events = rec.drain();
        assert_eq!(events.len(), 2);
        let enc = events.iter().find(|e| e.stage == Stage::Encode).unwrap();
        assert_eq!((enc.worker, enc.round, enc.layer, enc.bytes), (3, 7, 2, 128));
        assert!(enc.t_end_ns >= enc.t_start_ns);
        let tx = events.iter().find(|e| e.stage == Stage::FrameTx).unwrap();
        assert_eq!(tx.bytes, 36);
        assert_eq!(tx.duration_ns(), 0);
        // After the guard dropped, recording stops.
        drop(span(Stage::Solve));
        assert!(rec.drain().is_empty());
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let rec = Recorder::new(&TraceConfig::On {
            capacity: 4,
            format: TraceFormat::Chrome,
        })
        .unwrap();
        let _g = install(&rec, 0);
        for i in 0..10u64 {
            counter(Stage::FrameTx, i);
        }
        assert_eq!(rec.dropped(), 6);
        let events = rec.drain();
        assert_eq!(events.len(), 4);
        // Oldest-first order of the surviving tail.
        let bytes: Vec<u64> = events.iter().map(|e| e.bytes).collect();
        assert_eq!(bytes, vec![6, 7, 8, 9]);
    }

    #[test]
    fn multi_thread_events_share_one_clock_origin() {
        let rec = Recorder::new(&TraceConfig::on()).unwrap();
        let _g = install(&rec, SERVER_WORKER);
        drop(span(Stage::Round));
        std::thread::scope(|scope| {
            for wid in 0..2u16 {
                let rec = rec.clone();
                scope.spawn(move || {
                    let _g = install(&rec, wid);
                    set_round(1);
                    drop(span(Stage::Solve));
                });
            }
        });
        let events = rec.drain();
        assert_eq!(events.len(), 3);
        let tids: std::collections::HashSet<u16> = events.iter().map(|e| e.tid).collect();
        assert_eq!(tids.len(), 3, "each thread gets its own tid lane");
        let workers: std::collections::HashSet<u16> =
            events.iter().map(|e| e.worker).collect();
        assert!(workers.contains(&SERVER_WORKER));
        assert!(workers.contains(&0) && workers.contains(&1));
    }

    #[test]
    fn chrome_and_jsonl_exports_are_well_formed() {
        let events = [
            Event {
                t_start_ns: 1_000,
                t_end_ns: 3_500,
                bytes: 64,
                flow: 0,
                round: 2,
                layer: 1,
                stage: Stage::Encode,
                worker: 0,
                tid: 0,
            },
            Event {
                t_start_ns: 4_000,
                t_end_ns: 4_000,
                bytes: 36,
                flow: (3u64 << 32) | 9,
                round: 2,
                layer: 0,
                stage: Stage::FrameTx,
                worker: 1,
                tid: 1,
            },
        ];
        let chrome = chrome_trace_json(&events);
        assert!(chrome.starts_with('{') && chrome.ends_with('}'));
        assert!(chrome.contains("\"traceEvents\":["));
        assert!(chrome.contains("\"name\":\"encode\""));
        assert!(chrome.contains("\"ts\":1.000"));
        assert!(chrome.contains("\"dur\":2.500"));
        assert!(chrome.contains("\"pid\":1"));
        // Flow ids appear in args only for flow-bearing events.
        assert_eq!(chrome.matches("\"flow\":").count(), 1);
        assert!(chrome.contains(&format!("\"flow\":{}", (3u64 << 32) | 9)));
        let lines = jsonl(&events);
        assert_eq!(lines.lines().count(), 2);
        assert!(lines.contains("\"stage\":\"frame_tx\""));
        assert!(lines.contains("\"t_start_ns\":1000"));
        assert!(lines.contains("\"flow\":0"));
    }

    #[test]
    fn snapshot_rolls_up_counters_and_log2_histograms() {
        let mk = |stage, dur: u64, bytes| Event {
            t_start_ns: 0,
            t_end_ns: dur,
            bytes,
            flow: 0,
            round: 4,
            layer: 0,
            stage,
            worker: 0,
            tid: 0,
        };
        let events = [
            mk(Stage::Encode, 1024, 100),
            mk(Stage::Encode, 1500, 50),
            mk(Stage::Round, 1 << 20, 0),
        ];
        let snap = MetricsSnapshot::from_events(&events);
        assert_eq!(snap.counter("events_total"), Some(3));
        assert_eq!(snap.counter("encode_events"), Some(2));
        assert_eq!(snap.counter("encode_bytes"), Some(150));
        assert_eq!(snap.counter("rounds_seen"), Some(5));
        let h = snap.histogram("encode_duration_ns").unwrap();
        assert_eq!(h.count, 2);
        assert_eq!(h.sum_ns, 2524);
        // 1024 and 1500 both land in bucket 10 ([2^10, 2^11)).
        assert_eq!(h.buckets[10], 2);
        let r = snap.histogram("round_duration_ns").unwrap();
        assert_eq!(r.buckets[20], 1);
        assert_eq!(Histogram::bucket_lower_bound_ns(10), 1024);
        // Empty input still renders.
        let empty = MetricsSnapshot::from_events(&[]);
        assert_eq!(empty.counter("rounds_seen"), Some(0));
        // JSON is structurally sound and carries the schema tag.
        let json = snap.to_json();
        assert!(json.starts_with("{\"schema\":\"gsparse-metrics-v1\""));
        assert!(json.contains("\"encode_duration_ns\""));
        assert_eq!(
            json.matches('{').count(),
            json.matches('}').count(),
            "balanced braces"
        );
    }

    #[test]
    fn snapshot_folds_link_counters() {
        let c = LinkCounters::new();
        let mut snap = MetricsSnapshot::default();
        snap.fold_link_counters("link_w0", &c);
        assert_eq!(snap.counter("link_w0_bytes_tx"), Some(0));
        assert_eq!(snap.counter("link_w0_frames_vectored"), Some(0));
    }

    #[test]
    fn flow_counters_carry_id_and_sender_round() {
        let rec = Recorder::new(&TraceConfig::on()).unwrap();
        {
            let _g = install(&rec, 1);
            set_round(3);
            assert_eq!(current_round(), 3);
            // A stamped frame_rx records the *sender's* round (9), not the
            // ambient one.
            counter_flow(Stage::FrameRx, 64, (2u64 << 32) | 5, 9);
            counter(Stage::FrameTx, 32);
        }
        let events = rec.drain();
        let rx = events.iter().find(|e| e.stage == Stage::FrameRx).unwrap();
        assert_eq!((rx.flow, rx.round), ((2u64 << 32) | 5, 9));
        let tx = events.iter().find(|e| e.stage == Stage::FrameTx).unwrap();
        assert_eq!((tx.flow, tx.round), (0, 3));
        // With no recorder installed, current_round is 0 and now_ns falls
        // back to the process epoch, still monotone.
        assert_eq!(current_round(), 0);
        let a = now_ns();
        let b = now_ns();
        assert!(b >= a);
    }

    #[test]
    fn run_tag_and_dropped_counter() {
        assert_eq!(run_tag(40, "star"), "r40.star");
        let mut snap = MetricsSnapshot::default();
        snap.set_dropped(3);
        assert_eq!(snap.counter("trace_dropped_total"), Some(3));
        snap.set_dropped(5); // overwrites, never duplicates
        assert_eq!(snap.counter("trace_dropped_total"), Some(5));
        assert_eq!(
            snap.counters.iter().filter(|(n, _)| n == "trace_dropped_total").count(),
            1
        );
    }

    #[test]
    fn config_wire_roundtrip() {
        for cfg in [
            TraceConfig::Off,
            TraceConfig::on(),
            TraceConfig::On {
                capacity: 123,
                format: TraceFormat::Jsonl,
            },
        ] {
            let bytes = cfg.wire_bytes();
            let cap = u32::from_le_bytes(bytes[1..5].try_into().unwrap());
            assert_eq!(TraceConfig::from_wire(bytes[0], cap), Some(cfg));
        }
        assert_eq!(TraceConfig::from_wire(9, 0), None);
    }
}
