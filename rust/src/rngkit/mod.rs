//! Pseudo-random number generation for the training hot path.
//!
//! The paper (§5.3, "engineering tricks") notes that per-coordinate calls to
//! a random number generator dominate the sparsification cost, and replaces
//! them with a pre-generated array of uniforms that is read cyclically during
//! training. This module provides:
//!
//! * [`SplitMix64`] — seeding / stream-splitting generator,
//! * [`Xoshiro256pp`] — the main counter-free generator (fast, 256-bit state),
//! * [`RandArray`] — the paper's pre-generated uniform array trick,
//! * Gaussian sampling via Box–Muller for the synthetic data generators.

mod randarray;

pub use randarray::RandArray;

/// SplitMix64: used to expand a single `u64` seed into generator state and
/// to derive independent per-worker streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the workhorse PRNG. Passes BigCrush; ~1ns/draw.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed from a `u64` via SplitMix64 (the reference seeding procedure).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive the RNG for worker `m` from a base seed: independent streams
    /// per worker so runs are reproducible regardless of thread scheduling.
    pub fn for_worker(base_seed: u64, worker: usize) -> Self {
        let mut sm = SplitMix64::new(base_seed ^ 0xA076_1D64_78BD_642F);
        for _ in 0..=worker {
            sm.next_u64();
        }
        Self::seed_from_u64(sm.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)` with 24-bit resolution.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased rejection method).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_u64(x, n);
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f32) -> bool {
        self.next_f32() < p
    }

    /// Standard normal via Box–Muller (one value per call; the pair's twin
    /// is discarded — data generation is not on the hot path).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[inline]
fn mul_u64(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference sequence for seed 0 from the splitmix64 reference impl.
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(sm.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_streams() {
        let mut a = Xoshiro256pp::seed_from_u64(42);
        let mut b = Xoshiro256pp::seed_from_u64(42);
        let mut c = Xoshiro256pp::seed_from_u64(43);
        let sa: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let sb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let sc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(sa, sb);
        assert_ne!(sa, sc);
    }

    #[test]
    fn worker_streams_differ() {
        let mut w0 = Xoshiro256pp::for_worker(7, 0);
        let mut w1 = Xoshiro256pp::for_worker(7, 1);
        let s0: Vec<u64> = (0..4).map(|_| w0.next_u64()).collect();
        let s1: Vec<u64> = (0..4).map(|_| w1.next_u64()).collect();
        assert_ne!(s0, s1);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn uniform_f32_range() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        for _ in 0..10_000 {
            let x = rng.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            let x = rng.next_below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn bernoulli_frequency() {
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let p = 0.3_f32;
        let n = 200_000;
        let hits = (0..n).filter(|_| rng.bernoulli(p)).count();
        let freq = hits as f64 / n as f64;
        assert!((freq - 0.3).abs() < 0.01, "freq={freq}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.next_gaussian();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256pp::seed_from_u64(6);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
