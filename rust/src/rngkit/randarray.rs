//! The paper's pre-generated random-array trick (§5.3):
//!
//! > "Another costly operation is the pseudo-random number generation in the
//! > sampling procedure; therefore we generate a large array of pseudo-random
//! > numbers in \[0, 1\], and iteratively read the numbers during training
//! > without calling a random number generating function."
//!
//! [`RandArray`] holds such a buffer of uniform `f32`s and serves them
//! cyclically. Each worker owns its own array (seeded from its stream) so no
//! synchronization is needed. A per-epoch `rotate` with a fresh random offset
//! breaks the exact periodicity that a naive cyclic read would introduce.

use super::Xoshiro256pp;

/// Pre-generated uniform-\[0,1) array read cyclically on the hot path.
#[derive(Clone, Debug)]
pub struct RandArray {
    buf: Vec<f32>,
    pos: usize,
    rng: Xoshiro256pp,
}

impl RandArray {
    /// Generate `len` uniforms from `rng`. `len` should comfortably exceed
    /// the gradient dimension so successive steps see different windows.
    pub fn new(mut rng: Xoshiro256pp, len: usize) -> Self {
        assert!(len > 0, "RandArray length must be positive");
        let buf = (0..len).map(|_| rng.next_f32()).collect();
        Self { buf, pos: 0, rng }
    }

    /// Convenience: seed directly.
    pub fn from_seed(seed: u64, len: usize) -> Self {
        Self::new(Xoshiro256pp::seed_from_u64(seed), len)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Next uniform; wraps around at the end of the buffer.
    #[inline]
    pub fn next(&mut self) -> f32 {
        let v = self.buf[self.pos];
        self.pos += 1;
        if self.pos == self.buf.len() {
            self.pos = 0;
        }
        v
    }

    /// Bernoulli draw with probability `p` using the pre-generated stream.
    #[inline]
    pub fn bernoulli(&mut self, p: f32) -> bool {
        self.next() < p
    }

    /// Fill `dst` with the next `dst.len()` uniforms (vectorizable copy on
    /// the non-wrapping fast path).
    pub fn fill(&mut self, dst: &mut [f32]) {
        let mut written = 0;
        while written < dst.len() {
            let take = (dst.len() - written).min(self.buf.len() - self.pos);
            dst[written..written + take].copy_from_slice(&self.buf[self.pos..self.pos + take]);
            self.pos += take;
            if self.pos == self.buf.len() {
                self.pos = 0;
            }
            written += take;
        }
    }

    /// Re-randomize the read offset (call between epochs to avoid exact
    /// periodic reuse of the same window alignment).
    pub fn reseed_offset(&mut self) {
        self.pos = self.rng.next_below(self.buf.len() as u64) as usize;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn values_in_unit_interval() {
        let mut ra = RandArray::from_seed(11, 1024);
        for _ in 0..5000 {
            let v = ra.next();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn wraps_cyclically() {
        let mut ra = RandArray::from_seed(12, 8);
        let first: Vec<f32> = (0..8).map(|_| ra.next()).collect();
        let second: Vec<f32> = (0..8).map(|_| ra.next()).collect();
        assert_eq!(first, second);
    }

    #[test]
    fn fill_matches_next() {
        let mut a = RandArray::from_seed(13, 64);
        let mut b = RandArray::from_seed(13, 64);
        let mut buf = vec![0.0f32; 100]; // exercises the wrap path
        a.fill(&mut buf);
        for (i, &v) in buf.iter().enumerate() {
            assert_eq!(v, b.next(), "mismatch at {i}");
        }
    }

    #[test]
    fn bernoulli_frequency_close() {
        let mut ra = RandArray::from_seed(14, 1 << 16);
        let n = 1 << 16;
        let hits = (0..n).filter(|_| ra.bernoulli(0.25)).count();
        let freq = hits as f64 / n as f64;
        assert!((freq - 0.25).abs() < 0.02, "freq={freq}");
    }

    #[test]
    fn reseed_offset_stays_in_bounds() {
        let mut ra = RandArray::from_seed(15, 33);
        for _ in 0..100 {
            ra.reseed_offset();
            let _ = ra.next();
        }
    }
}
