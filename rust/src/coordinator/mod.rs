//! The distributed training coordinator — the paper's system layer.
//!
//! All four coordinators are driven from one [`crate::api::Session`]
//! (method, codec, seed, topology, network model, layer batching), with
//! per-run knobs in the `api` task structs; the pre-Session config structs
//! remain as deprecated shims.
//!
//! * [`sync`] — Algorithm 1: synchronous data-parallel SGD with per-worker
//!   gradient sparsification, honest encode → All-Reduce → Broadcast rounds,
//!   and the paper's `η_t ∝ 1/(t·var)` step size. Also the SVRG variant
//!   (§5.1), including the eq. 15 master-kept-full-gradient option.
//! * [`cluster`] — a real threaded leader/worker runtime exchanging encoded
//!   byte messages over channels; used by the HLO-backed models (CNN,
//!   transformer) and the end-to-end examples.
//! * [`async_engine`] — Algorithm 4: the §5.3 asynchronous shared-memory
//!   engine with the Lock / Atomic / Wild update schemes, where
//!   sparsification reduces write conflicts between threads.

//! * [`param_server`] — asynchronous parameter server with a bounded-
//!   staleness (SSP) pull protocol, workers pushing encoded sparsified
//!   gradients over channels (§2's deployment style, §3's "asynchronous
//!   algorithms can also be used with our technique").

//! * [`dist`] — the same parameter-server loop over the pluggable
//!   [`crate::transport`] layer, deployable as threads (`InProc` or loopback
//!   TCP) or as genuinely separate OS processes (`gsparse server` /
//!   `gsparse worker`).

pub mod async_engine;
pub mod cluster;
pub mod dist;
pub mod param_server;
pub mod sync;

pub use async_engine::{AsyncReport, AsyncSvmEngine};
pub use cluster::{Cluster, LayerUpdate};
pub use dist::{DistReport, RunPlan};
pub use param_server::PsReport;
pub use sync::{OptKind, SvrgVariant};

// Deprecated shims of the pre-Session config surface, re-exported so the
// old paths keep resolving during migration.
#[allow(deprecated)]
pub use dist::DistConfig;
#[allow(deprecated)]
pub use param_server::{run_param_server, PsConfig};
#[allow(deprecated)]
pub use sync::{train_convex, TrainOptions};
