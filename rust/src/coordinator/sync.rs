//! Algorithm 1 (synchronous distributed optimization) and its SVRG variant,
//! over the pure-Rust convex models.
//!
//! Each simulated worker `m` owns a shard of the data, computes a minibatch
//! stochastic gradient, runs the sparsifier, and *actually encodes* the
//! message; the master decodes, averages (`v_t = (1/M) Σ Q(g^m)`), and every
//! worker takes the same descent step — exactly the loop in Algorithm 1,
//! with byte-accurate communication accounting. Deterministic given the
//! seed (workers iterate in index order), so figure runs are reproducible.
//!
//! Entry point: [`crate::api::Session::train_convex`] with a
//! [`SyncTask`] — the session owns method/codec/seed/topology/net, the task
//! the per-run knobs. The old `(ConvexConfig, TrainOptions)` pair survives
//! as a deprecated shim ([`train_convex`]).

use crate::api::{MethodSpec, Session, SyncTask};
use crate::coding::WireCodec;
use crate::comm::{Aggregator, NetworkModel, ReduceAlgo};
use crate::config::ConvexConfig;
use crate::data::{shard_indices, Dataset};
use crate::metrics::{CurvePoint, RunCurve, SparsityMeter, VarianceRatio};
use crate::model::ConvexModel;
use crate::opt::LrSchedule;
use crate::rngkit::{RandArray, Xoshiro256pp};
use crate::sparsify::{self, Compressed, Compressor, SparseGrad};
use crate::transport::frame::{self, GradHeader, MsgView};
use crate::transport::{Connection, Hello, InProcTransport, Transport};
use std::time::Instant;

/// Which optimizer the synchronous loop runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptKind {
    /// SGD with `η_t = lr / (t · var)` (§5.1).
    Sgd,
    /// SGD with plain `η_t = lr / t` (the Fig 5–6 convention).
    SgdInvT,
    /// SVRG with `η = lr / var` and a periodic full-gradient reference.
    Svrg(SvrgVariant),
}

/// The two SVRG sparsification placements discussed in §5.1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SvrgVariant {
    /// Workers transmit `Q(g(w) − g(w̃) + ∇f(w̃))` — the variant the paper
    /// uses for its figures.
    SparsifyFull,
    /// eq. 15: the master keeps `∇f(w̃)` exactly; workers transmit only
    /// `Q(g(w) − g(w̃))`.
    MasterFullGrad,
}

/// Knobs beyond [`ConvexConfig`] (deprecated shim of the Session API).
#[deprecated(
    since = "0.2.0",
    note = "build a gsparse::api::Session (method/codec/net/seed/workers) and pass the \
            remaining knobs via gsparse::api::SyncTask to Session::train_convex"
)]
#[derive(Clone, Debug)]
pub struct TrainOptions {
    pub opt: OptKind,
    /// Record a curve point every `record_every` synchronization rounds.
    pub record_every: usize,
    /// Subtract this from losses when reporting (suboptimality); 0 = raw.
    pub f_star: f64,
    /// Re-sparsify the averaged gradient before broadcast (Alg. 1 step 7).
    pub resparsify_broadcast: bool,
    /// SVRG inner-loop length in rounds (default: one data pass).
    pub svrg_inner: Option<usize>,
    pub net: NetworkModel,
    /// Wire codec the workers encode sparse messages with (negotiated in
    /// every worker's transport handshake).
    pub codec: WireCodec,
}

#[allow(deprecated)]
impl Default for TrainOptions {
    fn default() -> Self {
        Self {
            opt: OptKind::Sgd,
            record_every: 8,
            f_star: 0.0,
            resparsify_broadcast: false,
            svrg_inner: None,
            net: NetworkModel::commodity_1g(),
            codec: WireCodec::Raw,
        }
    }
}

/// Per-worker state for the simulated cluster. The message buffer is
/// persistent: `compress_into` reuses it every round, so the steady-state
/// compression path allocates nothing. The worker's end of the in-process
/// transport link carries every message to the master as framed bytes.
struct Worker {
    shard: Vec<usize>,
    rng: Xoshiro256pp,
    rand: RandArray,
    compressor: Box<dyn Compressor>,
    grad: Vec<f32>,
    ref_grad: Vec<f32>,
    /// The worker's local iterate: equals the global `w` when `H = 1`, and
    /// drifts through local gradient steps between synchronizations when
    /// the session schedules local steps (`H > 1`).
    w_local: Vec<f32>,
    /// Gradient sum accumulated since the last synchronization; what gets
    /// compressed and transmitted on a communication round.
    acc: Vec<f32>,
    msg: Compressed,
    conn: Box<dyn Connection>,
}

impl Worker {
    fn sample_batch(&mut self, batch: usize, out: &mut Vec<usize>) {
        out.clear();
        for _ in 0..batch {
            let k = self.rng.next_below(self.shard.len() as u64) as usize;
            out.push(self.shard[k]);
        }
    }
}

/// Run Algorithm 1 (or its SVRG variant) under the old config pair.
#[deprecated(
    since = "0.2.0",
    note = "build a gsparse::api::Session and call Session::train_convex with a SyncTask"
)]
#[allow(deprecated)]
pub fn train_convex(
    cfg: &ConvexConfig,
    opts: &TrainOptions,
    ds: &Dataset,
    model: &dyn ConvexModel,
) -> RunCurve {
    let session = Session::builder()
        .method(MethodSpec::from_parts(
            cfg.method,
            cfg.rho,
            cfg.c2 * cfg.c1,
            cfg.qsgd_bits,
        ))
        .codec(opts.codec)
        .net(opts.net)
        .seed(cfg.seed)
        .workers(cfg.workers)
        .build();
    let task = SyncTask {
        batch: cfg.batch,
        epochs: cfg.epochs,
        lr: cfg.lr,
        opt: opts.opt,
        record_every: opts.record_every,
        f_star: opts.f_star,
        resparsify_broadcast: opts.resparsify_broadcast,
        // The old path re-sparsified at cfg.rho regardless of method.
        resparsify_rho: Some(cfg.rho),
        svrg_inner: opts.svrg_inner,
    };
    session.train_convex(&task, ds, model)
}

/// The canonical synchronous runner behind [`Session::train_convex`].
///
/// The returned [`RunCurve`] carries the paper's figure statistics: the
/// realized variance ratio `var`, the realized sparsity `spa`, the idealized
/// communication bits (Fig 5–6 x-axis) and the simulated network time.
pub(crate) fn run_session(
    session: &Session,
    task: &SyncTask,
    ds: &Dataset,
    model: &dyn ConvexModel,
) -> RunCurve {
    let d = ds.d();
    let m = session.workers();
    let codec = session.codec();
    let net = session.net();
    let start = Instant::now();

    // Observability: the whole simulated cluster runs on this one thread,
    // so a single recorder/context covers every worker's spans (per-worker
    // attribution rides on the span `layer` field). `TraceConfig::Off`
    // leaves both as cheap no-ops.
    let trace_cfg = session.trace();
    let recorder = crate::trace::Recorder::new(&trace_cfg);
    let _trace_guard = crate::trace::install_opt(recorder.as_ref(), 0);

    // Worker → master messages cross the in-process transport as framed
    // wire bytes, so the ledger gains a measured column next to the
    // idealized one (same trait, same framing as the TCP runtime).
    let transport = InProcTransport::new();
    let mut listener = transport.listen("sync").expect("in-process listen");
    let mut workers: Vec<Worker> = (0..m)
        .map(|w| Worker {
            shard: shard_indices(ds.n(), w, m),
            rng: Xoshiro256pp::for_worker(session.seed(), w),
            rand: RandArray::new(
                Xoshiro256pp::for_worker(session.seed() ^ 0x5EED_0001, w),
                (4 * d).max(1 << 14),
            ),
            compressor: session.compressor(),
            grad: vec![0.0; d],
            ref_grad: vec![0.0; d],
            w_local: vec![0.0; d],
            acc: vec![0.0; d],
            msg: Compressed::Sparse(SparseGrad::empty(d)),
            conn: transport
                .connect("sync", &Hello::with_codec(w as u32, codec))
                .expect("in-process connect"),
        })
        .collect();
    let mut master_links: Vec<Box<dyn Connection>> =
        crate::transport::accept_n(listener.as_mut(), m, codec).expect("in-process accept");
    let link_counters: Vec<_> = master_links.iter().map(|c| c.counters()).collect();

    let mut w = vec![0.0f32; d];
    let mut v = vec![0.0f32; d]; // averaged update
    let mut agg = Aggregator::new(net, ReduceAlgo::Sparse);

    // SVRG reference state.
    let is_svrg = matches!(task.opt, OptKind::Svrg(_));
    let mut w_ref = vec![0.0f32; d];
    let mut full_ref = vec![0.0f32; d];
    let svrg_inner = task
        .svrg_inner
        .unwrap_or_else(|| (ds.n() / (m * task.batch)).max(1));

    let rounds_per_pass = (ds.n() as f64 / (m * task.batch) as f64).max(1e-9);
    let total_rounds = (task.epochs as f64 * rounds_per_pass).ceil() as usize;

    // Step-7 re-sparsification density: an explicit task override, else the
    // session method's density when it has one (GSpar/UniSp/TopK), else no
    // thinning.
    let resparsify_rho = task
        .resparsify_rho
        .or_else(|| session.method().density())
        .unwrap_or(1.0);

    let mut var_meter = VarianceRatio::default();
    let mut spa_meter = SparsityMeter::default();
    let mut curve = RunCurve::new(session.method().to_string());
    let mut sim_time = 0.0f64;
    let mut batch_idx: Vec<usize> = Vec::with_capacity(task.batch);
    // Round-persistent scratch: decoded per-worker messages, the shared wire
    // buffer, and the step-7 re-sparsification state. Nothing below is
    // allocated inside the training loop.
    let mut decoded: Vec<SparseGrad> = (0..m).map(|_| SparseGrad::empty(0)).collect();
    let mut wire: Vec<u8> = Vec::new();
    let mut frame_buf: Vec<u8> = Vec::new();
    let mut rx_frame: Vec<u8> = Vec::new();
    let mut dense_tx: Vec<f32> = vec![0.0; d];
    let mut dense_bytes: Vec<u8> = Vec::new();
    let mut dense_rx: Vec<Vec<f32>> = (0..m).map(|_| Vec::new()).collect();
    let mut kinds: Vec<u8> = vec![0; m];
    let mut resparsify_p: Vec<f32> = Vec::new();
    let mut resparsify_sg = SparseGrad::empty(d);

    let schedule = match task.opt {
        OptKind::Sgd => LrSchedule::inv_t_var(task.lr),
        OptKind::SgdInvT => LrSchedule::inv_t(task.lr),
        OptKind::Svrg(_) => LrSchedule::constant(task.lr),
    };

    // Local-step scheduling (Qsparse-local-SGD style): workers synchronize
    // only on communication rounds; in between they take local gradient
    // steps and accumulate, and *nothing* crosses any link. The final
    // round always flushes so no tail gradient is lost.
    let h = session.local_steps();
    let comm_schedule = session.comm_schedule();
    assert!(
        h == 1 || !is_svrg,
        "local-step scheduling (H > 1) is not defined for the SVRG variants"
    );

    // Record the starting point.
    curve.points.push(CurvePoint {
        data_passes: 0.0,
        loss: model.loss(ds, &w) - task.f_star,
        comm_bits: 0,
        wall_ms: 0.0,
    });

    for t in 1..=total_rounds {
        crate::trace::set_round(t as u32);
        let _round_span = crate::trace::span(crate::trace::Stage::Round);
        // SVRG outer loop: refresh the reference point + full gradient.
        if is_svrg && (t - 1) % svrg_inner == 0 {
            w_ref.copy_from_slice(&w);
            model.grad_full(ds, &w_ref, &mut full_ref);
            // One dense synchronization round for the reference broadcast.
            let bytes = (d * 4) as u64;
            curve.ledger.record(sparsify::dense_ideal_bits(d), bytes);
            sim_time += net.round_time_s(&vec![bytes; m], bytes);
        }

        let comm = comm_schedule.is_comm_round(t as u64) || t == total_rounds;

        // ---- Algorithm 1 steps 3–4: local gradients (+ local steps) ----
        let local_span = crate::trace::span(crate::trace::Stage::LocalStep);
        let var_before = var_meter.value().max(1e-12);
        for worker in workers.iter_mut() {
            worker.sample_batch(task.batch, &mut batch_idx);
            model.grad_minibatch(ds, &worker.w_local, &batch_idx, &mut worker.grad);
            if let OptKind::Svrg(variant) = task.opt {
                model.grad_minibatch(ds, &w_ref, &batch_idx, &mut worker.ref_grad);
                match variant {
                    SvrgVariant::SparsifyFull => {
                        // g ← g(w) − g(w̃) + ∇f(w̃), then sparsify everything.
                        for i in 0..d {
                            worker.grad[i] = worker.grad[i] - worker.ref_grad[i] + full_ref[i];
                        }
                    }
                    SvrgVariant::MasterFullGrad => {
                        // eq. 15: transmit only Q(g(w) − g(w̃)).
                        for i in 0..d {
                            worker.grad[i] -= worker.ref_grad[i];
                        }
                    }
                }
            }
            crate::tensor::axpy(1.0, &worker.grad, &mut worker.acc);
            // (On a comm round `w_local` is about to be overwritten by the
            // fresh global `w`, so the local step would be dead work.)
            if h > 1 && !comm {
                // Local step on the worker's own iterate; the accumulated
                // gradient (not the local trajectory) is what synchronizes.
                let eta_local = match task.opt {
                    OptKind::Sgd => schedule.eta(t as u64, var_before),
                    OptKind::SgdInvT => schedule.eta(t as u64, 1.0),
                    OptKind::Svrg(_) => unreachable!("SVRG is gated to H = 1"),
                };
                crate::tensor::axpy(-eta_local, &worker.grad, &mut worker.w_local);
            }
        }

        drop(local_span);

        // ---- Local rounds end here: zero frames, zero bytes on the wire.
        if comm {
            // ---- Step 5: sparsify + ship the accumulated gradients ----
            let mut upload_bytes = 0u64;
            let mut all_sparse = true;
            for (widx, (worker, slot)) in workers.iter_mut().zip(decoded.iter_mut()).enumerate() {
                let g_norm = crate::tensor::norm2_sq(&worker.acc) as f64;
                let stats =
                    worker
                        .compressor
                        .compress_into(&worker.acc, &mut worker.rand, &mut worker.msg);
                let q_norm = worker.msg.norm2_sq();
                var_meter.record(q_norm, g_norm);
                spa_meter.record(stats.expected_nnz, d);
                // Honest wire accounting: every message is framed and shipped
                // over the worker's transport link; the master decodes from
                // what actually arrived. Sparse messages travel as codec
                // bytes; quantized/dense ones as raw f32 (their wire ledger
                // entry stays the idealized byte size, as before).
                let (kind, msg_bytes): (u8, u64) = match &worker.msg {
                    Compressed::Sparse(sg) => {
                        crate::coding::encode_with(sg, codec, &mut wire);
                        (0, wire.len() as u64)
                    }
                    other => {
                        all_sparse = false;
                        other.dense_le_bytes_into(&mut dense_tx, &mut dense_bytes);
                        (1, (stats.ideal_bits / 8).max(1))
                    }
                };
                let header = GradHeader {
                    based_on: t as u64,
                    g_norm_sq: g_norm,
                    q_norm_sq: q_norm,
                    expected_nnz: stats.expected_nnz,
                    ideal_bits: stats.ideal_bits,
                    kind,
                };
                let payload: &[u8] = if kind == 0 { &wire } else { &dense_bytes };
                let mut push_span = crate::trace::span(crate::trace::Stage::Push);
                push_span.layer(widx as u32);
                frame::encode_grad(&mut frame_buf, &header, payload);
                push_span.bytes(frame_buf.len() as u64);
                worker.conn.send(&frame_buf).expect("master link alive");
                master_links[widx].recv(&mut rx_frame).expect("worker frame");
                match frame::decode(&rx_frame).expect("self-encoded") {
                    MsgView::Grad { header: hd, payload } => {
                        if hd.kind == 0 {
                            crate::coding::decode_into(payload, slot).expect("self-encoded");
                        } else {
                            frame::weights_into(payload, &mut dense_rx[widx]);
                        }
                        kinds[widx] = hd.kind;
                    }
                    other => panic!("unexpected message from worker: {other:?}"),
                }
                upload_bytes += msg_bytes;
                let msg_codec = if kind == 0 { codec } else { WireCodec::Raw };
                curve.ledger.record_codec(stats.ideal_bits, msg_bytes, msg_codec);
            }

            // ---- Step 6: All-Reduce v_t = (1/M) Σ Q(Σ_local g^m) ----
            let mut apply_span = crate::trace::span(crate::trace::Stage::Apply);
            apply_span.bytes(upload_bytes);
            if all_sparse {
                let out = agg.reduce_decoded(&decoded, upload_bytes, &mut v);
                sim_time += out.sim_time_s;
            } else {
                // Mixed/dense/quantized messages: accumulate what arrived on
                // the links (decoded sparse slots or raw dense payloads).
                v.fill(0.0);
                let inv_m = 1.0 / m as f32;
                for ((kind, dec), den) in kinds.iter().zip(&decoded).zip(&dense_rx) {
                    if *kind == 0 {
                        dec.add_into(inv_m, &mut v);
                    } else {
                        crate::tensor::axpy(inv_m, den, &mut v);
                    }
                }
                sim_time += net.round_time_s(&vec![upload_bytes / m as u64; m], (d * 4) as u64);
            }
            drop(apply_span);

            // ---- Optional step 7: re-sparsify the average pre-broadcast ----
            if task.resparsify_broadcast {
                let pv = sparsify::greedy_probs(&v, resparsify_rho, 2, &mut resparsify_p);
                sparsify::sample_sparse_into(
                    &v,
                    &resparsify_p,
                    pv.inv_lambda,
                    &mut workers[0].rand,
                    &mut resparsify_sg,
                );
                v.fill(0.0);
                resparsify_sg.add_into(1.0, &mut v);
            }

            // SVRG eq. 15: master adds its exact full gradient after
            // averaging.
            if matches!(task.opt, OptKind::Svrg(SvrgVariant::MasterFullGrad)) {
                crate::tensor::axpy(1.0, &full_ref, &mut v);
            }

            // ---- Steps 8–9: broadcast + descent on every worker ----
            let var_now = var_meter.value().max(1e-12);
            let eta = match task.opt {
                OptKind::Sgd => schedule.eta(t as u64, var_now),
                OptKind::SgdInvT => schedule.eta(t as u64, 1.0),
                OptKind::Svrg(_) => schedule.eta_constant(var_now),
            };
            crate::tensor::axpy(-eta, &v, &mut w);
            for worker in workers.iter_mut() {
                worker.w_local.copy_from_slice(&w);
                worker.acc.fill(0.0);
            }
        }

        if t % task.record_every == 0 || t == total_rounds {
            curve.points.push(CurvePoint {
                data_passes: t as f64 / rounds_per_pass,
                loss: model.loss(ds, &w) - task.f_star,
                comm_bits: curve.ledger.ideal_bits,
                wall_ms: sim_time * 1e3,
            });
        }
    }

    curve.var_ratio = var_meter.value();
    curve.sparsity = spa_meter.value();
    curve
        .ledger
        .set_measured(link_counters.iter().map(|c| c.bytes_total()).sum());
    curve.ledger.set_measured_frames(
        link_counters.iter().map(|c| c.frames_rx() + c.frames_tx()).sum(),
    );
    curve.ledger.verify();
    if let Some(rec) = &recorder {
        if crate::trace::TraceConfig::dump_requested() {
            let tag = crate::trace::run_tag(total_rounds, "star");
            let _ = crate::trace::dump(rec, &tag, "sync", trace_cfg.format());
        }
    }
    let _ = start;
    curve
}

/// Estimate `f* = min_w f(w)` by running many full-gradient steps (shared by
/// the figure drivers so all curves subtract the same optimum).
pub fn estimate_f_star(ds: &Dataset, model: &dyn ConvexModel, iters: usize, lr: f32) -> f64 {
    let d = ds.d();
    let mut w = vec![0.0f32; d];
    let mut g = vec![0.0f32; d];
    let mut best = f64::INFINITY;
    let mut step = lr;
    let mut prev = f64::INFINITY;
    for _ in 0..iters {
        model.grad_full(ds, &w, &mut g);
        crate::tensor::axpy(-step, &g, &mut w);
        let l = model.loss(ds, &w);
        if l > prev {
            step *= 0.5; // crude backtracking keeps GD stable
        }
        prev = l;
        best = best.min(l);
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;
    use crate::data::gen_logistic;
    use crate::model::LogisticModel;

    fn small_session(spec: MethodSpec) -> Session {
        Session::builder()
            .method(spec)
            .workers(4)
            .seed(77)
            .build()
    }

    fn small_task() -> SyncTask {
        SyncTask {
            batch: 8,
            epochs: 12,
            lr: 1.0,
            ..SyncTask::default()
        }
    }

    fn small_data() -> (Dataset, LogisticModel) {
        let ds = gen_logistic(128, 256, 0.6, 0.25, 77);
        let model = LogisticModel::new(1.0 / (10.0 * 128.0));
        (ds, model)
    }

    fn run(spec: MethodSpec, opt: OptKind) -> RunCurve {
        let (ds, model) = small_data();
        let task = SyncTask {
            opt,
            ..small_task()
        };
        small_session(spec).train_convex(&task, &ds, &model)
    }

    fn gspar() -> MethodSpec {
        MethodSpec::GSpar { rho: 0.1, iters: 2 }
    }

    #[test]
    fn sgd_gspar_reduces_loss() {
        let curve = run(gspar(), OptKind::Sgd);
        let first = curve.points.first().unwrap().loss;
        let last = curve.final_loss();
        assert!(last < first * 0.9, "loss {first} -> {last}");
        assert!(curve.var_ratio > 1.0, "sparsification must inflate variance");
        assert!(curve.sparsity < 0.2, "expected sparse transmission");
        assert!(curve.ledger.ideal_bits > 0);
        assert!(curve.ledger.wire_bytes > 0);
        // The transport counters must have seen every payload byte plus
        // framing (length prefixes + handshakes).
        assert!(curve.ledger.measured_bytes > curve.ledger.wire_bytes);
    }

    #[test]
    fn entropy_codec_same_training_fewer_bytes() {
        // The codec only changes bytes on the wire, never the decoded
        // values: the training trajectory must match the raw run bitwise,
        // while both the wire and measured columns shrink — the Fig-1
        // logreg workload where `Entropy` must beat `Raw`.
        let (ds, model) = small_data();
        let run_with = |codec| {
            let session = Session::builder()
                .method(gspar())
                .workers(4)
                .seed(77)
                .codec(codec)
                .build();
            session.train_convex(&small_task(), &ds, &model)
        };
        let raw = run_with(WireCodec::Raw);
        let ent = run_with(WireCodec::Entropy);
        assert_eq!(raw.final_loss(), ent.final_loss());
        assert_eq!(raw.ledger.ideal_bits, ent.ledger.ideal_bits);
        assert!(
            ent.ledger.wire_bytes < raw.ledger.wire_bytes,
            "entropy {} !< raw {}",
            ent.ledger.wire_bytes,
            raw.ledger.wire_bytes
        );
        assert!(ent.ledger.measured_bytes < raw.ledger.measured_bytes);
        assert_eq!(
            ent.ledger.wire_bytes_by_codec,
            [0, ent.ledger.wire_bytes],
            "sparse GSpar messages must all land in the entropy column"
        );
    }

    #[test]
    fn svrg_both_variants_reduce_loss() {
        for variant in [SvrgVariant::SparsifyFull, SvrgVariant::MasterFullGrad] {
            let (ds, model) = small_data();
            let task = SyncTask {
                opt: OptKind::Svrg(variant),
                lr: 0.25,
                ..small_task()
            };
            let curve = small_session(gspar()).train_convex(&task, &ds, &model);
            let first = curve.points.first().unwrap().loss;
            let last = curve.final_loss();
            assert!(last < first * 0.9, "{variant:?}: {first} -> {last}");
        }
    }

    #[test]
    fn gspar_beats_unisp_at_same_density() {
        // The paper's core empirical claim (Figures 1–4): at matched spa,
        // GSpar has lower var and converges faster than UniSp.
        let gspar = run(gspar(), OptKind::Sgd);
        let unisp = run(MethodSpec::UniSp { rho: 0.1 }, OptKind::Sgd);
        assert!(
            gspar.var_ratio < unisp.var_ratio,
            "var: gspar {} vs unisp {}",
            gspar.var_ratio,
            unisp.var_ratio
        );
        assert!(
            gspar.final_loss() < unisp.final_loss() * 1.05,
            "loss: gspar {} vs unisp {}",
            gspar.final_loss(),
            unisp.final_loss()
        );
    }

    #[test]
    fn dense_baseline_fastest_per_iteration_but_most_bits() {
        let dense = run(MethodSpec::Dense, OptKind::Sgd);
        let gspar = run(gspar(), OptKind::Sgd);
        assert!(dense.var_ratio <= 1.0 + 1e-9);
        assert!(
            gspar.ledger.ideal_bits < dense.ledger.ideal_bits / 2,
            "sparsified bits {} should be ≪ dense {}",
            gspar.ledger.ideal_bits,
            dense.ledger.ideal_bits
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(gspar(), OptKind::Sgd);
        let b = run(gspar(), OptKind::Sgd);
        assert_eq!(a.final_loss(), b.final_loss());
        assert_eq!(a.ledger.ideal_bits, b.ledger.ideal_bits);
    }

    #[test]
    fn resparsify_broadcast_still_converges() {
        let (ds, model) = small_data();
        let task = SyncTask {
            resparsify_broadcast: true,
            ..small_task()
        };
        let curve = small_session(gspar()).train_convex(&task, &ds, &model);
        assert!(curve.final_loss() < curve.points[0].loss);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shim_matches_session_run_bitwise() {
        // The migration guarantee: `train_convex(&ConvexConfig,
        // &TrainOptions, …)` is a pure forwarding shim — identical curve,
        // identical ledger.
        let cfg = ConvexConfig {
            n: 128,
            d: 256,
            c1: 0.6,
            c2: 0.25,
            reg: 1.0 / (10.0 * 128.0),
            rho: 0.1,
            workers: 4,
            batch: 8,
            epochs: 12,
            lr: 1.0,
            method: Method::GSpar,
            seed: 77,
            qsgd_bits: 4,
        };
        let (ds, model) = small_data();
        let old = train_convex(&cfg, &TrainOptions::default(), &ds, &model);
        let new = small_session(gspar()).train_convex(&small_task(), &ds, &model);
        assert_eq!(old.final_loss(), new.final_loss());
        assert_eq!(old.ledger.ideal_bits, new.ledger.ideal_bits);
        assert_eq!(old.ledger.wire_bytes, new.ledger.wire_bytes);
        assert_eq!(old.ledger.measured_bytes, new.ledger.measured_bytes);
        assert_eq!(old.name, new.name);
    }

    #[test]
    fn f_star_estimate_below_sgd_losses() {
        let (ds, model) = small_data();
        let f_star = estimate_f_star(&ds, &model, 400, 1.0);
        let curve = run(MethodSpec::Dense, OptKind::Sgd);
        assert!(f_star <= curve.final_loss() + 1e-6);
        assert!(f_star.is_finite());
    }
}
