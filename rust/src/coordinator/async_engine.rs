//! Algorithm 4: asynchronous parallel SGD on shared memory (§5.3).
//!
//! Multiple threads train an ℓ2-regularized SVM against one shared weight
//! vector, with the paper's three update schemes:
//!
//! * **Lock** — a global mutex serializes every update (slowest, strongest
//!   consistency);
//! * **Atomic** — per-coordinate atomic compare-exchange adds (the scheme
//!   Figure 9 plots); conflicts (CAS retries) are counted;
//! * **Wild** — plain unsynchronized read-modify-write (HOGWILD!-style).
//!
//! Gradient sparsification reduces the number of coordinates each step
//! touches, which reduces cacheline contention and CAS conflicts — the §5.3
//! effect. The engine applies the paper's §5.3 engineering tricks verbatim:
//! survivors outside the exact set share the constant value `±1/λ` (no
//! per-coordinate division), and Bernoulli draws come from a pre-generated
//! uniform array.

use crate::config::{AsyncSvmConfig, Method, UpdateScheme};
use crate::data::Dataset;
use crate::metrics::{CurvePoint, RunCurve};
use crate::model::{ConvexModel, SvmModel};
use crate::rngkit::{RandArray, Xoshiro256pp};
use crate::sparsify::CompressEngine;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Shared f32 vector stored as atomic bit patterns.
struct SharedVec {
    data: Vec<AtomicU32>,
}

impl SharedVec {
    fn zeros(d: usize) -> Self {
        Self {
            data: (0..d).map(|_| AtomicU32::new(0f32.to_bits())).collect(),
        }
    }

    #[inline]
    fn load(&self, i: usize) -> f32 {
        f32::from_bits(self.data[i].load(Ordering::Relaxed))
    }

    /// Atomic `+= delta` via CAS; returns the number of retries (conflicts).
    #[inline]
    fn fetch_add(&self, i: usize, delta: f32) -> u32 {
        let cell = &self.data[i];
        let mut conflicts = 0;
        let mut cur = cell.load(Ordering::Relaxed);
        loop {
            let next = (f32::from_bits(cur) + delta).to_bits();
            match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return conflicts,
                Err(actual) => {
                    conflicts += 1;
                    cur = actual;
                }
            }
        }
    }

    /// Unsynchronized `+=` (the Wild scheme): racy read-modify-write.
    #[inline]
    fn wild_add(&self, i: usize, delta: f32) {
        let cur = f32::from_bits(self.data[i].load(Ordering::Relaxed));
        self.data[i].store((cur + delta).to_bits(), Ordering::Relaxed);
    }

    fn snapshot(&self, out: &mut [f32]) {
        for (o, cell) in out.iter_mut().zip(&self.data) {
            *o = f32::from_bits(cell.load(Ordering::Relaxed));
        }
    }
}

/// Outcome of an asynchronous run.
#[derive(Debug, Clone)]
pub struct AsyncReport {
    pub curve: RunCurve,
    /// Total coordinate updates applied across threads.
    pub updates: u64,
    /// CAS conflicts observed (Atomic scheme only).
    pub conflicts: u64,
    /// Wall time of the whole run.
    pub wall_ms: f64,
    /// Final loss.
    pub final_loss: f64,
}

/// The Algorithm-4 engine.
pub struct AsyncSvmEngine {
    pub cfg: AsyncSvmConfig,
}

impl AsyncSvmEngine {
    pub fn new(cfg: AsyncSvmConfig) -> Self {
        Self { cfg }
    }

    /// Run Algorithm 4: `threads` workers hammer the shared weights until
    /// the global step budget is exhausted; a monitor thread records the
    /// loss curve against wall-clock time.
    pub fn run(&self, ds: &Dataset) -> AsyncReport {
        let cfg = &self.cfg;
        let d = ds.d();
        let model = SvmModel::new(cfg.reg);
        let shared = Arc::new(SharedVec::zeros(d));
        let remaining = Arc::new(AtomicU64::new(cfg.total_steps as u64));
        let conflicts = Arc::new(AtomicU64::new(0));
        let updates = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let lock = Arc::new(Mutex::new(()));
        let start = Instant::now();

        // Observability: shared-memory runs have no Session, so the trace
        // switch is the environment (`GSPARSE_TRACE`). Spans are per claim
        // chunk — never per coordinate update — so the hot CAS loop stays
        // untouched.
        let trace_cfg = crate::trace::TraceConfig::from_env();
        let recorder = crate::trace::Recorder::new(&trace_cfg);

        // Monitor samples (wall_ms, loss).
        let monitor_points = Arc::new(Mutex::new(Vec::<(f64, f64)>::new()));

        std::thread::scope(|scope| {
            // Worker threads.
            for tid in 0..cfg.threads {
                let shared = Arc::clone(&shared);
                let remaining = Arc::clone(&remaining);
                let conflicts = Arc::clone(&conflicts);
                let updates = Arc::clone(&updates);
                let lock = Arc::clone(&lock);
                let model = model;
                let cfg = cfg.clone();
                let worker_recorder = recorder.clone();
                scope.spawn(move || {
                    let _trace_guard =
                        crate::trace::install_opt(worker_recorder.as_ref(), tid as u16);
                    worker_loop(
                        tid, &cfg, ds, &model, &shared, &remaining, &conflicts, &updates, &lock,
                    );
                });
            }
            // Monitor thread: snapshot loss every ~2 ms.
            {
                let shared = Arc::clone(&shared);
                let stop = Arc::clone(&stop);
                let monitor_points = Arc::clone(&monitor_points);
                let model = model;
                scope.spawn(move || {
                    let mut w = vec![0.0f32; d];
                    while !stop.load(Ordering::Relaxed) {
                        shared.snapshot(&mut w);
                        let loss = model.loss(ds, &w);
                        let ms = start.elapsed().as_secs_f64() * 1e3;
                        monitor_points.lock().unwrap().push((ms, loss));
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                });
            }
            // Wait for workers by polling the budget; then stop the monitor.
            while remaining.load(Ordering::Relaxed) > 0 {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            stop.store(true, Ordering::Relaxed);
        });

        let wall_ms = start.elapsed().as_secs_f64() * 1e3;
        let mut w = vec![0.0f32; d];
        shared.snapshot(&mut w);
        let final_loss = model.loss(ds, &w);

        let mut curve = RunCurve::new(format!(
            "{}-{}(th={})",
            method_name(cfg.method),
            cfg.scheme,
            cfg.threads
        ));
        for (ms, loss) in monitor_points.lock().unwrap().iter() {
            curve.points.push(CurvePoint {
                data_passes: 0.0,
                loss: *loss,
                comm_bits: 0,
                wall_ms: *ms,
            });
        }
        curve.points.push(CurvePoint {
            data_passes: 0.0,
            loss: final_loss,
            comm_bits: 0,
            wall_ms,
        });
        curve.sparsity = cfg.rho as f64;

        if let Some(rec) = &recorder {
            if crate::trace::TraceConfig::dump_requested() {
                let tag = crate::trace::run_tag(cfg.total_steps, "shared");
                let _ = crate::trace::dump(rec, &tag, "async", trace_cfg.format());
            }
        }

        AsyncReport {
            curve,
            updates: updates.load(Ordering::Relaxed),
            conflicts: conflicts.load(Ordering::Relaxed),
            wall_ms,
            final_loss,
        }
    }
}

fn method_name(m: Method) -> &'static str {
    match m {
        Method::Dense => "dense",
        Method::GSpar => "GSpar",
        Method::UniSp => "UniSp",
        other => {
            let _ = other;
            "other"
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    tid: usize,
    cfg: &AsyncSvmConfig,
    ds: &Dataset,
    model: &SvmModel,
    shared: &SharedVec,
    remaining: &AtomicU64,
    conflicts: &AtomicU64,
    updates: &AtomicU64,
    lock: &Mutex<()>,
) {
    let d = ds.d();
    let mut rng = Xoshiro256pp::for_worker(cfg.seed, tid);
    // §5.3 trick: pre-generated random array per thread.
    let mut rand = RandArray::new(
        Xoshiro256pp::for_worker(cfg.seed ^ 0xA5A5, tid),
        (8 * d).max(1 << 12),
    );
    let mut w_local = vec![0.0f32; d];
    let mut g = vec![0.0f32; d];
    // Per-thread scratch-arena engine: probability solves reuse one buffer
    // for the whole run (the updates are applied coordinate-wise, so only
    // the probability stage of the engine is exercised here).
    let mut engine = CompressEngine::greedy(cfg.rho, 2);
    engine.reserve(d);
    let mut t_local = 0u64;
    let mut local_conflicts = 0u64;
    let mut local_updates = 0u64;
    let chunk = 64u64; // claim steps in chunks to cut budget contention

    'outer: loop {
        // Claim a chunk of the global step budget.
        let mut claimed = remaining.load(Ordering::Relaxed);
        let take;
        loop {
            if claimed == 0 {
                break 'outer;
            }
            let want = claimed.min(chunk);
            match remaining.compare_exchange_weak(
                claimed,
                claimed - want,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    take = want;
                    break;
                }
                Err(actual) => claimed = actual,
            }
        }

        let mut chunk_span = crate::trace::span(crate::trace::Stage::LocalStep);
        chunk_span.bytes(take);
        for _ in 0..take {
            t_local += 1;
            // Step size: lr/ρ initial (paper §5.3), 1/sqrt(t) decay keeps
            // long runs stable without dying too fast.
            let eta = cfg.lr / cfg.rho / (1.0 + (t_local as f32).sqrt());
            let r = rng.next_below(ds.n() as u64) as usize;

            // Locked/atomic/wild READ of the coordinates the example touches.
            shared.snapshot(&mut w_local);
            model.grad_minibatch(ds, &w_local, &[r], &mut g);

            // Sparsify.
            let scale = -eta / 1.0; // single "machine" (M folds into threads)
            match cfg.method {
                Method::Dense => {
                    apply_dense(cfg.scheme, shared, &g, scale, lock, &mut local_conflicts);
                    local_updates += d as u64;
                }
                Method::UniSp => {
                    let inv_rho = 1.0 / cfg.rho;
                    for i in 0..d {
                        if g[i] != 0.0 && rand.next() < cfg.rho {
                            apply_one(
                                cfg.scheme,
                                shared,
                                i,
                                scale * g[i] * inv_rho,
                                lock,
                                &mut local_conflicts,
                            );
                            local_updates += 1;
                        }
                    }
                }
                _ => {
                    // GSpar (greedy, 2 iterations — the paper's setting),
                    // through the engine's reusable probability scratch.
                    let pv = engine.probs(&g);
                    // §5.3 trick: constant magnitude, no division.
                    let shared_val = pv.inv_lambda;
                    let p = engine.probabilities();
                    for i in 0..d {
                        let pi = p[i];
                        if pi <= 0.0 {
                            continue;
                        }
                        let delta = if pi >= 1.0 {
                            g[i]
                        } else if rand.next() < pi {
                            if g[i] < 0.0 {
                                -shared_val
                            } else {
                                shared_val
                            }
                        } else {
                            continue;
                        };
                        apply_one(cfg.scheme, shared, i, scale * delta, lock, &mut local_conflicts);
                        local_updates += 1;
                    }
                }
            }
        }
    }
    conflicts.fetch_add(local_conflicts, Ordering::Relaxed);
    updates.fetch_add(local_updates, Ordering::Relaxed);
}

#[inline]
fn apply_one(
    scheme: UpdateScheme,
    shared: &SharedVec,
    i: usize,
    delta: f32,
    lock: &Mutex<()>,
    conflicts: &mut u64,
) {
    match scheme {
        UpdateScheme::Lock => {
            let _guard = lock.lock().unwrap();
            shared.wild_add(i, delta);
        }
        UpdateScheme::Atomic => {
            *conflicts += shared.fetch_add(i, delta) as u64;
        }
        UpdateScheme::Wild => shared.wild_add(i, delta),
    }
}

fn apply_dense(
    scheme: UpdateScheme,
    shared: &SharedVec,
    g: &[f32],
    scale: f32,
    lock: &Mutex<()>,
    conflicts: &mut u64,
) {
    match scheme {
        UpdateScheme::Lock => {
            let _guard = lock.lock().unwrap();
            for (i, &gi) in g.iter().enumerate() {
                if gi != 0.0 {
                    shared.wild_add(i, scale * gi);
                }
            }
        }
        UpdateScheme::Atomic => {
            for (i, &gi) in g.iter().enumerate() {
                if gi != 0.0 {
                    *conflicts += shared.fetch_add(i, scale * gi) as u64;
                }
            }
        }
        UpdateScheme::Wild => {
            for (i, &gi) in g.iter().enumerate() {
                if gi != 0.0 {
                    shared.wild_add(i, scale * gi);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_svm;

    fn tiny_cfg(method: Method, scheme: UpdateScheme, threads: usize) -> AsyncSvmConfig {
        AsyncSvmConfig {
            n: 512,
            d: 64,
            c1: 0.01,
            c2: 0.9,
            reg: 0.1,
            rho: 0.1,
            threads,
            lr: 0.05,
            method,
            seed: 9,
            total_steps: 6_000,
            scheme,
        }
    }

    #[test]
    fn shared_vec_atomic_add_is_exact_cross_thread() {
        let v = Arc::new(SharedVec::zeros(4));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let v = Arc::clone(&v);
                s.spawn(move || {
                    for _ in 0..10_000 {
                        v.fetch_add(0, 1.0);
                    }
                });
            }
        });
        assert_eq!(v.load(0), 40_000.0);
    }

    #[test]
    fn async_gspar_reduces_loss_all_schemes() {
        let ds = gen_svm(512, 64, 0.01, 0.9, 9);
        for scheme in [UpdateScheme::Lock, UpdateScheme::Atomic, UpdateScheme::Wild] {
            let engine = AsyncSvmEngine::new(tiny_cfg(Method::GSpar, scheme, 4));
            let report = engine.run(&ds);
            let start_loss = 1.0; // f(0) for hinge = mean max(1-0,0) = 1
            assert!(
                report.final_loss < start_loss,
                "{scheme}: {start_loss} -> {}",
                report.final_loss
            );
            assert!(report.updates > 0);
        }
    }

    #[test]
    fn sparsified_touches_fewer_coordinates() {
        let ds = gen_svm(512, 64, 0.01, 0.9, 9);
        let dense = AsyncSvmEngine::new(tiny_cfg(Method::Dense, UpdateScheme::Atomic, 2)).run(&ds);
        let gspar = AsyncSvmEngine::new(tiny_cfg(Method::GSpar, UpdateScheme::Atomic, 2)).run(&ds);
        assert!(
            (gspar.updates as f64) < 0.6 * dense.updates as f64,
            "gspar updates {} vs dense {}",
            gspar.updates,
            dense.updates
        );
    }

    #[test]
    fn monitor_produces_a_curve() {
        let ds = gen_svm(512, 64, 0.01, 0.9, 10);
        let report = AsyncSvmEngine::new(tiny_cfg(Method::GSpar, UpdateScheme::Atomic, 2)).run(&ds);
        assert!(!report.curve.points.is_empty());
        assert!(report.wall_ms > 0.0);
        // Points are time-ordered.
        for w in report.curve.points.windows(2) {
            assert!(w[0].wall_ms <= w[1].wall_ms + 1e-9);
        }
    }
}
