//! Threaded data-parallel cluster for multi-layer (HLO-backed) models.
//!
//! The PJRT CPU client is `Rc`-based (not `Send`), so *model execution* for
//! all M simulated workers happens on the leader thread, one worker at a
//! time — on this 1-core testbed that is also the fastest layout. The
//! *communication path* is real concurrency: each worker's per-layer
//! gradients are sparsified + encoded on a scoped worker thread (the
//! compressors and RNG streams are per-worker state, exactly as on a real
//! cluster), the framed bytes cross the worker's [`crate::transport`] link,
//! and the leader receives, decodes and averages **in worker-id order** —
//! deterministic float accumulation, and the links' byte counters give the
//! ledger its measured column.
//!
//! §5.2 semantics: "the sparsification is done independently over each
//! layer" — every layer has its own probability vector, its own λ, and its
//! own message.

use crate::coding::WireCodec;
use crate::comm::NetworkModel;
use crate::metrics::{CommLedger, SparsityMeter, VarianceRatio};
use crate::rngkit::{RandArray, Xoshiro256pp};
use crate::sparsify::{Compressed, Compressor};
use crate::transport::frame::{self, GradHeader, MsgView};
use crate::transport::{Connection, Hello, InProcTransport, Transport};

/// Averaged update for one layer plus round statistics.
#[derive(Debug, Clone)]
pub struct LayerUpdate {
    pub grad: Vec<f32>,
    pub upload_bytes: u64,
    pub ideal_bits: u64,
}

/// Per-worker, per-layer communication state. `msgs[l]` is the reused
/// compression buffer for layer `l` — `compress_into` fills it in place
/// every round — and the byte buffers (`wire`, `frame_buf`, …) are reused
/// too, so a worker's steady-state round only allocates inside the
/// transport (one owned frame per message crossing the link).
struct WorkerComm {
    compressors: Vec<Box<dyn Compressor>>,
    msgs: Vec<Compressed>,
    rand: RandArray,
    conn: Box<dyn Connection>,
    wire: Vec<u8>,
    frame_buf: Vec<u8>,
    dense_tx: Vec<f32>,
    dense_bytes: Vec<u8>,
}

/// The synchronous cluster communication fabric.
pub struct Cluster {
    pub workers: usize,
    pub layers: Vec<usize>,
    comm: Vec<Option<WorkerComm>>,
    /// Leader-side ends of the per-worker transport links, by worker id.
    leader_links: Vec<Box<dyn Connection>>,
    /// Negotiated wire codec for every per-layer sparse message.
    pub codec: WireCodec,
    pub net: NetworkModel,
    pub var_meter: VarianceRatio,
    pub spa_meter: SparsityMeter,
    pub ledger: CommLedger,
    pub sim_time_s: f64,
}

impl Cluster {
    /// `layer_dims[l]` = flat size of layer `l`; one compressor per
    /// (worker, layer), built by `make_compressor` (e.g. GSpar at ρ).
    /// Messages travel under [`WireCodec::Raw`]; see [`Cluster::with_codec`].
    pub fn new<F>(workers: usize, layer_dims: &[usize], seed: u64, make_compressor: F) -> Self
    where
        F: FnMut() -> Box<dyn Compressor>,
    {
        Self::with_codec(workers, layer_dims, seed, WireCodec::Raw, make_compressor)
    }

    /// [`Cluster::new`] with an explicit wire codec, negotiated into every
    /// worker's handshake.
    pub fn with_codec<F>(
        workers: usize,
        layer_dims: &[usize],
        seed: u64,
        codec: WireCodec,
        mut make_compressor: F,
    ) -> Self
    where
        F: FnMut() -> Box<dyn Compressor>,
    {
        let transport = InProcTransport::new();
        let mut listener = transport.listen("cluster").expect("in-process listen");
        let comm: Vec<Option<WorkerComm>> = (0..workers)
            .map(|w| {
                Some(WorkerComm {
                    compressors: layer_dims.iter().map(|_| make_compressor()).collect(),
                    msgs: layer_dims
                        .iter()
                        .map(|&dim| Compressed::Sparse(crate::sparsify::SparseGrad::empty(dim)))
                        .collect(),
                    rand: RandArray::new(
                        Xoshiro256pp::for_worker(seed ^ 0xC10C, w),
                        layer_dims.iter().sum::<usize>().max(1 << 12) * 2,
                    ),
                    conn: transport
                        .connect("cluster", &Hello::with_codec(w as u32, codec))
                        .expect("in-process connect"),
                    wire: Vec::new(),
                    frame_buf: Vec::new(),
                    dense_tx: Vec::new(),
                    dense_bytes: Vec::new(),
                })
            })
            .collect();
        let leader_links: Vec<Box<dyn Connection>> =
            crate::transport::accept_n(listener.as_mut(), workers, codec)
                .expect("in-process accept");
        Self {
            workers,
            layers: layer_dims.to_vec(),
            comm,
            leader_links,
            codec,
            net: NetworkModel::commodity_1g(),
            var_meter: VarianceRatio::default(),
            spa_meter: SparsityMeter::default(),
            ledger: CommLedger::default(),
            sim_time_s: 0.0,
        }
    }

    /// One synchronization round. `grads[w][l]` is worker `w`'s gradient for
    /// layer `l`. Sparsification + encoding + sending run on one scoped
    /// thread per worker; the leader receives from each link in worker-id
    /// order, decodes and averages. Returns per-layer updates.
    pub fn round(&mut self, grads: &[Vec<Vec<f32>>]) -> Vec<LayerUpdate> {
        assert_eq!(grads.len(), self.workers);
        let layers = self.layers.clone();

        // Move each worker's comm state into its thread; all workers encode
        // and send concurrently, then the states come back via the joins.
        // (The link buffers the frames, so workers never block on the
        // leader.)
        let states: Vec<WorkerComm> = self
            .comm
            .iter_mut()
            .map(|s| s.take().expect("worker state present"))
            .collect();
        let codec = self.codec;
        let returned: Vec<WorkerComm> = std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(self.workers);
            for (w, mut st) in states.into_iter().enumerate() {
                let worker_grads = &grads[w];
                handles.push(scope.spawn(move || {
                    for (l, g) in worker_grads.iter().enumerate() {
                        let g_norm = crate::tensor::norm2_sq(g) as f64;
                        let stats =
                            st.compressors[l].compress_into(g, &mut st.rand, &mut st.msgs[l]);
                        let msg = &st.msgs[l];
                        let (kind, q_norm): (u8, f64) = match msg {
                            Compressed::Sparse(sg) => {
                                crate::coding::encode_with(sg, codec, &mut st.wire);
                                (0, msg.norm2_sq())
                            }
                            other => {
                                // Non-sparse messages travel as their
                                // decoded dense form (their wire-ledger
                                // entry stays the idealized size).
                                other.dense_le_bytes_into(
                                    &mut st.dense_tx,
                                    &mut st.dense_bytes,
                                );
                                (1, msg.norm2_sq())
                            }
                        };
                        let header = GradHeader {
                            based_on: l as u64,
                            g_norm_sq: g_norm,
                            q_norm_sq: q_norm,
                            expected_nnz: stats.expected_nnz,
                            ideal_bits: stats.ideal_bits,
                            kind,
                        };
                        let payload: &[u8] =
                            if kind == 0 { &st.wire } else { &st.dense_bytes };
                        frame::encode_grad(&mut st.frame_buf, &header, payload);
                        st.conn.send(&st.frame_buf).expect("leader link alive");
                    }
                    st
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("worker thread"))
                .collect()
        });
        for (slot, st) in self.comm.iter_mut().zip(returned) {
            *slot = Some(st);
        }

        // Leader: receive in worker-id order, decode + average.
        let mut updates: Vec<LayerUpdate> = layers
            .iter()
            .map(|&dim| LayerUpdate {
                grad: vec![0.0; dim],
                upload_bytes: 0,
                ideal_bits: 0,
            })
            .collect();
        let inv_m = 1.0 / self.workers as f32;
        let mut per_worker_bytes = vec![0u64; self.workers];
        let mut decode_slot = crate::sparsify::SparseGrad::empty(0);
        let mut rx_frame: Vec<u8> = Vec::new();
        for (w, link) in self.leader_links.iter_mut().enumerate() {
            for (l, upd) in updates.iter_mut().enumerate() {
                link.recv(&mut rx_frame).expect("worker frame");
                let (header, payload) = match frame::decode(&rx_frame).expect("self-encoded") {
                    MsgView::Grad { header, payload } => (header, payload),
                    other => panic!("unexpected message from worker: {other:?}"),
                };
                let upload = if header.kind == 0 {
                    crate::coding::decode_into(payload, &mut decode_slot)
                        .expect("self-encoded");
                    decode_slot.add_into(inv_m, &mut upd.grad);
                    payload.len() as u64
                } else {
                    frame::add_dense_le(payload, inv_m, &mut upd.grad);
                    (header.ideal_bits / 8).max(1)
                };
                upd.upload_bytes += upload;
                upd.ideal_bits += header.ideal_bits;
                per_worker_bytes[w] += upload;
                self.var_meter.record(header.q_norm_sq, header.g_norm_sq);
                self.spa_meter.record(header.expected_nnz, layers[l].max(1));
                let msg_codec = if header.kind == 0 { codec } else { WireCodec::Raw };
                self.ledger.record_codec(header.ideal_bits, upload, msg_codec);
            }
        }
        let broadcast: u64 = layers.iter().map(|&dim| (dim * 4) as u64).sum();
        self.sim_time_s += self.net.round_time_s(&per_worker_bytes, broadcast);
        // Counters are cumulative across rounds; overwrite the measured
        // column with their current totals.
        let measured = self
            .leader_links
            .iter()
            .map(|c| c.counters().bytes_total())
            .sum();
        self.ledger.set_measured(measured);
        updates
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;
    use crate::sparsify;

    fn grads_for(workers: usize, dims: &[usize], seed: u64) -> Vec<Vec<Vec<f32>>> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        (0..workers)
            .map(|_| {
                dims.iter()
                    .map(|&d| (0..d).map(|_| (rng.next_gaussian() * 0.1) as f32).collect())
                    .collect()
            })
            .collect()
    }

    #[test]
    fn dense_round_is_exact_mean() {
        let dims = [32usize, 64];
        let grads = grads_for(3, &dims, 50);
        let mut cluster = Cluster::new(3, &dims, 51, || {
            sparsify::build(Method::Dense, 1.0, 0.0, 4)
        });
        let updates = cluster.round(&grads);
        for (l, upd) in updates.iter().enumerate() {
            for i in 0..dims[l] {
                let expect: f32 = (0..3).map(|w| grads[w][l][i]).sum::<f32>() / 3.0;
                assert!((upd.grad[i] - expect).abs() < 1e-6, "layer {l} coord {i}");
            }
        }
        assert!(cluster.ledger.wire_bytes > 0);
        assert!(cluster.ledger.measured_bytes > 0);
    }

    #[test]
    fn gspar_round_is_unbiased_in_expectation() {
        // Average many rounds of the same gradients: mean → true mean.
        let dims = [128usize];
        let grads = grads_for(2, &dims, 52);
        let mut cluster = Cluster::new(2, &dims, 53, || {
            sparsify::build(Method::GSpar, 0.3, 0.0, 4)
        });
        let rounds = 3000;
        let mut acc = vec![0.0f64; 128];
        for _ in 0..rounds {
            let upd = cluster.round(&grads);
            for (a, &v) in acc.iter_mut().zip(&upd[0].grad) {
                *a += v as f64 / rounds as f64;
            }
        }
        for i in 0..128 {
            let expect = (grads[0][0][i] as f64 + grads[1][0][i] as f64) / 2.0;
            // Tolerance accounts for RandArray cyclic reuse correlating
            // rounds (the estimator is unbiased but not i.i.d. across
            // rounds).
            // Small-|g| coordinates carry the shared ±1/λ magnitude when
            // sampled, so their MC noise floor is absolute, not relative.
            let tol = (0.15 * expect.abs()).max(0.02);
            assert!(
                (acc[i] - expect).abs() < tol,
                "coord {i}: {} vs {expect}",
                acc[i]
            );
        }
        assert!(cluster.var_meter.value() > 1.0);
        assert!(cluster.spa_meter.value() < 0.5);
    }

    #[test]
    fn entropy_codec_same_updates_fewer_bytes() {
        let dims = [512usize, 128];
        let grads = grads_for(2, &dims, 58);
        let run = |codec| {
            let mut cluster = Cluster::with_codec(2, &dims, 59, codec, || {
                sparsify::build(Method::GSpar, 0.1, 0.0, 4)
            });
            let upd = cluster.round(&grads);
            (upd, cluster.ledger.clone())
        };
        let (raw_upd, raw_ledger) = run(WireCodec::Raw);
        let (ent_upd, ent_ledger) = run(WireCodec::Entropy);
        // Identical decoded per-layer updates, strictly fewer bytes.
        for (a, b) in raw_upd.iter().zip(&ent_upd) {
            assert_eq!(a.grad, b.grad);
        }
        assert!(
            ent_ledger.wire_bytes < raw_ledger.wire_bytes,
            "entropy {} !< raw {}",
            ent_ledger.wire_bytes,
            raw_ledger.wire_bytes
        );
        assert!(ent_ledger.measured_bytes < raw_ledger.measured_bytes);
        assert_eq!(
            ent_ledger.wire_bytes_by_codec[WireCodec::Entropy.index()],
            ent_ledger.wire_bytes
        );
    }

    #[test]
    fn per_layer_independence() {
        // A zero layer must stay zero and cost (almost) nothing.
        let dims = [16usize, 16];
        let mut grads = grads_for(2, &dims, 54);
        for w in 0..2 {
            grads[w][1].fill(0.0);
        }
        let mut cluster = Cluster::new(2, &dims, 55, || {
            sparsify::build(Method::GSpar, 0.5, 0.0, 4)
        });
        let upd = cluster.round(&grads);
        assert!(upd[1].grad.iter().all(|&v| v == 0.0));
        assert!(upd[0].upload_bytes >= upd[1].upload_bytes);
    }

    #[test]
    fn rounds_are_deterministic_and_measured_bytes_grow() {
        let dims = [64usize, 32];
        let grads = grads_for(2, &dims, 56);
        let run = || {
            let mut cluster = Cluster::new(2, &dims, 57, || {
                sparsify::build(Method::GSpar, 0.4, 0.0, 4)
            });
            let a = cluster.round(&grads);
            let m1 = cluster.ledger.measured_bytes;
            let b = cluster.round(&grads);
            let m2 = cluster.ledger.measured_bytes;
            assert!(m2 > m1, "measured column must accumulate across rounds");
            (a, b, m2)
        };
        let (a1, b1, m1) = run();
        let (a2, b2, m2) = run();
        for (x, y) in a1.iter().zip(&a2).chain(b1.iter().zip(&b2)) {
            assert_eq!(x.grad, y.grad, "leader aggregation must be deterministic");
        }
        assert_eq!(m1, m2);
    }
}
