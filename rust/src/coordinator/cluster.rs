//! Threaded data-parallel cluster for multi-layer (HLO-backed) models.
//!
//! The PJRT CPU client is `Rc`-based (not `Send`), so *model execution* for
//! all M simulated workers happens on the leader thread, one worker at a
//! time — on this 1-core testbed that is also the fastest layout. The
//! *communication path* is real concurrency: each worker's per-layer
//! gradients are sparsified + encoded on a scoped worker thread (the
//! compressors and RNG streams are per-worker state, exactly as on a real
//! cluster), the framed bytes cross the worker's [`crate::transport`] link,
//! and the leader receives, decodes and averages **in worker-id order** —
//! deterministic float accumulation, and the links' byte counters give the
//! ledger its measured column.
//!
//! §5.2 semantics: "the sparsification is done independently over each
//! layer" — every layer has its own probability vector, its own λ, and its
//! own message.
//!
//! ## Batched rounds
//!
//! A cluster built from a [`Session`] with
//! [`batch_layers`](crate::api::SessionBuilder::batch_layers) compresses a
//! worker's whole layer list in **one** engine invocation
//! ([`Compressor::compress_batch_into`]) and ships it as **one**
//! `WireBatch` transport frame per round — per-layer math (own λ, own
//! probability vector) with none of the per-layer fixed costs. The decoded
//! per-layer updates are bitwise identical to the per-layer path (pinned
//! by tests), while each round ships fewer frames and fewer header bytes.
//! Peers whose handshake announced transport version 2 — and methods that
//! cannot batch (see [`crate::api::MethodSpec::batchable`]) — fall back to
//! per-layer frames transparently.
//!
//! Meter granularity differs between the two flavors: the batch frame
//! carries layer-*summed* statistics, so `var`/`spa` record one pooled
//! sample per worker per round (a size-weighted density) where the
//! per-layer path records one sample per layer (an unweighted mean), and
//! [`LayerUpdate::ideal_bits`] switches from compressor expectations to
//! the exact per-message bit model. The decoded updates — the training
//! math — are identical either way.
//!
//! ## Pipelined rounds
//!
//! A session with [`pipeline`](crate::api::SessionBuilder::pipeline) ≥ 2
//! switches the batched send path to the streaming flavor: the
//! [`crate::coding::BatchStreamEncoder`] sizes the whole `WireBatch` up
//! front (header and per-layer sub-headers are fixed before any payload
//! byte exists), each layer is encoded into its own reused segment
//! buffer, and the frame leaves through one vectored gather write —
//! `GRAD_BATCH` header prefix + batch header + per-layer segments — with
//! no concatenation copy into a frame buffer. Depth 1 (the default)
//! keeps the historical encode-then-send reference path. The bytes on
//! every link are identical at either depth (pinned by tests and by the
//! shared plan/write implementation in `coding::batch`), so pipelined
//! senders interoperate with any batch-capable peer.
//!
//! ## Ring rounds
//!
//! A session with [`topology`](crate::api::SessionBuilder::topology) set to
//! [`Topology::Ring`] (and a sparse-message method — anything with a
//! [`density`](crate::api::MethodSpec::density)) replaces the star gather
//! with a worker-side collective: each worker flattens its per-layer
//! messages into one concatenated sparse vector
//! ([`merge::flatten_concat`]) and the workers ring-reduce it among
//! themselves ([`collective::RingReducer`]), re-injecting whatever mass
//! earlier per-hop budgets dropped (standard error feedback around the
//! collective). Only rank 0 forwards the — every-rank-identical — reduced
//! sum to the leader, which scatters it back into per-layer updates
//! ([`merge::scatter_concat`]). The ledger's hop column records the ring
//! links' transmitted bytes (this coordinator owns both sides, unlike the
//! dist server) and the end-to-end column what a consumer of the reduced
//! gradient pays. Star clusters ship zero ring frames and leave both
//! columns at 0; non-sparse methods and single-worker sessions silently
//! keep the star schedule.

use crate::api::Session;
use crate::coding::WireCodec;
use crate::collective::{self, RingPeer, RingReducer};
use crate::comm::{merge, NetworkModel, Topology};
use crate::feedback::{CommSchedule, FeedbackConfig, FeedbackState};
use crate::metrics::{CommLedger, SparsityMeter, VarianceRatio};
use crate::rngkit::{RandArray, Xoshiro256pp};
use crate::sparsify::{Compressed, CompressStats, Compressor, SparseGrad};
use crate::transport::frame::{self, GradHeader, MsgView};
use crate::transport::{
    Connection, Hello, InProcTransport, LinkCounters, Transport, TRANSPORT_VERSION,
};

/// Averaged update for one layer plus round statistics.
#[derive(Debug, Clone)]
pub struct LayerUpdate {
    pub grad: Vec<f32>,
    /// Wire bytes this layer's messages cost (in batched rounds: the
    /// layer's sub-message share of the batch).
    pub upload_bytes: u64,
    /// Idealized bits (per-layer compressor stats in per-layer rounds; the
    /// exact per-message bit model of the decoded messages in batched
    /// rounds, where the frame carries only layer-summed stats).
    pub ideal_bits: u64,
}

/// Per-worker communication state. `msgs[l]` is the reused compression
/// buffer for layer `l` — both round flavors fill it in place — and the
/// byte buffers (`wire`, `frame_buf`, …) are reused too, so a worker's
/// steady-state round only allocates inside the transport (one owned frame
/// per message crossing the link) plus, in batched rounds, a few L-sized
/// reference lists (pointers per *layer*, never per coordinate). In
/// batched mode `compressors` holds a single instance driving the whole
/// layer list; otherwise one per layer.
struct WorkerComm {
    compressors: Vec<Box<dyn Compressor>>,
    msgs: Vec<Compressed>,
    stats_buf: Vec<CompressStats>,
    rand: RandArray,
    conn: Box<dyn Connection>,
    wire: Vec<u8>,
    frame_buf: Vec<u8>,
    dense_tx: Vec<f32>,
    dense_bytes: Vec<u8>,
    /// Per-layer segment buffers for the pipelined (vectored) send path;
    /// empty and unused at depth 1.
    seg_bufs: Vec<Vec<u8>>,
    /// Ring-collective machinery; `None` under the star schedule.
    ring: Option<WorkerRing>,
}

/// One worker's half of the ring collective: its two peer links, the
/// reusable reducer scratch, the error-feedback residual that per-hop
/// budget drops fold into, and the flattened-message buffers.
struct WorkerRing {
    peer: RingPeer,
    reducer: RingReducer,
    fb: FeedbackState,
    /// `Some` switches the reduction to the shared-sketch, index-free mode.
    aligned: Option<collective::AlignedConfig>,
    res_sg: SparseGrad,
    flat: SparseGrad,
    flat_in: SparseGrad,
    reduced: SparseGrad,
}

/// Topology request handed to [`Cluster::build`]: the ring engages only
/// when the topology asks for it, the method ships sparse messages
/// (`density` is `Some`), and there are at least two workers — anything
/// else silently keeps the star schedule, so environment-driven topology
/// legs never break dense/quantized runs.
struct RingSpec {
    topology: Topology,
    aligned: bool,
    density: Option<f32>,
    feedback: FeedbackConfig,
}

impl RingSpec {
    fn star() -> Self {
        Self {
            topology: Topology::Star,
            aligned: false,
            density: None,
            feedback: FeedbackConfig::default(),
        }
    }
}

/// The synchronous cluster communication fabric.
pub struct Cluster {
    pub workers: usize,
    pub layers: Vec<usize>,
    comm: Vec<Option<WorkerComm>>,
    /// Leader-side ends of the per-worker transport links, by worker id.
    leader_links: Vec<Box<dyn Connection>>,
    /// Whether this cluster compresses + ships whole layer lists.
    batch: bool,
    /// Per-link negotiated capability: did worker `w`'s hello announce a
    /// batch-capable transport version?
    peer_batch: Vec<bool>,
    /// Local-step schedule: rounds between synchronizations accumulate
    /// worker gradients locally and ship nothing.
    schedule: CommSchedule,
    /// Pipeline depth: ≥ 2 streams batched frames as vectored segments
    /// (see the module doc); 1 is the sequential reference path.
    pipeline: usize,
    /// 1-based count of [`Cluster::round`] calls (drives the schedule).
    rounds_seen: u64,
    /// `rounds_seen` at the last synchronization (tracks whether a partial
    /// block is pending for [`Cluster::flush`]).
    last_comm: u64,
    /// `acc[w][l]`: worker `w`'s gradient sum for layer `l` since the last
    /// synchronization (allocated lazily, only under local-step schedules).
    acc: Vec<Vec<Vec<f32>>>,
    /// Whether rounds reduce over the worker ring instead of the star
    /// gather (topology Ring ∧ sparse-message method ∧ ≥ 2 workers).
    ring: bool,
    /// Counter handles for each worker's outgoing (right) ring link — the
    /// hop-bytes column sums these. Empty under star.
    ring_tx: Vec<LinkCounters>,
    /// Negotiated wire codec for every sparse message.
    pub codec: WireCodec,
    pub net: NetworkModel,
    pub var_meter: VarianceRatio,
    pub spa_meter: SparsityMeter,
    pub ledger: CommLedger,
    pub sim_time_s: f64,
    /// Trace recorder (None under [`crate::trace::TraceConfig::Off`]). The
    /// per-worker [`crate::trace::ThreadHandle`]s are pre-allocated so the
    /// round-scoped comm threads re-register without allocating.
    recorder: Option<crate::trace::Recorder>,
    trace_handles: Vec<crate::trace::ThreadHandle>,
    leader_handle: Option<crate::trace::ThreadHandle>,
    trace_cfg: crate::trace::TraceConfig,
}

impl Cluster {
    /// `layer_dims[l]` = flat size of layer `l`; one compressor per
    /// (worker, layer), built by `make_compressor` (e.g. GSpar at ρ).
    /// Messages travel under [`WireCodec::Raw`].
    #[deprecated(
        since = "0.2.0",
        note = "build a gsparse::api::Session and call Session::cluster"
    )]
    pub fn new<F>(workers: usize, layer_dims: &[usize], seed: u64, make_compressor: F) -> Self
    where
        F: FnMut() -> Box<dyn Compressor>,
    {
        Self::build(
            workers,
            layer_dims,
            seed,
            WireCodec::Raw,
            TRANSPORT_VERSION,
            false,
            CommSchedule::every_round(),
            1,
            crate::trace::TraceConfig::from_env(),
            RingSpec::star(),
            make_compressor,
        )
    }

    /// `new` with an explicit wire codec, negotiated into every worker's
    /// handshake.
    #[deprecated(
        since = "0.2.0",
        note = "build a gsparse::api::Session (with .codec(..)) and call Session::cluster"
    )]
    pub fn with_codec<F>(
        workers: usize,
        layer_dims: &[usize],
        seed: u64,
        codec: WireCodec,
        make_compressor: F,
    ) -> Self
    where
        F: FnMut() -> Box<dyn Compressor>,
    {
        Self::build(
            workers,
            layer_dims,
            seed,
            codec,
            TRANSPORT_VERSION,
            false,
            CommSchedule::every_round(),
            1,
            crate::trace::TraceConfig::from_env(),
            RingSpec::star(),
            make_compressor,
        )
    }

    /// The session-owned constructor behind [`Session::cluster`]: method,
    /// codec, seed, worker count, network model, transport version, layer
    /// batching, error feedback, and local-step schedule all come from the
    /// session.
    pub fn for_session(session: &Session, layer_dims: &[usize]) -> Self {
        let batch = session.batch_layers() && session.method().batchable();
        let mut cluster = Self::build(
            session.workers(),
            layer_dims,
            session.seed(),
            session.codec(),
            session.transport_version(),
            batch,
            session.comm_schedule(),
            session.pipeline(),
            session.trace(),
            RingSpec {
                topology: session.topology(),
                aligned: session.aligned(),
                density: session.method().density(),
                feedback: session.feedback().unwrap_or_default(),
            },
            || session.compressor(),
        );
        cluster.net = session.net();
        if cluster.ring {
            cluster.net.topology = Topology::Ring;
        }
        cluster
    }

    #[allow(clippy::too_many_arguments)]
    fn build<F>(
        workers: usize,
        layer_dims: &[usize],
        seed: u64,
        codec: WireCodec,
        hello_version: u8,
        batch: bool,
        schedule: CommSchedule,
        pipeline: usize,
        trace_cfg: crate::trace::TraceConfig,
        ring_spec: RingSpec,
        mut make_compressor: F,
    ) -> Self
    where
        F: FnMut() -> Box<dyn Compressor>,
    {
        let recorder = crate::trace::Recorder::new(&trace_cfg);
        let trace_handles: Vec<crate::trace::ThreadHandle> = recorder
            .as_ref()
            .map(|r| (0..workers).map(|w| r.thread_handle(w as u16)).collect())
            .unwrap_or_default();
        let leader_handle = recorder
            .as_ref()
            .map(|r| r.thread_handle(crate::trace::SERVER_WORKER));
        let ring_on = ring_spec.topology == Topology::Ring
            && ring_spec.density.is_some()
            && workers > 1;
        let transport = InProcTransport::new();
        let mut listener = transport.listen("cluster").expect("in-process listen");
        // The ring links are ordinary transport connections on this
        // cluster's private in-process registry (one registry per
        // `InProcTransport` instance, so the static names cannot collide
        // across clusters).
        let total_d: usize = layer_dims.iter().sum();
        let mut ring_peers: Vec<Option<RingPeer>> = if ring_on {
            let names: Vec<String> = (0..workers).map(|r| format!("cluster-ring-{r}")).collect();
            collective::form_ring_local(&transport, workers, codec, &names)
                .expect("in-process ring")
                .into_iter()
                .map(Some)
                .collect()
        } else {
            (0..workers).map(|_| None).collect()
        };
        let ring_tx: Vec<LinkCounters> = ring_peers
            .iter()
            .flatten()
            .map(|p| p.right_counters())
            .collect();
        let comm: Vec<Option<WorkerComm>> = (0..workers)
            .map(|w| {
                // Batched mode drives the whole layer list through one
                // compressor (batchable methods are stateless across
                // layers); per-layer mode keeps one per layer.
                let n_comp = if batch { 1 } else { layer_dims.len() };
                Some(WorkerComm {
                    compressors: (0..n_comp).map(|_| make_compressor()).collect(),
                    msgs: layer_dims
                        .iter()
                        .map(|&dim| Compressed::Sparse(SparseGrad::empty(dim)))
                        .collect(),
                    stats_buf: Vec::new(),
                    rand: RandArray::new(
                        Xoshiro256pp::for_worker(seed ^ 0xC10C, w),
                        layer_dims.iter().sum::<usize>().max(1 << 12) * 2,
                    ),
                    conn: transport
                        .connect(
                            "cluster",
                            &Hello::with_version(w as u32, codec, hello_version),
                        )
                        .expect("in-process connect"),
                    wire: Vec::new(),
                    frame_buf: Vec::new(),
                    dense_tx: Vec::new(),
                    dense_bytes: Vec::new(),
                    seg_bufs: Vec::new(),
                    ring: ring_peers[w].take().map(|peer| {
                        let rho = ring_spec.density.expect("ring implies density");
                        let budget =
                            collective::default_budget(rho, total_d as u32, workers);
                        WorkerRing {
                            peer,
                            reducer: RingReducer::new(codec, Some(budget)),
                            fb: FeedbackState::new(ring_spec.feedback),
                            aligned: ring_spec
                                .aligned
                                .then(|| collective::aligned_for(rho, total_d as u32, seed)),
                            res_sg: SparseGrad::empty(0),
                            flat: SparseGrad::empty(0),
                            flat_in: SparseGrad::empty(0),
                            reduced: SparseGrad::empty(0),
                        }
                    }),
                })
            })
            .collect();
        let accepted = crate::transport::accept_n_hello(listener.as_mut(), workers, codec)
            .expect("in-process accept");
        let mut leader_links = Vec::with_capacity(workers);
        let mut peer_batch = Vec::with_capacity(workers);
        for (conn, hello) in accepted {
            peer_batch.push(hello.supports_batch());
            leader_links.push(conn);
        }
        Self {
            workers,
            layers: layer_dims.to_vec(),
            comm,
            leader_links,
            batch,
            peer_batch,
            schedule,
            pipeline: pipeline.max(1),
            rounds_seen: 0,
            last_comm: 0,
            acc: Vec::new(),
            ring: ring_on,
            ring_tx,
            codec,
            net: {
                let mut net = NetworkModel::commodity_1g();
                if ring_on {
                    net.topology = Topology::Ring;
                }
                net
            },
            var_meter: VarianceRatio::default(),
            spa_meter: SparsityMeter::default(),
            ledger: CommLedger::default(),
            sim_time_s: 0.0,
            recorder,
            trace_handles,
            leader_handle,
            trace_cfg,
        }
    }

    /// The local-step schedule this cluster runs under.
    pub fn comm_schedule(&self) -> CommSchedule {
        self.schedule
    }

    /// Whether worker `w`'s messages travel as one `WireBatch` frame.
    fn batched_link(&self, w: usize) -> bool {
        self.batch && self.peer_batch[w]
    }

    /// One training round. `grads[w][l]` is worker `w`'s gradient for
    /// layer `l`.
    ///
    /// Under the default every-round schedule this synchronizes
    /// immediately. Under a local-step schedule
    /// ([`crate::api::SessionBuilder::local_steps`]) non-communication
    /// rounds accumulate each worker's gradients locally and return
    /// all-zero updates **without touching any link** — zero frames, zero
    /// bytes, provable from [`Cluster::frames_received`] and the ledger's
    /// measured columns — while every `H`-th round ships the accumulated
    /// sums through the normal compression + transport path.
    pub fn round(&mut self, grads: &[Vec<Vec<f32>>]) -> Vec<LayerUpdate> {
        assert_eq!(grads.len(), self.workers);
        self.rounds_seen += 1;
        if self.schedule.period() == 1 {
            return self.comm_round(grads);
        }
        if self.acc.is_empty() {
            self.acc = (0..self.workers)
                .map(|_| self.layers.iter().map(|&dim| vec![0.0; dim]).collect())
                .collect();
        }
        for (aw, gw) in self.acc.iter_mut().zip(grads) {
            for (al, gl) in aw.iter_mut().zip(gw) {
                crate::tensor::axpy(1.0, gl, al);
            }
        }
        if !self.schedule.is_comm_round(self.rounds_seen) {
            // Local round: nothing crosses any link. (The zero updates are
            // freshly allocated because the caller takes ownership; at one
            // O(d) allocation it is the same order as the accumulation
            // pass above — acceptable for the simulation-side path.)
            return self
                .layers
                .iter()
                .map(|&dim| LayerUpdate {
                    grad: vec![0.0; dim],
                    upload_bytes: 0,
                    ideal_bits: 0,
                })
                .collect();
        }
        self.synchronize_acc()
    }

    /// Flush a pending partial local-step block: if any rounds accumulated
    /// since the last synchronization, ship them now (one normal comm
    /// round) and return the updates. The cluster is round-driven and has
    /// no horizon of its own, so drivers that stop between scheduled
    /// synchronization points call this at the end of training — the
    /// analogue of the final-round flush the sync/dist coordinators do —
    /// or the tail gradients would be dropped. No-op (`None`) under the
    /// every-round schedule or when nothing is pending.
    pub fn flush(&mut self) -> Option<Vec<LayerUpdate>> {
        if self.schedule.period() == 1 || self.rounds_seen == self.last_comm {
            return None;
        }
        Some(self.synchronize_acc())
    }

    /// Ship the accumulated sums through one comm round and reset them.
    fn synchronize_acc(&mut self) -> Vec<LayerUpdate> {
        self.last_comm = self.rounds_seen;
        let acc = std::mem::take(&mut self.acc);
        let updates = self.comm_round(&acc);
        self.acc = acc;
        for aw in self.acc.iter_mut() {
            for al in aw.iter_mut() {
                al.fill(0.0);
            }
        }
        updates
    }

    /// One synchronization round over `grads` (the accumulated sums under
    /// a local-step schedule). Sparsification + encoding + sending run on
    /// one scoped thread per worker; the leader receives from each link in
    /// worker-id order, decodes and averages. Returns per-layer updates.
    fn comm_round(&mut self, grads: &[Vec<Vec<f32>>]) -> Vec<LayerUpdate> {
        let layers = self.layers.clone();
        let use_batch: Vec<bool> = (0..self.workers).map(|w| self.batched_link(w)).collect();
        let round_idx = self.rounds_seen as u32;
        let _leader_guard = crate::trace::install_handle_opt(self.leader_handle.as_ref());
        crate::trace::set_round(round_idx);
        let _round_span = crate::trace::span(crate::trace::Stage::Round);

        // Move each worker's comm state into its thread; all workers encode
        // and send concurrently, then the states come back via the joins.
        // (The link buffers the frames, so workers never block on the
        // leader.)
        let states: Vec<WorkerComm> = self
            .comm
            .iter_mut()
            .map(|s| s.take().expect("worker state present"))
            .collect();
        let codec = self.codec;
        let pipelined = self.pipeline >= 2;
        let returned: Vec<WorkerComm> = std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(self.workers);
            for (w, mut st) in states.into_iter().enumerate() {
                let worker_grads = &grads[w];
                let batched = use_batch[w];
                let trace_handle = self.trace_handles.get(w).cloned();
                handles.push(scope.spawn(move || {
                    let _trace_guard =
                        crate::trace::install_handle_opt(trace_handle.as_ref());
                    crate::trace::set_round(round_idx);
                    let _push_span = crate::trace::span(crate::trace::Stage::Push);
                    if st.ring.is_some() {
                        worker_round_ring(&mut st, worker_grads, codec);
                    } else if batched {
                        worker_round_batched(&mut st, worker_grads, codec, pipelined);
                    } else {
                        worker_round_per_layer(&mut st, worker_grads, codec);
                    }
                    st
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("worker thread"))
                .collect()
        });
        for (slot, st) in self.comm.iter_mut().zip(returned) {
            *slot = Some(st);
        }

        // Leader: receive in worker-id order, decode + average.
        let mut updates: Vec<LayerUpdate> = layers
            .iter()
            .map(|&dim| LayerUpdate {
                grad: vec![0.0; dim],
                upload_bytes: 0,
                ideal_bits: 0,
            })
            .collect();
        let inv_m = 1.0 / self.workers as f32;
        let total_d: usize = layers.iter().sum();
        let mut per_worker_bytes = vec![0u64; self.workers];
        let mut decode_slot = SparseGrad::empty(0);
        let mut batch_slots: Vec<SparseGrad> = Vec::new();
        let mut sub_lens: Vec<usize> = Vec::new();
        let mut rx_frame: Vec<u8> = Vec::new();
        if self.ring {
            // The workers already reduced among themselves; rank 0 alone
            // forwarded the summed flat gradient. Scatter it back into the
            // per-layer updates at 1/M (the all-reduce mean convention).
            {
                let mut wait = crate::trace::span(crate::trace::Stage::BarrierWait);
                wait.layer(0);
                self.leader_links[0]
                    .recv(&mut rx_frame)
                    .expect("worker frame");
            }
            let mut apply_span = crate::trace::span(crate::trace::Stage::Apply);
            apply_span.bytes(rx_frame.len() as u64);
            let (header, payload) = match frame::decode(&rx_frame).expect("self-encoded") {
                MsgView::Grad { header, payload } => (header, payload),
                other => panic!("unexpected message from worker: {other:?}"),
            };
            assert_eq!(header.kind, 0, "ring pushes are sparse by construction");
            crate::coding::decode_into(payload, &mut decode_slot).expect("self-encoded");
            assert_eq!(decode_slot.d as usize, total_d, "flat dimension drifted");
            {
                let mut slices: Vec<&mut [f32]> =
                    updates.iter_mut().map(|u| u.grad.as_mut_slice()).collect();
                merge::scatter_concat(&decode_slot, inv_m, &mut slices);
            }
            // Apportion the one payload's bytes (and the header's idealized
            // bits) over the layers by their share of the reduced entries —
            // a layer with no survivors costs nothing, preserving the
            // per-layer independence the star path reports.
            let mut layer_nnz = vec![0u64; layers.len()];
            if !layers.is_empty() {
                let mut layer = 0usize;
                let mut hi = layers[0];
                for (i, _v) in merge::Entries::new(&decode_slot) {
                    let i = i as usize;
                    while i >= hi {
                        layer += 1;
                        hi += layers[layer];
                    }
                    layer_nnz[layer] += 1;
                }
            }
            let total_nnz: u64 = layer_nnz.iter().sum();
            if total_nnz > 0 {
                for (upd, &nnz) in updates.iter_mut().zip(&layer_nnz) {
                    upd.upload_bytes += payload.len() as u64 * nnz / total_nnz;
                    upd.ideal_bits += header.ideal_bits * nnz / total_nnz;
                }
            }
            // Every ring node carries ~the reduced payload across its
            // 2(M−1) hop phases — feed the α-β ring arm that per-node size.
            per_worker_bytes.fill(payload.len() as u64);
            self.var_meter.record(header.q_norm_sq, header.g_norm_sq);
            self.spa_meter.record(header.expected_nnz, total_d.max(1));
            self.ledger
                .record_codec(header.ideal_bits, payload.len() as u64, codec);
            // Unlike the dist server, this coordinator owns both sides of
            // every ring link, so the hop column is measured, not modeled;
            // the end-to-end column records what a consumer of the reduced
            // gradient pays.
            self.ledger
                .set_hop_bytes(self.ring_tx.iter().map(|c| c.bytes_tx()).sum());
            self.ledger.add_end_to_end_bytes(rx_frame.len() as u64);
            let broadcast: u64 = layers.iter().map(|&dim| (dim * 4) as u64).sum();
            self.sim_time_s += self.net.round_time_s(&per_worker_bytes, broadcast);
            let measured = self
                .leader_links
                .iter()
                .map(|c| c.counters().bytes_total())
                .sum();
            self.ledger.set_measured(measured);
            self.ledger.set_measured_frames(self.frames_received());
            self.ledger.verify();
            return updates;
        }
        for (w, link) in self.leader_links.iter_mut().enumerate() {
            if use_batch[w] {
                // One frame carries the whole model update.
                {
                    let mut wait = crate::trace::span(crate::trace::Stage::BarrierWait);
                    wait.layer(w as u32);
                    link.recv(&mut rx_frame).expect("worker frame");
                }
                let mut apply_span = crate::trace::span(crate::trace::Stage::Apply);
                apply_span.bytes(rx_frame.len() as u64);
                let (header, payload) = match frame::decode(&rx_frame).expect("self-encoded") {
                    MsgView::GradBatch { header, payload } => (header, payload),
                    other => panic!("unexpected message from worker: {other:?}"),
                };
                crate::coding::decode_batch_into(payload, &mut batch_slots, &mut sub_lens)
                    .expect("self-encoded");
                assert_eq!(batch_slots.len(), updates.len(), "layer count drifted");
                for ((sg, upd), sub_len) in
                    batch_slots.iter().zip(updates.iter_mut()).zip(&sub_lens)
                {
                    sg.add_into(inv_m, &mut upd.grad);
                    upd.upload_bytes += *sub_len as u64;
                    upd.ideal_bits += crate::coding::ideal_message_bits(sg);
                }
                per_worker_bytes[w] += payload.len() as u64;
                self.var_meter.record(header.q_norm_sq, header.g_norm_sq);
                self.spa_meter.record(header.expected_nnz, total_d.max(1));
                self.ledger
                    .record_codec(header.ideal_bits, payload.len() as u64, codec);
            } else {
                for (l, upd) in updates.iter_mut().enumerate() {
                    {
                        let mut wait = crate::trace::span(crate::trace::Stage::BarrierWait);
                        wait.layer(l as u32);
                        link.recv(&mut rx_frame).expect("worker frame");
                    }
                    let mut apply_span = crate::trace::span(crate::trace::Stage::Apply);
                    apply_span.bytes(rx_frame.len() as u64);
                    apply_span.layer(l as u32);
                    let (header, payload) = match frame::decode(&rx_frame).expect("self-encoded")
                    {
                        MsgView::Grad { header, payload } => (header, payload),
                        other => panic!("unexpected message from worker: {other:?}"),
                    };
                    let upload = if header.kind == 0 {
                        crate::coding::decode_into(payload, &mut decode_slot)
                            .expect("self-encoded");
                        decode_slot.add_into(inv_m, &mut upd.grad);
                        payload.len() as u64
                    } else {
                        frame::add_dense_le(payload, inv_m, &mut upd.grad);
                        (header.ideal_bits / 8).max(1)
                    };
                    upd.upload_bytes += upload;
                    upd.ideal_bits += header.ideal_bits;
                    per_worker_bytes[w] += upload;
                    self.var_meter.record(header.q_norm_sq, header.g_norm_sq);
                    self.spa_meter.record(header.expected_nnz, layers[l].max(1));
                    let msg_codec = if header.kind == 0 { codec } else { WireCodec::Raw };
                    self.ledger.record_codec(header.ideal_bits, upload, msg_codec);
                }
            }
        }
        let broadcast: u64 = layers.iter().map(|&dim| (dim * 4) as u64).sum();
        self.sim_time_s += self.net.round_time_s(&per_worker_bytes, broadcast);
        // Counters are cumulative across rounds; overwrite the measured
        // columns with their current totals.
        let measured = self
            .leader_links
            .iter()
            .map(|c| c.counters().bytes_total())
            .sum();
        self.ledger.set_measured(measured);
        self.ledger.set_measured_frames(self.frames_received());
        self.ledger.verify();
        updates
    }

    /// Aggregated trace metrics for the rounds so far: span counters and
    /// log₂ latency histograms from the recorder, plus each leader link's
    /// transport counters. `None` when the cluster runs with tracing off.
    /// Draining is destructive per call (rings restart empty), so call it
    /// once at the end of a run.
    pub fn trace_metrics(&self) -> Option<crate::trace::MetricsSnapshot> {
        self.recorder.as_ref().map(|rec| {
            let events = rec.drain();
            let mut snap = crate::trace::MetricsSnapshot::from_events(&events);
            snap.set_dropped(rec.dropped());
            for (w, link) in self.leader_links.iter().enumerate() {
                snap.fold_link_counters(&format!("link_w{w}"), &link.counters());
            }
            snap.push_gauge("sim_time_s", self.sim_time_s);
            snap
        })
    }

    /// Transport frames the leader has received so far (cumulative across
    /// rounds, including each worker's one handshake frame) — the "fewer
    /// frames per round" half of the batched-path win.
    pub fn frames_received(&self) -> u64 {
        self.leader_links
            .iter()
            .map(|c| c.counters().frames_rx())
            .sum()
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        // Run-end trace dump: the cluster is round-driven with no explicit
        // shutdown, so teardown is the merge point. Opt-in via
        // `GSPARSE_TRACE_OUT` only — plain recording leaves no files.
        if let Some(rec) = &self.recorder {
            if crate::trace::TraceConfig::dump_requested() {
                let topo = if self.ring { "ring" } else { "star" };
                let tag = crate::trace::run_tag(self.rounds_seen as usize, topo);
                let _ = crate::trace::dump(rec, &tag, "cluster", self.trace_cfg.format());
            }
        }
    }
}

/// Per-layer round: one `GRAD` frame per layer (the historical path, and
/// the fallback for v2 peers / non-batchable methods). With a single
/// shared compressor (batched cluster talking to a v2 peer) the whole
/// layer list runs through [`Compressor::compress_batch_into`] on instance
/// 0 — identical messages for the stateless batchable methods (pinned by
/// the batch-equivalence tests), and the *required* entry point for
/// error-feedback wrappers, whose per-layer residual layout lives in that
/// one instance.
fn worker_round_per_layer(st: &mut WorkerComm, worker_grads: &[Vec<f32>], codec: WireCodec) {
    if st.compressors.len() == 1 {
        let refs: Vec<&[f32]> = worker_grads.iter().map(|g| g.as_slice()).collect();
        st.compressors[0].compress_batch_into(&refs, &mut st.rand, &mut st.msgs, &mut st.stats_buf);
    } else {
        st.stats_buf.clear();
        for (l, g) in worker_grads.iter().enumerate() {
            let stats = st.compressors[l].compress_into(g, &mut st.rand, &mut st.msgs[l]);
            st.stats_buf.push(stats);
        }
    }
    for (l, g) in worker_grads.iter().enumerate() {
        let stats = st.stats_buf[l];
        let g_norm = crate::tensor::norm2_sq(g) as f64;
        let msg = &st.msgs[l];
        let (kind, q_norm): (u8, f64) = match msg {
            Compressed::Sparse(sg) => {
                crate::coding::encode_with(sg, codec, &mut st.wire);
                (0, msg.norm2_sq())
            }
            other => {
                // Non-sparse messages travel as their decoded dense form
                // (their wire-ledger entry stays the idealized size).
                other.dense_le_bytes_into(&mut st.dense_tx, &mut st.dense_bytes);
                (1, msg.norm2_sq())
            }
        };
        let header = GradHeader {
            based_on: l as u64,
            g_norm_sq: g_norm,
            q_norm_sq: q_norm,
            expected_nnz: stats.expected_nnz,
            ideal_bits: stats.ideal_bits,
            kind,
        };
        let payload: &[u8] = if kind == 0 { &st.wire } else { &st.dense_bytes };
        frame::encode_grad(&mut st.frame_buf, &header, payload);
        st.conn.send(&st.frame_buf).expect("leader link alive");
    }
}

/// Batched round: one engine invocation over the whole layer list, one
/// `WireBatch` payload, one `GRAD_BATCH` frame. The header carries the
/// layer-summed statistics; the sub-messages carry each layer's own λ and
/// survivors, exactly as the per-layer path would have produced them.
///
/// `pipelined` selects how the frame reaches the connection: the
/// reference path materializes the whole `WireBatch` and copies it into
/// one frame buffer (`encode_batch` + `send`); the pipelined path sizes
/// the batch with [`crate::coding::BatchStreamEncoder`], encodes each
/// layer into its own reused segment buffer, and hands the connection a
/// vectored gather — frame prefix, batch header, per-layer segments —
/// with no concatenation copy. Identical bytes on the wire either way.
fn worker_round_batched(
    st: &mut WorkerComm,
    worker_grads: &[Vec<f32>],
    codec: WireCodec,
    pipelined: bool,
) {
    let layer_refs: Vec<&[f32]> = worker_grads.iter().map(|g| g.as_slice()).collect();
    st.compressors[0].compress_batch_into(
        &layer_refs,
        &mut st.rand,
        &mut st.msgs,
        &mut st.stats_buf,
    );
    let mut g_norm = 0.0f64;
    let mut q_norm = 0.0f64;
    let mut expected_nnz = 0.0f64;
    let mut ideal_bits = 0u64;
    for ((g, msg), stats) in worker_grads
        .iter()
        .zip(st.msgs.iter())
        .zip(st.stats_buf.iter())
    {
        g_norm += crate::tensor::norm2_sq(g) as f64;
        q_norm += msg.norm2_sq();
        expected_nnz += stats.expected_nnz;
        ideal_bits += stats.ideal_bits;
    }
    let sgs: Vec<&SparseGrad> = st
        .msgs
        .iter()
        .map(|m| match m {
            Compressed::Sparse(sg) => sg,
            other => unreachable!("batchable methods produce sparse messages, got {other:?}"),
        })
        .collect();
    let header = GradHeader {
        based_on: 0,
        g_norm_sq: g_norm,
        q_norm_sq: q_norm,
        expected_nnz,
        ideal_bits,
        kind: 0,
    };
    if pipelined {
        let mut enc = crate::coding::BatchStreamEncoder::plan(&sgs, codec);
        if st.seg_bufs.len() < sgs.len() {
            st.seg_bufs.resize_with(sgs.len(), Vec::new);
        }
        for (sg, seg) in sgs.iter().zip(st.seg_bufs.iter_mut()) {
            enc.encode_next(sg, seg);
        }
        debug_assert!(enc.is_done());
        frame::encode_grad_batch_prefix(&mut st.frame_buf, &header);
        let mut segments: Vec<&[u8]> = Vec::with_capacity(2 + sgs.len());
        segments.push(&st.frame_buf);
        segments.push(enc.header());
        segments.extend(st.seg_bufs.iter().take(sgs.len()).map(|s| s.as_slice()));
        st.conn
            .send_vectored(&segments)
            .expect("leader link alive");
    } else {
        crate::coding::encode_batch(&sgs, codec, &mut st.wire);
        frame::encode_grad_batch(&mut st.frame_buf, &header, &st.wire);
        st.conn.send(&st.frame_buf).expect("leader link alive");
    }
}

/// Ring round: the same per-layer compression front end as the star paths
/// (the shared single compressor drives whole-list batch compression when
/// the session batches; otherwise one engine per layer), then the flattened
/// message joins the worker-side ring reduction. Every rank finishes
/// holding the identical reduced sum; rank 0 alone forwards it to the
/// leader as one `GRAD` frame — the other ranks' leader links ship nothing.
fn worker_round_ring(st: &mut WorkerComm, worker_grads: &[Vec<f32>], codec: WireCodec) {
    if st.compressors.len() == 1 {
        let refs: Vec<&[f32]> = worker_grads.iter().map(|g| g.as_slice()).collect();
        st.compressors[0].compress_batch_into(&refs, &mut st.rand, &mut st.msgs, &mut st.stats_buf);
    } else {
        st.stats_buf.clear();
        for (l, g) in worker_grads.iter().enumerate() {
            let stats = st.compressors[l].compress_into(g, &mut st.rand, &mut st.msgs[l]);
            st.stats_buf.push(stats);
        }
    }
    let mut g_norm = 0.0f64;
    let mut q_norm = 0.0f64;
    let mut expected_nnz = 0.0f64;
    let mut ideal_bits = 0u64;
    for ((g, msg), stats) in worker_grads
        .iter()
        .zip(st.msgs.iter())
        .zip(st.stats_buf.iter())
    {
        g_norm += crate::tensor::norm2_sq(g) as f64;
        q_norm += msg.norm2_sq();
        expected_nnz += stats.expected_nnz;
        ideal_bits += stats.ideal_bits;
    }
    let ring = st.ring.as_mut().expect("ring round on a ring worker");
    let sgs: Vec<&SparseGrad> = st
        .msgs
        .iter()
        .map(|m| match m {
            Compressed::Sparse(sg) => sg,
            other => unreachable!("ring methods produce sparse messages, got {other:?}"),
        })
        .collect();
    merge::flatten_concat(&sgs, &mut ring.flat);
    let d = ring.flat.d as usize;
    // Re-inject the mass earlier budget caps dropped on this rank (standard
    // error feedback around the collective), then reduce.
    ring.fb.ensure_layout(&[d]);
    ring.res_sg.reset(d);
    {
        let res = ring.fb.layer_residual_mut(0);
        for (i, v) in res.iter_mut().enumerate() {
            if *v != 0.0 {
                ring.res_sg.exact.push((i as u32, *v));
                *v = 0.0;
            }
        }
    }
    merge::merge_sum(&ring.res_sg, &ring.flat, &mut ring.flat_in);
    match ring.aligned.as_ref() {
        Some(cfg) => ring.reducer.reduce_aligned(
            &mut ring.peer,
            cfg,
            &ring.flat_in,
            &mut ring.reduced,
            Some(&mut ring.fb),
        ),
        None => ring.reducer.reduce(
            &mut ring.peer,
            &ring.flat_in,
            &mut ring.reduced,
            Some(&mut ring.fb),
        ),
    }
    .expect("ring links alive");
    // The header carries this rank's *local* compression stats — the meters
    // want the per-worker quantization picture, and the reduced message's
    // cost is what the payload itself measures.
    if ring.peer.rank() == 0 {
        crate::coding::encode_with(&ring.reduced, codec, &mut st.wire);
        let header = GradHeader {
            based_on: 0,
            g_norm_sq: g_norm,
            q_norm_sq: q_norm,
            expected_nnz,
            ideal_bits,
            kind: 0,
        };
        frame::encode_grad(&mut st.frame_buf, &header, &st.wire);
        st.conn.send(&st.frame_buf).expect("leader link alive");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{MethodSpec, Session};

    fn grads_for(workers: usize, dims: &[usize], seed: u64) -> Vec<Vec<Vec<f32>>> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        (0..workers)
            .map(|_| {
                dims.iter()
                    .map(|&d| (0..d).map(|_| (rng.next_gaussian() * 0.1) as f32).collect())
                    .collect()
            })
            .collect()
    }

    fn session(method: MethodSpec, workers: usize, seed: u64) -> Session {
        Session::builder()
            .method(method)
            .workers(workers)
            .seed(seed)
            .build()
    }

    #[test]
    fn dense_round_is_exact_mean() {
        let dims = [32usize, 64];
        let grads = grads_for(3, &dims, 50);
        let mut cluster = session(MethodSpec::Dense, 3, 51).cluster(&dims);
        let updates = cluster.round(&grads);
        for (l, upd) in updates.iter().enumerate() {
            for i in 0..dims[l] {
                let expect: f32 = (0..3).map(|w| grads[w][l][i]).sum::<f32>() / 3.0;
                assert!((upd.grad[i] - expect).abs() < 1e-6, "layer {l} coord {i}");
            }
        }
        assert!(cluster.ledger.wire_bytes > 0);
        assert!(cluster.ledger.measured_bytes > 0);
    }

    #[test]
    fn gspar_round_is_unbiased_in_expectation() {
        // Average many rounds of the same gradients: mean → true mean.
        let dims = [128usize];
        let grads = grads_for(2, &dims, 52);
        let mut cluster = session(MethodSpec::GSpar { rho: 0.3, iters: 2 }, 2, 53).cluster(&dims);
        let rounds = 3000;
        let mut acc = vec![0.0f64; 128];
        for _ in 0..rounds {
            let upd = cluster.round(&grads);
            for (a, &v) in acc.iter_mut().zip(&upd[0].grad) {
                *a += v as f64 / rounds as f64;
            }
        }
        for i in 0..128 {
            let expect = (grads[0][0][i] as f64 + grads[1][0][i] as f64) / 2.0;
            // Tolerance accounts for RandArray cyclic reuse correlating
            // rounds (the estimator is unbiased but not i.i.d. across
            // rounds).
            // Small-|g| coordinates carry the shared ±1/λ magnitude when
            // sampled, so their MC noise floor is absolute, not relative.
            let tol = (0.15 * expect.abs()).max(0.02);
            assert!(
                (acc[i] - expect).abs() < tol,
                "coord {i}: {} vs {expect}",
                acc[i]
            );
        }
        assert!(cluster.var_meter.value() > 1.0);
        assert!(cluster.spa_meter.value() < 0.5);
    }

    #[test]
    fn entropy_codec_same_updates_fewer_bytes() {
        let dims = [512usize, 128];
        let grads = grads_for(2, &dims, 58);
        let run = |codec| {
            let mut cluster = Session::builder()
                .method(MethodSpec::GSpar { rho: 0.1, iters: 2 })
                .workers(2)
                .seed(59)
                .codec(codec)
                .build()
                .cluster(&dims);
            let upd = cluster.round(&grads);
            (upd, cluster.ledger.clone())
        };
        let (raw_upd, raw_ledger) = run(WireCodec::Raw);
        let (ent_upd, ent_ledger) = run(WireCodec::Entropy);
        // Identical decoded per-layer updates, strictly fewer bytes.
        for (a, b) in raw_upd.iter().zip(&ent_upd) {
            assert_eq!(a.grad, b.grad);
        }
        assert!(
            ent_ledger.wire_bytes < raw_ledger.wire_bytes,
            "entropy {} !< raw {}",
            ent_ledger.wire_bytes,
            raw_ledger.wire_bytes
        );
        assert!(ent_ledger.measured_bytes < raw_ledger.measured_bytes);
        assert_eq!(
            ent_ledger.wire_bytes_by_codec[WireCodec::Entropy.index()],
            ent_ledger.wire_bytes
        );
    }

    #[test]
    fn per_layer_independence() {
        // A zero layer must stay zero and cost (almost) nothing.
        let dims = [16usize, 16];
        let mut grads = grads_for(2, &dims, 54);
        for w in 0..2 {
            grads[w][1].fill(0.0);
        }
        let mut cluster = session(MethodSpec::GSpar { rho: 0.5, iters: 2 }, 2, 55).cluster(&dims);
        let upd = cluster.round(&grads);
        assert!(upd[1].grad.iter().all(|&v| v == 0.0));
        assert!(upd[0].upload_bytes >= upd[1].upload_bytes);
    }

    #[test]
    fn rounds_are_deterministic_and_measured_bytes_grow() {
        let dims = [64usize, 32];
        let grads = grads_for(2, &dims, 56);
        let run = || {
            let mut cluster =
                session(MethodSpec::GSpar { rho: 0.4, iters: 2 }, 2, 57).cluster(&dims);
            let a = cluster.round(&grads);
            let m1 = cluster.ledger.measured_bytes;
            let b = cluster.round(&grads);
            let m2 = cluster.ledger.measured_bytes;
            assert!(m2 > m1, "measured column must accumulate across rounds");
            (a, b, m2)
        };
        let (a1, b1, m1) = run();
        let (a2, b2, m2) = run();
        for (x, y) in a1.iter().zip(&a2).chain(b1.iter().zip(&b2)) {
            assert_eq!(x.grad, y.grad, "leader aggregation must be deterministic");
        }
        assert_eq!(m1, m2);
    }

    #[test]
    fn batched_round_updates_match_per_layer_bitwise() {
        // The batched pipeline is a wire/engine optimization, not a math
        // change: same session seed ⇒ identical decoded per-layer updates,
        // with fewer frames and fewer measured bytes per round.
        let dims = [700usize, 256, 128, 64];
        let grads = grads_for(2, &dims, 61);
        let run = |batch: bool, codec: WireCodec| {
            // Frame-count asserts are star-schedule facts; pin the topology
            // so the environment-driven ring leg cannot change them.
            let mut cluster = Session::builder()
                .method(MethodSpec::GSpar { rho: 0.05, iters: 2 })
                .workers(2)
                .seed(62)
                .codec(codec)
                .batch_layers(batch)
                .topology(Topology::Star)
                .build()
                .cluster(&dims);
            let upd = cluster.round(&grads);
            (upd, cluster.ledger.clone(), cluster.frames_received())
        };
        for codec in [WireCodec::Raw, WireCodec::Entropy] {
            let (per_layer, pl_ledger, pl_frames) = run(false, codec);
            let (batched, b_ledger, b_frames) = run(true, codec);
            for (l, (a, b)) in per_layer.iter().zip(&batched).enumerate() {
                assert_eq!(a.grad, b.grad, "layer {l} drifted under {codec}");
            }
            assert!(
                b_frames < pl_frames,
                "{codec}: batched frames {b_frames} !< per-layer {pl_frames}"
            );
            assert!(
                b_ledger.measured_bytes < pl_ledger.measured_bytes,
                "{codec}: batched measured {} !< per-layer {}",
                b_ledger.measured_bytes,
                pl_ledger.measured_bytes
            );
        }
    }

    #[test]
    fn pipelined_batched_round_is_bitwise_identical() {
        // Depth ≥ 2 changes the send mechanics (streaming encoder +
        // vectored gather), never the bytes: decoded updates, ledger, and
        // frame counts all match the depth-1 reference path exactly —
        // under both codecs, with and without error feedback.
        let dims = [700usize, 0, 256, 64];
        let grads = grads_for(2, &dims, 71);
        let run = |depth: usize, codec: WireCodec, feedback: bool| {
            let mut builder = Session::builder()
                .method(MethodSpec::GSpar { rho: 0.1, iters: 2 })
                .workers(2)
                .seed(72)
                .codec(codec)
                .batch_layers(true)
                .pipeline(depth);
            if feedback {
                builder = builder.feedback(crate::feedback::FeedbackConfig::default());
            }
            let mut cluster = builder.build().cluster(&dims);
            let first = cluster.round(&grads);
            let second = cluster.round(&grads);
            (first, second, cluster.ledger.clone(), cluster.frames_received())
        };
        for codec in [WireCodec::Raw, WireCodec::Entropy] {
            for feedback in [false, true] {
                let (s1, s2, s_ledger, s_frames) = run(1, codec, feedback);
                for depth in [2usize, 4] {
                    let (p1, p2, p_ledger, p_frames) = run(depth, codec, feedback);
                    for ((a, b), l) in s1.iter().zip(&p1).chain(s2.iter().zip(&p2)).zip(0..) {
                        assert_eq!(
                            a.grad, b.grad,
                            "{codec} fb={feedback} depth {depth}: layer {l} drifted"
                        );
                        assert_eq!(a.upload_bytes, b.upload_bytes);
                        assert_eq!(a.ideal_bits, b.ideal_bits);
                    }
                    assert_eq!(s_ledger.wire_bytes, p_ledger.wire_bytes);
                    assert_eq!(s_ledger.measured_bytes, p_ledger.measured_bytes);
                    assert_eq!(s_frames, p_frames);
                }
            }
        }
    }

    #[test]
    fn batched_cluster_falls_back_per_layer_for_v2_peers() {
        // A session pinned to transport version 2 cannot ship WireBatch
        // frames even with batching requested — the negotiated fallback.
        let dims = [96usize, 32];
        let grads = grads_for(2, &dims, 63);
        let mk = |version: u8, batch: bool| {
            Session::builder()
                .method(MethodSpec::GSpar { rho: 0.2, iters: 2 })
                .workers(2)
                .seed(64)
                .batch_layers(batch)
                .transport_version(version)
                .topology(Topology::Star)
                .build()
                .cluster(&dims)
        };
        let mut v2 = mk(2, true);
        let v2_upd = v2.round(&grads);
        // Per-layer frames: one hello + one frame per layer, per worker.
        assert_eq!(v2.frames_received(), (2 * (1 + dims.len())) as u64);
        let mut v3 = mk(3, true);
        let v3_upd = v3.round(&grads);
        assert_eq!(
            v3.frames_received(),
            2 * (1 + 1),
            "one hello + one batch frame per worker"
        );
        // Fallback is wire-level only: the decoded updates stay identical.
        for (a, b) in v2_upd.iter().zip(&v3_upd) {
            assert_eq!(a.grad, b.grad);
        }
    }

    #[test]
    fn non_batchable_methods_ignore_batch_layers() {
        let dims = [48usize, 16];
        let grads = grads_for(2, &dims, 65);
        let mut cluster = Session::builder()
            .method(MethodSpec::Qsgd { bits: 4 })
            .workers(2)
            .seed(66)
            .batch_layers(true)
            .build()
            .cluster(&dims);
        let upd = cluster.round(&grads);
        assert_eq!(upd.len(), dims.len());
        // Quantized fallback still ships per-layer frames (plus hellos).
        assert_eq!(cluster.frames_received(), (2 * (1 + dims.len())) as u64);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_constructors_match_session_clusters() {
        // The shim guarantee: `Cluster::with_codec` (and `new`) produce the
        // same rounds as a Session-built cluster with the same knobs.
        let dims = [120usize, 40];
        let grads = grads_for(2, &dims, 67);
        let mut old = Cluster::with_codec(2, &dims, 68, WireCodec::Entropy, || {
            MethodSpec::GSpar { rho: 0.3, iters: 2 }.build()
        });
        // The deprecated constructors are star-only; compare against a
        // star-pinned session so the ring environment leg stays orthogonal.
        let mut new = Session::builder()
            .method(MethodSpec::GSpar { rho: 0.3, iters: 2 })
            .workers(2)
            .seed(68)
            .codec(WireCodec::Entropy)
            .topology(Topology::Star)
            .build()
            .cluster(&dims);
        let a = old.round(&grads);
        let b = new.round(&grads);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.grad, y.grad);
            assert_eq!(x.upload_bytes, y.upload_bytes);
        }
        assert_eq!(old.ledger.wire_bytes, new.ledger.wire_bytes);
    }

    #[test]
    fn ring_round_matches_star_math_with_a_loose_budget() {
        // At ρ ≥ 0.5 the per-chunk budget ⌈2ρD/m⌉ covers a whole chunk, so
        // the ring reduction is the exact merged sum and the only star/ring
        // difference is float summation order (the star leader scales each
        // worker's message by 1/M before adding; the ring sums first).
        let dims = [96usize, 32];
        let grads = grads_for(2, &dims, 80);
        let mk = |topology| {
            Session::builder()
                .method(MethodSpec::GSpar { rho: 0.5, iters: 2 })
                .workers(2)
                .seed(81)
                .topology(topology)
                .build()
                .cluster(&dims)
        };
        let mut star = mk(Topology::Star);
        let mut ring = mk(Topology::Ring);
        let s = star.round(&grads);
        let r = ring.round(&grads);
        for (l, (a, b)) in s.iter().zip(&r).enumerate() {
            for (i, (x, y)) in a.grad.iter().zip(&b.grad).enumerate() {
                assert!(
                    (x - y).abs() <= 1e-5 * x.abs().max(1.0),
                    "layer {l} coord {i}: star {x} vs ring {y}"
                );
            }
        }
        // The ring columns fill; star rounds ship zero ring frames and
        // leave both columns at 0 — the no-extra-cost guarantee.
        assert!(ring.ledger.hop_bytes > 0);
        assert!(ring.ledger.end_to_end_bytes > 0);
        assert_eq!(star.ledger.hop_bytes, 0);
        assert_eq!(star.ledger.end_to_end_bytes, 0);
    }

    #[test]
    fn ring_rounds_are_deterministic_and_ship_one_leader_frame() {
        let dims = [64usize, 32];
        let grads = grads_for(3, &dims, 82);
        let run = || {
            let mut cluster = Session::builder()
                .method(MethodSpec::TopK { rho: 0.1 })
                .workers(3)
                .seed(83)
                .topology(Topology::Ring)
                .build()
                .cluster(&dims);
            let a = cluster.round(&grads);
            let b = cluster.round(&grads);
            (a, b, cluster.frames_received(), cluster.ledger.clone())
        };
        let (a1, b1, f1, l1) = run();
        let (a2, b2, f2, l2) = run();
        for (x, y) in a1.iter().zip(&a2).chain(b1.iter().zip(&b2)) {
            assert_eq!(x.grad, y.grad, "ring aggregation must be deterministic");
            assert_eq!(x.upload_bytes, y.upload_bytes);
        }
        assert_eq!(l1.hop_bytes, l2.hop_bytes);
        assert!(l1.hop_bytes > 0);
        // 3 hellos + one GRAD frame per round: only rank 0's leader link
        // carries gradients, the other ranks reduce over the ring alone.
        assert_eq!(f1, 3 + 2);
        assert_eq!(f2, f1);
        assert_eq!(l1.measured_frames, l2.measured_frames);
    }

    #[test]
    fn aligned_ring_round_is_deterministic_and_sparse() {
        let dims = [128usize];
        let grads = grads_for(2, &dims, 84);
        let run = || {
            let mut cluster = Session::builder()
                .method(MethodSpec::TopK { rho: 0.1 })
                .workers(2)
                .seed(85)
                .topology(Topology::Ring)
                .aligned_sparsity(true)
                .build()
                .cluster(&dims);
            let upd = cluster.round(&grads);
            (upd, cluster.ledger.clone())
        };
        let (u1, l1) = run();
        let (u2, _) = run();
        assert_eq!(u1[0].grad, u2[0].grad);
        assert!(l1.hop_bytes > 0);
        // The shared sketch selects at most k = ⌈ρd⌉ coordinates.
        let nnz = u1[0].grad.iter().filter(|&&v| v != 0.0).count();
        assert!(nnz > 0, "aligned selection must keep some coordinates");
        assert!(nnz <= 13, "aligned nnz {nnz} exceeds k");
    }
}
