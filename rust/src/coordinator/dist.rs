//! The multi-process parameter-server runtime: the same SSP-style loop as
//! [`super::param_server`], but every interaction — weight pulls, gradient
//! pushes, config distribution, shutdown — crosses a
//! [`crate::transport::Connection`], so the server and its workers can be
//! threads in one process ([`run_threads`] over `InProc` or loopback TCP)
//! or genuinely separate OS processes ([`run_processes`] + the `server` /
//! `worker` CLI subcommands).
//!
//! ## Deterministic round schedule
//!
//! The server drives a fixed two-phase schedule per round: first it answers
//! one weight pull per worker (all against the same weight version), then
//! it applies one gradient per worker **in worker-id order** (`w ← w − η_t
//! Q(g)`, stamping a new version each). Workers therefore compute
//! concurrently — over TCP, in real parallelism — while the *sequence of
//! weight vectors any worker ever observes* is a pure function of the
//! config and seed. That is what makes the acceptance criterion testable:
//! the compressed gradient bytes of every round are bitwise identical
//! across `InProc` and `Tcp`, and across threads and processes. Staleness
//! is bounded by construction: a gradient applied at version `v` was based
//! on a version at least `v − (M−1)`, the classic SSP window for M workers.
//!
//! ## Byte accounting
//!
//! Next to the α-β *simulated* time the ledger always had, the run reports
//! a **measured** column: the framed bytes that actually crossed the links
//! (handshakes, pulls, weights, gradients, shutdowns — payload plus length
//! prefixes), summed from the per-link [`LinkCounters`].

use crate::api::MethodSpec;
use crate::coding::WireCodec;
use crate::collective::{self, RingPeer, RingReducer};
use crate::comm::{merge, Topology};
use crate::config::Method;
use crate::coordinator::sync::estimate_f_star;
use crate::data::gen_logistic;
use crate::feedback::{CommSchedule, FeedbackConfig, FeedbackState};
use crate::metrics::{CurvePoint, RunCurve, SparsityMeter, VarianceRatio};
use crate::model::{ConvexModel, LogisticModel};
use crate::rngkit::{RandArray, Xoshiro256pp};
use crate::sparsify::{Compressed, Compressor, SparseGrad};
use crate::telemetry::{self, ClockEstimator, MetricsServer, Registry};
use crate::trace::{self, TraceConfig};
use crate::transport::frame::{self, GradHeader, MsgView, TraceCtx};
use crate::transport::{
    Connection, Hello, LinkCounters, Listener, TcpTransport, Transport,
};
use std::time::Instant;

/// Everything a worker needs to reproduce the run — the server ships this
/// in the `CONFIG` frame right after accepting, so worker processes only
/// need an address and an id on their command line.
///
/// Construct via [`crate::api::Session::dist_plan`] (session +
/// [`crate::api::DistTask`]); the old `DistConfig` name survives as a
/// deprecated alias.
#[derive(Clone, Debug, PartialEq)]
pub struct RunPlan {
    pub workers: usize,
    /// Synchronization rounds; total pushes = `rounds × workers`.
    pub rounds: usize,
    pub method: Method,
    pub rho: f32,
    /// QSGD quantization width (only for `Method::Qsgd`).
    pub qsgd_bits: u32,
    pub batch: usize,
    pub lr: f32,
    pub seed: u64,
    /// Synthetic logistic-regression dataset parameters (every participant
    /// regenerates the dataset locally — it is seed-deterministic).
    pub n: usize,
    pub d: usize,
    pub c1: f32,
    pub c2: f32,
    pub reg: f32,
    /// Wire codec for sparse gradient payloads; every worker's handshake
    /// must announce the same one or the accept phase refuses the link.
    pub codec: WireCodec,
    /// Local-step period `H` (Qsparse-local-SGD style): each worker pulls
    /// once, runs `H` local gradient steps, and pushes one compressed
    /// accumulated gradient — `rounds` counts *local* rounds, so the wire
    /// carries `⌈rounds / H⌉` pull/push pairs per worker. `1` (the
    /// default) is the historical round-per-push schedule.
    pub local_steps: usize,
    /// Error-feedback memory around every worker's compressor (ships to
    /// worker processes in the CONFIG frame like everything else).
    pub feedback: Option<FeedbackConfig>,
    /// Pipeline depth (max in-flight compressed round frames; 1 = the
    /// sequential reference path). Depth ≥ 2 makes workers hand their
    /// gradient frames to the connection as vectored header + payload
    /// segments — bytes on the wire are identical at every depth, so a
    /// pipelined sender interoperates with any v3 peer.
    pub pipeline: usize,
    /// Trace recording ([`crate::trace`]): shipped to worker processes in
    /// the CONFIG frame, so every participant of a multi-process run
    /// records under the same configuration and their per-worker trace
    /// files merge into one timeline keyed by worker id. Recording never
    /// changes the computed bytes or weights.
    pub trace: TraceConfig,
    /// Communication topology. Under [`Topology::Ring`] (and `workers > 1`)
    /// the workers bootstrap a peer ring through `RING_ADDR` relays, reduce
    /// every block's compressed gradients among themselves
    /// ([`crate::collective::RingReducer`]), and rank 0 alone pushes the
    /// reduced sum — the server applies **one** update per block instead of
    /// `M`. Star (the default) is the historical per-worker push schedule,
    /// byte-for-byte unchanged. Ring requires a sparse-message method.
    pub topology: Topology,
    /// Aligned-sparsity ring mode: ranks agree on a shared top-k index set
    /// via a count sketch and reduce index-free
    /// ([`crate::collective::RingReducer::reduce_aligned`]). Ignored under
    /// [`Topology::Star`].
    pub aligned: bool,
}

/// Deprecated name of [`RunPlan`].
#[deprecated(
    since = "0.2.0",
    note = "use gsparse::api::Session::dist_plan / dist_threads / dist_processes (the struct \
            itself is now coordinator::dist::RunPlan)"
)]
pub type DistConfig = RunPlan;

impl Default for RunPlan {
    fn default() -> Self {
        Self {
            workers: 2,
            rounds: 500,
            method: Method::GSpar,
            rho: 0.1,
            qsgd_bits: 4,
            batch: 8,
            lr: 0.5,
            seed: 42,
            n: 1024,
            d: 2048,
            c1: 0.6,
            c2: 0.25,
            reg: 1.0 / (10.0 * 1024.0),
            codec: WireCodec::Raw,
            local_steps: 1,
            feedback: None,
            pipeline: 1,
            // The CI trace leg (GSPARSE_TRACE=json) flows through plans
            // built without an explicit config, like SessionBuilder.
            trace: TraceConfig::from_env(),
            // Plans built through Session::dist_plan inherit the session's
            // topology (including its GSPARSE_TOPOLOGY env default); direct
            // RunPlan construction keeps the historical star schedule.
            topology: Topology::Star,
            aligned: false,
        }
    }
}

/// Version 2 appended the wire-codec byte; version 3 appended the
/// local-step period and the error-feedback toggle + decay; version 4
/// appended the pipeline depth; version 5 appended the trace config
/// (mode byte + u32 ring capacity); version 6 appended the topology and
/// aligned-sparsity bytes; version 7 appended the server's transport
/// version (the hello handshake is one-way, so this byte is how a worker
/// learns whether the server understands trace-context stamps and clock
/// probes — see [`frame::Hello::supports_ctx`]).
const CONFIG_VERSION: u8 = 7;
/// Offset of the codec byte: version + method + 6×u32 + u64 seed + 5×f32.
const CONFIG_CODEC_AT: usize = 2 + 6 * 4 + 8 + 5 * 4;
/// Codec byte + u32 local_steps + feedback flag + f32 decay + u32 pipeline
/// + trace mode byte + u32 trace ring capacity + topology byte + aligned
/// byte + server transport-version byte.
const CONFIG_LEN: usize = CONFIG_CODEC_AT + 1 + 4 + 1 + 4 + 4 + 1 + 4 + 1 + 1 + 1;

/// Server-side clock re-probe period: after the initial post-CONFIG ping,
/// every v4 link gets one fresh NTP-style probe exchange each
/// `PROBE_EVERY_BLOCKS` blocks, so the per-link offset estimate tracks
/// drift over long runs without ever contending with gradient traffic
/// (probes ride the same sequential frame stream).
pub const PROBE_EVERY_BLOCKS: usize = 16;

/// How many clock-probe pings the server sends per ctx-capable link over a
/// run of `blocks` blocks: one right after CONFIG plus the periodic
/// re-probes. Each ping costs exactly two frames on the link (ping out,
/// pong back) — the frame-accounting tests pin their counts with this.
pub fn probe_count(blocks: usize) -> usize {
    1 + blocks.saturating_sub(1) / PROBE_EVERY_BLOCKS
}

impl RunPlan {
    /// Serialize for the `CONFIG` frame (fixed-width LE fields).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64);
        out.push(CONFIG_VERSION);
        let method = Method::all()
            .iter()
            .position(|&m| m == self.method)
            .expect("method in Method::all") as u8;
        out.push(method);
        for v in [
            self.workers as u32,
            self.rounds as u32,
            self.batch as u32,
            self.n as u32,
            self.d as u32,
            self.qsgd_bits,
        ] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&self.seed.to_le_bytes());
        for v in [self.rho, self.lr, self.c1, self.c2, self.reg] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.push(self.codec.index() as u8);
        out.extend_from_slice(&(self.local_steps.max(1) as u32).to_le_bytes());
        out.push(u8::from(self.feedback.is_some()));
        out.extend_from_slice(
            &self.feedback.map(|f| f.decay).unwrap_or(0.0).to_le_bytes(),
        );
        out.extend_from_slice(&(self.pipeline.max(1) as u32).to_le_bytes());
        out.extend_from_slice(&self.trace.wire_bytes());
        out.push(match self.topology {
            Topology::Star => 0,
            Topology::Ring => 1,
        });
        out.push(u8::from(self.aligned));
        // Not a plan field: the encoding server's own transport version,
        // read back via [`RunPlan::decode_with_caps`].
        out.push(frame::TRANSPORT_VERSION);
        out
    }

    pub fn decode(buf: &[u8]) -> anyhow::Result<Self> {
        Self::decode_with_caps(buf).map(|(cfg, _)| cfg)
    }

    /// [`RunPlan::decode`] plus the server-capability byte (the server's
    /// transport version): the CONFIG frame is the only server→worker
    /// message guaranteed to precede all telemetry traffic, so it carries
    /// the bit a worker needs before deciding whether its own gradient
    /// frames may be trace-context stamped.
    pub fn decode_with_caps(buf: &[u8]) -> anyhow::Result<(Self, u8)> {
        anyhow::ensure!(buf.len() == CONFIG_LEN, "config frame length");
        anyhow::ensure!(buf[0] == CONFIG_VERSION, "config version {}", buf[0]);
        let method = *Method::all()
            .get(buf[1] as usize)
            .ok_or_else(|| anyhow::anyhow!("unknown method id {}", buf[1]))?;
        let u32_at = |i: usize| {
            u32::from_le_bytes(buf[2 + 4 * i..2 + 4 * (i + 1)].try_into().unwrap())
        };
        let f_base = 2 + 6 * 4 + 8;
        let f32_at = |i: usize| {
            f32::from_le_bytes(buf[f_base + 4 * i..f_base + 4 * (i + 1)].try_into().unwrap())
        };
        let codec_at = CONFIG_CODEC_AT;
        let codec = WireCodec::from_u8(buf[codec_at])
            .ok_or_else(|| anyhow::anyhow!("unknown codec id {}", buf[codec_at]))?;
        let local_steps = u32::from_le_bytes(
            buf[codec_at + 1..codec_at + 5].try_into().unwrap(),
        ) as usize;
        anyhow::ensure!(local_steps >= 1, "local_steps must be ≥ 1");
        let fb_flag = buf[codec_at + 5];
        anyhow::ensure!(fb_flag <= 1, "unknown feedback flag {fb_flag}");
        let decay = f32::from_le_bytes(buf[codec_at + 6..codec_at + 10].try_into().unwrap());
        let feedback = if fb_flag == 1 {
            anyhow::ensure!(
                (0.0..=1.0).contains(&decay),
                "feedback decay {decay} out of [0, 1]"
            );
            Some(FeedbackConfig::with_decay(decay))
        } else {
            None
        };
        let pipeline = u32::from_le_bytes(
            buf[codec_at + 10..codec_at + 14].try_into().unwrap(),
        ) as usize;
        anyhow::ensure!(pipeline >= 1, "pipeline depth must be ≥ 1");
        let trace_cap = u32::from_le_bytes(
            buf[codec_at + 15..codec_at + 19].try_into().unwrap(),
        );
        let trace = TraceConfig::from_wire(buf[codec_at + 14], trace_cap)
            .ok_or_else(|| anyhow::anyhow!("unknown trace mode {}", buf[codec_at + 14]))?;
        let topology = match buf[codec_at + 19] {
            0 => Topology::Star,
            1 => Topology::Ring,
            other => anyhow::bail!("unknown topology id {other}"),
        };
        let aligned_flag = buf[codec_at + 20];
        anyhow::ensure!(aligned_flag <= 1, "unknown aligned flag {aligned_flag}");
        let server_version = buf[codec_at + 21];
        let cfg = Self {
            workers: u32_at(0) as usize,
            rounds: u32_at(1) as usize,
            batch: u32_at(2) as usize,
            n: u32_at(3) as usize,
            d: u32_at(4) as usize,
            qsgd_bits: u32_at(5),
            method,
            seed: u64::from_le_bytes(buf[26..34].try_into().unwrap()),
            rho: f32_at(0),
            lr: f32_at(1),
            c1: f32_at(2),
            c2: f32_at(3),
            reg: f32_at(4),
            codec,
            local_steps,
            feedback,
            pipeline,
            trace,
            topology,
            aligned: aligned_flag == 1,
        };
        Ok((cfg, server_version))
    }

    /// Whether this plan runs the ring collective (ring topology with more
    /// than one worker; a single worker degenerates to the star schedule).
    fn ring_mode(&self) -> bool {
        self.topology == Topology::Ring && self.workers > 1
    }

    /// The method's target density when it produces sparse messages — ring
    /// mode requires one (quantized/dense fallbacks have no sparse merge).
    fn sparse_density(&self) -> Option<f32> {
        MethodSpec::from_parts(self.method, self.rho, self.c1 * self.c2, self.qsgd_bits)
            .density()
    }
}

/// Outcome of a distributed run, as observed by the server.
#[derive(Debug, Clone)]
pub struct DistReport {
    pub curve: RunCurve,
    pub final_loss: f64,
    /// Server-side weight version (== total applied pushes).
    pub versions: u64,
    /// Max `applied_version − based_on` over all pushes (≤ workers − 1 by
    /// the round schedule).
    pub max_observed_staleness: u64,
    /// FNV-1a over every gradient payload in apply order — two backends
    /// producing the same digest shipped bitwise-identical gradients.
    pub grad_digest: u64,
    /// Final weights (for cross-backend parity assertions).
    pub final_w: Vec<f32>,
    /// Measured framed bytes the server sent / received across all links.
    pub measured_tx_bytes: u64,
    pub measured_rx_bytes: u64,
    /// α-β simulated communication time over the gradient payload bytes.
    pub sim_time_s: f64,
    /// Server-side trace roll-up (per-stage counters + duration histograms
    /// + per-link transport counters) when the plan enabled tracing.
    pub trace_metrics: Option<trace::MetricsSnapshot>,
    /// Final Prometheus exposition text of the run's telemetry registry —
    /// what a last `/metrics` scrape would have returned (the registry is
    /// always maintained; the HTTP responder only starts when
    /// [`crate::telemetry::METRICS_ADDR_ENV`] names an address).
    pub metrics_text: String,
    /// Per-link NTP-style clock offsets (worker id, peer − server, ns) for
    /// every link that completed at least one probe exchange — what the
    /// trace merger uses to align per-role dumps. Empty when every peer
    /// predates the v4 probe frames.
    pub clock_offsets_ns: Vec<(u32, i64)>,
}

fn fnv1a(mut hash: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        hash ^= b as u64;
        hash = hash.wrapping_mul(0x0000_0100_0000_01B3);
    }
    hash
}

/// The topology's dump-filename spelling (feeds [`trace::run_tag`]).
fn topo_name(t: Topology) -> &'static str {
    match t {
        Topology::Star => "star",
        Topology::Ring => "ring",
    }
}

/// Fixed round-latency histogram bounds (seconds): ~log-spaced from 10 µs
/// to 3 s, wide enough for in-proc rounds and WAN-ish stragglers alike.
/// Fixed bounds keep scrapes from different runs mergeable bucket-by-bucket.
const LATENCY_BOUNDS: &[f64] = &[
    1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 0.1, 0.3, 1.0, 3.0,
];

/// Server-side receive that absorbs clock-probe `PONG`s into the link's
/// [`ClockEstimator`] (t3 taken at absorb time) and leaves the first
/// protocol frame in `rxbuf` — pongs can interleave anywhere in the frame
/// stream because the worker answers pings from inside its own recv loop.
fn recv_absorb_pongs(
    conn: &mut dyn Connection,
    rxbuf: &mut Vec<u8>,
    clock: &mut ClockEstimator,
) -> anyhow::Result<()> {
    loop {
        conn.recv(rxbuf)?;
        let absorbed = match frame::decode(rxbuf)? {
            MsgView::Probe { kind, t0, t1, t2 } => {
                anyhow::ensure!(
                    kind == frame::PROBE_PONG,
                    "unexpected clock-probe ping from a worker (only the server pings)"
                );
                clock.update(t0, t1, t2, trace::now_ns());
                true
            }
            _ => false,
        };
        if !absorbed {
            return Ok(());
        }
    }
}

/// Worker-side receive that answers clock-probe `PING`s in place (t1 at
/// receipt, t2 at reply encode) and leaves the first protocol frame in
/// `rxbuf`. The pong travels on the same sequential frame stream, so the
/// server's next receive on this link absorbs it.
fn recv_answer_pings(
    conn: &mut dyn Connection,
    rxbuf: &mut Vec<u8>,
    pongbuf: &mut Vec<u8>,
) -> anyhow::Result<()> {
    loop {
        conn.recv(rxbuf)?;
        let ping_t0 = match frame::decode(rxbuf)? {
            MsgView::Probe { kind, t0, .. } => {
                anyhow::ensure!(
                    kind == frame::PROBE_PING,
                    "unexpected clock-probe pong on a worker (only workers pong)"
                );
                Some(t0)
            }
            _ => None,
        };
        match ping_t0 {
            Some(t0) => {
                let t1 = trace::now_ns();
                frame::encode_probe(pongbuf, frame::PROBE_PONG, t0, t1, trace::now_ns());
                conn.send(pongbuf)?;
            }
            None => return Ok(()),
        }
    }
}

/// Write `<stem>.<tag>.clock.json` — the per-worker offsets the trace
/// merger (`gsparse trace-merge --clock …`) applies when aligning per-role
/// dumps: `{"schema":"gsparse-clock-v1","offsets_ns":{"<worker>":<ns>}}`.
/// Links that never completed a probe exchange are omitted.
fn write_clock_file(tag: &str, clocks: &[ClockEstimator]) -> std::io::Result<std::path::PathBuf> {
    let mut body = String::from("{\"schema\":\"gsparse-clock-v1\",\"offsets_ns\":{");
    let mut first = true;
    for (wid, c) in clocks.iter().enumerate() {
        if c.samples() == 0 {
            continue;
        }
        if !first {
            body.push(',');
        }
        first = false;
        body.push_str(&format!("\"{wid}\":{}", c.offset_ns()));
    }
    body.push_str("}}\n");
    let path = std::path::PathBuf::from(format!("{}.{tag}.clock.json", trace::out_stem()));
    std::fs::write(&path, &body)?;
    Ok(path)
}

/// Run the server side: accept `cfg.workers` connections, ship the config,
/// drive the round schedule, and report. The caller owns the listener, so
/// backends and tests control the address.
pub fn serve(listener: &mut dyn Listener, cfg: &RunPlan) -> anyhow::Result<DistReport> {
    let d = cfg.d;
    let ring = cfg.ring_mode();
    if ring {
        anyhow::ensure!(
            cfg.sparse_density().is_some(),
            "ring topology requires a sparse-message method, not {}",
            cfg.method
        );
    }
    let ds = gen_logistic(cfg.n, cfg.d, cfg.c1, cfg.c2, cfg.seed);
    let model = LogisticModel::new(cfg.reg);

    // Install the recorder before the accept phase so the handshake span
    // lands in the trace; recording reads only lengths and the clock, so
    // the run is bitwise identical with tracing on or off (tests/trace.rs).
    let recorder = trace::Recorder::new(&cfg.trace);
    let _trace_guard = trace::install_opt(recorder.as_ref(), trace::SERVER_WORKER);

    // ---- accept + config distribution (codec agreement checked here; the
    // per-peer hello version decides the weights-frame flavor below) ----
    let accepted = crate::transport::accept_n_hello(listener, cfg.workers, cfg.codec)?;
    let mut conns: Vec<Box<dyn Connection>> = Vec::with_capacity(cfg.workers);
    let mut peer_batch: Vec<bool> = Vec::with_capacity(cfg.workers);
    let mut peer_ctx: Vec<bool> = Vec::with_capacity(cfg.workers);
    for (conn, hello) in accepted {
        peer_batch.push(hello.supports_batch());
        peer_ctx.push(hello.supports_ctx());
        conns.push(conn);
    }
    let counters: Vec<LinkCounters> = conns.iter().map(|c| c.counters()).collect();

    // ---- live metrics plane ([`crate::telemetry`]): a per-run registry
    // the round loop updates lock-free, concatenated with the process
    // global (where workers in threads mode publish residual gauges) and
    // served over HTTP when the environment names an address. Metrics only
    // observe — the probes below are *version*-gated, never
    // telemetry-gated, so the bytes on every link are identical whether or
    // not anything scrapes them.
    let registry = Registry::new();
    let _metrics_server: Option<MetricsServer> =
        match std::env::var(telemetry::METRICS_ADDR_ENV) {
            Ok(addr) if !addr.is_empty() => Some(
                MetricsServer::start(&addr, vec![registry.clone(), telemetry::global()])
                    .map_err(|e| anyhow::anyhow!("binding metrics endpoint {addr}: {e}"))?,
            ),
            _ => None,
        };
    let per_worker_counter = |name: &str, help: &str| -> Vec<telemetry::Counter> {
        (0..cfg.workers)
            .map(|wid| {
                let l = wid.to_string();
                registry.counter(name, help, &[("worker", &l)])
            })
            .collect()
    };
    let rounds_total = per_worker_counter(
        "gsparse_rounds_total",
        "Gradient pushes applied by the server, per worker link.",
    );
    let round_latency: Vec<telemetry::Histo> = (0..cfg.workers)
        .map(|wid| {
            let l = wid.to_string();
            registry.histogram(
                "gsparse_round_latency_seconds",
                "Block latency from the server's phase start to this worker's gradient being applied.",
                &[("worker", &l)],
                LATENCY_BOUNDS,
            )
        })
        .collect();
    let wire_bytes_total = registry.counter(
        "gsparse_wire_bytes_total",
        "Compressed gradient payload bytes received (the ledger's wire column).",
        &[],
    );
    let e2e_bytes_total = registry.counter(
        "gsparse_end_to_end_bytes_total",
        "Framed bytes of ring-reduced gradient frames (the ledger's end-to-end column).",
        &[],
    );
    let straggler_ratio = registry.gauge(
        "gsparse_straggler_ratio",
        "Slowest over fastest per-worker gradient wait in the latest block (1 = perfectly even).",
        &[],
    );
    let straggler_rank = registry.gauge(
        "gsparse_straggler_rank",
        "Worker rank whose gradient the server waited longest for in the latest block.",
        &[],
    );
    let weight_version_gauge = registry.gauge(
        "gsparse_weight_version",
        "Server-side weight version (== total applied pushes).",
        &[],
    );
    let trace_dropped_total = registry.counter(
        "gsparse_trace_dropped_total",
        "Trace events overwritten in the server recorder's rings before draining.",
        &[],
    );
    let mut dropped_seen = 0u64;

    // Per-link NTP-style clock estimators, fed by the probe pongs the
    // probe-aware recvs absorb.
    let mut clocks: Vec<ClockEstimator> =
        (0..cfg.workers).map(|_| ClockEstimator::default()).collect();

    let cfg_bytes = cfg.encode();
    let mut txbuf = Vec::new();
    let mut rxbuf = Vec::new();
    for (wid, conn) in conns.iter_mut().enumerate() {
        frame::encode_config(&mut txbuf, &cfg_bytes);
        conn.send(&txbuf)?;
        // First clock probe straight after the config: the pong comes back
        // ahead of the worker's first protocol frame and is absorbed by
        // the probe-aware recvs below. Legacy (pre-v4) peers never see a
        // probe — their byte stream is exactly the pre-telemetry one.
        if peer_ctx[wid] {
            frame::encode_probe(&mut txbuf, frame::PROBE_PING, trace::now_ns(), 0, 0);
            conn.send(&txbuf)?;
        }
    }

    // ---- ring bootstrap: collect every worker's ring-listener address,
    // then relay each worker its right neighbour's — the workers open the
    // peer links themselves ([`collective::connect_ring`]); the server
    // never sees ring traffic, only this handshake ----
    if ring {
        let mut ring_addrs = vec![String::new(); cfg.workers];
        for (wid, conn) in conns.iter_mut().enumerate() {
            recv_absorb_pongs(conn.as_mut(), &mut rxbuf, &mut clocks[wid])?;
            match frame::decode(&rxbuf)? {
                MsgView::RingAddr { worker_id, addr } => {
                    anyhow::ensure!(
                        worker_id as usize == wid,
                        "ring address announced id {worker_id} on worker {wid}'s link"
                    );
                    ring_addrs[wid] = std::str::from_utf8(addr)
                        .map_err(|_| anyhow::anyhow!("ring address is not utf-8"))?
                        .to_string();
                }
                _ => anyhow::bail!("expected ring address from {}", conn.peer()),
            }
        }
        for (wid, conn) in conns.iter_mut().enumerate() {
            let right = (wid + 1) % cfg.workers;
            frame::encode_ring_addr(&mut txbuf, right as u32, &ring_addrs[right]);
            conn.send(&txbuf)?;
        }
    }

    // ---- training state ----
    let schedule = CommSchedule::every(cfg.local_steps);
    let blocks = schedule.blocks(cfg.rounds);
    let mut w = vec![0.0f32; d];
    let mut version = 0u64;
    let mut t = 0u64;
    // Ring blocks apply one ring-reduced push; star blocks apply M.
    let pushes_per_block = if ring { 1 } else { cfg.workers };
    let total = (blocks * pushes_per_block) as u64;
    let record_every = (total / 50).max(1);
    let mut curve = RunCurve::new(format!("dist-{}(M={})", cfg.method, cfg.workers));
    let mut var_meter = VarianceRatio::default();
    let mut spa_meter = SparsityMeter::default();
    let mut net = crate::comm::NetworkModel::commodity_1g();
    if ring {
        net.topology = Topology::Ring;
    }
    let mut sim_time = 0.0f64;
    let mut max_stale = 0u64;
    let mut digest = 0xCBF2_9CE4_8422_2325u64; // FNV-1a offset basis
    let mut sg = SparseGrad::empty(0);
    let mut round_bytes = vec![0u64; cfg.workers];
    let mut samples_done = 0u64;
    let mut txbuf_batch = Vec::new();
    let mut txbuf_ctx = Vec::new();
    let start = Instant::now();

    // One pull/push pair per worker per *block* of `local_steps` rounds:
    // the rounds inside a block happen entirely on the workers (local
    // gradient steps, zero wire traffic) — visible below as the frame and
    // byte counters scaling with `blocks`, not `rounds`.
    for block in 0..blocks {
        trace::set_round(block as u32);
        let _round_span = trace::span(trace::Stage::Round);
        let block_len = schedule.block_len(block, cfg.rounds) as u64;
        let block_start = Instant::now();
        // Periodic clock re-probe (v4 links only): keeps the per-link
        // offset estimate fresh over long runs. The pong is absorbed by
        // this block's own phase-1 receive.
        if block > 0 && block % PROBE_EVERY_BLOCKS == 0 {
            for (wid, conn) in conns.iter_mut().enumerate() {
                if peer_ctx[wid] {
                    frame::encode_probe(&mut txbuf, frame::PROBE_PING, trace::now_ns(), 0, 0);
                    conn.send(&txbuf)?;
                }
            }
        }
        // Phase 1: answer one pull per worker, all at the same version —
        // encode each weights flavor at most once. A *multi-tensor* weight
        // set goes to batch-capable (v3) peers as one WEIGHTS_BATCH frame
        // (the download sibling of GRAD_BATCH — one frame per round-trip
        // regardless of the tensor count), with the plain per-tensor
        // WEIGHTS fallback for v2 peers. This runtime's model is a single
        // flat vector, for which plain WEIGHTS is already one frame per
        // round-trip and 8 bytes cheaper, so everyone gets it; the
        // negotiation and both decode paths are in place for the
        // multi-tensor models the ROADMAP targets (run_worker accepts
        // either flavor).
        let weight_tensors: &[&[f32]] = &[w.as_slice()];
        let mut plain_encoded = false;
        let mut batch_encoded = false;
        let mut stamped_encoded = false;
        for (wid, conn) in conns.iter_mut().enumerate() {
            {
                let mut wait = trace::span(trace::Stage::BarrierWait);
                wait.layer(wid as u32);
                recv_absorb_pongs(conn.as_mut(), &mut rxbuf, &mut clocks[wid])?;
            }
            match frame::decode(&rxbuf)? {
                MsgView::Pull => {}
                _ => anyhow::bail!("expected pull from {}", conn.peer()),
            }
            if peer_batch[wid] && weight_tensors.len() > 1 {
                if !batch_encoded {
                    frame::encode_weights_batch(&mut txbuf_batch, version, weight_tensors);
                    batch_encoded = true;
                }
                conn.send(&txbuf_batch)?;
            } else {
                if !plain_encoded {
                    frame::encode_weights(&mut txbuf, version, &w);
                    plain_encoded = true;
                }
                if peer_ctx[wid] {
                    // Stamp the broadcast with a per-link trace context so
                    // the worker's frame_rx span links back to this send.
                    // One stamped copy is kept next to the unstamped
                    // master (restamped per link) — mixed-version fleets
                    // send each peer its own flavor.
                    let ctx = TraceCtx {
                        round: block as u32,
                        sender: u32::MAX,
                        seq: trace::next_flow_seq(),
                    };
                    if !stamped_encoded {
                        txbuf_ctx.clear();
                        txbuf_ctx.extend_from_slice(&txbuf);
                        frame::stamp_ctx(&mut txbuf_ctx, ctx);
                        stamped_encoded = true;
                    } else {
                        frame::restamp_ctx(&mut txbuf_ctx, ctx);
                    }
                    conn.send(&txbuf_ctx)?;
                } else {
                    conn.send(&txbuf)?;
                }
            }
        }
        // Phase 2 (ring): the workers already reduced among themselves;
        // rank 0 alone pushes the summed gradient and the server applies
        // it once, scaled to the mean (`−η/M · Σ g` — the all-reduce SGD
        // convention, one weight version per block).
        if ring {
            let conn = &mut conns[0];
            {
                let mut wait = trace::span(trace::Stage::BarrierWait);
                wait.layer(0);
                recv_absorb_pongs(conn.as_mut(), &mut rxbuf, &mut clocks[0])?;
            }
            let (header, payload) = match frame::decode(&rxbuf)? {
                MsgView::Grad { header, payload } => (header, payload),
                _ => anyhow::bail!("expected ring-reduced gradient from {}", conn.peer()),
            };
            anyhow::ensure!(header.kind == 0, "ring pushes are sparse by construction");
            t += 1;
            let eta = cfg.lr / (1.0 + t as f32 / cfg.workers as f32);
            crate::coding::decode_into(payload, &mut sg)?;
            anyhow::ensure!(
                sg.d as usize == d,
                "gradient dimension {} != configured {d}",
                sg.d
            );
            {
                let mut apply = trace::span(trace::Stage::Apply);
                apply.bytes(payload.len() as u64);
                sg.add_into(-eta / cfg.workers as f32, &mut w);
            }
            max_stale = max_stale.max(version.saturating_sub(header.based_on));
            version += 1;
            digest = fnv1a(digest, payload);
            var_meter.record(header.q_norm_sq, header.g_norm_sq);
            spa_meter.record(header.expected_nnz, d);
            let upload = payload.len() as u64;
            curve.ledger.record_codec(header.ideal_bits, upload, cfg.codec);
            // The server cannot see the worker-owned ring links, so the
            // hop column stays 0 here (the cluster coordinator, which owns
            // both sides, fills it); the end-to-end column records what a
            // consumer of the reduced gradient pays.
            curve.ledger.add_end_to_end_bytes(rxbuf.len() as u64);
            // Every ring node carries ~the reduced payload across its
            // 2(M−1) hop phases — feed the α-β ring arm that per-node size.
            round_bytes.fill(upload);
            rounds_total[0].inc();
            round_latency[0].observe(block_start.elapsed().as_secs_f64());
            wire_bytes_total.inc_by(upload);
            e2e_bytes_total.inc_by(rxbuf.len() as u64);
            weight_version_gauge.set(version as f64);
            if let Some(rec) = recorder.as_ref() {
                let d = rec.dropped();
                trace_dropped_total.inc_by(d - dropped_seen);
                dropped_seen = d;
            }
            samples_done += block_len * (cfg.batch * cfg.workers) as u64;
            if t % record_every == 0 || t == total {
                curve.points.push(CurvePoint {
                    data_passes: samples_done as f64 / ds.n() as f64,
                    loss: model.loss(&ds, &w),
                    comm_bits: curve.ledger.wire_bytes * 8,
                    wall_ms: start.elapsed().as_secs_f64() * 1e3,
                });
            }
            sim_time += net.round_time_s(&round_bytes, (d * 4) as u64);
            continue;
        }
        // Phase 2 (star): apply one (accumulated) gradient per worker, in
        // worker-id order.
        let mut slowest_wait = 0.0f64;
        let mut slowest_wid = 0usize;
        let mut fastest_wait = f64::INFINITY;
        for (wid, conn) in conns.iter_mut().enumerate() {
            {
                let wait_start = Instant::now();
                let mut wait = trace::span(trace::Stage::BarrierWait);
                wait.layer(wid as u32);
                recv_absorb_pongs(conn.as_mut(), &mut rxbuf, &mut clocks[wid])?;
                // The blocking part of this worker's turn — what the
                // straggler gauge attributes. Sequential worker-id order
                // means earlier workers absorb shared wait, so this is a
                // lower bound on the true straggle, exact for the slowest.
                let waited = wait_start.elapsed().as_secs_f64();
                if waited > slowest_wait {
                    slowest_wait = waited;
                    slowest_wid = wid;
                }
                fastest_wait = fastest_wait.min(waited);
            }
            let (header, payload) = match frame::decode(&rxbuf)? {
                MsgView::Grad { header, payload } => (header, payload),
                _ => anyhow::bail!("expected gradient from {}", conn.peer()),
            };
            t += 1;
            let eta = cfg.lr / (1.0 + t as f32 / cfg.workers as f32);
            if header.kind == 0 {
                crate::coding::decode_into(payload, &mut sg)?;
                // The codec only checks internal consistency; the declared
                // dimension must also match ours or `add_into` would panic.
                anyhow::ensure!(
                    sg.d as usize == d,
                    "gradient dimension {} != configured {d}",
                    sg.d
                );
                let mut apply = trace::span(trace::Stage::Apply);
                apply.bytes(payload.len() as u64);
                sg.add_into(-eta, &mut w);
            } else {
                anyhow::ensure!(payload.len() == 4 * d, "dense payload length");
                let mut apply = trace::span(trace::Stage::Apply);
                apply.bytes(payload.len() as u64);
                frame::add_dense_le(payload, -eta, &mut w);
            }
            max_stale = max_stale.max(version.saturating_sub(header.based_on));
            version += 1;
            digest = fnv1a(digest, payload);
            var_meter.record(header.q_norm_sq, header.g_norm_sq);
            spa_meter.record(header.expected_nnz, d);
            // Wire-column convention shared with sync/cluster: sparse
            // messages cost their codec bytes (ledgered under the
            // negotiated codec's column); quantized/dense fallbacks (which
            // travel as raw f32 only because no byte codec exists for
            // them) are ledgered at their idealized size under `Raw`. The
            // measured column records what actually crossed the link
            // either way.
            let upload = if header.kind == 0 {
                payload.len() as u64
            } else {
                (header.ideal_bits / 8).max(1)
            };
            let msg_codec = if header.kind == 0 { cfg.codec } else { WireCodec::Raw };
            curve.ledger.record_codec(header.ideal_bits, upload, msg_codec);
            round_bytes[wid] = upload;
            rounds_total[wid].inc();
            round_latency[wid].observe(block_start.elapsed().as_secs_f64());
            wire_bytes_total.inc_by(upload);
            weight_version_gauge.set(version as f64);
            samples_done += block_len * cfg.batch as u64;
            if t % record_every == 0 || t == total {
                curve.points.push(CurvePoint {
                    data_passes: samples_done as f64 / ds.n() as f64,
                    loss: model.loss(&ds, &w),
                    comm_bits: curve.ledger.wire_bytes * 8,
                    wall_ms: start.elapsed().as_secs_f64() * 1e3,
                });
            }
        }
        straggler_ratio.set(slowest_wait / fastest_wait.max(1e-9));
        straggler_rank.set(slowest_wid as f64);
        if let Some(rec) = recorder.as_ref() {
            let dropped = rec.dropped();
            trace_dropped_total.inc_by(dropped - dropped_seen);
            dropped_seen = dropped;
        }
        sim_time += net.round_time_s(&round_bytes, (d * 4) as u64);
    }

    // ---- shutdown: each worker sends one final pull ----
    for (wid, conn) in conns.iter_mut().enumerate() {
        recv_absorb_pongs(conn.as_mut(), &mut rxbuf, &mut clocks[wid])?;
        match frame::decode(&rxbuf)? {
            MsgView::Pull => {}
            _ => anyhow::bail!("expected final pull from {}", conn.peer()),
        }
        frame::encode_shutdown(&mut txbuf);
        conn.send(&txbuf)?;
    }

    let measured_tx: u64 = counters.iter().map(|c| c.bytes_tx()).sum();
    let measured_rx: u64 = counters.iter().map(|c| c.bytes_rx()).sum();
    curve.ledger.measured_bytes = measured_tx + measured_rx;
    curve
        .ledger
        .set_measured_frames(counters.iter().map(|c| c.frames_tx() + c.frames_rx()).sum());
    curve.ledger.verify();
    curve.var_ratio = var_meter.value();
    curve.sparsity = spa_meter.value();
    if let Some(rec) = recorder.as_ref() {
        let dropped = rec.dropped();
        trace_dropped_total.inc_by(dropped - dropped_seen);
    }
    let run_tag = trace::run_tag(cfg.rounds, topo_name(cfg.topology));
    let trace_metrics = recorder.as_ref().map(|rec| {
        let events = rec.drain();
        let mut snap = trace::MetricsSnapshot::from_events(&events);
        snap.set_dropped(rec.dropped());
        for (wid, c) in counters.iter().enumerate() {
            snap.fold_link_counters(&format!("link_w{wid}"), c);
        }
        snap.push_gauge("sim_time_s", sim_time);
        if TraceConfig::dump_requested() {
            let _ = trace::dump_events(&events, &run_tag, "server", cfg.trace.format());
            // The clock sidecar rides along with the server dump: same
            // stem and tag, consumed by `gsparse trace-merge --clock`.
            let _ = write_clock_file(&run_tag, &clocks);
        }
        snap
    });
    let clock_offsets_ns: Vec<(u32, i64)> = clocks
        .iter()
        .enumerate()
        .filter(|(_, c)| c.samples() > 0)
        .map(|(wid, c)| (wid as u32, c.offset_ns()))
        .collect();
    let final_loss = model.loss(&ds, &w);
    Ok(DistReport {
        curve,
        final_loss,
        versions: version,
        max_observed_staleness: max_stale,
        grad_digest: digest,
        final_w: w,
        measured_tx_bytes: measured_tx,
        measured_rx_bytes: measured_rx,
        sim_time_s: sim_time,
        trace_metrics,
        metrics_text: registry.render(),
        clock_offsets_ns,
    })
}

/// One dist worker's ring machinery (built only under ring topology): the
/// peer links, the reusable reducer scratch, and the error-feedback
/// residual the per-hop budget folds dropped mass into.
struct RingState {
    peer: RingPeer,
    reducer: RingReducer,
    fb: FeedbackState,
    aligned_cfg: collective::AlignedConfig,
    res_sg: SparseGrad,
    ring_in: SparseGrad,
    ring_out: SparseGrad,
}

/// Run the worker side over an established connection. `worker_id` and
/// `codec` must match the hello this connection was opened with (the id
/// seeds the RNG streams; the codec was negotiated at accept time, and the
/// server-shipped config must agree with it), and `hello_version` the
/// transport version that hello announced — a worker impersonating an
/// older peer must keep its own frames telemetry-free (no trace-context
/// stamps), exactly as the server keeps that link probe-free.
///
/// `ring_env` is the transport + bind address this worker would use for
/// its ring listener should the server-shipped config request
/// [`Topology::Ring`] (`"127.0.0.1:0"` for TCP, a per-worker-unique name
/// for in-proc). `None` is fine for star runs; a ring config without a
/// ring environment is a clean error.
pub fn run_worker(
    conn: &mut dyn Connection,
    worker_id: u32,
    codec: WireCodec,
    hello_version: u8,
    ring_env: Option<(&dyn Transport, &str)>,
) -> anyhow::Result<()> {
    let mut rxbuf = Vec::new();
    let mut txbuf = Vec::new();
    let mut pongbuf = Vec::new();
    conn.recv(&mut rxbuf)?;
    let (cfg, server_version) = match frame::decode(&rxbuf)? {
        MsgView::Config { bytes } => RunPlan::decode_with_caps(bytes)?,
        _ => anyhow::bail!("expected config from server"),
    };
    anyhow::ensure!(
        cfg.codec == codec,
        "server config says codec {}, this worker negotiated {codec}",
        cfg.codec
    );
    // Gradient frames carry a trace context only when both ends opted into
    // v4: our own hello announced it AND the config's capability byte says
    // the server understands it.
    let stamp_grads = hello_version >= 4 && server_version >= 4;
    // The CONFIG frame just told us whether to trace — every later frame,
    // solve, sample, and encode on this worker lands in its own recorder,
    // keyed by worker id so per-process traces merge into one timeline.
    let recorder = trace::Recorder::new(&cfg.trace);
    let _trace_guard = trace::install_opt(recorder.as_ref(), worker_id as u16);
    // Ring bootstrap: bind a peer listener, announce its address to the
    // server, learn the right neighbour's from the relay, then form the
    // ring (connect right, accept left — see [`collective::connect_ring`]).
    let mut ring_state: Option<RingState> = None;
    if cfg.ring_mode() {
        let rho = cfg.sparse_density().ok_or_else(|| {
            anyhow::anyhow!(
                "ring topology requires a sparse-message method, not {}",
                cfg.method
            )
        })?;
        let (transport, bind) = ring_env.ok_or_else(|| {
            anyhow::anyhow!("server requested ring topology but this worker has no ring transport")
        })?;
        let mut listener = transport.listen(bind)?;
        frame::encode_ring_addr(&mut txbuf, worker_id, &listener.local_addr());
        conn.send(&txbuf)?;
        recv_answer_pings(conn, &mut rxbuf, &mut pongbuf)?;
        let right_addr = match frame::decode(&rxbuf)? {
            MsgView::RingAddr { worker_id: rid, addr } => {
                anyhow::ensure!(
                    rid as usize == (worker_id as usize + 1) % cfg.workers,
                    "server relayed rank {rid}, expected this worker's right neighbour"
                );
                std::str::from_utf8(addr)
                    .map_err(|_| anyhow::anyhow!("ring address is not utf-8"))?
                    .to_string()
            }
            _ => anyhow::bail!("expected ring address relay from server"),
        };
        let peer = collective::connect_ring(
            transport,
            listener.as_mut(),
            &right_addr,
            worker_id,
            cfg.workers as u32,
            codec,
        )?;
        let budget = collective::default_budget(rho, cfg.d as u32, cfg.workers);
        ring_state = Some(RingState {
            peer,
            reducer: RingReducer::new(codec, Some(budget)),
            fb: FeedbackState::new(cfg.feedback.unwrap_or_default()),
            aligned_cfg: collective::aligned_for(rho, cfg.d as u32, cfg.seed),
            res_sg: SparseGrad::empty(0),
            ring_in: SparseGrad::empty(0),
            ring_out: SparseGrad::empty(0),
        });
    }
    let d = cfg.d;
    let ds = gen_logistic(cfg.n, cfg.d, cfg.c1, cfg.c2, cfg.seed);
    let model = LogisticModel::new(cfg.reg);
    let schedule = CommSchedule::every(cfg.local_steps);
    let h = schedule.period();
    // Same per-worker RNG streams as the in-process parameter server, so a
    // worker's gradient sequence is comparable across deployments.
    let mut rng = Xoshiro256pp::for_worker(cfg.seed, worker_id as usize);
    let mut rand = RandArray::new(
        Xoshiro256pp::for_worker(cfg.seed ^ 0x9511, worker_id as usize),
        (4 * d).max(1 << 12),
    );
    // Same compressor construction as the sync trainer (eps = C1·C2 for
    // GSpar-exact), wrapped in the config-shipped error-feedback memory
    // when the plan asks for it, so sync-vs-dist comparisons compare like
    // with like.
    let mut compressor = crate::api::build_compressor(
        MethodSpec::from_parts(cfg.method, cfg.rho, cfg.c1 * cfg.c2, cfg.qsgd_bits),
        cfg.feedback,
    );
    // Residual-norm gauge in the process-global telemetry registry: under
    // `run_threads` every worker shares the server process, so these show
    // up on the server's `/metrics` endpoint; spawned worker processes
    // keep their own (unserved) global. Registered only when the plan
    // carries feedback state at all.
    let wid_label = worker_id.to_string();
    let residual_gauge = (cfg.feedback.is_some() || cfg.ring_mode()).then(|| {
        telemetry::global().gauge(
            "gsparse_feedback_residual_norm",
            "L2 norm of this worker's error-feedback residual after its latest push.",
            &[("worker", &wid_label)],
        )
    });
    let mut msg = Compressed::Sparse(SparseGrad::empty(d));
    let mut w_local: Vec<f32> = Vec::with_capacity(d);
    let mut grad = vec![0.0f32; d];
    let mut acc = vec![0.0f32; d];
    let mut wire = Vec::new();
    let mut dense_tx: Vec<f32> = Vec::new();
    let mut dense_scratch: Vec<u8> = Vec::new();
    let mut idx = Vec::with_capacity(cfg.batch);
    let mut rounds_done = 0usize;
    let mut block_idx = 0u32;

    loop {
        trace::set_round(block_idx);
        block_idx += 1;
        let version = {
            let mut pull = trace::span(trace::Stage::Pull);
            frame::encode_pull(&mut txbuf);
            conn.send(&txbuf)?;
            recv_answer_pings(conn, &mut rxbuf, &mut pongbuf)?;
            pull.bytes(rxbuf.len() as u64);
            match frame::decode(&rxbuf)? {
                MsgView::Shutdown => break,
                MsgView::Weights { version, w_bytes } => {
                    anyhow::ensure!(w_bytes.len() == 4 * d, "weights length");
                    frame::weights_into(w_bytes, &mut w_local);
                    version
                }
                MsgView::WeightsBatch { version, batch } => {
                    // The batched pull (one frame for the whole tensor
                    // list); this runtime's model is one flat vector, so
                    // the concatenated arena must match `d` exactly.
                    frame::weights_batch_into(batch, &mut w_local);
                    anyhow::ensure!(w_local.len() == d, "weights batch total length");
                    version
                }
                _ => anyhow::bail!("expected weights or shutdown"),
            }
        };
        // One block of `H` local rounds (fewer on the trailing partial
        // block): gradient + local step per round, one compressed
        // accumulated push at the end — nothing else touches the wire.
        let block_len = h.min(cfg.rounds - rounds_done);
        acc.fill(0.0);
        for s in 0..block_len {
            let _step = trace::span(trace::Stage::LocalStep);
            idx.clear();
            for _ in 0..cfg.batch {
                idx.push(rng.next_below(ds.n() as u64) as usize);
            }
            model.grad_minibatch(&ds, &w_local, &idx, &mut grad);
            crate::tensor::axpy(1.0, &grad, &mut acc);
            // The next block starts by pulling fresh weights, so the last
            // iteration's local step would be dead work.
            if h > 1 && s + 1 < block_len {
                let eta_local = cfg.lr / (1.0 + version as f32 / cfg.workers as f32);
                crate::tensor::axpy(-eta_local, &grad, &mut w_local);
            }
        }
        rounds_done += block_len;
        let g_norm_sq = crate::tensor::norm2_sq(&acc) as f64;
        let stats = compressor.compress_into(&acc, &mut rand, &mut msg);
        let q_norm_sq = msg.norm2_sq();
        if let Some(rs) = ring_state.as_mut() {
            let sg_local = match &msg {
                Compressed::Sparse(sg) => sg,
                other => anyhow::bail!("ring hops need sparse messages, got {other:?}"),
            };
            // Re-inject the mass earlier budget caps dropped on this rank
            // (standard error feedback around the collective), then reduce.
            rs.fb.ensure_layout(&[d]);
            rs.res_sg.reset(d);
            {
                let res = rs.fb.layer_residual_mut(0);
                for (i, v) in res.iter_mut().enumerate() {
                    if *v != 0.0 {
                        rs.res_sg.exact.push((i as u32, *v));
                        *v = 0.0;
                    }
                }
            }
            merge::merge_sum(&rs.res_sg, sg_local, &mut rs.ring_in);
            if cfg.aligned {
                rs.reducer.reduce_aligned(
                    &mut rs.peer,
                    &rs.aligned_cfg,
                    &rs.ring_in,
                    &mut rs.ring_out,
                    Some(&mut rs.fb),
                )?;
            } else {
                rs.reducer
                    .reduce(&mut rs.peer, &rs.ring_in, &mut rs.ring_out, Some(&mut rs.fb))?;
            }
            if let Some(g) = &residual_gauge {
                g.set(rs.fb.residual_norm2_sq().sqrt());
            }
            // Rank 0 alone forwards the (every-rank-identical) reduced sum;
            // the header carries this rank's *local* compression stats —
            // the meters want the per-worker quantization picture, and the
            // reduced message's cost is what the payload itself measures.
            if worker_id == 0 {
                crate::coding::encode_with(&rs.ring_out, codec, &mut wire);
                let header = GradHeader {
                    based_on: version,
                    g_norm_sq,
                    q_norm_sq,
                    expected_nnz: stats.expected_nnz,
                    ideal_bits: stats.ideal_bits,
                    kind: 0,
                };
                let mut push = trace::span(trace::Stage::Push);
                push.bytes(wire.len() as u64);
                frame::encode_grad(&mut txbuf, &header, &wire);
                if stamp_grads {
                    frame::stamp_ctx(
                        &mut txbuf,
                        TraceCtx {
                            round: trace::current_round(),
                            sender: worker_id,
                            seq: trace::next_flow_seq(),
                        },
                    );
                }
                conn.send(&txbuf)?;
            }
            continue;
        }
        let (kind, payload): (u8, &[u8]) = match &msg {
            Compressed::Sparse(sg) => {
                crate::coding::encode_with(sg, codec, &mut wire);
                (0, &wire)
            }
            other => {
                // Quantized/dense methods travel as raw dense f32 — our
                // byte codec covers the sparse format only. Both buffers
                // are persistent across rounds.
                other.dense_le_bytes_into(&mut dense_tx, &mut dense_scratch);
                (1, &dense_scratch)
            }
        };
        let header = GradHeader {
            based_on: version,
            g_norm_sq,
            q_norm_sq,
            expected_nnz: stats.expected_nnz,
            ideal_bits: stats.ideal_bits,
            kind,
        };
        {
            let mut push = trace::span(trace::Stage::Push);
            push.bytes(payload.len() as u64);
            let ctx = TraceCtx {
                round: trace::current_round(),
                sender: worker_id,
                seq: trace::next_flow_seq(),
            };
            if cfg.pipeline >= 2 {
                // Pipelined send: header prefix + codec payload as a
                // vectored gather, skipping the payload copy into the
                // frame buffer. The concatenated bytes are exactly the
                // `encode_grad` frame, so any v3 peer decodes this without
                // knowing the sender's depth. The trace context rides on
                // the tag-bearing first segment.
                frame::encode_grad_prefix(&mut txbuf, &header);
                if stamp_grads {
                    frame::stamp_ctx(&mut txbuf, ctx);
                }
                conn.send_vectored(&[&txbuf, payload])?;
            } else {
                frame::encode_grad(&mut txbuf, &header, payload);
                if stamp_grads {
                    frame::stamp_ctx(&mut txbuf, ctx);
                }
                conn.send(&txbuf)?;
            }
        }
        if let Some(g) = &residual_gauge {
            if let Some(r2) = compressor.residual_norm2_sq() {
                g.set(r2.sqrt());
            }
        }
    }
    if let Some(rec) = recorder.as_ref() {
        if TraceConfig::dump_requested() {
            let tag = trace::run_tag(cfg.rounds, topo_name(cfg.topology));
            let _ = trace::dump(rec, &tag, &format!("worker{worker_id}"), cfg.trace.format());
        }
    }
    Ok(())
}

/// Ring-listener bind address for worker `wid` alongside a server bound at
/// `server_bind`: TCP-looking addresses (they contain `:`) get an ephemeral
/// loopback port, in-proc names a per-worker suffix (unique per run because
/// the server bind name already is).
fn ring_bind_addr(server_bind: &str, wid: usize) -> String {
    if server_bind.contains(':') {
        "127.0.0.1:0".to_string()
    } else {
        format!("{server_bind}-ring{wid}")
    }
}

/// Launch a full cluster as threads in this process: one server plus
/// `cfg.workers` workers, all talking through `transport` (use
/// [`crate::transport::InProcTransport`] for channels or [`TcpTransport`]
/// with a `127.0.0.1:0` bind for real loopback sockets).
pub fn run_threads<T>(transport: T, bind_addr: &str, cfg: &RunPlan) -> anyhow::Result<DistReport>
where
    T: Transport + Clone + 'static,
{
    let mut listener = transport.listen(bind_addr)?;
    let addr = listener.local_addr();
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(cfg.workers);
        for wid in 0..cfg.workers {
            let transport = transport.clone();
            let addr = addr.clone();
            let ring_bind = ring_bind_addr(bind_addr, wid);
            let codec = cfg.codec;
            handles.push(scope.spawn(move || -> anyhow::Result<()> {
                let hello = Hello::with_codec(wid as u32, codec);
                let mut conn = transport.connect(&addr, &hello)?;
                run_worker(
                    conn.as_mut(),
                    wid as u32,
                    codec,
                    hello.version,
                    Some((&transport, ring_bind.as_str())),
                )
            }));
        }
        let report = serve(listener.as_mut(), cfg);
        // Join every worker before propagating, and surface the server's
        // error first — it is the root cause when both sides fail.
        let worker_results: Vec<anyhow::Result<()>> = handles
            .into_iter()
            .map(|h| h.join().expect("worker thread panicked"))
            .collect();
        let report = report?;
        for r in worker_results {
            r?;
        }
        Ok(report)
    })
}

/// Launch a real multi-process cluster over loopback TCP: the server runs
/// in this process, and each worker is spawned as `bin worker --addr …
/// --id …` (pass [`std::env::current_exe`] for `bin` from the `gsparse`
/// binary itself, or `CARGO_BIN_EXE_gsparse` from integration tests).
pub fn run_processes(
    bin: &std::path::Path,
    bind_addr: &str,
    cfg: &RunPlan,
) -> anyhow::Result<DistReport> {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Arc, Mutex};

    let transport = TcpTransport::new();
    let mut listener = transport.listen(bind_addr)?;
    let addr = listener.local_addr();
    let mut children = Vec::with_capacity(cfg.workers);
    for wid in 0..cfg.workers {
        let child = std::process::Command::new(bin)
            .arg("worker")
            .arg("--addr")
            .arg(&addr)
            .arg("--id")
            .arg(wid.to_string())
            .arg("--codec")
            .arg(cfg.codec.to_string())
            .stdin(std::process::Stdio::null())
            .spawn()
            .map_err(|e| anyhow::anyhow!("spawning worker {wid} ({}): {e}", bin.display()))?;
        children.push(child);
    }
    // Watchdog: `serve` blocks in accept/recv, so a worker that dies
    // before (or instead of) participating would hang the server forever.
    // On an unsuccessful early exit, poison the listener with an
    // out-of-range hello — serve's validation turns that into a clean
    // error, which unwinds the whole launch.
    let children = Arc::new(Mutex::new(children));
    let done = Arc::new(AtomicBool::new(false));
    let watchdog = {
        let children = Arc::clone(&children);
        let done = Arc::clone(&done);
        let addr = addr.clone();
        let codec = cfg.codec;
        std::thread::spawn(move || {
            while !done.load(Ordering::Acquire) {
                let failed = {
                    let mut kids = children.lock().expect("children lock");
                    kids.iter_mut().any(|c| {
                        matches!(c.try_wait(), Ok(Some(status)) if !status.success())
                    })
                };
                if failed {
                    // The poison hello matches the negotiated codec so it
                    // reaches the id check and fails there cleanly.
                    let _ = TcpTransport::new()
                        .connect(&addr, &Hello::with_codec(u32::MAX, codec));
                    return;
                }
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
        })
    };
    let report = serve(listener.as_mut(), cfg);
    done.store(true, Ordering::Release);
    let _ = watchdog.join();
    let mut kids = children.lock().expect("children lock");
    for (wid, child) in kids.iter_mut().enumerate() {
        if report.is_err() {
            let _ = child.kill();
        }
        let status = child.wait()?;
        if report.is_ok() {
            anyhow::ensure!(status.success(), "worker {wid} exited with {status}");
        }
    }
    report
}

/// Convenience wrapper used by the figure drivers and the example: run the
/// distributed logistic-regression workload and also report the dense
/// baseline `f*` so losses print as suboptimality.
pub fn f_star_for(cfg: &RunPlan) -> f64 {
    let ds = gen_logistic(cfg.n, cfg.d, cfg.c1, cfg.c2, cfg.seed);
    let model = LogisticModel::new(cfg.reg);
    estimate_f_star(&ds, &model, 200, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::InProcTransport;

    fn small_cfg() -> RunPlan {
        RunPlan {
            workers: 3,
            rounds: 60,
            n: 192,
            d: 96,
            batch: 4,
            ..Default::default()
        }
    }

    #[test]
    fn config_roundtrip() {
        let codec_at = CONFIG_CODEC_AT;
        for codec in [WireCodec::Raw, WireCodec::Entropy] {
            let cfg = RunPlan {
                method: Method::Qsgd,
                seed: 0xDEADBEEF,
                codec,
                local_steps: 3,
                feedback: Some(FeedbackConfig::with_decay(0.75)),
                pipeline: 4,
                trace: TraceConfig::on(),
                topology: Topology::Ring,
                aligned: true,
                ..small_cfg()
            };
            let bytes = cfg.encode();
            assert_eq!(RunPlan::decode(&bytes).unwrap(), cfg);
            // v7 appends the server's transport version as a capability
            // byte; it travels next to the plan, not inside it.
            let (back, caps) = RunPlan::decode_with_caps(&bytes).unwrap();
            assert_eq!(back, cfg);
            assert_eq!(caps, frame::TRANSPORT_VERSION);
            assert!(RunPlan::decode(&bytes[..bytes.len() - 1]).is_err());
            let mut bad = bytes.clone();
            bad[1] = 200;
            assert!(RunPlan::decode(&bad).is_err());
            let mut bad = bytes.clone();
            bad[codec_at] = 9; // unknown codec id
            assert!(RunPlan::decode(&bad).is_err());
            let mut bad = bytes.clone();
            bad[codec_at + 5] = 7; // unknown feedback flag
            assert!(RunPlan::decode(&bad).is_err());
            // local_steps = 0 is not a valid shipped schedule.
            let mut bad = bytes.clone();
            bad[codec_at + 1..codec_at + 5].copy_from_slice(&0u32.to_le_bytes());
            assert!(RunPlan::decode(&bad).is_err());
            // Neither is pipeline depth 0.
            let mut bad = bytes.clone();
            bad[codec_at + 10..codec_at + 14].copy_from_slice(&0u32.to_le_bytes());
            assert!(RunPlan::decode(&bad).is_err());
            // Unknown trace mode bytes are refused.
            let mut bad = bytes.clone();
            bad[codec_at + 14] = 9;
            assert!(RunPlan::decode(&bad).is_err());
            // So are unknown topology ids and aligned flags.
            let mut bad = bytes.clone();
            bad[codec_at + 19] = 9;
            assert!(RunPlan::decode(&bad).is_err());
            let mut bad = bytes.clone();
            bad[codec_at + 20] = 7;
            assert!(RunPlan::decode(&bad).is_err());
        }
        // The default plan (no feedback, every-round) roundtrips too, as
        // does an explicitly trace-off / JSONL-trace one.
        for trace in [TraceConfig::Off, TraceConfig::from_env(), TraceConfig::on()] {
            let cfg = RunPlan {
                trace,
                ..small_cfg()
            };
            assert_eq!(RunPlan::decode(&cfg.encode()).unwrap(), cfg);
        }
    }

    #[test]
    fn local_steps_ship_fewer_frames_and_bytes_deterministically() {
        // H = 4 over the same total local-round budget: every wire column
        // must scale with blocks (⌈rounds/H⌉), not rounds — local rounds
        // provably ship nothing — and the run stays deterministic and
        // bitwise identical across backends (tests/feedback.rs covers the
        // TCP leg).
        let base = RunPlan {
            rounds: 64,
            ..small_cfg()
        };
        let h4 = RunPlan {
            local_steps: 4,
            ..base.clone()
        };
        let every = run_threads(InProcTransport::new(), "ls-1", &base).unwrap();
        let local = run_threads(InProcTransport::new(), "ls-4", &h4).unwrap();
        let local2 = run_threads(InProcTransport::new(), "ls-4b", &h4).unwrap();
        assert_eq!(local.grad_digest, local2.grad_digest);
        assert_eq!(local.final_w, local2.final_w);
        // 64 rounds → 16 blocks → 16 pushes per worker.
        assert_eq!(local.versions, 16 * base.workers as u64);
        assert_eq!(every.versions, 64 * base.workers as u64);
        assert_eq!(
            local.curve.ledger.messages * 4,
            every.curve.ledger.messages
        );
        // Per-link frames: 1 hello + 1 config + (blocks + 1) pulls +
        // blocks weights + blocks grads + 1 shutdown = 3·blocks + 4, plus
        // 2 frames (ping + pong) per clock probe on every v4 link.
        let frames_for = |blocks: u64| {
            (3 * blocks + 4 + 2 * probe_count(blocks as usize) as u64) * base.workers as u64
        };
        assert_eq!(local.curve.ledger.measured_frames, frames_for(16));
        assert_eq!(every.curve.ledger.measured_frames, frames_for(64));
        assert!(
            local.curve.ledger.measured_bytes < every.curve.ledger.measured_bytes / 3,
            "H=4 measured {} should be well under a third of H=1's {}",
            local.curve.ledger.measured_bytes,
            every.curve.ledger.measured_bytes
        );
        // Still optimizes: the accumulated-gradient schedule must reach a
        // loss comparable to (here: below a loose multiple of) every-round.
        let ds = gen_logistic(base.n, base.d, base.c1, base.c2, base.seed);
        let model = LogisticModel::new(base.reg);
        let f0 = model.loss(&ds, &vec![0.0; base.d]);
        assert!(local.final_loss < f0, "{f0} -> {}", local.final_loss);
    }

    #[test]
    fn feedback_plan_converges_and_is_deterministic() {
        let cfg = RunPlan {
            method: Method::TopK,
            rho: 0.05,
            feedback: Some(FeedbackConfig::default()),
            ..small_cfg()
        };
        let a = run_threads(InProcTransport::new(), "fb-a", &cfg).unwrap();
        let b = run_threads(InProcTransport::new(), "fb-b", &cfg).unwrap();
        assert_eq!(a.grad_digest, b.grad_digest);
        assert_eq!(a.final_w, b.final_w);
        let ds = gen_logistic(cfg.n, cfg.d, cfg.c1, cfg.c2, cfg.seed);
        let model = LogisticModel::new(cfg.reg);
        let f0 = model.loss(&ds, &vec![0.0; cfg.d]);
        assert!(a.final_loss < f0, "{f0} -> {}", a.final_loss);
        // And the feedback run genuinely differs from the memoryless one.
        let plain = RunPlan {
            feedback: None,
            ..cfg.clone()
        };
        let p = run_threads(InProcTransport::new(), "fb-p", &plain).unwrap();
        assert_ne!(p.grad_digest, a.grad_digest);
    }

    #[test]
    fn entropy_codec_reaches_identical_weights_with_fewer_bytes() {
        // Same seeds, same schedule, different wire codec: the decoded
        // gradients are identical, so the weight trajectory is bitwise
        // equal — only the bytes on the wire shrink.
        let raw_cfg = small_cfg();
        let ent_cfg = RunPlan {
            codec: WireCodec::Entropy,
            ..small_cfg()
        };
        let raw = run_threads(InProcTransport::new(), "raw", &raw_cfg).unwrap();
        let ent = run_threads(InProcTransport::new(), "ent", &ent_cfg).unwrap();
        assert_eq!(raw.final_w, ent.final_w);
        assert_eq!(raw.versions, ent.versions);
        assert!(
            ent.curve.ledger.wire_bytes < raw.curve.ledger.wire_bytes,
            "entropy {} !< raw {}",
            ent.curve.ledger.wire_bytes,
            raw.curve.ledger.wire_bytes
        );
        assert!(
            ent.curve.ledger.measured_bytes < raw.curve.ledger.measured_bytes,
            "entropy framed {} !< raw framed {}",
            ent.curve.ledger.measured_bytes,
            raw.curve.ledger.measured_bytes
        );
        // Every sparse byte lands in the entropy column of the ledger.
        assert_eq!(
            ent.curve.ledger.wire_bytes_by_codec[WireCodec::Entropy.index()],
            ent.curve.ledger.wire_bytes
        );
        assert_eq!(ent.curve.ledger.wire_bytes_by_codec[WireCodec::Raw.index()], 0);
    }

    #[test]
    fn inproc_cluster_converges_and_counts_bytes() {
        let cfg = small_cfg();
        let report = run_threads(InProcTransport::new(), "ps", &cfg).unwrap();
        let ds = gen_logistic(cfg.n, cfg.d, cfg.c1, cfg.c2, cfg.seed);
        let model = LogisticModel::new(cfg.reg);
        let f0 = model.loss(&ds, &vec![0.0; cfg.d]);
        assert!(report.final_loss < f0, "{f0} -> {}", report.final_loss);
        assert_eq!(report.versions, (cfg.rounds * cfg.workers) as u64);
        assert!(report.max_observed_staleness <= cfg.workers as u64 - 1);
        assert!(report.curve.ledger.wire_bytes > 0);
        // Measured framing must exceed the payload bytes it carries.
        assert!(report.curve.ledger.measured_bytes > report.curve.ledger.wire_bytes);
        assert!(report.sim_time_s > 0.0);
        assert!(!report.curve.points.is_empty());
    }

    #[test]
    fn inproc_runs_are_deterministic() {
        let cfg = small_cfg();
        let a = run_threads(InProcTransport::new(), "a", &cfg).unwrap();
        let b = run_threads(InProcTransport::new(), "b", &cfg).unwrap();
        assert_eq!(a.grad_digest, b.grad_digest);
        assert_eq!(a.final_w, b.final_w);
        assert_eq!(
            a.curve.ledger.measured_bytes,
            b.curve.ledger.measured_bytes
        );
    }

    #[test]
    fn pipelined_workers_ship_bitwise_identical_runs() {
        // Depth ≥ 2 only changes *how* the worker hands bytes to the
        // connection (vectored header + payload), never which bytes: the
        // digest, weights, and measured ledger all match depth 1 exactly.
        let base = small_cfg();
        let piped = RunPlan {
            pipeline: 2,
            ..small_cfg()
        };
        let a = run_threads(InProcTransport::new(), "pd-1", &base).unwrap();
        let b = run_threads(InProcTransport::new(), "pd-2", &piped).unwrap();
        assert_eq!(a.grad_digest, b.grad_digest);
        assert_eq!(a.final_w, b.final_w);
        assert_eq!(
            a.curve.ledger.measured_bytes,
            b.curve.ledger.measured_bytes
        );
        assert_eq!(
            a.curve.ledger.measured_frames,
            b.curve.ledger.measured_frames
        );
    }

    #[test]
    fn ring_topology_applies_once_per_block_and_is_deterministic() {
        let star = small_cfg();
        let ring = RunPlan {
            topology: Topology::Ring,
            ..small_cfg()
        };
        let s = run_threads(InProcTransport::new(), "ring-s", &star).unwrap();
        let r = run_threads(InProcTransport::new(), "ring-r", &ring).unwrap();
        let r2 = run_threads(InProcTransport::new(), "ring-r2", &ring).unwrap();
        assert_eq!(r.grad_digest, r2.grad_digest);
        assert_eq!(r.final_w, r2.final_w);
        // One ring-reduced apply per block instead of M; the reduced push
        // is always based on the block's own weight version.
        assert_eq!(r.versions, ring.rounds as u64);
        assert_eq!(s.versions, (star.rounds * star.workers) as u64);
        assert_eq!(r.max_observed_staleness, 0);
        // The end-to-end column records rank 0's reduced frames; star has
        // no such column entry. The hop column stays 0 server-side (the
        // ring links are worker-owned).
        assert!(r.curve.ledger.end_to_end_bytes > 0);
        assert_eq!(s.curve.ledger.end_to_end_bytes, 0);
        assert_eq!(r.curve.ledger.hop_bytes, 0);
        // Per-link server frames: hello + config + ring-addr in/out +
        // (blocks+1) pulls + blocks weights + shutdown = 2·blocks + 6, plus
        // 2 frames per clock probe, plus blocks gradient pushes on rank 0's
        // link only — every other rank ships its gradient over the ring,
        // not to the server.
        let blocks = ring.rounds as u64;
        assert_eq!(
            r.curve.ledger.measured_frames,
            (2 * blocks + 6 + 2 * probe_count(blocks as usize) as u64) * ring.workers as u64
                + blocks
        );
        // Still optimizes.
        let ds = gen_logistic(ring.n, ring.d, ring.c1, ring.c2, ring.seed);
        let model = LogisticModel::new(ring.reg);
        let f0 = model.loss(&ds, &vec![0.0; ring.d]);
        assert!(r.final_loss < f0, "{f0} -> {}", r.final_loss);
    }

    #[test]
    fn aligned_ring_is_deterministic_and_converges() {
        let cfg = RunPlan {
            topology: Topology::Ring,
            aligned: true,
            method: Method::TopK,
            rho: 0.1,
            ..small_cfg()
        };
        let a = run_threads(InProcTransport::new(), "aring-a", &cfg).unwrap();
        let b = run_threads(InProcTransport::new(), "aring-b", &cfg).unwrap();
        assert_eq!(a.grad_digest, b.grad_digest);
        assert_eq!(a.final_w, b.final_w);
        let ds = gen_logistic(cfg.n, cfg.d, cfg.c1, cfg.c2, cfg.seed);
        let model = LogisticModel::new(cfg.reg);
        let f0 = model.loss(&ds, &vec![0.0; cfg.d]);
        assert!(a.final_loss < f0, "{f0} -> {}", a.final_loss);
        // Aligned hops carry no index bytes, so the digest must differ from
        // the index-carrying ring (different selected sets in general).
        let plain = RunPlan {
            aligned: false,
            ..cfg.clone()
        };
        let p = run_threads(InProcTransport::new(), "aring-p", &plain).unwrap();
        assert_ne!(p.grad_digest, a.grad_digest);
    }

    #[test]
    fn metrics_registry_matches_ledger_and_clocks_sample() {
        let cfg = small_cfg();
        let report = run_threads(InProcTransport::new(), "metrics", &cfg).unwrap();
        // Per-worker round counters cover every round, and the wire-byte
        // counter is byte-for-byte the CommLedger column — the acceptance
        // bar for a mid-run scrape being trustworthy.
        for w in 0..cfg.workers {
            let needle = format!("gsparse_rounds_total{{worker=\"{w}\"}} {}", cfg.rounds);
            assert!(
                report.metrics_text.contains(&needle),
                "missing `{needle}` in rendered metrics:\n{}",
                report.metrics_text
            );
        }
        let wire = format!("gsparse_wire_bytes_total {}", report.curve.ledger.wire_bytes);
        assert!(report.metrics_text.contains(&wire), "missing `{wire}`");
        assert!(report.metrics_text.contains("gsparse_trace_dropped_total 0"));
        assert!(report.metrics_text.contains("# TYPE gsparse_round_latency_seconds histogram"));
        // Every v4 link produced clock samples, and same-process clocks
        // must read as near-zero offset (well under a second).
        assert_eq!(report.clock_offsets_ns.len(), cfg.workers);
        for (wid, off) in &report.clock_offsets_ns {
            assert!((*wid as usize) < cfg.workers);
            assert!(off.abs() < 1_000_000_000, "worker {wid} offset {off}ns");
        }
    }

    #[test]
    fn ring_with_dense_method_is_a_clean_error() {
        let cfg = RunPlan {
            topology: Topology::Ring,
            method: Method::Dense,
            rounds: 2,
            ..small_cfg()
        };
        assert!(run_threads(InProcTransport::new(), "ring-dense", &cfg).is_err());
    }

    #[test]
    fn dense_method_travels_as_raw_f32() {
        let cfg = RunPlan {
            method: Method::Dense,
            rounds: 4,
            ..small_cfg()
        };
        let report = run_threads(InProcTransport::new(), "dense", &cfg).unwrap();
        // Every gradient frame carries d × 4 payload bytes.
        assert_eq!(
            report.curve.ledger.wire_bytes,
            (cfg.rounds * cfg.workers * cfg.d * 4) as u64
        );
    }
}
