//! Asynchronous parameter server with bounded staleness — the deployment
//! style the paper's §2 surveys (SSP / parameter-server systems) and §3
//! covers with "asynchronous algorithms can also be used with our technique
//! in a similar fashion".
//!
//! Topology: one server thread owns the weights; W worker threads loop
//! { pull weights → minibatch gradient → sparsify → **encode** → push }.
//! Pushes cross the in-process [`crate::transport`] as framed wire bytes
//! (the same §3.3 codec as the synchronous path, behind the same
//! `Transport` abstraction the TCP runtime uses), so this is an honest
//! distributed-system simulation at the process level, and the transport's
//! per-link counters give the report a *measured* byte column. The server
//! applies updates as they arrive (`w ← w − η_t Q(g)`) and stamps each
//! weight version. The
//! **stale-synchronous-parallel bound** gates the *fastest* worker: worker
//! `m` may start its `c`-th iteration only while
//! `c − min_m' clock(m') ≤ max_staleness`, the classic SSP condition — the
//! slowest worker is always runnable, so the protocol cannot deadlock.
//!
//! Entry point: [`crate::api::Session::param_server`] with a [`PsTask`];
//! the old [`PsConfig`] struct survives as a deprecated shim.

use crate::api::{MethodSpec, PsTask, Session};
use crate::coding::WireCodec;
use crate::config::Method;
use crate::data::Dataset;
use crate::metrics::{CurvePoint, RunCurve, VarianceRatio};
use crate::model::ConvexModel;
use crate::rngkit::{RandArray, Xoshiro256pp};
use crate::sparsify::Compressed;
use crate::transport::frame::{self, GradHeader, MsgView};
use crate::transport::{Connection, Hello, InProcTransport, Mux, Transport};
use std::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Parameter-server run configuration (deprecated shim of the Session API).
#[deprecated(
    since = "0.2.0",
    note = "build a gsparse::api::Session (method/codec/seed/workers) and pass the \
            remaining knobs via gsparse::api::PsTask to Session::param_server"
)]
#[derive(Clone, Debug)]
pub struct PsConfig {
    pub workers: usize,
    /// Total pushes across all workers.
    pub total_pushes: usize,
    /// SSP bound: max versions a worker's weights may lag the server.
    pub max_staleness: u64,
    pub method: Method,
    pub rho: f32,
    pub batch: usize,
    pub lr: f32,
    pub seed: u64,
    /// Wire codec for sparse gradient pushes (negotiated in each worker's
    /// handshake, exactly as on the TCP runtime).
    pub codec: WireCodec,
}

#[allow(deprecated)]
impl Default for PsConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            total_pushes: 2000,
            max_staleness: 8,
            method: Method::GSpar,
            rho: 0.1,
            batch: 8,
            lr: 0.5,
            seed: 42,
            codec: WireCodec::Raw,
        }
    }
}

/// Outcome of a parameter-server run.
#[derive(Debug, Clone)]
pub struct PsReport {
    pub curve: RunCurve,
    pub final_loss: f64,
    /// Server-side weight version (== total applied pushes).
    pub versions: u64,
    /// Times a worker blocked on the staleness bound.
    pub staleness_stalls: u64,
    /// Max observed staleness at pull time.
    pub max_observed_staleness: u64,
    pub wire_bytes: u64,
    /// `wire_bytes` split by the codec each push was encoded under
    /// (indexed by [`WireCodec::index`]; dense/quantized fallbacks land in
    /// the `Raw` column).
    pub wire_bytes_by_codec: [u64; 2],
    /// Measured framed bytes on the worker→server links (payloads plus
    /// length prefixes plus handshakes), from the transport counters.
    pub measured_bytes: u64,
    /// Aggregated trace metrics (counters, gauges, log₂ latency
    /// histograms) when the session ran with tracing enabled; `None` under
    /// [`crate::trace::TraceConfig::Off`].
    pub trace_metrics: Option<crate::trace::MetricsSnapshot>,
}

/// Shared weight store with versioning (server publishes, workers pull).
struct WeightStore {
    state: Mutex<(Vec<f32>, u64)>, // (weights, version)
}

/// Run the asynchronous parameter server under the old config struct.
#[deprecated(
    since = "0.2.0",
    note = "build a gsparse::api::Session and call Session::param_server with a PsTask"
)]
#[allow(deprecated)]
pub fn run_param_server(
    cfg: &PsConfig,
    ds: &Dataset,
    model: &(dyn ConvexModel + Sync),
) -> PsReport {
    let session = Session::builder()
        .method(MethodSpec::from_parts(cfg.method, cfg.rho, 0.0, 4))
        .codec(cfg.codec)
        .seed(cfg.seed)
        .workers(cfg.workers)
        .build();
    let task = PsTask {
        // The shim keeps the old `total_pushes` name; the Session-era task
        // calls the same budget `total_iterations`.
        total_iterations: cfg.total_pushes,
        max_staleness: cfg.max_staleness,
        batch: cfg.batch,
        lr: cfg.lr,
    };
    session.param_server(&task, ds, model)
}

/// The canonical SSP runner behind [`Session::param_server`].
pub(crate) fn run_session(
    session: &Session,
    task: &PsTask,
    ds: &Dataset,
    model: &(dyn ConvexModel + Sync),
) -> PsReport {
    let d = ds.d();
    let workers = session.workers();
    let codec = session.codec();
    let seed = session.seed();
    let spec = session.method();
    let feedback = session.feedback();
    // Local-step scheduling: each worker claims up to H iterations from
    // the push budget, pulls once, runs them locally (accumulating the
    // gradient sum, stepping its own iterate), and pushes the compressed
    // accumulation — one pull + one push per H iterations on the wire.
    let h = session.local_steps();
    let store = Arc::new(WeightStore {
        state: Mutex::new((vec![0.0f32; d], 0)),
    });
    let budget = Arc::new(AtomicU64::new(task.total_iterations as u64));
    let stalls = Arc::new(AtomicU64::new(0));
    let max_stale = Arc::new(AtomicU64::new(0));
    // SSP clocks: per-worker iteration counters (u64::MAX = exited).
    let clocks = Arc::new((Mutex::new(vec![0u64; workers]), Condvar::new()));
    // Server-side applied-update counter: the gate also bounds how far any
    // worker may run ahead of what the server has *applied*, which caps the
    // channel backlog (otherwise "staleness" is unbounded pipeline lag).
    let applied = Arc::new(AtomicU64::new(0));
    // Total pushes sent (global units, vs `applied`): bounds the channel
    // backlog so "staleness" cannot hide as pipeline lag while the server
    // is busy (e.g. taking a loss snapshot).
    let sent = Arc::new(AtomicU64::new(0));
    // Gradient iterations actually computed (the data-passes numerator:
    // a worker's trailing block may claim fewer than H iterations).
    let iterations_done = Arc::new(AtomicU64::new(0));
    // Worker → server pushes travel through the transport layer: one
    // framed in-process link per worker, multiplexed into arrival order at
    // the server — same abstraction, different backend, as the TCP runtime.
    let transport = InProcTransport::new();
    let mut listener = transport.listen("ssp-ps").expect("in-process listen");
    let mut worker_conns: Vec<Option<Box<dyn Connection>>> = (0..workers)
        .map(|wid| {
            Some(
                transport
                    .connect("ssp-ps", &Hello::with_codec(wid as u32, codec))
                    .expect("in-process connect"),
            )
        })
        .collect();
    let server_ends = crate::transport::accept_n(listener.as_mut(), workers, codec)
        .expect("in-process accept");
    let link_counters: Vec<_> = server_ends.iter().map(|c| c.counters()).collect();
    let mut mux = Mux::new(
        server_ends
            .into_iter()
            .enumerate()
            .map(|(wid, conn)| (wid as u32, conn))
            .collect(),
    );
    let start = Instant::now();

    // Observability: one recorder shared by the server thread and every
    // worker thread (each installs its own per-thread context, so the ring
    // buffers never contend). `TraceConfig::Off` makes all of this no-ops.
    let trace_cfg = session.trace();
    let recorder = crate::trace::Recorder::new(&trace_cfg);
    let _trace_guard = crate::trace::install_opt(recorder.as_ref(), crate::trace::SERVER_WORKER);

    let mut curve = RunCurve::new(format!(
        "ps-{}(st={})",
        spec.method(),
        task.max_staleness
    ));
    let mut var_meter = VarianceRatio::default();
    let mut wire_bytes = 0u64;

    let (total_iterations, max_staleness, batch, lr) =
        (task.total_iterations, task.max_staleness, task.batch, task.lr);

    std::thread::scope(|scope| {
        // ---- workers ----
        for wid in 0..workers {
            let store = Arc::clone(&store);
            let budget = Arc::clone(&budget);
            let stalls = Arc::clone(&stalls);
            let max_stale = Arc::clone(&max_stale);
            let clocks = Arc::clone(&clocks);
            let applied = Arc::clone(&applied);
            let sent = Arc::clone(&sent);
            let iterations_done = Arc::clone(&iterations_done);
            let mut conn = worker_conns[wid].take().expect("connection unclaimed");
            let worker_recorder = recorder.clone();
            scope.spawn(move || {
                let _trace_guard =
                    crate::trace::install_opt(worker_recorder.as_ref(), wid as u16);
                let mut rng = Xoshiro256pp::for_worker(seed, wid);
                let mut rand = RandArray::new(
                    Xoshiro256pp::for_worker(seed ^ 0x9511, wid),
                    (4 * d).max(1 << 12),
                );
                let mut compressor = crate::api::build_compressor(spec, feedback);
                let mut w_local = vec![0.0f32; d];
                let mut grad = vec![0.0f32; d];
                // Gradient sum accumulated over one local-step block (for
                // H = 1 this is bitwise the single minibatch gradient).
                let mut acc = vec![0.0f32; d];
                // Reused across pushes: the compressor writes into `msg`
                // in place; only the wire bytes are freshly allocated, since
                // they are moved into the channel.
                let mut msg = Compressed::Sparse(crate::sparsify::SparseGrad::empty(d));
                // Reused per-push buffers: codec bytes, the dense fallback,
                // and the framed message (the transport copies the frame
                // into the link).
                let mut wire: Vec<u8> = Vec::new();
                let mut dense_tx: Vec<f32> = Vec::new();
                let mut frame_buf: Vec<u8> = Vec::new();
                let mut my_version = 0u64;
                let mut block: u32 = 0;
                let (clock_mx, clock_cv) = &*clocks;
                loop {
                    crate::trace::set_round(block);
                    block = block.wrapping_add(1);
                    let _round_span = crate::trace::span(crate::trace::Stage::Round);
                    // Claim up to H iterations from the budget (H = 1:
                    // exactly the historical one-claim-per-push loop).
                    let mut claimed = 0usize;
                    while claimed < h
                        && budget
                            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |b| {
                                b.checked_sub(1)
                            })
                            .is_ok()
                    {
                        claimed += 1;
                    }
                    if claimed == 0 {
                        break;
                    }
                    // SSP gate: block while this worker is more than
                    // `max_staleness` iterations ahead of the slowest live
                    // worker. The slowest worker always passes — no deadlock.
                    {
                        let _wait_span =
                            crate::trace::span(crate::trace::Stage::BarrierWait);
                        let mut cl = clock_mx.lock().unwrap();
                        loop {
                            let min_clock = cl
                                .iter()
                                .copied()
                                .filter(|&c| c != u64::MAX)
                                .min()
                                .unwrap_or(u64::MAX);
                            // (a) classic SSP: ≤ max_staleness ahead of the
                            //     slowest live worker (per-worker clocks);
                            // (b) backlog: ≤ workers·(max_staleness+1)
                            //     sent-but-unapplied pushes (global units).
                            let ssp_violated =
                                cl[wid].saturating_sub(min_clock) > max_staleness;
                            let backlog = sent
                                .load(Ordering::Acquire)
                                .saturating_sub(applied.load(Ordering::Acquire));
                            let backlog_violated =
                                backlog > workers as u64 * (max_staleness + 1);
                            if ssp_violated || backlog_violated {
                                stalls.fetch_add(1, Ordering::Relaxed);
                                cl = clock_cv.wait(cl).unwrap();
                            } else {
                                break;
                            }
                        }
                    }
                    // Pull the freshest weights (records observed staleness).
                    {
                        let mut pull_span = crate::trace::span(crate::trace::Stage::Pull);
                        pull_span.bytes((d * 4) as u64);
                        let guard = store.state.lock().unwrap();
                        let (ref w, version) = *guard;
                        max_stale
                            .fetch_max(version.saturating_sub(my_version), Ordering::Relaxed);
                        w_local.copy_from_slice(w);
                        my_version = version;
                    }
                    // Local block: `claimed` gradient computations against
                    // the worker's own iterate, no wire traffic until the
                    // accumulated sum is pushed below.
                    let mut local_span = crate::trace::span(crate::trace::Stage::LocalStep);
                    local_span.layer(claimed as u32);
                    acc.fill(0.0);
                    for s in 0..claimed {
                        let idx: Vec<usize> = (0..batch)
                            .map(|_| rng.next_below(ds.n() as u64) as usize)
                            .collect();
                        model.grad_minibatch(ds, &w_local, &idx, &mut grad);
                        crate::tensor::axpy(1.0, &grad, &mut acc);
                        // The next block starts with a fresh pull, so the
                        // last iteration's local step would be dead work.
                        if h > 1 && s + 1 < claimed {
                            let eta_local = lr / (1.0 + my_version as f32 / workers as f32);
                            crate::tensor::axpy(-eta_local, &grad, &mut w_local);
                        }
                    }
                    iterations_done.fetch_add(claimed as u64, Ordering::Relaxed);
                    drop(local_span);
                    let mut push_span = crate::trace::span(crate::trace::Stage::Push);
                    let g_norm = crate::tensor::norm2_sq(&acc) as f64;
                    let stats = compressor.compress_into(&acc, &mut rand, &mut msg);
                    let q_norm = msg.norm2_sq();
                    let (kind, payload): (u8, &[u8]) = match &msg {
                        Compressed::Sparse(sg) => {
                            crate::coding::encode_with(sg, codec, &mut wire);
                            (0, &wire)
                        }
                        other => {
                            // Quantized/dense fallback: raw f32 LE bytes,
                            // through the persistent scratch buffers.
                            other.dense_le_bytes_into(&mut dense_tx, &mut wire);
                            (1, &wire)
                        }
                    };
                    let header = GradHeader {
                        based_on: my_version,
                        g_norm_sq: g_norm,
                        q_norm_sq: q_norm,
                        expected_nnz: stats.expected_nnz,
                        ideal_bits: stats.ideal_bits,
                        kind,
                    };
                    frame::encode_grad(&mut frame_buf, &header, payload);
                    push_span.bytes(frame_buf.len() as u64);
                    sent.fetch_add(1, Ordering::Release);
                    let send_failed = conn.send(&frame_buf).is_err();
                    drop(push_span);
                    // Advance this worker's SSP clock and wake gated peers.
                    {
                        let mut cl = clock_mx.lock().unwrap();
                        cl[wid] += 1;
                    }
                    clock_cv.notify_all();
                    if send_failed {
                        break;
                    }
                }
                // Mark exited so peers never gate on a dead worker.
                {
                    let mut cl = clock_mx.lock().unwrap();
                    cl[wid] = u64::MAX;
                }
                clock_cv.notify_all();
            });
        }
        // ---- server (this thread) ----
        let mut t = 0u64;
        let record_every = (total_iterations / 50).max(1) as u64;
        let mut decode_slot = crate::sparsify::SparseGrad::empty(0);
        while let Some((_wid, frame_bytes)) = mux.recv() {
            let frame_bytes = frame_bytes.expect("worker link healthy");
            let (header, payload) = match frame::decode(&frame_bytes).expect("worker-encoded") {
                MsgView::Grad { header, payload } => (header, payload),
                other => panic!("unexpected message from worker: {other:?}"),
            };
            t += 1;
            crate::trace::set_round(t as u32);
            let eta = lr / (1.0 + (t as f32 / workers as f32));
            {
                let mut apply_span = crate::trace::span(crate::trace::Stage::Apply);
                apply_span.bytes(payload.len() as u64);
                let mut guard = store.state.lock().unwrap();
                let (ref mut w, ref mut version) = *guard;
                if header.kind == 0 {
                    crate::coding::decode_into(payload, &mut decode_slot)
                        .expect("worker-encoded");
                    decode_slot.add_into(-eta, w);
                    wire_bytes += payload.len() as u64;
                } else {
                    frame::add_dense_le(payload, -eta, w);
                }
                *version += 1;
            }
            // Same wire-column convention as the other coordinators: codec
            // bytes under the negotiated codec, dense fallbacks at their
            // idealized size under `Raw`.
            if header.kind == 0 {
                curve
                    .ledger
                    .record_codec(header.ideal_bits, payload.len() as u64, codec);
            } else {
                curve.ledger.record(header.ideal_bits, (header.ideal_bits / 8).max(1));
            }
            // Publish the applied counter and wake SSP-gated workers. The
            // empty lock acquisition orders the publish against a worker's
            // gate check, preventing a missed wakeup.
            applied.store(t, Ordering::Release);
            {
                let (clock_mx, clock_cv) = &*clocks;
                drop(clock_mx.lock().unwrap());
                clock_cv.notify_all();
            }
            var_meter.record(header.q_norm_sq, header.g_norm_sq);
            let _ = header.based_on;
            if t % record_every == 0 {
                let w_snapshot = store.state.lock().unwrap().0.clone();
                let iters = iterations_done.load(Ordering::Relaxed);
                curve.points.push(CurvePoint {
                    // Iterations actually computed (each push covers up to
                    // H minibatches; trailing partial blocks fewer).
                    data_passes: (iters * batch as u64) as f64 / ds.n() as f64,
                    loss: model.loss(ds, &w_snapshot),
                    comm_bits: wire_bytes * 8,
                    wall_ms: start.elapsed().as_secs_f64() * 1e3,
                });
            }
        }
    });

    let (w, versions) = store.state.lock().unwrap().clone();
    let final_loss = model.loss(ds, &w);
    let measured_bytes: u64 = link_counters.iter().map(|c| c.bytes_total()).sum();
    curve.var_ratio = var_meter.value();
    curve.ledger.set_measured(measured_bytes);
    curve.ledger.set_measured_frames(
        link_counters.iter().map(|c| c.frames_rx() + c.frames_tx()).sum(),
    );
    curve.ledger.verify();
    let trace_metrics = recorder.as_ref().map(|rec| {
        let events = rec.drain();
        let mut snap = crate::trace::MetricsSnapshot::from_events(&events);
        for (wid, c) in link_counters.iter().enumerate() {
            snap.fold_link_counters(&format!("link_w{wid}"), c);
        }
        snap.push_gauge("staleness_stalls", stalls.load(Ordering::Relaxed) as f64);
        snap.set_dropped(rec.dropped());
        if crate::trace::TraceConfig::dump_requested() {
            let tag = crate::trace::run_tag(total_iterations, "star");
            let _ = crate::trace::dump_events(&events, &tag, "ps", trace_cfg.format());
        }
        snap
    });
    let wire_bytes_by_codec = curve.ledger.wire_bytes_by_codec;
    PsReport {
        curve,
        final_loss,
        versions,
        staleness_stalls: stalls.load(Ordering::Relaxed),
        max_observed_staleness: max_stale.load(Ordering::Relaxed),
        wire_bytes,
        wire_bytes_by_codec,
        measured_bytes,
        trace_metrics,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_logistic;
    use crate::model::LogisticModel;

    fn setup() -> (crate::data::Dataset, LogisticModel) {
        let ds = gen_logistic(256, 128, 0.6, 0.25, 71);
        (ds, LogisticModel::new(1.0 / (10.0 * 256.0)))
    }

    fn session(codec: WireCodec, workers: usize, method: MethodSpec) -> Session {
        Session::builder()
            .method(method)
            .codec(codec)
            .workers(workers)
            .seed(42)
            .build()
    }

    fn gspar() -> MethodSpec {
        MethodSpec::GSpar { rho: 0.1, iters: 2 }
    }

    #[test]
    fn ps_converges_with_gspar() {
        let (ds, model) = setup();
        let task = PsTask {
            total_iterations: 3000,
            ..PsTask::default()
        };
        let report = session(WireCodec::Raw, 4, gspar()).param_server(&task, &ds, &model);
        let f0 = model.loss(&ds, &vec![0.0; 128]);
        assert!(
            report.final_loss < f0 * 0.8,
            "{f0} -> {}",
            report.final_loss
        );
        assert_eq!(report.versions, 3000);
        assert!(report.wire_bytes > 0);
        assert!(report.curve.var_ratio > 1.0);
        assert!(!report.curve.points.is_empty());
    }

    #[test]
    fn ps_entropy_codec_converges_with_fewer_wire_bytes() {
        let (ds, model) = setup();
        let task = PsTask {
            total_iterations: 2000,
            ..PsTask::default()
        };
        let raw = session(WireCodec::Raw, 4, gspar()).param_server(&task, &ds, &model);
        let ent = session(WireCodec::Entropy, 4, gspar()).param_server(&task, &ds, &model);
        let f0 = model.loss(&ds, &vec![0.0; 128]);
        assert!(ent.final_loss < f0 * 0.8, "{f0} -> {}", ent.final_loss);
        assert_eq!(ent.versions, 2000);
        // The async schedule is nondeterministic, so the two runs push
        // *different* gradient populations and this is a statistical
        // comparison, not a per-message invariant: at this workload the
        // entropy encoding averages ~30% fewer bytes per push, and the
        // totals are means over 2000 pushes each, so the ordering holds
        // with enormous margin. (The bitwise per-message guarantee is
        // pinned by the deterministic sync/dist/cluster tests instead.)
        assert!(
            ent.wire_bytes < raw.wire_bytes,
            "entropy {} !< raw {}",
            ent.wire_bytes,
            raw.wire_bytes
        );
        assert_eq!(ent.wire_bytes_by_codec[WireCodec::Raw.index()], 0);
        assert_eq!(
            ent.wire_bytes_by_codec[WireCodec::Entropy.index()],
            ent.curve.ledger.wire_bytes
        );
    }

    #[test]
    fn ps_dense_and_sparse_reach_similar_loss() {
        let (ds, model) = setup();
        let task = PsTask {
            total_iterations: 3000,
            ..PsTask::default()
        };
        let dense = session(WireCodec::Raw, 4, MethodSpec::Dense).param_server(&task, &ds, &model);
        let gspar = session(WireCodec::Raw, 4, gspar()).param_server(&task, &ds, &model);
        assert!(
            gspar.final_loss < dense.final_loss * 1.5,
            "gspar {} vs dense {}",
            gspar.final_loss,
            dense.final_loss
        );
    }

    #[test]
    fn ps_staleness_observed_is_bounded_by_pull_cadence() {
        // Workers pull every step, so observed staleness stays small and
        // the version counter equals the push budget exactly.
        let (ds, model) = setup();
        let task = PsTask {
            total_iterations: 1200,
            max_staleness: 4,
            ..PsTask::default()
        };
        let report = session(WireCodec::Raw, 6, gspar()).param_server(&task, &ds, &model);
        assert_eq!(report.versions, 1200);
        // Provable worst case between one worker's consecutive pulls: each
        // peer advances ≤ max_staleness+2 (SSP clock gate), plus the full
        // drained backlog window (≤ workers·(max_staleness+2), including
        // the check-then-send race) — ≈ 66 here; assert with slack. A
        // gate-less run observes ~300 (unbounded pipeline lag).
        assert!(
            report.max_observed_staleness <= 100,
            "staleness {}",
            report.max_observed_staleness
        );
        // And the gate must actually have engaged on this contended box.
        let loose = PsTask {
            total_iterations: 1200,
            max_staleness: 10_000,
            ..PsTask::default()
        };
        let ungated = session(WireCodec::Raw, 6, gspar()).param_server(&loose, &ds, &model);
        assert!(
            report.max_observed_staleness <= ungated.max_observed_staleness.max(100),
            "gated {} should not exceed ungated {}",
            report.max_observed_staleness,
            ungated.max_observed_staleness
        );
    }

    #[test]
    fn ps_single_worker_is_sequential_sgd() {
        let (ds, model) = setup();
        let task = PsTask {
            total_iterations: 1500,
            ..PsTask::default()
        };
        let report =
            session(WireCodec::Raw, 1, MethodSpec::Dense).param_server(&task, &ds, &model);
        // One worker: the backlog gate caps sent-but-unapplied pushes at
        // workers·(max_staleness+1), so pull lag is bounded by that window.
        assert!(
            report.max_observed_staleness <= task.max_staleness + 2,
            "staleness {}",
            report.max_observed_staleness
        );
        let f0 = model.loss(&ds, &vec![0.0; 128]);
        assert!(report.final_loss < f0);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_ps_config_shim_still_runs() {
        // The shim forwards to the Session path; the async schedule is
        // nondeterministic, so assert convergence + bookkeeping, not bytes.
        let (ds, model) = setup();
        let cfg = PsConfig {
            total_pushes: 800,
            ..Default::default()
        };
        let report = run_param_server(&cfg, &ds, &model);
        assert_eq!(report.versions, 800);
        let f0 = model.loss(&ds, &vec![0.0; 128]);
        assert!(report.final_loss < f0);
    }
}
