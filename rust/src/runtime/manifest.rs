//! `artifacts/manifest.txt` — the contract between `python/compile/aot.py`
//! and the Rust runtime. One line per tensor:
//!
//! ```text
//! <artifact> in  <idx> <dtype> <dim0>x<dim1>...   # e.g. logistic_grad in 0 f32 8x2048
//! <artifact> out <idx> <dtype> <dim0>x...
//! ```
//!
//! Scalars use the dims token `scalar`.

use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One tensor's signature.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSig {
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl TensorSig {
    pub fn elements(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }

    pub fn dims_i64(&self) -> Vec<i64> {
        self.dims.iter().map(|&d| d as i64).collect()
    }
}

/// Input/output signature of one artifact.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ArtifactSig {
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// Parsed manifest: artifact name → signature.
#[derive(Debug, Default, Clone)]
pub struct Manifest {
    sigs: BTreeMap<String, ArtifactSig>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let mut sigs: BTreeMap<String, ArtifactSig> = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            if toks.len() != 5 {
                return Err(anyhow!("manifest line {}: expected 5 tokens", lineno + 1));
            }
            let (name, dir, idx, dtype, dims_tok) = (toks[0], toks[1], toks[2], toks[3], toks[4]);
            let idx: usize = idx
                .parse()
                .with_context(|| format!("manifest line {}: bad index", lineno + 1))?;
            let dims: Vec<usize> = if dims_tok == "scalar" {
                Vec::new()
            } else {
                dims_tok
                    .split('x')
                    .map(|p| p.parse::<usize>())
                    .collect::<std::result::Result<_, _>>()
                    .with_context(|| format!("manifest line {}: bad dims", lineno + 1))?
            };
            let sig = sigs.entry(name.to_string()).or_default();
            let list = match dir {
                "in" => &mut sig.inputs,
                "out" => &mut sig.outputs,
                other => return Err(anyhow!("manifest line {}: bad direction `{other}`", lineno + 1)),
            };
            if list.len() != idx {
                return Err(anyhow!(
                    "manifest line {}: index {idx} out of order (have {})",
                    lineno + 1,
                    list.len()
                ));
            }
            list.push(TensorSig {
                dtype: dtype.to_string(),
                dims,
            });
        }
        Ok(Self { sigs })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSig> {
        self.sigs.get(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.sigs.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# logistic gradient
logistic_grad in 0 f32 8x2048
logistic_grad in 1 f32 8
logistic_grad in 2 f32 2048
logistic_grad out 0 f32 2048
logistic_grad out 1 f32 scalar
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let sig = m.get("logistic_grad").unwrap();
        assert_eq!(sig.inputs.len(), 3);
        assert_eq!(sig.outputs.len(), 2);
        assert_eq!(sig.inputs[0].dims, vec![8, 2048]);
        assert_eq!(sig.outputs[1].dims, Vec::<usize>::new());
        assert_eq!(sig.outputs[1].elements(), 1);
        assert_eq!(sig.inputs[0].dims_i64(), vec![8i64, 2048]);
        assert_eq!(m.names().collect::<Vec<_>>(), vec!["logistic_grad"]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("too few tokens\n").is_err());
        assert!(Manifest::parse("a in zero f32 4\n").is_err());
        assert!(Manifest::parse("a sideways 0 f32 4\n").is_err());
        assert!(Manifest::parse("a in 1 f32 4\n").is_err()); // out-of-order idx
        assert!(Manifest::parse("a in 0 f32 4xx\n").is_err());
    }
}
