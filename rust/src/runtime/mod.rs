//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) from the Rust hot path.
//!
//! Interchange format is **HLO text**, not serialized `HloModuleProto`:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that this image's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md). Every artifact is lowered with
//! `return_tuple=True`, so outputs always decompose as a tuple.
//!
//! Python never runs at training time: `make artifacts` produces the text
//! files plus `manifest.txt` (name → input/output signature), and this
//! module is the only consumer.

mod manifest;

pub use manifest::{ArtifactSig, Manifest, TensorSig};

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled artifact ready to execute.
pub struct Executable {
    pub name: String,
    pub sig: Option<ArtifactSig>,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with literal inputs; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let mut out = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing artifact `{}`", self.name))?;
        let first = out
            .pop()
            .and_then(|mut replicas| {
                if replicas.is_empty() {
                    None
                } else {
                    Some(replicas.remove(0))
                }
            })
            .ok_or_else(|| anyhow!("artifact `{}` produced no outputs", self.name))?;
        let literal = first.to_literal_sync()?;
        Ok(literal.to_tuple()?)
    }

    /// Execute and return the outputs as `Vec<f32>` buffers (the common case
    /// for gradients/losses).
    pub fn run_f32(&self, inputs: &[xla::Literal]) -> Result<Vec<Vec<f32>>> {
        self.run(inputs)?
            .iter()
            .map(|l| l.to_vec::<f32>().map_err(Into::into))
            .collect()
    }
}

/// PJRT CPU client + compiled-executable cache, keyed by artifact name.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: HashMap<String, Executable>,
    manifest: Option<Manifest>,
    dir: Option<PathBuf>,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            cache: HashMap::new(),
            manifest: None,
            dir: None,
        })
    }

    /// Point the runtime at an artifacts directory (reads `manifest.txt` if
    /// present; artifacts themselves load lazily on first use).
    pub fn with_artifact_dir(mut self, dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let mpath = dir.join("manifest.txt");
        if mpath.exists() {
            self.manifest = Some(Manifest::load(&mpath)?);
        }
        self.dir = Some(dir);
        Ok(self)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile HLO text at `path` and register it under `name`.
    pub fn load_file(&mut self, name: &str, path: &Path) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow!("non-UTF8 path {path:?}"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact `{name}`"))?;
        let sig = self.manifest.as_ref().and_then(|m| m.get(name).cloned());
        self.cache.insert(
            name.to_string(),
            Executable {
                name: name.to_string(),
                sig,
                exe,
            },
        );
        Ok(())
    }

    /// Get (lazily loading from the artifact dir) the named executable.
    pub fn get(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let dir = self
                .dir
                .clone()
                .ok_or_else(|| anyhow!("artifact `{name}` not loaded and no artifact dir set"))?;
            let path = dir.join(format!("{name}.hlo.txt"));
            if !path.exists() {
                return Err(anyhow!(
                    "artifact `{name}` not found at {} — run `make artifacts` first",
                    path.display()
                ));
            }
            self.load_file(name, &path)?;
        }
        Ok(&self.cache[name])
    }

    /// Names available in the manifest (empty if none was found).
    pub fn manifest_names(&self) -> Vec<String> {
        self.manifest
            .as_ref()
            .map(|m| m.names().map(str::to_string).collect())
            .unwrap_or_default()
    }
}

/// Helpers for building input literals.
pub mod lit {
    use anyhow::Result;

    /// Dense f32 tensor literal with the given dims.
    pub fn f32_tensor(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        let n: i64 = dims.iter().product();
        anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
        Ok(xla::Literal::vec1(data).reshape(dims)?)
    }

    /// Dense i32 tensor literal.
    pub fn i32_tensor(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
        let n: i64 = dims.iter().product();
        anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
        Ok(xla::Literal::vec1(data).reshape(dims)?)
    }

    /// Scalar f32 literal.
    pub fn f32_scalar(v: f32) -> xla::Literal {
        xla::Literal::scalar(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need real artifacts live in rust/tests/ (they run
    // after `make artifacts`). Here we only cover the artifact-less paths.

    #[test]
    fn missing_artifact_is_a_clear_error() {
        let mut rt = Runtime::cpu().unwrap().with_artifact_dir("/nonexistent-dir").unwrap();
        let err = match rt.get("nope") {
            Err(e) => e.to_string(),
            Ok(_) => panic!("expected error"),
        };
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn no_dir_is_a_clear_error() {
        let mut rt = Runtime::cpu().unwrap();
        let err = match rt.get("nope") {
            Err(e) => e.to_string(),
            Ok(_) => panic!("expected error"),
        };
        assert!(err.contains("no artifact dir"), "{err}");
    }

    #[test]
    fn lit_helpers_validate_shapes() {
        assert!(lit::f32_tensor(&[1.0, 2.0], &[2, 2]).is_err());
        let l = lit::f32_tensor(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
        let i = lit::i32_tensor(&[1, 2, 3], &[3]).unwrap();
        assert_eq!(i.element_count(), 3);
    }
}
