//! Training-run metrics: loss curves, the paper's realized variance ratio
//! (`var` in Figures 1–4) and realized sparsity (`spa`), communication-cost
//! ledgers, and CSV/JSONL writers for the figure drivers.

use crate::coding::WireCodec;
use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// Accumulates the paper's `var` statistic:
/// `var = Σ_t Σ_m ||Q(g^m)||² / Σ_t Σ_m ||g^m||²` (§5.1).
#[derive(Debug, Default, Clone)]
pub struct VarianceRatio {
    sum_q: f64,
    sum_g: f64,
}

impl VarianceRatio {
    pub fn record(&mut self, q_norm_sq: f64, g_norm_sq: f64) {
        self.sum_q += q_norm_sq;
        self.sum_g += g_norm_sq;
    }

    /// The realized ratio; 1.0 when nothing has been recorded (dense runs).
    pub fn value(&self) -> f64 {
        if self.sum_g == 0.0 {
            1.0
        } else {
            self.sum_q / self.sum_g
        }
    }
}

/// Accumulates realized expected sparsity `spa = mean(Σ_i p_i / d)`.
#[derive(Debug, Default, Clone)]
pub struct SparsityMeter {
    sum_density: f64,
    count: u64,
}

impl SparsityMeter {
    pub fn record(&mut self, expected_nnz: f64, d: usize) {
        self.sum_density += expected_nnz / d as f64;
        self.count += 1;
    }

    pub fn value(&self) -> f64 {
        if self.count == 0 {
            1.0
        } else {
            self.sum_density / self.count as f64
        }
    }
}

/// Communication ledger: bits transmitted, split by the paper's idealized
/// cost formulas (used for the Fig 5–6 x-axis) and the actual wire bytes of
/// our codec.
#[derive(Debug, Default, Clone)]
pub struct CommLedger {
    /// Idealized bits per the paper's H(T, M) formulas.
    pub ideal_bits: u64,
    /// Actual encoded message bytes produced by `coding::`.
    pub wire_bytes: u64,
    /// `wire_bytes` split by the [`WireCodec`] each message was encoded
    /// under (indexed by [`WireCodec::index`]) — the per-codec column that
    /// shows the measured-vs-ideal gap closing as runs move to `Entropy`.
    pub wire_bytes_by_codec: [u64; 2],
    /// **Measured** framed bytes observed by the transport layer's per-link
    /// counters (payloads + length prefixes + handshakes) — what actually
    /// crossed the socket or channel, as opposed to the modeled columns
    /// above. Zero for runs that never touched a transport.
    pub measured_bytes: u64,
    /// **Measured** transport frames on the same counters (both
    /// directions, handshakes included). Together with `measured_bytes`
    /// this is what proves a local-step round shipped *nothing*: rounds
    /// scheduled between synchronizations leave both columns unchanged.
    pub measured_frames: u64,
    /// **Measured** bytes the ring collective's hop links transmitted
    /// (reduce-scatter + all-gather frames, overhead included) — the
    /// per-node cost a [`Topology::Ring`](crate::comm::Topology) round pays
    /// instead of the star's leader ingress. Zero on star topologies and on
    /// coordinators that cannot observe the hop links (the dist *server*
    /// never sees worker-owned ring links; only the cluster coordinator,
    /// which owns every endpoint, fills this column).
    pub hop_bytes: u64,
    /// **Measured** bytes of the fully reduced result delivered after the
    /// ring (rank 0's single result frame per round) — what replaces the
    /// star's `M` uploads. Zero on star topologies.
    pub end_to_end_bytes: u64,
    /// Number of messages (one per worker per step).
    pub messages: u64,
}

impl CommLedger {
    /// Record a message ledgered under [`WireCodec::Raw`] (dense/quantized
    /// fallbacks and legacy call sites).
    pub fn record(&mut self, ideal_bits: u64, wire_bytes: u64) {
        self.record_codec(ideal_bits, wire_bytes, WireCodec::Raw);
    }

    /// Record a message encoded under `codec`.
    pub fn record_codec(&mut self, ideal_bits: u64, wire_bytes: u64, codec: WireCodec) {
        self.ideal_bits += ideal_bits;
        self.wire_bytes += wire_bytes;
        self.wire_bytes_by_codec[codec.index()] += wire_bytes;
        self.messages += 1;
    }

    /// Set the measured column from transport counters (counters are
    /// cumulative, so this overwrites rather than accumulates).
    pub fn set_measured(&mut self, measured_bytes: u64) {
        self.measured_bytes = measured_bytes;
    }

    /// Set the measured frame column from transport counters (cumulative —
    /// overwrites, like [`Self::set_measured`]).
    pub fn set_measured_frames(&mut self, measured_frames: u64) {
        self.measured_frames = measured_frames;
    }

    /// Set the ring hop-bytes column from the ring links' cumulative
    /// counters (overwrites, like [`Self::set_measured`]).
    pub fn set_hop_bytes(&mut self, hop_bytes: u64) {
        self.hop_bytes = hop_bytes;
    }

    /// Accumulate the framed bytes of one round's reduced-result delivery
    /// (per-round frame sizes, not a cumulative counter — hence adds).
    pub fn add_end_to_end_bytes(&mut self, bytes: u64) {
        self.end_to_end_bytes += bytes;
    }

    /// Wire-bytes (encoded payload, in bits) over ideal-bits — the gap the
    /// entropy codec closes (`NaN` before anything was recorded). Framing
    /// overhead is the separate `measured_bytes` column.
    pub fn wire_bits_over_ideal(&self) -> f64 {
        (self.wire_bytes * 8) as f64 / self.ideal_bits as f64
    }

    /// Cross-column consistency, as a predicate (see [`Self::verify`]):
    ///
    /// * the per-codec split sums back to `wire_bytes`;
    /// * when a transport measured this run, the framed bytes are at least
    ///   the encoded payload bytes they carried (framing only ever adds),
    ///   and frames were actually counted alongside them;
    /// * messages and wire bytes appear together.
    pub fn consistent(&self) -> bool {
        let split_ok =
            self.wire_bytes_by_codec.iter().sum::<u64>() == self.wire_bytes;
        let measured_ok = self.measured_bytes == 0
            || (self.measured_bytes >= self.wire_bytes && self.measured_frames > 0);
        // Zero-byte messages are legal; wire bytes without messages are not.
        let messages_ok = self.messages > 0 || self.wire_bytes == 0;
        split_ok && measured_ok && messages_ok
    }

    /// Debug assertion that the columns agree ([`Self::consistent`]) —
    /// every coordinator calls this after folding its transport counters
    /// in, so counter drift (a path that records payloads but misses the
    /// framed column, or vice versa) fails loudly in debug/test builds
    /// instead of skewing reported ratios.
    pub fn verify(&self) {
        debug_assert!(
            self.consistent(),
            "CommLedger columns disagree: ideal_bits={} wire_bytes={} by_codec={:?} \
             measured_bytes={} measured_frames={} hop_bytes={} end_to_end_bytes={} messages={}",
            self.ideal_bits,
            self.wire_bytes,
            self.wire_bytes_by_codec,
            self.measured_bytes,
            self.measured_frames,
            self.hop_bytes,
            self.end_to_end_bytes,
            self.messages,
        );
    }

    pub fn merge(&mut self, other: &CommLedger) {
        self.ideal_bits += other.ideal_bits;
        self.wire_bytes += other.wire_bytes;
        for (mine, theirs) in self
            .wire_bytes_by_codec
            .iter_mut()
            .zip(other.wire_bytes_by_codec)
        {
            *mine += theirs;
        }
        self.measured_bytes += other.measured_bytes;
        self.measured_frames += other.measured_frames;
        self.hop_bytes += other.hop_bytes;
        self.end_to_end_bytes += other.end_to_end_bytes;
        self.messages += other.messages;
    }
}

/// One point on a training curve.
#[derive(Debug, Clone)]
pub struct CurvePoint {
    /// x-axis: data passes (epochs), fractional.
    pub data_passes: f64,
    /// Objective value f(w_t).
    pub loss: f64,
    /// Cumulative idealized communication bits.
    pub comm_bits: u64,
    /// Wall-clock milliseconds since run start.
    pub wall_ms: f64,
}

/// A named training curve plus its summary statistics — what each figure
/// driver prints.
#[derive(Debug, Clone)]
pub struct RunCurve {
    pub name: String,
    pub points: Vec<CurvePoint>,
    pub var_ratio: f64,
    pub sparsity: f64,
    pub ledger: CommLedger,
}

impl RunCurve {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            points: Vec::new(),
            var_ratio: 1.0,
            sparsity: 1.0,
            ledger: CommLedger::default(),
        }
    }

    pub fn final_loss(&self) -> f64 {
        self.points.last().map(|p| p.loss).unwrap_or(f64::NAN)
    }

    /// Label in the paper's style: `name (var=…, spa=…)`.
    pub fn label(&self) -> String {
        format!(
            "{} (var={:.3}, spa={:.4})",
            self.name, self.var_ratio, self.sparsity
        )
    }

    /// CSV rows: `name,data_passes,loss,comm_bits,wall_ms`.
    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        for p in &self.points {
            let _ = writeln!(
                s,
                "{},{},{},{},{}",
                self.name, p.data_passes, p.loss, p.comm_bits, p.wall_ms
            );
        }
        s
    }
}

/// Write a set of curves to a CSV file with a header.
pub fn write_csv(path: &Path, curves: &[RunCurve]) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "series,data_passes,loss,comm_bits,wall_ms")?;
    for c in curves {
        f.write_all(c.to_csv().as_bytes())?;
    }
    Ok(())
}

/// Render curves as a coarse ASCII plot (log10 y) for terminal inspection —
/// the figure drivers print this so the paper's plot shapes are visible
/// without any plotting dependency.
pub fn ascii_plot(curves: &[RunCurve], width: usize, height: usize, xaxis: XAxis) -> String {
    let mut out = String::new();
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    let mut xmax = 0.0f64;
    for c in curves {
        for p in &c.points {
            let y = p.loss.max(1e-300).log10();
            ymin = ymin.min(y);
            ymax = ymax.max(y);
            xmax = xmax.max(xaxis.of(p));
        }
    }
    if !ymin.is_finite() || !ymax.is_finite() || xmax == 0.0 {
        return "(no data)\n".into();
    }
    if ymax - ymin < 1e-9 {
        ymax = ymin + 1e-9;
    }
    let mut grid = vec![vec![b' '; width]; height];
    for (ci, c) in curves.iter().enumerate() {
        let ch = b"0123456789abcdef"[ci % 16];
        for p in &c.points {
            let x = ((xaxis.of(p) / xmax) * (width - 1) as f64).round() as usize;
            let y = (((p.loss.max(1e-300).log10()) - ymin) / (ymax - ymin)
                * (height - 1) as f64)
                .round() as usize;
            let row = height - 1 - y.min(height - 1);
            grid[row][x.min(width - 1)] = ch;
        }
    }
    let _ = writeln!(out, "log10(loss) in [{ymin:.2}, {ymax:.2}], x up to {xmax:.3e} ({})", xaxis.name());
    for row in grid {
        let _ = writeln!(out, "|{}|", String::from_utf8_lossy(&row));
    }
    for (ci, c) in curves.iter().enumerate() {
        let _ = writeln!(out, "  [{}] {}", (b"0123456789abcdef"[ci % 16]) as char, c.label());
    }
    out
}

/// Which x-axis a plot uses.
#[derive(Clone, Copy, Debug)]
pub enum XAxis {
    DataPasses,
    CommBits,
    WallMs,
}

impl XAxis {
    fn of(self, p: &CurvePoint) -> f64 {
        match self {
            XAxis::DataPasses => p.data_passes,
            XAxis::CommBits => p.comm_bits as f64,
            XAxis::WallMs => p.wall_ms,
        }
    }
    fn name(self) -> &'static str {
        match self {
            XAxis::DataPasses => "data passes",
            XAxis::CommBits => "communication bits",
            XAxis::WallMs => "wall ms",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variance_ratio_accumulates() {
        let mut v = VarianceRatio::default();
        assert_eq!(v.value(), 1.0);
        v.record(2.0, 1.0);
        v.record(4.0, 2.0);
        assert!((v.value() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn sparsity_meter_means() {
        let mut s = SparsityMeter::default();
        assert_eq!(s.value(), 1.0);
        s.record(10.0, 100);
        s.record(30.0, 100);
        assert!((s.value() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn ledger_merge() {
        let mut a = CommLedger::default();
        a.record(100, 16);
        a.set_measured(40);
        a.set_measured_frames(3);
        a.set_hop_bytes(7);
        a.add_end_to_end_bytes(5);
        let mut b = CommLedger::default();
        b.record_codec(50, 8, WireCodec::Entropy);
        b.set_measured(10);
        b.set_measured_frames(2);
        b.set_hop_bytes(3);
        b.add_end_to_end_bytes(4);
        b.add_end_to_end_bytes(2);
        a.merge(&b);
        assert_eq!(a.ideal_bits, 150);
        assert_eq!(a.wire_bytes, 24);
        assert_eq!(a.wire_bytes_by_codec, [16, 8]);
        assert_eq!(a.measured_bytes, 50);
        assert_eq!(a.measured_frames, 5);
        assert_eq!(a.hop_bytes, 10);
        assert_eq!(a.end_to_end_bytes, 11);
        assert_eq!(a.messages, 2);
    }

    #[test]
    fn ledger_consistency_predicate() {
        let mut l = CommLedger::default();
        assert!(l.consistent(), "empty ledger is consistent");
        l.verify();
        l.record_codec(100, 16, WireCodec::Raw);
        l.set_measured(40);
        l.set_measured_frames(3);
        assert!(l.consistent());
        l.verify();
        // Framed bytes below the payloads they carried: counter drift.
        let mut bad = l.clone();
        bad.set_measured(8);
        assert!(!bad.consistent());
        // Measured bytes without any counted frames: drift.
        let mut bad = l.clone();
        bad.set_measured_frames(0);
        assert!(!bad.consistent());
        // A per-codec split that misses the total: drift.
        let mut bad = l.clone();
        bad.wire_bytes_by_codec[WireCodec::Entropy.index()] += 1;
        assert!(!bad.consistent());
        // Wire bytes with no recorded messages: drift.
        let mut bad = CommLedger::default();
        bad.wire_bytes = 5;
        bad.wire_bytes_by_codec[0] = 5;
        assert!(!bad.consistent());
        // Simulated-only runs (no transport) stay consistent.
        let mut sim = CommLedger::default();
        sim.record(64, 8);
        assert!(sim.consistent());
        sim.verify();
        // Ring columns are independent of the star-era constraints: a ring
        // run with hop + end-to-end bytes stays consistent.
        let mut ring = l.clone();
        ring.set_hop_bytes(12);
        ring.add_end_to_end_bytes(9);
        assert!(ring.consistent());
        ring.verify();
    }

    #[test]
    fn ledger_per_codec_column_and_ratio() {
        let mut l = CommLedger::default();
        assert!(l.wire_bits_over_ideal().is_nan());
        l.record_codec(64, 16, WireCodec::Raw);
        l.record_codec(64, 8, WireCodec::Entropy);
        assert_eq!(l.wire_bytes, 24);
        assert_eq!(l.wire_bytes_by_codec, [16, 8]);
        assert!((l.wire_bits_over_ideal() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn curve_csv_and_label() {
        let mut c = RunCurve::new("gspar");
        c.var_ratio = 1.5;
        c.sparsity = 0.05;
        c.points.push(CurvePoint {
            data_passes: 1.0,
            loss: 0.5,
            comm_bits: 1000,
            wall_ms: 3.5,
        });
        assert!(c.label().contains("var=1.500"));
        assert!(c.to_csv().contains("gspar,1,0.5,1000,3.5"));
        assert!((c.final_loss() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn csv_writer_creates_file() {
        let dir = std::env::temp_dir().join("gsparse_test_metrics");
        let path = dir.join("curves.csv");
        let mut c = RunCurve::new("x");
        c.points.push(CurvePoint {
            data_passes: 0.5,
            loss: 1.0,
            comm_bits: 1,
            wall_ms: 0.0,
        });
        write_csv(&path, &[c]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("series,"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn ascii_plot_renders() {
        let mut c = RunCurve::new("a");
        for i in 0..20 {
            c.points.push(CurvePoint {
                data_passes: i as f64,
                loss: (20.0 - i as f64).max(0.1),
                comm_bits: i * 10,
                wall_ms: i as f64,
            });
        }
        let s = ascii_plot(&[c], 40, 10, XAxis::DataPasses);
        assert!(s.contains("log10(loss)"));
        assert!(s.contains("[0]"));
        let empty = ascii_plot(&[], 40, 10, XAxis::CommBits);
        assert!(empty.contains("no data"));
    }
}
