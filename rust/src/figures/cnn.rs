//! Figures 7–8: CNNs on the CIFAR-like dataset with Adam and per-layer
//! gradient sparsification (§5.2).
//!
//! Paper setting: 3 conv(3×3) + BN layers, 2 pools, FC-256, Adam lr 0.02;
//! channels {32, 24} (Fig 7) and {64, 48} (Fig 8); loss vs epochs and vs
//! communication cost (∝ ρ), down to ρ ≈ 0.004. Scale substitution
//! (synthetic CIFAR-like data, reduced steps on the 1-core testbed) is
//! documented in DESIGN.md §Substitutions.

use crate::api::{MethodSpec, Session};
use crate::data::CifarLike;
use crate::metrics::{write_csv, CurvePoint, RunCurve};
use crate::model::hlo::HloTrainStep;
use crate::opt::Adam;
use crate::runtime::Runtime;

/// One training run of `cnn<channels>_step` with per-layer compressor ρ.
/// `rho = 1.0` means dense. With `batch` the whole layer list travels as
/// one `WireBatch` frame per worker per round (`--batch-layers`).
fn train_cnn(
    rt: &mut Runtime,
    channels: usize,
    rho: f32,
    steps: usize,
    workers: usize,
    seed: u64,
    batch: bool,
) -> anyhow::Result<RunCurve> {
    let step = HloTrainStep::from_manifest(rt, &format!("cnn{channels}_step"))?;
    let mut params = step.init_params(rt, seed as i32)?;
    let ds = CifarLike::generate(512, seed ^ 0xC1FA);
    let bsz = step.x_dims[0];
    let layer_dims = step.layer_dims();
    let method = if rho >= 1.0 {
        MethodSpec::Dense
    } else {
        MethodSpec::GSpar { rho: rho.min(1.0), iters: 2 }
    };
    let session = Session::builder()
        .method(method)
        .workers(workers)
        .seed(seed)
        .batch_layers(batch)
        .build();
    let mut cluster = session.cluster(&layer_dims);
    let mut adams: Vec<Adam> = layer_dims.iter().map(|&d| Adam::new(d, 0.02)).collect();
    let mut rng = crate::rngkit::Xoshiro256pp::seed_from_u64(seed ^ 0xADA);
    let mut x = vec![0.0f32; bsz * CifarLike::PIXELS];
    let mut y = vec![0i32; bsz];

    let label = if rho >= 1.0 {
        format!("cnn{channels}-dense")
    } else {
        format!("cnn{channels}-rho{rho}")
    };
    let mut curve = RunCurve::new(label);
    let samples_per_step = (workers * bsz) as f64;
    let epoch_len = ds.n as f64;
    for t in 0..steps {
        let mut worker_grads = Vec::with_capacity(workers);
        let mut loss_sum = 0.0f64;
        for _ in 0..workers {
            let idx: Vec<usize> = (0..bsz)
                .map(|_| rng.next_below(ds.n as u64) as usize)
                .collect();
            ds.batch_into(&idx, &mut x, &mut y);
            let (loss, grads) = step.grads(rt, &params, &x, &y)?;
            loss_sum += loss as f64;
            worker_grads.push(grads);
        }
        let updates = cluster.round(&worker_grads);
        for ((p, upd), adam) in params.iter_mut().zip(&updates).zip(adams.iter_mut()) {
            adam.step(p, &upd.grad);
        }
        curve.points.push(CurvePoint {
            data_passes: (t + 1) as f64 * samples_per_step / epoch_len,
            loss: loss_sum / workers as f64,
            comm_bits: cluster.ledger.ideal_bits,
            wall_ms: cluster.sim_time_s * 1e3,
        });
    }
    curve.var_ratio = cluster.var_meter.value();
    curve.sparsity = cluster.spa_meter.value();
    curve.ledger = cluster.ledger.clone();
    Ok(curve)
}

fn run_fig(name: &str, channel_set: &[usize], quick: bool, batch: bool) -> anyhow::Result<()> {
    println!("\n================ {name} ================");
    let mut rt = Runtime::cpu()?.with_artifact_dir("artifacts")?;
    let available = rt.manifest_names();
    let steps = if quick { 12 } else { 40 };
    let rhos = if quick {
        vec![1.0f32, 0.05]
    } else {
        vec![1.0f32, 0.1, 0.02, 0.004]
    };
    let mut all = Vec::new();
    for &ch in channel_set {
        if !available.contains(&format!("cnn{ch}_step")) {
            println!(
                "  (cnn{ch} artifact not built — run `make artifacts-full` for the 48/64 variants)"
            );
            continue;
        }
        for &rho in &rhos {
            let curve = train_cnn(&mut rt, ch, rho, steps, 2, 7, batch)?;
            println!(
                "  {:<22} loss {:.3} -> {:.3}   var {:.2}  spa {:.4}  Mbits {:.2}",
                curve.name,
                curve.points.first().map(|p| p.loss).unwrap_or(f64::NAN),
                curve.final_loss(),
                curve.var_ratio,
                curve.sparsity,
                curve.ledger.ideal_bits as f64 / 1e6,
            );
            all.push(curve);
        }
    }
    let path = super::results_dir().join(format!("{name}.csv"));
    write_csv(&path, &all)?;
    println!("wrote {}", path.display());
    Ok(())
}

/// Figure 7: channels 32 (top) and 24 (bottom). `batch` enables the
/// batched multi-layer wire path (`--batch-layers`).
pub fn fig7(quick: bool, batch: bool) -> anyhow::Result<()> {
    run_fig("fig7_cnn_32_24", &[32, 24], quick, batch)
}

/// Figure 8: channels 64 (top) and 48 (bottom) — requires
/// `make artifacts-full`. `batch` enables the batched multi-layer wire
/// path (`--batch-layers`).
pub fn fig8(quick: bool, batch: bool) -> anyhow::Result<()> {
    run_fig("fig8_cnn_64_48", &[64, 48], quick, batch)
}
