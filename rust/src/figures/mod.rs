//! One driver per paper figure. Each regenerates the same series the paper
//! plots (same workload recipe, same grid of hyper-parameters, same labels)
//! and writes `results/figN*.csv` plus an ASCII rendering to stdout.
//!
//! The paper's evaluation has no numbered tables — Figures 1–9 are the
//! entire quantitative surface; `theory` additionally prints the Lemma-3 /
//! Theorem-4 bound-vs-measured sweep. See DESIGN.md §3 for the
//! experiment-to-module map and EXPERIMENTS.md for recorded outputs.

mod async_svm;
mod cnn;
mod convex_grid;
mod e2e;
mod qsgd;
mod theory;

pub use async_svm::fig9;
pub use cnn::{fig7, fig8};
pub use convex_grid::{fig1, fig2, fig3, fig4, ConvexFigureScale};
pub use e2e::run_transformer_e2e;
pub use qsgd::{fig5, fig6};
pub use theory::theory_bounds;

use std::path::PathBuf;

/// Where figure CSVs land.
pub fn results_dir() -> PathBuf {
    std::env::var("GSPARSE_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("results"))
}

/// Run one figure by number (1–9), `theory`, or `all`. `batch` turns on
/// the batched multi-layer wire path for the cluster-backed figures (7–8);
/// the single-tensor convex figures ignore it.
pub fn run(which: &str, quick: bool, batch: bool) -> anyhow::Result<()> {
    let scale = if quick {
        ConvexFigureScale::quick()
    } else {
        ConvexFigureScale::paper()
    };
    match which {
        "1" => fig1(&scale),
        "2" => fig2(&scale),
        "3" => fig3(&scale),
        "4" => fig4(&scale),
        "5" => fig5(&scale),
        "6" => fig6(&scale),
        "7" => fig7(quick, batch)?,
        "8" => fig8(quick, batch)?,
        "9" => fig9(quick),
        "theory" => theory_bounds(),
        "all" => {
            for f in ["1", "2", "3", "4", "5", "6", "7", "8", "9", "theory"] {
                run(f, quick, batch)?;
            }
        }
        other => anyhow::bail!("unknown figure `{other}` (1-9, theory, all)"),
    }
    Ok(())
}
