//! Figure 9: asynchronous multi-thread SVM (Algorithm 4), loss (log₂) vs
//! wall-clock milliseconds, GSpar vs dense, across thread counts and
//! regularization strengths.
//!
//! Paper setting: N = 51200, d = 256, C₁ = 0.01, C₂ = 0.9, threads
//! {16, 32}, reg {0.5, 0.1, 0.05}, atomic updates, lr/ρ initial step.
//! (This testbed has 1 hardware core; thread counts are oversubscribed —
//! DESIGN.md §Substitutions — so we also run {2, 4, 8} and report conflict
//! counts, which capture the §5.3 mechanism directly.)

use crate::config::{AsyncSvmConfig, Method, UpdateScheme};
use crate::coordinator::AsyncSvmEngine;
use crate::data::gen_svm;
use crate::metrics::write_csv;

pub fn fig9(quick: bool) {
    println!("\n================ fig9_async_svm ================");
    let (n, steps) = if quick { (8192, 40_000) } else { (51200, 200_000) };
    let d = 256;
    let ds = gen_svm(n, d, 0.01, 0.9, 2018);
    let threads_set: &[usize] = if quick { &[4, 16] } else { &[2, 4, 8, 16, 32] };
    let regs: &[f32] = if quick { &[0.1] } else { &[0.5, 0.1, 0.05] };
    let mut all = Vec::new();
    println!(
        "{:<26} {:>9} {:>12} {:>12} {:>10} {:>12}",
        "series", "wall_ms", "final_loss", "log2(loss)", "updates", "conflicts"
    );
    for &threads in threads_set {
        for &reg in regs {
            for method in [Method::Dense, Method::GSpar] {
                let cfg = AsyncSvmConfig {
                    n,
                    d,
                    c1: 0.01,
                    c2: 0.9,
                    reg,
                    rho: 0.05,
                    threads,
                    lr: 0.05,
                    method,
                    seed: 2018,
                    total_steps: steps,
                    scheme: UpdateScheme::Atomic,
                };
                let report = AsyncSvmEngine::new(cfg).run(&ds);
                println!(
                    "{:<26} {:>9.1} {:>12.5} {:>12.3} {:>10} {:>12}",
                    format!("{}(th={threads},reg={reg})", if method == Method::Dense { "dense" } else { "GSpar" }),
                    report.wall_ms,
                    report.final_loss,
                    report.final_loss.max(1e-12).log2(),
                    report.updates,
                    report.conflicts,
                );
                let mut curve = report.curve;
                curve.name = format!(
                    "{}_th{threads}_reg{reg}",
                    if method == Method::Dense { "dense" } else { "gspar" }
                );
                all.push(curve);
            }
        }
    }
    let path = super::results_dir().join("fig9_async_svm.csv");
    if let Err(e) = write_csv(&path, &all) {
        eprintln!("warning: could not write {}: {e}", path.display());
    } else {
        println!("wrote {}", path.display());
    }
}
