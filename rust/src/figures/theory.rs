//! Lemma 3 / Theorem 4 validation sweep: construct `(ρ, s)`-approximately
//! sparse gradients, run the closed-form sparsifier with ε = ρ, and print
//! bound vs measured for expected sparsity and coding length.

use crate::coding::theorem4_bound_bits;
use crate::rngkit::Xoshiro256pp;
use crate::sparsify::{closed_form_probs, hybrid_ideal_bits};

pub fn theory_bounds() {
    println!("\n================ theory: Lemma 3 & Theorem 4 ================");
    println!(
        "{:>6} {:>6} {:>8} | {:>12} {:>12} | {:>12} {:>12}",
        "d", "s", "rho", "E[nnz]", "(1+ρ)s", "bits", "Thm4 bound"
    );
    let mut rng = Xoshiro256pp::seed_from_u64(42);
    for &d in &[512usize, 2048, 8192] {
        for &s_frac in &[0.01f64, 0.05, 0.2] {
            let s = ((d as f64 * s_frac) as usize).max(2);
            let mut g = vec![0.0f32; d];
            for gi in g.iter_mut().take(s) {
                *gi = 1.0 + rng.next_f32();
            }
            for gi in g.iter_mut().skip(s) {
                *gi = rng.next_f32() * 0.01;
            }
            let l1_s: f64 = g[..s].iter().map(|&x| x.abs() as f64).sum();
            let l1_sc: f64 = g[s..].iter().map(|&x| x.abs() as f64).sum();
            let rho = l1_sc / l1_s;
            let mut p = Vec::new();
            let pv = closed_form_probs(&g, rho as f32, &mut p);
            let nnz_bound = (1.0 + rho) * s as f64;
            let qb_mass = pv.expected_nnz - pv.num_exact as f64;
            let bits = hybrid_ideal_bits(pv.num_exact as u64, qb_mass, d);
            let bound = theorem4_bound_bits(s, rho, d);
            let ok1 = pv.expected_nnz <= nnz_bound * (1.0 + 1e-6);
            let ok2 = bits <= bound + 64;
            println!(
                "{d:>6} {s:>6} {rho:>8.4} | {:>12.2} {:>12.2} | {bits:>12} {bound:>12}  {}{}",
                pv.expected_nnz,
                nnz_bound,
                if ok1 { "✓" } else { "✗ L3" },
                if ok2 { "✓" } else { "✗ T4" },
            );
        }
    }
}
