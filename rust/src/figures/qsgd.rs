//! Figures 5–6: GSpar vs QSGD(b) vs dense baseline, x-axis = cumulative
//! communication coding length (the paper's `H(T, M)` formulas), step size
//! `η_t ∝ 1/t` for every method (variance-agnostic, per §5.1).
//!
//! Grid: rows λ₂ ∈ {1/(10N), 1/N}, columns C₂ ∈ {4⁻¹, 4⁻²};
//! Fig 5 uses C₁ = 0.6, Fig 6 uses C₁ = 0.9.

use super::convex_grid::ConvexFigureScale;
use crate::api::{MethodSpec, Session, SyncTask};
use crate::config::Method;
use crate::coordinator::sync::{estimate_f_star, OptKind};
use crate::data::gen_logistic;
use crate::metrics::{ascii_plot, write_csv, RunCurve, XAxis};
use crate::model::LogisticModel;

fn run_cell(
    scale: &ConvexFigureScale,
    c1: f32,
    c2: f32,
    reg_factor: f32,
) -> Vec<RunCurve> {
    let reg = reg_factor / scale.n as f32;
    let ds = gen_logistic(scale.n, scale.d, c1, c2, scale.seed);
    let model = LogisticModel::new(reg);
    let f_star = estimate_f_star(&ds, &model, 400, 1.0);
    let task = SyncTask {
        batch: 8,
        epochs: scale.epochs,
        lr: 1.0,
        opt: OptKind::SgdInvT, // η ∝ 1/t for both methods (paper's setting)
        f_star,
        ..SyncTask::default()
    };
    let mut curves = Vec::new();
    for (method, bits) in [
        (Method::Dense, 32),
        (Method::GSpar, 32),
        (Method::Qsgd, 2),
        (Method::Qsgd, 4),
        (Method::Qsgd, 8),
    ] {
        let session = Session::builder()
            .method(MethodSpec::from_parts(method, 0.1, c2 * c1, bits))
            .workers(4)
            .seed(scale.seed)
            .build();
        let mut c = session.train_convex(&task, &ds, &model);
        if method == Method::Qsgd {
            c.name = format!("QSGD({bits})");
        }
        curves.push(c);
    }
    curves
}

fn run_fig(name: &str, c1: f32, scale: &ConvexFigureScale) {
    println!("\n================ {name} (C1={c1}) ================");
    let mut all = Vec::new();
    for (ri, reg_factor) in [0.1f32, 1.0].iter().enumerate() {
        for (ci, c2) in [0.25f32, 0.0625].iter().enumerate() {
            let curves = run_cell(scale, c1, *c2, *reg_factor);
            println!(
                "\n--- cell (reg={}N⁻¹, C2=4^-{}) — x-axis: coding length (bits) ---",
                if ri == 0 { "0.1" } else { "1" },
                ci + 1
            );
            for c in &curves {
                println!(
                    "  {:<28} final subopt {:.4e}  total bits {:.3e}  bits/elt {:.2}",
                    c.label(),
                    c.final_loss(),
                    c.ledger.ideal_bits as f64,
                    c.ledger.ideal_bits as f64
                        / (c.ledger.messages as f64 * scale.d as f64).max(1.0),
                );
            }
            print!("{}", ascii_plot(&curves, 64, 12, XAxis::CommBits));
            for mut c in curves {
                c.name = format!("r{ri}c{ci}_{}", c.name);
                all.push(c);
            }
        }
    }
    let path = super::results_dir().join(format!("{name}.csv"));
    if let Err(e) = write_csv(&path, &all) {
        eprintln!("warning: could not write {}: {e}", path.display());
    } else {
        println!("\nwrote {}", path.display());
    }
}

/// Figure 5: C₁ = 0.6.
pub fn fig5(scale: &ConvexFigureScale) {
    run_fig("fig5_qsgd_c1_0.6", 0.6, scale);
}

/// Figure 6: C₁ = 0.9.
pub fn fig6(scale: &ConvexFigureScale) {
    run_fig("fig6_qsgd_c1_0.9", 0.9, scale);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gspar_spends_fewer_bits_than_qsgd_at_strong_sparsity() {
        let scale = ConvexFigureScale {
            n: 128,
            d: 512,
            epochs: 8,
            seed: 6,
        };
        // Strong sparsity setting (C1 small shrinks masked coordinates).
        let curves = run_cell(&scale, 0.2, 0.25, 0.1);
        let gspar = &curves[1];
        let qsgd4 = &curves[3];
        assert_eq!(gspar.ledger.messages, qsgd4.ledger.messages);
        assert!(
            gspar.ledger.ideal_bits < qsgd4.ledger.ideal_bits,
            "gspar bits {} should undercut QSGD(4) {} on sparse gradients",
            gspar.ledger.ideal_bits,
            qsgd4.ledger.ideal_bits
        );
    }
}
