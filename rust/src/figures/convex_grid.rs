//! Figures 1–4: synchronous SGD / SVRG on synthetic ℓ2-logistic regression.
//!
//! Paper grid (§5.1): N = 1024, d = 2048, M = 4 workers, minibatch 8;
//! rows λ₂ ∈ {1/(10N), 1/N}; columns C₂ ∈ {4⁻¹, 4⁻², 4⁻³};
//! Fig 1/3 use C₁ = 0.6 (weaker sparsity), Fig 2/4 use C₁ = 0.9 (stronger).
//! Series: GSpar vs UniSp vs dense baseline, labeled with the realized
//! `var` and `spa` statistics; x-axis = data passes, y-axis = suboptimality.

use crate::api::{MethodSpec, Session, SyncTask};
use crate::config::Method;
use crate::coordinator::sync::{estimate_f_star, OptKind, SvrgVariant};
use crate::data::gen_logistic;
use crate::metrics::{ascii_plot, write_csv, RunCurve, XAxis};
use crate::model::LogisticModel;

/// Problem scale for the convex figures — paper scale or a fast CI scale.
#[derive(Clone, Copy, Debug)]
pub struct ConvexFigureScale {
    pub n: usize,
    pub d: usize,
    pub epochs: usize,
    pub seed: u64,
}

impl ConvexFigureScale {
    /// The paper's exact setting.
    pub fn paper() -> Self {
        Self {
            n: 1024,
            d: 2048,
            epochs: 30,
            seed: 2018,
        }
    }

    /// Reduced scale for smoke runs / CI.
    pub fn quick() -> Self {
        Self {
            n: 256,
            d: 512,
            epochs: 12,
            seed: 2018,
        }
    }
}

fn grid_cell(
    scale: &ConvexFigureScale,
    c1: f32,
    c2: f32,
    reg_factor: f32, // 0.1 => 1/(10N); 1.0 => 1/N
    opt: OptKind,
    rho: f32,
) -> Vec<RunCurve> {
    let reg = reg_factor / scale.n as f32;
    let ds = gen_logistic(scale.n, scale.d, c1, c2, scale.seed);
    let model = LogisticModel::new(reg);
    let f_star = estimate_f_star(&ds, &model, 400, 1.0);
    let task = SyncTask {
        batch: 8,
        epochs: scale.epochs,
        lr: if matches!(opt, OptKind::Svrg(_)) { 0.25 } else { 1.0 },
        opt,
        f_star,
        ..SyncTask::default()
    };
    [Method::Dense, Method::GSpar, Method::UniSp]
        .iter()
        .map(|&method| {
            let session = Session::builder()
                .method(MethodSpec::from_parts(method, rho, c2 * c1, 4))
                .workers(4)
                .seed(scale.seed)
                .build();
            session.train_convex(&task, &ds, &model)
        })
        .collect()
}

fn run_grid(name: &str, c1: f32, opt: OptKind, scale: &ConvexFigureScale) {
    println!("\n================ {name} (C1={c1}) ================");
    let mut all = Vec::new();
    for (ri, reg_factor) in [0.1f32, 1.0].iter().enumerate() {
        for (ci, c2) in [0.25f32, 0.0625, 0.015625].iter().enumerate() {
            let rho = 0.1;
            let curves = grid_cell(scale, c1, *c2, *reg_factor, opt, rho);
            println!(
                "\n--- cell (reg={}N⁻¹, C2=4^-{}) ---",
                if ri == 0 { "0.1" } else { "1" },
                ci + 1
            );
            for c in &curves {
                println!(
                    "  {:<28} final subopt {:.4e}  bits {:.3e}",
                    c.label(),
                    c.final_loss(),
                    c.ledger.ideal_bits as f64
                );
            }
            print!("{}", ascii_plot(&curves, 64, 12, XAxis::DataPasses));
            for mut c in curves {
                c.name = format!("r{ri}c{ci}_{}", c.name);
                all.push(c);
            }
        }
    }
    let path = super::results_dir().join(format!("{name}.csv"));
    if let Err(e) = write_csv(&path, &all) {
        eprintln!("warning: could not write {}: {e}", path.display());
    } else {
        println!("\nwrote {}", path.display());
    }
}

/// Figure 1: SGD, C₁ = 0.6 (weaker sparsity).
pub fn fig1(scale: &ConvexFigureScale) {
    run_grid("fig1_sgd_c1_0.6", 0.6, OptKind::Sgd, scale);
}

/// Figure 2: SGD, C₁ = 0.9 (stronger sparsity).
pub fn fig2(scale: &ConvexFigureScale) {
    run_grid("fig2_sgd_c1_0.9", 0.9, OptKind::Sgd, scale);
}

/// Figure 3: SVRG, C₁ = 0.6.
pub fn fig3(scale: &ConvexFigureScale) {
    run_grid(
        "fig3_svrg_c1_0.6",
        0.6,
        OptKind::Svrg(SvrgVariant::SparsifyFull),
        scale,
    );
}

/// Figure 4: SVRG, C₁ = 0.9.
pub fn fig4(scale: &ConvexFigureScale) {
    run_grid(
        "fig4_svrg_c1_0.9",
        0.9,
        OptKind::Svrg(SvrgVariant::SparsifyFull),
        scale,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_cell_produces_three_ordered_series() {
        let scale = ConvexFigureScale {
            n: 128,
            d: 256,
            epochs: 6,
            seed: 5,
        };
        let curves = grid_cell(&scale, 0.6, 0.25, 0.1, OptKind::Sgd, 0.1);
        assert_eq!(curves.len(), 3);
        // baseline var = 1, GSpar var < UniSp var (the figure's key shape).
        assert!(curves[0].var_ratio <= 1.0 + 1e-9);
        assert!(curves[1].var_ratio < curves[2].var_ratio);
        for c in &curves {
            assert!(c.points.len() >= 2);
        }
    }
}
