//! End-to-end transformer training driver: proves the full stack composes —
//! L1 Pallas kernels and L2 JAX graphs lowered to HLO artifacts, loaded by
//! the L3 Rust runtime, trained data-parallel with per-layer gradient
//! sparsification, honest encoded messages, and Adam.
//!
//! Used by both `gsparse e2e` and `examples/transformer_e2e.rs`; the run is
//! recorded in EXPERIMENTS.md.

use crate::api::{MethodSpec, Session};
use crate::data::ByteCorpus;
use crate::metrics::{write_csv, CurvePoint, RunCurve};
use crate::model::hlo::HloTrainStep;
use crate::opt::Adam;
use crate::runtime::Runtime;

/// Train the transformer artifact for `steps` rounds with `workers`
/// simulated data-parallel workers and per-layer GSpar at density `rho`
/// (`rho >= 1.0` = dense); `batch` ships each round as one `WireBatch`
/// frame per worker (`--batch-layers`). Prints the loss curve; writes
/// `results/e2e_transformer.csv`.
pub fn run_transformer_e2e(
    steps: usize,
    workers: usize,
    rho: f32,
    batch: bool,
) -> anyhow::Result<()> {
    let mut rt = Runtime::cpu()?.with_artifact_dir("artifacts")?;
    let step = HloTrainStep::from_manifest(&mut rt, "transformer_step")?;
    let total_params = step.total_params();
    let (bsz, seq) = (step.x_dims[0], step.x_dims[1]);
    println!(
        "transformer e2e: {} params across {} tensors; batch {bsz} x seq {seq}; \
         {workers} workers; rho {rho}",
        total_params,
        step.params.len()
    );
    let mut params = step.init_params(&mut rt, 42)?;
    let corpus = ByteCorpus::generate(1 << 16, 64, 7);
    println!(
        "corpus: {} bytes, unigram entropy {:.3} nats (uniform = {:.3})",
        corpus.bytes.len(),
        corpus.unigram_entropy_nats(),
        (64f64).ln()
    );

    let layer_dims = step.layer_dims();
    let method = if rho >= 1.0 {
        MethodSpec::Dense
    } else {
        MethodSpec::GSpar { rho: rho.min(1.0), iters: 2 }
    };
    let session = Session::builder()
        .method(method)
        .workers(workers)
        .seed(99)
        .batch_layers(batch)
        .build();
    let mut cluster = session.cluster(&layer_dims);
    let mut adams: Vec<Adam> = layer_dims.iter().map(|&d| Adam::new(d, 3e-3)).collect();
    let mut rng = crate::rngkit::Xoshiro256pp::seed_from_u64(1);

    let mut curve = RunCurve::new(format!("transformer-rho{rho}"));
    let t0 = std::time::Instant::now();
    for t in 0..steps {
        let mut worker_grads = Vec::with_capacity(workers);
        let mut loss_sum = 0.0f64;
        for _ in 0..workers {
            let mut toks = Vec::with_capacity(bsz * seq);
            let mut tgts = Vec::with_capacity(bsz * seq);
            for _ in 0..bsz {
                let (tk, tg) = corpus.sample_window(seq, &mut rng);
                toks.extend(tk);
                tgts.extend(tg);
            }
            let (loss, grads) = step.grads_tokens(&mut rt, &params, &toks, &tgts)?;
            loss_sum += loss as f64;
            worker_grads.push(grads);
        }
        let updates = cluster.round(&worker_grads);
        for ((p, upd), adam) in params.iter_mut().zip(&updates).zip(adams.iter_mut()) {
            adam.step(p, &upd.grad);
        }
        let loss = loss_sum / workers as f64;
        curve.points.push(CurvePoint {
            data_passes: t as f64,
            loss,
            comm_bits: cluster.ledger.ideal_bits,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        });
        if t % 10 == 0 || t + 1 == steps {
            println!(
                "step {t:>4}: loss {loss:.4}  (var {:.2}, spa {:.4}, {:.1} Mbit sent, {:.1} s)",
                cluster.var_meter.value(),
                cluster.spa_meter.value(),
                cluster.ledger.ideal_bits as f64 / 1e6,
                t0.elapsed().as_secs_f64()
            );
        }
    }
    curve.var_ratio = cluster.var_meter.value();
    curve.sparsity = cluster.spa_meter.value();
    curve.ledger = cluster.ledger.clone();

    let first = curve.points.first().map(|p| p.loss).unwrap_or(f64::NAN);
    let last = curve.final_loss();
    println!(
        "\nloss {first:.4} -> {last:.4} over {steps} steps; \
         comm {:.2} Mbit ideal ({:.2} MB wire); dense would be {:.2} Mbit",
        curve.ledger.ideal_bits as f64 / 1e6,
        curve.ledger.wire_bytes as f64 / 1e6,
        (steps * workers * total_params * 32) as f64 / 1e6,
    );
    let path = super::results_dir().join("e2e_transformer.csv");
    write_csv(&path, std::slice::from_ref(&curve))?;
    println!("wrote {}", path.display());
    anyhow::ensure!(last < first, "loss must decrease ({first} -> {last})");
    Ok(())
}
