//! Lock-free metrics registry with Prometheus text rendering.
//!
//! Two-phase discipline, same shape as the trace rings: **registration**
//! (naming a series, attaching labels, fixing histogram buckets) takes the
//! registry mutex and may allocate — it happens at setup time, once per
//! series. **Updates** go through the returned [`Counter`] / [`Gauge`] /
//! [`Histo`] handles, which are `Arc`s over plain atomics: one relaxed
//! RMW per update, no lock, no allocation, no clock — safe to call from
//! the round hot loop. **Rendering** ([`Registry::render`]) takes the
//! mutex again (scrape-time only) and emits Prometheus text exposition
//! format 0.0.4, the thing `curl`/Prometheus expect from `/metrics`.
//!
//! Registering the same `(name, labels)` twice returns a handle to the
//! same underlying series (idempotent), so per-round code can look its
//! series up without threading handles through every signature — though
//! holding the handle is cheaper.

use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{Arc, Mutex};
use std::fmt::Write as _;

/// A monotone counter handle. Clone freely; all clones hit one cell.
#[derive(Clone, Debug)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Add `v` to the counter.
    // verifier: hot-path — one relaxed RMW, nothing else.
    #[inline]
    pub fn inc_by(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    /// Add one.
    // verifier: hot-path — one relaxed RMW, nothing else.
    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A point-in-time gauge handle (stores f64 bits in an atomic).
#[derive(Clone, Debug)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    /// Set the gauge.
    // verifier: hot-path — one relaxed store, nothing else.
    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

#[derive(Debug)]
struct HistoInner {
    /// Upper bounds of the finite buckets (ascending); the +Inf bucket is
    /// implicit. Fixed at registration — updates never resize anything.
    bounds: Box<[f64]>,
    /// Non-cumulative per-bucket counts; `buckets[bounds.len()]` is +Inf.
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    /// Running sum as f64 bits, advanced by a CAS loop (lock-free).
    sum_bits: AtomicU64,
}

/// A fixed-bucket histogram handle.
#[derive(Clone, Debug)]
pub struct Histo(Arc<HistoInner>);

impl Histo {
    /// Record one observation.
    // verifier: hot-path — bounded scan + relaxed RMWs; the sum uses a
    // CAS loop (lock-free, never parks).
    #[inline]
    pub fn observe(&self, v: f64) {
        let inner = &*self.0;
        let mut idx = inner.bounds.len();
        for (i, b) in inner.bounds.iter().enumerate() {
            if v <= *b {
                idx = i;
                break;
            }
        }
        inner.buckets[idx].fetch_add(1, Ordering::Relaxed);
        inner.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = inner.sum_bits.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + v).to_bits();
            match inner
                .sum_bits
                .compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.0.sum_bits.load(Ordering::Relaxed))
    }
}

enum Cell {
    Counter(Arc<AtomicU64>),
    Gauge(Arc<AtomicU64>),
    Histo(Arc<HistoInner>),
}

struct Series {
    /// Pre-rendered label block, `{k="v",...}` or empty.
    labels: String,
    cell: Cell,
}

struct Family {
    name: String,
    help: String,
    kind: &'static str, // "counter" | "gauge" | "histogram"
    series: Vec<Series>,
}

/// The registry: shared, cheap to clone, internally a mutex over the
/// family list (taken only at registration and render time).
#[derive(Clone, Default)]
pub struct Registry {
    families: Arc<Mutex<Vec<Family>>>,
}

/// Render a label set as the exposition block: `{a="x",b="y"}`.
fn render_labels(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let mut out = String::from("{");
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{k}=\"");
        for ch in v.chars() {
            match ch {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                c => out.push(c),
            }
        }
        out.push('"');
    }
    out.push('}');
    out
}

/// Format a float the exposition format accepts (`+Inf`/`-Inf`/`NaN`).
fn fmt_f64(v: f64, out: &mut String) {
    if v.is_nan() {
        out.push_str("NaN");
    } else if v == f64::INFINITY {
        out.push_str("+Inf");
    } else if v == f64::NEG_INFINITY {
        out.push_str("-Inf");
    } else {
        let _ = write!(out, "{v}");
    }
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    fn series_cell(
        &self,
        name: &str,
        help: &str,
        kind: &'static str,
        labels: &[(&str, &str)],
        mk: impl FnOnce() -> Cell,
    ) -> Cell {
        let label_block = render_labels(labels);
        let mut fams = self.families.lock().expect("metrics registry");
        let fam = match fams.iter_mut().find(|f| f.name == name) {
            Some(f) => {
                assert_eq!(
                    f.kind, kind,
                    "metric `{name}` registered as {} and {kind}",
                    f.kind
                );
                f
            }
            None => {
                fams.push(Family {
                    name: name.to_string(),
                    help: help.to_string(),
                    kind,
                    series: Vec::new(),
                });
                fams.last_mut().unwrap()
            }
        };
        if let Some(s) = fam.series.iter().find(|s| s.labels == label_block) {
            return match &s.cell {
                Cell::Counter(c) => Cell::Counter(Arc::clone(c)),
                Cell::Gauge(g) => Cell::Gauge(Arc::clone(g)),
                Cell::Histo(h) => Cell::Histo(Arc::clone(h)),
            };
        }
        let cell = mk();
        let clone = match &cell {
            Cell::Counter(c) => Cell::Counter(Arc::clone(c)),
            Cell::Gauge(g) => Cell::Gauge(Arc::clone(g)),
            Cell::Histo(h) => Cell::Histo(Arc::clone(h)),
        };
        fam.series.push(Series {
            labels: label_block,
            cell,
        });
        clone
    }

    /// Register (or look up) a monotone counter.
    pub fn counter(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Counter {
        match self.series_cell(name, help, "counter", labels, || {
            Cell::Counter(Arc::new(AtomicU64::new(0)))
        }) {
            Cell::Counter(c) => Counter(c),
            _ => unreachable!(),
        }
    }

    /// Register (or look up) a gauge.
    pub fn gauge(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Gauge {
        match self.series_cell(name, help, "gauge", labels, || {
            Cell::Gauge(Arc::new(AtomicU64::new(0f64.to_bits())))
        }) {
            Cell::Gauge(g) => Gauge(g),
            _ => unreachable!(),
        }
    }

    /// Register (or look up) a histogram with the given finite upper
    /// bounds (ascending; +Inf is implicit). Bounds are fixed for the life
    /// of the series — a second registration's `bounds` are ignored.
    pub fn histogram(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        bounds: &[f64],
    ) -> Histo {
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must ascend"
        );
        match self.series_cell(name, help, "histogram", labels, || {
            Cell::Histo(Arc::new(HistoInner {
                bounds: bounds.to_vec().into_boxed_slice(),
                buckets: (0..bounds.len() + 1)
                    .map(|_| AtomicU64::new(0))
                    .collect::<Vec<_>>()
                    .into_boxed_slice(),
                count: AtomicU64::new(0),
                sum_bits: AtomicU64::new(0f64.to_bits()),
            }))
        }) {
            Cell::Histo(h) => Histo(h),
            _ => unreachable!(),
        }
    }

    /// Render every family in Prometheus text exposition format 0.0.4.
    pub fn render(&self) -> String {
        let fams = self.families.lock().expect("metrics registry");
        let mut out = String::new();
        for fam in fams.iter() {
            let _ = writeln!(out, "# HELP {} {}", fam.name, fam.help);
            let _ = writeln!(out, "# TYPE {} {}", fam.name, fam.kind);
            for s in &fam.series {
                match &s.cell {
                    Cell::Counter(c) => {
                        let _ = writeln!(
                            out,
                            "{}{} {}",
                            fam.name,
                            s.labels,
                            c.load(Ordering::Relaxed)
                        );
                    }
                    Cell::Gauge(g) => {
                        let _ = write!(out, "{}{} ", fam.name, s.labels);
                        fmt_f64(f64::from_bits(g.load(Ordering::Relaxed)), &mut out);
                        out.push('\n');
                    }
                    Cell::Histo(h) => {
                        // Exposition histograms are cumulative per bucket;
                        // the cells store raw counts, so accumulate here.
                        let mut cum = 0u64;
                        for (i, b) in h.bounds.iter().enumerate() {
                            cum += h.buckets[i].load(Ordering::Relaxed);
                            let _ = write!(out, "{}_bucket{{", fam.name);
                            if !s.labels.is_empty() {
                                // splice the bucket label into the block
                                out.push_str(&s.labels[1..s.labels.len() - 1]);
                                out.push(',');
                            }
                            out.push_str("le=\"");
                            fmt_f64(*b, &mut out);
                            let _ = writeln!(out, "\"}} {cum}");
                        }
                        cum += h.buckets[h.bounds.len()].load(Ordering::Relaxed);
                        let _ = write!(out, "{}_bucket{{", fam.name);
                        if !s.labels.is_empty() {
                            out.push_str(&s.labels[1..s.labels.len() - 1]);
                            out.push(',');
                        }
                        let _ = writeln!(out, "le=\"+Inf\"}} {cum}");
                        let _ = write!(out, "{}_sum{} ", fam.name, s.labels);
                        fmt_f64(f64::from_bits(h.sum_bits.load(Ordering::Relaxed)), &mut out);
                        out.push('\n');
                        let _ = writeln!(
                            out,
                            "{}_count{} {}",
                            fam.name,
                            s.labels,
                            h.count.load(Ordering::Relaxed)
                        );
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_render_exposition_text() {
        let reg = Registry::new();
        let c = reg.counter("rounds_total", "Completed rounds.", &[("worker", "0")]);
        c.inc();
        c.inc_by(4);
        assert_eq!(c.get(), 5);
        let g = reg.gauge("straggler_ratio", "Slowest/mean round time.", &[]);
        g.set(1.25);
        let text = reg.render();
        assert!(text.contains("# HELP rounds_total Completed rounds."));
        assert!(text.contains("# TYPE rounds_total counter"));
        assert!(text.contains("rounds_total{worker=\"0\"} 5"));
        assert!(text.contains("# TYPE straggler_ratio gauge"));
        assert!(text.contains("straggler_ratio 1.25"));
    }

    #[test]
    fn reregistration_returns_the_same_series() {
        let reg = Registry::new();
        let a = reg.counter("x_total", "x", &[("k", "v")]);
        let b = reg.counter("x_total", "x", &[("k", "v")]);
        a.inc();
        b.inc();
        assert_eq!(a.get(), 2);
        // A different label set is a different series under one family.
        let c = reg.counter("x_total", "x", &[("k", "w")]);
        c.inc_by(7);
        let text = reg.render();
        assert!(text.contains("x_total{k=\"v\"} 2"));
        assert!(text.contains("x_total{k=\"w\"} 7"));
        assert_eq!(text.matches("# TYPE x_total").count(), 1);
    }

    #[test]
    fn histogram_buckets_are_cumulative_with_inf() {
        let reg = Registry::new();
        let h = reg.histogram(
            "round_seconds",
            "Round latency.",
            &[("worker", "1")],
            &[0.001, 0.01, 0.1],
        );
        h.observe(0.0005);
        h.observe(0.05);
        h.observe(2.0);
        assert_eq!(h.count(), 3);
        assert!((h.sum() - 2.0505).abs() < 1e-12);
        let text = reg.render();
        assert!(text.contains("round_seconds_bucket{worker=\"1\",le=\"0.001\"} 1"));
        assert!(text.contains("round_seconds_bucket{worker=\"1\",le=\"0.01\"} 1"));
        assert!(text.contains("round_seconds_bucket{worker=\"1\",le=\"0.1\"} 2"));
        assert!(text.contains("round_seconds_bucket{worker=\"1\",le=\"+Inf\"} 3"));
        assert!(text.contains("round_seconds_count{worker=\"1\"} 3"));
        assert!(text.contains("# TYPE round_seconds histogram"));
    }

    #[test]
    fn gauge_specials_render_prometheus_style() {
        let reg = Registry::new();
        let g = reg.gauge("g", "g", &[]);
        g.set(f64::INFINITY);
        assert!(reg.render().contains("g +Inf"));
        g.set(f64::NAN);
        assert!(reg.render().contains("g NaN"));
    }

    #[test]
    fn updates_are_safe_across_threads() {
        let reg = Registry::new();
        let c = reg.counter("t_total", "t", &[]);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let c = c.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 4000);
    }
}
