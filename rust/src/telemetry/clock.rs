//! NTP-style per-link clock-offset estimation.
//!
//! Every process timestamps trace events on its own monotonic clock
//! ([`crate::trace::now_ns`]), so two processes' dumps disagree by an
//! unknown per-pair offset — merging them naively puts a `frame_rx`
//! *before* its `frame_tx`. The transport's PROBE frames fix that with the
//! classic four-timestamp exchange:
//!
//! ```text
//!   local  ──t0──▶ PING ──▶ peer t1 (rx) … t2 (tx) ──▶ PONG ──t3──▶ local
//! ```
//!
//! * offset  θ = ((t1 − t0) + (t2 − t3)) / 2   (peer clock − local clock)
//! * rtt     δ = (t3 − t0) − (t2 − t1)
//!
//! θ's error is bounded by δ/2 (attained only when the path delay is
//! fully asymmetric), so the estimator keeps the sample with the smallest
//! rtt seen — the standard min-filter: the tighter the round trip, the
//! tighter the bound. On loopback links rtt is tens of microseconds, which
//! is what gets the merged-timeline skew to sub-millisecond.
//!
//! The estimate maps peer timestamps into local time as
//! `local ≈ peer_ts − θ`. Residual error (up to δ/2) can still produce
//! slightly negative flow latencies; the merger's causal clamp
//! ([`crate::telemetry::merge`]) absorbs that.

/// Running best-sample estimate of one peer's clock offset.
#[derive(Clone, Copy, Debug, Default)]
pub struct ClockEstimator {
    offset_ns: i64,
    best_rtt_ns: u64,
    samples: u32,
}

impl ClockEstimator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Feed one four-timestamp probe exchange (`t0`,`t3` on the local
    /// clock; `t1`,`t2` on the peer's). Returns `true` when the sample
    /// beat the best rtt so far and updated the estimate. Samples with a
    /// non-positive rtt (reordered or corrupt timestamps) are rejected.
    pub fn update(&mut self, t0: u64, t1: u64, t2: u64, t3: u64) -> bool {
        let rtt = (t3 as i128 - t0 as i128) - (t2 as i128 - t1 as i128);
        if rtt < 0 || t3 < t0 {
            return false;
        }
        let rtt = rtt as u64;
        self.samples += 1;
        if self.samples == 1 || rtt < self.best_rtt_ns {
            let theta = ((t1 as i128 - t0 as i128) + (t2 as i128 - t3 as i128)) / 2;
            self.offset_ns = theta as i64;
            self.best_rtt_ns = rtt;
            true
        } else {
            false
        }
    }

    /// Estimated `peer clock − local clock` in nanoseconds (0 until the
    /// first accepted sample).
    pub fn offset_ns(&self) -> i64 {
        self.offset_ns
    }

    /// Round-trip time of the best (kept) sample.
    pub fn rtt_ns(&self) -> Option<u64> {
        (self.samples > 0).then_some(self.best_rtt_ns)
    }

    /// Worst-case offset error of the kept sample: δ/2.
    pub fn error_bound_ns(&self) -> Option<u64> {
        self.rtt_ns().map(|r| r / 2)
    }

    /// Accepted probe exchanges so far.
    pub fn samples(&self) -> u32 {
        self.samples
    }

    /// Map a peer timestamp onto the local clock (saturating at 0).
    pub fn peer_to_local_ns(&self, peer_ns: u64) -> u64 {
        (peer_ns as i128 - self.offset_ns as i128).max(0) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build the four timestamps of one exchange given a true offset and
    /// asymmetric path delays.
    fn exchange(local_t0: u64, true_offset: i64, d_fwd: u64, d_back: u64) -> (u64, u64, u64, u64) {
        let peer = |local: u64| (local as i128 + true_offset as i128) as u64;
        let t1 = peer(local_t0 + d_fwd);
        let t2 = t1 + 1_000; // peer thinks for 1 µs
        let t3 = (t2 as i128 - true_offset as i128) as u64 + d_back;
        (local_t0, t1, t2, t3)
    }

    #[test]
    fn symmetric_delay_recovers_the_offset_exactly() {
        let mut est = ClockEstimator::new();
        let (t0, t1, t2, t3) = exchange(1_000_000, 123_456_789, 40_000, 40_000);
        assert!(est.update(t0, t1, t2, t3));
        assert_eq!(est.offset_ns(), 123_456_789);
        assert_eq!(est.rtt_ns(), Some(80_000));
        assert_eq!(est.error_bound_ns(), Some(40_000));
    }

    #[test]
    fn asymmetric_delay_error_is_bounded_by_half_rtt() {
        // True offset -5 ms; forward path 10 µs, back path 90 µs.
        let mut est = ClockEstimator::new();
        let (t0, t1, t2, t3) = exchange(6_000_000_000, -5_000_000, 10_000, 90_000);
        assert!(est.update(t0, t1, t2, t3));
        let err = (est.offset_ns() - (-5_000_000)).unsigned_abs();
        let bound = est.error_bound_ns().unwrap();
        assert!(err <= bound, "err {err} > bound {bound}");
        // The error is exactly the delay asymmetry / 2.
        assert_eq!(err, (90_000 - 10_000) / 2);
    }

    #[test]
    fn min_rtt_filter_keeps_the_tightest_sample() {
        let mut est = ClockEstimator::new();
        // A sloppy sample (wide, asymmetric) followed by a tight one.
        let (a0, a1, a2, a3) = exchange(0, 7_000, 900_000, 100_000);
        assert!(est.update(a0, a1, a2, a3));
        let sloppy = est.offset_ns();
        let (b0, b1, b2, b3) = exchange(5_000_000, 7_000, 2_000, 2_000);
        assert!(est.update(b0, b1, b2, b3));
        assert_eq!(est.offset_ns(), 7_000, "tight sample is exact");
        assert_ne!(sloppy, 7_000, "the sloppy sample alone was biased");
        // A later, wider sample is ignored.
        let (c0, c1, c2, c3) = exchange(9_000_000, 7_000, 300_000, 1_000);
        assert!(!est.update(c0, c1, c2, c3));
        assert_eq!(est.offset_ns(), 7_000);
        assert_eq!(est.samples(), 3);
    }

    #[test]
    fn garbage_samples_are_rejected() {
        let mut est = ClockEstimator::new();
        // Negative rtt: peer "thought" longer than the whole round trip.
        assert!(!est.update(100, 50, 10_000, 200));
        // t3 before t0 (local clock went backwards — impossible input).
        assert!(!est.update(1_000, 1_100, 1_200, 900));
        assert_eq!(est.rtt_ns(), None);
        assert_eq!(est.offset_ns(), 0);
    }

    #[test]
    fn peer_to_local_maps_both_signs() {
        let mut est = ClockEstimator::new();
        let (t0, t1, t2, t3) = exchange(1_000_000, 500, 100, 100);
        est.update(t0, t1, t2, t3);
        assert_eq!(est.peer_to_local_ns(10_500), 10_000);
        let mut neg = ClockEstimator::new();
        let (t0, t1, t2, t3) = exchange(1_000_000, -500, 100, 100);
        neg.update(t0, t1, t2, t3);
        assert_eq!(neg.peer_to_local_ns(10_000), 10_500);
        // Saturation at zero rather than wraparound.
        assert_eq!(est.peer_to_local_ns(0), 0);
    }
}
