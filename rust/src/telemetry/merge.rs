//! Clock-aligned merging of per-role Chrome trace dumps.
//!
//! A distributed run leaves one `<stem>.<tag>.<role>.trace.json` per
//! process (see the naming contract in [`crate::trace`]) plus the server's
//! `<stem>.<tag>.clock.json` of per-worker offsets estimated from PROBE
//! exchanges ([`crate::telemetry::clock`]). This module folds them into
//! one timeline:
//!
//! 1. every worker's timestamps are mapped onto the server clock
//!    (`server_time = worker_ts − offset`);
//! 2. a **causal clamp** absorbs residual estimator error: while any
//!    stamped `frame_tx → frame_rx` pair would run backwards in time, the
//!    receiving role's events are shifted later by the worst violation
//!    (bounded passes; each pass only moves roles forward);
//! 3. roles become Chrome processes (`pid` = role index, named via
//!    metadata events) and every matched flow id becomes a Chrome flow
//!    arrow — a `ph:"s"` at the `frame_tx` and a `ph:"f"` at the matching
//!    `frame_rx` — which is what draws the cross-process causality lines
//!    in Perfetto.
//!
//! The `gsparse trace-merge` subcommand is a thin CLI over
//! [`merge_files`].

use super::json::{self, Json};
use std::collections::HashMap;
use std::path::Path;

/// One event lifted out of a per-role Chrome dump.
#[derive(Clone, Debug, PartialEq)]
pub struct MergeEvent {
    pub name: String,
    pub ts_us: f64,
    pub dur_us: f64,
    pub tid: u64,
    pub round: u64,
    pub layer: u64,
    pub bytes: u64,
    /// Stamped flow id (0 = not flow-bearing).
    pub flow: u64,
}

/// One role's worth of events, tagged with the role name from the dump
/// filename (`server`, `worker0`, …).
#[derive(Clone, Debug)]
pub struct RoleTrace {
    pub role: String,
    pub events: Vec<MergeEvent>,
}

/// What [`merge`] produced.
#[derive(Clone, Debug)]
pub struct MergeReport {
    /// The merged Chrome trace document.
    pub json: String,
    /// `frame_tx`/`frame_rx` pairs linked with flow arrows.
    pub flows_linked: usize,
    /// Flow-bearing events whose counterpart never appeared.
    pub flows_unmatched: usize,
    /// Smallest tx→rx latency in the merged timeline (µs); `+Inf` when no
    /// flow was linked. Non-negative by construction after the clamp.
    pub min_flow_latency_us: f64,
    /// Per-role total shift applied (clock offset + causal clamp), µs.
    pub role_shift_us: Vec<(String, f64)>,
}

/// Extract the role name from a dump path:
/// `<stem>.<tag>.<role>.trace.json[l]` → `<role>`.
pub fn role_from_path(path: &Path) -> Option<String> {
    let name = path.file_name()?.to_str()?;
    let before = name
        .strip_suffix(".trace.json")
        .or_else(|| name.strip_suffix(".trace.jsonl"))?;
    let role = before.rsplit('.').next()?;
    (!role.is_empty()).then(|| role.to_string())
}

/// Parse one Chrome dump (ours: `X` events with `args.{round,layer,bytes}`
/// and optionally `args.flow`).
pub fn parse_chrome_trace(text: &str) -> Result<Vec<MergeEvent>, String> {
    let doc = json::parse(text)?;
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or("no traceEvents array")?;
    let mut out = Vec::with_capacity(events.len());
    for e in events {
        if e.get("ph").and_then(Json::as_str) != Some("X") {
            continue; // metadata/flow events from an earlier merge pass
        }
        let num = |key: &str| e.get(key).and_then(Json::as_f64).unwrap_or(0.0);
        let arg = |key: &str| {
            e.get("args")
                .and_then(|a| a.get(key))
                .and_then(Json::as_u64)
                .unwrap_or(0)
        };
        out.push(MergeEvent {
            name: e
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("?")
                .to_string(),
            ts_us: num("ts"),
            dur_us: num("dur"),
            tid: e.get("tid").and_then(Json::as_u64).unwrap_or(0),
            round: arg("round"),
            layer: arg("layer"),
            bytes: arg("bytes"),
            flow: e
                .get("args")
                .and_then(|a| a.get("flow"))
                .and_then(Json::as_u64)
                .unwrap_or(0),
        });
    }
    Ok(out)
}

/// Parse the server's clock dump: worker id → offset
/// (`worker_clock − server_clock`, ns).
pub fn parse_clock(text: &str) -> Result<Vec<(u32, i64)>, String> {
    let doc = json::parse(text)?;
    let offsets = doc.get("offsets_ns").ok_or("no offsets_ns object")?;
    let Json::Obj(fields) = offsets else {
        return Err("offsets_ns is not an object".into());
    };
    let mut out = Vec::with_capacity(fields.len());
    for (key, v) in fields {
        let id: u32 = key.parse().map_err(|_| format!("bad worker id `{key}`"))?;
        let off = v.as_i64().ok_or(format!("bad offset for worker {key}"))?;
        out.push((id, off));
    }
    Ok(out)
}

/// The initial per-role shift from the clock table: workers move by
/// `−offset` onto the server clock; everything else stays put.
fn clock_shift_us(role: &str, offsets: &[(u32, i64)]) -> f64 {
    let Some(id) = role.strip_prefix("worker").and_then(|s| s.parse::<u32>().ok()) else {
        return 0.0;
    };
    offsets
        .iter()
        .find(|(w, _)| *w == id)
        .map(|(_, off)| -(*off as f64) / 1e3)
        .unwrap_or(0.0)
}

/// Merge per-role traces into one clock-aligned Chrome document.
pub fn merge(roles: &[RoleTrace], offsets: &[(u32, i64)]) -> MergeReport {
    let mut shift: Vec<f64> = roles
        .iter()
        .map(|r| clock_shift_us(&r.role, offsets))
        .collect();

    // Flow endpoints: flow id → (tx role + end-time, rx role + start-time),
    // both in pre-shift role-local µs. First occurrence wins; flow ids are
    // sender-unique so duplicates mean a re-used dump, which we tolerate.
    let mut tx_of: HashMap<u64, (usize, f64)> = HashMap::new();
    let mut rx_of: HashMap<u64, (usize, f64)> = HashMap::new();
    for (ri, role) in roles.iter().enumerate() {
        for e in &role.events {
            if e.flow == 0 {
                continue;
            }
            if e.name == "frame_tx" {
                tx_of.entry(e.flow).or_insert((ri, e.ts_us + e.dur_us));
            } else if e.name == "frame_rx" {
                rx_of.entry(e.flow).or_insert((ri, e.ts_us));
            }
        }
    }

    // Causal clamp: push receivers later until no linked flow runs
    // backwards. Shifts only grow, and each pass takes the worst violation
    // per role, so this settles in one pass for star topologies and a few
    // for rings; 8 passes bound pathological inputs.
    for _ in 0..8 {
        let mut moved = false;
        for (flow, &(tri, ttx)) in &tx_of {
            let Some(&(rri, trx)) = rx_of.get(flow) else {
                continue;
            };
            if tri == rri {
                continue;
            }
            let violation = (ttx + shift[tri]) - (trx + shift[rri]);
            if violation > 0.0 {
                shift[rri] += violation;
                moved = true;
            }
        }
        if !moved {
            break;
        }
    }

    // Link stats + minimum latency after alignment.
    let mut flows_linked = 0usize;
    let mut min_latency = f64::INFINITY;
    let mut links: Vec<(u64, usize, f64, usize, f64)> = Vec::new();
    for (flow, &(tri, ttx)) in &tx_of {
        match rx_of.get(flow) {
            Some(&(rri, trx)) if rri != tri => {
                flows_linked += 1;
                let lat = (trx + shift[rri]) - (ttx + shift[tri]);
                min_latency = min_latency.min(lat);
                links.push((*flow, tri, ttx + shift[tri], rri, trx + shift[rri]));
            }
            _ => {}
        }
    }
    links.sort_by(|a, b| a.2.total_cmp(&b.2));
    // Endpoints with no cross-role counterpart (same-role pairs — e.g. an
    // in-process topology's dump — cannot draw arrows and count on both
    // ends).
    let mut flows_unmatched = 0usize;
    for (flow, (tri, _)) in &tx_of {
        if !matches!(rx_of.get(flow), Some((rri, _)) if rri != tri) {
            flows_unmatched += 1;
        }
    }
    for (flow, (rri, _)) in &rx_of {
        if !matches!(tx_of.get(flow), Some((tri, _)) if tri != rri) {
            flows_unmatched += 1;
        }
    }

    // Emit the merged document: metadata names, every role's events under
    // pid = role index, then the flow arrows.
    use std::fmt::Write as _;
    let mut out = String::new();
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    let mut first = true;
    let mut push_sep = |out: &mut String| {
        if first {
            first = false;
        } else {
            out.push(',');
        }
    };
    for (ri, role) in roles.iter().enumerate() {
        push_sep(&mut out);
        let _ = write!(
            out,
            "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{ri},\"tid\":0,\
             \"args\":{{\"name\":\"{}\"}}}}",
            role.role
        );
        for e in &role.events {
            push_sep(&mut out);
            let _ = write!(
                out,
                "{{\"name\":\"{}\",\"cat\":\"gsparse\",\"ph\":\"X\",\"ts\":{:.3},\
                 \"dur\":{:.3},\"pid\":{ri},\"tid\":{},\"args\":{{\"round\":{},\
                 \"layer\":{},\"bytes\":{}",
                e.name,
                e.ts_us + shift[ri],
                e.dur_us,
                e.tid,
                e.round,
                e.layer,
                e.bytes
            );
            if e.flow != 0 {
                let _ = write!(out, ",\"flow\":{}", e.flow);
            }
            out.push_str("}}");
        }
    }
    for (flow, tri, ttx, rri, trx) in &links {
        push_sep(&mut out);
        let _ = write!(
            out,
            "{{\"name\":\"frame\",\"cat\":\"gsparse.flow\",\"ph\":\"s\",\
             \"id\":\"{flow}\",\"ts\":{ttx:.3},\"pid\":{tri},\"tid\":0}}"
        );
        push_sep(&mut out);
        let _ = write!(
            out,
            "{{\"name\":\"frame\",\"cat\":\"gsparse.flow\",\"ph\":\"f\",\"bp\":\"e\",\
             \"id\":\"{flow}\",\"ts\":{trx:.3},\"pid\":{rri},\"tid\":0}}"
        );
    }
    out.push_str("]}");

    MergeReport {
        json: out,
        flows_linked,
        flows_unmatched,
        min_flow_latency_us: min_latency,
        role_shift_us: roles
            .iter()
            .zip(&shift)
            .map(|(r, s)| (r.role.clone(), *s))
            .collect(),
    }
}

/// File-level convenience: read trace dumps (roles from filenames) and an
/// optional clock dump, then [`merge`].
pub fn merge_files(trace_paths: &[std::path::PathBuf], clock_path: Option<&Path>) -> Result<MergeReport, String> {
    let mut roles = Vec::with_capacity(trace_paths.len());
    for p in trace_paths {
        let role = role_from_path(p).ok_or(format!(
            "{}: not a `<stem>.<tag>.<role>.trace.json` dump",
            p.display()
        ))?;
        let text = std::fs::read_to_string(p).map_err(|e| format!("{}: {e}", p.display()))?;
        let events = parse_chrome_trace(&text).map_err(|e| format!("{}: {e}", p.display()))?;
        roles.push(RoleTrace { role, events });
    }
    let offsets = match clock_path {
        Some(p) => {
            let text = std::fs::read_to_string(p).map_err(|e| format!("{}: {e}", p.display()))?;
            parse_clock(&text).map_err(|e| format!("{}: {e}", p.display()))?
        }
        None => Vec::new(),
    };
    Ok(merge(&roles, &offsets))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(name: &str, ts: f64, flow: u64) -> MergeEvent {
        MergeEvent {
            name: name.to_string(),
            ts_us: ts,
            dur_us: 0.0,
            tid: 0,
            round: 1,
            layer: 0,
            bytes: 36,
            flow,
        }
    }

    #[test]
    fn role_names_come_from_the_dump_filenames() {
        let p = Path::new("out/run.r40.star.worker3.trace.json");
        assert_eq!(role_from_path(p).as_deref(), Some("worker3"));
        let p = Path::new("x.r30.sim.sync.trace.jsonl");
        assert_eq!(role_from_path(p).as_deref(), Some("sync"));
        assert_eq!(role_from_path(Path::new("nope.json")), None);
    }

    #[test]
    fn clock_offsets_shift_workers_onto_the_server_clock() {
        // Worker clock runs 2 ms ahead: its rx at "1000 µs" really happened
        // at server-time ≈ -1000... after the shift the tx→rx latency is 50.
        let server = RoleTrace {
            role: "server".into(),
            events: vec![ev("frame_tx", 3_000.0, 42)],
        };
        let worker = RoleTrace {
            role: "worker0".into(),
            events: vec![ev("frame_rx", 5_050.0, 42)],
        };
        let report = merge(&[server, worker], &[(0, 2_000_000)]);
        assert_eq!(report.flows_linked, 1);
        assert_eq!(report.flows_unmatched, 0);
        assert!((report.min_flow_latency_us - 50.0).abs() < 1e-9, "{}", report.min_flow_latency_us);
        assert_eq!(report.role_shift_us[1], ("worker0".into(), -2_000.0));
    }

    #[test]
    fn causal_clamp_forces_nonnegative_latency() {
        // No clock table and the rx apparently precedes the tx by 30 µs:
        // the clamp must push the receiving role forward.
        let a = RoleTrace {
            role: "server".into(),
            events: vec![ev("frame_tx", 1_000.0, 7), ev("frame_tx", 2_000.0, 8)],
        };
        let b = RoleTrace {
            role: "worker0".into(),
            events: vec![ev("frame_rx", 970.0, 7), ev("frame_rx", 2_100.0, 8)],
        };
        let report = merge(&[a, b], &[]);
        assert_eq!(report.flows_linked, 2);
        assert!(report.min_flow_latency_us >= 0.0);
        // Flow 7 becomes exactly causal; flow 8 keeps its slack + shift.
        assert!((report.role_shift_us[1].1 - 30.0).abs() < 1e-9);
    }

    #[test]
    fn merged_document_carries_flow_arrows_and_process_names() {
        let a = RoleTrace {
            role: "server".into(),
            events: vec![ev("frame_tx", 10.0, 5)],
        };
        let b = RoleTrace {
            role: "worker1".into(),
            events: vec![ev("frame_rx", 20.0, 5), ev("frame_rx", 30.0, 999)],
        };
        let report = merge(&[a, b], &[]);
        assert_eq!(report.flows_linked, 1);
        assert_eq!(report.flows_unmatched, 1, "flow 999 has no tx");
        let doc = crate::telemetry::json::parse(&report.json).unwrap();
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let phs: Vec<&str> = evs
            .iter()
            .filter_map(|e| e.get("ph").and_then(Json::as_str))
            .collect();
        assert_eq!(phs.iter().filter(|p| **p == "M").count(), 2);
        assert_eq!(phs.iter().filter(|p| **p == "s").count(), 1);
        assert_eq!(phs.iter().filter(|p| **p == "f").count(), 1);
        let names: Vec<&str> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
            .filter_map(|e| e.get("args")?.get("name")?.as_str())
            .collect();
        assert_eq!(names, vec!["server", "worker1"]);
        // Re-parsing the merged doc skips the arrows/metadata cleanly.
        let reparsed = parse_chrome_trace(&report.json).unwrap();
        assert_eq!(reparsed.len(), 3);
    }

    #[test]
    fn clock_file_roundtrip() {
        let table =
            parse_clock("{\"schema\":\"gsparse-clock-v1\",\"offsets_ns\":{\"0\":1500,\"2\":-700}}")
                .unwrap();
        assert_eq!(table, vec![(0, 1500), (2, -700)]);
        assert!(parse_clock("{}").is_err());
    }
}
