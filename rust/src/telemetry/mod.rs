//! `gsparse::telemetry` — the live observability plane built on top of the
//! [`crate::trace`] recorder.
//!
//! The trace subsystem answers "where did this run's time go" *after* the
//! run, from per-process dump files. This module adds the three pieces
//! that turn those post-hoc, per-process dumps into a live, cross-process
//! story:
//!
//! * [`registry`] — a lock-free metrics registry (monotone counters,
//!   gauges, fixed-bucket histograms) rendered in Prometheus text
//!   exposition format. Update handles are plain relaxed atomics: the hot
//!   path never blocks, never allocates, and never touches the registration
//!   lock (same discipline as the trace rings, and enforced by the same
//!   verifier `hot-path` rule).
//! * [`http`] — a deliberately tiny blocking HTTP/1.1 responder that
//!   serves the registry at `/metrics` from one accept-loop thread, so a
//!   mid-run `curl` (or a Prometheus scrape job) can watch a distributed
//!   run converge. No async runtime, no external crates — the offline-image
//!   rule.
//! * [`clock`] + [`merge`] — NTP-style per-link clock-offset estimation
//!   (fed by PROBE ping/pong frames piggybacked on the transport, see
//!   [`crate::transport::frame`]) and the trace-file merger that applies
//!   those offsets to per-role Chrome dumps, links `frame_tx`/`frame_rx`
//!   event pairs through their stamped flow ids, and emits one causally
//!   consistent timeline with Chrome flow arrows. [`json`] is the minimal
//!   JSON reader the merger uses on our own dump files.
//!
//! Everything here is observation-only: turning telemetry on changes no
//! wire byte and no model float (pinned by `tests/trace.rs` across all
//! four coordinators — probes are a transport *version* feature, not a
//! telemetry feature, so they flow whether or not anyone is watching).

pub mod clock;
pub mod http;
pub mod json;
pub mod merge;
pub mod registry;

pub use clock::ClockEstimator;
pub use http::MetricsServer;
pub use registry::{Counter, Gauge, Histo, Registry};

/// Environment variable naming the `/metrics` bind address (the
/// `--metrics-addr` CLI flag sets it). Empty/unset means no endpoint.
pub const METRICS_ADDR_ENV: &str = "GSPARSE_METRICS_ADDR";

/// The process-global registry. Code that lives far from the coordinator
/// (e.g. per-worker feedback residual gauges in the in-process topologies)
/// publishes here; the server's HTTP responder serves a run-scoped
/// registry *plus* this one. Cheap to clone (an `Arc` inside).
pub fn global() -> Registry {
    use std::sync::OnceLock;
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new).clone()
}
