//! A minimal JSON reader for the merger.
//!
//! The offline image has no serde; the repo's exporters hand-roll their
//! JSON *writers*, and this is the matching *reader* — just enough of
//! RFC 8259 to parse our own trace/clock dumps back in. One deliberate
//! deviation from "parse every number as f64": plain integers keep full
//! 64-bit precision ([`Json::UInt`]/[`Json::Int`]), because flow ids are
//! `sender << 32 | seq` u64s that do not survive an f64 round-trip (the
//! server's sender rank is `u32::MAX`, putting its flow ids above 2^63).

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// A number with a fraction or exponent (or an integer too big for
    /// the integer variants).
    Num(f64),
    /// A plain non-negative integer, kept exact.
    UInt(u64),
    /// A plain negative integer, kept exact.
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    /// Key order preserved; duplicate keys kept as-is (first `get` wins).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field by key.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value as f64 (any numeric variant).
    pub fn as_f64(&self) -> Option<f64> {
        match *self {
            Json::Num(v) => Some(v),
            Json::UInt(v) => Some(v as f64),
            Json::Int(v) => Some(v as f64),
            _ => None,
        }
    }

    /// Exact u64 (only from the exact-integer variants).
    pub fn as_u64(&self) -> Option<u64> {
        match *self {
            Json::UInt(v) => Some(v),
            Json::Int(v) => u64::try_from(v).ok(),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match *self {
            Json::Int(v) => Some(v),
            Json::UInt(v) => i64::try_from(v).ok(),
            _ => None,
        }
    }
}

/// Parse one JSON document (trailing whitespace allowed, nothing else).
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let value = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing data at byte {pos}"));
    }
    Ok(value)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, ch: u8) -> Result<(), String> {
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == ch {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected `{}` at byte {}", ch as char, *pos))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err("unexpected end of input".into()),
        Some(b'{') => parse_obj(b, pos),
        Some(b'[') => parse_arr(b, pos),
        Some(b'"') => Ok(Json::Str(parse_string(b, pos)?)),
        Some(b't') => parse_lit(b, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false", Json::Bool(false)),
        Some(b'n') => parse_lit(b, pos, "null", Json::Null),
        Some(_) => parse_number(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, value: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        Err(format!("bad literal at byte {}", *pos))
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    expect(b, pos, b'{')?;
    let mut fields = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(fields));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        expect(b, pos, b':')?;
        let value = parse_value(b, pos)?;
        fields.push((key, value));
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            _ => return Err(format!("expected `,` or `}}` at byte {}", *pos)),
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    expect(b, pos, b'[')?;
    let mut items = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => return Err(format!("expected `,` or `]` at byte {}", *pos)),
        }
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at byte {}", *pos));
    }
    *pos += 1;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => return Err("unterminated string".into()),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .ok_or("truncated \\u escape")?;
                        let code = u32::from_str_radix(
                            std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                            16,
                        )
                        .map_err(|_| "bad \\u escape")?;
                        // Surrogate pairs are not emitted by our writers;
                        // map lone surrogates to the replacement char.
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => return Err(format!("bad escape at byte {}", *pos)),
                }
                *pos += 1;
            }
            Some(_) => {
                // Consume one UTF-8 scalar (input is a &str, so slicing on
                // a char boundary found from here is safe).
                let rest = std::str::from_utf8(&b[*pos..]).map_err(|e| e.to_string())?;
                let ch = rest.chars().next().unwrap();
                out.push(ch);
                *pos += ch.len_utf8();
            }
        }
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    let mut has_frac_or_exp = false;
    while let Some(&c) = b.get(*pos) {
        match c {
            b'0'..=b'9' => *pos += 1,
            b'.' | b'e' | b'E' | b'+' | b'-' => {
                has_frac_or_exp = true;
                *pos += 1;
            }
            _ => break,
        }
    }
    let text = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
    if text.is_empty() || text == "-" {
        return Err(format!("bad number at byte {start}"));
    }
    if !has_frac_or_exp {
        if let Ok(v) = text.parse::<u64>() {
            return Ok(Json::UInt(v));
        }
        if let Ok(v) = text.parse::<i64>() {
            return Ok(Json::Int(v));
        }
    }
    text.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("bad number `{text}` at byte {start}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_containers_and_escapes() {
        let doc = parse(
            "{\"a\": [1, -2, 3.5, 1e3, true, false, null], \"s\": \"q\\\"\\\\\\u0041\\n\"}",
        )
        .unwrap();
        let a = doc.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0], Json::UInt(1));
        assert_eq!(a[1], Json::Int(-2));
        assert_eq!(a[2], Json::Num(3.5));
        assert_eq!(a[3], Json::Num(1000.0));
        assert_eq!(a[4], Json::Bool(true));
        assert_eq!(a[6], Json::Null);
        assert_eq!(doc.get("s").unwrap().as_str(), Some("q\"\\A\n"));
    }

    #[test]
    fn big_flow_ids_survive_exactly() {
        // The server's flow ids exceed 2^63 — f64 would mangle them.
        let id = (u32::MAX as u64) << 32 | 12345;
        let doc = parse(&format!("{{\"flow\":{id}}}")).unwrap();
        assert_eq!(doc.get("flow").unwrap().as_u64(), Some(id));
    }

    #[test]
    fn roundtrips_our_own_exporters() {
        use crate::trace::{chrome_trace_json, Event, Stage};
        let events = [Event {
            t_start_ns: 1_500,
            t_end_ns: 2_500,
            bytes: 64,
            flow: (7u64 << 32) | 3,
            round: 2,
            layer: 0,
            stage: Stage::FrameTx,
            worker: 1,
            tid: 0,
        }];
        let doc = parse(&chrome_trace_json(&events)).unwrap();
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].get("name").unwrap().as_str(), Some("frame_tx"));
        assert_eq!(evs[0].get("ts").unwrap().as_f64(), Some(1.5));
        let args = evs[0].get("args").unwrap();
        assert_eq!(args.get("flow").unwrap().as_u64(), Some((7u64 << 32) | 3));
    }

    #[test]
    fn rejects_malformed_documents() {
        assert!(parse("").is_err());
        assert!(parse("{\"a\":1").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{} trailing").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("nulx").is_err());
    }
}
