//! A deliberately tiny blocking HTTP/1.1 responder for `/metrics`.
//!
//! One thread, one connection at a time, `Connection: close` on every
//! response — the absolute minimum that `curl` and a Prometheus scrape
//! job need, with no async runtime and no external crates (the
//! offline-image rule). Serving a scrape costs one registry render on the
//! responder thread; the training hot path is never involved (the
//! registry's update handles are lock-free, and `render` only takes the
//! registration mutex, which the hot path never touches).
//!
//! Lifecycle: [`MetricsServer::start`] binds and spawns the accept loop;
//! dropping the server (or calling [`MetricsServer::stop`]) flips the stop
//! flag and pokes the listener with a loopback connect so the blocking
//! `accept` wakes up and the thread exits. A slow or stuck client cannot
//! wedge the loop: reads carry a 500 ms timeout.

use super::registry::Registry;
use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::Arc;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

/// A running `/metrics` endpoint. Dropping it shuts the thread down.
pub struct MetricsServer {
    addr: String,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an OS-assigned port) and serve
    /// `registries` — later registries win on name collisions simply by
    /// being concatenated after earlier ones; in practice the run registry
    /// and the process-global one use disjoint names.
    pub fn start(addr: &str, registries: Vec<Registry>) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?.to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop_in = Arc::clone(&stop);
        let handle = crate::sync::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop_in.load(Ordering::Relaxed) {
                    break;
                }
                if let Ok(stream) = conn {
                    // Per-connection errors (reset, timeout, bad request)
                    // only lose that one scrape.
                    let _ = serve_one(stream, &registries);
                }
            }
        });
        Ok(Self {
            addr: local,
            stop,
            handle: Some(handle),
        })
    }

    /// The bound address (`host:port`, concrete even when asked for `:0`).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Stop the responder thread and wait for it.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        let Some(handle) = self.handle.take() else {
            return;
        };
        self.stop.store(true, Ordering::Relaxed);
        // Wake the blocking accept. If the connect fails the listener is
        // already gone and the thread has exited on its own.
        let _ = TcpStream::connect(&self.addr);
        let _ = handle.join();
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve_one(mut stream: TcpStream, registries: &[Registry]) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    stream.set_write_timeout(Some(Duration::from_millis(500)))?;
    // Read until the end of the request head (or a 4 KiB cap — nothing we
    // serve takes a body).
    let mut head = Vec::with_capacity(256);
    let mut byte = [0u8; 256];
    while !head.windows(4).any(|w| w == b"\r\n\r\n") && head.len() < 4096 {
        let n = stream.read(&mut byte)?;
        if n == 0 {
            break;
        }
        head.extend_from_slice(&byte[..n]);
    }
    let head = String::from_utf8_lossy(&head);
    let mut parts = head.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    let (status, ctype, body) = match (method, path) {
        ("GET", "/metrics") => {
            let mut text = String::new();
            for r in registries {
                text.push_str(&r.render());
            }
            (
                "200 OK",
                "text/plain; version=0.0.4; charset=utf-8",
                text,
            )
        }
        ("GET", "/") | ("GET", "/health") => ("200 OK", "text/plain", "ok\n".to_string()),
        ("GET", _) => ("404 Not Found", "text/plain", "not found\n".to_string()),
        _ => (
            "405 Method Not Allowed",
            "text/plain",
            "method not allowed\n".to_string(),
        ),
    };
    write!(
        stream,
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(addr: &str, path: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_metrics_health_and_404() {
        let reg = Registry::new();
        let c = reg.counter("scrapes_total", "Scrapes served.", &[]);
        c.inc_by(3);
        let server = MetricsServer::start("127.0.0.1:0", vec![reg.clone()]).unwrap();
        let addr = server.addr().to_string();

        let resp = get(&addr, "/metrics");
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
        assert!(resp.contains("text/plain; version=0.0.4"));
        assert!(resp.contains("scrapes_total 3"));

        // A second scrape sees live updates (counters move between reads).
        c.inc();
        assert!(get(&addr, "/metrics").contains("scrapes_total 4"));

        assert!(get(&addr, "/health").starts_with("HTTP/1.1 200"));
        assert!(get(&addr, "/nope").starts_with("HTTP/1.1 404"));

        server.stop();
        // After stop the port no longer answers.
        assert!(TcpStream::connect(&addr).is_err() || {
            // The OS may allow one last connect to a dying socket; a read
            // must then return nothing.
            let mut s = TcpStream::connect(&addr).unwrap();
            let _ = write!(s, "GET /metrics HTTP/1.1\r\n\r\n");
            let mut buf = String::new();
            s.read_to_string(&mut buf).unwrap_or(0) == 0
        });
    }

    #[test]
    fn concatenates_multiple_registries() {
        let a = Registry::new();
        a.counter("a_total", "a", &[]).inc();
        let b = Registry::new();
        b.gauge("b_gauge", "b", &[]).set(2.5);
        let server = MetricsServer::start("127.0.0.1:0", vec![a, b]).unwrap();
        let resp = get(server.addr(), "/metrics");
        assert!(resp.contains("a_total 1"));
        assert!(resp.contains("b_gauge 2.5"));
    }
}
