//! The paper's core contribution: **unbiased gradient sparsification**.
//!
//! Coordinate `i` of a stochastic gradient `g` survives with probability
//! `p_i` and is amplified to `g_i / p_i`, so the sparsified vector `Q(g)` is
//! unbiased (`E[Q(g)] = g`) with variance `Σ_i g_i² / p_i`. Proposition 1
//! shows the probability vector minimizing expected sparsity under a variance
//! budget has the form `p_i = min(λ |g_i|, 1)`: a *dominating set* `S_k` of
//! the `k` largest-magnitude coordinates is always kept (`p = 1`), and the
//! rest are kept with probability proportional to magnitude. Crucially, every
//! survivor outside `S_k` then carries the *same* value `sign(g_i)/λ`, which
//! the §3.3 hybrid coding exploits.
//!
//! This module provides:
//! * [`probs`] — the two solvers for `p`: closed-form (Algorithm 2, full-sort
//!   reference plus the selection-based O(d + k log k) hot path) and greedy
//!   (Algorithm 3, the one used in all of the paper's experiments);
//! * [`sample`] — Bernoulli selection + unbiased rescaling into the
//!   [`SparseGrad`] split representation;
//! * [`engine`] — the allocation-free [`CompressEngine`] scratch arena
//!   fusing probabilities → sampling → wire encoding, with sharded parallel
//!   compression for large gradients;
//! * [`batch`] — the batched multi-layer [`BatchCompressEngine`]: one
//!   invocation (and one shard-pool dispatch) for a whole model's layer
//!   list, feeding the `WireBatch` wire format;
//! * [`Compressor`] implementations for the paper's method (GSpar) and every
//!   baseline in the evaluation: uniform sampling (UniSp), QSGD, TernGrad,
//!   deterministic top-k, and 1-bit SGD (a plain [`SignCompressor`] composed
//!   with the shared [`crate::feedback`] error-memory subsystem) — all
//!   reusing caller-held message buffers via [`Compressor::compress_into`].

pub mod baselines;
pub mod batch;
pub mod engine;
pub mod pool;
pub mod probs;
pub mod sample;

pub use baselines::{
    OneBitSgd, QsgdCompressor, SignCompressor, TernGradCompressor, TopKCompressor, UniformSampler,
};
pub use batch::BatchCompressEngine;
pub use engine::{CompressEngine, EngineMode};
pub use pool::ShardPool;
pub use probs::{
    closed_form_probs, closed_form_probs_sorted, closed_form_probs_with, greedy_probs,
    ProbVector, SelectScratch,
};
pub use sample::{sample_sparse, sample_sparse_into};

use crate::config::Method;
use crate::rngkit::RandArray;

/// An unbiasedly-sparsified gradient in the paper's two-part representation.
///
/// * `exact` — survivors from the dominating set `S_k` (`p_i = 1`); their
///   values are transmitted as full floats (`Q_A` in §3.3).
/// * `shared` — survivors with `p_i = λ|g_i| < 1`; their decoded value is
///   `± shared_mag` with `shared_mag = 1/λ`, so only index + sign travel on
///   the wire (`Q_B` in §3.3).
#[derive(Debug, Clone, PartialEq)]
pub struct SparseGrad {
    /// Original dimension `d`.
    pub d: u32,
    /// `(index, value)` pairs for `S_k` survivors, ascending index.
    pub exact: Vec<(u32, f32)>,
    /// `(index, is_negative)` for rescaled survivors, ascending index.
    pub shared: Vec<(u32, bool)>,
    /// The common magnitude `1/λ` of all `shared` survivors.
    pub shared_mag: f32,
}

impl SparseGrad {
    /// Reset to an empty gradient of dimension `d`, keeping buffer capacity.
    /// Every reuse path (sampler, codec decode, compressor slots) goes
    /// through here so a future field cannot be left stale on one of them.
    pub fn reset(&mut self, d: usize) {
        self.d = d as u32;
        self.exact.clear();
        self.shared.clear();
        self.shared_mag = 0.0;
    }

    pub fn empty(d: usize) -> Self {
        Self {
            d: d as u32,
            exact: Vec::new(),
            shared: Vec::new(),
            shared_mag: 0.0,
        }
    }

    /// Number of transmitted (non-zero) coordinates.
    pub fn nnz(&self) -> usize {
        self.exact.len() + self.shared.len()
    }

    /// Decode into a dense vector (adds into `out`, scaled by `alpha`).
    pub fn add_into(&self, alpha: f32, out: &mut [f32]) {
        assert_eq!(out.len(), self.d as usize);
        for &(i, v) in &self.exact {
            out[i as usize] += alpha * v;
        }
        let pos = alpha * self.shared_mag;
        for &(i, neg) in &self.shared {
            out[i as usize] += if neg { -pos } else { pos };
        }
    }

    /// Decode to a fresh dense vector.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.d as usize];
        self.add_into(1.0, &mut out);
        out
    }

    /// Squared ℓ2 norm of the decoded vector (computed sparsely).
    pub fn norm2_sq(&self) -> f64 {
        let mut s: f64 = self
            .exact
            .iter()
            .map(|&(_, v)| (v as f64) * (v as f64))
            .sum();
        s += self.shared.len() as f64 * (self.shared_mag as f64) * (self.shared_mag as f64);
        s
    }
}

/// What a compression step produced: either a genuinely sparse message, a
/// dense quantized message (QSGD/TernGrad/1-bit), or the uncompressed vector.
#[derive(Debug, Clone)]
pub enum Compressed {
    /// No compression (the paper's "baseline").
    Dense(Vec<f32>),
    /// Unbiased sparsification (GSpar / UniSp / top-k).
    Sparse(SparseGrad),
    /// QSGD: ℓ2 norm + per-coordinate `sign · level/2^bits`.
    Qsgd {
        d: u32,
        norm: f32,
        bits: u32,
        /// Signed quantization levels, `|level| ≤ 2^bits`.
        levels: Vec<i32>,
    },
    /// TernGrad: scale `s = max|g|` + per-coordinate {-1, 0, +1}.
    Ternary { d: u32, scale: f32, signs: Vec<i8> },
}

impl Compressed {
    /// Dimension of the decoded vector.
    pub fn dim(&self) -> usize {
        match self {
            Compressed::Dense(v) => v.len(),
            Compressed::Sparse(s) => s.d as usize,
            Compressed::Qsgd { d, .. } => *d as usize,
            Compressed::Ternary { d, .. } => *d as usize,
        }
    }

    /// Number of non-zero coordinates in the decoded vector.
    pub fn nnz(&self) -> usize {
        match self {
            Compressed::Dense(v) => v.iter().filter(|&&x| x != 0.0).count(),
            Compressed::Sparse(s) => s.nnz(),
            Compressed::Qsgd { levels, .. } => levels.iter().filter(|&&l| l != 0).count(),
            Compressed::Ternary { signs, .. } => signs.iter().filter(|&&s| s != 0).count(),
        }
    }

    /// `out += alpha * decode(self)`.
    pub fn add_into(&self, alpha: f32, out: &mut [f32]) {
        match self {
            Compressed::Dense(v) => {
                crate::tensor::axpy(alpha, v, out);
            }
            Compressed::Sparse(s) => s.add_into(alpha, out),
            Compressed::Qsgd {
                norm, bits, levels, ..
            } => {
                let unit = *norm / (1u32 << bits) as f32;
                for (o, &l) in out.iter_mut().zip(levels.iter()) {
                    if l != 0 {
                        *o += alpha * unit * l as f32;
                    }
                }
            }
            Compressed::Ternary { scale, signs, .. } => {
                for (o, &s) in out.iter_mut().zip(signs.iter()) {
                    if s != 0 {
                        *o += alpha * scale * s as f32;
                    }
                }
            }
        }
    }

    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.dim()];
        self.add_into(1.0, &mut out);
        out
    }

    /// Serialize the decoded dense form as `f32` LE bytes into `out`
    /// (cleared first), reusing `scratch` for the decode — the `kind = 1`
    /// transport payload for messages that have no byte codec of their own
    /// (QSGD / TernGrad / dense). Both buffers keep their capacity, so the
    /// steady-state path does not allocate.
    pub fn dense_le_bytes_into(&self, scratch: &mut Vec<f32>, out: &mut Vec<u8>) {
        scratch.resize(self.dim(), 0.0);
        scratch.fill(0.0);
        self.add_into(1.0, scratch);
        out.clear();
        out.reserve(4 * scratch.len());
        for &v in scratch.iter() {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }

    /// Squared ℓ2 norm of the decoded message (for the `var` metric).
    pub fn norm2_sq(&self) -> f64 {
        match self {
            Compressed::Dense(v) => crate::tensor::norm2_sq(v) as f64,
            Compressed::Sparse(s) => s.norm2_sq(),
            Compressed::Qsgd {
                norm, bits, levels, ..
            } => {
                let unit = (*norm / (1u32 << bits) as f32) as f64;
                levels
                    .iter()
                    .map(|&l| {
                        let v = unit * l as f64;
                        v * v
                    })
                    .sum()
            }
            Compressed::Ternary { scale, signs, .. } => {
                let s2 = (*scale as f64) * (*scale as f64);
                signs.iter().filter(|&&s| s != 0).count() as f64 * s2
            }
        }
    }
}

/// Per-step statistics reported by a compressor (feeds the paper's `var` and
/// `spa` figure labels and the Fig 5–6 communication-cost x-axis).
#[derive(Debug, Clone, Copy, Default)]
pub struct CompressStats {
    /// Expected sparsity `Σ_i p_i` (realized nnz for deterministic methods).
    pub expected_nnz: f64,
    /// Idealized coding length in bits for this message, per the paper's
    /// §5.1 cost formulas (Theorem 4 hybrid cost for GSpar, `d·b` for dense,
    /// `d·bits`+float for QSGD, 2 bits/coord for TernGrad…).
    pub ideal_bits: u64,
}

/// A gradient compressor: one instance per worker (may carry state, e.g.
/// 1-bit error feedback).
pub trait Compressor: Send {
    /// Compress `g` into a caller-held [`Compressed`], drawing randomness
    /// from the worker's pre-generated uniform array (the paper's §5.3
    /// trick). Implementations reuse the buffers inside `out` when its
    /// variant matches their own — in steady state (same method, same `d`
    /// round after round) this path performs no heap allocation.
    fn compress_into(
        &mut self,
        g: &[f32],
        rand: &mut RandArray,
        out: &mut Compressed,
    ) -> CompressStats;

    /// Convenience wrapper allocating a fresh message (tests, one-shot use).
    fn compress(&mut self, g: &[f32], rand: &mut RandArray) -> (Compressed, CompressStats) {
        let mut out = Compressed::Sparse(SparseGrad::empty(g.len()));
        let stats = self.compress_into(g, rand, &mut out);
        (out, stats)
    }

    /// Compress a whole model's layer list in one call: `out[ℓ]` receives
    /// layer `ℓ`'s message (slots reused; `out` is resized to the layer
    /// count) and `stats` one entry per layer. The default implementation
    /// loops [`Compressor::compress_into`] over the layers on this one
    /// instance — correct for stateless compressors, and exactly what the
    /// per-layer wire path does; GSpar overrides it with the fused
    /// [`BatchCompressEngine`] (shared uniform stream, per-layer solves,
    /// one shard-pool dispatch), producing bitwise-identical messages.
    fn compress_batch_into(
        &mut self,
        layers: &[&[f32]],
        rand: &mut RandArray,
        out: &mut Vec<Compressed>,
        stats: &mut Vec<CompressStats>,
    ) {
        if out.len() < layers.len() {
            out.resize_with(layers.len(), || Compressed::Sparse(SparseGrad::empty(0)));
        }
        out.truncate(layers.len());
        stats.clear();
        for (g, slot) in layers.iter().zip(out.iter_mut()) {
            stats.push(self.compress_into(g, rand, slot));
        }
    }

    /// Human-readable name for figure labels.
    fn name(&self) -> &'static str;

    /// Squared L2 norm of the compressor's carried error-feedback
    /// residual, if it holds one. Memoryless compressors return `None`;
    /// [`crate::feedback::WithFeedback`] overrides this so telemetry can
    /// export the residual norm without knowing the concrete wrapper type.
    fn residual_norm2_sq(&self) -> Option<f64> {
        None
    }
}

/// Forwarding impl so adapters generic over `C: Compressor` (e.g.
/// [`crate::feedback::WithFeedback`]) can wrap a boxed trait object from
/// [`crate::api::MethodSpec::build`] directly.
impl<T: Compressor + ?Sized> Compressor for Box<T> {
    fn compress_into(
        &mut self,
        g: &[f32],
        rand: &mut RandArray,
        out: &mut Compressed,
    ) -> CompressStats {
        (**self).compress_into(g, rand, out)
    }

    fn compress_batch_into(
        &mut self,
        layers: &[&[f32]],
        rand: &mut RandArray,
        out: &mut Vec<Compressed>,
        stats: &mut Vec<CompressStats>,
    ) {
        (**self).compress_batch_into(layers, rand, out, stats)
    }

    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn residual_norm2_sq(&self) -> Option<f64> {
        (**self).residual_norm2_sq()
    }
}

/// Reset `out` to an empty `Compressed::Sparse` of dimension `d`, reusing
/// its buffers when the variant already matches; returns the inner
/// [`SparseGrad`] ready to fill.
pub(crate) fn sparse_slot(out: &mut Compressed, d: usize) -> &mut SparseGrad {
    if !matches!(out, Compressed::Sparse(_)) {
        *out = Compressed::Sparse(SparseGrad::empty(d));
    }
    match out {
        Compressed::Sparse(sg) => {
            sg.reset(d);
            sg
        }
        _ => unreachable!("just set to Sparse"),
    }
}

/// Bits per float on the simulated wire (the paper's `b`). f32 everywhere.
pub const FLOAT_BITS: u64 = 32;

/// `⌈log2 d⌉` — index cost in bits used by the paper's coding-length model.
pub fn index_bits(d: usize) -> u64 {
    (usize::BITS - (d.max(2) - 1).leading_zeros()) as u64
}

/// The paper's GSpar compressor: greedy probabilities (Algorithm 3, the
/// variant used in all experiments) or closed-form (Algorithm 2, via the
/// selection-based solver), then fused Bernoulli sampling and hybrid-coding
/// cost accounting — a thin [`Compressor`] facade over
/// [`BatchCompressEngine`] (whose inner [`CompressEngine`] serves the
/// single-tensor path).
pub struct GSparCompressor {
    /// Use Algorithm 2 (exact) instead of Algorithm 3 (greedy).
    pub exact: bool,
    batch: BatchCompressEngine,
    /// Per-call probability-scalar scratch for the batched path.
    pv_scratch: Vec<ProbVector>,
}

impl GSparCompressor {
    pub fn greedy(rho: f32, iters: usize) -> Self {
        Self {
            exact: false,
            batch: Self::worker_engine(BatchCompressEngine::greedy(rho, iters)),
            pv_scratch: Vec::new(),
        }
    }

    pub fn closed_form(eps: f32) -> Self {
        Self {
            exact: true,
            batch: Self::worker_engine(BatchCompressEngine::closed_form(eps)),
            pv_scratch: Vec::new(),
        }
    }

    /// Per-worker compressors run *inside* coordinator threads (one per
    /// simulated worker), so their embedded engine defaults to the
    /// sequential path — nested sharding would spawn workers×cores scoped
    /// threads per round and oversubscribe the box. Callers that own the
    /// whole core budget (benches, single-stream pipelines) either use
    /// [`CompressEngine`] directly or opt back in via [`Self::engine`].
    fn worker_engine(engine: BatchCompressEngine) -> BatchCompressEngine {
        engine.with_sharding(
            engine::DEFAULT_SHARD_LEN,
            engine::DEFAULT_PARALLEL_MIN_D,
            1,
        )
    }

    /// The scratch-arena engine backing this compressor's single-tensor
    /// path.
    pub fn engine(&mut self) -> &mut CompressEngine {
        self.batch.engine()
    }

    /// The batched multi-layer engine backing
    /// [`Compressor::compress_batch_into`].
    pub fn batch_engine(&mut self) -> &mut BatchCompressEngine {
        &mut self.batch
    }

    /// Compute the probability vector only (used by tests and the fused
    /// L1-kernel cross-checks).
    pub fn probabilities(&mut self, g: &[f32]) -> ProbVector {
        self.batch.engine().probs(g)
    }
}

impl Compressor for GSparCompressor {
    fn compress_into(
        &mut self,
        g: &[f32],
        rand: &mut RandArray,
        out: &mut Compressed,
    ) -> CompressStats {
        let sg = sparse_slot(out, g.len());
        let pv = self.batch.engine().compress_sparse_into(g, rand, sg);
        CompressEngine::stats_for(&pv, g.len())
    }

    fn compress_batch_into(
        &mut self,
        layers: &[&[f32]],
        rand: &mut RandArray,
        out: &mut Vec<Compressed>,
        stats: &mut Vec<CompressStats>,
    ) {
        if out.len() < layers.len() {
            out.resize_with(layers.len(), || Compressed::Sparse(SparseGrad::empty(0)));
        }
        out.truncate(layers.len());
        {
            let mut slots: Vec<&mut SparseGrad> = out
                .iter_mut()
                .zip(layers.iter())
                .map(|(slot, g)| sparse_slot(slot, g.len()))
                .collect();
            self.batch
                .compress_batch_sparse_into(layers, rand, &mut slots, &mut self.pv_scratch);
        }
        stats.clear();
        for (pv, g) in self.pv_scratch.iter().zip(layers.iter()) {
            stats.push(CompressEngine::stats_for(pv, g.len()));
        }
    }

    fn name(&self) -> &'static str {
        if self.exact {
            "GSpar-exact"
        } else {
            "GSpar"
        }
    }
}

/// The paper's §5.1 idealized per-message cost for the hybrid coding:
/// `Σ_{p_i=1}(b + log₂d) + min(2d, log₂d · Σ_{p_i<1} p_i) + b`.
pub fn hybrid_ideal_bits(num_exact: u64, expected_qb: f64, d: usize) -> u64 {
    let ib = index_bits(d);
    let qa = num_exact * (FLOAT_BITS + ib);
    let qb = ((expected_qb.max(0.0)) * ib as f64).min(2.0 * d as f64) as u64;
    qa + qb + FLOAT_BITS
}

/// Dense-transmission cost: `d · b`.
pub fn dense_ideal_bits(d: usize) -> u64 {
    d as u64 * FLOAT_BITS
}

/// Build a compressor for a [`Method`].
///
/// `rho` is the target density (GSpar/UniSp/TopK), `eps` the variance budget
/// (GSpar-exact), `qsgd_bits` the QSGD quantization width.
///
/// Deprecated: the three positional `f32`/`u32` arguments are unlabeled and
/// most of them are ignored by most methods — use the typed
/// [`crate::api::MethodSpec`] instead, whose variants carry exactly the
/// parameters their method consumes. Equivalence between the two paths is
/// pinned by a test in `api`.
#[deprecated(
    since = "0.2.0",
    note = "use gsparse::api::MethodSpec (e.g. `MethodSpec::GSpar { rho, iters: 2 }.build()` \
            or `MethodSpec::from_parts(method, rho, eps, qsgd_bits).build()`)"
)]
pub fn build(method: Method, rho: f32, eps: f32, qsgd_bits: u32) -> Box<dyn Compressor> {
    crate::api::MethodSpec::from_parts(method, rho, eps, qsgd_bits).build()
}

/// Identity compressor (the paper's dense "baseline").
pub struct DenseCompressor;

impl Compressor for DenseCompressor {
    fn compress_into(
        &mut self,
        g: &[f32],
        _rand: &mut RandArray,
        out: &mut Compressed,
    ) -> CompressStats {
        match out {
            Compressed::Dense(v) => {
                v.clear();
                v.extend_from_slice(g);
            }
            other => *other = Compressed::Dense(g.to_vec()),
        }
        CompressStats {
            expected_nnz: g.len() as f64,
            ideal_bits: dense_ideal_bits(g.len()),
        }
    }

    fn name(&self) -> &'static str {
        "baseline"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngkit::RandArray;

    #[test]
    fn sparse_grad_decode_and_norm() {
        let sg = SparseGrad {
            d: 6,
            exact: vec![(0, 2.0), (4, -1.0)],
            shared: vec![(2, false), (5, true)],
            shared_mag: 0.5,
        };
        assert_eq!(sg.nnz(), 4);
        let dense = sg.to_dense();
        assert_eq!(dense, vec![2.0, 0.0, 0.5, 0.0, -1.0, -0.5]);
        let n2: f64 = dense.iter().map(|&v| (v as f64).powi(2)).sum();
        assert!((sg.norm2_sq() - n2).abs() < 1e-9);
    }

    #[test]
    fn compressed_dense_roundtrip() {
        let g = vec![1.0, -2.0, 0.0, 3.0];
        let c = Compressed::Dense(g.clone());
        assert_eq!(c.to_dense(), g);
        assert_eq!(c.nnz(), 3);
        assert_eq!(c.dim(), 4);
        assert!((c.norm2_sq() - 14.0).abs() < 1e-9);
    }

    #[test]
    fn index_bits_values() {
        assert_eq!(index_bits(2), 1);
        assert_eq!(index_bits(1024), 10);
        assert_eq!(index_bits(1025), 11);
        assert_eq!(index_bits(2048), 11);
    }

    #[test]
    fn dense_compressor_identity() {
        let mut c = DenseCompressor;
        let g = vec![0.5, -0.25, 0.0];
        let mut ra = RandArray::from_seed(1, 64);
        let (out, stats) = c.compress(&g, &mut ra);
        assert_eq!(out.to_dense(), g);
        assert_eq!(stats.expected_nnz, 3.0);
        assert_eq!(stats.ideal_bits, 96);
    }

    #[test]
    fn hybrid_bits_min_with_dense_symbols() {
        // When expected QB mass is huge, cost is capped at 2d + QA + b.
        let d = 1024;
        let bits = hybrid_ideal_bits(0, 1e12, d);
        assert_eq!(bits, 2 * d as u64 + FLOAT_BITS);
    }

    #[test]
    fn factory_builds_every_method() {
        let mut ra = RandArray::from_seed(2, 4096);
        let g: Vec<f32> = (0..128).map(|i| ((i * 37 % 17) as f32 - 8.0) / 8.0).collect();
        for &m in Method::all() {
            let mut c = crate::api::MethodSpec::from_parts(m, 0.2, 0.5, 4).build();
            let (out, stats) = c.compress(&g, &mut ra);
            assert_eq!(out.dim(), g.len(), "{m}");
            assert!(stats.ideal_bits > 0, "{m}");
            assert!(!c.name().is_empty());
        }
    }

    #[test]
    fn default_batch_impl_equals_per_layer_loop() {
        // The trait's default `compress_batch_into` must agree with looping
        // `compress_into` for every method (same draws, same messages) —
        // and GSpar's fused override must agree with the default.
        // (No zero-size layer here: top-k is undefined at d = 0; the
        // GSpar batch tests cover empty layers.)
        let dims = [96usize, 64, 200];
        let layers: Vec<Vec<f32>> = dims
            .iter()
            .enumerate()
            .map(|(i, &d)| {
                (0..d)
                    .map(|j| (((i * 131 + j * 37) % 23) as f32 - 11.0) / 9.0)
                    .collect()
            })
            .collect();
        let refs: Vec<&[f32]> = layers.iter().map(|g| g.as_slice()).collect();
        for &m in Method::all() {
            let spec = crate::api::MethodSpec::from_parts(m, 0.3, 0.5, 4);
            let mut batched = spec.build();
            let mut looped = spec.build();
            let mut rand_b = RandArray::from_seed(777, 1 << 14);
            let mut rand_l = rand_b.clone();
            let mut out_b: Vec<Compressed> = Vec::new();
            let mut stats_b: Vec<CompressStats> = Vec::new();
            batched.compress_batch_into(&refs, &mut rand_b, &mut out_b, &mut stats_b);
            assert_eq!(out_b.len(), layers.len(), "{m}");
            assert_eq!(stats_b.len(), layers.len(), "{m}");
            for (l, g) in refs.iter().enumerate() {
                let mut slot = Compressed::Sparse(SparseGrad::empty(g.len()));
                let stats = looped.compress_into(g, &mut rand_l, &mut slot);
                assert_eq!(stats.expected_nnz, stats_b[l].expected_nnz, "{m} layer {l}");
                assert_eq!(stats.ideal_bits, stats_b[l].ideal_bits, "{m} layer {l}");
                assert_eq!(
                    format!("{slot:?}"),
                    format!("{:?}", out_b[l]),
                    "{m} layer {l}: messages differ"
                );
            }
        }
    }
}
