//! The allocation-free sparsification engine: one reusable scratch arena
//! that fuses probability computation (Algorithm 2/3) → Bernoulli sampling →
//! wire encoding, with sharded parallel compression for large gradients.
//!
//! Motivation (§5.3 of the paper, and the perf-sensitivity observations in
//! Alistarh et al. 2018 / Basu et al. 2019): the communication win of
//! sparsification only survives if compressor overhead stays sublinear in
//! wall-clock. [`CompressEngine`] makes the rust_pallas hot path match that:
//!
//! * **No per-round allocation.** Probabilities, uniforms, the partial-
//!   selection scratch, shard buffers, the output [`SparseGrad`] and the
//!   wire buffer are all reused across rounds; a steady-state
//!   [`CompressEngine::compress_into`] performs zero heap allocations (see
//!   `tests/alloc_free.rs`).
//! * **Selection, not sorting.** The closed-form solver runs through
//!   [`closed_form_probs_with`] — O(d + k log k) exponential-search
//!   quickselect instead of the O(d log d) full sort.
//! * **Data-independent draw consumption.** The engine pre-fills one
//!   uniform *per coordinate* from the worker's [`RandArray`] (the paper's
//!   pre-generated-array trick) before sampling. Coordinate `i` always owns
//!   draw `i`, so splitting the gradient into shards cannot change which
//!   draw any coordinate sees — sharded output is **bitwise identical** to
//!   the sequential path by construction.
//! * **Sharded parallel compression.** Gradients with `d ≥ parallel_min_d`
//!   are split into cache-sized chunks compressed concurrently on a
//!   **persistent [`ShardPool`]** (threads are spawned once, on the first
//!   parallel call, and reused for the lifetime of the engine — no
//!   per-round spawn/join cost), each chunk appending into its own
//!   persistent shard buffer; shard outputs concatenate in chunk order,
//!   which equals the sequential coordinate order, so which thread ran a
//!   chunk cannot change any output byte.
//! * **Pooled solver passes.** The greedy solver's ‖g‖₁ / init / rescale /
//!   statistics passes run over a fixed chunk grid on the same pool: each
//!   chunk writes partial f64 sums that are reduced sequentially in chunk
//!   order, so the pooled probabilities are bitwise identical to the
//!   single-threaded ones (and independent of the sampling shard
//!   geometry). The closed-form solver's `(Σ|g|, Σg²)` moment pass runs
//!   over the same grid, and when its plan has an empty exact head
//!   (`k = 0`) the probability write `p_i = min(λ|g_i|, 1)` **fuses with
//!   Bernoulli sampling** into one sweep over the sampling chunk grid —
//!   one pass over the gradient instead of two, with the `ProbVector`
//!   scalars reduced per chunk in chunk order so the pooled and sequential
//!   fused paths stay bitwise identical.

use super::pool::ShardPool;
use super::probs::{
    abs_moment_sums, closed_form_finish, closed_form_plan, greedy_stats_pass, init_scale_pass,
    l1_norm_pass, rescale_pass, ClosedFormPlan, ProbVector, SelectScratch,
};
use super::{hybrid_ideal_bits, CompressStats, SparseGrad};
use crate::coding::{self, Encoding, WireCodec};
use crate::rngkit::RandArray;

/// Default chunk size: 16 Ki coordinates ≈ 192 KiB of working set
/// (gradient + probabilities + uniforms), sized to stay cache-resident.
pub const DEFAULT_SHARD_LEN: usize = 1 << 14;

/// Default dimension at which sharded parallel compression kicks in.
pub const DEFAULT_PARALLEL_MIN_D: usize = 1 << 16;

/// Fixed chunk length of the greedy solver's init/rescale/stats passes.
/// Deliberately independent of the sampling `shard_len`: probability
/// values must never depend on the sharding geometry, so the chunk grid —
/// and therefore the chunk-ordered f64 reductions — is a constant of the
/// engine. 16 Ki coordinates keeps a chunk's (g, p) working set
/// cache-resident.
const PROBS_CHUNK_LEN: usize = 1 << 14;

/// One chunk's partial sums from a greedy solver pass (two f64 lanes + a
/// counter cover every pass shape).
#[derive(Clone, Copy, Debug, Default)]
struct PassPartial {
    a: f64,
    b: f64,
    n: u64,
}

/// Run one per-chunk greedy pass over `p` (chunked at `chunk_len`) and the
/// matching `partials` slots, either sequentially in chunk order or as
/// grouped jobs on the pool. Chunk `c`'s output goes to `partials[c]`
/// regardless of which thread ran it, and the caller reduces the partials
/// in chunk order — so the pooled result is bitwise identical to the
/// sequential one by construction.
fn run_prob_pass<F>(
    pool: Option<&ShardPool>,
    threads: usize,
    chunk_len: usize,
    p: &mut [f32],
    partials: &mut [PassPartial],
    f: &F,
) where
    F: Fn(usize, &mut [f32], &mut PassPartial) + Sync,
{
    let nchunks = partials.len();
    let pool = match pool {
        Some(pool) if threads > 1 && nchunks > 1 => pool,
        _ => {
            for (c, (pc, part)) in p.chunks_mut(chunk_len).zip(partials.iter_mut()).enumerate() {
                f(c, pc, part);
            }
            return;
        }
    };
    let per = nchunks.div_ceil(threads.min(nchunks));
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(nchunks.div_ceil(per));
    let mut first = 0usize;
    for (pg, partg) in p.chunks_mut(chunk_len * per).zip(partials.chunks_mut(per)) {
        let base = first;
        first += partg.len();
        jobs.push(Box::new(move || {
            for (j, (pc, part)) in pg.chunks_mut(chunk_len).zip(partg.iter_mut()).enumerate() {
                f(base + j, pc, part);
            }
        }));
    }
    let mut dispatch = crate::trace::span(crate::trace::Stage::ShardDispatch);
    dispatch.bytes(nchunks as u64);
    pool.run(jobs);
}

/// Which probability solver the engine runs.
#[derive(Clone, Copy, Debug)]
pub enum EngineMode {
    /// Algorithm 3 (greedy fixed point) at target density `rho`.
    Greedy { rho: f32, iters: usize },
    /// Algorithm 2 (closed form) at variance budget `eps`, via the
    /// selection-based solver.
    ClosedForm { eps: f32 },
}

/// Per-shard output buffers, persistent across rounds.
#[derive(Debug, Default, Clone)]
struct ShardBuf {
    exact: Vec<(u32, f32)>,
    shared: Vec<(u32, bool)>,
}

/// Reusable, allocation-free sparsification engine. One per worker (it
/// carries per-worker scratch); `Send` so coordinator threads can own one.
#[derive(Debug)]
pub struct CompressEngine {
    mode: EngineMode,
    shard_len: usize,
    parallel_min_d: usize,
    max_threads: usize,
    /// Probability vector scratch (`p_i = min(λ|g_i|, 1)`).
    p: Vec<f32>,
    /// One pre-filled uniform per coordinate (draw `i` belongs to coord `i`).
    uniforms: Vec<f32>,
    /// Partial-selection scratch for the closed-form solver.
    select: SelectScratch,
    /// Per-chunk partial sums of the greedy solver's pooled passes.
    prob_partials: Vec<PassPartial>,
    /// Per-chunk output buffers for the parallel path.
    shards: Vec<ShardBuf>,
    /// Persistent worker threads for the parallel path, created lazily on
    /// the first compress that crosses `parallel_min_d`.
    pool: Option<ShardPool>,
}

impl CompressEngine {
    /// Engine running Algorithm 3 (the paper's experimental setting).
    pub fn greedy(rho: f32, iters: usize) -> Self {
        Self::new(EngineMode::Greedy { rho, iters })
    }

    /// Engine running Algorithm 2 via the selection-based solver.
    pub fn closed_form(eps: f32) -> Self {
        Self::new(EngineMode::ClosedForm { eps })
    }

    pub fn new(mode: EngineMode) -> Self {
        Self {
            mode,
            shard_len: DEFAULT_SHARD_LEN,
            parallel_min_d: DEFAULT_PARALLEL_MIN_D,
            max_threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            p: Vec::new(),
            uniforms: Vec::new(),
            select: SelectScratch::default(),
            prob_partials: Vec::new(),
            shards: Vec::new(),
            pool: None,
        }
    }

    /// Override the sharding geometry (tests force both paths through this;
    /// `max_threads = 1` or `parallel_min_d = usize::MAX` pins the engine to
    /// the sequential path).
    pub fn with_sharding(
        mut self,
        shard_len: usize,
        parallel_min_d: usize,
        max_threads: usize,
    ) -> Self {
        self.shard_len = shard_len.max(1);
        self.parallel_min_d = parallel_min_d;
        self.max_threads = max_threads.max(1);
        // A resized pool would mispartition; rebuild lazily at the new size.
        self.pool = None;
        self
    }

    pub fn mode(&self) -> EngineMode {
        self.mode
    }

    /// Pre-size the engine's *internal* scratch (probabilities, uniforms,
    /// selection buffers) for dimension `d`. For a fully allocation-free
    /// sequential `compress_into`, the caller-held buffers need their own
    /// worst-case reserve too: `out.exact`/`out.shared` up to `d` entries
    /// and `wire` up to `coding::HEADER_LEN + 9 * d` bytes (see
    /// `tests/alloc_free.rs` for the canonical setup).
    pub fn reserve(&mut self, d: usize) {
        self.p.reserve(d.saturating_sub(self.p.len()));
        self.uniforms.reserve(d.saturating_sub(self.uniforms.len()));
        self.select.reserve(d);
    }

    /// Compute the probability vector only (into internal scratch); used by
    /// the shared-memory async engine, which applies updates coordinate-wise
    /// and never materializes a [`SparseGrad`].
    pub fn probs(&mut self, g: &[f32]) -> ProbVector {
        self.compute_probs(g)
    }

    /// The probability vector from the most recent solve.
    pub fn probabilities(&self) -> &[f32] {
        &self.p
    }

    /// Per-message statistics under the paper's §5.1 hybrid-coding model.
    pub fn stats_for(pv: &ProbVector, d: usize) -> CompressStats {
        CompressStats {
            expected_nnz: pv.expected_nnz,
            ideal_bits: hybrid_ideal_bits(
                pv.num_exact as u64,
                pv.expected_nnz - pv.num_exact as f64,
                d,
            ),
        }
    }

    /// Fused probabilities → sampling into a reused [`SparseGrad`].
    ///
    /// Draw convention: exactly `d + 1` uniforms are consumed from `rand`
    /// per call — one per coordinate, whether or not the coordinate is
    /// sampled (this data-independence is what makes the sharded and
    /// sequential paths bitwise identical for the same [`RandArray`] state),
    /// plus one spacer draw that decorrelates successive cyclic windows.
    pub fn compress_sparse_into(
        &mut self,
        g: &[f32],
        rand: &mut RandArray,
        out: &mut SparseGrad,
    ) -> ProbVector {
        let d = g.len();
        if d == 0 {
            let pv = self.compute_probs(g);
            out.reset(0);
            out.shared_mag = pv.inv_lambda;
            return pv;
        }
        if self.uniforms.len() < d {
            self.uniforms.resize(d, 0.0);
        }
        rand.fill(&mut self.uniforms[..d]);
        // Spacer draw: with exactly-d consumption per step, the cyclic array
        // (whose length is typically a power of two or a multiple of d)
        // would revisit identical uniform windows every few steps; one extra
        // draw makes the stride d + 1, which is coprime with power-of-two
        // lengths and walks the whole buffer — the same decorrelation
        // rationale as `RandArray::reseed_offset`.
        let _ = rand.next();
        out.reset(d);

        // Closed-form mode plans before writing any probability: when the
        // exact head is empty (k = 0, the heavy-sparsification norm) the
        // probability write collapses to the pointwise formula and fuses
        // with sampling into a single sweep; otherwise the solver finishes
        // normally and the shared sampling pass below runs as before.
        let solve_span = crate::trace::span(crate::trace::Stage::Solve);
        let pv = match self.mode {
            EngineMode::ClosedForm { eps } => match self.closed_form_plan_chunked(g, eps) {
                None => ProbVector {
                    inv_lambda: 0.0,
                    num_exact: 0,
                    expected_nnz: 0.0,
                    variance: 0.0,
                },
                Some(plan) if plan.k == 0 => {
                    drop(solve_span);
                    let _sample_span = crate::trace::span(crate::trace::Stage::Sample);
                    let pv = self.sample_fused_closed_form(g, &plan, out);
                    out.shared_mag = pv.inv_lambda;
                    return pv;
                }
                Some(plan) => closed_form_finish(g, &plan, &mut self.p, &self.select),
            },
            EngineMode::Greedy { rho, iters } => self.greedy_probs_chunked(g, rho, iters),
        };
        drop(solve_span);
        out.shared_mag = pv.inv_lambda;
        let mut sample_span = crate::trace::span(crate::trace::Stage::Sample);
        sample_span.layer(d as u32);

        let shard_len = self.shard_len;
        let nchunks = d.div_ceil(shard_len);
        let p = &self.p[..d];
        let u = &self.uniforms[..d];
        let threads = self.max_threads.min(nchunks);
        if d < self.parallel_min_d || threads <= 1 {
            // Sequential path: same per-chunk kernel, run in chunk order.
            for c in 0..nchunks {
                let lo = c * shard_len;
                let hi = (lo + shard_len).min(d);
                sample_chunk(
                    &g[lo..hi],
                    &p[lo..hi],
                    &u[lo..hi],
                    lo as u32,
                    &mut out.exact,
                    &mut out.shared,
                );
            }
        } else {
            // Parallel path: each chunk appends into its own persistent
            // buffer; concatenation in chunk order reproduces the
            // sequential output exactly. The chunk → buffer assignment is
            // fixed by index, so the pool's scheduling freedom (which
            // thread runs which group) cannot affect the output.
            if self.shards.len() < nchunks {
                self.shards.resize_with(nchunks, ShardBuf::default);
            }
            let want_threads = self.max_threads;
            let pool = self
                .pool
                .get_or_insert_with(|| ShardPool::new(want_threads));
            let shards = &mut self.shards[..nchunks];
            let per = nchunks.div_ceil(threads);
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                Vec::with_capacity(nchunks.div_ceil(per));
            for (t, group) in shards.chunks_mut(per).enumerate() {
                let first = t * per;
                jobs.push(Box::new(move || {
                    for (j, sh) in group.iter_mut().enumerate() {
                        let lo = (first + j) * shard_len;
                        let hi = (lo + shard_len).min(d);
                        sh.exact.clear();
                        sh.shared.clear();
                        sample_chunk(
                            &g[lo..hi],
                            &p[lo..hi],
                            &u[lo..hi],
                            lo as u32,
                            &mut sh.exact,
                            &mut sh.shared,
                        );
                    }
                }));
            }
            {
                let mut dispatch = crate::trace::span(crate::trace::Stage::ShardDispatch);
                dispatch.bytes(nchunks as u64);
                pool.run(jobs);
            }
            for sh in shards.iter() {
                out.exact.extend_from_slice(&sh.exact);
                out.shared.extend_from_slice(&sh.shared);
            }
        }
        pv
    }

    /// The full fused pass: probabilities → sampling → wire encoding, all
    /// into caller-held reusable buffers. Returns the probability scalars
    /// and the wire encoding chosen. Encodes under [`WireCodec::Raw`]; use
    /// [`Self::compress_into_with`] to fuse the entropy (Rice) encoder into
    /// the same pass.
    pub fn compress_into(
        &mut self,
        g: &[f32],
        rand: &mut RandArray,
        out: &mut SparseGrad,
        wire: &mut Vec<u8>,
    ) -> (ProbVector, Encoding) {
        self.compress_into_with(g, WireCodec::Raw, rand, out, wire)
    }

    /// [`Self::compress_into`] under an explicit [`WireCodec`]: the fused
    /// probabilities → sampling → wire pass may emit the entropy-coded
    /// encodings directly, without materializing any intermediate message
    /// representation between the sampler and the encoder.
    pub fn compress_into_with(
        &mut self,
        g: &[f32],
        codec: WireCodec,
        rand: &mut RandArray,
        out: &mut SparseGrad,
        wire: &mut Vec<u8>,
    ) -> (ProbVector, Encoding) {
        let pv = self.compress_sparse_into(g, rand, out);
        let enc = coding::encode_with(out, codec, wire);
        (pv, enc)
    }

    /// The sharding geometry `(shard_len, parallel_min_d, max_threads)` —
    /// shared with [`super::batch::BatchCompressEngine`] so the batched
    /// path chunks exactly like the single-tensor path.
    pub(crate) fn geometry(&self) -> (usize, usize, usize) {
        (self.shard_len, self.parallel_min_d, self.max_threads)
    }

    fn compute_probs(&mut self, g: &[f32]) -> ProbVector {
        match self.mode {
            EngineMode::Greedy { rho, iters } => self.greedy_probs_chunked(g, rho, iters),
            EngineMode::ClosedForm { eps } => match self.closed_form_plan_chunked(g, eps) {
                None => ProbVector {
                    inv_lambda: 0.0,
                    num_exact: 0,
                    expected_nnz: 0.0,
                    variance: 0.0,
                },
                // k = 0: same pointwise write (and the same chunk-ordered
                // scalar accumulation) as the fused sampling pass, so
                // `probs()` and the compress path agree bitwise — which is
                // what lets the batched engine solve here and sample later.
                Some(plan) if plan.k == 0 => self.closed_form_write_pass(g, &plan),
                Some(plan) => closed_form_finish(g, &plan, &mut self.p, &self.select),
            },
        }
    }

    /// Chunked `(Σ|g|, Σg²)` moment pass + the closed-form eq. (6) search.
    /// Returns `None` on an empty or all-zero gradient (probabilities are
    /// left zeroed). The moment pass runs over the fixed
    /// [`PROBS_CHUNK_LEN`] grid — on the shard pool for large gradients —
    /// with partials reduced in chunk order, so the pooled sums (and hence
    /// the whole plan) are bitwise identical to the sequential path.
    fn closed_form_plan_chunked(&mut self, g: &[f32], eps: f32) -> Option<ClosedFormPlan> {
        let d = g.len();
        assert!(eps >= 0.0, "variance budget must be non-negative");
        self.p.clear();
        self.p.resize(d, 0.0);
        if d == 0 {
            return None;
        }
        let chunk = PROBS_CHUNK_LEN;
        let nchunks = d.div_ceil(chunk);
        let threads = self.max_threads.min(nchunks);
        let pooled = d >= self.parallel_min_d && threads > 1;
        if pooled && self.pool.is_none() {
            self.pool = Some(ShardPool::new(self.max_threads));
        }
        if self.prob_partials.len() < nchunks {
            self.prob_partials.resize(nchunks, PassPartial::default());
        }
        let pool = if pooled { self.pool.as_ref() } else { None };
        let p = &mut self.p[..d];
        let partials = &mut self.prob_partials[..nchunks];
        run_prob_pass(pool, threads, chunk, p, partials, &|c, _pc, part| {
            let lo = c * chunk;
            let hi = (lo + chunk).min(d);
            let (l1, l2) = abs_moment_sums(&g[lo..hi]);
            part.a = l1;
            part.b = l2;
        });
        let mut total_l1 = 0.0f64;
        let mut total_l2 = 0.0f64;
        for part in partials.iter() {
            total_l1 += part.a;
            total_l2 += part.b;
        }
        if total_l2 == 0.0 {
            return None;
        }
        Some(closed_form_plan(g, eps, &mut self.select, total_l1, total_l2))
    }

    /// The `k = 0` probability write without sampling (the `probs()` path):
    /// the same pointwise kernel and per-chunk scalar accumulation as the
    /// fused sampling pass, over the same sampling chunk grid, reduced in
    /// chunk order — so solve-then-sample-later callers (the batched
    /// engine) see bitwise the probabilities and scalars the fused
    /// solve-and-sample path produces.
    fn closed_form_write_pass(&mut self, g: &[f32], plan: &ClosedFormPlan) -> ProbVector {
        let d = g.len();
        debug_assert!(plan.lambda > 0.0, "k = 0 with a non-zero gradient implies λ > 0");
        let shard_len = self.shard_len;
        let nchunks = d.div_ceil(shard_len);
        let threads = self.max_threads.min(nchunks);
        let pooled = d >= self.parallel_min_d && threads > 1;
        if pooled && self.pool.is_none() {
            self.pool = Some(ShardPool::new(self.max_threads));
        }
        if self.prob_partials.len() < nchunks {
            self.prob_partials.resize(nchunks, PassPartial::default());
        }
        let pool = if pooled { self.pool.as_ref() } else { None };
        let lambda = plan.lambda;
        let p = &mut self.p[..d];
        let partials = &mut self.prob_partials[..nchunks];
        run_prob_pass(pool, threads, shard_len, p, partials, &|c, pc, part| {
            let lo = c * shard_len;
            let hi = (lo + shard_len).min(d);
            closed_form_write_chunk(&g[lo..hi], lambda, pc, part);
        });
        reduce_closed_form_partials(partials, plan.inv_lambda)
    }

    /// The fused `k = 0` closed-form pass: write `p_i = min(λ|g_i|, 1)` and
    /// Bernoulli-sample the coordinate against its pre-assigned uniform in
    /// the same sweep over the sampling chunk grid, sequentially or on the
    /// pool. Chunk outputs land in index-assigned buffers and the
    /// `ProbVector` partials reduce in chunk order, so the pooled result is
    /// bitwise identical to the sequential one.
    fn sample_fused_closed_form(
        &mut self,
        g: &[f32],
        plan: &ClosedFormPlan,
        out: &mut SparseGrad,
    ) -> ProbVector {
        let d = g.len();
        debug_assert!(plan.lambda > 0.0, "k = 0 with a non-zero gradient implies λ > 0");
        let lambda = plan.lambda;
        let shard_len = self.shard_len;
        let nchunks = d.div_ceil(shard_len);
        let threads = self.max_threads.min(nchunks);
        if self.prob_partials.len() < nchunks {
            self.prob_partials.resize(nchunks, PassPartial::default());
        }
        let u = &self.uniforms[..d];
        let p = &mut self.p[..d];
        let partials = &mut self.prob_partials[..nchunks];
        if d < self.parallel_min_d || threads <= 1 {
            for c in 0..nchunks {
                let lo = c * shard_len;
                let hi = (lo + shard_len).min(d);
                fused_closed_form_chunk(
                    &g[lo..hi],
                    &u[lo..hi],
                    lambda,
                    lo as u32,
                    &mut p[lo..hi],
                    &mut out.exact,
                    &mut out.shared,
                    &mut partials[c],
                );
            }
        } else {
            if self.shards.len() < nchunks {
                self.shards.resize_with(nchunks, ShardBuf::default);
            }
            let want_threads = self.max_threads;
            let pool = self
                .pool
                .get_or_insert_with(|| ShardPool::new(want_threads));
            let shards = &mut self.shards[..nchunks];
            let per = nchunks.div_ceil(threads);
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                Vec::with_capacity(nchunks.div_ceil(per));
            for (((t, group), pg), partg) in shards
                .chunks_mut(per)
                .enumerate()
                .zip(p.chunks_mut(shard_len * per))
                .zip(partials.chunks_mut(per))
            {
                let first = t * per;
                jobs.push(Box::new(move || {
                    for (j, ((sh, part), pc)) in group
                        .iter_mut()
                        .zip(partg.iter_mut())
                        .zip(pg.chunks_mut(shard_len))
                        .enumerate()
                    {
                        let lo = (first + j) * shard_len;
                        let hi = (lo + shard_len).min(d);
                        sh.exact.clear();
                        sh.shared.clear();
                        fused_closed_form_chunk(
                            &g[lo..hi],
                            &u[lo..hi],
                            lambda,
                            lo as u32,
                            pc,
                            &mut sh.exact,
                            &mut sh.shared,
                            part,
                        );
                    }
                }));
            }
            {
                let mut dispatch = crate::trace::span(crate::trace::Stage::ShardDispatch);
                dispatch.bytes(nchunks as u64);
                pool.run(jobs);
            }
            for sh in shards.iter() {
                out.exact.extend_from_slice(&sh.exact);
                out.shared.extend_from_slice(&sh.shared);
            }
        }
        reduce_closed_form_partials(partials, plan.inv_lambda)
    }

    /// Algorithm 3 over the engine's fixed chunk grid, with every pass
    /// (‖g‖₁, the init scale, each fixed-point rescale, and the final
    /// statistics) runnable on the persistent [`ShardPool`]: chunks write
    /// per-chunk partial sums that are reduced sequentially **in chunk
    /// order**, so the pooled and sequential paths produce bitwise
    /// identical probabilities and scalars (asserted by the engine's
    /// determinism tests). Mathematically identical to
    /// [`super::probs::greedy_probs`]; the f64 reductions merely associate
    /// per chunk instead of over the whole array.
    fn greedy_probs_chunked(&mut self, g: &[f32], rho: f32, iters: usize) -> ProbVector {
        let d = g.len();
        assert!(rho > 0.0 && rho <= 1.0, "rho must be in (0, 1]");
        self.p.clear();
        self.p.resize(d, 0.0);
        if d == 0 {
            return ProbVector {
                inv_lambda: 0.0,
                num_exact: 0,
                expected_nnz: 0.0,
                variance: 0.0,
            };
        }
        let chunk = PROBS_CHUNK_LEN;
        let nchunks = d.div_ceil(chunk);
        let threads = self.max_threads.min(nchunks);
        let pooled = d >= self.parallel_min_d && threads > 1;
        if pooled && self.pool.is_none() {
            self.pool = Some(ShardPool::new(self.max_threads));
        }
        if self.prob_partials.len() < nchunks {
            self.prob_partials.resize(nchunks, PassPartial::default());
        }
        let pool = if pooled { self.pool.as_ref() } else { None };
        let p = &mut self.p[..d];
        let partials = &mut self.prob_partials[..nchunks];

        // Pass 1: ‖g‖₁ (per-chunk partials, reduced in chunk order).
        run_prob_pass(pool, threads, chunk, p, partials, &|c, _pc, part| {
            let lo = c * chunk;
            let hi = (lo + chunk).min(d);
            part.a = l1_norm_pass(&g[lo..hi]);
        });
        let mut l1 = 0.0f64;
        for part in partials.iter() {
            l1 += part.a;
        }
        if l1 == 0.0 {
            return ProbVector {
                inv_lambda: 0.0,
                num_exact: 0,
                expected_nnz: 0.0,
                variance: 0.0,
            };
        }

        let target = rho as f64 * d as f64;
        let mut gamma = target / l1;
        // Pass 2: init p = min(γ|g|, 1) fused with the first iteration's
        // (Σ_{p<1} p, #capped) statistics.
        let gf = gamma as f32;
        run_prob_pass(pool, threads, chunk, p, partials, &|c, pc, part| {
            let lo = c * chunk;
            let hi = (lo + chunk).min(d);
            let (sum, capped) = init_scale_pass(&g[lo..hi], gf, pc);
            part.a = sum;
            part.n = capped as u64;
        });
        let mut active_sum = 0.0f64;
        let mut capped = 0u64;
        for part in partials.iter() {
            active_sum += part.a;
            capped += part.n;
        }

        for _ in 0..iters {
            let want = target - capped as f64;
            if want <= 0.0 || active_sum <= 0.0 {
                break;
            }
            let scale = want / active_sum;
            if scale <= 1.0 {
                break;
            }
            gamma *= scale;
            let cf = scale as f32;
            // Rescale pass fused with the next iteration's statistics.
            run_prob_pass(pool, threads, chunk, p, partials, &|_c, pc, part| {
                let (sum, next_capped) = rescale_pass(pc, cf);
                part.a = sum;
                part.n = next_capped as u64;
            });
            active_sum = 0.0;
            capped = 0;
            for part in partials.iter() {
                active_sum += part.a;
                capped += part.n;
            }
        }

        // Final pass: the Prop-1 statistics.
        let inv_gamma = 1.0 / gamma;
        run_prob_pass(pool, threads, chunk, p, partials, &|c, pc, part| {
            let lo = c * chunk;
            let hi = (lo + chunk).min(d);
            let (nnz, var, exact) = greedy_stats_pass(pc, &g[lo..hi], inv_gamma);
            part.a = nnz;
            part.b = var;
            part.n = exact;
        });
        let mut expected_nnz = 0.0f64;
        let mut variance = 0.0f64;
        let mut num_exact = 0u64;
        for part in partials.iter() {
            expected_nnz += part.a;
            variance += part.b;
            num_exact += part.n;
        }

        ProbVector {
            inv_lambda: inv_gamma as f32,
            num_exact: num_exact as usize,
            expected_nnz,
            variance,
        }
    }
}

/// Reduce per-chunk closed-form partials (chunk order) into the final
/// `ProbVector`. `k = 0`, so the exact head contributes nothing up front.
fn reduce_closed_form_partials(partials: &[PassPartial], inv_lambda: f32) -> ProbVector {
    let mut expected_nnz = 0.0f64;
    let mut variance = 0.0f64;
    let mut num_exact = 0u64;
    for part in partials {
        expected_nnz += part.a;
        variance += part.b;
        num_exact += part.n;
    }
    ProbVector {
        inv_lambda,
        num_exact: num_exact as usize,
        expected_nnz,
        variance,
    }
}

/// Write pass of a `k = 0` closed-form plan over one chunk:
/// `p_i = min(λ|g_i|, 1)` plus the chunk's `ProbVector` partials in
/// coordinate order — the exact accumulation [`fused_closed_form_chunk`]
/// performs, minus the sampling, so the solve-only and solve-and-sample
/// paths produce identical scalars. Zero coordinates keep their zeroed
/// probability and contribute nothing.
#[inline]
fn closed_form_write_chunk(g: &[f32], lambda: f64, p: &mut [f32], part: &mut PassPartial) {
    let mut nnz = 0.0f64;
    let mut var = 0.0f64;
    let mut nexact = 0u64;
    for i in 0..g.len() {
        let m = g[i].abs() as f64;
        if m == 0.0 {
            continue;
        }
        let pf = (lambda * m).min(1.0);
        let pi = pf as f32;
        p[i] = pi;
        nnz += pf;
        var += m * m / pf;
        nexact += (pi >= 1.0) as u64;
    }
    part.a = nnz;
    part.b = var;
    part.n = nexact;
}

/// [`closed_form_write_chunk`] fused with Bernoulli sampling: the
/// probability is written and coordinate `base + i` is sampled against its
/// pre-assigned uniform in the same sweep. Membership is decided exactly
/// like [`sample_chunk`] reading the written probabilities, so fusing
/// cannot change any survivor.
#[inline]
#[allow(clippy::too_many_arguments)]
fn fused_closed_form_chunk(
    g: &[f32],
    u: &[f32],
    lambda: f64,
    base: u32,
    p: &mut [f32],
    exact: &mut Vec<(u32, f32)>,
    shared: &mut Vec<(u32, bool)>,
    part: &mut PassPartial,
) {
    let mut nnz = 0.0f64;
    let mut var = 0.0f64;
    let mut nexact = 0u64;
    for i in 0..g.len() {
        let m = g[i].abs() as f64;
        if m == 0.0 {
            continue;
        }
        let pf = (lambda * m).min(1.0);
        let pi = pf as f32;
        p[i] = pi;
        nnz += pf;
        var += m * m / pf;
        if pi >= 1.0 {
            nexact += 1;
            exact.push((base + i as u32, g[i]));
        } else if u[i] < pi {
            shared.push((base + i as u32, g[i] < 0.0));
        }
    }
    part.a = nnz;
    part.b = var;
    part.n = nexact;
}

/// The per-chunk sampling kernel. `base` is the chunk's first coordinate
/// index; `u[i]` is the pre-assigned uniform for coordinate `base + i`.
/// Shared with the batched engine, whose chunks are layer-local.
#[inline]
pub(crate) fn sample_chunk(
    g: &[f32],
    p: &[f32],
    u: &[f32],
    base: u32,
    exact: &mut Vec<(u32, f32)>,
    shared: &mut Vec<(u32, bool)>,
) {
    for i in 0..g.len() {
        let pi = p[i];
        if pi <= 0.0 {
            continue;
        }
        if pi >= 1.0 {
            exact.push((base + i as u32, g[i]));
        } else if u[i] < pi {
            shared.push((base + i as u32, g[i] < 0.0));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gradient(d: usize, seed: u64) -> Vec<f32> {
        crate::benchkit::skewed_gradient(d, seed, 0.1)
    }

    #[test]
    fn sharded_is_bitwise_identical_to_sequential() {
        for (d, seed) in [(70_000usize, 1u64), (65_536, 2), (100_001, 3)] {
            let g = gradient(d, seed);
            for mode in [
                EngineMode::Greedy { rho: 0.05, iters: 2 },
                EngineMode::ClosedForm { eps: 0.5 },
            ] {
                // Sequential: threads pinned to 1.
                let mut seq_engine =
                    CompressEngine::new(mode).with_sharding(1 << 12, usize::MAX, 1);
                let mut seq_rand = RandArray::from_seed(seed ^ 0xDEAD, 1 << 18);
                let mut seq_out = SparseGrad::empty(0);
                let mut seq_wire = Vec::new();
                let (seq_pv, _) =
                    seq_engine.compress_into(&g, &mut seq_rand, &mut seq_out, &mut seq_wire);

                // Sharded: forced parallel, small chunks, several threads.
                let mut par_engine = CompressEngine::new(mode).with_sharding(1 << 12, 1, 4);
                let mut par_rand = RandArray::from_seed(seed ^ 0xDEAD, 1 << 18);
                let mut par_out = SparseGrad::empty(0);
                let mut par_wire = Vec::new();
                let (par_pv, _) =
                    par_engine.compress_into(&g, &mut par_rand, &mut par_out, &mut par_wire);

                assert_eq!(seq_out, par_out, "d={d} mode={mode:?}");
                assert_eq!(seq_wire, par_wire, "d={d} mode={mode:?}: wire bytes differ");
                assert_eq!(seq_pv.num_exact, par_pv.num_exact);
                assert!(seq_out.nnz() > 0, "degenerate test input");
            }
        }
    }

    #[test]
    fn pooled_greedy_passes_match_sequential_bitwise() {
        // The solver satellite: init/rescale/stats passes dispatched on the
        // shard pool must reproduce the single-threaded chunk loop exactly
        // — probabilities, scalars, and all (chunk-ordered reduction).
        for (d, seed) in [(70_000usize, 61u64), (1 << 17, 62), (49_999, 63)] {
            let g = gradient(d, seed);
            let mut seq = CompressEngine::greedy(0.03, 2).with_sharding(1 << 12, usize::MAX, 1);
            let pv_seq = seq.probs(&g);
            let mut par = CompressEngine::greedy(0.03, 2).with_sharding(1 << 12, 1, 4);
            let pv_par = par.probs(&g);
            assert_eq!(seq.probabilities(), par.probabilities(), "d={d}");
            assert_eq!(pv_seq.inv_lambda, pv_par.inv_lambda, "d={d}");
            assert_eq!(pv_seq.num_exact, pv_par.num_exact, "d={d}");
            assert_eq!(pv_seq.expected_nnz, pv_par.expected_nnz, "d={d}");
            assert_eq!(pv_seq.variance, pv_par.variance, "d={d}");
        }
    }

    #[test]
    fn pooled_closed_form_matches_sequential_bitwise() {
        // The carried-over solver satellite: the chunked moment pass and
        // the fused k = 0 write+sample pass dispatched on the shard pool
        // must reproduce the single-threaded chunk loops exactly — output,
        // wire bytes, probabilities, and every ProbVector scalar.
        for (d, seed, eps) in [
            (70_000usize, 71u64, 0.5f32),
            (1 << 17, 72, 2.0),
            (49_999, 73, 0.05),
        ] {
            let g = gradient(d, seed);
            let mut seq = CompressEngine::closed_form(eps).with_sharding(1 << 12, usize::MAX, 1);
            let mut par = CompressEngine::closed_form(eps).with_sharding(1 << 12, 1, 4);
            let mut seq_rand = RandArray::from_seed(seed ^ 0xF00D, 1 << 18);
            let mut par_rand = RandArray::from_seed(seed ^ 0xF00D, 1 << 18);
            let (mut seq_out, mut par_out) = (SparseGrad::empty(0), SparseGrad::empty(0));
            let (mut seq_wire, mut par_wire) = (Vec::new(), Vec::new());
            let (pv_s, _) = seq.compress_into(&g, &mut seq_rand, &mut seq_out, &mut seq_wire);
            let (pv_p, _) = par.compress_into(&g, &mut par_rand, &mut par_out, &mut par_wire);
            assert_eq!(seq_out, par_out, "d={d} eps={eps}");
            assert_eq!(seq_wire, par_wire, "d={d} eps={eps}");
            assert_eq!(seq.probabilities(), par.probabilities(), "d={d} eps={eps}");
            assert_eq!(pv_s.inv_lambda, pv_p.inv_lambda, "d={d} eps={eps}");
            assert_eq!(pv_s.num_exact, pv_p.num_exact, "d={d} eps={eps}");
            assert_eq!(pv_s.expected_nnz, pv_p.expected_nnz, "d={d} eps={eps}");
            assert_eq!(pv_s.variance, pv_p.variance, "d={d} eps={eps}");
            assert!(seq_out.nnz() > 0, "degenerate test input");
        }
    }

    #[test]
    fn fused_closed_form_sampling_obeys_membership_law() {
        // Whatever path the closed-form mode takes (fused k = 0 sweep or
        // solve-then-sample), the output must satisfy the membership law
        // against the replayed uniforms and the engine's probabilities,
        // and the solve-only `probs()` path must agree with the compress
        // path bitwise.
        let d = 40_000;
        let g = gradient(d, 77);
        for eps in [0.05f32, 3.0] {
            let mut engine = CompressEngine::closed_form(eps).with_sharding(1 << 12, 1, 4);
            let mut rand = RandArray::from_seed(78, 1 << 18);
            let mut replay = rand.clone();
            let mut uniforms = vec![0.0f32; d];
            let mut out = SparseGrad::empty(0);
            let pv = engine.compress_sparse_into(&g, &mut rand, &mut out);
            replay.fill(&mut uniforms);
            let p = engine.probabilities().to_vec();
            let mut want_exact = Vec::new();
            let mut want_shared = Vec::new();
            for i in 0..d {
                let pi = p[i];
                if pi <= 0.0 {
                    continue;
                }
                if pi >= 1.0 {
                    want_exact.push((i as u32, g[i]));
                } else if uniforms[i] < pi {
                    want_shared.push((i as u32, g[i] < 0.0));
                }
            }
            assert_eq!(out.exact, want_exact, "eps={eps}");
            assert_eq!(out.shared, want_shared, "eps={eps}");
            assert_eq!(out.shared_mag, pv.inv_lambda, "eps={eps}");
            let mut probe = CompressEngine::closed_form(eps).with_sharding(1 << 12, 1, 4);
            let pv2 = probe.probs(&g);
            assert_eq!(probe.probabilities(), &p[..], "eps={eps}");
            assert_eq!(pv2.expected_nnz, pv.expected_nnz, "eps={eps}");
            assert_eq!(pv2.variance, pv.variance, "eps={eps}");
            assert_eq!(pv2.num_exact, pv.num_exact, "eps={eps}");
        }
    }

    #[test]
    fn chunked_greedy_agrees_with_free_function_solver() {
        // The chunk grid only changes f64 association, not the math: the
        // engine's solver must agree with `greedy_probs` to far better
        // than f32 resolution on the probabilities and tightly on the
        // scalars.
        let d = 50_000;
        let g = gradient(d, 64);
        let mut engine = CompressEngine::greedy(0.05, 2);
        let pv = engine.probs(&g);
        let mut p_ref = Vec::new();
        let pv_ref = crate::sparsify::greedy_probs(&g, 0.05, 2, &mut p_ref);
        assert_eq!(pv.num_exact, pv_ref.num_exact);
        let rel = |a: f64, b: f64| (a - b).abs() / a.abs().max(b.abs()).max(1e-12);
        assert!(rel(pv.expected_nnz, pv_ref.expected_nnz) < 1e-9);
        assert!(rel(pv.variance, pv_ref.variance) < 1e-9);
        assert!(
            rel(pv.inv_lambda as f64, pv_ref.inv_lambda as f64) < 1e-5,
            "{} vs {}",
            pv.inv_lambda,
            pv_ref.inv_lambda
        );
        for (i, (&a, &b)) in engine.probabilities().iter().zip(&p_ref).enumerate() {
            assert!(
                (a - b).abs() <= 1e-6 * b.abs().max(1e-6),
                "p[{i}]: {a} vs {b}"
            );
        }
    }

    #[test]
    fn parallel_path_creates_one_pool_and_reuses_it() {
        let d = 40_000;
        let g = gradient(d, 5);
        let mut engine = CompressEngine::greedy(0.05, 2).with_sharding(1 << 12, 1, 3);
        assert!(engine.pool.is_none(), "pool is lazy");
        let mut rand = RandArray::from_seed(6, 1 << 18);
        let mut out = SparseGrad::empty(0);
        engine.compress_sparse_into(&g, &mut rand, &mut out);
        let threads = engine.pool.as_ref().expect("pool created").threads();
        assert_eq!(threads, 3);
        for _ in 0..4 {
            engine.compress_sparse_into(&g, &mut rand, &mut out);
        }
        // Still the same pool object (threads were not respawned).
        assert_eq!(engine.pool.as_ref().unwrap().threads(), 3);
        // Regeometrizing drops the stale pool.
        let engine = engine.with_sharding(1 << 12, 1, 2);
        assert!(engine.pool.is_none());
    }

    #[test]
    fn fused_output_matches_probabilities_and_uniforms() {
        // Membership law: exact ⇔ p = 1; shared ⇔ u < p < 1 with the
        // coordinate's own pre-assigned uniform.
        let d = 4096;
        let g = gradient(d, 7);
        let mut engine = CompressEngine::greedy(0.1, 2);
        let mut rand = RandArray::from_seed(11, 1 << 16);
        // Clone the RandArray to replay the exact uniforms the engine reads.
        let mut replay = rand.clone();
        let mut uniforms = vec![0.0f32; d];
        let mut out = SparseGrad::empty(0);
        let pv = engine.compress_sparse_into(&g, &mut rand, &mut out);
        replay.fill(&mut uniforms);
        let p = engine.probabilities();

        let mut want_exact = Vec::new();
        let mut want_shared = Vec::new();
        for i in 0..d {
            let pi = p[i];
            if pi <= 0.0 {
                continue;
            }
            if pi >= 1.0 {
                want_exact.push((i as u32, g[i]));
            } else if uniforms[i] < pi {
                want_shared.push((i as u32, g[i] < 0.0));
            }
        }
        assert_eq!(out.exact, want_exact);
        assert_eq!(out.shared, want_shared);
        assert_eq!(out.shared_mag, pv.inv_lambda);
        assert_eq!(out.d, d as u32);
    }

    #[test]
    fn wire_roundtrips_and_stats_are_consistent() {
        let d = 2048;
        let g = gradient(d, 9);
        let mut engine = CompressEngine::closed_form(0.8);
        let mut rand = RandArray::from_seed(13, 1 << 16);
        let mut out = SparseGrad::empty(0);
        let mut wire = Vec::new();
        let (pv, _enc) = engine.compress_into(&g, &mut rand, &mut out, &mut wire);
        let back = crate::coding::decode(&wire).unwrap();
        assert_eq!(back, out);
        let stats = CompressEngine::stats_for(&pv, d);
        assert!(stats.ideal_bits > 0);
        assert!(stats.expected_nnz > 0.0);
        // Exact survivors are exactly the p = 1 set.
        assert_eq!(
            out.exact.len(),
            engine.probabilities().iter().filter(|&&p| p >= 1.0).count()
        );
    }

    #[test]
    fn engine_unbiasedness_monte_carlo() {
        // E[Q(g)] = g must survive the fused + pre-assigned-uniform path.
        let d = 48;
        let g = gradient(d, 21);
        let mut engine = CompressEngine::greedy(0.3, 2);
        let mut rand = RandArray::from_seed(22, (1 << 22) + 7);
        let trials = 20_000;
        let mut mean = vec![0.0f64; d];
        let mut out = SparseGrad::empty(0);
        for _ in 0..trials {
            engine.compress_sparse_into(&g, &mut rand, &mut out);
            for &(i, v) in &out.exact {
                mean[i as usize] += v as f64;
            }
            for &(i, neg) in &out.shared {
                let v = if neg { -out.shared_mag } else { out.shared_mag };
                mean[i as usize] += v as f64;
            }
        }
        let p = engine.probabilities().to_vec();
        for i in 0..d {
            let m = mean[i] / trials as f64;
            let pi = p[i] as f64;
            if pi == 0.0 {
                assert_eq!(m, 0.0);
                continue;
            }
            let gi = g[i] as f64;
            let var = gi * gi * (1.0 - pi) / pi;
            let tol = 4.0 * (var / trials as f64).sqrt() + 1e-9;
            assert!((m - gi).abs() <= tol, "coord {i}: {m} vs {gi} (tol {tol})");
        }
    }

    #[test]
    fn empty_and_zero_gradients() {
        let mut engine = CompressEngine::greedy(0.5, 2);
        let mut rand = RandArray::from_seed(31, 1 << 10);
        let mut out = SparseGrad::empty(0);
        let mut wire = Vec::new();
        let (pv, _) = engine.compress_into(&[], &mut rand, &mut out, &mut wire);
        assert_eq!(out.nnz(), 0);
        assert_eq!(pv.expected_nnz, 0.0);
        let g = vec![0.0f32; 100];
        let (pv, _) = engine.compress_into(&g, &mut rand, &mut out, &mut wire);
        assert_eq!(out.nnz(), 0);
        assert_eq!(pv.expected_nnz, 0.0);
        assert_eq!(crate::coding::decode(&wire).unwrap(), out);
    }
}
