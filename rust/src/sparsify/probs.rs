//! The two solvers for the optimal probability vector `p_i = min(λ|g_i|, 1)`
//! of Proposition 1: the closed form of Algorithm 2 and the greedy fixed
//! point of Algorithm 3.

/// Result of a probability computation. The probabilities themselves are
/// written into the caller's scratch buffer (no hot-path allocation); this
/// struct carries the scalars the sampler and coder need.
#[derive(Debug, Clone, Copy)]
pub struct ProbVector {
    /// `1/λ` — the decoded magnitude shared by all survivors with `p_i < 1`.
    /// Zero when no such coordinates exist.
    pub inv_lambda: f32,
    /// Number of coordinates with `p_i == 1` (the dominating set `S_k`).
    pub num_exact: usize,
    /// Expected sparsity `Σ_i p_i`.
    pub expected_nnz: f64,
    /// Variance bound `Σ_i g_i²/p_i` of the sparsified vector (f64; only
    /// over `p_i > 0`).
    pub variance: f64,
}

/// **Algorithm 2** (closed form), hot-path entry point: finds the smallest
/// `k` satisfying eq. (6)
///
/// ```text
/// |g_(k+1)| · Σ_{i>k} |g_(i)|  ≤  ε Σ_i g_i² + Σ_{i>k} g_(i)²
/// ```
///
/// then sets `p_(i) = 1` for `i ≤ k` and `p_(i) = λ|g_(i)|` otherwise, with
/// `λ = Σ_{i>k}|g_(i)| / (ε Σ g² + Σ_{i>k} g_(i)²)` — eq. (7).
///
/// Uses the selection-based solver (exponential search over the threshold
/// with quickselect partitioning, O(d + k log k)) with a throwaway scratch;
/// round-based callers should hold a [`SelectScratch`] and call
/// [`closed_form_probs_with`] so no allocation happens per step.
pub fn closed_form_probs(g: &[f32], eps: f32, p_out: &mut Vec<f32>) -> ProbVector {
    let mut scratch = SelectScratch::default();
    closed_form_probs_with(g, eps, p_out, &mut scratch)
}

/// Reference implementation of Algorithm 2 via a full O(d log d) sort.
/// Kept for validation: the selection-based solver must reproduce its
/// `ProbVector` and probabilities (see the equivalence tests); not used on
/// the hot path.
pub fn closed_form_probs_sorted(g: &[f32], eps: f32, p_out: &mut Vec<f32>) -> ProbVector {
    let d = g.len();
    p_out.clear();
    p_out.resize(d, 0.0);
    assert!(eps >= 0.0, "variance budget must be non-negative");

    // Order coordinate indices by |g| descending.
    let mut order: Vec<u32> = (0..d as u32).collect();
    order.sort_unstable_by(|&a, &b| {
        let (ma, mb) = (g[a as usize].abs(), g[b as usize].abs());
        mb.partial_cmp(&ma).unwrap_or(std::cmp::Ordering::Equal)
    });

    // Suffix sums over the sorted order: tail_l1[k] = Σ_{i>k} |g_(i)|,
    // tail_l2[k] = Σ_{i>k} g_(i)² (1-based k, i.e. after removing top-k).
    let mut tail_l1 = vec![0.0f64; d + 1];
    let mut tail_l2 = vec![0.0f64; d + 1];
    for i in (0..d).rev() {
        let m = g[order[i] as usize].abs() as f64;
        tail_l1[i] = tail_l1[i + 1] + m;
        tail_l2[i] = tail_l2[i + 1] + m * m;
    }
    let total_l2 = tail_l2[0];

    if total_l2 == 0.0 {
        // Zero gradient: nothing to keep.
        return ProbVector {
            inv_lambda: 0.0,
            num_exact: 0,
            expected_nnz: 0.0,
            variance: 0.0,
        };
    }

    // Smallest k in [0, d] with |g_(k+1)| · tail_l1[k] ≤ ε·total + tail_l2[k].
    let budget = eps as f64 * total_l2;
    let mut k = d; // fallback: keep everything exactly
    for cand in 0..d {
        let next_mag = g[order[cand] as usize].abs() as f64; // |g_(k+1)| for k = cand
        if next_mag * tail_l1[cand] <= budget + tail_l2[cand] {
            k = cand;
            break;
        }
    }

    let (lambda, inv_lambda) = if k == d || tail_l1[k] == 0.0 {
        (0.0, 0.0)
    } else {
        let lam = tail_l1[k] / (budget + tail_l2[k]);
        (lam, (1.0 / lam) as f32)
    };

    let mut expected_nnz = k as f64;
    let mut variance = tail_l2[0] - tail_l2[k]; // exact coords contribute g².
    let mut num_exact = k;
    for &idx in &order[..k] {
        p_out[idx as usize] = 1.0;
    }
    for &idx in &order[k..] {
        let m = g[idx as usize].abs() as f64;
        if m == 0.0 {
            continue;
        }
        let p = (lambda * m).min(1.0);
        p_out[idx as usize] = p as f32;
        expected_nnz += p;
        variance += m * m / p;
        // Boundary coordinates where λ|g| ≥ 1 are kept with certainty and
        // travel in the QA part — count them as exact for coding stats.
        if p_out[idx as usize] >= 1.0 {
            num_exact += 1;
        }
    }

    ProbVector {
        inv_lambda,
        num_exact,
        expected_nnz,
        variance,
    }
}

/// Reusable scratch for [`closed_form_probs_with`]: the partial ordering of
/// coordinate indices and the prefix sums over its sorted head. Holding one
/// per worker makes the closed-form solver allocation-free across rounds.
#[derive(Debug, Default, Clone)]
pub struct SelectScratch {
    /// Coordinate indices; `order[..sorted]` is the descending-magnitude
    /// head during a solve.
    order: Vec<u32>,
    /// `prefix_l1[k] = Σ_{i<k} |g_(i)|` over the sorted head (f64).
    prefix_l1: Vec<f64>,
    /// `prefix_l2[k] = Σ_{i<k} g_(i)²` over the sorted head (f64).
    prefix_l2: Vec<f64>,
}

impl SelectScratch {
    /// Pre-size for dimension `d` so a subsequent solve performs no heap
    /// allocation (buffers only ever grow).
    pub fn reserve(&mut self, d: usize) {
        self.order.reserve(d.saturating_sub(self.order.len()));
        self.prefix_l1.reserve((d + 1).saturating_sub(self.prefix_l1.len()));
        self.prefix_l2.reserve((d + 1).saturating_sub(self.prefix_l2.len()));
    }
}

/// `(Σ|g_i|, Σ g_i²)` in one pass, 4-lane f64 accumulators (vectorizes).
/// Also the per-chunk kernel of the engine's pooled closed-form path: chunk
/// partials are reduced in chunk order there, so the pooled sums are
/// bitwise identical to the engine's sequential chunk loop.
#[inline]
pub(crate) fn abs_moment_sums(g: &[f32]) -> (f64, f64) {
    let mut s1 = [0.0f64; 4];
    let mut s2 = [0.0f64; 4];
    let chunks = g.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        for lane in 0..4 {
            let m = g[i + lane].abs() as f64;
            s1[lane] += m;
            s2[lane] += m * m;
        }
    }
    let mut l1 = (s1[0] + s1[1]) + (s1[2] + s1[3]);
    let mut l2 = (s2[0] + s2[1]) + (s2[2] + s2[3]);
    for &x in &g[chunks * 4..] {
        let m = x.abs() as f64;
        l1 += m;
        l2 += m * m;
    }
    (l1, l2)
}

/// **Algorithm 2** via partial selection — the hot-path solver.
///
/// The full sort in [`closed_form_probs_sorted`] only ever *reads* the top
/// of the ordering: eq. (6) is monotone in `k`, so the smallest feasible `k`
/// can be found by exponential search. We grow a sorted head of the
/// magnitude ordering in doubling steps — each step is one quickselect
/// partition of the unsorted suffix, O(d), plus a sort of the newly admitted
/// elements — and stop as soon as a feasible `k` appears in the head.
/// Total work is O(d + k log k) instead of O(d log d); for the typical
/// `k ≪ d` regime the solver touches the suffix only through the partition
/// passes and never orders it.
///
/// Results match [`closed_form_probs_sorted`] up to f64 summation order
/// (prefix-minus-total vs. backward suffix sums); the equivalence tests pin
/// this down.
pub fn closed_form_probs_with(
    g: &[f32],
    eps: f32,
    p_out: &mut Vec<f32>,
    scratch: &mut SelectScratch,
) -> ProbVector {
    let (total_l1, total_l2) = abs_moment_sums(g);
    closed_form_probs_with_sums(g, eps, p_out, scratch, total_l1, total_l2)
}

/// [`closed_form_probs_with`] given precomputed moment sums — the entry
/// point of the engine's pooled path, which accumulates `(Σ|g|, Σg²)` over
/// its fixed chunk grid (so the pooled and sequential sums are bitwise
/// identical) before handing them to the solver.
pub(crate) fn closed_form_probs_with_sums(
    g: &[f32],
    eps: f32,
    p_out: &mut Vec<f32>,
    scratch: &mut SelectScratch,
    total_l1: f64,
    total_l2: f64,
) -> ProbVector {
    let d = g.len();
    p_out.clear();
    p_out.resize(d, 0.0);
    if total_l2 == 0.0 {
        assert!(eps >= 0.0, "variance budget must be non-negative");
        // Zero gradient: nothing to keep.
        return ProbVector {
            inv_lambda: 0.0,
            num_exact: 0,
            expected_nnz: 0.0,
            variance: 0.0,
        };
    }
    let plan = closed_form_plan(g, eps, scratch, total_l1, total_l2);
    closed_form_finish(g, &plan, p_out, scratch)
}

/// Outcome of the eq. (6) search: everything after it is a write pass over
/// the probabilities. `k == 0` means the exact head is empty, so that write
/// pass is the single pointwise formula `p_i = min(λ|g_i|, 1)` — the shape
/// the engine fuses with Bernoulli sampling.
#[derive(Debug, Clone, Copy)]
pub(crate) struct ClosedFormPlan {
    /// Size of the dominating set `S_k` (top-`k` magnitudes kept exactly).
    pub k: usize,
    /// `λ` of eq. (7); zero when the tail is empty or all-zero.
    pub lambda: f64,
    /// `1/λ` as `f32` (the decoded shared magnitude).
    pub inv_lambda: f32,
}

/// The eq. (6)/(7) search of [`closed_form_probs_with`], stopping before
/// any probability is written. The partial magnitude ordering and its
/// prefix sums are left in `scratch` for [`closed_form_finish`] (or the
/// engine's fused sample pass). Caller guarantees `total_l2 > 0`.
pub(crate) fn closed_form_plan(
    g: &[f32],
    eps: f32,
    scratch: &mut SelectScratch,
    total_l1: f64,
    total_l2: f64,
) -> ClosedFormPlan {
    let d = g.len();
    assert!(eps >= 0.0, "variance budget must be non-negative");
    let budget = eps as f64 * total_l2;

    let order = &mut scratch.order;
    let prefix_l1 = &mut scratch.prefix_l1;
    let prefix_l2 = &mut scratch.prefix_l2;
    order.clear();
    order.extend(0..d as u32);
    prefix_l1.clear();
    prefix_l1.push(0.0);
    prefix_l2.clear();
    prefix_l2.push(0.0);

    let mag = |i: u32| g[i as usize].abs();
    let desc = |a: &u32, b: &u32| {
        mag(*b)
            .partial_cmp(&mag(*a))
            .unwrap_or(std::cmp::Ordering::Equal)
    };

    let mut sorted = 0usize; // order[..sorted] = top-`sorted`, descending
    let mut checked = 0usize; // candidates k < checked already failed eq. (6)
    let mut k = d; // fallback: keep everything exactly
    // First guess d/64: sorting it costs ≪ one partition pass, and it covers
    // the common k ∝ d regime in a single doubling step.
    let mut target = (d / 64).max(32).min(d);
    loop {
        if target > sorted {
            if target < d {
                // One quickselect partition brings the next largest
                // (target - sorted) magnitudes to the front of the suffix.
                order[sorted..].select_nth_unstable_by(target - sorted - 1, desc);
            }
            order[sorted..target].sort_unstable_by(desc);
            let mut l1 = prefix_l1[sorted];
            let mut l2 = prefix_l2[sorted];
            for &idx in &order[sorted..target] {
                let m = mag(idx) as f64;
                l1 += m;
                l2 += m * m;
                prefix_l1.push(l1);
                prefix_l2.push(l2);
            }
            sorted = target;
        }
        if sorted < d {
            // Partial regime: smallest k in [checked, sorted) satisfying
            // eq. (6), with total-minus-prefix tails. Their accumulated f64
            // error grows like d·ulp·Σ, so allow a slack of that scale so a
            // hairline tie is decided deterministically rather than by
            // subtraction noise. The slack direction accepts the tie (one
            // *smaller* k): at near-equality the boundary coordinate has
            // λ|g_(k+1)| ≈ 1, so it is kept with probability ≈ 1 either way
            // and the variance drift is O(slack). Genuine margins dwarf the
            // slack, and the noise-dominated endgame (tails that are a
            // vanishing fraction of the total) is handled by the exact scan
            // below instead.
            let slack = d as f64 * f64::EPSILON * total_l2;
            let mut found = false;
            for cand in checked..sorted {
                let next_mag = mag(order[cand]) as f64; // |g_(k+1)| for k = cand
                let tail1 = total_l1 - prefix_l1[cand];
                let tail2 = total_l2 - prefix_l2[cand];
                if next_mag * tail1 <= budget + tail2 + slack {
                    k = cand;
                    found = true;
                    break;
                }
            }
            if found {
                break;
            }
            checked = sorted;
            target = (sorted * 2).min(d);
        } else {
            // Full-sort regime: exact backward suffix accumulation, the same
            // smallest-first summation order as the sorted reference, so the
            // ε = 0 boundary (eq. (6) holds with exact equality at k = d−1)
            // is decided identically. Eq. (6) is monotone in k, so the
            // smallest feasible k is the bottom of the trailing run of
            // successes in a descending scan.
            let mut tail1 = 0.0f64;
            let mut tail2 = 0.0f64;
            for cand in (checked..d).rev() {
                let m = mag(order[cand]) as f64;
                tail1 += m;
                tail2 += m * m;
                if m * tail1 <= budget + tail2 {
                    k = cand;
                } else {
                    break;
                }
            }
            break;
        }
    }

    // λ from *exact* tail sums: re-accumulate over the actual tail elements
    // (backward, matching the reference solver) — the subtractive tails used
    // during the search lose all precision when the kept set carries nearly
    // the whole mass.
    let (lambda, inv_lambda) = if k == d {
        (0.0, 0.0)
    } else {
        let mut tail1 = 0.0f64;
        let mut tail2 = 0.0f64;
        for &idx in order[k..].iter().rev() {
            let m = mag(idx) as f64;
            tail1 += m;
            tail2 += m * m;
        }
        if tail1 == 0.0 {
            (0.0, 0.0)
        } else {
            let lam = tail1 / (budget + tail2);
            (lam, (1.0 / lam) as f32)
        }
    };

    ClosedFormPlan {
        k,
        lambda,
        inv_lambda,
    }
}

/// The write pass following [`closed_form_plan`]: `p = 1` on the exact head
/// `S_k`, `p_i = min(λ|g_i|, 1)` on the tail, with the `ProbVector` scalars
/// accumulated along the scratch ordering. `p_out` must already be zeroed
/// to length `d` and `scratch` must hold the state the plan left behind.
pub(crate) fn closed_form_finish(
    g: &[f32],
    plan: &ClosedFormPlan,
    p_out: &mut [f32],
    scratch: &SelectScratch,
) -> ProbVector {
    let (k, lambda) = (plan.k, plan.lambda);
    let order = &scratch.order;
    let prefix_l2 = &scratch.prefix_l2;
    let mag = |i: u32| g[i as usize].abs();

    let mut expected_nnz = k as f64;
    let mut variance = prefix_l2[k.min(prefix_l2.len() - 1)]; // S_k contributes g².
    let mut num_exact = k;
    for &idx in &order[..k] {
        p_out[idx as usize] = 1.0;
    }
    // order[k..sorted] is sorted, order[sorted..] is an arbitrary
    // arrangement of the remaining (strictly smaller) magnitudes — together
    // exactly the complement of S_k, which is all the final pass needs.
    for &idx in &order[k..] {
        let m = mag(idx) as f64;
        if m == 0.0 {
            continue;
        }
        let p = (lambda * m).min(1.0);
        p_out[idx as usize] = p as f32;
        expected_nnz += p;
        variance += m * m / p;
        // Boundary coordinates where λ|g| ≥ 1 are kept with certainty and
        // travel in the QA part — count them as exact for coding stats.
        if p_out[idx as usize] >= 1.0 {
            num_exact += 1;
        }
    }

    ProbVector {
        inv_lambda: plan.inv_lambda,
        num_exact,
        expected_nnz,
        variance,
    }
}

/// **Algorithm 3** (greedy). Targets expected density `ρ = Σ p_i / d`:
///
/// 1. `p⁰_i = min(ρ d |g_i| / ||g||₁, 1)`;
/// 2. repeat: with active set `I = {i : p_i < 1}` (and `p_i > 0`), rescale
///    `c = (ρd − d + |I|)/Σ_{I} p_i`; stop if `c ≤ 1`; else
///    `p_i ← min(c·p_i, 1)`.
///
/// The paper observes `j = 2` iterations suffice in practice. The final `p`
/// still has the Proposition-1 form `p_i = min(γ|g_i|, 1)` because every
/// rescale multiplies all uncapped entries by the same factor; we track `γ`
/// so the sampler can share `1/γ` across all `p_i < 1` survivors.
///
/// Runs in O(d · iters), allocation-free given the scratch buffer, and fully
/// vectorizable (the paper's SIMD observation).
pub fn greedy_probs(g: &[f32], rho: f32, iters: usize, p_out: &mut Vec<f32>) -> ProbVector {
    let d = g.len();
    assert!(rho > 0.0 && rho <= 1.0, "rho must be in (0, 1]");
    p_out.clear();
    p_out.resize(d, 0.0);

    // ||g||₁ in f64 (d can be large and magnitudes tiny).
    let l1 = l1_norm_pass(g);
    if l1 == 0.0 {
        return ProbVector {
            inv_lambda: 0.0,
            num_exact: 0,
            expected_nnz: 0.0,
            variance: 0.0,
        };
    }

    let target = rho as f64 * d as f64;
    // γ accumulates the total scale so that p_i = min(γ|g_i|, 1).
    let mut gamma = target / l1;
    // Init pass in pure f32 (vectorizes; γ error ≪ the f32 probability ulp),
    // fused with the first iteration's (Σ_{p<1} p, #capped) statistics so
    // each fixed-point iteration makes exactly one pass over `p`.
    let gf = gamma as f32;
    let (mut active_sum, mut capped) = init_scale_pass(g, gf, p_out);

    for _ in 0..iters {
        let want = target - capped as f64; // ρd − d + |I| with zeros excluded
        if want <= 0.0 || active_sum <= 0.0 {
            break;
        }
        let c = want / active_sum;
        if c <= 1.0 {
            break;
        }
        gamma *= c;
        let cf = c as f32;
        // Scale pass fused with the next iteration's statistics.
        let (next_sum, next_capped) = rescale_pass(p_out, cf);
        active_sum = next_sum;
        capped = next_capped;
    }

    // Final scalars — division-free (for p < 1, m²/p = m/γ — Prop. 1 form)
    // and branchless (g = 0 ⇒ p = 0 ⇒ both select arms contribute 0), so
    // the loop vectorizes.
    let inv_gamma = 1.0 / gamma;
    let (expected_nnz, variance, num_exact) = greedy_stats_pass(p_out, g, inv_gamma);

    ProbVector {
        inv_lambda: inv_gamma as f32,
        num_exact: num_exact as usize,
        expected_nnz,
        variance,
    }
}

/// `‖g‖₁` in f64 over one slice: 4-lane unrolled accumulation breaks the
/// serial FP dependency chain so the loop vectorizes. Also the per-chunk
/// kernel of the engine's pooled greedy path — chunk partials are reduced
/// in chunk order there, so the parallel result is deterministic.
#[inline]
pub(crate) fn l1_norm_pass(g: &[f32]) -> f64 {
    let mut acc = [0.0f64; 4];
    let chunks = g.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += g[i].abs() as f64;
        acc[1] += g[i + 1].abs() as f64;
        acc[2] += g[i + 2].abs() as f64;
        acc[3] += g[i + 3].abs() as f64;
    }
    let mut l1 = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for &x in &g[chunks * 4..] {
        l1 += x.abs() as f64;
    }
    l1
}

/// The greedy solver's final statistics over one slice:
/// `(Σ p, Σ g²/p, #{p ≥ 1})` in the division-free Prop-1 form. Per-chunk
/// kernel of the pooled path (partials reduced in chunk order).
#[inline]
pub(crate) fn greedy_stats_pass(p: &[f32], g: &[f32], inv_gamma: f64) -> (f64, f64, u64) {
    let mut expected_nnz = 0.0f64;
    let mut variance = 0.0f64;
    let mut num_exact = 0u64;
    for (&pi, &x) in p.iter().zip(g.iter()) {
        let m = x.abs() as f64;
        let is_capped = pi >= 1.0;
        num_exact += is_capped as u64;
        expected_nnz += if is_capped { 1.0 } else { pi as f64 };
        variance += if is_capped { m * m } else { m * inv_gamma };
    }
    (expected_nnz, variance, num_exact)
}

/// `p_i = min(gf·|g_i|, 1)` plus `(Σ_{0<p<1} p, #{p ≥ 1})` in one pass.
/// Branchless (selects) with 4-lane f64 accumulators so LLVM vectorizes.
#[inline]
pub(crate) fn init_scale_pass(g: &[f32], gf: f32, p_out: &mut [f32]) -> (f64, usize) {
    let d = g.len();
    let mut sum = [0.0f64; 4];
    let mut cap = [0u64; 4];
    let chunks = d / 4;
    for c in 0..chunks {
        let i = c * 4;
        for lane in 0..4 {
            let v = (gf * g[i + lane].abs()).min(1.0);
            p_out[i + lane] = v;
            let capped = v >= 1.0;
            cap[lane] += capped as u64;
            sum[lane] += if capped { 0.0 } else { v as f64 };
        }
    }
    let mut active_sum = (sum[0] + sum[1]) + (sum[2] + sum[3]);
    let mut capped = (cap[0] + cap[1] + cap[2] + cap[3]) as usize;
    for i in chunks * 4..d {
        let v = (gf * g[i].abs()).min(1.0);
        p_out[i] = v;
        if v >= 1.0 {
            capped += 1;
        } else {
            active_sum += v as f64;
        }
    }
    (active_sum, capped)
}

/// `p_i ← min(c·p_i, 1)` for uncapped entries, returning the next
/// iteration's `(Σ_{0<p<1} p, #{p ≥ 1})` from the same pass. Branchless:
/// capped entries multiply by 1 (min keeps them at 1.0 exactly).
#[inline]
pub(crate) fn rescale_pass(p_out: &mut [f32], cf: f32) -> (f64, usize) {
    let d = p_out.len();
    let mut sum = [0.0f64; 4];
    let mut cap = [0u64; 4];
    let chunks = d / 4;
    for c in 0..chunks {
        let i = c * 4;
        for lane in 0..4 {
            let v = p_out[i + lane];
            // Capped entries stay exactly 1.0: 1.0*cf >= 1.0 since cf > 1.
            let nv = (v * cf).min(1.0);
            p_out[i + lane] = nv;
            let capped = nv >= 1.0;
            cap[lane] += capped as u64;
            sum[lane] += if capped { 0.0 } else { nv as f64 };
        }
    }
    let mut active_sum = (sum[0] + sum[1]) + (sum[2] + sum[3]);
    let mut capped = (cap[0] + cap[1] + cap[2] + cap[3]) as usize;
    for p in p_out[chunks * 4..].iter_mut() {
        let nv = (*p * cf).min(1.0);
        *p = nv;
        if nv >= 1.0 {
            capped += 1;
        } else {
            active_sum += nv as f64;
        }
    }
    (active_sum, capped)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_grad(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::rngkit::Xoshiro256pp::seed_from_u64(seed);
        (0..d)
            .map(|_| {
                let u = rng.next_f32();
                if u < 0.1 {
                    (rng.next_gaussian() * 5.0) as f32
                } else {
                    (rng.next_gaussian() * 0.05) as f32
                }
            })
            .collect()
    }

    #[test]
    fn closed_form_satisfies_variance_budget() {
        let g = sample_grad(512, 1);
        let total: f64 = g.iter().map(|&x| (x as f64).powi(2)).sum();
        for eps in [0.1f32, 0.5, 1.0, 3.0] {
            let mut p = Vec::new();
            let pv = closed_form_probs(&g, eps, &mut p);
            // Variance constraint: Σ g²/p ≤ (1+ε) Σ g² (+ small slack).
            assert!(
                pv.variance <= (1.0 + eps as f64) * total * (1.0 + 1e-6),
                "eps={eps}: var {} > budget {}",
                pv.variance,
                (1.0 + eps as f64) * total
            );
        }
    }

    #[test]
    fn closed_form_prop1_shape() {
        // p_i = min(λ|g_i|, 1): monotone in |g_i| and exactly 1 on S_k.
        let g = sample_grad(256, 2);
        let mut p = Vec::new();
        let pv = closed_form_probs(&g, 0.5, &mut p);
        let lam = if pv.inv_lambda > 0.0 {
            1.0 / pv.inv_lambda as f64
        } else {
            0.0
        };
        let mut exact = 0;
        for (i, &pi) in p.iter().enumerate() {
            let m = g[i].abs() as f64;
            if pi >= 1.0 {
                exact += 1;
            } else if m > 0.0 && lam > 0.0 {
                assert!(
                    (pi as f64 - (lam * m).min(1.0)).abs() < 1e-5,
                    "p[{i}]={pi} vs λ|g|={}",
                    lam * m
                );
            }
        }
        assert_eq!(exact, pv.num_exact);
    }

    #[test]
    fn closed_form_larger_eps_sparser() {
        let g = sample_grad(512, 3);
        let mut p = Vec::new();
        let lo = closed_form_probs(&g, 0.1, &mut p).expected_nnz;
        let hi = closed_form_probs(&g, 2.0, &mut p).expected_nnz;
        assert!(hi < lo, "eps=2 nnz {hi} !< eps=0.1 nnz {lo}");
    }

    #[test]
    fn closed_form_zero_eps_keeps_everything() {
        // ε = 0 allows no variance increase ⇒ p_i = 1 on all non-zeros.
        let g = vec![1.0, -2.0, 0.0, 0.5];
        let mut p = Vec::new();
        let pv = closed_form_probs(&g, 0.0, &mut p);
        assert_eq!(p, vec![1.0, 1.0, 0.0, 1.0]);
        // All three non-zeros end at p = 1 (k may stop earlier when the
        // boundary coordinate lands exactly at λ|g| = 1 — still exact).
        assert_eq!(pv.num_exact, 3);
        assert!((pv.expected_nnz - 3.0).abs() < 1e-12);
    }

    #[test]
    fn closed_form_zero_gradient() {
        let g = vec![0.0; 16];
        let mut p = Vec::new();
        let pv = closed_form_probs(&g, 1.0, &mut p);
        assert_eq!(pv.expected_nnz, 0.0);
        assert!(p.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn greedy_hits_target_density() {
        let g = sample_grad(2048, 4);
        let mut p = Vec::new();
        for rho in [0.02f32, 0.1, 0.3] {
            let pv = greedy_probs(&g, rho, 2, &mut p);
            let density = pv.expected_nnz / g.len() as f64;
            // Greedy may undershoot after truncation but should be close
            // after 2 iterations (paper's observation).
            assert!(
                density <= rho as f64 + 1e-3,
                "rho={rho}: density {density} exceeds target"
            );
            assert!(
                density >= rho as f64 * 0.75,
                "rho={rho}: density {density} far below target"
            );
        }
    }

    #[test]
    fn greedy_prop1_form() {
        // Final p must satisfy p_i = min(γ|g_i|, 1) with γ = 1/inv_lambda.
        let g = sample_grad(512, 5);
        let mut p = Vec::new();
        let pv = greedy_probs(&g, 0.1, 2, &mut p);
        assert!(pv.inv_lambda > 0.0);
        let gamma = 1.0 / pv.inv_lambda as f64;
        for (i, &pi) in p.iter().enumerate() {
            let expect = (gamma * g[i].abs() as f64).min(1.0);
            assert!(
                (pi as f64 - expect).abs() < 1e-4 * expect.max(1e-6),
                "p[{i}]={pi} expect {expect}"
            );
        }
    }

    #[test]
    fn greedy_rho_one_keeps_all_nonzero() {
        let g = vec![0.5, -0.1, 0.0, 2.0];
        let mut p = Vec::new();
        let pv = greedy_probs(&g, 1.0, 4, &mut p);
        // With ρ=1 the fixed point pushes every non-zero to p=1.
        assert!(p[0] >= 0.99 && p[1] >= 0.99 && p[3] >= 0.99, "{p:?}");
        assert_eq!(p[2], 0.0);
        assert!(pv.expected_nnz > 2.9);
    }

    #[test]
    fn greedy_zero_gradient() {
        let g = vec![0.0; 8];
        let mut p = Vec::new();
        let pv = greedy_probs(&g, 0.5, 2, &mut p);
        assert_eq!(pv.expected_nnz, 0.0);
        assert_eq!(pv.inv_lambda, 0.0);
    }

    #[test]
    fn greedy_more_iters_weakly_increases_density() {
        let g = sample_grad(1024, 6);
        let (mut p1, mut p2) = (Vec::new(), Vec::new());
        let d1 = greedy_probs(&g, 0.05, 1, &mut p1).expected_nnz;
        let d2 = greedy_probs(&g, 0.05, 4, &mut p2).expected_nnz;
        assert!(d2 >= d1 - 1e-9, "more iterations should not lose density");
    }

    #[test]
    fn greedy_variance_close_to_optimal() {
        // At matched sparsity, greedy's variance should be within a small
        // factor of the closed form's (it approximates the same optimum).
        let g = sample_grad(1024, 7);
        let mut p = Vec::new();
        let greedy = greedy_probs(&g, 0.1, 2, &mut p);
        // Find eps for closed-form that lands at similar nnz via bisection.
        let (mut lo, mut hi) = (0.0f32, 50.0f32);
        let mut pc = Vec::new();
        for _ in 0..40 {
            let mid = 0.5 * (lo + hi);
            let nnz = closed_form_probs(&g, mid, &mut pc).expected_nnz;
            if nnz > greedy.expected_nnz {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let exact = closed_form_probs(&g, 0.5 * (lo + hi), &mut pc);
        assert!(
            greedy.variance <= exact.variance * 1.10 + 1e-9,
            "greedy var {} vs optimal {}",
            greedy.variance,
            exact.variance
        );
    }

    /// Shared checker: the selection-based solver must reproduce the sorted
    /// reference's `ProbVector` and probabilities (up to f64 summation
    /// order).
    fn assert_solvers_agree(g: &[f32], eps: f32) -> Result<(), String> {
        let mut p_ref = Vec::new();
        let pv_ref = closed_form_probs_sorted(g, eps, &mut p_ref);
        let mut p_sel = Vec::new();
        let mut scratch = SelectScratch::default();
        let pv_sel = closed_form_probs_with(g, eps, &mut p_sel, &mut scratch);

        let rel = |a: f64, b: f64| (a - b).abs() / a.abs().max(b.abs()).max(1e-12);
        if (pv_sel.inv_lambda as f64 - pv_ref.inv_lambda as f64).abs()
            > 1e-5 * (pv_ref.inv_lambda as f64).max(1e-12)
        {
            return Err(format!(
                "inv_lambda: sel {} vs ref {}",
                pv_sel.inv_lambda, pv_ref.inv_lambda
            ));
        }
        if pv_sel.num_exact != pv_ref.num_exact {
            return Err(format!(
                "num_exact: sel {} vs ref {}",
                pv_sel.num_exact, pv_ref.num_exact
            ));
        }
        if rel(pv_sel.expected_nnz, pv_ref.expected_nnz) > 1e-9 {
            return Err(format!(
                "expected_nnz: sel {} vs ref {}",
                pv_sel.expected_nnz, pv_ref.expected_nnz
            ));
        }
        if rel(pv_sel.variance, pv_ref.variance) > 1e-9 {
            return Err(format!(
                "variance: sel {} vs ref {}",
                pv_sel.variance, pv_ref.variance
            ));
        }
        for i in 0..g.len() {
            if (p_sel[i] - p_ref[i]).abs() > 1e-6 {
                return Err(format!("p[{i}]: sel {} vs ref {}", p_sel[i], p_ref[i]));
            }
        }
        Ok(())
    }

    #[test]
    fn selection_solver_matches_sorted_reference() {
        for seed in 0..6u64 {
            let g = sample_grad(700 + 13 * seed as usize, 40 + seed);
            for eps in [0.0f32, 0.1, 0.5, 1.0, 3.0] {
                if let Err(e) = assert_solvers_agree(&g, eps) {
                    panic!("seed {seed} eps {eps}: {e}");
                }
            }
        }
        // Degenerate shapes.
        assert_solvers_agree(&[0.0; 32], 1.0).unwrap();
        assert_solvers_agree(&[2.5], 0.5).unwrap();
        assert_solvers_agree(&[1.0, -1.0, 1.0, -1.0], 0.7).unwrap(); // ties
    }

    #[test]
    fn property_selection_equals_sorted() {
        crate::proptest_lite::run("selection solver == sorted solver", 64, |gen| {
            let d = gen.usize_in(1, 1500);
            let g = gen.gradient_vec(d);
            let eps = gen.f32_in(0.0, 4.0);
            assert_solvers_agree(&g, eps)
        });
    }

    #[test]
    fn selection_scratch_is_reusable_across_dimensions() {
        // Same scratch across shrinking/growing d must not leak state.
        let mut scratch = SelectScratch::default();
        let mut p = Vec::new();
        for &(d, seed) in &[(512usize, 60u64), (33, 61), (2048, 62), (1, 63)] {
            let g = sample_grad(d, seed);
            let pv = closed_form_probs_with(&g, 0.5, &mut p, &mut scratch);
            let mut p_ref = Vec::new();
            let pv_ref = closed_form_probs_sorted(&g, 0.5, &mut p_ref);
            assert_eq!(pv.num_exact, pv_ref.num_exact, "d={d}");
            for i in 0..d {
                assert!((p[i] - p_ref[i]).abs() < 1e-6, "d={d} p[{i}]");
            }
        }
    }

    #[test]
    fn property_probabilities_valid_range() {
        crate::proptest_lite::run("probs in (0,1] and zero iff g zero", 64, |gen| {
            let d = gen.usize_in(1, 600);
            let g = gen.gradient_vec(d);
            let rho = gen.f32_in(0.01, 1.0);
            let mut p = Vec::new();
            greedy_probs(&g, rho, 2, &mut p);
            for (i, &pi) in p.iter().enumerate() {
                if !(0.0..=1.0).contains(&pi) {
                    return Err(format!("greedy p[{i}]={pi} out of range"));
                }
                if g[i] == 0.0 && pi != 0.0 {
                    return Err(format!("greedy p[{i}]={pi} but g=0"));
                }
                if g[i] != 0.0 && pi == 0.0 {
                    return Err(format!("greedy p[{i}]=0 but g={}", g[i]));
                }
            }
            let eps = gen.f32_in(0.0, 3.0);
            closed_form_probs(&g, eps, &mut p);
            for (i, &pi) in p.iter().enumerate() {
                if !(0.0..=1.0).contains(&pi) {
                    return Err(format!("closed p[{i}]={pi} out of range"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_closed_form_variance_budget() {
        crate::proptest_lite::run("closed form respects (1+eps) variance", 48, |gen| {
            let d = gen.usize_in(2, 400);
            let g = gen.gradient_vec(d);
            let eps = gen.f32_in(0.0, 4.0);
            let total: f64 = g.iter().map(|&x| (x as f64).powi(2)).sum();
            let mut p = Vec::new();
            let pv = closed_form_probs(&g, eps, &mut p);
            let budget = (1.0 + eps as f64) * total * (1.0 + 1e-5) + 1e-12;
            if pv.variance > budget {
                return Err(format!("variance {} > budget {budget}", pv.variance));
            }
            Ok(())
        });
    }
}
