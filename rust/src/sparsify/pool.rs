//! Persistent worker thread pool for the sharded compression path.
//!
//! The engine used to spawn fresh scoped threads (`std::thread::scope`) on
//! every large `compress_into` call; at the 10–100 µs scale of one
//! compression round, thread spawn/join is a measurable fixed cost (tens of
//! µs on this box). [`ShardPool`] keeps the threads alive across calls and
//! hands them borrowed closures through a scoped-execution API whose
//! blocking semantics make the lifetime erasure sound: [`ShardPool::run`]
//! does not return until every submitted job has finished, so borrows
//! captured by the jobs provably outlive their execution.
//!
//! Work partitioning is the caller's: the engine still assigns chunks to
//! shard buffers by chunk index, so which pool thread runs a job cannot
//! change any output byte — sharded compression stays bitwise identical to
//! the sequential path (asserted by the engine's determinism tests, which
//! now exercise the pool).

use crate::sync::mpsc::{channel, Receiver, Sender};
use crate::sync::thread::JoinHandle;
use crate::sync::{thread, Arc, Mutex};
use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// (generation, submission slot, work). The generation tags which
/// `run_streamed` call a job belongs to, so an aborted call (panicking
/// `on_done`) can never leak its completions into the next call.
type Job = (u64, usize, Box<dyn FnOnce() + Send + 'static>);

type Done = (u64, usize, Result<(), String>);

/// A fixed-size pool of persistent worker threads executing borrowed jobs
/// to completion ([`ShardPool::run`]). Dropping the pool joins the threads.
pub struct ShardPool {
    job_tx: Option<Sender<Job>>,
    done_rx: Receiver<Done>,
    generation: Cell<u64>,
    threads: Vec<JoinHandle<()>>,
}

impl std::fmt::Debug for ShardPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardPool")
            .field("threads", &self.threads.len())
            .finish()
    }
}

impl ShardPool {
    /// Spawn `threads` persistent workers (at least one).
    pub fn new(threads: usize) -> Self {
        let (job_tx, job_rx) = channel::<Job>();
        let (done_tx, done_rx) = channel::<Done>();
        // The job queue is shared work-stealing style: whichever worker is
        // free locks the receiver and takes the next job. Jobs are coarse
        // (a group of shards), so the lock is uncontended in practice.
        let job_rx = Arc::new(Mutex::new(job_rx));
        let threads = (0..threads.max(1))
            .map(|_| {
                let job_rx = Arc::clone(&job_rx);
                let done_tx = done_tx.clone();
                thread::spawn(move || loop {
                    let job = {
                        let guard = job_rx.lock().expect("pool queue lock");
                        guard.recv()
                    };
                    let Ok((gen, slot, job)) = job else {
                        break; // pool dropped
                    };
                    let result = catch_unwind(AssertUnwindSafe(job)).map_err(|payload| {
                        payload
                            .downcast_ref::<&str>()
                            .map(|s| s.to_string())
                            .or_else(|| payload.downcast_ref::<String>().cloned())
                            .unwrap_or_else(|| "non-string panic payload".into())
                    });
                    if done_tx.send((gen, slot, result)).is_err() {
                        break;
                    }
                })
            })
            .collect();
        Self {
            job_tx: Some(job_tx),
            done_rx,
            generation: Cell::new(0),
            threads,
        }
    }

    pub fn threads(&self) -> usize {
        self.threads.len()
    }

    /// Execute `jobs` on the pool and block until all of them finished.
    /// A panic inside any job is re-raised here — after every other job has
    /// completed, so no borrow is left running.
    pub fn run<'env>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        self.run_streamed(jobs, |_| {});
    }

    /// Like [`ShardPool::run`], but invokes `on_done(i)` **on the calling
    /// thread** as soon as job `i` (submission index) has completed, in
    /// completion order — the hook the pipelined send path uses to hand a
    /// finished chunk's output downstream while later chunks are still
    /// running. `on_done` must not touch state the still-running jobs
    /// borrow mutably; the usual pattern is reading job `i`'s disjoint
    /// output slot. If any job panics, the panic is re-raised here after
    /// every job has finished (completed jobs still get their `on_done`
    /// call first). If `on_done` itself panics, the call still blocks until
    /// every outstanding job has finished before the unwind escapes — the
    /// borrowed jobs must never outlive this call frame — and the pool
    /// stays usable afterwards.
    pub fn run_streamed<'env>(
        &self,
        jobs: Vec<Box<dyn FnOnce() + Send + 'env>>,
        mut on_done: impl FnMut(usize),
    ) {
        let n = jobs.len();
        let gen = self.generation.get().wrapping_add(1);
        self.generation.set(gen);
        let tx = self.job_tx.as_ref().expect("pool is alive until drop");
        // Armed before the first send: from the moment a borrowed job is in
        // flight, *every* exit from this function — normal return, a panic
        // in `on_done`, or a re-raised job panic — first blocks until all
        // `n` completions of this generation have arrived.
        let mut drain = DrainGuard {
            rx: &self.done_rx,
            gen,
            remaining: n,
        };
        for (slot, job) in jobs.into_iter().enumerate() {
            // SAFETY: lifetime erasure only. The `DrainGuard` above blocks
            // (in the loop below, or in its Drop if that loop unwinds)
            // until all `n` jobs of this generation report completion, and
            // pool workers report *after* the job has returned (or
            // unwound), so everything the job borrows from `'env` strictly
            // outlives its execution. The drain can only end early when
            // `recv` disconnects, which requires every worker thread to
            // have exited — then nothing borrowing `'env` runs either.
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce() + Send + 'env>,
                    Box<dyn FnOnce() + Send + 'static>,
                >(job)
            };
            tx.send((gen, slot, job)).expect("pool workers alive");
        }
        let mut panicked: Option<String> = None;
        while drain.remaining > 0 {
            match self.done_rx.recv().expect("pool workers alive") {
                (g, _, _) if g != gen => {} // stale completion from an aborted call
                (_, slot, Ok(())) => {
                    // Count down before `on_done`: if the hook panics, the
                    // guard must not wait for this already-received slot.
                    drain.remaining -= 1;
                    on_done(slot);
                }
                (_, _, Err(msg)) => {
                    drain.remaining -= 1;
                    panicked = Some(msg);
                }
            }
        }
        drain.remaining = 0; // fully drained; disarm the guard
        if let Some(msg) = panicked {
            panic!("shard pool job panicked: {msg}");
        }
    }
}

/// Soundness backstop for [`ShardPool::run_streamed`]: while armed
/// (`remaining > 0`), leaving the call frame — normally or by unwinding out
/// of the `on_done` hook — first receives every outstanding completion of
/// the current generation, so no borrowed job can still be running once the
/// `'env` borrows end.
struct DrainGuard<'a> {
    rx: &'a Receiver<Done>,
    gen: u64,
    remaining: usize,
}

impl Drop for DrainGuard<'_> {
    fn drop(&mut self) {
        while self.remaining > 0 {
            match self.rx.recv() {
                Ok((g, _, _)) if g == self.gen => self.remaining -= 1,
                Ok(_) => {} // stale completion from an older aborted call
                // Disconnected: workers only exit when the pool itself is
                // being dropped, at which point no borrowed job is running.
                Err(_) => break,
            }
        }
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        // Disconnect the queue so idle workers observe `Err` and exit.
        drop(self.job_tx.take());
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_borrowed_jobs_to_completion() {
        let pool = ShardPool::new(4);
        let mut outputs = vec![0usize; 16];
        for round in 1..=3 {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = outputs
                .chunks_mut(4)
                .enumerate()
                .map(|(i, chunk)| {
                    let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                        for (j, slot) in chunk.iter_mut().enumerate() {
                            *slot = round * 100 + i * 10 + j;
                        }
                    });
                    job
                })
                .collect();
            pool.run(jobs);
        }
        for (k, &v) in outputs.iter().enumerate() {
            assert_eq!(v, 300 + (k / 4) * 10 + k % 4);
        }
    }

    #[test]
    fn reuses_the_same_threads_across_calls() {
        let pool = ShardPool::new(2);
        assert_eq!(pool.threads(), 2);
        let seen = AtomicUsize::new(0);
        for _ in 0..8 {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2)
                .map(|_| {
                    let seen = &seen;
                    let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                        seen.fetch_add(1, Ordering::Relaxed);
                    });
                    job
                })
                .collect();
            pool.run(jobs);
        }
        assert_eq!(seen.load(Ordering::Relaxed), 16);
    }

    #[test]
    #[should_panic(expected = "shard pool job panicked")]
    fn job_panic_propagates_after_all_jobs_finish() {
        let pool = ShardPool::new(2);
        let ok = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|i| {
                let ok = &ok;
                let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    if i == 1 {
                        panic!("boom");
                    }
                    ok.fetch_add(1, Ordering::Relaxed);
                });
                job
            })
            .collect();
        pool.run(jobs);
    }

    #[test]
    fn streamed_completions_arrive_once_per_job_with_outputs_visible() {
        let pool = ShardPool::new(3);
        let outputs: Vec<AtomicUsize> = (0..12).map(|_| AtomicUsize::new(0)).collect();
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..12)
            .map(|i| {
                let slot = &outputs[i];
                let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    slot.store(i + 1, Ordering::Release);
                });
                job
            })
            .collect();
        let mut seen = vec![false; 12];
        pool.run_streamed(jobs, |i| {
            // Each index is reported exactly once, and by the time it is
            // reported the job's output is visible to the calling thread.
            assert!(!seen[i], "index {i} reported twice");
            seen[i] = true;
            assert_eq!(outputs[i].load(Ordering::Acquire), i + 1);
        });
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn panicking_on_done_hook_drains_before_unwinding() {
        let pool = ShardPool::new(3);
        let outputs: Vec<AtomicUsize> = (0..8).map(|_| AtomicUsize::new(0)).collect();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
                .map(|i| {
                    let slot = &outputs[i];
                    let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                        slot.store(i + 1, Ordering::Release);
                    });
                    job
                })
                .collect();
            pool.run_streamed(jobs, |_| panic!("hook failure"));
        }));
        assert!(caught.is_err(), "hook panic must propagate");
        // Every job of the aborted call finished before the unwind escaped
        // the call frame (otherwise workers would still hold the borrow of
        // `outputs` here — the soundness property the DrainGuard exists for).
        for (i, slot) in outputs.iter().enumerate() {
            assert_eq!(slot.load(Ordering::Acquire), i + 1);
        }
        // And the pool is still fully usable for the next round.
        let ok = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|_| {
                let ok = &ok;
                let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    ok.fetch_add(1, Ordering::Relaxed);
                });
                job
            })
            .collect();
        let mut done = 0usize;
        pool.run_streamed(jobs, |_| done += 1);
        assert_eq!(done, 4, "no stale completions may leak into a new call");
        assert_eq!(ok.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn job_panic_and_hook_panic_together_leave_pool_reusable() {
        let pool = ShardPool::new(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                .map(|i| {
                    let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                        if i == 0 {
                            panic!("job boom");
                        }
                    });
                    job
                })
                .collect();
            pool.run_streamed(jobs, |_| panic!("hook boom"));
        }));
        assert!(caught.is_err());
        let n = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2)
            .map(|_| {
                let n = &n;
                let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    n.fetch_add(1, Ordering::Relaxed);
                });
                job
            })
            .collect();
        pool.run(jobs);
        assert_eq!(n.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn zero_thread_request_still_works() {
        let pool = ShardPool::new(0);
        assert_eq!(pool.threads(), 1);
        let mut x = 0u64;
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![Box::new(|| x = 7)];
        pool.run(jobs);
        drop(pool);
        assert_eq!(x, 7);
    }
}
