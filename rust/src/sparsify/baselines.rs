//! Every comparison method from the paper's evaluation, implemented as
//! [`Compressor`]s:
//!
//! * [`UniformSampler`] — the paper's **UniSp** baseline: every coordinate
//!   kept with the same probability ρ (rescaled by 1/ρ for unbiasedness);
//! * [`QsgdCompressor`] — QSGD \[Alistarh et al. 2017\], the stochastic
//!   quantizer the paper compares against in Figures 5–6;
//! * [`TernGradCompressor`] — TernGrad \[Wen et al. 2017\] {−1, 0, +1}
//!   ternarization (related work the paper discusses);
//! * [`TopKCompressor`] — deterministic top-k (biased) ablation;
//! * [`SignCompressor`] — plain two-sided sign compression (no memory);
//! * [`OneBitSgd`] — 1Bit-SGD \[Seide et al. 2014\]: the sign compressor
//!   composed with the shared [`crate::feedback`] error-memory subsystem.

use super::{index_bits, sparse_slot, Compressed, CompressStats, Compressor, FLOAT_BITS};
use crate::rngkit::RandArray;

/// **UniSp**: `p_i = ρ` for all `i`; survivors carry `g_i / ρ`.
pub struct UniformSampler {
    pub rho: f32,
}

impl UniformSampler {
    pub fn new(rho: f32) -> Self {
        assert!(rho > 0.0 && rho <= 1.0);
        Self { rho }
    }
}

impl Compressor for UniformSampler {
    fn compress_into(
        &mut self,
        g: &[f32],
        rand: &mut RandArray,
        out: &mut Compressed,
    ) -> CompressStats {
        let sg = sparse_slot(out, g.len());
        // Realized nnz is data-dependent; reserving `d` up front makes the
        // steady state deterministically allocation-free.
        sg.exact.reserve(g.len());
        let inv_rho = 1.0 / self.rho;
        for (i, &gi) in g.iter().enumerate() {
            if gi != 0.0 && rand.next() < self.rho {
                // Values differ per coordinate → they go in the exact part
                // (full floats on the wire; UniSp has no shared-magnitude
                // structure to exploit, which is exactly why it codes worse).
                sg.exact.push((i as u32, gi * inv_rho));
            }
        }
        let nnz = sg.exact.len() as u64;
        CompressStats {
            expected_nnz: self.rho as f64 * g.iter().filter(|&&x| x != 0.0).count() as f64,
            ideal_bits: nnz * (FLOAT_BITS + index_bits(g.len())),
        }
    }

    fn name(&self) -> &'static str {
        "UniSp"
    }
}

/// **QSGD** with `s = 2^bits` quantization levels:
/// `Q(g_i) = ‖g‖₂ · sign(g_i) · ξ_i` where `ξ_i` stochastically rounds
/// `|g_i|/‖g‖₂ · s` to a neighbouring integer level — unbiased by
/// construction. Idealized cost follows the paper's Fig 5 model: `b` bits
/// per coordinate plus the norm float (`H(T,M) = T·M·b` per element).
pub struct QsgdCompressor {
    pub bits: u32,
}

impl QsgdCompressor {
    pub fn new(bits: u32) -> Self {
        assert!((1..=16).contains(&bits));
        Self { bits }
    }
}

impl Compressor for QsgdCompressor {
    fn compress_into(
        &mut self,
        g: &[f32],
        rand: &mut RandArray,
        out: &mut Compressed,
    ) -> CompressStats {
        let d = g.len();
        let norm = crate::tensor::norm2_sq(g).sqrt();
        // Reuse the level buffer when the previous message was QSGD too.
        if !matches!(out, Compressed::Qsgd { .. }) {
            *out = Compressed::Qsgd {
                d: 0,
                norm: 0.0,
                bits: self.bits,
                levels: Vec::new(),
            };
        }
        let Compressed::Qsgd {
            d: out_d,
            norm: out_norm,
            bits: out_bits,
            levels,
        } = out
        else {
            unreachable!("just set to Qsgd")
        };
        *out_d = d as u32;
        *out_norm = norm;
        *out_bits = self.bits;
        levels.clear();
        let s = (1u32 << self.bits) as f32;
        let mut expected_nnz = 0.0f64;
        if norm == 0.0 {
            levels.resize(d, 0);
        } else {
            for &gi in g {
                let x = gi.abs() / norm * s; // in [0, s]
                let lo = x.floor();
                let frac = x - lo;
                let level = if rand.next() < frac { lo + 1.0 } else { lo };
                let signed = if gi < 0.0 { -level } else { level } as i32;
                if signed != 0 {
                    expected_nnz += 1.0;
                }
                levels.push(signed);
            }
        }
        CompressStats {
            expected_nnz,
            // Paper's Fig-5 accounting: b bits per element + the norm float.
            ideal_bits: d as u64 * self.bits as u64 + FLOAT_BITS,
        }
    }

    fn name(&self) -> &'static str {
        "QSGD"
    }
}

/// **TernGrad**: `Q(g_i) = s · sign(g_i) · Z_i`, `s = max_i |g_i|`,
/// `Z_i ~ Bernoulli(|g_i| / s)` — unbiased. 2 bits per coordinate + scale.
pub struct TernGradCompressor;

impl TernGradCompressor {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        Self
    }
}

impl Compressor for TernGradCompressor {
    fn compress_into(
        &mut self,
        g: &[f32],
        rand: &mut RandArray,
        out: &mut Compressed,
    ) -> CompressStats {
        let d = g.len();
        let scale = g.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        if !matches!(out, Compressed::Ternary { .. }) {
            *out = Compressed::Ternary {
                d: 0,
                scale: 0.0,
                signs: Vec::new(),
            };
        }
        let Compressed::Ternary {
            d: out_d,
            scale: out_scale,
            signs,
        } = out
        else {
            unreachable!("just set to Ternary")
        };
        *out_d = d as u32;
        *out_scale = scale;
        signs.clear();
        let mut expected_nnz = 0.0f64;
        if scale == 0.0 {
            signs.resize(d, 0i8);
        } else {
            for &gi in g {
                let p = gi.abs() / scale;
                expected_nnz += p as f64;
                if rand.next() < p {
                    signs.push(if gi < 0.0 { -1 } else { 1 });
                } else {
                    signs.push(0);
                }
            }
        }
        CompressStats {
            expected_nnz,
            ideal_bits: 2 * d as u64 + FLOAT_BITS,
        }
    }

    fn name(&self) -> &'static str {
        "TernGrad"
    }
}

/// Deterministic **top-k**: keeps the `⌈ρd⌉` largest-magnitude coordinates
/// unmodified. *Biased* — included as an ablation to show why the paper
/// insists on unbiasedness (top-k needs error feedback to converge well).
pub struct TopKCompressor {
    pub rho: f32,
    scratch: Vec<(u32, f32)>,
}

impl TopKCompressor {
    pub fn new(rho: f32) -> Self {
        assert!(rho > 0.0 && rho <= 1.0);
        Self {
            rho,
            scratch: Vec::new(),
        }
    }
}

impl Compressor for TopKCompressor {
    fn compress_into(
        &mut self,
        g: &[f32],
        _rand: &mut RandArray,
        out: &mut Compressed,
    ) -> CompressStats {
        let d = g.len();
        let k = ((self.rho as f64 * d as f64).ceil() as usize).clamp(1, d);
        self.scratch.clear();
        self.scratch
            .extend(g.iter().enumerate().map(|(i, &v)| (i as u32, v)));
        // Partial selection of the k largest magnitudes.
        self.scratch.select_nth_unstable_by(k.saturating_sub(1), |a, b| {
            b.1.abs()
                .partial_cmp(&a.1.abs())
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let sg = sparse_slot(out, d);
        sg.exact.extend(
            self.scratch[..k]
                .iter()
                .copied()
                .filter(|&(_, v)| v != 0.0),
        );
        sg.exact.sort_unstable_by_key(|&(i, _)| i);
        let nnz = sg.exact.len() as u64;
        CompressStats {
            expected_nnz: nnz as f64,
            ideal_bits: nnz * (FLOAT_BITS + index_bits(d)),
        }
    }

    fn name(&self) -> &'static str {
        "TopK"
    }
}

/// Plain two-sided **sign compression** (the quantizer inside 1Bit-SGD,
/// *without* any memory): transmit `sign(c)` scaled by the mean absolute
/// magnitude of the same-sign coordinates. Biased and lossy — on its own it
/// does not converge; compose it with
/// [`WithFeedback`](crate::feedback::WithFeedback) (which is exactly what
/// [`OneBitSgd`] is) to recover SGD behavior.
pub struct SignCompressor;

impl SignCompressor {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        Self
    }
}

impl Compressor for SignCompressor {
    fn compress_into(
        &mut self,
        g: &[f32],
        _rand: &mut RandArray,
        out: &mut Compressed,
    ) -> CompressStats {
        let d = g.len();
        let mut pos_sum = 0.0f64;
        let mut pos_n = 0u64;
        let mut neg_sum = 0.0f64;
        let mut neg_n = 0u64;
        for &c in g {
            if c >= 0.0 {
                pos_sum += c as f64;
                pos_n += 1;
            } else {
                neg_sum += (-c) as f64;
                neg_n += 1;
            }
        }
        let pos_mag = if pos_n > 0 { (pos_sum / pos_n as f64) as f32 } else { 0.0 };
        let neg_mag = if neg_n > 0 { (neg_sum / neg_n as f64) as f32 } else { 0.0 };
        // Two-sided magnitudes are not representable as Ternary (one scale),
        // so the message travels in its decoded dense form, written straight
        // into the reused output buffer; the cost model still accounts
        // 1 bit/coordinate + the two scalars.
        if !matches!(out, Compressed::Dense(_)) {
            *out = Compressed::Dense(Vec::new());
        }
        let Compressed::Dense(dense) = out else {
            unreachable!("just set to Dense")
        };
        dense.clear();
        let mut nnz = 0u64;
        for &c in g {
            let (s, q) = if c >= 0.0 { (1i8, pos_mag) } else { (-1i8, -neg_mag) };
            if q != 0.0 {
                nnz += 1;
            }
            dense.push(match if q == 0.0 { 0 } else { s } {
                1 => pos_mag,
                -1 => -neg_mag,
                _ => 0.0,
            });
        }
        CompressStats {
            expected_nnz: nnz as f64,
            ideal_bits: d as u64 + 2 * FLOAT_BITS,
        }
    }

    fn name(&self) -> &'static str {
        "Sign"
    }
}

/// **1Bit-SGD** \[Seide et al. 2014\]: [`SignCompressor`] composed with the
/// shared error-feedback subsystem — `Q(g + e)` with `e ← (g + e) − Q(g+e)`
/// carried to the next step. This used to be a bespoke residual loop inside
/// this type; it is now literally `WithFeedback<SignCompressor>`, and the
/// refactor is bitwise-identical to the old implementation (pinned by
/// `tests/feedback.rs`).
pub struct OneBitSgd {
    inner: crate::feedback::WithFeedback<SignCompressor>,
}

impl OneBitSgd {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        Self {
            inner: crate::feedback::WithFeedback::new(SignCompressor),
        }
    }

    /// 1Bit-SGD under an explicit feedback configuration (e.g. a residual
    /// decay β < 1) — how a session-level
    /// [`FeedbackConfig`](crate::feedback::FeedbackConfig) reaches this
    /// method without stacking a second residual memory on top.
    pub fn with_config(cfg: crate::feedback::FeedbackConfig) -> Self {
        Self {
            inner: crate::feedback::WithFeedback::with_config(SignCompressor, cfg),
        }
    }

    /// The carried residual `e` (for tests and diagnostics).
    pub fn residual(&self) -> &[f32] {
        self.inner.state().residual()
    }
}

impl Compressor for OneBitSgd {
    fn compress_into(
        &mut self,
        g: &[f32],
        rand: &mut RandArray,
        out: &mut Compressed,
    ) -> CompressStats {
        self.inner.compress_into(g, rand, out)
    }

    fn compress_batch_into(
        &mut self,
        layers: &[&[f32]],
        rand: &mut RandArray,
        out: &mut Vec<Compressed>,
        stats: &mut Vec<CompressStats>,
    ) {
        self.inner.compress_batch_into(layers, rand, out, stats)
    }

    fn name(&self) -> &'static str {
        "1Bit-SGD"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngkit::RandArray;

    fn gradient(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::rngkit::Xoshiro256pp::seed_from_u64(seed);
        (0..d).map(|_| (rng.next_gaussian() * 0.3) as f32).collect()
    }

    #[test]
    fn uniform_is_unbiased() {
        let g = gradient(32, 20);
        let mut c = UniformSampler::new(0.25);
        // Array long enough that no draws are reused across trials (cyclic
        // reuse correlates trials and breaks the 4σ Monte-Carlo tolerance).
        let mut ra = RandArray::from_seed(21, 1 << 21);
        let trials = 40_000;
        let mut mean = vec![0.0f64; g.len()];
        for _ in 0..trials {
            let (out, _) = c.compress(&g, &mut ra);
            let dense = out.to_dense();
            for (m, &v) in mean.iter_mut().zip(&dense) {
                *m += v as f64;
            }
        }
        for i in 0..g.len() {
            let m = mean[i] / trials as f64;
            let gi = g[i] as f64;
            let var = gi * gi * (1.0 - 0.25) / 0.25;
            let tol = 4.0 * (var / trials as f64).sqrt() + 1e-9;
            assert!((m - gi).abs() <= tol, "coord {i}: {m} vs {gi}");
        }
    }

    #[test]
    fn uniform_variance_exceeds_gspar_at_same_density() {
        // The whole point of the paper: at matched expected sparsity, the
        // magnitude-aware probabilities give smaller variance than uniform.
        let g = {
            // Heavily skewed gradient.
            let mut v = gradient(512, 22);
            for (i, x) in v.iter_mut().enumerate() {
                if i % 50 == 0 {
                    *x *= 30.0;
                } else {
                    *x *= 0.02;
                }
            }
            v
        };
        let rho = 0.1f32;
        let mut p = Vec::new();
        let gspar = crate::sparsify::probs::greedy_probs(&g, rho, 2, &mut p);
        // Uniform variance: Σ g²/ρ over non-zeros.
        let uni_var: f64 = g
            .iter()
            .filter(|&&x| x != 0.0)
            .map(|&x| (x as f64).powi(2) / rho as f64)
            .sum();
        assert!(
            gspar.variance < uni_var * 0.5,
            "gspar var {} should beat uniform {} decisively on skewed g",
            gspar.variance,
            uni_var
        );
    }

    #[test]
    fn qsgd_is_unbiased() {
        let g = gradient(24, 23);
        let mut c = QsgdCompressor::new(2);
        let mut ra = RandArray::from_seed(24, 1 << 21);
        let trials = 60_000;
        let mut mean = vec![0.0f64; g.len()];
        for _ in 0..trials {
            let (out, _) = c.compress(&g, &mut ra);
            for (m, v) in mean.iter_mut().zip(out.to_dense()) {
                *m += v as f64;
            }
        }
        let norm = crate::tensor::norm2_sq(&g).sqrt() as f64;
        for i in 0..g.len() {
            let m = mean[i] / trials as f64;
            let gi = g[i] as f64;
            // Per-coordinate MC sd bounded by the quantization unit.
            let unit = norm / 4.0;
            let tol = 4.0 * (unit / (trials as f64).sqrt()) + 1e-9;
            assert!((m - gi).abs() <= tol, "coord {i}: {m} vs {gi} (tol {tol})");
        }
    }

    #[test]
    fn qsgd_levels_bounded() {
        let g = gradient(256, 25);
        let mut c = QsgdCompressor::new(3);
        let mut ra = RandArray::from_seed(26, 1 << 16);
        let (out, _) = c.compress(&g, &mut ra);
        if let Compressed::Qsgd { levels, bits, .. } = out {
            let cap = (1i32 << bits) + 1;
            assert!(levels.iter().all(|&l| l.abs() <= cap));
        } else {
            panic!("wrong variant");
        }
    }

    #[test]
    fn terngrad_is_unbiased_and_bounded() {
        let g = gradient(24, 27);
        let mut c = TernGradCompressor::new();
        let mut ra = RandArray::from_seed(28, 1 << 21);
        let trials = 60_000;
        let mut mean = vec![0.0f64; g.len()];
        let scale = g.iter().fold(0.0f32, |m, &x| m.max(x.abs())) as f64;
        for _ in 0..trials {
            let (out, _) = c.compress(&g, &mut ra);
            if let Compressed::Ternary { ref signs, .. } = out {
                assert!(signs.iter().all(|&s| (-1..=1).contains(&s)));
            }
            for (m, v) in mean.iter_mut().zip(out.to_dense()) {
                *m += v as f64;
            }
        }
        for i in 0..g.len() {
            let m = mean[i] / trials as f64;
            let gi = g[i] as f64;
            let var = scale * gi.abs() - gi * gi;
            let tol = 4.0 * (var.max(0.0) / trials as f64).sqrt() + 1e-9;
            assert!((m - gi).abs() <= tol, "coord {i}: {m} vs {gi}");
        }
    }

    #[test]
    fn topk_keeps_largest() {
        let g = vec![0.1, -5.0, 0.2, 3.0, -0.05, 0.0];
        let mut c = TopKCompressor::new(0.34); // k = ceil(0.34*6) = 3
        let mut ra = RandArray::from_seed(29, 64);
        let (out, stats) = c.compress(&g, &mut ra);
        let dense = out.to_dense();
        assert_eq!(dense[1], -5.0);
        assert_eq!(dense[3], 3.0);
        assert_eq!(dense[2], 0.2);
        assert_eq!(dense[0], 0.0);
        assert_eq!(stats.expected_nnz, 3.0);
    }

    #[test]
    fn onebit_error_feedback_sums_to_signal() {
        // Over many steps on a constant gradient, the *accumulated decoded*
        // signal + residual equals the accumulated true signal (the error
        // never leaks) — the invariant that makes 1-bit SGD converge.
        let g = gradient(64, 30);
        let mut c = OneBitSgd::new();
        let mut ra = RandArray::from_seed(31, 64);
        let steps = 500;
        let mut decoded_sum = vec![0.0f64; g.len()];
        for _ in 0..steps {
            let (out, _) = c.compress(&g, &mut ra);
            for (s, v) in decoded_sum.iter_mut().zip(out.to_dense()) {
                *s += v as f64;
            }
        }
        for i in 0..g.len() {
            let true_sum = g[i] as f64 * steps as f64;
            let leak = (decoded_sum[i] + c.residual()[i] as f64) - true_sum;
            assert!(
                leak.abs() < 2e-2 * steps as f64 * g[i].abs().max(0.05) as f64,
                "coord {i}: leak {leak}"
            );
        }
    }

    #[test]
    fn zero_gradient_all_methods() {
        let g = vec![0.0f32; 50];
        let mut ra = RandArray::from_seed(32, 1024);
        for m in crate::config::Method::all() {
            let mut c = crate::api::MethodSpec::from_parts(*m, 0.2, 0.5, 4).build();
            let (out, stats) = c.compress(&g, &mut ra);
            assert!(
                out.to_dense().iter().all(|&v| v == 0.0),
                "{m}: zero gradient must decode to zero"
            );
            // Dense transmits all d coordinates regardless of value.
            if *m != crate::config::Method::Dense {
                assert!(stats.expected_nnz <= 1e-9, "{m}");
            }
        }
    }
}
