//! The batched multi-layer engine: one scratch-arena invocation for a whole
//! model's layer list.
//!
//! §5.2 sparsifies each layer **independently** — its own probability
//! vector, its own λ, its own message — but independence of the *math* does
//! not require independence of the *machinery*. [`BatchCompressEngine`]
//! runs the per-layer closed-form / greedy solves back to back over one
//! shared scratch arena, draws every layer's uniforms from the worker's
//! single pre-generated stream, and dispatches the sampling of **all**
//! layers' chunks to the persistent [`ShardPool`] in one `run` call —
//! instead of re-entering the single-tensor engine (and its pool) once per
//! layer.
//!
//! Bitwise contract: for the same [`RandArray`] state, compressing a layer
//! list through this engine produces exactly the [`SparseGrad`]s the
//! single-tensor [`CompressEngine`] produces when called once per layer in
//! order. The engine consumes `d_ℓ + 1` uniforms per layer — `d_ℓ` loaded
//! up front, plus the same spacer draw — and assigns chunk output buffers
//! by (layer, chunk) index, so pool scheduling cannot reorder a byte. The
//! cluster coordinator's batched-vs-per-layer parity tests pin this.
//!
//! The fused wire path ([`BatchCompressEngine::compress_batch_into`])
//! encodes the resulting layer list straight into one `WireBatch` message
//! ([`crate::coding::batch`]) — probabilities → sampling → entropy coding
//! in a single pass, with no intermediate per-layer message materialized.

use super::engine::{sample_chunk, CompressEngine, EngineMode};
use super::pool::ShardPool;
use super::probs::ProbVector;
use super::SparseGrad;
use crate::coding::{self, WireCodec};
use crate::rngkit::RandArray;

/// One (layer, chunk) work item of the batched sampling pass.
#[derive(Clone, Copy, Debug)]
struct ChunkMeta {
    /// Which layer this chunk belongs to.
    layer: usize,
    /// Chunk bounds in layer-local coordinates (so survivor indices match
    /// the per-layer path exactly).
    lo: usize,
    hi: usize,
    /// The layer's offset into the concatenated probability/uniform arena.
    goff: usize,
}

/// Per-chunk output buffers, persistent across rounds (mirrors the
/// single-tensor engine's shard buffers).
#[derive(Debug, Default)]
struct ShardBuf {
    exact: Vec<(u32, f32)>,
    shared: Vec<(u32, bool)>,
}

/// Reusable batched engine: a [`CompressEngine`] (solver + per-layer
/// scratch) plus concatenated probability/uniform arenas sized for the
/// whole layer list. One per worker; `Send` so coordinator threads can own
/// one.
#[derive(Debug)]
pub struct BatchCompressEngine {
    engine: CompressEngine,
    /// Concatenated probability vectors, one segment per layer.
    p_all: Vec<f32>,
    /// Concatenated pre-assigned uniforms, one segment per layer.
    u_all: Vec<f32>,
    /// The (layer, chunk) plan of the current call.
    chunk_meta: Vec<ChunkMeta>,
    /// Per-chunk output buffers for the pooled path.
    shards: Vec<ShardBuf>,
    /// Persistent worker threads, created lazily on the first pooled call.
    pool: Option<ShardPool>,
}

impl BatchCompressEngine {
    /// Batched engine running Algorithm 3 (greedy) per layer.
    pub fn greedy(rho: f32, iters: usize) -> Self {
        Self::new(EngineMode::Greedy { rho, iters })
    }

    /// Batched engine running Algorithm 2 (closed form) per layer.
    pub fn closed_form(eps: f32) -> Self {
        Self::new(EngineMode::ClosedForm { eps })
    }

    pub fn new(mode: EngineMode) -> Self {
        Self {
            engine: CompressEngine::new(mode),
            p_all: Vec::new(),
            u_all: Vec::new(),
            chunk_meta: Vec::new(),
            shards: Vec::new(),
            pool: None,
        }
    }

    /// Override the sharding geometry (shared with the inner single-tensor
    /// engine; `max_threads = 1` pins both to the sequential path).
    pub fn with_sharding(
        mut self,
        shard_len: usize,
        parallel_min_d: usize,
        max_threads: usize,
    ) -> Self {
        self.engine = self.engine.with_sharding(shard_len, parallel_min_d, max_threads);
        self.pool = None;
        self
    }

    /// The inner single-tensor engine (single-layer compress, probability
    /// solves, scratch reservation).
    pub fn engine(&mut self) -> &mut CompressEngine {
        &mut self.engine
    }

    /// Fused per-layer solve → batched sampling into the caller's reused
    /// [`SparseGrad`] slots (`outs[ℓ]` receives layer `ℓ`). Appends one
    /// [`ProbVector`] per layer to `pvs` (cleared first).
    ///
    /// Draw convention: identical to calling
    /// [`CompressEngine::compress_sparse_into`] once per layer in order —
    /// `d_ℓ` uniforms plus one spacer per non-empty layer — which is what
    /// makes the batched and per-layer paths bitwise interchangeable.
    pub fn compress_batch_sparse_into(
        &mut self,
        layers: &[&[f32]],
        rand: &mut RandArray,
        outs: &mut [&mut SparseGrad],
        pvs: &mut Vec<ProbVector>,
    ) {
        assert_eq!(layers.len(), outs.len(), "one output slot per layer");
        pvs.clear();
        let total: usize = layers.iter().map(|g| g.len()).sum();
        if self.p_all.len() < total {
            self.p_all.resize(total, 0.0);
        }
        if self.u_all.len() < total {
            self.u_all.resize(total, 0.0);
        }

        // Phase 1 — per-layer solves into the shared arena, consuming the
        // uniform stream exactly like the per-layer path.
        let mut solve_span = crate::trace::span(crate::trace::Stage::Solve);
        solve_span.layer(layers.len() as u32);
        let mut off = 0usize;
        for (g, out) in layers.iter().zip(outs.iter_mut()) {
            let d = g.len();
            let pv = self.engine.probs(g);
            out.reset(d);
            out.shared_mag = pv.inv_lambda;
            pvs.push(pv);
            if d > 0 {
                self.p_all[off..off + d].copy_from_slice(&self.engine.probabilities()[..d]);
                rand.fill(&mut self.u_all[off..off + d]);
                // Same spacer draw as the single-tensor engine (stride
                // d + 1 through the cyclic array).
                let _ = rand.next();
            }
            off += d;
        }

        drop(solve_span);
        // Phase 2 — one sampling pass over every layer's chunks.
        let mut sample_span = crate::trace::span(crate::trace::Stage::Sample);
        sample_span.layer(layers.len() as u32);
        let (shard_len, parallel_min_d, max_threads) = self.engine.geometry();
        self.chunk_meta.clear();
        let mut goff = 0usize;
        for (l, g) in layers.iter().enumerate() {
            let d = g.len();
            let mut lo = 0usize;
            while lo < d {
                let hi = (lo + shard_len).min(d);
                self.chunk_meta.push(ChunkMeta { layer: l, lo, hi, goff });
                lo = hi;
            }
            goff += d;
        }
        let nchunks = self.chunk_meta.len();
        let threads = max_threads.min(nchunks.max(1));
        if total < parallel_min_d || threads <= 1 {
            // Sequential: chunk order == concatenated coordinate order.
            for meta in &self.chunk_meta {
                let g = layers[meta.layer];
                let a = meta.goff + meta.lo;
                let b = meta.goff + meta.hi;
                let out = &mut *outs[meta.layer];
                sample_chunk(
                    &g[meta.lo..meta.hi],
                    &self.p_all[a..b],
                    &self.u_all[a..b],
                    meta.lo as u32,
                    &mut out.exact,
                    &mut out.shared,
                );
            }
        } else {
            // Pooled: ONE dispatch for the whole layer list. Chunks are
            // pre-assigned to buffers by index, so scheduling freedom
            // cannot affect any output byte; concatenation below runs in
            // (layer, chunk) order, reproducing the sequential output.
            if self.shards.len() < nchunks {
                self.shards.resize_with(nchunks, ShardBuf::default);
            }
            let pool = self.pool.get_or_insert_with(|| ShardPool::new(max_threads));
            let per = nchunks.div_ceil(threads);
            let p_all = &self.p_all;
            let u_all = &self.u_all;
            let metas = &self.chunk_meta;
            let shards = &mut self.shards[..nchunks];
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                Vec::with_capacity(nchunks.div_ceil(per));
            for (group, metas_group) in shards.chunks_mut(per).zip(metas.chunks(per)) {
                jobs.push(Box::new(move || {
                    for (sh, meta) in group.iter_mut().zip(metas_group) {
                        sh.exact.clear();
                        sh.shared.clear();
                        let g = layers[meta.layer];
                        let a = meta.goff + meta.lo;
                        let b = meta.goff + meta.hi;
                        sample_chunk(
                            &g[meta.lo..meta.hi],
                            &p_all[a..b],
                            &u_all[a..b],
                            meta.lo as u32,
                            &mut sh.exact,
                            &mut sh.shared,
                        );
                    }
                }));
            }
            {
                let mut dispatch = crate::trace::span(crate::trace::Stage::ShardDispatch);
                dispatch.bytes(nchunks as u64);
                pool.run(jobs);
            }
            for (sh, meta) in self.shards[..nchunks].iter().zip(self.chunk_meta.iter()) {
                let out = &mut *outs[meta.layer];
                out.exact.extend_from_slice(&sh.exact);
                out.shared.extend_from_slice(&sh.shared);
            }
        }
    }

    /// The fully fused batched pass: per-layer solves → one sampling
    /// dispatch → one `WireBatch` encode, all into caller-held reusable
    /// buffers (`outs` is resized to the layer count; `wire` receives the
    /// encoded batch). No intermediate per-layer message is materialized
    /// between the sampler and the encoder.
    pub fn compress_batch_into(
        &mut self,
        layers: &[&[f32]],
        codec: WireCodec,
        rand: &mut RandArray,
        outs: &mut Vec<SparseGrad>,
        wire: &mut Vec<u8>,
        pvs: &mut Vec<ProbVector>,
    ) {
        if outs.len() < layers.len() {
            outs.resize_with(layers.len(), || SparseGrad::empty(0));
        }
        outs.truncate(layers.len());
        {
            let mut slots: Vec<&mut SparseGrad> = outs.iter_mut().collect();
            self.compress_batch_sparse_into(layers, rand, &mut slots, pvs);
        }
        let refs: Vec<&SparseGrad> = outs.iter().collect();
        coding::encode_batch(&refs, codec, wire);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsify::CompressEngine;

    fn layer_list(dims: &[usize], seed: u64) -> Vec<Vec<f32>> {
        dims.iter()
            .enumerate()
            .map(|(i, &d)| crate::benchkit::skewed_gradient(d, seed + i as u64, 0.1))
            .collect()
    }

    fn run_per_layer(
        mode: EngineMode,
        layers: &[Vec<f32>],
        seed: u64,
    ) -> (Vec<SparseGrad>, Vec<ProbVector>) {
        // The reference path: a fresh single-tensor engine per layer (as
        // the per-layer cluster keeps one compressor per layer), one
        // shared RandArray consumed in layer order.
        let mut rand = RandArray::from_seed(seed, 1 << 18);
        let mut outs = Vec::new();
        let mut pvs = Vec::new();
        for g in layers {
            let mut engine = CompressEngine::new(mode).with_sharding(1 << 10, usize::MAX, 1);
            let mut sg = SparseGrad::empty(0);
            pvs.push(engine.compress_sparse_into(g, &mut rand, &mut sg));
            outs.push(sg);
        }
        (outs, pvs)
    }

    #[test]
    fn batched_is_bitwise_identical_to_per_layer() {
        let dims = [5000usize, 0, 12_288, 700, 16_384];
        let layers = layer_list(&dims, 11);
        let refs: Vec<&[f32]> = layers.iter().map(|g| g.as_slice()).collect();
        for mode in [
            EngineMode::Greedy { rho: 0.05, iters: 2 },
            EngineMode::ClosedForm { eps: 0.5 },
        ] {
            let (want, want_pvs) = run_per_layer(mode, &layers, 0xBA7C);
            // Sequential batched path.
            let mut seq = BatchCompressEngine::new(mode).with_sharding(1 << 10, usize::MAX, 1);
            let mut rand = RandArray::from_seed(0xBA7C, 1 << 18);
            let mut outs = Vec::new();
            let mut pvs = Vec::new();
            let mut wire = Vec::new();
            seq.compress_batch_into(
                &refs,
                WireCodec::Raw,
                &mut rand,
                &mut outs,
                &mut wire,
                &mut pvs,
            );
            assert_eq!(outs, want, "sequential batched path drifted ({mode:?})");
            // Pooled batched path: small chunks, several threads, forced on.
            let mut par = BatchCompressEngine::new(mode).with_sharding(1 << 10, 1, 4);
            let mut rand = RandArray::from_seed(0xBA7C, 1 << 18);
            let mut outs_p = Vec::new();
            let mut pvs_p = Vec::new();
            let mut wire_p = Vec::new();
            par.compress_batch_into(
                &refs,
                WireCodec::Raw,
                &mut rand,
                &mut outs_p,
                &mut wire_p,
                &mut pvs_p,
            );
            assert_eq!(outs_p, want, "pooled batched path drifted ({mode:?})");
            assert_eq!(wire, wire_p, "wire bytes differ between pooled and sequential");
            for (a, b) in pvs.iter().zip(&want_pvs) {
                assert_eq!(a.num_exact, b.num_exact);
                assert_eq!(a.inv_lambda, b.inv_lambda);
            }
            // And the batch decodes back to the same layers.
            let mut back = Vec::new();
            let mut lens = Vec::new();
            coding::decode_batch_into(&wire, &mut back, &mut lens).unwrap();
            assert_eq!(back, want);
        }
    }

    #[test]
    fn fused_entropy_batch_matches_separate_encode() {
        let dims = [1 << 14, 1 << 13];
        let layers = layer_list(&dims, 23);
        let refs: Vec<&[f32]> = layers.iter().map(|g| g.as_slice()).collect();
        let mut engine = BatchCompressEngine::greedy(0.02, 2).with_sharding(1 << 12, usize::MAX, 1);
        let mut rand = RandArray::from_seed(99, 1 << 18);
        let mut outs = Vec::new();
        let mut pvs = Vec::new();
        let mut wire = Vec::new();
        engine.compress_batch_into(
            &refs,
            WireCodec::Entropy,
            &mut rand,
            &mut outs,
            &mut wire,
            &mut pvs,
        );
        let sg_refs: Vec<&SparseGrad> = outs.iter().collect();
        let mut expect = Vec::new();
        coding::encode_batch(&sg_refs, WireCodec::Entropy, &mut expect);
        assert_eq!(wire, expect);
        assert!(wire.len() < dims.iter().sum::<usize>()); // sanity: sparse
    }

    #[test]
    fn empty_layer_list_is_a_valid_batch() {
        let mut engine = BatchCompressEngine::greedy(0.1, 2);
        let mut rand = RandArray::from_seed(1, 1 << 10);
        let mut outs = vec![SparseGrad::empty(5)]; // stale slot must be dropped
        let mut pvs = Vec::new();
        let mut wire = Vec::new();
        engine.compress_batch_into(&[], WireCodec::Raw, &mut rand, &mut outs, &mut wire, &mut pvs);
        assert!(outs.is_empty());
        assert!(pvs.is_empty());
        let mut back = Vec::new();
        let mut lens = Vec::new();
        coding::decode_batch_into(&wire, &mut back, &mut lens).unwrap();
        assert!(back.is_empty());
    }
}
