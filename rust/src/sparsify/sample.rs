//! Bernoulli coordinate selection + unbiased rescaling (`Q(g)_i = Z_i g_i /
//! p_i`), producing the split [`SparseGrad`] representation the §3.3 hybrid
//! coder transmits.

use super::SparseGrad;
use crate::rngkit::RandArray;

/// Sample a sparsified gradient given the probability vector `p` (in the
/// Proposition-1 form, i.e. `p_i = min(|g_i|/inv_lambda, 1)`).
///
/// * Coordinates with `p_i == 1` go to [`SparseGrad::exact`] with their true
///   value (`g_i / 1`).
/// * Coordinates with `0 < p_i < 1` survive a Bernoulli(`p_i`) draw from the
///   pre-generated uniform array; survivors carry only index + sign because
///   the rescaled value `g_i / p_i = sign(g_i) · inv_lambda` is shared.
///
/// One engineering trick from §5.3 is applied verbatim: no floating-point
/// division happens per coordinate — the shared magnitude is `inv_lambda`
/// computed once by the probability solver.
pub fn sample_sparse(
    g: &[f32],
    p: &[f32],
    inv_lambda: f32,
    rand: &mut RandArray,
) -> SparseGrad {
    let mut out = SparseGrad::empty(g.len());
    sample_sparse_into(g, p, inv_lambda, rand, &mut out);
    out
}

/// [`sample_sparse`] into a caller-provided [`SparseGrad`], reusing its
/// buffers — the allocation-free form the compressors use every round. Draw
/// consumption is unchanged: one uniform per coordinate with `0 < p_i < 1`.
pub fn sample_sparse_into(
    g: &[f32],
    p: &[f32],
    inv_lambda: f32,
    rand: &mut RandArray,
    out: &mut SparseGrad,
) {
    assert_eq!(g.len(), p.len());
    out.reset(g.len());
    out.shared_mag = inv_lambda;
    for i in 0..g.len() {
        let pi = p[i];
        if pi <= 0.0 {
            continue;
        }
        if pi >= 1.0 {
            out.exact.push((i as u32, g[i]));
        } else if rand.next() < pi {
            out.shared.push((i as u32, g[i] < 0.0));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsify::probs::{closed_form_probs, greedy_probs};

    fn gradient(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::rngkit::Xoshiro256pp::seed_from_u64(seed);
        (0..d)
            .map(|_| {
                let u = rng.next_f32();
                if u < 0.08 {
                    (rng.next_gaussian() * 4.0) as f32
                } else if u < 0.2 {
                    0.0
                } else {
                    (rng.next_gaussian() * 0.03) as f32
                }
            })
            .collect()
    }

    #[test]
    fn unbiasedness_monte_carlo() {
        // E[Q(g)] = g — the paper's central claim about Q.
        let d = 64;
        let g = gradient(d, 10);
        let mut p = Vec::new();
        let pv = greedy_probs(&g, 0.3, 2, &mut p);
        let mut ra = RandArray::from_seed(99, 1 << 22);
        let trials = 20_000;
        let mut mean = vec![0.0f64; d];
        for _ in 0..trials {
            let sg = sample_sparse(&g, &p, pv.inv_lambda, &mut ra);
            for &(i, v) in &sg.exact {
                mean[i as usize] += v as f64;
            }
            for &(i, neg) in &sg.shared {
                let v = if neg { -sg.shared_mag } else { sg.shared_mag };
                mean[i as usize] += v as f64;
            }
        }
        for m in mean.iter_mut() {
            *m /= trials as f64;
        }
        // Tolerance: 4 sigma of the MC estimate of each coordinate.
        for i in 0..d {
            let pi = p[i] as f64;
            if pi == 0.0 {
                assert_eq!(mean[i], 0.0);
                continue;
            }
            let gi = g[i] as f64;
            let var = gi * gi * (1.0 - pi) / pi;
            let tol = 4.0 * (var / trials as f64).sqrt() + 1e-9;
            assert!(
                (mean[i] - gi).abs() <= tol,
                "coord {i}: mean {} vs g {} (tol {tol})",
                mean[i],
                gi
            );
        }
    }

    #[test]
    fn realized_variance_matches_bound() {
        // E||Q(g)||² should match Σ g_i²/p_i (Prop. 1's objective) closely.
        let d = 128;
        let g = gradient(d, 11);
        let mut p = Vec::new();
        let pv = closed_form_probs(&g, 0.8, &mut p);
        let mut ra = RandArray::from_seed(7, 1 << 22);
        let trials = 20_000;
        let mut sum_sq = 0.0f64;
        for _ in 0..trials {
            let sg = sample_sparse(&g, &p, pv.inv_lambda, &mut ra);
            sum_sq += sg.norm2_sq();
        }
        let measured = sum_sq / trials as f64;
        assert!(
            (measured - pv.variance).abs() / pv.variance < 0.05,
            "measured E||Q||² {measured} vs predicted {}",
            pv.variance
        );
    }

    #[test]
    fn realized_nnz_matches_expectation() {
        let d = 256;
        let g = gradient(d, 12);
        let mut p = Vec::new();
        let pv = greedy_probs(&g, 0.15, 2, &mut p);
        let mut ra = RandArray::from_seed(8, 1 << 22);
        let trials = 5_000;
        let mut total = 0usize;
        for _ in 0..trials {
            total += sample_sparse(&g, &p, pv.inv_lambda, &mut ra).nnz();
        }
        let measured = total as f64 / trials as f64;
        assert!(
            (measured - pv.expected_nnz).abs() / pv.expected_nnz < 0.05,
            "measured nnz {measured} vs expected {}",
            pv.expected_nnz
        );
    }

    #[test]
    fn exact_coords_always_survive() {
        // The closed form puts the dominating set S_k at exactly p = 1, so
        // those coordinates must appear in every sample. (Greedy approaches
        // p = 1 geometrically and may leave them in the shared part.)
        let g = vec![10.0, -0.01, 0.02, -10.0];
        let mut p = Vec::new();
        // Tight variance budget forces the two big coordinates into S_k.
        let pv = closed_form_probs(&g, 0.001, &mut p);
        assert!(pv.num_exact >= 2, "big coords should dominate: {p:?}");
        let mut ra = RandArray::from_seed(9, 4096);
        for _ in 0..100 {
            let sg = sample_sparse(&g, &p, pv.inv_lambda, &mut ra);
            let exact_idx: Vec<u32> = sg.exact.iter().map(|&(i, _)| i).collect();
            assert!(exact_idx.contains(&0));
            assert!(exact_idx.contains(&3));
        }
    }

    #[test]
    fn shared_survivors_decode_with_correct_sign() {
        let g = vec![0.01, -0.01, 0.02, -0.02, 0.03, -0.03];
        let mut p = Vec::new();
        let pv = greedy_probs(&g, 0.5, 2, &mut p);
        let mut ra = RandArray::from_seed(10, 4096);
        for _ in 0..200 {
            let sg = sample_sparse(&g, &p, pv.inv_lambda, &mut ra);
            let dense = sg.to_dense();
            for (i, &v) in dense.iter().enumerate() {
                if v != 0.0 {
                    assert_eq!(v.signum(), g[i].signum(), "sign flip at {i}");
                }
            }
        }
    }

    #[test]
    fn property_unbiased_small_dims() {
        crate::proptest_lite::run("sampling is sign/zero-consistent", 48, |gen| {
            let d = gen.usize_in(1, 200);
            let g = gen.gradient_vec(d);
            let rho = gen.f32_in(0.05, 1.0);
            let mut p = Vec::new();
            let pv = greedy_probs(&g, rho, 2, &mut p);
            let mut ra = RandArray::new(
                crate::rngkit::Xoshiro256pp::seed_from_u64(gen.u64()),
                1 << 14,
            );
            let sg = sample_sparse(&g, &p, pv.inv_lambda, &mut ra);
            if sg.nnz() > d {
                return Err(format!("nnz {} > d {d}", sg.nnz()));
            }
            let dense = sg.to_dense();
            for i in 0..d {
                if g[i] == 0.0 && dense[i] != 0.0 {
                    return Err(format!("zero coord {i} decoded non-zero"));
                }
                if dense[i] != 0.0 && dense[i].signum() != g[i].signum() {
                    return Err(format!("sign flip at {i}"));
                }
            }
            // Indices strictly ascending in both parts.
            if sg.exact.windows(2).any(|w| w[0].0 >= w[1].0)
                || sg.shared.windows(2).any(|w| w[0].0 >= w[1].0)
            {
                return Err("indices not strictly ascending".into());
            }
            Ok(())
        });
    }
}
