//! Seeded property-testing mini-framework (proptest is unavailable in the
//! offline registry — see DESIGN.md §Substitutions).
//!
//! A property is a closure over a [`Gen`] that either returns `Ok(())` or an
//! `Err(String)` describing the violated invariant. [`run`] executes it for
//! `cases` independent seeds and reports the first failing seed so failures
//! reproduce deterministically:
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla_extension rpath on this image)
//! use gsparse::proptest_lite::{run, Gen};
//! run("abs is non-negative", 256, |g: &mut Gen| {
//!     let x = g.f32_in(-10.0, 10.0);
//!     if x.abs() >= 0.0 { Ok(()) } else { Err(format!("abs({x}) < 0")) }
//! });
//! ```

use crate::rngkit::Xoshiro256pp;

/// Random input generator handed to each property case.
pub struct Gen {
    rng: Xoshiro256pp,
    /// Case index (0..cases), usable for size scaling.
    pub case: usize,
}

impl Gen {
    pub fn new(seed: u64, case: usize) -> Self {
        Self {
            rng: Xoshiro256pp::seed_from_u64(seed),
            case,
        }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.rng.next_below((hi - lo) as u64) as usize
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// A random gradient-like vector: mixture of large and small magnitudes
    /// with a controllable fraction of exact zeros — the shape the paper's
    /// (ρ,s)-approximate-sparsity analysis cares about.
    pub fn gradient_vec(&mut self, d: usize) -> Vec<f32> {
        let p_zero = self.f32_in(0.0, 0.5);
        let p_big = self.f32_in(0.01, 0.3);
        (0..d)
            .map(|_| {
                let u = self.rng.next_f32();
                if u < p_zero {
                    0.0
                } else if u < p_zero + p_big {
                    (self.rng.next_gaussian() * 10.0) as f32
                } else {
                    (self.rng.next_gaussian() * 0.05) as f32
                }
            })
            .collect()
    }

    /// Access the raw RNG for ad-hoc draws.
    pub fn rng(&mut self) -> &mut Xoshiro256pp {
        &mut self.rng
    }
}

/// Run `prop` for `cases` seeds; panic with the failing seed + message on the
/// first violation.
pub fn run<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    // Fixed base so CI runs are reproducible; override with GSPARSE_PT_SEED.
    let base: u64 = std::env::var("GSPARSE_PT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_CAFE);
    for case in 0..cases {
        let seed = base.wrapping_add((case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut g = Gen::new(seed, case);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case} (seed 0x{seed:x}):\n  {msg}\n\
                 reproduce with GSPARSE_PT_SEED={base} and case index {case}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        run("trivial", 64, |g| {
            let x = g.f32_in(0.0, 1.0);
            if (0.0..=1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'must-fail'")]
    fn failing_property_panics_with_seed() {
        run("must-fail", 16, |g| {
            let x = g.usize_in(0, 10);
            if x < 9 {
                Ok(())
            } else {
                Err("hit 9".into())
            }
        });
    }

    #[test]
    fn gradient_vec_has_requested_len() {
        run("gradient_vec len", 16, |g| {
            let d = g.usize_in(1, 300);
            let v = g.gradient_vec(d);
            if v.len() == d {
                Ok(())
            } else {
                Err(format!("len {} != {d}", v.len()))
            }
        });
    }
}
