//! `gsparse` — leader entrypoint + CLI.
//!
//! Subcommands:
//! * `fig <1-9|theory|all> [--paper]` — regenerate a paper figure's series
//!   (quick scale by default; `--paper` uses the paper's exact N/d/epochs);
//! * `train [--method ...] [--rho ...] ...` — one synchronous convex run;
//! * `async-svm [--threads ...] [--scheme ...]` — one Algorithm-4 run;
//! * `e2e` — the transformer end-to-end driver (same code as the example);
//! * `version`.

use gsparse::cli::Args;
use gsparse::config::{AsyncSvmConfig, ConvexConfig, Method, UpdateScheme};
use gsparse::coordinator::sync::{estimate_f_star, train_convex, OptKind, TrainOptions};
use gsparse::coordinator::AsyncSvmEngine;
use gsparse::data::{gen_logistic, gen_svm};
use gsparse::metrics::{ascii_plot, XAxis};
use gsparse::model::LogisticModel;

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand.as_deref() {
        Some("fig") => cmd_fig(&args),
        Some("train") => cmd_train(&args),
        Some("async-svm") => cmd_async(&args),
        Some("e2e") => cmd_e2e(&args),
        Some("version") => {
            println!("gsparse {}", gsparse::VERSION);
            Ok(())
        }
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "gsparse {} — Gradient Sparsification (Wangni et al., NeurIPS 2018)\n\
         \n\
         USAGE: gsparse <SUBCOMMAND> [OPTIONS]\n\
         \n\
         SUBCOMMANDS:\n\
           fig <1-9|theory|all> [--paper]   regenerate a paper figure\n\
           train [--method M] [--rho R] [--epochs E] [--svrg] ...\n\
           async-svm [--threads T] [--scheme lock|atomic|wild] [--method M]\n\
           e2e [--steps N] [--workers M] [--rho R]   transformer end-to-end\n\
           version",
        gsparse::VERSION
    );
}

fn cmd_fig(args: &Args) -> anyhow::Result<()> {
    let which = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    gsparse::figures::run(which, !args.flag("paper"))
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let mut cfg = ConvexConfig::default();
    cfg.n = args.get_parse("n", cfg.n);
    cfg.d = args.get_parse("d", cfg.d);
    cfg.c1 = args.get_parse("c1", cfg.c1);
    cfg.c2 = args.get_parse("c2", cfg.c2);
    cfg.rho = args.get_parse("rho", cfg.rho);
    cfg.workers = args.get_parse("workers", cfg.workers);
    cfg.epochs = args.get_parse("epochs", cfg.epochs);
    cfg.lr = args.get_parse("lr", cfg.lr);
    cfg.seed = args.get_parse("seed", cfg.seed);
    cfg.reg = args.get_parse("reg", 1.0 / (10.0 * cfg.n as f32));
    if let Some(m) = args.get("method") {
        cfg.method = Method::parse(m).ok_or_else(|| anyhow::anyhow!("unknown method {m}"))?;
    }
    let ds = gen_logistic(cfg.n, cfg.d, cfg.c1, cfg.c2, cfg.seed);
    let model = LogisticModel::new(cfg.reg);
    let f_star = estimate_f_star(&ds, &model, 400, 1.0);
    let opts = TrainOptions {
        opt: if args.flag("svrg") {
            OptKind::Svrg(gsparse::coordinator::sync::SvrgVariant::SparsifyFull)
        } else {
            OptKind::Sgd
        },
        f_star,
        ..Default::default()
    };
    let curve = train_convex(&cfg, &opts, &ds, &model);
    println!("{}", curve.label());
    println!(
        "final suboptimality {:.4e}; {:.3e} ideal bits; {:.3e} wire bytes; sim net {:.1} ms",
        curve.final_loss(),
        curve.ledger.ideal_bits as f64,
        curve.ledger.wire_bytes as f64,
        curve.points.last().map(|p| p.wall_ms).unwrap_or(0.0),
    );
    print!("{}", ascii_plot(&[curve], 72, 14, XAxis::DataPasses));
    Ok(())
}

fn cmd_async(args: &Args) -> anyhow::Result<()> {
    let mut cfg = AsyncSvmConfig::default();
    cfg.n = args.get_parse("n", 8192);
    cfg.threads = args.get_parse("threads", cfg.threads);
    cfg.reg = args.get_parse("reg", cfg.reg);
    cfg.rho = args.get_parse("rho", cfg.rho);
    cfg.lr = args.get_parse("lr", cfg.lr);
    cfg.total_steps = args.get_parse("steps", 50_000);
    cfg.seed = args.get_parse("seed", cfg.seed);
    if let Some(s) = args.get("scheme") {
        cfg.scheme =
            UpdateScheme::parse(s).ok_or_else(|| anyhow::anyhow!("unknown scheme {s}"))?;
    }
    if let Some(m) = args.get("method") {
        cfg.method = Method::parse(m).ok_or_else(|| anyhow::anyhow!("unknown method {m}"))?;
    }
    let ds = gen_svm(cfg.n, cfg.d, cfg.c1, cfg.c2, cfg.seed);
    let report = AsyncSvmEngine::new(cfg).run(&ds);
    println!(
        "{}: final loss {:.5} in {:.1} ms ({} coordinate updates, {} conflicts)",
        report.curve.name, report.final_loss, report.wall_ms, report.updates, report.conflicts
    );
    print!("{}", ascii_plot(&[report.curve], 72, 12, XAxis::WallMs));
    Ok(())
}

fn cmd_e2e(args: &Args) -> anyhow::Result<()> {
    let steps = args.get_parse("steps", 200usize);
    let workers = args.get_parse("workers", 4usize);
    let rho = args.get_parse("rho", 0.05f32);
    gsparse::figures::run_transformer_e2e(steps, workers, rho)
}
