//! `gsparse` — leader entrypoint + CLI.
//!
//! Subcommands:
//! * `fig <1-9|theory|all> [--paper]` — regenerate a paper figure's series
//!   (quick scale by default; `--paper` uses the paper's exact N/d/epochs);
//! * `train [--method ...] [--rho ...] ...` — one synchronous convex run;
//! * `async-svm [--threads ...] [--scheme ...]` — one Algorithm-4 run;
//! * `e2e` — the transformer end-to-end driver (same code as the example);
//! * `server` / `worker` — one role of the real multi-process parameter
//!   server (TCP; workers receive the full config from the server);
//! * `dist` — launch a whole loopback cluster from one command (threads by
//!   default, `--procs` spawns genuine worker processes);
//! * `trace-merge` — merge per-role trace dumps into one causal timeline
//!   (clock-aligned, with flow arrows linking `frame_tx` → `frame_rx`);
//! * `version`.

use gsparse::api::{DistTask, MethodSpec, Session, SyncTask};
use gsparse::cli::Args;
use gsparse::coding::WireCodec;
use gsparse::config::{AsyncSvmConfig, Method, UpdateScheme};
use gsparse::coordinator::sync::{estimate_f_star, OptKind};
use gsparse::coordinator::AsyncSvmEngine;
use gsparse::data::{gen_logistic, gen_svm};
use gsparse::metrics::{ascii_plot, XAxis};
use gsparse::model::LogisticModel;
use gsparse::transport::{Hello, InProcTransport, Listener, TcpTransport, Transport};

fn main() {
    let args = Args::from_env();
    apply_trace_args(&args);
    let result = match args.subcommand.as_deref() {
        Some("fig") => cmd_fig(&args),
        Some("train") => cmd_train(&args),
        Some("async-svm") => cmd_async(&args),
        Some("e2e") => cmd_e2e(&args),
        Some("server") => cmd_server(&args),
        Some("worker") => cmd_worker(&args),
        Some("dist") => cmd_dist(&args),
        Some("trace-merge") => cmd_trace_merge(&args),
        Some("version") => {
            println!("gsparse {}", gsparse::VERSION);
            Ok(())
        }
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// `--trace-out STEM` / `--trace json|jsonl|off`: the CLI spellings of the
/// `GSPARSE_TRACE_OUT` / `GSPARSE_TRACE` environment switches (see
/// [`gsparse::trace`]). Applied before any session is built so the flags
/// flow into every coordinator — including, via the CONFIG frame and the
/// inherited environment, `dist --procs` worker processes, whose per-role
/// dumps merge with the server's by worker id.
fn apply_trace_args(args: &Args) {
    if let Some(mode) = args.get("trace") {
        std::env::set_var("GSPARSE_TRACE", mode);
    }
    if let Some(stem) = args.get("trace-out") {
        std::env::set_var("GSPARSE_TRACE_OUT", stem);
        // Dumping implies recording unless the caller pinned a mode.
        if std::env::var("GSPARSE_TRACE").map(|v| v.is_empty()).unwrap_or(true) {
            std::env::set_var("GSPARSE_TRACE", "json");
        }
    }
    // `--metrics-addr H:P` → the `/metrics` responder bind address, via the
    // same environment seam the server coordinator reads (only the serving
    // role binds it; worker processes just export into their registries).
    if let Some(addr) = args.get("metrics-addr") {
        std::env::set_var(gsparse::telemetry::METRICS_ADDR_ENV, addr);
    }
}

fn print_help() {
    println!(
        "gsparse {} — Gradient Sparsification (Wangni et al., NeurIPS 2018)\n\
         \n\
         USAGE: gsparse <SUBCOMMAND> [OPTIONS]\n\
         \n\
         SUBCOMMANDS:\n\
           fig <1-9|theory|all> [--paper] [--batch-layers]   regenerate a paper figure\n\
           train [--method M] [--rho R] [--epochs E] [--codec raw|entropy] [--svrg]\n\
                 [--feedback] [--feedback-decay B] [--local-steps H] ...\n\
           async-svm [--threads T] [--scheme lock|atomic|wild] [--method M]\n\
           e2e [--steps N] [--workers M] [--rho R] [--batch-layers]   transformer end-to-end\n\
           server [--addr H:P] [--workers M] [--rounds R] [--codec C]\n\
                  [--feedback] [--local-steps H] [--pipeline D]\n\
                  [--topology star|ring] [--aligned] ...\n\
           worker --addr H:P --id N [--codec C]   one worker process (config from server)\n\
           dist [--transport inproc|tcp] [--procs] [--codec raw|entropy]\n\
                [--feedback] [--feedback-decay B] [--local-steps H] [--pipeline D]\n\
                [--topology star|ring] [--aligned] ...\n\
           trace-merge FILE... [--clock FILE] [--out FILE]   merge per-role dumps into\n\
                one clock-aligned causal timeline with tx->rx flow arrows\n\
           version\n\
         \n\
         OBSERVABILITY (any subcommand):\n\
           --trace json|jsonl|off    record trace events (env: GSPARSE_TRACE)\n\
           --trace-out STEM          dump per-role traces STEM.r<rounds>.<topo>.<role>\n\
                                     .trace.json[l] at run end, plus the server's\n\
                                     STEM.r<rounds>.<topo>.clock.json offset sidecar\n\
                                     (env: GSPARSE_TRACE_OUT; implies --trace json)\n\
           --metrics-addr H:P        serve live Prometheus text on http://H:P/metrics\n\
                                     for the run's duration (env: GSPARSE_METRICS_ADDR)",
        gsparse::VERSION
    );
}

fn cmd_fig(args: &Args) -> anyhow::Result<()> {
    let which = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    gsparse::figures::run(which, !args.flag("paper"), args.flag("batch-layers"))
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let n: usize = args.get_parse("n", 1024);
    let d: usize = args.get_parse("d", 2048);
    let c1: f32 = args.get_parse("c1", 0.6);
    let c2: f32 = args.get_parse("c2", 0.25);
    let rho: f32 = args.get_parse("rho", 0.1);
    let reg: f32 = args.get_parse("reg", 1.0 / (10.0 * n as f32));
    let seed: u64 = args.get_parse("seed", 42);
    let mut method = Method::GSpar;
    if let Some(m) = args.get("method") {
        method = Method::parse(m).ok_or_else(|| anyhow::anyhow!("unknown method {m}"))?;
    }
    let local_steps: usize = args.get_parse("local-steps", 1);
    anyhow::ensure!(
        !(args.flag("svrg") && local_steps > 1),
        "--svrg cannot be combined with --local-steps > 1 (local-step scheduling is \
         not defined for the SVRG variants)"
    );
    let mut builder = Session::builder()
        .method(MethodSpec::from_parts(method, rho, c2 * c1, 4))
        .codec(parse_codec(args)?)
        .workers(args.get_parse("workers", 4))
        .local_steps(local_steps)
        .seed(seed);
    if let Some(cfg) = parse_feedback(args)? {
        builder = builder.feedback(cfg);
    }
    let session = builder.build();
    let ds = gen_logistic(n, d, c1, c2, seed);
    let model = LogisticModel::new(reg);
    let f_star = estimate_f_star(&ds, &model, 400, 1.0);
    let task = SyncTask {
        epochs: args.get_parse("epochs", 30),
        lr: args.get_parse("lr", 0.5),
        opt: if args.flag("svrg") {
            OptKind::Svrg(gsparse::coordinator::sync::SvrgVariant::SparsifyFull)
        } else {
            OptKind::Sgd
        },
        f_star,
        ..SyncTask::default()
    };
    let curve = session.train_convex(&task, &ds, &model);
    println!("{}", curve.label());
    println!(
        "final suboptimality {:.4e}; {:.3e} ideal bits; {:.3e} wire bytes; sim net {:.1} ms",
        curve.final_loss(),
        curve.ledger.ideal_bits as f64,
        curve.ledger.wire_bytes as f64,
        curve.points.last().map(|p| p.wall_ms).unwrap_or(0.0),
    );
    print!("{}", ascii_plot(&[curve], 72, 14, XAxis::DataPasses));
    Ok(())
}

fn cmd_async(args: &Args) -> anyhow::Result<()> {
    let mut cfg = AsyncSvmConfig::default();
    cfg.n = args.get_parse("n", 8192);
    cfg.threads = args.get_parse("threads", cfg.threads);
    cfg.reg = args.get_parse("reg", cfg.reg);
    cfg.rho = args.get_parse("rho", cfg.rho);
    cfg.lr = args.get_parse("lr", cfg.lr);
    cfg.total_steps = args.get_parse("steps", 50_000);
    cfg.seed = args.get_parse("seed", cfg.seed);
    if let Some(s) = args.get("scheme") {
        cfg.scheme =
            UpdateScheme::parse(s).ok_or_else(|| anyhow::anyhow!("unknown scheme {s}"))?;
    }
    if let Some(m) = args.get("method") {
        cfg.method = Method::parse(m).ok_or_else(|| anyhow::anyhow!("unknown method {m}"))?;
    }
    let ds = gen_svm(cfg.n, cfg.d, cfg.c1, cfg.c2, cfg.seed);
    let report = AsyncSvmEngine::new(cfg).run(&ds);
    println!(
        "{}: final loss {:.5} in {:.1} ms ({} coordinate updates, {} conflicts)",
        report.curve.name, report.final_loss, report.wall_ms, report.updates, report.conflicts
    );
    print!("{}", ascii_plot(&[report.curve], 72, 12, XAxis::WallMs));
    Ok(())
}

fn cmd_e2e(args: &Args) -> anyhow::Result<()> {
    let steps = args.get_parse("steps", 200usize);
    let workers = args.get_parse("workers", 4usize);
    let rho = args.get_parse("rho", 0.05f32);
    gsparse::figures::run_transformer_e2e(steps, workers, rho, args.flag("batch-layers"))
}

/// `--codec raw|entropy` (default raw).
fn parse_codec(args: &Args) -> anyhow::Result<WireCodec> {
    match args.get("codec") {
        None => Ok(WireCodec::Raw),
        Some(s) => {
            WireCodec::parse(s).ok_or_else(|| anyhow::anyhow!("unknown codec {s} (raw|entropy)"))
        }
    }
}

/// `--feedback` (optionally `--feedback-decay B`) → error-feedback config,
/// with the range checked here so bad input gets the CLI error path, not a
/// library assert.
fn parse_feedback(args: &Args) -> anyhow::Result<Option<gsparse::feedback::FeedbackConfig>> {
    if args.flag("feedback") || args.get("feedback-decay").is_some() {
        let decay: f32 = args.get_parse("feedback-decay", 1.0f32);
        anyhow::ensure!(
            (0.0..=1.0).contains(&decay),
            "--feedback-decay must be in [0, 1], got {decay}"
        );
        Ok(Some(gsparse::feedback::FeedbackConfig::with_decay(decay)))
    } else {
        Ok(None)
    }
}

/// Build the distributed-run session + task shared by `server` and `dist`
/// from CLI options (workers receive the compiled plan over the wire, so
/// `worker` takes only the handshake-negotiated `--codec`).
fn dist_session_from_args(args: &Args) -> anyhow::Result<(Session, DistTask)> {
    let mut task = DistTask::default();
    task.rounds = args.get_parse("rounds", task.rounds);
    task.batch = args.get_parse("batch", task.batch);
    task.lr = args.get_parse("lr", task.lr);
    task.n = args.get_parse("n", task.n);
    task.d = args.get_parse("d", task.d);
    task.c1 = args.get_parse("c1", task.c1);
    task.c2 = args.get_parse("c2", task.c2);
    task.reg = args.get_parse("reg", 1.0 / (10.0 * task.n as f32));
    let mut method = Method::GSpar;
    if let Some(m) = args.get("method") {
        method = Method::parse(m).ok_or_else(|| anyhow::anyhow!("unknown method {m}"))?;
    }
    let rho: f32 = args.get_parse("rho", 0.1);
    let qsgd_bits: u32 = args.get_parse("qsgd-bits", 4);
    let mut builder = Session::builder()
        .method(MethodSpec::from_parts(method, rho, task.c1 * task.c2, qsgd_bits))
        .codec(parse_codec(args)?)
        .workers(args.get_parse("workers", 2))
        .local_steps(args.get_parse("local-steps", 1))
        .pipeline(args.get_parse("pipeline", 1))
        .seed(args.get_parse("seed", 42));
    if let Some(t) = args.get("topology") {
        builder = builder.topology(match t {
            "star" => gsparse::comm::Topology::Star,
            "ring" => gsparse::comm::Topology::Ring,
            other => anyhow::bail!("unknown topology {other} (star|ring)"),
        });
    }
    if args.flag("aligned") {
        builder = builder.aligned_sparsity(true);
    }
    if let Some(cfg) = parse_feedback(args)? {
        builder = builder.feedback(cfg);
    }
    Ok((builder.build(), task))
}

fn print_dist_report(report: &gsparse::coordinator::DistReport) {
    println!("{}", report.curve.label());
    println!(
        "final loss {:.6}; versions {}; max staleness {}",
        report.final_loss, report.versions, report.max_observed_staleness
    );
    let ledger = &report.curve.ledger;
    let overhead = if ledger.wire_bytes > 0 {
        ledger.measured_bytes as f64 / ledger.wire_bytes as f64
    } else {
        f64::NAN
    };
    println!(
        "bytes: wire {} (raw {}, entropy {}), measured {} on the links ({overhead:.2}x \
         incl. weights+framing); ideal bits {} (wire/ideal {:.3}); sim net {:.1} ms",
        ledger.wire_bytes,
        ledger.wire_bytes_by_codec[WireCodec::Raw.index()],
        ledger.wire_bytes_by_codec[WireCodec::Entropy.index()],
        ledger.measured_bytes,
        ledger.ideal_bits,
        ledger.wire_bits_over_ideal(),
        report.sim_time_s * 1e3,
    );
    println!("gradient digest {:#018x}", report.grad_digest);
}

fn cmd_server(args: &Args) -> anyhow::Result<()> {
    let (session, task) = dist_session_from_args(args)?;
    let addr = args.get_or("addr", "127.0.0.1:0");
    let transport = TcpTransport::new();
    let mut listener = transport.listen(addr)?;
    println!(
        "gsparse server listening on {} — waiting for {} worker(s):",
        listener.local_addr(),
        session.workers()
    );
    for wid in 0..session.workers() {
        println!(
            "  {} worker --addr {} --id {wid} --codec {}",
            std::env::args().next().unwrap_or_else(|| "gsparse".into()),
            listener.local_addr(),
            session.codec()
        );
    }
    let report = session.dist_serve(listener.as_mut(), &task)?;
    print_dist_report(&report);
    Ok(())
}

fn cmd_worker(args: &Args) -> anyhow::Result<()> {
    let addr = args
        .get("addr")
        .ok_or_else(|| anyhow::anyhow!("worker requires --addr host:port"))?;
    let id: u32 = args.get_parse("id", u32::MAX);
    anyhow::ensure!(id != u32::MAX, "worker requires --id N");
    let codec = parse_codec(args)?;
    let transport = TcpTransport::new();
    let hello = Hello::with_codec(id, codec);
    let mut conn = transport.connect(addr, &hello)?;
    // The ring environment is only used if the server-shipped config asks
    // for ring topology; an ephemeral loopback port serves any TCP worker.
    gsparse::coordinator::dist::run_worker(
        conn.as_mut(),
        id,
        codec,
        hello.version,
        Some((&transport, "127.0.0.1:0")),
    )
}

/// `trace-merge A.trace.json B.trace.json ... [--clock STEM.clock.json]
/// [--out merged.trace.json]`: align per-role dumps onto the server clock
/// and link `frame_tx` → `frame_rx` pairs with Chrome flow arrows.
fn cmd_trace_merge(args: &Args) -> anyhow::Result<()> {
    anyhow::ensure!(
        !args.positional.is_empty(),
        "trace-merge requires at least one <stem>.<tag>.<role>.trace.json file"
    );
    let files: Vec<std::path::PathBuf> =
        args.positional.iter().map(std::path::PathBuf::from).collect();
    let clock = args.get("clock").map(std::path::Path::new);
    let report = gsparse::telemetry::merge::merge_files(&files, clock)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let out = args.get_or("out", "merged.trace.json");
    std::fs::write(out, &report.json)?;
    println!(
        "merged {} role dump(s) -> {out}: {} flow(s) linked, {} unmatched",
        files.len(),
        report.flows_linked,
        report.flows_unmatched
    );
    if report.flows_linked > 0 {
        println!("min tx->rx latency {:.1} us", report.min_flow_latency_us);
    }
    for (role, shift) in &report.role_shift_us {
        println!("  {role}: shifted {shift:+.1} us onto the server clock");
    }
    Ok(())
}

fn cmd_dist(args: &Args) -> anyhow::Result<()> {
    let (session, task) = dist_session_from_args(args)?;
    let backend = args.get_or("transport", "inproc");
    let report = if args.flag("procs") {
        let bin = std::env::current_exe()?;
        println!(
            "launching 1 server + {} worker processes over loopback TCP...",
            session.workers()
        );
        session.dist_processes(&bin, "127.0.0.1:0", &task)?
    } else {
        match backend {
            "inproc" => session.dist_threads(InProcTransport::new(), "dist", &task)?,
            "tcp" => session.dist_threads(TcpTransport::new(), "127.0.0.1:0", &task)?,
            other => anyhow::bail!("unknown transport {other} (inproc|tcp)"),
        }
    };
    print_dist_report(&report);
    print!(
        "{}",
        ascii_plot(&[report.curve], 72, 12, XAxis::DataPasses)
    );
    Ok(())
}
