//! # gsparse
//!
//! A Rust + JAX + Pallas reproduction of **"Gradient Sparsification for
//! Communication-Efficient Distributed Optimization"** (Wangni, Wang, Liu,
//! Zhang — NeurIPS 2018).
//!
//! The library sparsifies stochastic gradients *unbiasedly* — coordinate `i`
//! survives with probability `p_i` and is amplified to `g_i / p_i` — choosing
//! `p` to minimize expected coding length under a variance budget
//! (`p_i = min(λ|g_i|, 1)`, Proposition 1). On top of that primitive it
//! provides the full training system the paper evaluates:
//!
//! * [`api`] — the unified front door: a typed [`api::MethodSpec`] and one
//!   [`api::Session`] (method, codec, seed, topology, batching) consumed by
//!   every coordinator;
//! * [`sparsify`] — the optimal sparsifiers (closed-form Algorithm 2, greedy
//!   Algorithm 3) and every baseline (uniform, QSGD, TernGrad, top-k, 1-bit);
//! * [`feedback`] — error-feedback residual memory ([`feedback::WithFeedback`]
//!   around any compressor) and local-step scheduling
//!   ([`feedback::CommSchedule`]) for the biased/aggressive regimes;
//! * [`coding`] — the §3.3 hybrid wire format and Theorem-4 bit accounting;
//! * [`comm`] — the α-β cost model plus the sparse merge kernels;
//! * [`collective`] — ring reduce-scatter / all-gather of sparse gradient
//!   messages over the transport, with per-hop re-sparsification and an
//!   aligned-sparsity (shared-sketch, index-free) mode;
//! * [`transport`] — the real one: a pluggable framed transport (`InProc`
//!   channels / TCP sockets) with per-link byte counters, behind one trait;
//! * [`trace`] — low-overhead per-stage span recording (solve / sample /
//!   encode / send / apply …) with Chrome-trace + JSONL exporters and a
//!   metrics registry, threaded through every coordinator;
//! * [`opt`] — SGD / SVRG / Adam with the paper's variance-scaled step sizes;
//! * [`coordinator`] — synchronous data-parallel training (Algorithm 1), the
//!   SVRG master variant (eq. 15), and the §5.3 asynchronous shared-memory
//!   engine (Algorithm 4) with Lock/Atomic/Wild schemes;
//! * [`model`] + [`runtime`] — pure-Rust convex models and PJRT-loaded,
//!   JAX/Pallas-compiled CNN & transformer steps (`artifacts/*.hlo.txt`);
//! * [`data`] — the paper's synthetic generators plus CIFAR-like images and
//!   a tiny byte corpus;
//! * [`figures`] — one driver per paper figure (1–9) regenerating its series.
//!
//! Python (JAX + Pallas) runs only at build time (`make artifacts`); the
//! request path is pure Rust. See `DESIGN.md` for the architecture and
//! `EXPERIMENTS.md` for reproduction results.
//!
//! Correctness tooling: the repo-invariant lint pass lives in the sibling
//! `verifier` crate (`cargo run -p verifier`), and [`sync`] is the seam the
//! `--features model` exhaustive-interleaving checker swaps in under
//! `rust/tests/model.rs`. See README §Correctness tooling.

// Every `unsafe` operation must sit in an explicit `unsafe {}` block with
// its own `// SAFETY:` comment, even inside `unsafe fn` — enforced here and
// cross-checked by the verifier's `safety-comment` rule.
#![deny(unsafe_op_in_unsafe_fn)]
#![warn(clippy::undocumented_unsafe_blocks)]

pub mod api;
pub mod benchkit;
pub mod cli;
pub mod coding;
pub mod collective;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod feedback;
pub mod figures;
pub mod metrics;
pub mod model;
pub mod opt;
pub mod proptest_lite;
pub mod rngkit;
pub mod runtime;
pub mod sparsify;
pub mod sync;
pub mod telemetry;
pub mod tensor;
pub mod trace;
pub mod transport;

/// Crate version string (reported by the CLI).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
