//! Error-feedback memory and local-step scheduling — the two composition
//! primitives that unlock the aggressive-compression regimes the paper's
//! unbiased sparsifiers deliberately avoid.
//!
//! The paper keeps `E[Q(g)] = g` so plain SGD analysis applies, but the
//! related work shows the *biased* operating points (top-k at ρ ≪ 0.01,
//! sign compression, infrequent communication) converge at full SGD rates
//! **only** when the compression error is remembered and re-injected:
//! "The Convergence of Sparsified Gradient Methods" (Alistarh et al., 2018)
//! proves top-k + error memory matches SGD, and "Qsparse-local-SGD" (Basu
//! et al., 2019) composes sparsification with local steps *and* error
//! compensation. This module makes both first-class:
//!
//! * [`FeedbackState`] — a per-worker residual arena with a per-layer
//!   layout (one contiguous buffer, offsets per layer) and scratch-reuse
//!   discipline matching [`crate::sparsify::CompressEngine`]: after the
//!   layout stabilizes, a steady-state single-tensor step performs no heap
//!   allocation (pinned in `tests/alloc_free.rs`; the batched path allows
//!   itself one layer-count pointer list per call, like the batched
//!   cluster round).
//! * [`WithFeedback`] — an adapter wrapping **any**
//!   [`Compressor`](crate::sparsify::Compressor): each step compresses the
//!   error-corrected gradient `c = g + e` and accumulates the new residual
//!   `e ← β · (c − decode(compress(c)))`, where `β` is an optional
//!   momentum-style decay (`β = 1` is the classic error feedback of
//!   1Bit-SGD; `β < 1` forgets stale error, useful under non-stationarity).
//!   Works on the single-tensor *and* the batched multi-layer path
//!   ([`Compressor::compress_batch_into`](crate::sparsify::Compressor::compress_batch_into)),
//!   where the residual arena is laid out per layer so the fused
//!   `BatchCompressEngine`/`WireBatch` pipeline keeps its bitwise parity
//!   with the per-layer path.
//! * [`CommSchedule`] — every-round vs. every-`H`-rounds synchronization à
//!   la Qsparse-local-SGD. Coordinators built from a
//!   [`Session`](crate::api::Session) with
//!   [`local_steps(H)`](crate::api::SessionBuilder::local_steps) run `H`
//!   rounds per synchronization; non-communication rounds send **zero
//!   frames and zero bytes** (visible in the
//!   [`CommLedger`](crate::metrics::CommLedger) frame/byte counters and
//!   the transport link counters). The sync trainer and the PS/dist
//!   runtimes take true local gradient steps on per-worker iterates
//!   between synchronizations; the round-driven
//!   [`Cluster`](crate::coordinator::cluster::Cluster) — whose caller owns
//!   the model — accumulates gradients between synchronizations instead,
//!   and drivers that stop off-schedule flush the pending partial block
//!   via `Cluster::flush`.
//!
//! The historical [`OneBitSgd`](crate::sparsify::OneBitSgd) baseline is now
//! a plain sign compressor ([`crate::sparsify::SignCompressor`]) composed
//! with this subsystem — bitwise-identical to its former bespoke residual
//! loop (pinned by `tests/feedback.rs`).
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla_extension rpath on this image)
//! use gsparse::api::{MethodSpec, Session, SyncTask};
//! use gsparse::feedback::FeedbackConfig;
//!
//! // Biased top-k at ρ = 0.001 — divergent on its own, SGD-rate with
//! // error feedback — synchronizing every 4 rounds.
//! let session = Session::builder()
//!     .method(MethodSpec::TopK { rho: 0.001 })
//!     .feedback(FeedbackConfig::default())
//!     .local_steps(4)
//!     .build();
//! let ds = gsparse::data::gen_logistic(256, 2048, 0.6, 0.25, 7);
//! let model = gsparse::model::LogisticModel::new(1.0 / 2560.0);
//! let curve = session.train_convex(&SyncTask::default(), &ds, &model);
//! assert!(curve.final_loss().is_finite());
//! ```

use crate::rngkit::RandArray;
use crate::sparsify::{Compressed, CompressStats, Compressor};

/// Configuration of the error-feedback memory.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FeedbackConfig {
    /// Residual decay `β`: the carried error is `β · (c − decode(Q(c)))`.
    /// `1.0` (the default) is classic error feedback — no information is
    /// ever dropped; `β < 1` forgets stale error geometrically.
    pub decay: f32,
}

impl Default for FeedbackConfig {
    fn default() -> Self {
        Self { decay: 1.0 }
    }
}

impl FeedbackConfig {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_decay(decay: f32) -> Self {
        assert!(
            (0.0..=1.0).contains(&decay),
            "feedback decay must be in [0, 1], got {decay}"
        );
        Self { decay }
    }

    /// The toggle named by `GSPARSE_FEEDBACK` (unset/`off`/`0`/`false` →
    /// `None`) — how the shared test suites run once per leg of the CI
    /// feedback matrix, exactly like `WireCodec::from_env` serves the codec
    /// matrix.
    pub fn from_env() -> Option<Self> {
        match std::env::var("GSPARSE_FEEDBACK") {
            Err(_) => None,
            Ok(s) => match s.to_ascii_lowercase().as_str() {
                "" | "0" | "off" | "false" => None,
                "1" | "on" | "true" => Some(Self::default()),
                other => panic!("GSPARSE_FEEDBACK={other:?} is not a toggle (on|off)"),
            },
        }
    }
}

/// When workers synchronize: every round, or every `H` rounds with local
/// steps in between (Qsparse-local-SGD style). Rounds are 1-based; round
/// `t` communicates iff `t % H == 0` (coordinators with a known horizon
/// also flush on the final round so no tail gradient is lost).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CommSchedule {
    period: usize,
}

impl Default for CommSchedule {
    fn default() -> Self {
        Self::every_round()
    }
}

impl CommSchedule {
    /// Synchronize every round (`H = 1`) — the historical behavior.
    pub fn every_round() -> Self {
        Self { period: 1 }
    }

    /// Synchronize every `h` rounds (`h` is clamped to ≥ 1).
    pub fn every(h: usize) -> Self {
        Self { period: h.max(1) }
    }

    /// The local-step period `H`.
    pub fn period(self) -> usize {
        self.period
    }

    /// Whether 1-based round `round` is a communication round.
    pub fn is_comm_round(self, round: u64) -> bool {
        round % self.period as u64 == 0
    }

    /// Number of communication rounds (blocks) in `total_rounds` rounds,
    /// counting a trailing partial block.
    pub fn blocks(self, total_rounds: usize) -> usize {
        total_rounds.div_ceil(self.period)
    }

    /// Length of 0-based block `block` within `total_rounds` rounds: the
    /// full period except possibly for the trailing partial block.
    pub fn block_len(self, block: usize, total_rounds: usize) -> usize {
        let start = block * self.period;
        assert!(start < total_rounds, "block {block} out of range");
        self.period.min(total_rounds - start)
    }
}

impl std::fmt::Display for CommSchedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.period == 1 {
            f.write_str("every-round")
        } else {
            write!(f, "every-{}-rounds", self.period)
        }
    }
}

/// Per-worker residual arena with a per-layer layout.
///
/// One contiguous buffer holds every layer's residual (`offsets[l] ..
/// offsets[l + 1]` is layer `l`'s segment), mirroring the concatenated
/// arenas of [`crate::sparsify::BatchCompressEngine`], plus the corrected
/// (`c = g + e`) and decode scratch buffers. All buffers are reused across
/// steps; the arena only reallocates when the layer layout itself changes
/// (which also zeroes the residual — stale error from a different model
/// shape must not leak into a new one).
#[derive(Debug, Clone)]
pub struct FeedbackState {
    decay: f32,
    /// Layer offsets into the arenas; `offsets.len()` = layer count + 1.
    offsets: Vec<usize>,
    /// The residual `e`, concatenated per layer.
    residual: Vec<f32>,
    /// The corrected gradient `c = g + e` of the current step.
    corrected: Vec<f32>,
    /// Dense decode scratch (sized to the largest layer).
    decoded: Vec<f32>,
}

impl FeedbackState {
    pub fn new(cfg: FeedbackConfig) -> Self {
        Self {
            decay: cfg.decay,
            offsets: vec![0],
            residual: Vec::new(),
            corrected: Vec::new(),
            decoded: Vec::new(),
        }
    }

    pub fn decay(&self) -> f32 {
        self.decay
    }

    /// Number of layers in the current layout.
    pub fn layers(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Total residual dimension across all layers.
    pub fn total_dim(&self) -> usize {
        *self.offsets.last().expect("offsets never empty")
    }

    /// The whole residual arena (concatenated per-layer segments).
    pub fn residual(&self) -> &[f32] {
        &self.residual[..self.total_dim()]
    }

    /// Layer `l`'s residual segment.
    pub fn layer_residual(&self, l: usize) -> &[f32] {
        &self.residual[self.offsets[l]..self.offsets[l + 1]]
    }

    /// Mutable access to layer `l`'s residual segment — the fold-in point
    /// for the ring collective: per-hop re-sparsification adds its dropped
    /// mass here ([`crate::collective`]), and drains it back into the next
    /// round's outgoing message, so bounded hop budgets keep the top-k +
    /// error-feedback contraction instead of silently losing gradient.
    pub fn layer_residual_mut(&mut self, l: usize) -> &mut [f32] {
        &mut self.residual[self.offsets[l]..self.offsets[l + 1]]
    }

    /// `‖e‖²` over the whole arena (f64 accumulation).
    pub fn residual_norm2_sq(&self) -> f64 {
        self.residual()
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum()
    }

    fn layout_is(&self, dims: &[usize]) -> bool {
        self.offsets.len() == dims.len() + 1
            && dims
                .iter()
                .enumerate()
                .all(|(l, &d)| self.offsets[l + 1] - self.offsets[l] == d)
    }

    /// Adopt the layer layout `dims`, zeroing the residual if it changed
    /// (matching the historical 1Bit-SGD reset on a dimension change).
    pub fn ensure_layout(&mut self, dims: &[usize]) {
        if self.layout_is(dims) {
            return;
        }
        self.rebuild_layout(dims.iter().copied());
    }

    /// [`Self::ensure_layout`] straight from a layer list (no intermediate
    /// dimension vector, so the steady state allocates nothing).
    fn ensure_layout_for(&mut self, layers: &[&[f32]]) {
        let matches = self.offsets.len() == layers.len() + 1
            && layers
                .iter()
                .enumerate()
                .all(|(l, g)| self.offsets[l + 1] - self.offsets[l] == g.len());
        if matches {
            return;
        }
        self.rebuild_layout(layers.iter().map(|g| g.len()));
    }

    /// Rebuild offsets + arenas for a new layout; the residual starts from
    /// zero (stale error from a different model shape must not leak).
    fn rebuild_layout(&mut self, dims: impl Iterator<Item = usize>) {
        self.offsets.clear();
        self.offsets.push(0);
        let mut total = 0usize;
        let mut max_d = 0usize;
        for d in dims {
            total += d;
            max_d = max_d.max(d);
            self.offsets.push(total);
        }
        self.residual.clear();
        self.residual.resize(total, 0.0);
        self.corrected.clear();
        self.corrected.resize(total, 0.0);
        if self.decoded.len() < max_d {
            self.decoded.resize(max_d, 0.0);
        }
    }

    /// `corrected[l] = g + e[l]` — the error-corrected gradient the wrapped
    /// compressor sees.
    fn correct(&mut self, l: usize, g: &[f32]) {
        let lo = self.offsets[l];
        let hi = self.offsets[l + 1];
        assert_eq!(g.len(), hi - lo, "layer {l} gradient/layout mismatch");
        for i in 0..g.len() {
            self.corrected[lo + i] = g[i] + self.residual[lo + i];
        }
    }

    /// Layer `l`'s corrected gradient from the current step.
    fn corrected_layer(&self, l: usize) -> &[f32] {
        &self.corrected[self.offsets[l]..self.offsets[l + 1]]
    }

    /// Absorb the compression error of layer `l`:
    /// `e[l] ← decay · (c[l] − decode(msg))`.
    fn absorb(&mut self, l: usize, msg: &Compressed) {
        let lo = self.offsets[l];
        let hi = self.offsets[l + 1];
        let d = hi - lo;
        assert_eq!(msg.dim(), d, "layer {l} message/layout mismatch");
        if self.decoded.len() < d {
            self.decoded.resize(d, 0.0);
        }
        let dec = &mut self.decoded[..d];
        dec.fill(0.0);
        msg.add_into(1.0, dec);
        let decay = self.decay;
        for i in 0..d {
            self.residual[lo + i] = decay * (self.corrected[lo + i] - dec[i]);
        }
    }
}

/// Error-feedback adapter around any [`Compressor`]: compresses `c = g + e`
/// and carries `e ← β(c − decode(Q(c)))` to the next step. Per-step output
/// is whatever the inner compressor produces (so the wire path, the
/// batched `WireBatch` pipeline, and the ledger conventions all apply
/// unchanged); across steps the accumulated decoded signal tracks the
/// accumulated true signal — the invariant that makes biased compressors
/// converge.
///
/// One instance per worker (it carries the worker's residual). On the
/// batched path the residual arena is laid out per layer, so batched and
/// per-layer rounds stay bitwise interchangeable (see `tests/feedback.rs`).
#[derive(Debug)]
pub struct WithFeedback<C> {
    inner: C,
    state: FeedbackState,
}

impl<C: Compressor> WithFeedback<C> {
    /// Wrap with the default configuration (decay 1 — classic feedback).
    pub fn new(inner: C) -> Self {
        Self::with_config(inner, FeedbackConfig::default())
    }

    pub fn with_config(inner: C, cfg: FeedbackConfig) -> Self {
        Self {
            inner,
            state: FeedbackState::new(cfg),
        }
    }

    pub fn inner(&self) -> &C {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut C {
        &mut self.inner
    }

    /// The residual memory (read-only; tests assert bitwise determinism on
    /// it across backends).
    pub fn state(&self) -> &FeedbackState {
        &self.state
    }
}

impl<C: Compressor> Compressor for WithFeedback<C> {
    fn compress_into(
        &mut self,
        g: &[f32],
        rand: &mut RandArray,
        out: &mut Compressed,
    ) -> CompressStats {
        self.state.ensure_layout(&[g.len()]);
        let WithFeedback { inner, state } = self;
        state.correct(0, g);
        let stats = inner.compress_into(state.corrected_layer(0), rand, out);
        state.absorb(0, out);
        stats
    }

    fn compress_batch_into(
        &mut self,
        layers: &[&[f32]],
        rand: &mut RandArray,
        out: &mut Vec<Compressed>,
        stats: &mut Vec<CompressStats>,
    ) {
        let WithFeedback { inner, state } = self;
        state.ensure_layout_for(layers);
        for (l, g) in layers.iter().enumerate() {
            state.correct(l, g);
        }
        {
            // L pointers per call (one per *layer*, never per coordinate) —
            // the same small allowance the batched cluster round makes.
            let corrected: Vec<&[f32]> =
                (0..layers.len()).map(|l| state.corrected_layer(l)).collect();
            inner.compress_batch_into(&corrected, rand, out, stats);
        }
        for (l, msg) in out.iter().enumerate() {
            state.absorb(l, msg);
        }
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn residual_norm2_sq(&self) -> Option<f64> {
        Some(self.state.residual_norm2_sq())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsify::{GSparCompressor, SparseGrad, TopKCompressor};

    fn gradient(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::rngkit::Xoshiro256pp::seed_from_u64(seed);
        (0..d).map(|_| (rng.next_gaussian() * 0.3) as f32).collect()
    }

    #[test]
    fn schedule_arithmetic() {
        let every = CommSchedule::every_round();
        assert_eq!(every.period(), 1);
        assert!(every.is_comm_round(1) && every.is_comm_round(7));
        assert_eq!(every.blocks(10), 10);
        assert_eq!(every.to_string(), "every-round");

        let h4 = CommSchedule::every(4);
        assert_eq!(h4.period(), 4);
        assert!(!h4.is_comm_round(1));
        assert!(!h4.is_comm_round(3));
        assert!(h4.is_comm_round(4) && h4.is_comm_round(8));
        assert_eq!(h4.blocks(10), 3);
        assert_eq!(h4.block_len(0, 10), 4);
        assert_eq!(h4.block_len(1, 10), 4);
        assert_eq!(h4.block_len(2, 10), 2);
        assert_eq!(h4.to_string(), "every-4-rounds");

        // Clamped to ≥ 1.
        assert_eq!(CommSchedule::every(0).period(), 1);
    }

    #[test]
    fn feedback_config_decay_validation() {
        assert_eq!(FeedbackConfig::default().decay, 1.0);
        assert_eq!(FeedbackConfig::with_decay(0.5).decay, 0.5);
    }

    #[test]
    #[should_panic(expected = "feedback decay")]
    fn feedback_config_rejects_out_of_range_decay() {
        let _ = FeedbackConfig::with_decay(1.5);
    }

    #[test]
    fn state_layout_and_reset() {
        let mut st = FeedbackState::new(FeedbackConfig::default());
        st.ensure_layout(&[4, 2]);
        assert_eq!(st.layers(), 2);
        assert_eq!(st.total_dim(), 6);
        assert_eq!(st.layer_residual(0).len(), 4);
        assert_eq!(st.layer_residual(1).len(), 2);
        // Absorb something so the residual is non-zero…
        st.correct(1, &[1.0, -2.0]);
        st.absorb(1, &Compressed::Sparse(SparseGrad::empty(2)));
        assert!(st.residual_norm2_sq() > 0.0);
        // …same layout keeps it, a new layout zeroes it.
        st.ensure_layout(&[4, 2]);
        assert!(st.residual_norm2_sq() > 0.0);
        st.ensure_layout(&[3, 3]);
        assert_eq!(st.residual_norm2_sq(), 0.0);
        assert_eq!(st.total_dim(), 6);
    }

    #[test]
    fn no_error_leaks_over_many_steps_topk() {
        // The defining invariant: Σ_t decode(Q_t) + e_T = Σ_t g_t exactly
        // (up to float rounding) — the error never escapes the loop.
        let g = gradient(64, 11);
        let mut c = WithFeedback::new(TopKCompressor::new(0.05));
        let mut ra = RandArray::from_seed(12, 1 << 10);
        let steps = 400;
        let mut decoded_sum = vec![0.0f64; g.len()];
        for _ in 0..steps {
            let (out, _) = c.compress(&g, &mut ra);
            for (s, v) in decoded_sum.iter_mut().zip(out.to_dense()) {
                *s += v as f64;
            }
        }
        for i in 0..g.len() {
            let true_sum = g[i] as f64 * steps as f64;
            let leak = (decoded_sum[i] + c.state().residual()[i] as f64) - true_sum;
            assert!(
                leak.abs() < 2e-2 * steps as f64 * (g[i].abs() as f64).max(0.05),
                "coord {i}: leak {leak}"
            );
        }
    }

    #[test]
    fn decay_shrinks_the_residual() {
        let g = gradient(128, 21);
        let run = |decay: f32| {
            let mut c = WithFeedback::with_config(
                TopKCompressor::new(0.02),
                FeedbackConfig::with_decay(decay),
            );
            let mut ra = RandArray::from_seed(22, 1 << 10);
            let mut out = Compressed::Sparse(SparseGrad::empty(g.len()));
            for _ in 0..50 {
                c.compress_into(&g, &mut ra, &mut out);
            }
            c.state().residual_norm2_sq()
        };
        let full = run(1.0);
        let decayed = run(0.5);
        assert!(
            decayed < full,
            "decay 0.5 residual {decayed} should be below decay 1.0 residual {full}"
        );
    }

    #[test]
    fn batched_path_matches_per_layer_path_bitwise() {
        // One WithFeedback over a layer list (per-layer residual arena)
        // must produce exactly the messages of independent per-layer
        // WithFeedback instances consuming the same uniform stream in
        // layer order — the contract that keeps the batched cluster round
        // interchangeable with the per-layer one.
        let dims = [96usize, 40, 200];
        let layers: Vec<Vec<f32>> = dims
            .iter()
            .enumerate()
            .map(|(l, &d)| gradient(d, 30 + l as u64))
            .collect();
        let refs: Vec<&[f32]> = layers.iter().map(|g| g.as_slice()).collect();
        let steps = 5;

        // Batched: one adapter over the whole list.
        let mut batched = WithFeedback::new(GSparCompressor::greedy(0.1, 2));
        let mut rand_b = RandArray::from_seed(77, 1 << 16);
        let mut out_b: Vec<Compressed> = Vec::new();
        let mut stats_b: Vec<CompressStats> = Vec::new();

        // Per-layer: independent adapters, same stream consumed in order.
        let mut per_layer: Vec<WithFeedback<GSparCompressor>> = dims
            .iter()
            .map(|_| WithFeedback::new(GSparCompressor::greedy(0.1, 2)))
            .collect();
        let mut rand_l = rand_b.clone();
        let mut out_l: Vec<Compressed> = dims
            .iter()
            .map(|&d| Compressed::Sparse(SparseGrad::empty(d)))
            .collect();

        for step in 0..steps {
            batched.compress_batch_into(&refs, &mut rand_b, &mut out_b, &mut stats_b);
            for (l, g) in refs.iter().copied().enumerate() {
                per_layer[l].compress_into(g, &mut rand_l, &mut out_l[l]);
            }
            for l in 0..dims.len() {
                assert_eq!(
                    format!("{:?}", out_b[l]),
                    format!("{:?}", out_l[l]),
                    "step {step} layer {l}: messages diverged"
                );
                assert_eq!(
                    batched.state().layer_residual(l),
                    per_layer[l].state().residual(),
                    "step {step} layer {l}: residuals diverged"
                );
            }
        }
    }

    #[test]
    fn dense_inner_compressor_keeps_zero_residual() {
        // Lossless inner compressor ⇒ decode(Q(c)) = c ⇒ e stays 0.
        let g = gradient(32, 41);
        let mut c = WithFeedback::new(crate::sparsify::DenseCompressor);
        let mut ra = RandArray::from_seed(42, 1 << 8);
        let mut out = Compressed::Dense(Vec::new());
        for _ in 0..3 {
            c.compress_into(&g, &mut ra, &mut out);
        }
        assert_eq!(c.state().residual_norm2_sq(), 0.0);
        assert_eq!(out.to_dense(), g);
    }

    #[test]
    fn from_env_parses_toggles() {
        // Not set in the test environment by default; the explicit values
        // go through the same parser the CI matrix uses. (Avoid mutating
        // the process environment — other tests read it concurrently.)
        match std::env::var("GSPARSE_FEEDBACK") {
            Err(_) => assert!(FeedbackConfig::from_env().is_none()),
            Ok(_) => {
                let _ = FeedbackConfig::from_env(); // must not panic on CI values
            }
        }
    }
}
