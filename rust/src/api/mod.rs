//! The unified public API: one [`Session`] that owns every cross-coordinator
//! choice (compression method, wire codec, RNG seed, worker topology,
//! network model, layer batching) exactly once, and is consumed by **all
//! four** coordinators — the synchronous trainer, the SSP parameter server,
//! the threaded cluster, and the TCP distributed runtime.
//!
//! Before this module the same five knobs were duplicated across four
//! near-identical config structs (`TrainOptions`, `PsConfig`, `DistConfig`,
//! `Cluster::with_codec`) plus the positional
//! `sparsify::build(method, rho, eps, qsgd_bits)` factory, whose unlabeled
//! `f32` arguments were an accident waiting to happen. The replacement:
//!
//! * [`MethodSpec`] — a typed compressor specification: every method carries
//!   exactly the parameters it uses, by name (`MethodSpec::GSpar { rho,
//!   iters }`), so ρ cannot be passed where ε was meant;
//! * [`SessionBuilder`] → [`Session`] — the shared run context, built once:
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla_extension rpath on this image)
//! use gsparse::api::{MethodSpec, Session, SyncTask};
//! use gsparse::coding::WireCodec;
//!
//! let session = Session::builder()
//!     .method(MethodSpec::GSpar { rho: 0.1, iters: 2 })
//!     .codec(WireCodec::Entropy)
//!     .workers(4)
//!     .seed(2018)
//!     .build();
//! let ds = gsparse::data::gen_logistic(256, 512, 0.6, 0.25, 2018);
//! let model = gsparse::model::LogisticModel::new(1.0 / 2560.0);
//! let task = SyncTask { epochs: 2, ..SyncTask::default() };
//! let curve = session.train_convex(&task, &ds, &model);
//! assert!(curve.final_loss().is_finite());
//! ```
//!
//! The per-run knobs that are *not* shared across coordinators (epochs,
//! learning rate, push budgets, dataset shape) live in small task structs
//! ([`SyncTask`], [`PsTask`], [`DistTask`]) taken by the corresponding
//! `Session` method. The old config structs survive as `#[deprecated]`
//! shims that forward here, so downstream code migrates on its own
//! schedule.
//!
//! Layer batching: [`SessionBuilder::batch_layers`] turns on the batched
//! multi-layer model-update pipeline for [`Session::cluster`] — one engine
//! invocation and **one** `WireBatch` transport frame per worker per round
//! instead of one frame per layer (see [`crate::coding::batch`]). Peers
//! that negotiated transport version 2 fall back to per-layer frames
//! automatically.
//!
//! Error feedback + local steps: [`SessionBuilder::feedback`] wraps every
//! worker's compressor in the shared residual memory
//! ([`crate::feedback::WithFeedback`]), and
//! [`SessionBuilder::local_steps`] makes workers synchronize only every
//! `H` rounds (local gradient steps in between, zero wire traffic on
//! non-communication rounds) — both honored by all four coordinators; see
//! [`crate::feedback`].

use crate::coding::WireCodec;
use crate::comm::{NetworkModel, Topology};
use crate::config::Method;
use crate::feedback::{CommSchedule, FeedbackConfig, WithFeedback};
use crate::coordinator::cluster::Cluster;
use crate::coordinator::dist::{self, DistReport, RunPlan};
use crate::coordinator::param_server::PsReport;
use crate::coordinator::sync::OptKind;
use crate::data::Dataset;
use crate::metrics::RunCurve;
use crate::model::ConvexModel;
use crate::sparsify::{
    Compressor, DenseCompressor, GSparCompressor, OneBitSgd, QsgdCompressor, TernGradCompressor,
    TopKCompressor, UniformSampler,
};
use crate::trace::TraceConfig;
use crate::transport::{Listener, Transport, TRANSPORT_VERSION};

/// Typed compressor specification — the replacement for the positional
/// `sparsify::build(method, rho, eps, qsgd_bits)` factory. Each variant
/// names exactly the parameters its method consumes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MethodSpec {
    /// No compression (the paper's dense "baseline").
    Dense,
    /// The paper's sparsifier with the greedy solver (Algorithm 3, the
    /// variant used in all of its experiments) at target density `rho`.
    GSpar {
        /// Target density ρ ∈ (0, 1].
        rho: f32,
        /// Fixed-point iterations of Algorithm 3 (the paper uses 2).
        iters: usize,
    },
    /// The closed-form solver (Algorithm 2) at variance budget `eps`.
    GSparExact {
        /// Variance budget ε of the closed-form solve.
        eps: f32,
    },
    /// Uniform-probability sampling baseline at density `rho`.
    UniSp {
        /// Keep probability for every coordinate.
        rho: f32,
    },
    /// QSGD stochastic quantization at `bits` levels per coordinate.
    Qsgd {
        /// Quantization width in bits.
        bits: u32,
    },
    /// TernGrad {-1, 0, +1} ternarization.
    TernGrad,
    /// Deterministic (biased) top-k at density `rho`.
    TopK {
        /// Kept fraction of coordinates.
        rho: f32,
    },
    /// 1-bit SGD with error feedback.
    OneBit,
}

impl MethodSpec {
    /// The untyped [`Method`] tag this spec builds (labels, wire configs).
    pub fn method(&self) -> Method {
        match self {
            MethodSpec::Dense => Method::Dense,
            MethodSpec::GSpar { .. } => Method::GSpar,
            MethodSpec::GSparExact { .. } => Method::GSparExact,
            MethodSpec::UniSp { .. } => Method::UniSp,
            MethodSpec::Qsgd { .. } => Method::Qsgd,
            MethodSpec::TernGrad => Method::TernGrad,
            MethodSpec::TopK { .. } => Method::TopK,
            MethodSpec::OneBit => Method::OneBit,
        }
    }

    /// Bridge from the old positional convention: `rho` is the density
    /// (GSpar/UniSp/TopK), `eps` the variance budget (GSpar-exact), and
    /// `qsgd_bits` the QSGD width — with the same defaults the deprecated
    /// `sparsify::build` applied (2 greedy iterations).
    pub fn from_parts(method: Method, rho: f32, eps: f32, qsgd_bits: u32) -> Self {
        match method {
            Method::Dense => MethodSpec::Dense,
            Method::GSpar => MethodSpec::GSpar { rho, iters: 2 },
            Method::GSparExact => MethodSpec::GSparExact { eps },
            Method::UniSp => MethodSpec::UniSp { rho },
            Method::Qsgd => MethodSpec::Qsgd { bits: qsgd_bits },
            Method::TernGrad => MethodSpec::TernGrad,
            Method::TopK => MethodSpec::TopK { rho },
            Method::OneBit => MethodSpec::OneBit,
        }
    }

    /// Target transmission density, for the methods that have one.
    pub fn density(&self) -> Option<f32> {
        match *self {
            MethodSpec::GSpar { rho, .. }
            | MethodSpec::UniSp { rho }
            | MethodSpec::TopK { rho } => Some(rho),
            _ => None,
        }
    }

    /// QSGD quantization width, defaulting to the historical 4 bits — what
    /// the wire-shipped [`RunPlan`] carries for non-QSGD methods.
    pub fn qsgd_bits(&self) -> u32 {
        match *self {
            MethodSpec::Qsgd { bits } => bits,
            _ => 4,
        }
    }

    /// Whether this method supports the batched multi-layer pipeline: it
    /// must produce sparse (`SparseGrad`) messages — the only payload the
    /// `WireBatch` frame packs. (1-bit SGD's residual now lives in the
    /// shared [`crate::feedback`] subsystem, which handles per-layer
    /// layouts fine, but its sign messages are dense, so it still cannot
    /// batch. Session-level error feedback composes with every batchable
    /// method.)
    pub fn batchable(&self) -> bool {
        matches!(
            self,
            MethodSpec::GSpar { .. }
                | MethodSpec::GSparExact { .. }
                | MethodSpec::UniSp { .. }
                | MethodSpec::TopK { .. }
        )
    }

    /// Build a fresh compressor instance for this spec (one per worker —
    /// some methods carry per-worker state).
    pub fn build(&self) -> Box<dyn Compressor> {
        match *self {
            MethodSpec::Dense => Box::new(DenseCompressor),
            MethodSpec::GSpar { rho, iters } => Box::new(GSparCompressor::greedy(rho, iters)),
            MethodSpec::GSparExact { eps } => Box::new(GSparCompressor::closed_form(eps)),
            MethodSpec::UniSp { rho } => Box::new(UniformSampler::new(rho)),
            MethodSpec::Qsgd { bits } => Box::new(QsgdCompressor::new(bits)),
            MethodSpec::TernGrad => Box::new(TernGradCompressor::new()),
            MethodSpec::TopK { rho } => Box::new(TopKCompressor::new(rho)),
            MethodSpec::OneBit => Box::new(OneBitSgd::new()),
        }
    }
}

/// [`MethodSpec::build`] plus the session's error-feedback wrap — the one
/// construction path every coordinator (and the wire-shipped dist worker)
/// uses, so feedback state exists wherever a compressor does.
///
/// 1Bit-SGD is *already* `WithFeedback<SignCompressor>` by definition, so
/// a session-level feedback config is applied to its one residual memory
/// (via [`OneBitSgd::with_config`]) instead of stacking a second adapter
/// on top — which would silently compute a different algorithm than
/// either the baseline or single error feedback.
pub(crate) fn build_compressor(
    spec: MethodSpec,
    feedback: Option<FeedbackConfig>,
) -> Box<dyn Compressor> {
    match feedback {
        None => spec.build(),
        Some(cfg) if spec == MethodSpec::OneBit => Box::new(OneBitSgd::with_config(cfg)),
        Some(cfg) => Box::new(WithFeedback::with_config(spec.build(), cfg)),
    }
}

impl std::fmt::Display for MethodSpec {
    /// Figure-label form, matching the labels the coordinators always used.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            MethodSpec::Dense => f.write_str("baseline"),
            MethodSpec::GSpar { rho, .. } => write!(f, "GSpar(rho={rho})"),
            MethodSpec::GSparExact { .. } => f.write_str("GSpar-exact"),
            MethodSpec::UniSp { rho } => write!(f, "UniSp(rho={rho})"),
            MethodSpec::Qsgd { bits } => write!(f, "QSGD({bits})"),
            MethodSpec::TernGrad => f.write_str("TernGrad"),
            MethodSpec::TopK { rho } => write!(f, "TopK(rho={rho})"),
            MethodSpec::OneBit => f.write_str("1Bit"),
        }
    }
}

/// Builder for [`Session`]. Every field has the historical default, so
/// `Session::builder().build()` reproduces the old `Default` configs.
#[derive(Clone, Debug)]
pub struct SessionBuilder {
    method: MethodSpec,
    codec: WireCodec,
    seed: u64,
    workers: usize,
    net: NetworkModel,
    batch_layers: bool,
    transport_version: u8,
    feedback: Option<FeedbackConfig>,
    local_steps: usize,
    pipeline: usize,
    trace: TraceConfig,
    topology: Topology,
    aligned: bool,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        Self {
            method: MethodSpec::GSpar { rho: 0.1, iters: 2 },
            codec: WireCodec::Raw,
            seed: 42,
            workers: 4,
            net: NetworkModel::commodity_1g(),
            batch_layers: false,
            transport_version: TRANSPORT_VERSION,
            feedback: None,
            local_steps: 1,
            pipeline: 1,
            // The CI trace leg (GSPARSE_TRACE=json) flows through every
            // session built by the shared suites without test changes.
            trace: TraceConfig::from_env(),
            // Likewise the CI topology leg (GSPARSE_TOPOLOGY=ring).
            topology: topology_from_env(),
            aligned: false,
        }
    }
}

impl SessionBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// The gradient compression method (see [`MethodSpec`]).
    pub fn method(mut self, method: MethodSpec) -> Self {
        self.method = method;
        self
    }

    /// The negotiated wire codec every transport handshake announces.
    pub fn codec(mut self, codec: WireCodec) -> Self {
        self.codec = codec;
        self
    }

    /// Root RNG seed; workers derive their streams from it by id.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Worker count M (threads in one process, or remote processes).
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// The α-β network model backing the simulated-time column.
    pub fn net(mut self, net: NetworkModel) -> Self {
        self.net = net;
        self
    }

    /// Enable the batched multi-layer pipeline: multi-layer coordinators
    /// compress a model's whole layer list in one engine invocation and
    /// ship it as one `WireBatch` frame per round (methods that cannot
    /// batch — see [`MethodSpec::batchable`] — fall back per layer).
    pub fn batch_layers(mut self, on: bool) -> Self {
        self.batch_layers = on;
        self
    }

    /// Compatibility override: announce an older transport version in this
    /// session's handshakes (clamped to the supported window). Version 2
    /// peers cannot receive `WireBatch` frames, so batching falls back to
    /// per-layer messages on such links.
    pub fn transport_version(mut self, version: u8) -> Self {
        self.transport_version =
            version.clamp(crate::transport::MIN_TRANSPORT_VERSION, TRANSPORT_VERSION);
        self
    }

    /// Wrap this session's compressors in the shared error-feedback memory
    /// ([`crate::feedback::WithFeedback`]): every worker compresses the
    /// error-corrected gradient `g + e` and carries the compression error
    /// to its next step. Applies to **all four** coordinators, including
    /// the batched `WireBatch` pipeline (per-layer residual layout) and
    /// the wire-shipped distributed workers (the config frame carries it).
    /// For [`MethodSpec::OneBit`] — which carries its own residual by
    /// definition — the config (e.g. a decay) is applied to that one
    /// residual memory rather than stacking a second adapter.
    pub fn feedback(mut self, cfg: FeedbackConfig) -> Self {
        self.feedback = Some(cfg);
        self
    }

    /// Synchronize only every `h` rounds (Qsparse-local-SGD style): between
    /// synchronizations workers take local gradient steps and accumulate;
    /// non-communication rounds ship **zero frames and zero bytes** on
    /// every coordinator. `h = 1` (the default) is the historical
    /// every-round behavior.
    pub fn local_steps(mut self, h: usize) -> Self {
        self.local_steps = h.max(1);
        self
    }

    /// Pipeline depth: the maximum number of in-flight compressed round
    /// frames a sender may have unacknowledged on the wire. Depth 1 (the
    /// default) is the historical fully-sequential reference path; depth
    /// ≥ 2 enables the streaming `WireBatch` encoder and vectored
    /// zero-copy frame writes, overlapping chunk compression with network
    /// transmission. The decoded updates are **bitwise identical** at
    /// every depth — pipelining reorders work, never bytes. Clamped to at
    /// least 1.
    pub fn pipeline(mut self, depth: usize) -> Self {
        self.pipeline = depth.max(1);
        self
    }

    /// Trace recording for every coordinator this session runs
    /// ([`crate::trace`]): per-stage spans (solve / sample / encode / send
    /// / apply / barrier wait …) into per-thread ring buffers, with zero
    /// effect on the computed bytes and weights. The distributed runtime
    /// ships the config to worker processes in the CONFIG frame, so
    /// multi-process traces merge by worker id. Defaults to the
    /// `GSPARSE_TRACE` environment setting ([`TraceConfig::from_env`]).
    pub fn trace(mut self, cfg: TraceConfig) -> Self {
        self.trace = cfg;
        self
    }

    /// Wire topology of the transport-backed coordinators: `Star` (every
    /// worker talks to the leader/server — the historical path) or `Ring`
    /// (gradients are reduced by a sparse ring reduce-scatter / all-gather
    /// over peer-to-peer links, [`crate::collective`], and only rank 0
    /// delivers the reduced result). Star rounds are byte-for-byte
    /// unchanged by this knob. Defaults to the `GSPARSE_TOPOLOGY`
    /// environment setting ([`topology_from_env`]).
    pub fn topology(mut self, topology: Topology) -> Self {
        self.topology = topology;
        self
    }

    /// Ring-only refinement: aligned sparsity. Workers agree on one top-k
    /// index set via a cheap shared-seed count sketch and reduce the
    /// values index-free (no index bytes on the wire after the sketch
    /// exchange) — see [`crate::collective::RingReducer::reduce_aligned`].
    /// Ignored on star topologies.
    pub fn aligned_sparsity(mut self, on: bool) -> Self {
        self.aligned = on;
        self
    }

    pub fn build(self) -> Session {
        Session {
            method: self.method,
            codec: self.codec,
            seed: self.seed,
            workers: self.workers,
            net: self.net,
            batch_layers: self.batch_layers,
            transport_version: self.transport_version,
            feedback: self.feedback,
            local_steps: self.local_steps,
            pipeline: self.pipeline,
            trace: self.trace,
            topology: self.topology,
            aligned: self.aligned,
        }
    }
}

/// Read the pipeline depth from the `GSPARSE_PIPELINE` environment
/// variable — the hook the CI matrix and the shared test suites use to
/// steer every run through a given depth. Unset or empty means depth 1
/// (the sequential reference path); anything that does not parse as a
/// positive integer panics, so a typo in a CI matrix cannot silently
/// test the wrong configuration.
pub fn pipeline_from_env() -> usize {
    match std::env::var("GSPARSE_PIPELINE") {
        Err(_) => 1,
        Ok(v) if v.is_empty() => 1,
        Ok(v) => match v.parse::<usize>() {
            Ok(depth) if depth >= 1 => depth,
            _ => panic!("GSPARSE_PIPELINE must be a positive integer, got {v:?}"),
        },
    }
}

/// Read the wire topology from the `GSPARSE_TOPOLOGY` environment variable
/// — the hook the CI `topology: [star, ring]` matrix uses to steer the
/// shared suites. Unset or empty means [`Topology::Star`] (the historical
/// path); anything but `star` / `ring` panics, so a typo in a CI matrix
/// cannot silently test the wrong configuration.
pub fn topology_from_env() -> Topology {
    match std::env::var("GSPARSE_TOPOLOGY") {
        Err(_) => Topology::Star,
        Ok(v) => match v.to_ascii_lowercase().as_str() {
            "" | "star" => Topology::Star,
            "ring" => Topology::Ring,
            other => panic!("GSPARSE_TOPOLOGY must be star|ring, got {other:?}"),
        },
    }
}

/// The shared run context consumed by all four coordinators. Construct via
/// [`Session::builder`]; the per-run knobs go into [`SyncTask`] /
/// [`PsTask`] / [`DistTask`] at call time.
#[derive(Clone, Debug)]
pub struct Session {
    method: MethodSpec,
    codec: WireCodec,
    seed: u64,
    workers: usize,
    net: NetworkModel,
    batch_layers: bool,
    transport_version: u8,
    feedback: Option<FeedbackConfig>,
    local_steps: usize,
    pipeline: usize,
    trace: TraceConfig,
    topology: Topology,
    aligned: bool,
}

impl Session {
    pub fn builder() -> SessionBuilder {
        SessionBuilder::new()
    }

    pub fn method(&self) -> MethodSpec {
        self.method
    }

    pub fn codec(&self) -> WireCodec {
        self.codec
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn net(&self) -> NetworkModel {
        self.net
    }

    pub fn batch_layers(&self) -> bool {
        self.batch_layers
    }

    pub fn transport_version(&self) -> u8 {
        self.transport_version
    }

    /// The error-feedback configuration, if enabled.
    pub fn feedback(&self) -> Option<FeedbackConfig> {
        self.feedback
    }

    /// The local-step period `H` (1 = synchronize every round).
    pub fn local_steps(&self) -> usize {
        self.local_steps
    }

    /// The pipeline depth (max in-flight round frames; 1 = sequential
    /// reference path). See [`SessionBuilder::pipeline`].
    pub fn pipeline(&self) -> usize {
        self.pipeline
    }

    /// The trace configuration (see [`SessionBuilder::trace`]).
    pub fn trace(&self) -> TraceConfig {
        self.trace
    }

    /// The wire topology (see [`SessionBuilder::topology`]).
    pub fn topology(&self) -> Topology {
        self.topology
    }

    /// Whether ring rounds use aligned sparsity (see
    /// [`SessionBuilder::aligned_sparsity`]).
    pub fn aligned(&self) -> bool {
        self.aligned
    }

    /// The communication schedule implied by [`Self::local_steps`].
    pub fn comm_schedule(&self) -> CommSchedule {
        CommSchedule::every(self.local_steps)
    }

    /// A fresh per-worker compressor for this session's method, wrapped in
    /// the error-feedback memory when [`SessionBuilder::feedback`] was set.
    pub fn compressor(&self) -> Box<dyn Compressor> {
        build_compressor(self.method, self.feedback)
    }

    /// Run the synchronous Algorithm-1 trainer (or its SVRG variants) on a
    /// convex model — the session-owned replacement for the deprecated
    /// `train_convex(&ConvexConfig, &TrainOptions, …)`.
    pub fn train_convex(
        &self,
        task: &SyncTask,
        ds: &Dataset,
        model: &dyn ConvexModel,
    ) -> RunCurve {
        crate::coordinator::sync::run_session(self, task, ds, model)
    }

    /// Run the asynchronous SSP parameter server — the session-owned
    /// replacement for the deprecated `run_param_server(&PsConfig, …)`.
    pub fn param_server(
        &self,
        task: &PsTask,
        ds: &Dataset,
        model: &(dyn ConvexModel + Sync),
    ) -> PsReport {
        crate::coordinator::param_server::run_session(self, task, ds, model)
    }

    /// Build the threaded leader/worker cluster for a multi-layer model —
    /// the session-owned replacement for the deprecated `Cluster::new` /
    /// `Cluster::with_codec`. Honors [`SessionBuilder::batch_layers`].
    pub fn cluster(&self, layer_dims: &[usize]) -> Cluster {
        Cluster::for_session(self, layer_dims)
    }

    /// Compile this session plus a [`DistTask`] into the wire-shipped
    /// [`RunPlan`] the distributed runtime's CONFIG frame carries.
    ///
    /// The CONFIG wire format (v2) carries only the [`Method`] tag, the
    /// density and the QSGD width — as the runtime always has — so the
    /// solver knobs a [`MethodSpec`] can override locally are rebuilt from
    /// the historical defaults on the worker: GSpar runs 2 greedy
    /// iterations and GSpar-exact derives ε = C1·C2 from the shipped
    /// dataset parameters, regardless of what `GSpar { iters }` /
    /// `GSparExact { eps }` say here.
    pub fn dist_plan(&self, task: &DistTask) -> RunPlan {
        RunPlan {
            workers: self.workers,
            rounds: task.rounds,
            method: self.method.method(),
            rho: self.method.density().unwrap_or(1.0),
            qsgd_bits: self.method.qsgd_bits(),
            batch: task.batch,
            lr: task.lr,
            seed: self.seed,
            n: task.n,
            d: task.d,
            c1: task.c1,
            c2: task.c2,
            reg: task.reg,
            codec: self.codec,
            local_steps: self.local_steps,
            feedback: self.feedback,
            pipeline: self.pipeline,
            trace: self.trace,
            topology: self.topology,
            aligned: self.aligned,
        }
    }

    /// Launch the distributed runtime as threads in this process (InProc
    /// channels or loopback TCP) — see [`dist::run_threads`].
    pub fn dist_threads<T>(
        &self,
        transport: T,
        bind_addr: &str,
        task: &DistTask,
    ) -> anyhow::Result<DistReport>
    where
        T: Transport + Clone + 'static,
    {
        dist::run_threads(transport, bind_addr, &self.dist_plan(task))
    }

    /// Launch a real multi-process cluster over loopback TCP — see
    /// [`dist::run_processes`].
    pub fn dist_processes(
        &self,
        bin: &std::path::Path,
        bind_addr: &str,
        task: &DistTask,
    ) -> anyhow::Result<DistReport> {
        dist::run_processes(bin, bind_addr, &self.dist_plan(task))
    }

    /// Run only the server side of the distributed runtime on an
    /// already-bound listener — see [`dist::serve`].
    pub fn dist_serve(
        &self,
        listener: &mut dyn Listener,
        task: &DistTask,
    ) -> anyhow::Result<DistReport> {
        dist::serve(listener, &self.dist_plan(task))
    }
}

/// Per-run knobs of the synchronous trainer (everything the deprecated
/// `ConvexConfig` + `TrainOptions` pair carried that is not session state).
#[derive(Clone, Debug)]
pub struct SyncTask {
    /// Minibatch size per worker.
    pub batch: usize,
    /// Data passes to run.
    pub epochs: usize,
    /// Base learning rate.
    pub lr: f32,
    /// Optimizer (SGD / SGD-1/t / SVRG variants).
    pub opt: OptKind,
    /// Record a curve point every this many synchronization rounds.
    pub record_every: usize,
    /// Subtract this from reported losses (suboptimality); 0 = raw.
    pub f_star: f64,
    /// Re-sparsify the averaged gradient before broadcast (Alg. 1 step 7).
    pub resparsify_broadcast: bool,
    /// Density of the step-7 re-sparsification. `None` uses the session
    /// method's own density ([`MethodSpec::density`]), falling back to 1.0
    /// (no thinning) for methods without one; the deprecated shim sets it
    /// to the old `ConvexConfig::rho` so its behavior is preserved exactly.
    pub resparsify_rho: Option<f32>,
    /// SVRG inner-loop length in rounds (default: one data pass).
    pub svrg_inner: Option<usize>,
}

impl Default for SyncTask {
    fn default() -> Self {
        Self {
            batch: 8,
            epochs: 30,
            lr: 0.5,
            opt: OptKind::Sgd,
            record_every: 8,
            f_star: 0.0,
            resparsify_broadcast: false,
            resparsify_rho: None,
            svrg_inner: None,
        }
    }
}

/// Per-run knobs of the SSP parameter server.
#[derive(Clone, Debug)]
pub struct PsTask {
    /// Total gradient **iterations** across all workers. With
    /// [`SessionBuilder::local_steps`]` = H > 1` each wire push covers up
    /// to `H` of them, so the applied-push count is ≈ `total_iterations /
    /// H`. (Renamed from `total_pushes`, which had counted iterations —
    /// not pushes — since local steps landed; [`PsTask::total_pushes`] is
    /// the deprecated alias.)
    pub total_iterations: usize,
    /// SSP bound: max versions a worker's weights may lag the server.
    pub max_staleness: u64,
    /// Minibatch size per worker.
    pub batch: usize,
    /// Base learning rate.
    pub lr: f32,
}

impl PsTask {
    /// Deprecated alias of [`PsTask::total_iterations`] — the field never
    /// counted wire pushes once local steps landed.
    #[deprecated(since = "0.7.0", note = "renamed to `total_iterations`")]
    pub fn total_pushes(&self) -> usize {
        self.total_iterations
    }

    /// Deprecated chainable setter kept for the old field name.
    #[deprecated(since = "0.7.0", note = "set `total_iterations` instead")]
    pub fn with_total_pushes(mut self, n: usize) -> Self {
        self.total_iterations = n;
        self
    }
}

impl Default for PsTask {
    fn default() -> Self {
        Self {
            total_iterations: 2000,
            max_staleness: 8,
            batch: 8,
            lr: 0.5,
        }
    }
}

/// Per-run knobs of the distributed (TCP / multi-process) runtime: the
/// round budget plus the seed-deterministic synthetic workload every
/// participant regenerates locally.
#[derive(Clone, Debug)]
pub struct DistTask {
    /// Synchronization rounds; total pushes = rounds × workers.
    pub rounds: usize,
    /// Minibatch size per worker.
    pub batch: usize,
    /// Base learning rate.
    pub lr: f32,
    /// Dataset size N.
    pub n: usize,
    /// Dimension d.
    pub d: usize,
    /// Magnitude shrink factor C1.
    pub c1: f32,
    /// Shrink threshold C2.
    pub c2: f32,
    /// ℓ2 regularization.
    pub reg: f32,
}

impl Default for DistTask {
    fn default() -> Self {
        Self {
            rounds: 500,
            batch: 8,
            lr: 0.5,
            n: 1024,
            d: 2048,
            c1: 0.6,
            c2: 0.25,
            reg: 1.0 / (10.0 * 1024.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngkit::RandArray;
    use crate::sparsify::{Compressed, SparseGrad};

    #[test]
    fn method_spec_round_trips_the_method_tag() {
        for &m in Method::all() {
            let spec = MethodSpec::from_parts(m, 0.2, 0.5, 6);
            assert_eq!(spec.method(), m, "{m}");
            assert!(!spec.to_string().is_empty());
        }
        assert_eq!(MethodSpec::Qsgd { bits: 6 }.qsgd_bits(), 6);
        assert_eq!(MethodSpec::Dense.qsgd_bits(), 4);
        assert_eq!(MethodSpec::GSpar { rho: 0.3, iters: 2 }.density(), Some(0.3));
        assert_eq!(MethodSpec::TernGrad.density(), None);
    }

    #[test]
    fn batchable_methods_are_the_sparse_stateless_ones() {
        assert!(MethodSpec::GSpar { rho: 0.1, iters: 2 }.batchable());
        assert!(MethodSpec::GSparExact { eps: 0.5 }.batchable());
        assert!(MethodSpec::UniSp { rho: 0.1 }.batchable());
        assert!(MethodSpec::TopK { rho: 0.1 }.batchable());
        assert!(!MethodSpec::Dense.batchable());
        assert!(!MethodSpec::Qsgd { bits: 4 }.batchable());
        assert!(!MethodSpec::TernGrad.batchable());
        assert!(!MethodSpec::OneBit.batchable());
    }

    /// The satellite guarantee for the deprecated positional factory: for
    /// every method, `sparsify::build(m, rho, eps, bits)` and
    /// `MethodSpec::from_parts(m, rho, eps, bits).build()` construct
    /// compressors that produce identical messages and statistics.
    #[test]
    #[allow(deprecated)]
    fn deprecated_build_and_method_spec_build_identical_compressors() {
        let g: Vec<f32> = (0..512)
            .map(|i| ((i * 37 % 29) as f32 - 14.0) / 10.0)
            .collect();
        for &m in Method::all() {
            let mut old = crate::sparsify::build(m, 0.2, 0.5, 5);
            let mut new = MethodSpec::from_parts(m, 0.2, 0.5, 5).build();
            let mut rand_old = RandArray::from_seed(97, 1 << 14);
            let mut rand_new = rand_old.clone();
            let mut msg_old = Compressed::Sparse(SparseGrad::empty(g.len()));
            let mut msg_new = Compressed::Sparse(SparseGrad::empty(g.len()));
            for _ in 0..3 {
                let s_old = old.compress_into(&g, &mut rand_old, &mut msg_old);
                let s_new = new.compress_into(&g, &mut rand_new, &mut msg_new);
                assert_eq!(s_old.expected_nnz, s_new.expected_nnz, "{m}");
                assert_eq!(s_old.ideal_bits, s_new.ideal_bits, "{m}");
                assert_eq!(
                    format!("{msg_old:?}"),
                    format!("{msg_new:?}"),
                    "{m}: messages differ"
                );
            }
            assert_eq!(old.name(), new.name(), "{m}");
        }
    }

    #[test]
    fn builder_defaults_and_overrides() {
        let s = Session::builder().build();
        assert_eq!(s.workers(), 4);
        assert_eq!(s.seed(), 42);
        assert_eq!(s.codec(), WireCodec::Raw);
        assert!(!s.batch_layers());
        assert_eq!(s.transport_version(), TRANSPORT_VERSION);
        assert_eq!(s.feedback(), None);
        assert_eq!(s.local_steps(), 1);
        assert_eq!(s.pipeline(), 1);
        assert_eq!(s.comm_schedule(), crate::feedback::CommSchedule::every_round());
        // Default mirrors the environment hook (Star in a clean test env,
        // Ring in the CI topology leg).
        assert_eq!(s.topology(), topology_from_env());
        assert!(!s.aligned());

        let s = Session::builder()
            .method(MethodSpec::TopK { rho: 0.05 })
            .codec(WireCodec::Entropy)
            .workers(0) // clamped to 1
            .seed(7)
            .batch_layers(true)
            .transport_version(0) // clamped to the supported window
            .feedback(FeedbackConfig::with_decay(0.9))
            .local_steps(0) // clamped to 1
            .pipeline(0) // clamped to 1
            .build();
        assert_eq!(s.workers(), 1);
        assert_eq!(s.seed(), 7);
        assert_eq!(s.codec(), WireCodec::Entropy);
        assert!(s.batch_layers());
        assert_eq!(s.transport_version(), crate::transport::MIN_TRANSPORT_VERSION);
        assert_eq!(s.method().method(), Method::TopK);
        assert_eq!(s.feedback(), Some(FeedbackConfig::with_decay(0.9)));
        assert_eq!(s.local_steps(), 1);
        assert_eq!(s.pipeline(), 1);
        assert!(!s.compressor().name().is_empty());

        let s = Session::builder().pipeline(4).build();
        assert_eq!(s.pipeline(), 4);

        let s = Session::builder()
            .topology(Topology::Ring)
            .aligned_sparsity(true)
            .build();
        assert_eq!(s.topology(), Topology::Ring);
        assert!(s.aligned());
    }

    #[test]
    fn session_compressor_is_feedback_wrapped() {
        // A feedback session's TopK compressor must behave like
        // WithFeedback<TopK>: repeated compressions of the same gradient
        // change the message (the residual keeps injecting the dropped
        // mass), whereas the plain compressor is idempotent.
        let g: Vec<f32> = (0..64)
            .map(|i| ((i * 37 % 29) as f32 - 14.0) / 10.0)
            .collect();
        let rand = RandArray::from_seed(5, 1 << 10);
        let run = |session: &Session| {
            let mut c = session.compressor();
            let mut out = Compressed::Sparse(SparseGrad::empty(g.len()));
            let mut rand = rand.clone();
            c.compress_into(&g, &mut rand, &mut out);
            let first = format!("{out:?}");
            c.compress_into(&g, &mut rand, &mut out);
            (first, format!("{out:?}"))
        };
        let plain = Session::builder().method(MethodSpec::TopK { rho: 0.05 }).build();
        let fb = Session::builder()
            .method(MethodSpec::TopK { rho: 0.05 })
            .feedback(FeedbackConfig::default())
            .build();
        let (p1, p2) = run(&plain);
        assert_eq!(p1, p2, "plain top-k is deterministic and memoryless");
        let (f1, f2) = run(&fb);
        assert_eq!(p1, f1, "first feedback step sees zero residual");
        assert_ne!(f1, f2, "the residual must alter the second message");
    }

    #[test]
    fn builder_trace_config_round_trips() {
        // Default mirrors the environment hook (off in a clean test env,
        // on in the CI trace leg).
        let s = Session::builder().build();
        assert_eq!(s.trace().enabled(), TraceConfig::from_env().enabled());
        // Explicit config wins and flows into the wire-shipped plan.
        let s = Session::builder().trace(TraceConfig::on()).build();
        assert!(s.trace().enabled());
        let plan = s.dist_plan(&DistTask::default());
        assert_eq!(plan.trace, TraceConfig::on());
        let s = Session::builder().trace(TraceConfig::Off).build();
        assert!(!s.trace().enabled());
    }

    #[test]
    #[allow(deprecated)]
    fn ps_task_total_pushes_alias_reads_and_writes_total_iterations() {
        let t = PsTask::default().with_total_pushes(123);
        assert_eq!(t.total_iterations, 123);
        assert_eq!(t.total_pushes(), 123);
        assert_eq!(PsTask::default().total_iterations, 2000);
    }

    #[test]
    fn dist_plan_compiles_session_and_task() {
        let session = Session::builder()
            .method(MethodSpec::Qsgd { bits: 6 })
            .codec(WireCodec::Entropy)
            .workers(3)
            .seed(99)
            .pipeline(2)
            .build();
        let task = DistTask {
            rounds: 17,
            d: 64,
            ..DistTask::default()
        };
        let plan = session.dist_plan(&task);
        assert_eq!(plan.workers, 3);
        assert_eq!(plan.rounds, 17);
        assert_eq!(plan.topology, session.topology());
        assert!(!plan.aligned);
        assert_eq!(plan.method, Method::Qsgd);
        assert_eq!(plan.qsgd_bits, 6);
        assert_eq!(plan.seed, 99);
        assert_eq!(plan.d, 64);
        assert_eq!(plan.codec, WireCodec::Entropy);
        assert_eq!(plan.pipeline, 2);
        // The plan survives its own wire encoding (the CONFIG frame).
        assert_eq!(RunPlan::decode(&plan.encode()).unwrap(), plan);
    }
}
