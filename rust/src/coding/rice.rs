//! Golomb-Rice coding of index-gap streams — the bit-level half of the
//! `Entropy` wire codec.
//!
//! A sorted, strictly-ascending index sequence `i_0 < i_1 < …` is turned
//! into non-negative *gaps* (`g_0 = i_0`, `g_j = i_j − i_{j−1} − 1`), which
//! for the sparsifier's near-uniform survivor pattern are approximately
//! geometric — exactly the distribution Rice codes are optimal for. Each gap
//! is written as `q = g >> k` one-bits, a terminating zero bit, then the `k`
//! low bits of `g` (LSB first); `k` is chosen per stream from the observed
//! gap distribution and carried in the message header.
//!
//! Bit order is LSB-first within each byte (the same convention as the
//! 2-bit dense-symbol packing), and the stream is zero-padded to a byte
//! boundary — the decoder rejects non-zero padding so every message has
//! exactly one canonical byte form (what the golden-fixture tests pin).
//!
//! Everything here is branch-simple byte shuffling over caller-held
//! buffers: encoding appends to a reused `Vec<u8>`, decoding borrows the
//! stream, and neither path allocates.

/// Largest accepted Rice parameter: indices are `u32`, so `k ≥ 32` can never
/// shorten a codeword and is rejected on decode as adversarial.
pub const MAX_RICE_PARAM: u8 = 31;

/// Decode-side failures of the bit stream itself (the message layer maps
/// these onto `WireError`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RiceError {
    /// The stream ended in the middle of a codeword.
    Truncated,
    /// A unary quotient run exceeded the caller's bound — the gap it
    /// encodes could not fit the dimension, so the scan stops early
    /// instead of walking an adversarial all-ones payload.
    QuotientOverflow,
}

/// Pack two signed per-layer Rice parameter deltas into one byte: the QA
/// delta in the high nibble, the QB delta in the low nibble, each a 4-bit
/// two's-complement value in `[-8, 7]`. This is the `WireBatch` v2 delta
/// byte that lets a sub-message override the batch-pooled parameters.
pub fn pack_param_deltas(dka: i8, dkb: i8) -> u8 {
    debug_assert!((-8..=7).contains(&dka), "dka {dka} out of nibble range");
    debug_assert!((-8..=7).contains(&dkb), "dkb {dkb} out of nibble range");
    (((dka as u8) & 0xF) << 4) | ((dkb as u8) & 0xF)
}

/// Inverse of [`pack_param_deltas`]: sign-extend both nibbles back to
/// `(dka, dkb)`.
pub fn unpack_param_deltas(b: u8) -> (i8, i8) {
    // Sign-extend a 4-bit two's-complement nibble: flip the sign bit into
    // the carry position and subtract it back out.
    let sx = |n: u8| ((n ^ 8).wrapping_sub(8)) as i8;
    (sx((b >> 4) & 0xF), sx(b & 0xF))
}

/// Total bits a gap stream costs at parameter `k` (`q + 1 + k` per gap).
pub fn stream_bits<I: Iterator<Item = u32>>(gaps: I, k: u32) -> u64 {
    gaps.map(|g| (g >> k) as u64 + 1 + k as u64).sum()
}

/// Pick the Rice parameter for a gap stream: seed `k` from the mean gap
/// (the classic `⌊log₂(mean+1)⌋` estimate), then refine by exact cost over
/// the neighbouring parameters. Returns `(k, total stream bits at k)` so
/// the caller never has to re-walk the stream for the winning cost. `gaps`
/// is a factory so the caller can hand over a recomputable iterator instead
/// of a buffered stream — choosing the parameter allocates nothing.
pub fn choose_param<F, I>(gaps: F) -> (u8, u64)
where
    F: Fn() -> I,
    I: Iterator<Item = u32>,
{
    let (mut n, mut sum) = (0u64, 0u64);
    for g in gaps() {
        n += 1;
        sum += g as u64;
    }
    if n == 0 {
        return (0, 0);
    }
    let mean = sum / n;
    let k0 = 63 - (mean + 1).leading_zeros() as i64; // ⌊log₂(mean+1)⌋
    let mut best_k = 0u8;
    let mut best_cost = u64::MAX;
    for k in [k0 - 1, k0, k0 + 1] {
        let k = k.clamp(0, MAX_RICE_PARAM as i64) as u32;
        let cost = stream_bits(gaps(), k);
        // Strict `<` keeps the lowest k on ties (deterministic bytes).
        if cost < best_cost {
            best_cost = cost;
            best_k = k as u8;
        }
    }
    (best_k, best_cost)
}

/// Append-only bit sink over a byte buffer (LSB-first within each byte).
pub struct BitWriter<'a> {
    out: &'a mut Vec<u8>,
    cur: u8,
    nbits: u32,
}

impl<'a> BitWriter<'a> {
    pub fn new(out: &'a mut Vec<u8>) -> Self {
        Self {
            out,
            cur: 0,
            nbits: 0,
        }
    }

    #[inline]
    fn push_bit(&mut self, bit: bool) {
        if bit {
            self.cur |= 1 << self.nbits;
        }
        self.nbits += 1;
        if self.nbits == 8 {
            self.out.push(self.cur);
            self.cur = 0;
            self.nbits = 0;
        }
    }

    /// Write one Rice codeword for `gap` at parameter `k`.
    pub fn write_rice(&mut self, gap: u32, k: u32) {
        let q = gap >> k;
        for _ in 0..q {
            self.push_bit(true);
        }
        self.push_bit(false);
        for b in 0..k {
            self.push_bit(gap & (1 << b) != 0);
        }
    }

    /// Flush the partial final byte (zero-padded) into the buffer.
    pub fn finish(self) {
        if self.nbits > 0 {
            self.out.push(self.cur);
        }
    }
}

/// Bounds-checked bit reader over a received stream.
pub struct BitReader<'a> {
    data: &'a [u8],
    /// Next bit to read, in bits from the start of `data`.
    pos: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        Self { data, pos: 0 }
    }

    #[inline]
    fn read_bit(&mut self) -> Option<bool> {
        let byte = *self.data.get(self.pos / 8)?;
        let bit = byte & (1 << (self.pos % 8)) != 0;
        self.pos += 1;
        Some(bit)
    }

    /// Read one Rice codeword at parameter `k`, rejecting unary quotients
    /// above `q_max` (gaps are bounded by the dimension, so anything larger
    /// is a malformed or adversarial stream).
    pub fn read_rice(&mut self, k: u32, q_max: u32) -> Result<u32, RiceError> {
        let mut q: u32 = 0;
        loop {
            match self.read_bit() {
                None => return Err(RiceError::Truncated),
                Some(false) => break,
                Some(true) => {
                    q += 1;
                    if q > q_max {
                        return Err(RiceError::QuotientOverflow);
                    }
                }
            }
        }
        let mut rem: u32 = 0;
        for b in 0..k {
            match self.read_bit() {
                None => return Err(RiceError::Truncated),
                Some(bit) => {
                    if bit {
                        rem |= 1 << b;
                    }
                }
            }
        }
        Ok((q << k) | rem)
    }

    /// Bytes fully or partially consumed so far.
    pub fn consumed_bytes(&self) -> usize {
        self.pos.div_ceil(8)
    }

    /// True iff every remaining bit of the partially-consumed final byte is
    /// zero — the canonical-padding requirement.
    pub fn padding_is_zero(&self) -> bool {
        let end = self.consumed_bytes() * 8;
        let mut probe = BitReader {
            data: self.data,
            pos: self.pos,
        };
        while probe.pos < end {
            if probe.read_bit() == Some(true) {
                return false;
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(gaps: &[u32], k: u32) {
        let mut buf = Vec::new();
        let mut w = BitWriter::new(&mut buf);
        for &g in gaps {
            w.write_rice(g, k);
        }
        w.finish();
        assert_eq!(
            buf.len() as u64,
            stream_bits(gaps.iter().copied(), k).div_ceil(8),
            "stream_bits must predict the byte length exactly"
        );
        let mut r = BitReader::new(&buf);
        for &g in gaps {
            assert_eq!(r.read_rice(k, u32::MAX).unwrap(), g, "k={k}");
        }
        assert_eq!(r.consumed_bytes(), buf.len());
        assert!(r.padding_is_zero());
    }

    #[test]
    fn rice_roundtrips_across_parameters() {
        for k in [0u32, 1, 3, 7, 15, 31] {
            roundtrip(&[0, 1, 2, 5, 100, 0, 63, 1 << 16], k);
            roundtrip(&[], k);
            roundtrip(&[0], k);
        }
        // A gap needing all 32 bits at k = 31.
        roundtrip(&[u32::MAX], 31);
    }

    #[test]
    fn choose_param_tracks_the_gap_scale() {
        // Mean gap ~1 → small k; mean gap ~1000 → k near 10.
        let (small, _) = choose_param(|| [0u32, 1, 2, 1, 0, 3].into_iter());
        assert!(small <= 2, "{small}");
        let (big, _) = choose_param(|| std::iter::repeat(1000u32).take(64));
        assert!((8..=11).contains(&big), "{big}");
        assert_eq!(choose_param(|| std::iter::empty::<u32>()), (0, 0));
    }

    #[test]
    fn chosen_param_is_locally_optimal_and_cost_is_exact() {
        // The refined choice must never lose to its immediate neighbours,
        // and the returned cost must equal the recomputed stream bits.
        let gaps: Vec<u32> = (0..200u32).map(|i| (i * 37) % 513).collect();
        let (k, cost) = choose_param(|| gaps.iter().copied());
        let k = k as u32;
        assert_eq!(cost, stream_bits(gaps.iter().copied(), k));
        for nk in [k.saturating_sub(1), k + 1] {
            if nk != k && nk <= MAX_RICE_PARAM as u32 {
                assert!(cost <= stream_bits(gaps.iter().copied(), nk));
            }
        }
    }

    #[test]
    fn truncated_and_overflowing_streams_error() {
        let mut buf = Vec::new();
        let mut w = BitWriter::new(&mut buf);
        w.write_rice(77, 2);
        w.finish();
        // Truncation: drop the final byte.
        let mut r = BitReader::new(&buf[..buf.len() - 1]);
        assert!(matches!(
            r.read_rice(2, u32::MAX),
            Err(RiceError::Truncated) | Ok(_)
        ));
        // All-ones stream: the quotient bound stops the scan.
        let ones = [0xFFu8; 16];
        let mut r = BitReader::new(&ones);
        assert_eq!(r.read_rice(0, 100), Err(RiceError::QuotientOverflow));
        // Empty stream is truncation, not a panic.
        let mut r = BitReader::new(&[]);
        assert_eq!(r.read_rice(3, 10), Err(RiceError::Truncated));
    }

    #[test]
    fn param_delta_nibbles_roundtrip_exactly() {
        for dka in -8i8..=7 {
            for dkb in -8i8..=7 {
                let b = pack_param_deltas(dka, dkb);
                assert_eq!(unpack_param_deltas(b), (dka, dkb), "byte {b:#04x}");
            }
        }
        // Spot-check the byte layout itself: high nibble = QA, low = QB.
        assert_eq!(pack_param_deltas(0, 0), 0x00);
        assert_eq!(pack_param_deltas(1, -1), 0x1F);
        assert_eq!(pack_param_deltas(-8, 7), 0x87);
        assert_eq!(unpack_param_deltas(0xF0), (-1, 0));
    }

    #[test]
    fn padding_check_flags_nonzero_tail() {
        let mut buf = Vec::new();
        let mut w = BitWriter::new(&mut buf);
        w.write_rice(1, 0); // 2 bits: "10" → one byte with 6 padding bits
        w.finish();
        let mut r = BitReader::new(&buf);
        r.read_rice(0, 10).unwrap();
        assert!(r.padding_is_zero());
        let mut bad = buf.clone();
        bad[0] |= 0x80; // flip the top padding bit
        let mut r = BitReader::new(&bad);
        r.read_rice(0, 10).unwrap();
        assert!(!r.padding_is_zero());
    }
}
