//! Entropy accounting for the dense symbol stream `q̃ ∈ {0, ±1, 2}^d`.
//!
//! The paper bounds the entropy-coded size by
//! `Σ_ℓ d_ℓ log₂(d / d_ℓ) ≤ 2d` bits, where `d_ℓ` counts occurrences of
//! symbol `ℓ`. We expose that quantity so the figure drivers can report the
//! tighter entropy cost alongside the fixed 2-bit cost.

use crate::sparsify::SparseGrad;

/// Symbol histogram of a sparsified gradient's dense representation.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SymbolCounts {
    /// Dropped coordinates (symbol 0).
    pub zeros: usize,
    /// Positive QB survivors (+1).
    pub plus: usize,
    /// Negative QB survivors (−1).
    pub minus: usize,
    /// QA survivors (symbol 2).
    pub exact: usize,
}

impl SymbolCounts {
    pub fn of(sg: &SparseGrad) -> Self {
        let plus = sg.shared.iter().filter(|&&(_, neg)| !neg).count();
        let minus = sg.shared.len() - plus;
        let exact = sg.exact.len();
        Self {
            zeros: sg.d as usize - plus - minus - exact,
            plus,
            minus,
            exact,
        }
    }

    pub fn total(&self) -> usize {
        self.zeros + self.plus + self.minus + self.exact
    }
}

/// The paper's entropy bound `Σ_ℓ d_ℓ log₂(d / d_ℓ)` in bits (0-count
/// symbols contribute nothing). Always ≤ 2d.
pub fn symbol_entropy_bits(counts: &SymbolCounts) -> f64 {
    let d = counts.total() as f64;
    if d == 0.0 {
        return 0.0;
    }
    [counts.zeros, counts.plus, counts.minus, counts.exact]
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| c as f64 * (d / c as f64).log2())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(d: usize, exact: usize, plus: usize, minus: usize) -> SparseGrad {
        let mut sg = SparseGrad::empty(d);
        let mut idx = 0u32;
        for _ in 0..exact {
            sg.exact.push((idx, 1.0));
            idx += 1;
        }
        for _ in 0..plus {
            sg.shared.push((idx, false));
            idx += 1;
        }
        for _ in 0..minus {
            sg.shared.push((idx, true));
            idx += 1;
        }
        sg
    }

    #[test]
    fn counts_are_correct() {
        let sg = msg(100, 5, 10, 15);
        let c = SymbolCounts::of(&sg);
        assert_eq!(
            c,
            SymbolCounts {
                zeros: 70,
                plus: 10,
                minus: 15,
                exact: 5
            }
        );
        assert_eq!(c.total(), 100);
    }

    #[test]
    fn entropy_bounded_by_2d() {
        for (e, p, m) in [(0, 0, 0), (25, 25, 25), (10, 5, 3), (100, 0, 0)] {
            let sg = msg(100, e, p, m);
            let bits = symbol_entropy_bits(&SymbolCounts::of(&sg));
            assert!(bits <= 2.0 * 100.0 + 1e-9, "({e},{p},{m}): {bits}");
            assert!(bits >= 0.0);
        }
    }

    #[test]
    fn entropy_zero_when_uniformly_one_symbol() {
        let sg = msg(64, 0, 0, 0); // all zeros
        assert_eq!(symbol_entropy_bits(&SymbolCounts::of(&sg)), 0.0);
    }

    #[test]
    fn entropy_maximized_at_uniform_quarters() {
        let uniform = msg(100, 25, 25, 25);
        let skewed = msg(100, 1, 1, 1);
        assert!(
            symbol_entropy_bits(&SymbolCounts::of(&uniform))
                > symbol_entropy_bits(&SymbolCounts::of(&skewed))
        );
        // Uniform quarters = exactly 2 bits/symbol.
        let bits = symbol_entropy_bits(&SymbolCounts::of(&uniform));
        assert!((bits - 200.0).abs() < 1e-9);
    }

    #[test]
    fn property_entropy_bound_holds() {
        crate::proptest_lite::run("entropy ≤ 2d", 64, |gen| {
            let d = gen.usize_in(4, 1000);
            let e = gen.usize_in(0, d / 4 + 1);
            let p = gen.usize_in(0, d / 4 + 1);
            let m = gen.usize_in(0, d / 4 + 1);
            let sg = msg(d, e, p, m);
            let bits = symbol_entropy_bits(&SymbolCounts::of(&sg));
            if bits > 2.0 * d as f64 + 1e-6 {
                return Err(format!("entropy {bits} > 2d = {}", 2 * d));
            }
            Ok(())
        });
    }
}
