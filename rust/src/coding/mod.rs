//! §3.3 coding strategy: pack a sparsified gradient into an actual byte
//! message, and account its cost both in real wire bytes and in the paper's
//! idealized bit model (Theorem 4).
//!
//! Two codings are implemented, and the encoder picks the cheaper one per
//! message — mirroring the `min(·, ·)` in Theorem 4:
//!
//! * **Indexed** — `Q_A` as `(index, float)` pairs, `Q_B` as indices plus a
//!   sign bitmap plus the single shared float `1/λ`;
//! * **Dense symbols** — the paper's `q̃ ∈ {0, ±1, 2}^d` alternative: a 2-bit
//!   symbol per coordinate (0 = dropped, ±1 = QB survivor with sign,
//!   2 = QA survivor) followed by the QA floats in coordinate order.
//!
//! The negotiated [`WireCodec`] widens that choice: under
//! [`WireCodec::Entropy`] the encoder may also emit **IndexedRice** —
//! sorted index streams delta-coded and Golomb-Rice compressed ([`rice`]),
//! with the per-message parameters carried in the header — which is what
//! actually closes the gap between measured wire bytes and the Theorem-4
//! ideal bits that the symbol-entropy bound
//! `Σ_ℓ d_ℓ log₂(d/d_ℓ) ≤ 2d` only accounts.
//!
//! For multi-layer models, [`batch`] packs a whole layer list behind a
//! single `WireBatch` header with batch-shared Rice parameters — one
//! transport frame per model update instead of one per layer:
//!
//! ```text
//! WireBatch     ┌ "GSPB" ┬ ver ┬ codec ┬ ka ┬ kb ┬ L ┐  12-byte header
//!               └────────┴─────┴───────┴────┴────┴───┘
//! sub-message   ┌ enc ┬ d ┬ nnz_a ┬ nnz_b ┬ 1/λ ┬ [Δk] ┬ payload ┐  × L
//!               └─────┴───┴───────┴───────┴─────┴──────┴─────────┘
//!                 bit 7 of enc ⇒ the optional Δk byte is present:
//!                 signed 4-bit (dka, dkb) applied to the pooled ka/kb
//! ```
//!
//! Sub-payloads are byte-identical to the single-message layouts; only the
//! repeated header bytes and per-message Rice parameters are shared. A
//! layer whose gap scale diverges from the pooled distribution may spend
//! one Δk byte (format version 2) to run at its own Rice optimum.
//!
//! **Streaming sub-header rule** (the pipelined send path relies on it):
//! every sub-header field — encoding choice, counts, Δk byte, and hence the
//! exact batch length — is fixed by one sizing pass before any payload
//! byte exists, so [`batch::BatchStreamEncoder`] can emit the header and
//! then hand per-layer segments to the transport incrementally, bitwise
//! identical to the one-shot [`encode_batch`].

pub mod batch;
mod entropy;
mod message;
pub mod rice;

pub use batch::{
    decode_batch_into, encode_batch, encoded_batch_len, BatchStreamEncoder, BATCH_HEADER_LEN,
    BATCH_MAGIC, BATCH_VERSION, PARAM_DELTA_FLAG, SUB_HEADER_LEN,
};
pub use entropy::{symbol_entropy_bits, SymbolCounts};
pub use message::{
    decode, decode_into, encode, encode_with, encoded_len, encoded_len_with, Encoding, WireCodec,
    WireError, HEADER_LEN,
};

use crate::sparsify::{index_bits, SparseGrad, FLOAT_BITS};

/// Theorem 4's idealized coding-length bound for a `(ρ,s)`-approximately
/// sparse gradient: `s(b + log₂ d) + min(ρ·s·log₂ d, d) + b` bits.
pub fn theorem4_bound_bits(s: usize, rho: f64, d: usize) -> u64 {
    let ib = index_bits(d) as f64;
    let qa = s as f64 * (FLOAT_BITS as f64 + ib);
    let qb = (rho * s as f64 * ib).min(d as f64);
    (qa + qb).ceil() as u64 + FLOAT_BITS
}

/// Exact idealized cost of a *given* message under the paper's bit model
/// (full-precision floats, `⌈log₂ d⌉`-bit indices, 1-bit signs folded into
/// the QB index cost, one float for `1/λ`); the dense-symbol alternative is
/// taken when cheaper, as in the Fig 5 cost formula.
pub fn ideal_message_bits(sg: &SparseGrad) -> u64 {
    let d = sg.d as usize;
    let ib = index_bits(d);
    let qa = sg.exact.len() as u64 * (FLOAT_BITS + ib);
    let qb_indexed = sg.shared.len() as u64 * ib;
    let qb_dense = 2 * d as u64;
    qa + qb_indexed.min(qb_dense) + FLOAT_BITS
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theorem4_bound_monotone_in_s() {
        let d = 2048;
        let b1 = theorem4_bound_bits(10, 0.5, d);
        let b2 = theorem4_bound_bits(100, 0.5, d);
        assert!(b2 > b1);
    }

    #[test]
    fn theorem4_qb_term_caps_at_d() {
        let d = 64;
        // Huge rho*s*log2d should cap the middle term at d.
        let b = theorem4_bound_bits(1, 1e9, d);
        assert_eq!(b, (FLOAT_BITS + index_bits(d)) + d as u64 + FLOAT_BITS);
    }

    #[test]
    fn ideal_bits_picks_cheaper_qb_coding() {
        let mut sg = SparseGrad::empty(32);
        sg.shared = (0..30).map(|i| (i as u32, false)).collect();
        // Indexed QB: 30 * 5 bits = 150 > dense 2*32 = 64.
        assert_eq!(ideal_message_bits(&sg), 64 + FLOAT_BITS);
        sg.shared.truncate(2); // 2*5 = 10 < 64
        assert_eq!(ideal_message_bits(&sg), 10 + FLOAT_BITS);
    }
}
