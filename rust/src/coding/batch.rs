//! `WireBatch`: one wire message for a whole model's layer list.
//!
//! The paper's §5.2 experiments sparsify CNN gradients **layer by layer**,
//! so a synchronization round used to ship one framed single-tensor message
//! (see [`crate::coding::encode_with`]) per layer — paying a 24-byte codec
//! header, a per-message Rice parameter search, and a transport frame per
//! layer. `WireBatch` packs all per-layer sub-messages behind one batch
//! header with **shared Rice parameters** (chosen once from the pooled gap
//! distribution of every layer's index streams), so a whole model update
//! travels as a single length-delimited transport frame:
//!
//! ```text
//! offset  size  field
//! 0       4     magic "GSPB"
//! 4       1     version (2; version-1 batches are still decoded)
//! 5       1     codec the batch was encoded under (0 = raw, 1 = entropy)
//! 6       1     ka — pooled Rice parameter for the QA index streams
//! 7       1     kb — pooled Rice parameter for the QB index streams
//! 8       4     L — number of layers (u32 LE)
//! 12      ...   L sub-messages, concatenated in layer order
//! ```
//!
//! Each sub-message drops the magic/version/Rice-parameter bytes the
//! single-message header repeats (17 bytes instead of 24 + frame):
//!
//! ```text
//! offset  size  field
//! 0       1     encoding byte: bits 0–6 = encoding (0 = Indexed,
//!               1 = DenseSymbols, 2 = IndexedRice); bit 7 = Rice
//!               parameter-delta flag (version ≥ 2, IndexedRice only)
//! 1       4     d            (u32 LE)
//! 5       4     nnz_a        (u32 LE)
//! 9       4     nnz_b        (u32 LE)
//! 13      4     shared_mag   (f32 LE, = 1/λ)
//! [17]    [1]   parameter-delta byte, present iff bit 7 of the encoding
//!               byte is set: signed 4-bit deltas `(dka << 4) | dkb`
//!               applied to the pooled header parameters, each in [-8, 7]
//! 17|18   ...   payload — byte-identical to the single-message layouts,
//!               with `IndexedRice` reading `(ka + dka, kb + dkb)`
//! ```
//!
//! A layer whose gap scale diverges from the pooled distribution may spend
//! one delta byte to run its Rice streams at its own optimum; the encoder
//! does so only when that is *strictly* smaller than the pooled form, so
//! ties keep the shorter spelling and every batch still has exactly one
//! canonical byte form per codec. A delta byte of `0x00` (both deltas
//! zero) and any delta pushing an effective parameter outside
//! `[0, MAX_RICE_PARAM]` are rejected on decode.
//!
//! **Streaming sub-header rule.** Everything a sub-header (and delta byte)
//! carries is decided by one cheap sizing pass over the layer list — no
//! payload bytes need to exist yet. [`BatchStreamEncoder`] exploits this:
//! `plan()` fixes the batch header, every per-layer encoding choice, and
//! the exact total byte length up front, then `encode_next()` materializes
//! one layer's sub-message at a time, so finished segments can be handed
//! to the transport while later layers are still being encoded. The
//! streaming path and [`encode_batch`] share the same plan/write internals
//! and produce **bitwise-identical** batches by construction.
//!
//! Sub-message payloads have no explicit length: the fixed-layout encodings
//! derive theirs from `(d, nnz_a, nnz_b)`, and the Rice stream ends after
//! exactly `nnz_a + nnz_b` codewords plus canonical zero padding — the same
//! self-delimiting property the single-message decoder already enforces.
//! The encoder still chooses the cheapest admissible encoding per layer
//! (falling back to the raw layouts when neither Rice form pays),
//! mirroring the Theorem-4 `min(·,·)` per layer. Header bytes 6–7 must be
//! zero when no sub-message uses `IndexedRice`.

use super::message::{
    self, dense_payload_len, gaps_of, indexed_payload_len, rice_payload_len, Encoding, WireCodec,
    WireError,
};
use super::rice::{self, MAX_RICE_PARAM};
use crate::sparsify::SparseGrad;

/// Magic of a batched message ("GSPB" vs the single-message "GSPR").
pub const BATCH_MAGIC: &[u8; 4] = b"GSPB";
/// Current batch format version. Version 1 (no per-layer parameter deltas)
/// is still accepted on decode for wire compatibility with older peers.
pub const BATCH_VERSION: u8 = 2;
/// Fixed batch-header length in bytes.
pub const BATCH_HEADER_LEN: usize = 12;
/// Fixed per-layer sub-header length in bytes (excluding the optional
/// parameter-delta byte).
pub const SUB_HEADER_LEN: usize = 17;
/// Bit 7 of the sub-header encoding byte: a parameter-delta byte follows
/// the fixed sub-header (version ≥ 2, `IndexedRice` only).
pub const PARAM_DELTA_FLAG: u8 = 0x80;

/// The shared Rice parameters the `Entropy` codec would use for this layer
/// list: one `(ka, kb)` pair chosen from the pooled gap distributions of
/// every layer's QA / QB index streams.
fn shared_rice_params(sgs: &[&SparseGrad]) -> (u8, u8) {
    let (ka, _) = rice::choose_param(|| sgs.iter().flat_map(|sg| gaps_of(&sg.exact)));
    let (kb, _) = rice::choose_param(|| sgs.iter().flat_map(|sg| gaps_of(&sg.shared)));
    (ka, kb)
}

/// One layer's planned sub-message: everything the write pass needs, fixed
/// before any payload byte exists.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct SubPlan {
    enc: Encoding,
    /// `Some(byte)` ⇒ the sub-header carries a parameter-delta byte and the
    /// payload runs at the per-layer effective parameters below.
    delta: Option<u8>,
    /// Effective Rice parameters for this layer's payload (= the pooled
    /// pair unless `delta` is set).
    ka: u8,
    kb: u8,
    /// Payload bytes (excluding sub-header and delta byte).
    payload_len: usize,
}

impl SubPlan {
    fn wire_len(&self) -> usize {
        SUB_HEADER_LEN + self.delta.is_some() as usize + self.payload_len
    }
}

/// Cheapest admissible sub-message for one layer under the batch's pooled
/// Rice parameters — considering, under `Entropy`, both the pooled-parameter
/// Rice form and the 1-byte-delta per-layer-optimum form.
fn plan_sub(sg: &SparseGrad, codec: WireCodec, ka: u8, kb: u8) -> SubPlan {
    let (na, nb) = (sg.exact.len(), sg.shared.len());
    let indexed = indexed_payload_len(na, nb);
    let dense = dense_payload_len(sg.d as usize, na);
    let raw = indexed.min(dense);
    // Entropy candidate: pooled parameters, or the per-layer optimum behind
    // a 1-byte delta when that is *strictly* smaller — ties keep the pooled
    // form so each layer list has one canonical spelling.
    let mut rice_cost = usize::MAX;
    let mut delta = None;
    let (mut eka, mut ekb) = (ka, kb);
    if codec == WireCodec::Entropy && (na > 0 || nb > 0) {
        let pooled_bits = rice::stream_bits(gaps_of(&sg.exact), ka as u32)
            + rice::stream_bits(gaps_of(&sg.shared), kb as u32);
        rice_cost = rice_payload_len(na, nb, pooled_bits);
        // Per-layer optimum; an empty stream stays at the pooled parameter
        // (its bits are zero either way, so a delta would be pure noise).
        let (la, bits_a) = if na == 0 {
            (ka, 0)
        } else {
            rice::choose_param(|| gaps_of(&sg.exact))
        };
        let (lb, bits_b) = if nb == 0 {
            (kb, 0)
        } else {
            rice::choose_param(|| gaps_of(&sg.shared))
        };
        let (dka, dkb) = (la as i16 - ka as i16, lb as i16 - kb as i16);
        if (dka, dkb) != (0, 0) && (-8..=7).contains(&dka) && (-8..=7).contains(&dkb) {
            let with_delta = 1 + rice_payload_len(na, nb, bits_a + bits_b);
            if with_delta < rice_cost {
                rice_cost = with_delta;
                delta = Some(rice::pack_param_deltas(dka as i8, dkb as i8));
                (eka, ekb) = (la, lb);
            }
        }
    }
    if rice_cost < raw {
        SubPlan {
            enc: Encoding::IndexedRice,
            delta,
            ka: eka,
            kb: ekb,
            payload_len: rice_cost - delta.is_some() as usize,
        }
    } else if indexed <= dense {
        SubPlan {
            enc: Encoding::Indexed,
            delta: None,
            ka,
            kb,
            payload_len: indexed,
        }
    } else {
        SubPlan {
            enc: Encoding::DenseSymbols,
            delta: None,
            ka,
            kb,
            payload_len: dense,
        }
    }
}

/// The sizing pass shared by [`encode_batch`], [`encoded_batch_len`] and
/// [`BatchStreamEncoder`]: pooled parameters, per-layer plans, the exact
/// total length, and the header parameter bytes (zero when no layer uses
/// Rice, keeping one canonical byte form per codec).
fn plan_batch(sgs: &[&SparseGrad], codec: WireCodec) -> (u8, u8, usize, Vec<SubPlan>) {
    let (ka, kb) = match codec {
        WireCodec::Raw => (0, 0),
        WireCodec::Entropy => shared_rice_params(sgs),
    };
    let mut total = BATCH_HEADER_LEN;
    let mut any_rice = false;
    let plan: Vec<SubPlan> = sgs
        .iter()
        .map(|sg| {
            let p = plan_sub(sg, codec, ka, kb);
            any_rice |= p.enc == Encoding::IndexedRice;
            total += p.wire_len();
            p
        })
        .collect();
    let (hka, hkb) = if any_rice { (ka, kb) } else { (0, 0) };
    (hka, hkb, total, plan)
}

/// The fixed 12-byte batch header for a planned batch.
fn batch_header(hka: u8, hkb: u8, codec: WireCodec, nlayers: usize) -> [u8; BATCH_HEADER_LEN] {
    let mut h = [0u8; BATCH_HEADER_LEN];
    h[0..4].copy_from_slice(BATCH_MAGIC);
    h[4] = BATCH_VERSION;
    h[5] = codec.index() as u8;
    h[6] = hka;
    h[7] = hkb;
    h[8..12].copy_from_slice(&(nlayers as u32).to_le_bytes());
    h
}

/// Append one planned sub-message (sub-header, optional delta byte,
/// payload) — the single write path both the one-shot and the streaming
/// encoder go through, so their bytes cannot diverge.
fn write_sub(sg: &SparseGrad, plan: &SubPlan, out: &mut Vec<u8>) {
    let mut enc_byte = plan.enc as u8;
    if plan.delta.is_some() {
        enc_byte |= PARAM_DELTA_FLAG;
    }
    out.push(enc_byte);
    out.extend_from_slice(&sg.d.to_le_bytes());
    out.extend_from_slice(&(sg.exact.len() as u32).to_le_bytes());
    out.extend_from_slice(&(sg.shared.len() as u32).to_le_bytes());
    out.extend_from_slice(&sg.shared_mag.to_le_bytes());
    if let Some(db) = plan.delta {
        out.push(db);
    }
    message::write_payload(sg, plan.enc, plan.ka, plan.kb, out);
}

/// Byte length [`encode_batch`] will produce for this layer list.
pub fn encoded_batch_len(sgs: &[&SparseGrad], codec: WireCodec) -> usize {
    plan_batch(sgs, codec).2
}

/// Encode a layer list into one `WireBatch` message (cleared `out`, whose
/// capacity is reused across rounds). Per-round cost beyond the byte
/// writes: one L-element plan buffer (a few bytes per *layer*, never per
/// coordinate). The per-layer sub-messages are written straight from the
/// [`SparseGrad`]s — no intermediate per-layer message is materialized.
pub fn encode_batch(sgs: &[&SparseGrad], codec: WireCodec, out: &mut Vec<u8>) {
    let mut trace_span = crate::trace::span(crate::trace::Stage::Encode);
    let (hka, hkb, total, plan) = plan_batch(sgs, codec);
    out.clear();
    out.reserve(total);
    out.extend_from_slice(&batch_header(hka, hkb, codec, sgs.len()));
    for (sg, p) in sgs.iter().zip(plan.iter()) {
        write_sub(sg, p, out);
    }
    debug_assert_eq!(out.len(), total);
    trace_span.bytes(out.len() as u64);
}

/// Incremental `WireBatch` encoder for the pipelined send path.
///
/// [`BatchStreamEncoder::plan`] runs the sizing pass once: after it
/// returns, the batch header bytes, every per-layer sub-header (including
/// parameter-delta decisions), and the exact [`total_len`] are fixed — so a
/// sender can emit the transport frame's length prefix and the batch
/// header immediately, then call [`encode_next`] per layer and hand each
/// finished segment to the connection while later layers are still being
/// encoded. The concatenation `header() ++ segment_0 ++ … ++ segment_{L-1}`
/// is bitwise identical to [`encode_batch`] over the same layer list (the
/// two share one plan/write implementation; the parity tests pin it).
///
/// `encode_next` must be called with the same [`SparseGrad`]s, in the same
/// order, that `plan` saw — the plan is positional.
///
/// [`total_len`]: BatchStreamEncoder::total_len
/// [`encode_next`]: BatchStreamEncoder::encode_next
pub struct BatchStreamEncoder {
    plan: Vec<SubPlan>,
    header: [u8; BATCH_HEADER_LEN],
    total: usize,
    next: usize,
}

impl BatchStreamEncoder {
    /// Size and plan a batch without materializing any payload bytes.
    pub fn plan(sgs: &[&SparseGrad], codec: WireCodec) -> Self {
        let (hka, hkb, total, plan) = plan_batch(sgs, codec);
        Self {
            plan,
            header: batch_header(hka, hkb, codec, sgs.len()),
            total,
            next: 0,
        }
    }

    /// Exact byte length of the full batch (header + every sub-message).
    pub fn total_len(&self) -> usize {
        self.total
    }

    /// The fixed 12-byte batch header.
    pub fn header(&self) -> &[u8] {
        &self.header
    }

    /// Number of layers in the planned batch.
    pub fn layers(&self) -> usize {
        self.plan.len()
    }

    /// Index of the layer the next [`Self::encode_next`] call will emit.
    pub fn next_layer(&self) -> usize {
        self.next
    }

    /// True once every layer's segment has been emitted.
    pub fn is_done(&self) -> bool {
        self.next == self.plan.len()
    }

    /// Planned wire length (sub-header + delta byte + payload) of `layer`.
    pub fn sub_len(&self, layer: usize) -> usize {
        self.plan[layer].wire_len()
    }

    /// Encode the next layer's sub-message into `out` (cleared first) and
    /// return its length. `sg` must be the same layer, at the same
    /// position, the plan pass saw.
    pub fn encode_next(&mut self, sg: &SparseGrad, out: &mut Vec<u8>) -> usize {
        let mut trace_span = crate::trace::span(crate::trace::Stage::Encode);
        trace_span.layer(self.next as u32);
        let p = &self.plan[self.next];
        out.clear();
        out.reserve(p.wire_len());
        write_sub(sg, p, out);
        debug_assert_eq!(out.len(), p.wire_len());
        self.next += 1;
        trace_span.bytes(out.len() as u64);
        out.len()
    }
}

/// Decode a `WireBatch` into caller-held per-layer [`SparseGrad`]s
/// (buffers reused; `out` is resized to the layer count). `sub_lens`
/// receives each sub-message's total byte length (header + delta byte +
/// payload) — the per-layer share of the batch the coordinators ledger.
/// Accepts format versions 1 and 2; the parameter-delta flag is rejected
/// in version-1 batches. On error both outputs may hold partial content
/// and must not be interpreted.
pub fn decode_batch_into(
    buf: &[u8],
    out: &mut Vec<SparseGrad>,
    sub_lens: &mut Vec<usize>,
) -> Result<(), WireError> {
    let mut trace_span = crate::trace::span(crate::trace::Stage::Decode);
    trace_span.bytes(buf.len() as u64);
    sub_lens.clear();
    if buf.len() < BATCH_HEADER_LEN {
        return Err(WireError::Truncated(buf.len()));
    }
    if &buf[0..4] != BATCH_MAGIC {
        return Err(WireError::BadMagic);
    }
    let version = buf[4];
    if version != 1 && version != BATCH_VERSION {
        return Err(WireError::BadVersion(version));
    }
    let codec = WireCodec::from_u8(buf[5]).ok_or(WireError::BadEncoding(buf[5]))?;
    let (ka, kb) = (buf[6], buf[7]);
    if ka > MAX_RICE_PARAM {
        return Err(WireError::BadRiceParam(ka));
    }
    if kb > MAX_RICE_PARAM {
        return Err(WireError::BadRiceParam(kb));
    }
    if codec == WireCodec::Raw && (ka != 0 || kb != 0) {
        return Err(WireError::NonZeroReserved(if ka != 0 { ka } else { kb }));
    }
    let nlayers = u32::from_le_bytes(buf[8..12].try_into().unwrap()) as usize;
    // A hostile layer count must not drive the resize below: every claimed
    // sub-message costs at least its fixed header, so the buffer itself
    // bounds the count before any allocation happens.
    let min_total = BATCH_HEADER_LEN as u64 + nlayers as u64 * SUB_HEADER_LEN as u64;
    if (buf.len() as u64) < min_total {
        return Err(WireError::Truncated(buf.len()));
    }
    if out.len() < nlayers {
        out.resize_with(nlayers, || SparseGrad::empty(0));
    }
    out.truncate(nlayers);

    let mut off = BATCH_HEADER_LEN;
    let mut any_rice = false;
    for slot in out.iter_mut() {
        if buf.len() < off + SUB_HEADER_LEN {
            return Err(WireError::Truncated(buf.len()));
        }
        let h = &buf[off..off + SUB_HEADER_LEN];
        let flagged = h[0] & PARAM_DELTA_FLAG != 0;
        if flagged && version < 2 {
            // The delta byte is a version-2 construct; a v1 batch carrying
            // the flag is malformed, not merely old.
            return Err(WireError::BadParamDelta(h[0]));
        }
        let enc = match h[0] & !PARAM_DELTA_FLAG {
            0 => Encoding::Indexed,
            1 => Encoding::DenseSymbols,
            2 => Encoding::IndexedRice,
            e => return Err(WireError::BadEncoding(e)),
        };
        if flagged && enc != Encoding::IndexedRice {
            return Err(WireError::BadParamDelta(h[0]));
        }
        if enc == Encoding::IndexedRice {
            if codec == WireCodec::Raw {
                // A raw-codec batch may not smuggle Rice sub-messages.
                return Err(WireError::BadEncoding(2));
            }
            any_rice = true;
        }
        let d = u32::from_le_bytes(h[1..5].try_into().unwrap());
        let na = u32::from_le_bytes(h[5..9].try_into().unwrap()) as usize;
        let nb = u32::from_le_bytes(h[9..13].try_into().unwrap()) as usize;
        let shared_mag = f32::from_le_bytes(h[13..17].try_into().unwrap());
        // Same adversarial-header gates as the single-message decoder,
        // before any per-layer reserve.
        if na as u64 + nb as u64 > d as u64 {
            return Err(WireError::CountsExceedDim {
                na: na as u32,
                nb: nb as u32,
                d,
            });
        }
        if !shared_mag.is_finite() {
            return Err(WireError::NonFiniteSharedMag(shared_mag));
        }
        let mut payload_off = off + SUB_HEADER_LEN;
        let (eka, ekb) = if flagged {
            if buf.len() < payload_off + 1 {
                return Err(WireError::Truncated(buf.len()));
            }
            let db = buf[payload_off];
            payload_off += 1;
            if db == 0 {
                // Zero deltas must be spelled as the pooled (flagless)
                // form — one canonical byte form per batch.
                return Err(WireError::BadParamDelta(0));
            }
            let (dka, dkb) = rice::unpack_param_deltas(db);
            let eka = ka as i16 + dka as i16;
            let ekb = kb as i16 + dkb as i16;
            let range = 0..=MAX_RICE_PARAM as i16;
            if !range.contains(&eka) || !range.contains(&ekb) {
                return Err(WireError::BadParamDelta(db));
            }
            (eka as u8, ekb as u8)
        } else {
            (ka, kb)
        };
        slot.reset(d as usize);
        slot.shared_mag = shared_mag;
        let consumed =
            message::read_payload(enc, d, na, nb, eka, ekb, &buf[payload_off..], slot)?;
        sub_lens.push(payload_off - off + consumed);
        off = payload_off + consumed;
    }
    if off != buf.len() {
        return Err(WireError::LengthMismatch {
            expected: off,
            got: buf.len(),
        });
    }
    if !any_rice && (ka != 0 || kb != 0) {
        return Err(WireError::NonZeroReserved(if ka != 0 { ka } else { kb }));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngkit::RandArray;
    use crate::sparsify::{greedy_probs, sample_sparse};

    fn sample_layer(d: usize, rho: f32, seed: u64) -> SparseGrad {
        let mut rng = crate::rngkit::Xoshiro256pp::seed_from_u64(seed);
        let g: Vec<f32> = (0..d).map(|_| (rng.next_gaussian() * 0.5) as f32).collect();
        let mut p = Vec::new();
        let pv = greedy_probs(&g, rho, 2, &mut p);
        let mut ra = RandArray::from_seed(seed ^ 1, 1 << 16);
        sample_sparse(&g, &p, pv.inv_lambda, &mut ra)
    }

    /// A hand-built QB-only layer with a fixed index stride — its gap scale
    /// is exactly `stride - 1`, which the delta tests steer far away from
    /// the pooled distribution.
    fn strided_layer(d: usize, stride: usize, count: usize) -> SparseGrad {
        let mut sg = SparseGrad::empty(d);
        sg.shared_mag = 1.0;
        for i in 0..count {
            sg.shared.push(((i * stride) as u32, i % 3 == 0));
        }
        assert!((count - 1) * stride < d);
        sg
    }

    fn roundtrip(layers: &[SparseGrad], codec: WireCodec) -> (Vec<u8>, Vec<usize>) {
        let refs: Vec<&SparseGrad> = layers.iter().collect();
        let mut buf = Vec::new();
        encode_batch(&refs, codec, &mut buf);
        assert_eq!(buf.len(), encoded_batch_len(&refs, codec), "{codec}");
        let mut back = Vec::new();
        let mut sub_lens = Vec::new();
        decode_batch_into(&buf, &mut back, &mut sub_lens).unwrap_or_else(|e| {
            panic!("batch decode failed under {codec}: {e}");
        });
        assert_eq!(back.len(), layers.len());
        for (l, (a, b)) in layers.iter().zip(&back).enumerate() {
            assert_eq!(a, b, "layer {l} drifted under {codec}");
        }
        assert_eq!(
            sub_lens.iter().sum::<usize>() + BATCH_HEADER_LEN,
            buf.len(),
            "sub lengths must tile the batch"
        );
        (buf, sub_lens)
    }

    /// Offsets of each sub-message's encoding byte, from decoded sub_lens.
    fn sub_offsets(sub_lens: &[usize]) -> Vec<usize> {
        let mut offs = Vec::with_capacity(sub_lens.len());
        let mut off = BATCH_HEADER_LEN;
        for &len in sub_lens {
            offs.push(off);
            off += len;
        }
        offs
    }

    #[test]
    fn multi_layer_roundtrips_both_codecs() {
        let layers = vec![
            sample_layer(4096, 0.01, 7),
            SparseGrad::empty(100),
            sample_layer(257, 0.9, 8), // d % 4 != 0, DenseSymbols
            sample_layer(1 << 14, 0.02, 9),
        ];
        for codec in [WireCodec::Raw, WireCodec::Entropy] {
            roundtrip(&layers, codec);
        }
    }

    #[test]
    fn empty_batch_and_single_layer_batch() {
        for codec in [WireCodec::Raw, WireCodec::Entropy] {
            let (buf, _) = roundtrip(&[], codec);
            assert_eq!(buf.len(), BATCH_HEADER_LEN);
            roundtrip(&[SparseGrad::empty(1)], codec);
            roundtrip(&[sample_layer(2048, 0.05, 11)], codec);
        }
    }

    #[test]
    fn raw_batch_beats_per_layer_headers() {
        // Under the raw codec the sub-payloads are byte-identical to the
        // single-message payloads, so the batch wins exactly the header
        // bytes: 17 per layer instead of 24, plus one 12-byte batch header.
        let layers = vec![
            sample_layer(2048, 0.02, 21),
            sample_layer(1024, 0.05, 22),
            sample_layer(512, 0.1, 23),
        ];
        let refs: Vec<&SparseGrad> = layers.iter().collect();
        let batch = encoded_batch_len(&refs, WireCodec::Raw);
        let singles: usize = layers
            .iter()
            .map(|sg| super::super::encoded_len_with(sg, WireCodec::Raw))
            .sum();
        assert_eq!(
            batch,
            singles + BATCH_HEADER_LEN
                - layers.len() * (super::super::HEADER_LEN - SUB_HEADER_LEN),
        );
        assert!(batch < singles);
    }

    #[test]
    fn entropy_batch_never_larger_than_raw_batch() {
        let layers: Vec<SparseGrad> = (0..4).map(|i| sample_layer(1 << 13, 0.02, 30 + i)).collect();
        let refs: Vec<&SparseGrad> = layers.iter().collect();
        let raw = encoded_batch_len(&refs, WireCodec::Raw);
        let ent = encoded_batch_len(&refs, WireCodec::Entropy);
        assert!(ent <= raw, "entropy batch {ent} > raw batch {raw}");
        // At this sparsity Rice must actually engage.
        let mut buf = Vec::new();
        encode_batch(&refs, WireCodec::Entropy, &mut buf);
        assert!(buf[6] > 0 || buf[7] > 0, "expected shared Rice params");
        assert!(ent < raw);
    }

    #[test]
    fn divergent_layers_spend_a_delta_byte_and_win() {
        // One layer with gap scale ~127, one with gap scale 0: the pooled
        // parameter fits neither, so both should diverge behind 1-byte
        // deltas, each strictly cheaper than the pooled Rice form.
        let layers = vec![
            strided_layer(1 << 16, 128, 400), // mean gap 127 → k ≈ 6–7
            strided_layer(1 << 12, 1, 400),   // mean gap 0 → k = 0
        ];
        let (buf, sub_lens) = roundtrip(&layers, WireCodec::Entropy);
        let offs = sub_offsets(&sub_lens);
        let flagged: Vec<bool> = offs
            .iter()
            .map(|&o| buf[o] & PARAM_DELTA_FLAG != 0)
            .collect();
        assert!(
            flagged.iter().any(|&f| f),
            "expected at least one param-delta sub-message, got {flagged:?}"
        );
        // The delta byte sits right after the 17-byte sub-header and is
        // never the canonical all-zero value.
        for (&o, &f) in offs.iter().zip(&flagged) {
            if f {
                assert_ne!(buf[o + SUB_HEADER_LEN], 0, "zero delta byte is non-canonical");
            }
        }
        // Divergent parameters must not cost more than the raw codec would.
        let refs: Vec<&SparseGrad> = layers.iter().collect();
        assert!(
            encoded_batch_len(&refs, WireCodec::Entropy)
                < encoded_batch_len(&refs, WireCodec::Raw)
        );
    }

    #[test]
    fn homogeneous_batch_spends_no_delta_bytes() {
        // A single-layer batch's pooled parameters *are* the layer optimum,
        // so the delta form can never be strictly smaller.
        let layers = vec![sample_layer(1 << 14, 0.02, 33)];
        let (buf, sub_lens) = roundtrip(&layers, WireCodec::Entropy);
        for &o in &sub_offsets(&sub_lens) {
            assert_eq!(buf[o] & PARAM_DELTA_FLAG, 0, "unexpected delta flag");
        }
    }

    #[test]
    fn version1_batches_without_deltas_still_decode() {
        // A delta-free v2 batch differs from its v1 spelling only in the
        // version byte; patching it back to 1 must decode identically.
        let layers = vec![sample_layer(1 << 14, 0.02, 34), SparseGrad::empty(50)];
        for codec in [WireCodec::Raw, WireCodec::Entropy] {
            let (buf, sub_lens) = roundtrip(&layers, codec);
            for &o in &sub_offsets(&sub_lens) {
                assert_eq!(buf[o] & PARAM_DELTA_FLAG, 0, "fixture must be delta-free");
            }
            let mut v1 = buf.clone();
            assert_eq!(v1[4], BATCH_VERSION);
            v1[4] = 1;
            let mut back = Vec::new();
            let mut lens = Vec::new();
            decode_batch_into(&v1, &mut back, &mut lens).unwrap();
            assert_eq!(back, layers, "{codec}: v1 spelling drifted");
        }
    }

    #[test]
    fn rejects_malformed_param_deltas() {
        let layers = vec![
            strided_layer(1 << 16, 128, 400),
            strided_layer(1 << 12, 1, 400),
        ];
        let (buf, sub_lens) = roundtrip(&layers, WireCodec::Entropy);
        let offs = sub_offsets(&sub_lens);
        let flagged_off = *offs
            .iter()
            .find(|&&o| buf[o] & PARAM_DELTA_FLAG != 0)
            .expect("fixture must contain a delta sub-message");
        let mut out = Vec::new();
        let mut lens = Vec::new();

        // Zero delta byte: the pooled form is canonical for zero deltas.
        let mut bad = buf.clone();
        bad[flagged_off + SUB_HEADER_LEN] = 0;
        assert_eq!(
            decode_batch_into(&bad, &mut out, &mut lens),
            Err(WireError::BadParamDelta(0))
        );
        // Delta pushing the effective parameter below zero: header kb plus
        // -8 is negative whenever kb < 8 (true for this fixture).
        assert!(buf[7] < 8, "fixture sanity: pooled kb {}", buf[7]);
        let mut bad = buf.clone();
        bad[flagged_off + SUB_HEADER_LEN] = rice::pack_param_deltas(0, -8);
        assert_eq!(
            decode_batch_into(&bad, &mut out, &mut lens),
            Err(WireError::BadParamDelta(rice::pack_param_deltas(0, -8)))
        );
        // The flag on a non-Rice sub-message is structurally invalid.
        let raw_layers = vec![sample_layer(512, 0.05, 41)];
        let refs: Vec<&SparseGrad> = raw_layers.iter().collect();
        let mut rbuf = Vec::new();
        encode_batch(&refs, WireCodec::Raw, &mut rbuf);
        let enc_at = BATCH_HEADER_LEN;
        assert!(rbuf[enc_at] & PARAM_DELTA_FLAG == 0 && rbuf[enc_at] != 2);
        let mut bad = rbuf.clone();
        bad[enc_at] |= PARAM_DELTA_FLAG;
        assert_eq!(
            decode_batch_into(&bad, &mut out, &mut lens),
            Err(WireError::BadParamDelta(bad[enc_at]))
        );
        // The flag inside a version-1 batch is malformed, not merely old.
        let mut bad = buf.clone();
        bad[4] = 1;
        assert_eq!(
            decode_batch_into(&bad, &mut out, &mut lens),
            Err(WireError::BadParamDelta(buf[flagged_off]))
        );
        // Truncation right after a flagged sub-header (the delta byte is
        // part of the header for length purposes).
        let cut = &buf[..flagged_off + SUB_HEADER_LEN];
        assert!(matches!(
            decode_batch_into(cut, &mut out, &mut lens),
            Err(WireError::Truncated(_))
        ));
    }

    #[test]
    fn stream_encoder_matches_encode_batch_bytewise() {
        let layer_sets: Vec<Vec<SparseGrad>> = vec![
            vec![],
            vec![SparseGrad::empty(64)],
            vec![
                sample_layer(4096, 0.01, 71),
                SparseGrad::empty(100),
                sample_layer(257, 0.9, 72),
                strided_layer(1 << 16, 128, 400), // forces a delta byte
                strided_layer(1 << 12, 1, 400),
            ],
        ];
        for layers in &layer_sets {
            let refs: Vec<&SparseGrad> = layers.iter().collect();
            for codec in [WireCodec::Raw, WireCodec::Entropy] {
                let mut want = Vec::new();
                encode_batch(&refs, codec, &mut want);

                let mut enc = BatchStreamEncoder::plan(&refs, codec);
                assert_eq!(enc.total_len(), want.len(), "{codec}: planned length");
                assert_eq!(enc.layers(), layers.len());
                let mut got = Vec::new();
                got.extend_from_slice(enc.header());
                let mut seg = Vec::new();
                for (l, sg) in layers.iter().enumerate() {
                    assert_eq!(enc.next_layer(), l);
                    assert!(!enc.is_done());
                    let n = enc.encode_next(sg, &mut seg);
                    assert_eq!(n, enc.sub_len(l), "{codec}: layer {l} segment length");
                    got.extend_from_slice(&seg);
                }
                assert!(enc.is_done());
                assert_eq!(got, want, "{codec}: streamed bytes drifted");
            }
        }
    }

    #[test]
    fn rejects_malformed_batches() {
        let layers = vec![sample_layer(512, 0.05, 41), SparseGrad::empty(9)];
        let refs: Vec<&SparseGrad> = layers.iter().collect();
        let mut buf = Vec::new();
        encode_batch(&refs, WireCodec::Raw, &mut buf);
        let mut out = Vec::new();
        let mut lens = Vec::new();

        let mut bad = buf.clone();
        bad[0] = b'X';
        assert_eq!(
            decode_batch_into(&bad, &mut out, &mut lens),
            Err(WireError::BadMagic)
        );
        let mut bad = buf.clone();
        bad[4] = 9;
        assert_eq!(
            decode_batch_into(&bad, &mut out, &mut lens),
            Err(WireError::BadVersion(9))
        );
        let mut bad = buf.clone();
        bad[5] = 7; // unknown codec byte
        assert_eq!(
            decode_batch_into(&bad, &mut out, &mut lens),
            Err(WireError::BadEncoding(7))
        );
        // Raw batch with nonzero Rice parameters is non-canonical.
        let mut bad = buf.clone();
        bad[6] = 3;
        assert_eq!(
            decode_batch_into(&bad, &mut out, &mut lens),
            Err(WireError::NonZeroReserved(3))
        );
        // Hostile layer count: not backed by payload bytes.
        let mut bad = buf.clone();
        bad[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            decode_batch_into(&bad, &mut out, &mut lens),
            Err(WireError::Truncated(_))
        ));
        // Trailing bytes after the final sub-message.
        let mut bad = buf.clone();
        bad.push(0);
        assert!(matches!(
            decode_batch_into(&bad, &mut out, &mut lens),
            Err(WireError::LengthMismatch { .. })
        ));
        // An empty sub-message claiming the Rice encoding is non-canonical
        // (it would let the shared-parameter header bytes float freely).
        let empty = vec![SparseGrad::empty(9)];
        let refs: Vec<&SparseGrad> = empty.iter().collect();
        let mut ebuf = Vec::new();
        encode_batch(&refs, WireCodec::Entropy, &mut ebuf);
        let sub0_enc = BATCH_HEADER_LEN; // first sub-message's encoding byte
        assert_eq!(ebuf[sub0_enc], Encoding::Indexed as u8);
        let mut bad = ebuf.clone();
        bad[sub0_enc] = Encoding::IndexedRice as u8;
        assert_eq!(
            decode_batch_into(&bad, &mut out, &mut lens),
            Err(WireError::BadRiceStream("empty rice message"))
        );
        // Truncation anywhere inside the sub-messages.
        assert!(decode_batch_into(&buf[..buf.len() - 1], &mut out, &mut lens).is_err());
        assert!(decode_batch_into(&buf[..BATCH_HEADER_LEN + 3], &mut out, &mut lens).is_err());
    }

    #[test]
    fn decode_reuses_buffers_across_batches() {
        let big = vec![sample_layer(4096, 0.2, 50), sample_layer(2048, 0.1, 51)];
        let small = vec![SparseGrad::empty(7)];
        let mut buf = Vec::new();
        let mut out = Vec::new();
        let mut lens = Vec::new();
        let refs: Vec<&SparseGrad> = big.iter().collect();
        encode_batch(&refs, WireCodec::Raw, &mut buf);
        decode_batch_into(&buf, &mut out, &mut lens).unwrap();
        assert_eq!(out, big);
        let refs: Vec<&SparseGrad> = small.iter().collect();
        encode_batch(&refs, WireCodec::Raw, &mut buf);
        decode_batch_into(&buf, &mut out, &mut lens).unwrap();
        assert_eq!(out, small);
        assert_eq!(lens.len(), 1);
    }

    #[test]
    fn property_batches_roundtrip_bitwise() {
        crate::proptest_lite::run("wire-batch roundtrip is exact", 48, |gen| {
            let nlayers = gen.usize_in(0, 6);
            let layers: Vec<SparseGrad> = (0..nlayers)
                .map(|_| {
                    let d = gen.usize_in(1, 1500);
                    if gen.bool() {
                        SparseGrad::empty(d)
                    } else {
                        let rho = gen.f32_in(0.01, 1.0);
                        let g = gen.gradient_vec(d);
                        let mut p = Vec::new();
                        let pv = greedy_probs(&g, rho, 2, &mut p);
                        let mut ra = RandArray::new(
                            crate::rngkit::Xoshiro256pp::seed_from_u64(gen.u64()),
                            1 << 14,
                        );
                        sample_sparse(&g, &p, pv.inv_lambda, &mut ra)
                    }
                })
                .collect();
            let refs: Vec<&SparseGrad> = layers.iter().collect();
            for codec in [WireCodec::Raw, WireCodec::Entropy] {
                let mut buf = Vec::new();
                encode_batch(&refs, codec, &mut buf);
                if buf.len() != encoded_batch_len(&refs, codec) {
                    return Err(format!("length mismatch under {codec}"));
                }
                // The streaming encoder must agree byte for byte.
                let mut enc = BatchStreamEncoder::plan(&refs, codec);
                let mut streamed = enc.header().to_vec();
                let mut seg = Vec::new();
                for sg in &layers {
                    enc.encode_next(sg, &mut seg);
                    streamed.extend_from_slice(&seg);
                }
                if streamed != buf {
                    return Err(format!("streamed bytes drifted under {codec}"));
                }
                let mut back = Vec::new();
                let mut lens = Vec::new();
                if let Err(e) = decode_batch_into(&buf, &mut back, &mut lens) {
                    return Err(format!("decode failed under {codec}: {e}"));
                }
                if back != layers {
                    return Err(format!("roundtrip not identical under {codec}"));
                }
            }
            Ok(())
        });
    }
}
