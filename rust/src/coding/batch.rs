//! `WireBatch`: one wire message for a whole model's layer list.
//!
//! The paper's §5.2 experiments sparsify CNN gradients **layer by layer**,
//! so a synchronization round used to ship one framed single-tensor message
//! (see [`crate::coding::encode_with`]) per layer — paying a 24-byte codec
//! header, a per-message Rice parameter search, and a transport frame per
//! layer. `WireBatch` packs all per-layer sub-messages behind one batch
//! header with **shared Rice parameters** (chosen once from the pooled gap
//! distribution of every layer's index streams), so a whole model update
//! travels as a single length-delimited transport frame:
//!
//! ```text
//! offset  size  field
//! 0       4     magic "GSPB"
//! 4       1     version (1)
//! 5       1     codec the batch was encoded under (0 = raw, 1 = entropy)
//! 6       1     ka — shared Rice parameter for every QA index stream
//! 7       1     kb — shared Rice parameter for every QB index stream
//! 8       4     L — number of layers (u32 LE)
//! 12      ...   L sub-messages, concatenated in layer order
//! ```
//!
//! Each sub-message drops the magic/version/Rice-parameter bytes the
//! single-message header repeats (17 bytes instead of 24 + frame):
//!
//! ```text
//! offset  size  field
//! 0       1     encoding (0 = Indexed, 1 = DenseSymbols, 2 = IndexedRice)
//! 1       4     d            (u32 LE)
//! 5       4     nnz_a        (u32 LE)
//! 9       4     nnz_b        (u32 LE)
//! 13      4     shared_mag   (f32 LE, = 1/λ)
//! 17      ...   payload — byte-identical to the single-message layouts,
//!               with `IndexedRice` reading the shared ka/kb above
//! ```
//!
//! Sub-message payloads have no explicit length: the fixed-layout encodings
//! derive theirs from `(d, nnz_a, nnz_b)`, and the Rice stream ends after
//! exactly `nnz_a + nnz_b` codewords plus canonical zero padding — the same
//! self-delimiting property the single-message decoder already enforces.
//! The encoder still chooses the cheapest admissible encoding per layer
//! (falling back to the raw layouts when the shared parameters don't pay),
//! mirroring the Theorem-4 `min(·,·)` per layer. Header bytes 6–7 must be
//! zero when no sub-message uses `IndexedRice`, so every batch has exactly
//! one canonical byte form per codec.

use super::message::{
    self, dense_payload_len, gaps_of, indexed_payload_len, rice_payload_len, Encoding, WireCodec,
    WireError,
};
use super::rice::{self, MAX_RICE_PARAM};
use crate::sparsify::SparseGrad;

/// Magic of a batched message ("GSPB" vs the single-message "GSPR").
pub const BATCH_MAGIC: &[u8; 4] = b"GSPB";
pub const BATCH_VERSION: u8 = 1;
/// Fixed batch-header length in bytes.
pub const BATCH_HEADER_LEN: usize = 12;
/// Fixed per-layer sub-header length in bytes.
pub const SUB_HEADER_LEN: usize = 17;

/// The shared Rice parameters the `Entropy` codec would use for this layer
/// list: one `(ka, kb)` pair chosen from the pooled gap distributions of
/// every layer's QA / QB index streams.
fn shared_rice_params(sgs: &[&SparseGrad]) -> (u8, u8) {
    let (ka, _) = rice::choose_param(|| sgs.iter().flat_map(|sg| gaps_of(&sg.exact)));
    let (kb, _) = rice::choose_param(|| sgs.iter().flat_map(|sg| gaps_of(&sg.shared)));
    (ka, kb)
}

/// Cheapest admissible encoding for one layer under the batch's shared
/// Rice parameters; returns the encoding and its payload length.
fn choose_sub(sg: &SparseGrad, codec: WireCodec, ka: u8, kb: u8) -> (Encoding, usize) {
    let (na, nb) = (sg.exact.len(), sg.shared.len());
    let indexed = indexed_payload_len(na, nb);
    let dense = dense_payload_len(sg.d as usize, na);
    let raw = indexed.min(dense);
    let rice_len = match codec {
        WireCodec::Raw => usize::MAX,
        WireCodec::Entropy => {
            let bits = rice::stream_bits(gaps_of(&sg.exact), ka as u32)
                + rice::stream_bits(gaps_of(&sg.shared), kb as u32);
            rice_payload_len(na, nb, bits)
        }
    };
    if rice_len < raw {
        (Encoding::IndexedRice, rice_len)
    } else if indexed <= dense {
        (Encoding::Indexed, indexed)
    } else {
        (Encoding::DenseSymbols, dense)
    }
}

/// Byte length [`encode_batch`] will produce for this layer list.
pub fn encoded_batch_len(sgs: &[&SparseGrad], codec: WireCodec) -> usize {
    let (ka, kb) = match codec {
        WireCodec::Raw => (0, 0),
        WireCodec::Entropy => shared_rice_params(sgs),
    };
    BATCH_HEADER_LEN
        + sgs
            .iter()
            .map(|sg| SUB_HEADER_LEN + choose_sub(sg, codec, ka, kb).1)
            .sum::<usize>()
}

/// Encode a layer list into one `WireBatch` message (cleared `out`, whose
/// capacity is reused across rounds). Per-round cost beyond the byte
/// writes: one L-element encoding-plan buffer (one byte per *layer*, never
/// per coordinate). The per-layer sub-messages are written straight from
/// the [`SparseGrad`]s — no intermediate per-layer message is materialized.
pub fn encode_batch(sgs: &[&SparseGrad], codec: WireCodec, out: &mut Vec<u8>) {
    let (ka, kb) = match codec {
        WireCodec::Raw => (0, 0),
        WireCodec::Entropy => shared_rice_params(sgs),
    };
    // Sizing pass: per-layer encoding choices (cached — the Entropy cost
    // model walks both gap streams, so recomputing it during the write
    // pass would double the O(nnz) work), the total length for one
    // reserve, and whether Rice engages anywhere — header bytes 6–7 are
    // zero otherwise, keeping one canonical byte form per (layer list,
    // codec).
    let mut total = BATCH_HEADER_LEN;
    let mut any_rice = false;
    let plan: Vec<Encoding> = sgs
        .iter()
        .map(|sg| {
            let (enc, len) = choose_sub(sg, codec, ka, kb);
            any_rice |= enc == Encoding::IndexedRice;
            total += SUB_HEADER_LEN + len;
            enc
        })
        .collect();
    let (hka, hkb) = if any_rice { (ka, kb) } else { (0, 0) };

    out.clear();
    out.reserve(total);
    out.extend_from_slice(BATCH_MAGIC);
    out.push(BATCH_VERSION);
    out.push(codec.index() as u8);
    out.push(hka);
    out.push(hkb);
    out.extend_from_slice(&(sgs.len() as u32).to_le_bytes());
    for (sg, &enc) in sgs.iter().zip(plan.iter()) {
        out.push(enc as u8);
        out.extend_from_slice(&sg.d.to_le_bytes());
        out.extend_from_slice(&(sg.exact.len() as u32).to_le_bytes());
        out.extend_from_slice(&(sg.shared.len() as u32).to_le_bytes());
        out.extend_from_slice(&sg.shared_mag.to_le_bytes());
        message::write_payload(sg, enc, ka, kb, out);
    }
    debug_assert_eq!(out.len(), total);
}

/// Decode a `WireBatch` into caller-held per-layer [`SparseGrad`]s
/// (buffers reused; `out` is resized to the layer count). `sub_lens`
/// receives each sub-message's total byte length (header + payload) — the
/// per-layer share of the batch the coordinators ledger. On error both
/// outputs may hold partial content and must not be interpreted.
pub fn decode_batch_into(
    buf: &[u8],
    out: &mut Vec<SparseGrad>,
    sub_lens: &mut Vec<usize>,
) -> Result<(), WireError> {
    sub_lens.clear();
    if buf.len() < BATCH_HEADER_LEN {
        return Err(WireError::Truncated(buf.len()));
    }
    if &buf[0..4] != BATCH_MAGIC {
        return Err(WireError::BadMagic);
    }
    if buf[4] != BATCH_VERSION {
        return Err(WireError::BadVersion(buf[4]));
    }
    let codec = WireCodec::from_u8(buf[5]).ok_or(WireError::BadEncoding(buf[5]))?;
    let (ka, kb) = (buf[6], buf[7]);
    if ka > MAX_RICE_PARAM {
        return Err(WireError::BadRiceParam(ka));
    }
    if kb > MAX_RICE_PARAM {
        return Err(WireError::BadRiceParam(kb));
    }
    if codec == WireCodec::Raw && (ka != 0 || kb != 0) {
        return Err(WireError::NonZeroReserved(if ka != 0 { ka } else { kb }));
    }
    let nlayers = u32::from_le_bytes(buf[8..12].try_into().unwrap()) as usize;
    // A hostile layer count must not drive the resize below: every claimed
    // sub-message costs at least its fixed header, so the buffer itself
    // bounds the count before any allocation happens.
    let min_total = BATCH_HEADER_LEN as u64 + nlayers as u64 * SUB_HEADER_LEN as u64;
    if (buf.len() as u64) < min_total {
        return Err(WireError::Truncated(buf.len()));
    }
    if out.len() < nlayers {
        out.resize_with(nlayers, || SparseGrad::empty(0));
    }
    out.truncate(nlayers);

    let mut off = BATCH_HEADER_LEN;
    let mut any_rice = false;
    for slot in out.iter_mut() {
        if buf.len() < off + SUB_HEADER_LEN {
            return Err(WireError::Truncated(buf.len()));
        }
        let h = &buf[off..off + SUB_HEADER_LEN];
        let enc = match h[0] {
            0 => Encoding::Indexed,
            1 => Encoding::DenseSymbols,
            2 => Encoding::IndexedRice,
            e => return Err(WireError::BadEncoding(e)),
        };
        if enc == Encoding::IndexedRice {
            if codec == WireCodec::Raw {
                // A raw-codec batch may not smuggle Rice sub-messages.
                return Err(WireError::BadEncoding(2));
            }
            any_rice = true;
        }
        let d = u32::from_le_bytes(h[1..5].try_into().unwrap());
        let na = u32::from_le_bytes(h[5..9].try_into().unwrap()) as usize;
        let nb = u32::from_le_bytes(h[9..13].try_into().unwrap()) as usize;
        let shared_mag = f32::from_le_bytes(h[13..17].try_into().unwrap());
        // Same adversarial-header gates as the single-message decoder,
        // before any per-layer reserve.
        if na as u64 + nb as u64 > d as u64 {
            return Err(WireError::CountsExceedDim {
                na: na as u32,
                nb: nb as u32,
                d,
            });
        }
        if !shared_mag.is_finite() {
            return Err(WireError::NonFiniteSharedMag(shared_mag));
        }
        slot.reset(d as usize);
        slot.shared_mag = shared_mag;
        let consumed =
            message::read_payload(enc, d, na, nb, ka, kb, &buf[off + SUB_HEADER_LEN..], slot)?;
        sub_lens.push(SUB_HEADER_LEN + consumed);
        off += SUB_HEADER_LEN + consumed;
    }
    if off != buf.len() {
        return Err(WireError::LengthMismatch {
            expected: off,
            got: buf.len(),
        });
    }
    if !any_rice && (ka != 0 || kb != 0) {
        return Err(WireError::NonZeroReserved(if ka != 0 { ka } else { kb }));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngkit::RandArray;
    use crate::sparsify::{greedy_probs, sample_sparse};

    fn sample_layer(d: usize, rho: f32, seed: u64) -> SparseGrad {
        let mut rng = crate::rngkit::Xoshiro256pp::seed_from_u64(seed);
        let g: Vec<f32> = (0..d).map(|_| (rng.next_gaussian() * 0.5) as f32).collect();
        let mut p = Vec::new();
        let pv = greedy_probs(&g, rho, 2, &mut p);
        let mut ra = RandArray::from_seed(seed ^ 1, 1 << 16);
        sample_sparse(&g, &p, pv.inv_lambda, &mut ra)
    }

    fn roundtrip(layers: &[SparseGrad], codec: WireCodec) -> (Vec<u8>, Vec<usize>) {
        let refs: Vec<&SparseGrad> = layers.iter().collect();
        let mut buf = Vec::new();
        encode_batch(&refs, codec, &mut buf);
        assert_eq!(buf.len(), encoded_batch_len(&refs, codec), "{codec}");
        let mut back = Vec::new();
        let mut sub_lens = Vec::new();
        decode_batch_into(&buf, &mut back, &mut sub_lens).unwrap_or_else(|e| {
            panic!("batch decode failed under {codec}: {e}");
        });
        assert_eq!(back.len(), layers.len());
        for (l, (a, b)) in layers.iter().zip(&back).enumerate() {
            assert_eq!(a, b, "layer {l} drifted under {codec}");
        }
        assert_eq!(
            sub_lens.iter().sum::<usize>() + BATCH_HEADER_LEN,
            buf.len(),
            "sub lengths must tile the batch"
        );
        (buf, sub_lens)
    }

    #[test]
    fn multi_layer_roundtrips_both_codecs() {
        let layers = vec![
            sample_layer(4096, 0.01, 7),
            SparseGrad::empty(100),
            sample_layer(257, 0.9, 8), // d % 4 != 0, DenseSymbols
            sample_layer(1 << 14, 0.02, 9),
        ];
        for codec in [WireCodec::Raw, WireCodec::Entropy] {
            roundtrip(&layers, codec);
        }
    }

    #[test]
    fn empty_batch_and_single_layer_batch() {
        for codec in [WireCodec::Raw, WireCodec::Entropy] {
            let (buf, _) = roundtrip(&[], codec);
            assert_eq!(buf.len(), BATCH_HEADER_LEN);
            roundtrip(&[SparseGrad::empty(1)], codec);
            roundtrip(&[sample_layer(2048, 0.05, 11)], codec);
        }
    }

    #[test]
    fn raw_batch_beats_per_layer_headers() {
        // Under the raw codec the sub-payloads are byte-identical to the
        // single-message payloads, so the batch wins exactly the header
        // bytes: 17 per layer instead of 24, plus one 12-byte batch header.
        let layers = vec![
            sample_layer(2048, 0.02, 21),
            sample_layer(1024, 0.05, 22),
            sample_layer(512, 0.1, 23),
        ];
        let refs: Vec<&SparseGrad> = layers.iter().collect();
        let batch = encoded_batch_len(&refs, WireCodec::Raw);
        let singles: usize = layers
            .iter()
            .map(|sg| super::super::encoded_len_with(sg, WireCodec::Raw))
            .sum();
        assert_eq!(
            batch,
            singles + BATCH_HEADER_LEN
                - layers.len() * (super::super::HEADER_LEN - SUB_HEADER_LEN),
        );
        assert!(batch < singles);
    }

    #[test]
    fn entropy_batch_never_larger_than_raw_batch() {
        let layers: Vec<SparseGrad> = (0..4).map(|i| sample_layer(1 << 13, 0.02, 30 + i)).collect();
        let refs: Vec<&SparseGrad> = layers.iter().collect();
        let raw = encoded_batch_len(&refs, WireCodec::Raw);
        let ent = encoded_batch_len(&refs, WireCodec::Entropy);
        assert!(ent <= raw, "entropy batch {ent} > raw batch {raw}");
        // At this sparsity Rice must actually engage.
        let mut buf = Vec::new();
        encode_batch(&refs, WireCodec::Entropy, &mut buf);
        assert!(buf[6] > 0 || buf[7] > 0, "expected shared Rice params");
        assert!(ent < raw);
    }

    #[test]
    fn rejects_malformed_batches() {
        let layers = vec![sample_layer(512, 0.05, 41), SparseGrad::empty(9)];
        let refs: Vec<&SparseGrad> = layers.iter().collect();
        let mut buf = Vec::new();
        encode_batch(&refs, WireCodec::Raw, &mut buf);
        let mut out = Vec::new();
        let mut lens = Vec::new();

        let mut bad = buf.clone();
        bad[0] = b'X';
        assert_eq!(
            decode_batch_into(&bad, &mut out, &mut lens),
            Err(WireError::BadMagic)
        );
        let mut bad = buf.clone();
        bad[4] = 9;
        assert_eq!(
            decode_batch_into(&bad, &mut out, &mut lens),
            Err(WireError::BadVersion(9))
        );
        let mut bad = buf.clone();
        bad[5] = 7; // unknown codec byte
        assert_eq!(
            decode_batch_into(&bad, &mut out, &mut lens),
            Err(WireError::BadEncoding(7))
        );
        // Raw batch with nonzero Rice parameters is non-canonical.
        let mut bad = buf.clone();
        bad[6] = 3;
        assert_eq!(
            decode_batch_into(&bad, &mut out, &mut lens),
            Err(WireError::NonZeroReserved(3))
        );
        // Hostile layer count: not backed by payload bytes.
        let mut bad = buf.clone();
        bad[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            decode_batch_into(&bad, &mut out, &mut lens),
            Err(WireError::Truncated(_))
        ));
        // Trailing bytes after the final sub-message.
        let mut bad = buf.clone();
        bad.push(0);
        assert!(matches!(
            decode_batch_into(&bad, &mut out, &mut lens),
            Err(WireError::LengthMismatch { .. })
        ));
        // An empty sub-message claiming the Rice encoding is non-canonical
        // (it would let the shared-parameter header bytes float freely).
        let empty = vec![SparseGrad::empty(9)];
        let refs: Vec<&SparseGrad> = empty.iter().collect();
        let mut ebuf = Vec::new();
        encode_batch(&refs, WireCodec::Entropy, &mut ebuf);
        let sub0_enc = BATCH_HEADER_LEN; // first sub-message's encoding byte
        assert_eq!(ebuf[sub0_enc], Encoding::Indexed as u8);
        let mut bad = ebuf.clone();
        bad[sub0_enc] = Encoding::IndexedRice as u8;
        assert_eq!(
            decode_batch_into(&bad, &mut out, &mut lens),
            Err(WireError::BadRiceStream("empty rice message"))
        );
        // Truncation anywhere inside the sub-messages.
        assert!(decode_batch_into(&buf[..buf.len() - 1], &mut out, &mut lens).is_err());
        assert!(decode_batch_into(&buf[..BATCH_HEADER_LEN + 3], &mut out, &mut lens).is_err());
    }

    #[test]
    fn decode_reuses_buffers_across_batches() {
        let big = vec![sample_layer(4096, 0.2, 50), sample_layer(2048, 0.1, 51)];
        let small = vec![SparseGrad::empty(7)];
        let mut buf = Vec::new();
        let mut out = Vec::new();
        let mut lens = Vec::new();
        let refs: Vec<&SparseGrad> = big.iter().collect();
        encode_batch(&refs, WireCodec::Raw, &mut buf);
        decode_batch_into(&buf, &mut out, &mut lens).unwrap();
        assert_eq!(out, big);
        let refs: Vec<&SparseGrad> = small.iter().collect();
        encode_batch(&refs, WireCodec::Raw, &mut buf);
        decode_batch_into(&buf, &mut out, &mut lens).unwrap();
        assert_eq!(out, small);
        assert_eq!(lens.len(), 1);
    }

    #[test]
    fn property_batches_roundtrip_bitwise() {
        crate::proptest_lite::run("wire-batch roundtrip is exact", 48, |gen| {
            let nlayers = gen.usize_in(0, 6);
            let layers: Vec<SparseGrad> = (0..nlayers)
                .map(|_| {
                    let d = gen.usize_in(1, 1500);
                    if gen.bool() {
                        SparseGrad::empty(d)
                    } else {
                        let rho = gen.f32_in(0.01, 1.0);
                        let g = gen.gradient_vec(d);
                        let mut p = Vec::new();
                        let pv = greedy_probs(&g, rho, 2, &mut p);
                        let mut ra = RandArray::new(
                            crate::rngkit::Xoshiro256pp::seed_from_u64(gen.u64()),
                            1 << 14,
                        );
                        sample_sparse(&g, &p, pv.inv_lambda, &mut ra)
                    }
                })
                .collect();
            let refs: Vec<&SparseGrad> = layers.iter().collect();
            for codec in [WireCodec::Raw, WireCodec::Entropy] {
                let mut buf = Vec::new();
                encode_batch(&refs, codec, &mut buf);
                if buf.len() != encoded_batch_len(&refs, codec) {
                    return Err(format!("length mismatch under {codec}"));
                }
                let mut back = Vec::new();
                let mut lens = Vec::new();
                if let Err(e) = decode_batch_into(&buf, &mut back, &mut lens) {
                    return Err(format!("decode failed under {codec}: {e}"));
                }
                if back != layers {
                    return Err(format!("roundtrip not identical under {codec}"));
                }
            }
            Ok(())
        });
    }
}
