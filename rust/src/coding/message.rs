//! The actual byte wire format for sparsified gradients (what the simulated
//! All-Reduce ships between workers).
//!
//! ```text
//! offset  size  field
//! 0       4     magic "GSPR"
//! 4       1     version (1)
//! 5       1     encoding (0 = Indexed, 1 = DenseSymbols)
//! 6       2     reserved (0)
//! 8       4     d            (u32 LE)
//! 12      4     nnz_a        (u32 LE)
//! 16      4     nnz_b        (u32 LE)
//! 20      4     shared_mag   (f32 LE, = 1/λ)
//! 24      ...   payload
//! ```
//!
//! * Indexed payload: `nnz_a × (u32 index, f32 value)`, then `nnz_b × u32`
//!   QB indices, then `⌈nnz_b/8⌉` bytes of QB sign bitmap (bit set ⇒
//!   negative).
//! * DenseSymbols payload: `⌈d/4⌉` bytes of 2-bit symbols in coordinate
//!   order (0 dropped, 1 = +shared, 2 = −shared, 3 = exact), then `nnz_a`
//!   f32 values for the exact coordinates in ascending coordinate order.
//!
//! [`encode`] picks the smaller of the two encodings, exactly like the
//! `min(·,·)` in Theorem 4.

use crate::sparsify::SparseGrad;

pub const MAGIC: &[u8; 4] = b"GSPR";
pub const VERSION: u8 = 1;
/// Fixed header length in bytes.
pub const HEADER_LEN: usize = 24;

/// Which payload layout a message uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Encoding {
    Indexed = 0,
    DenseSymbols = 1,
}

/// Wire-format decode errors. (`Display`/`Error` are hand-written: the
/// offline image has no `thiserror`.)
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WireError {
    Truncated(usize),
    BadMagic,
    BadVersion(u8),
    BadEncoding(u8),
    LengthMismatch { expected: usize, got: usize },
    IndexOutOfBounds { index: u32, d: u32 },
    IndicesNotSorted(usize),
    /// Header claims more survivors than coordinates (`na + nb > d`) — an
    /// adversarial or corrupted message; rejected before any buffer grows.
    CountsExceedDim { na: u32, nb: u32, d: u32 },
    /// `shared_mag` is NaN or ±∞ — decoding would poison every QB
    /// coordinate, so the message is rejected at the header.
    NonFiniteSharedMag(f32),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated(n) => write!(f, "message too short: {n} bytes"),
            WireError::BadMagic => write!(f, "bad magic"),
            WireError::BadVersion(v) => write!(f, "unsupported version {v}"),
            WireError::BadEncoding(e) => write!(f, "unknown encoding {e}"),
            WireError::LengthMismatch { expected, got } => {
                write!(f, "payload length mismatch: expected {expected}, got {got}")
            }
            WireError::IndexOutOfBounds { index, d } => {
                write!(f, "index {index} out of bounds (d = {d})")
            }
            WireError::IndicesNotSorted(pos) => {
                write!(f, "indices not strictly ascending at position {pos}")
            }
            WireError::CountsExceedDim { na, nb, d } => {
                write!(f, "survivor counts {na} + {nb} exceed dimension {d}")
            }
            WireError::NonFiniteSharedMag(v) => {
                write!(f, "shared magnitude {v} is not finite")
            }
        }
    }
}

impl std::error::Error for WireError {}

fn indexed_payload_len(nnz_a: usize, nnz_b: usize) -> usize {
    nnz_a * 8 + nnz_b * 4 + nnz_b.div_ceil(8)
}

fn dense_payload_len(d: usize, nnz_a: usize) -> usize {
    d.div_ceil(4) + nnz_a * 4
}

/// Byte length [`encode`] will produce for `sg` (header + cheaper payload).
pub fn encoded_len(sg: &SparseGrad) -> usize {
    HEADER_LEN
        + indexed_payload_len(sg.exact.len(), sg.shared.len())
            .min(dense_payload_len(sg.d as usize, sg.exact.len()))
}

/// Encode into `out` (cleared first; capacity is reused across calls, so a
/// steady-state encode performs no heap allocation). Returns the encoding
/// chosen.
pub fn encode(sg: &SparseGrad, out: &mut Vec<u8>) -> Encoding {
    let d = sg.d as usize;
    let (na, nb) = (sg.exact.len(), sg.shared.len());
    // Header math lives in one place: compute both payload lengths once,
    // pick the cheaper encoding, and reserve via the same `encoded_len`
    // formula the tests check against.
    let indexed_len = indexed_payload_len(na, nb);
    let dense_len = dense_payload_len(d, na);
    let enc = if indexed_len <= dense_len {
        Encoding::Indexed
    } else {
        Encoding::DenseSymbols
    };
    out.clear();
    out.reserve(encoded_len(sg));
    out.extend_from_slice(MAGIC);
    out.push(VERSION);
    out.push(enc as u8);
    out.extend_from_slice(&[0, 0]);
    out.extend_from_slice(&(sg.d).to_le_bytes());
    out.extend_from_slice(&(na as u32).to_le_bytes());
    out.extend_from_slice(&(nb as u32).to_le_bytes());
    out.extend_from_slice(&sg.shared_mag.to_le_bytes());

    match enc {
        Encoding::Indexed => {
            // Pre-size once and write at offsets: avoids per-entry capacity
            // checks (measured 2.5x on the encode hot path — see
            // EXPERIMENTS.md §Perf).
            let start = out.len();
            out.resize(start + indexed_len, 0);
            let payload = &mut out[start..];
            let mut off = 0;
            for &(i, v) in &sg.exact {
                payload[off..off + 4].copy_from_slice(&i.to_le_bytes());
                payload[off + 4..off + 8].copy_from_slice(&v.to_le_bytes());
                off += 8;
            }
            for &(i, _) in &sg.shared {
                payload[off..off + 4].copy_from_slice(&i.to_le_bytes());
                off += 4;
            }
            for (pos, &(_, neg)) in sg.shared.iter().enumerate() {
                if neg {
                    payload[off + pos / 8] |= 1 << (pos % 8);
                }
            }
        }
        Encoding::DenseSymbols => {
            // 2-bit symbols, written in place in the output buffer (no
            // temporary allocation on the hot path).
            let sym_start = out.len();
            out.resize(sym_start + d.div_ceil(4), 0);
            {
                let symbols = &mut out[sym_start..];
                for &(i, _) in &sg.exact {
                    let i = i as usize;
                    symbols[i / 4] |= 0b11 << (2 * (i % 4));
                }
                for &(i, neg) in &sg.shared {
                    let i = i as usize;
                    let sym = if neg { 0b10 } else { 0b01 };
                    symbols[i / 4] |= sym << (2 * (i % 4));
                }
            }
            for &(_, v) in &sg.exact {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
    enc
}

/// Decode a wire message back into a fresh [`SparseGrad`]. Validates
/// structure and rejects malformed input (the failure-injection tests
/// exercise every arm).
pub fn decode(buf: &[u8]) -> Result<SparseGrad, WireError> {
    let mut sg = SparseGrad::empty(0);
    decode_into(buf, &mut sg)?;
    Ok(sg)
}

/// Decode into a caller-provided [`SparseGrad`], reusing its buffers (the
/// allocation-free path the [`crate::comm::Aggregator`] and coordinator use
/// every round). On error `sg` may hold partially-decoded content and must
/// not be interpreted.
pub fn decode_into(buf: &[u8], sg: &mut SparseGrad) -> Result<(), WireError> {
    if buf.len() < HEADER_LEN {
        return Err(WireError::Truncated(buf.len()));
    }
    if &buf[0..4] != MAGIC {
        return Err(WireError::BadMagic);
    }
    if buf[4] != VERSION {
        return Err(WireError::BadVersion(buf[4]));
    }
    let enc = match buf[5] {
        0 => Encoding::Indexed,
        1 => Encoding::DenseSymbols,
        e => return Err(WireError::BadEncoding(e)),
    };
    let d = u32::from_le_bytes(buf[8..12].try_into().unwrap());
    let na = u32::from_le_bytes(buf[12..16].try_into().unwrap()) as usize;
    let nb = u32::from_le_bytes(buf[16..20].try_into().unwrap()) as usize;
    let shared_mag = f32::from_le_bytes(buf[20..24].try_into().unwrap());
    // Adversarial-header gates (bytes may arrive from a socket): the
    // survivor counts must fit the dimension — checked before any reserve,
    // so a hostile header cannot trigger a huge allocation — and the shared
    // magnitude must be finite, or every QB coordinate would decode to
    // NaN/∞ and poison the weight vector.
    if na as u64 + nb as u64 > d as u64 {
        return Err(WireError::CountsExceedDim {
            na: na as u32,
            nb: nb as u32,
            d,
        });
    }
    if !shared_mag.is_finite() {
        return Err(WireError::NonFiniteSharedMag(shared_mag));
    }
    let payload = &buf[HEADER_LEN..];

    sg.reset(d as usize);
    sg.shared_mag = shared_mag;

    match enc {
        Encoding::Indexed => {
            let expected = indexed_payload_len(na, nb);
            if payload.len() != expected {
                return Err(WireError::LengthMismatch {
                    expected,
                    got: payload.len(),
                });
            }
            let mut off = 0;
            sg.exact.reserve(na);
            let mut prev: i64 = -1;
            for pos in 0..na {
                let i = u32::from_le_bytes(payload[off..off + 4].try_into().unwrap());
                let v = f32::from_le_bytes(payload[off + 4..off + 8].try_into().unwrap());
                off += 8;
                if i >= d {
                    return Err(WireError::IndexOutOfBounds { index: i, d });
                }
                if (i as i64) <= prev {
                    return Err(WireError::IndicesNotSorted(pos));
                }
                prev = i as i64;
                sg.exact.push((i, v));
            }
            let idx_end = off + nb * 4;
            let bitmap = &payload[idx_end..];
            sg.shared.reserve(nb);
            prev = -1;
            for pos in 0..nb {
                let i =
                    u32::from_le_bytes(payload[off + pos * 4..off + pos * 4 + 4].try_into().unwrap());
                if i >= d {
                    return Err(WireError::IndexOutOfBounds { index: i, d });
                }
                if (i as i64) <= prev {
                    return Err(WireError::IndicesNotSorted(pos));
                }
                prev = i as i64;
                let neg = bitmap[pos / 8] & (1 << (pos % 8)) != 0;
                sg.shared.push((i, neg));
            }
        }
        Encoding::DenseSymbols => {
            let expected = dense_payload_len(d as usize, na);
            if payload.len() != expected {
                return Err(WireError::LengthMismatch {
                    expected,
                    got: payload.len(),
                });
            }
            let symbols = &payload[..(d as usize).div_ceil(4)];
            let values = &payload[(d as usize).div_ceil(4)..];
            sg.exact.reserve(na);
            sg.shared.reserve(nb);
            let mut voff = 0;
            // Byte-at-a-time with a zero-byte fast path: 4 coordinates per
            // iteration, and all-dropped groups cost one compare.
            for (bi, &byte) in symbols.iter().enumerate() {
                if byte == 0 {
                    continue;
                }
                let base = (bi * 4) as u32;
                let mut rest = byte;
                for lane in 0..4u32 {
                    let sym = rest & 0b11;
                    rest >>= 2;
                    if sym == 0 {
                        continue;
                    }
                    let i = base + lane;
                    if i >= d {
                        break;
                    }
                    match sym {
                        0b01 => sg.shared.push((i, false)),
                        0b10 => sg.shared.push((i, true)),
                        _ => {
                            if voff + 4 > values.len() {
                                return Err(WireError::LengthMismatch {
                                    expected,
                                    got: payload.len(),
                                });
                            }
                            let v =
                                f32::from_le_bytes(values[voff..voff + 4].try_into().unwrap());
                            voff += 4;
                            sg.exact.push((i, v));
                        }
                    }
                }
            }
            if sg.exact.len() != na || sg.shared.len() != nb {
                return Err(WireError::LengthMismatch {
                    expected: na + nb,
                    got: sg.exact.len() + sg.shared.len(),
                });
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngkit::RandArray;
    use crate::sparsify::{greedy_probs, sample_sparse};

    fn sample_message(d: usize, rho: f32, seed: u64) -> SparseGrad {
        let mut rng = crate::rngkit::Xoshiro256pp::seed_from_u64(seed);
        let g: Vec<f32> = (0..d).map(|_| (rng.next_gaussian() * 0.5) as f32).collect();
        let mut p = Vec::new();
        let pv = greedy_probs(&g, rho, 2, &mut p);
        let mut ra = RandArray::from_seed(seed ^ 1, 1 << 16);
        sample_sparse(&g, &p, pv.inv_lambda, &mut ra)
    }

    #[test]
    fn roundtrip_indexed() {
        let sg = sample_message(1024, 0.02, 40); // sparse -> indexed
        let mut buf = Vec::new();
        let enc = encode(&sg, &mut buf);
        assert_eq!(enc, Encoding::Indexed);
        assert_eq!(buf.len(), encoded_len(&sg));
        let back = decode(&buf).unwrap();
        assert_eq!(back, sg);
    }

    #[test]
    fn roundtrip_dense_symbols() {
        let sg = sample_message(256, 0.9, 41); // dense -> symbol coding
        let mut buf = Vec::new();
        let enc = encode(&sg, &mut buf);
        assert_eq!(enc, Encoding::DenseSymbols);
        let back = decode(&buf).unwrap();
        assert_eq!(back, sg);
    }

    #[test]
    fn empty_message_roundtrip() {
        let sg = SparseGrad::empty(100);
        let mut buf = Vec::new();
        encode(&sg, &mut buf);
        assert_eq!(decode(&buf).unwrap(), sg);
    }

    #[test]
    fn rejects_truncated() {
        let sg = sample_message(128, 0.1, 42);
        let mut buf = Vec::new();
        encode(&sg, &mut buf);
        assert_eq!(decode(&buf[..10]), Err(WireError::Truncated(10)));
        let err = decode(&buf[..buf.len() - 1]).unwrap_err();
        assert!(matches!(err, WireError::LengthMismatch { .. }), "{err:?}");
    }

    #[test]
    fn rejects_bad_magic_version_encoding() {
        let sg = sample_message(128, 0.1, 43);
        let mut buf = Vec::new();
        encode(&sg, &mut buf);
        let mut bad = buf.clone();
        bad[0] = b'X';
        assert_eq!(decode(&bad), Err(WireError::BadMagic));
        let mut bad = buf.clone();
        bad[4] = 9;
        assert_eq!(decode(&bad), Err(WireError::BadVersion(9)));
        let mut bad = buf.clone();
        bad[5] = 7;
        assert_eq!(decode(&bad), Err(WireError::BadEncoding(7)));
    }

    #[test]
    fn rejects_out_of_bounds_index() {
        let mut sg = SparseGrad::empty(16);
        sg.exact.push((3, 1.0));
        let mut buf = Vec::new();
        encode(&sg, &mut buf);
        // Corrupt the index to 999 (little-endian at payload offset 0).
        buf[HEADER_LEN..HEADER_LEN + 4].copy_from_slice(&999u32.to_le_bytes());
        assert_eq!(
            decode(&buf),
            Err(WireError::IndexOutOfBounds { index: 999, d: 16 })
        );
    }

    #[test]
    fn rejects_unsorted_indices() {
        // d large enough that the Indexed encoding is chosen.
        let mut sg = SparseGrad::empty(1000);
        sg.exact.push((5, 1.0));
        sg.exact.push((9, 2.0));
        let mut buf = Vec::new();
        encode(&sg, &mut buf);
        // Swap index order.
        buf[HEADER_LEN..HEADER_LEN + 4].copy_from_slice(&9u32.to_le_bytes());
        buf[HEADER_LEN + 8..HEADER_LEN + 12].copy_from_slice(&5u32.to_le_bytes());
        assert!(matches!(
            decode(&buf),
            Err(WireError::IndicesNotSorted(_)) | Err(WireError::IndexOutOfBounds { .. })
        ));
    }

    #[test]
    fn rejects_counts_exceeding_dimension() {
        // Adversarial header: na + nb > d must be rejected *before* the
        // payload-length check (so no hostile reserve can happen either).
        let mut sg = SparseGrad::empty(16);
        sg.exact.push((3, 1.0));
        let mut buf = Vec::new();
        encode(&sg, &mut buf);
        buf[12..16].copy_from_slice(&12u32.to_le_bytes()); // na = 12
        buf[16..20].copy_from_slice(&5u32.to_le_bytes()); // nb = 5, 17 > 16
        assert_eq!(
            decode(&buf),
            Err(WireError::CountsExceedDim {
                na: 12,
                nb: 5,
                d: 16
            })
        );
        // Saturating case: both counts u32::MAX must not overflow the check.
        buf[12..16].copy_from_slice(&u32::MAX.to_le_bytes());
        buf[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            decode(&buf),
            Err(WireError::CountsExceedDim { .. })
        ));
    }

    #[test]
    fn rejects_non_finite_shared_mag() {
        let mut sg = SparseGrad::empty(64);
        sg.exact.push((1, 2.0));
        sg.shared.push((5, false));
        sg.shared.push((9, true));
        sg.shared_mag = 0.5;
        let mut buf = Vec::new();
        encode(&sg, &mut buf);
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut corrupt = buf.clone();
            corrupt[20..24].copy_from_slice(&bad.to_le_bytes());
            assert!(
                matches!(
                    decode(&corrupt),
                    Err(WireError::NonFiniteSharedMag(_))
                ),
                "shared_mag {bad} must be rejected"
            );
        }
    }

    #[test]
    fn encoder_picks_smaller_encoding() {
        for (d, rho) in [(4096, 0.01f32), (128, 0.8), (512, 0.25), (64, 1.0)] {
            let sg = sample_message(d, rho, 44 + d as u64);
            let mut buf = Vec::new();
            encode(&sg, &mut buf);
            let indexed = HEADER_LEN + indexed_payload_len(sg.exact.len(), sg.shared.len());
            let dense = HEADER_LEN + dense_payload_len(d, sg.exact.len());
            assert_eq!(buf.len(), indexed.min(dense), "d={d} rho={rho}");
        }
    }

    #[test]
    fn property_dense_symbols_roundtrip_unaligned_d() {
        // DenseSymbols packs 4 coordinates per byte; d % 4 != 0 leaves a
        // partial final byte whose high lanes must be ignored on decode.
        crate::proptest_lite::run("dense-symbol roundtrip, d % 4 != 0", 64, |gen| {
            let d = gen.usize_in(1, 500) * 4 + gen.usize_in(1, 4); // never ≡ 0 (mod 4)
            assert_ne!(d % 4, 0);
            // High density forces the DenseSymbols encoding.
            let sg = {
                let mut rng = crate::rngkit::Xoshiro256pp::seed_from_u64(gen.u64());
                let g: Vec<f32> = (0..d).map(|_| (rng.next_gaussian() * 0.5) as f32).collect();
                let mut p = Vec::new();
                let pv = greedy_probs(&g, 0.95, 2, &mut p);
                let mut ra = RandArray::from_seed(gen.u64(), 1 << 14);
                sample_sparse(&g, &p, pv.inv_lambda, &mut ra)
            };
            let mut buf = Vec::new();
            let enc = encode(&sg, &mut buf);
            if enc != Encoding::DenseSymbols {
                return Err(format!("expected DenseSymbols at d={d}, got {enc:?}"));
            }
            if buf.len() != encoded_len(&sg) {
                return Err(format!("encoded_len {} != {}", encoded_len(&sg), buf.len()));
            }
            match decode(&buf) {
                Ok(back) if back == sg => Ok(()),
                Ok(_) => Err(format!("roundtrip not identical at d={d}")),
                Err(e) => Err(format!("decode failed at d={d}: {e}")),
            }
        });
    }

    #[test]
    fn property_empty_and_zero_gradient_messages() {
        // Zero gradients and empty messages must roundtrip at any d,
        // including d % 4 != 0 and d = 1.
        crate::proptest_lite::run("empty/zero-gradient roundtrip", 64, |gen| {
            let d = gen.usize_in(1, 3000);
            let sg = if gen.bool() {
                SparseGrad::empty(d)
            } else {
                // Zero gradient through the full solver + sampler pipeline.
                let g = vec![0.0f32; d];
                let mut p = Vec::new();
                let pv = greedy_probs(&g, 0.5, 2, &mut p);
                let mut ra = RandArray::from_seed(gen.u64(), 1 << 12);
                sample_sparse(&g, &p, pv.inv_lambda, &mut ra)
            };
            if sg.nnz() != 0 {
                return Err("zero gradient produced survivors".into());
            }
            let mut buf = Vec::new();
            encode(&sg, &mut buf);
            match decode(&buf) {
                Ok(back) if back == sg => Ok(()),
                Ok(_) => Err("roundtrip not identical".into()),
                Err(e) => Err(format!("decode failed: {e}")),
            }
        });
    }

    #[test]
    fn decode_into_reuses_buffers_across_messages() {
        // A big message followed by a small one into the same SparseGrad:
        // the decode must fully reset length/contents (capacity persists).
        let big = sample_message(2048, 0.6, 90);
        let small = sample_message(64, 0.1, 91);
        let mut buf = Vec::new();
        let mut slot = SparseGrad::empty(0);
        encode(&big, &mut buf);
        decode_into(&buf, &mut slot).unwrap();
        assert_eq!(slot, big);
        let cap_before = slot.exact.capacity();
        encode(&small, &mut buf);
        decode_into(&buf, &mut slot).unwrap();
        assert_eq!(slot, small);
        assert!(slot.exact.capacity() >= cap_before, "capacity must be kept");
    }

    #[test]
    fn property_roundtrip_random_messages() {
        crate::proptest_lite::run("wire roundtrip is exact", 64, |gen| {
            let d = gen.usize_in(1, 2000);
            let rho = gen.f32_in(0.01, 1.0);
            let g = gen.gradient_vec(d);
            let mut p = Vec::new();
            let pv = greedy_probs(&g, rho, 2, &mut p);
            let mut ra = RandArray::new(
                crate::rngkit::Xoshiro256pp::seed_from_u64(gen.u64()),
                1 << 14,
            );
            let sg = sample_sparse(&g, &p, pv.inv_lambda, &mut ra);
            let mut buf = Vec::new();
            encode(&sg, &mut buf);
            if buf.len() != encoded_len(&sg) {
                return Err(format!("encoded_len {} != actual {}", encoded_len(&sg), buf.len()));
            }
            match decode(&buf) {
                Ok(back) if back == sg => Ok(()),
                Ok(_) => Err("roundtrip not identical".into()),
                Err(e) => Err(format!("decode failed: {e}")),
            }
        });
    }
}
